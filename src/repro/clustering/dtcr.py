"""DTCR-like deep clustering baseline (Ma et al., NeurIPS'19).

The paper compares TNN columns against DTCR ("Learning Representations for
Time Series Clustering"): a seq2seq GRU autoencoder whose bottleneck is
regularized by a k-means objective (plus an auxiliary fake-sample
classifier).  We implement the core of that recipe in JAX:

  encoder: bidirectional GRU -> final states -> representation h
  decoder: GRU reconstructing the series (teacher-forced)
  loss   : reconstruction MSE + lambda * soft k-means loss on h
           + fake-sample discrimination (shuffled-timestep negatives)

It is intentionally compact (the paper's point is that a *single TNN column*
gets within ~12% of this much heavier DNN) but is a real, trainable deep
baseline — used by benchmarks/table2_clustering.py for the DTCR column.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.clustering.kmeans import kmeans


@dataclasses.dataclass(frozen=True)
class DTCRConfig:
    hidden: int = 32
    n_clusters: int = 2
    lam_kmeans: float = 0.1
    lam_fake: float = 0.1
    lr: float = 1e-2
    steps: int = 300
    seed: int = 0


def _gru_init(rng, in_dim, hidden):
    k1, k2, k3 = jax.random.split(rng, 3)
    scale = 1.0 / np.sqrt(max(in_dim + hidden, 1))
    return {
        "wz": jax.random.normal(k1, (in_dim + hidden, hidden)) * scale,
        "wr": jax.random.normal(k2, (in_dim + hidden, hidden)) * scale,
        "wh": jax.random.normal(k3, (in_dim + hidden, hidden)) * scale,
        "bz": jnp.zeros((hidden,)),
        "br": jnp.zeros((hidden,)),
        "bh": jnp.zeros((hidden,)),
    }


def _gru_cell(params, h, x):
    hx = jnp.concatenate([x, h], axis=-1)
    z = jax.nn.sigmoid(hx @ params["wz"] + params["bz"])
    r = jax.nn.sigmoid(hx @ params["wr"] + params["br"])
    hxr = jnp.concatenate([x, r * h], axis=-1)
    hh = jnp.tanh(hxr @ params["wh"] + params["bh"])
    return (1 - z) * h + z * hh


def _gru_scan(params, xs, h0, reverse=False):
    def step(h, x):
        h = _gru_cell(params, h, x)
        return h, h

    hT, hs = jax.lax.scan(step, h0, xs, reverse=reverse)
    return hT, hs


def init_params(rng, cfg: DTCRConfig):
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    h = cfg.hidden
    return {
        "enc_fwd": _gru_init(k1, 1, h),
        "enc_bwd": _gru_init(k2, 1, h),
        "dec": _gru_init(k3, 1, 2 * h),
        "w_out": jax.random.normal(k4, (2 * h, 1)) * 0.1,
        "b_out": jnp.zeros((1,)),
        "w_cls": jax.random.normal(k5, (2 * h, 2)) * 0.1,
        "b_cls": jnp.zeros((2,)),
    }


def encode(params, x):
    """x: [B, L] -> representation [B, 2H]."""
    xs = x.T[:, :, None]  # [L, B, 1]
    B = x.shape[0]
    h = params["enc_fwd"]["bz"].shape[0]
    hf, _ = _gru_scan(params["enc_fwd"], xs, jnp.zeros((B, h)))
    hb, _ = _gru_scan(params["enc_bwd"], xs, jnp.zeros((B, h)), reverse=True)
    return jnp.concatenate([hf, hb], axis=-1)  # [B, 2H]


def decode(params, rep, L):
    """Autoregressive-teacher-free decoder: zero inputs, state=rep."""
    B = rep.shape[0]
    xs = jnp.zeros((L, B, 1))
    _, hs = _gru_scan(params["dec"], xs, rep)
    return (hs @ params["w_out"] + params["b_out"])[..., 0].T  # [B, L]


def _soft_kmeans_loss(rep, centers):
    d2 = ((rep[:, None, :] - centers[None]) ** 2).sum(-1)
    return jnp.min(d2, axis=1).mean()


def _make_fakes(rng, x, frac=0.2):
    """DTCR's fake samples: shuffle a fraction of timesteps."""
    B, L = x.shape
    n_swap = max(1, int(frac * L))
    idx = jax.random.randint(rng, (B, n_swap), 0, L)
    src = jax.random.randint(rng, (B, n_swap), 0, L)
    rows = jnp.arange(B)[:, None]
    return x.at[rows, idx].set(x[rows, src])


def fit_predict(x: np.ndarray, cfg: DTCRConfig) -> np.ndarray:
    """Train the DTCR-like model; returns cluster labels via k-means on the
    learned representation (the DTCR evaluation protocol)."""
    x = jnp.asarray(x, jnp.float32)
    x = (x - x.mean(axis=1, keepdims=True)) / (x.std(axis=1, keepdims=True) + 1e-6)
    rng = jax.random.key(cfg.seed)
    rng, kp = jax.random.split(rng)
    params = init_params(kp, cfg)
    B, L = x.shape

    # initial centers from random reps
    centers = jnp.asarray(
        np.random.default_rng(cfg.seed).normal(size=(cfg.n_clusters, 2 * cfg.hidden)),
        jnp.float32,
    )

    def loss_fn(p, centers, key):
        rep = encode(p, x)
        recon = decode(p, rep, L)
        l_rec = ((recon - x) ** 2).mean()
        l_km = _soft_kmeans_loss(rep, centers)
        fakes = _make_fakes(key, x)
        rep_f = encode(p, fakes)
        logits = jnp.concatenate([rep, rep_f]) @ p["w_cls"] + p["b_cls"]
        labels = jnp.concatenate([jnp.zeros((B,), jnp.int32), jnp.ones((B,), jnp.int32)])
        l_fake = -jnp.mean(
            jax.nn.log_softmax(logits)[jnp.arange(2 * B), labels]
        )
        return l_rec + cfg.lam_kmeans * l_km + cfg.lam_fake * l_fake

    @jax.jit
    def step(p, opt_m, opt_v, centers, key, t):
        g = jax.grad(loss_fn)(p, centers, key)
        # Adam
        b1, b2, eps = 0.9, 0.999, 1e-8
        opt_m = jax.tree.map(lambda m, gg: b1 * m + (1 - b1) * gg, opt_m, g)
        opt_v = jax.tree.map(lambda v, gg: b2 * v + (1 - b2) * gg**2, opt_v, g)
        mhat = jax.tree.map(lambda m: m / (1 - b1**t), opt_m)
        vhat = jax.tree.map(lambda v: v / (1 - b2**t), opt_v)
        p = jax.tree.map(
            lambda pp, m, v: pp - cfg.lr * m / (jnp.sqrt(v) + eps), p, mhat, vhat
        )
        return p, opt_m, opt_v

    @jax.jit
    def update_centers(p, centers):
        rep = encode(p, x)
        d2 = ((rep[:, None, :] - centers[None]) ** 2).sum(-1)
        assign = jax.nn.one_hot(jnp.argmin(d2, 1), cfg.n_clusters)
        cnt = assign.sum(0)[:, None]
        return jnp.where(cnt > 0, (assign.T @ rep) / jnp.maximum(cnt, 1), centers)

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    for t in range(1, cfg.steps + 1):
        rng, key = jax.random.split(rng)
        params, m, v = step(params, m, v, centers, key, jnp.float32(t))
        if t % 10 == 0:
            centers = update_centers(params, centers)

    rep = np.asarray(encode(params, x))
    _, labels = kmeans(rep, cfg.n_clusters, seed=cfg.seed)
    return labels
