# Clustering evaluation substrate for the paper's Table II: rand index,
# k-means normalization baseline, and a DTCR-like deep baseline.
from repro.clustering import dtcr, kmeans, metrics  # noqa: F401
