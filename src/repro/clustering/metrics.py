"""Clustering metrics: rand index (paper's Table II metric) and helpers."""
from __future__ import annotations

import numpy as np


def rand_index(labels_true: np.ndarray, labels_pred: np.ndarray) -> float:
    """Unadjusted Rand index between two labelings (paper follows [2]).

    RI = (#agreeing pairs) / (#pairs); computed from the contingency table
    in O(n_classes * n_clusters) without materializing pairs.
    """
    a = np.asarray(labels_true).ravel()
    b = np.asarray(labels_pred).ravel()
    if a.shape != b.shape:
        raise ValueError(f"shape mismatch {a.shape} vs {b.shape}")
    n = a.size
    if n < 2:
        return 1.0
    _, ai = np.unique(a, return_inverse=True)
    _, bi = np.unique(b, return_inverse=True)
    cont = np.zeros((ai.max() + 1, bi.max() + 1), np.int64)
    np.add.at(cont, (ai, bi), 1)
    sum_comb_c = (cont * (cont - 1) // 2).sum()
    sum_comb_a = (cont.sum(1) * (cont.sum(1) - 1) // 2).sum()
    sum_comb_b = (cont.sum(0) * (cont.sum(0) - 1) // 2).sum()
    total = n * (n - 1) // 2
    # pairs agreeing: both-same + both-different
    both_same = sum_comb_c
    both_diff = total - sum_comb_a - sum_comb_b + sum_comb_c
    return float((both_same + both_diff) / total)


def normalized_rand(ri: float, ri_kmeans: float) -> float:
    """Table II normalizes rand indices to k-means."""
    return ri / max(ri_kmeans, 1e-12)
