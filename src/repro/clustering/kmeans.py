"""k-means in JAX (the paper's normalization baseline for Table II)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def _plusplus_init(rng: np.random.Generator, x: np.ndarray, k: int) -> np.ndarray:
    """k-means++ seeding (numpy; tiny and sequential by nature)."""
    n = x.shape[0]
    centers = [x[rng.integers(n)]]
    for _ in range(1, k):
        d2 = np.min(
            ((x[:, None, :] - np.stack(centers)[None]) ** 2).sum(-1), axis=1
        )
        probs = d2 / max(d2.sum(), 1e-12)
        centers.append(x[rng.choice(n, p=probs)])
    return np.stack(centers)


@functools.partial(jax.jit, static_argnames=("iters",))
def _lloyd(x: jnp.ndarray, centers: jnp.ndarray, iters: int):
    def step(c, _):
        d2 = ((x[:, None, :] - c[None]) ** 2).sum(-1)  # [n, k]
        assign = jnp.argmin(d2, axis=1)
        onehot = jax.nn.one_hot(assign, c.shape[0], dtype=x.dtype)  # [n, k]
        counts = onehot.sum(0)  # [k]
        sums = onehot.T @ x  # [k, d]
        new_c = jnp.where(
            counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), c
        )
        return new_c, None

    centers, _ = jax.lax.scan(step, centers, None, length=iters)
    d2 = ((x[:, None, :] - centers[None]) ** 2).sum(-1)
    return centers, jnp.argmin(d2, axis=1)


def kmeans(
    x: np.ndarray, k: int, iters: int = 50, seed: int = 0, restarts: int = 4
) -> tuple[np.ndarray, np.ndarray]:
    """k-means with k-means++ init and restarts; returns (centers, labels)."""
    rng = np.random.default_rng(seed)
    xj = jnp.asarray(x, jnp.float32)
    best = None
    for _ in range(restarts):
        c0 = jnp.asarray(_plusplus_init(rng, np.asarray(x, np.float64), k), jnp.float32)
        centers, labels = _lloyd(xj, c0, iters)
        inertia = float(
            ((xj - centers[labels]) ** 2).sum()
        )
        if best is None or inertia < best[0]:
            best = (inertia, np.asarray(centers), np.asarray(labels))
    return best[1], best[2]
