"""Unified simulation-backend registry and central accelerator dispatch.

Before this module existed, ``core/column.py`` and ``kernels/ops.py`` were
two parallel implementations of the same column semantics, and every Pallas
entry point re-decided ``interpret=True`` on its own.  All execution-path
policy now lives here:

* **Registry** — three named backends sharing one contract:
    'event'  — closed-form event-driven solver (exact for RNL/SNL).
    'cycle'  — cycle-accurate lax.scan (bit-identical to generated RTL,
               required for LIF).
    'pallas' — the fused column step (``kernels/fused_column.py``): RNL fire
               + k-WTA + expected STDP in one kernel invocation.
  Each backend provides ``fire`` (batched post-WTA forward) and ``fit``
  (online STDP training as ONE jitted, donated lax.scan over epochs x
  volleys — a single compilation per config, no per-epoch dispatch).

* **Lowering policy** — ``pallas_interpret()`` / ``pallas_lowering()`` /
  ``padded_lowering()`` are the ONE place that inspects
  ``jax.default_backend()``.  On TPU the fused step compiles through Mosaic
  — including the padded-envelope scans (design sweep, network layers),
  whose per-design scalars are runtime SMEM operands of the kernel;
  elsewhere it lowers to the pure-jnp reference body (same algebra, same
  results) because the Pallas interpreter is a validation tool, not an
  execution engine.  Pass ``lowering='interpret'`` explicitly to validate
  the kernel off-TPU.

* **Resolution** — ``resolve(mode, cfg, training=...)`` maps the public
  ``mode`` knob ('auto' | 'event' | 'cycle' | 'pallas') to a registry name.
  'auto' keeps the paper's hybrid forward semantics (event where exact,
  cycle for LIF) and routes *training* to the fused path whenever the
  config fits its contract (RNL, expected STDP, index tie-break).

Multi-layer networks (``repro.core.network``) resolve here too, layer by
layer against each layer's column config.  The full contract is documented
in ``docs/backends.md``.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import os
import pickle
import warnings
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.core import neuron, stdp, wta
from repro.core.types import ColumnConfig, TIME_DTYPE
from repro.kernels import fused_column


# ----------------------------------------------------------- central policy
def on_tpu() -> bool:
    """True iff jax is executing on TPU.  The ONLY backend probe."""
    return jax.default_backend() == "tpu"


def pallas_interpret() -> bool:
    """Central ``interpret`` decision for raw Pallas kernel entry points."""
    return not on_tpu()


def pallas_lowering() -> str:
    """How the fused column step should lower on this host.

    'mosaic' on TPU (real kernels), 'reference' elsewhere — the jnp body of
    the same fused step; the interpreter is only ever chosen explicitly.
    """
    return "mosaic" if on_tpu() else "reference"


def padded_lowering(response: str) -> str:
    """Response-aware lowering for the fused (padded-kernel) paths.

    The Mosaic kernel takes the per-design scalars (threshold, t_max,
    live q, STDP mus) as runtime SMEM operands, so padded heterogeneous
    batches — the design sweep and network layer training — run the real
    kernel on TPU; single-column 'pallas' entry points resolve here too
    (they are the D=1 slice of the same kernel).  The kernel implements
    the RNL plane decomposition only; SNL lowers to the reference body of
    the same algebra everywhere (bit-identical on integer weight grids, so
    this is a lowering choice, not a semantic switch).  The interpreter is
    never chosen here — validation passes ``lowering='interpret'``
    explicitly.

    This is the ``lowering`` input of every :func:`execution_plan` — the
    plan RECORDS the lowering it was chosen for (plan metadata surfaces
    it in bench rows / serve stats), it never overrides it: which algebra
    body runs is a correctness-scoped decision, which blocking it runs
    under is the cost model's.
    """
    low = pallas_lowering()
    if response in fused_column.fire_responses(low):
        return low
    return "reference"


def volley_block(
    lowering: str, n_volleys: int, d: Optional[int] = None
) -> int:
    """Hand-tuned fallback volley-block size for the blocked fused scans.

    Since the cost model landed this is the CONSTANTS HALF of the block
    policy: :func:`execution_plan` consults the device-calibrated cost
    model (``roofline.costmodel``) when a calibration is active and falls
    back to exactly these numbers when none is — un-calibrated hosts (and
    every existing test pin) behave as before.  Prefer
    ``execution_plan(...).v_blk`` in new code; call this directly only to
    name the constants themselves (the bench head-to-heads do).

    The padded training scan (``fused_column.fit_scan_padded``) advances
    ``v_blk`` volleys per outer scan step; this is the ONE place the
    fallback block size is decided.  Kernel lowerings fold the block inside
    a single kernel invocation (an in-kernel ``fori_loop`` with the weight
    buffer VMEM-resident), so a larger block amortizes kernel launches and
    HBM weight round-trips at no code-size cost.  The reference lowering
    statically *unrolls* the block into one fused XLA body — the block
    must stay small enough that compile time and the unrolled graph stay
    bounded (8 is the measured CPU sweet spot; beyond ~16 the win
    regresses), and when the caller knows the design-axis length ``d`` of
    the padded batch, the block is additionally capped at
    ``max(2, 2 * d)``: small-D batches get cheap traces, large-D batches
    keep the full block.  Clamped to the stream length so a short fit
    never pays for block-tail padding.  Blocking is a throughput knob
    only — results are bit-identical for every block size.
    """
    base = 8 if lowering == "reference" else 32
    if d is not None and lowering == "reference":
        # Envelope-aware unroll cap: the reference block's compile time
        # grows ~linearly with v_blk (each unrolled volley is another copy
        # of the fused body in ONE XLA computation) while warm throughput
        # is flat past a couple of volleys once the design axis is small —
        # measured on the bench geometries, v_blk 8 -> 2 cuts the cold
        # trace ~3x at D <= 2 with warm time unchanged.  So a 1-column
        # network layer or a 2-design DSE bucket must not pay the full
        # 8-volley unroll; at D >= 4 the cap leaves the block at 8, which
        # keeps every PR 4/5 warm number intact.
        base = min(base, max(2, 2 * int(d)))
    return max(1, min(base, int(n_volleys)))


# Lane-aligned kernel time-block fallback (the old hard-coded keyword
# default of every padded entry point; still the constants-policy choice).
DEFAULT_T_BLK = 128


def execution_plan(
    kind: str,
    lowering: str,
    d: int,
    p_pad: int,
    q_pad: int,
    t_window: int,
    n_volleys: int,
    epochs: int = 1,
    *,
    w_max: Optional[int] = None,
    response: str = "rnl",
):
    """The ONE policy front door: an ``ExecutionPlan`` for a padded scan.

    Every knob the fused paths used to pick from scattered constants —
    ``volley_block``'s 8/32, the ``t_blk=128`` default, the envelope
    waste cap, the shard count — now routes through here.  With a device
    calibration active (``roofline.costmodel.load_or_calibrate()``; the
    benches and launchers opt in, libraries and tests never do
    implicitly) the plan minimizes roofline-predicted step time subject
    to the profile's footprint bound; without one it packages the
    hand-tuned constants verbatim (``plan.source == 'constants'``), so
    un-calibrated behavior is bit-for-bit the pre-costmodel policy.

    Deterministic for fixed inputs and memoized per profile, so a warmed
    executable key (``warm_fit_padded``) and a traffic-time key
    (``fit_padded``) resolve the SAME blocking by construction — the
    zero-compile-after-warmup guarantee survives the policy swap.  Plans
    change blocking/sharding only, never semantics: every candidate is
    bit-identical (the ``v_blk``/``t_blk``/shard contracts in
    ``docs/kernels.md``), so a mis-calibrated model can cost time, not
    correctness.  See ``docs/costmodel.md``.
    """
    from repro.roofline import costmodel

    return costmodel.choose_plan(
        kind, lowering, int(d), int(p_pad), int(q_pad), int(t_window),
        int(n_volleys), int(epochs),
        w_max=int(w_max) if w_max is not None else 7,
        response=response,
    )


def _plan_blocks(
    kind: str,
    lowering: str,
    d: int,
    p_pad: int,
    q_pad: int,
    t_window: int,
    n_volleys: int,
    epochs: int,
    w_max: Optional[int],
    response: str,
    v_blk: Optional[int],
    t_blk: Optional[int],
) -> tuple[int, int]:
    """Resolve the (v_blk, t_blk) a padded entry point should run under:
    caller-pinned values win untouched; unset knobs come from the plan
    (cost model when calibrated, the documented constants otherwise)."""
    if v_blk is not None and t_blk is not None:
        return int(v_blk), int(t_blk)
    plan = execution_plan(
        kind, lowering, d, p_pad, q_pad, t_window, n_volleys, epochs,
        w_max=w_max, response=response,
    )
    return (
        int(v_blk) if v_blk is not None else plan.v_blk,
        int(t_blk) if t_blk is not None else plan.t_blk,
    )


def assign_lowering(response: str, w) -> str:
    """Lowering for the batched assignment pass, given the trained weights.

    The assignment kernel fires on the integer weight grid (its one-hot
    plane decomposition needs integral weights), while the reference body
    keeps the established float-weight fire.  That makes the kernel a pure
    *lowering* choice only when the weights already sit on the grid — true
    after integer-mu, unstabilized training from integer init, checked
    concretely here — and a semantic switch otherwise, so off-grid weights
    always take the reference body, on every host.  ``w`` must be a
    concrete array (call this outside jit); abstract values (tracers)
    fall back to 'reference'.
    """
    low = padded_lowering(response)
    if low == "reference":
        return low
    try:
        # concreteness probe: under a trace this bool() raises instead of
        # answering, which is exactly the "not concrete" signal we need —
        # no reliance on tracer internals
        on_grid = bool(jnp.all(w == jnp.round(w)))
    except jax.errors.ConcretizationTypeError:
        return "reference"
    return low if on_grid else "reference"


# ------------------------------------------------- lowering degradation
# Fused-scan lowerings ordered top (fastest, most machinery) to bottom
# (plainest): a failing rung re-resolves one level down.  'cycle' sits
# below them all but is a *solver*, not a lowering of the fused step —
# it only joins a ladder when it is provably bit-identical for the
# design at hand (``cycle_exact``), because a fallback may change how a
# result is computed, never what it is.
LOWERING_LADDER = ("mosaic", "interpret", "reference")

# Bound on degradation attempts per evaluation: at most every rung of the
# ladder below the starting lowering, plus the optional 'cycle' solver
# rung.  There is no "try the same rung twice" retry — the scans are
# deterministic, so an identical retry reproduces the identical failure.
MAX_EVAL_RETRIES = len(LOWERING_LADDER)


def lowering_ladder(start: str, cycle_exact: bool = False) -> tuple[str, ...]:
    """Degradation ladder for a fused evaluation starting at ``start``.

    The central retry policy for fault-tolerant sweeps
    (``simulator.cluster_time_series_many(on_error='isolate')`` and
    ``dse.explore``): when a rung fails — a Mosaic lowering error, an OOM,
    a kernel miscompile guard — the evaluation re-resolves one rung down
    and retries, bounded by the ladder length (``MAX_EVAL_RETRIES``).
    Every fused rung computes the same algebra (bit-identical on any
    host, see ``docs/backends.md``), so degradation changes *how* a
    result is produced, never the result.

    ``cycle_exact=True`` appends the 'cycle' solver as a last rung; pass
    it only when ``cycle_exact(cfg, w0)`` holds — i.e. the solver is
    bit-identical to the fused path for this design — otherwise the
    ladder ends at 'reference' and an evaluation failing every rung is
    quarantined rather than silently re-scored under different fire
    semantics.
    """
    if start == "cycle":
        return ("cycle",)
    if start in LOWERING_LADDER:
        rungs = LOWERING_LADDER[LOWERING_LADDER.index(start):]
        # the interpreter is validation-only: never auto-degrade INTO it,
        # only out of it when a caller started there explicitly
        rungs = tuple(r for r in rungs if r == start or r != "interpret")
    else:
        raise ValueError(
            f"unknown lowering: {start!r} (have {LOWERING_LADDER + ('cycle',)})"
        )
    return rungs + (("cycle",) if cycle_exact else ())


# Degraded-mode backoff for the ONLINE re-fit path (the serving analogue
# of MAX_EVAL_RETRIES): after the k-th consecutive re-fit failure a
# bucket sits out 2^(k-1) re-fit windows — capped so a long outage never
# pushes the retry horizon out indefinitely — and keeps serving from its
# last-good weights in the meantime.
REFIT_BACKOFF_CAP = 8


def refit_backoff(failures: int) -> int:
    """Re-fit windows to sit out after the ``failures``-th consecutive
    online re-fit failure (central policy; the streaming service consumes
    this through its degraded mode, see ``docs/serving.md``)."""
    return int(min(2 ** (max(int(failures), 1) - 1), REFIT_BACKOFF_CAP))


def cycle_exact(cfg: ColumnConfig, w0) -> bool:
    """True iff the 'cycle' solver is bit-identical to the fused path for
    this design, making it a legal bottom rung of the degradation ladder.

    The fused fire rounds weights to the integer grid {0..w_max}; the
    solvers fire on float weights.  The two coincide exactly when
    training keeps the weights on the grid: integer STDP steps, no
    stabilizer, and init weights already integral (checked concretely,
    like ``assign_lowering`` — abstract weights answer False).
    """
    s = cfg.stdp
    if s.stabilizer != "none" or s.mode != "expected":
        return False
    if not all(
        float(mu).is_integer()
        for mu in (s.mu_capture, s.mu_backoff, s.mu_search)
    ):
        return False
    try:
        return bool(jnp.all(w0 == jnp.round(w0)))
    except jax.errors.ConcretizationTypeError:
        return False


# ---------------------------------------------------- bucket / shard policy
# A design joins a shared padding envelope only while padding inflates no
# member's per-volley fire volume (p * q * t_max) beyond this factor:
# sharing one compiled step saves a one-time compilation, but padded FLOPs
# recur every volley of every fit, so a tiny design must never ride a huge
# design's envelope.  Shared by heterogeneous design sweeps
# (``simulator.cluster_time_series_many``) and network layer grouping
# (``network._fused_envelopes``).
ENVELOPE_WASTE_CAP = 4.0


def envelope_buckets(
    shapes: Sequence[tuple[int, int, int]],
    waste_cap: Optional[float] = None,
    max_bucket: Optional[int] = None,
    n_volleys: Optional[int] = None,
    epochs: int = 1,
) -> list[tuple[tuple[int, int, int], list[int]]]:
    """Pack (p, q, t_max) design shapes into shared padding envelopes.

    Members pack greedily (largest fire volume first) into buckets whose
    envelope is the elementwise max of its members' shapes, subject to two
    caps:

    * ``waste_cap`` (None -> plan policy): with a device calibration
      active AND a stream length hint (``n_volleys``/``epochs``), the cap
      comes from the cost model's compile-vs-recurring-waste break-even
      (``costmodel.choose_waste_cap`` — padding waste recurs every
      volley, sharing an envelope saves one compile, so short streams
      tolerate more waste than long ones); otherwise the hand-tuned
      ``ENVELOPE_WASTE_CAP`` constant.  Either way the cap bounds how far
      padding may inflate any member's per-volley fire volume —
      size-compatible designs share one compiled scan, badly mismatched
      ones get their own envelope (and their own, cheap, compilation).
    * ``max_bucket`` (None -> unbounded): upper bound on designs per
      bucket.  Bounds the working set of one compiled sweep (the padded
      volley/assignment buffers scale with the bucket's design axis) and
      keeps the design axis shard-friendly.  Buckets whose envelope
      shapes AND member counts coincide (e.g. same-shape designs split
      into full ``max_bucket`` groups) share one compiled trace via the
      ordinary jit cache; an unequal-sized tail bucket is its own trace.

    Returns ``[(envelope, member_indices), ...]``; every input index
    appears in exactly one bucket.  Bucketing never changes results — each
    design's padded scan is bit-identical under any envelope that contains
    it (the padding contract in ``docs/kernels.md``).
    """
    if waste_cap is None:
        waste_cap = ENVELOPE_WASTE_CAP
        if n_volleys is not None and shapes:
            from repro.roofline import costmodel

            pm = max(p for (p, _, _) in shapes)
            qm = max(q for (_, q, _) in shapes)
            tm = max(t for (_, _, t) in shapes)
            waste_cap = costmodel.choose_waste_cap(
                None, len(shapes), pm, qm, tm,
                n_volleys=int(n_volleys), epochs=int(epochs),
            )
    vols = [p * q * t for (p, q, t) in shapes]
    order = sorted(range(len(shapes)), key=lambda i: -vols[i])
    buckets: list[tuple[tuple[int, int, int], list[int]]] = []
    for i in order:
        p, q, t = shapes[i]
        placed = False
        for bi, (env, members) in enumerate(buckets):
            if max_bucket is not None and len(members) >= max_bucket:
                continue
            cand = (max(env[0], p), max(env[1], q), max(env[2], t))
            vol = cand[0] * cand[1] * cand[2]
            if all(vol <= waste_cap * vols[m] for m in members + [i]):
                buckets[bi] = (cand, members + [i])
                placed = True
                break
        if not placed:
            buckets.append(((p, q, t), [i]))
    return buckets


DESIGN_AXIS = "design"


def design_shards(d: int, volume: Optional[float] = None) -> int:
    """Shard count policy for a design axis of length ``d``.

    Default policy: the largest divisor of ``d`` that fits the local
    device count — the design axis of a padded sweep is embarrassingly
    parallel (every design's fire/WTA/STDP is independent), so it shards
    with no collectives at all.  1 on a single-device host or when
    nothing divides: the single-device fallback is simply "no sharding".

    With a per-design fire ``volume`` hint (``p * q * t``) AND an active
    device calibration, the cost model picks the shard count instead
    (``costmodel.choose_shards``): shard only while the compute saved per
    volley exceeds the added per-device dispatch, so a microsecond-sized
    bucket stops paying k launches to split sub-dispatch work.  Sharding
    is a throughput knob only — results are bit-identical for any count.
    """
    if volume is not None:
        from repro.roofline import costmodel

        return costmodel.choose_shards(int(d), float(volume))
    n_dev = jax.local_device_count()
    k = min(int(d), n_dev)
    while k > 1 and d % k:
        k -= 1
    return max(k, 1)


def design_mesh(
    d: int, volume: Optional[float] = None, shards: Optional[int] = None
):
    """1-D device mesh over ``DESIGN_AXIS`` for a design axis of length
    ``d``, or None on a single device / when ``d`` has no usable divisor
    (the clean single-device fallback — callers treat None as 'leave the
    arrays where they are').  ``volume`` is the optional per-design fire
    volume hint forwarded to the ``design_shards`` plan policy; callers
    that already hold an ``ExecutionPlan`` pass its ``shards`` count
    directly so the mesh and the recorded plan can never disagree."""
    k = shards if shards is not None else design_shards(d, volume)
    if k <= 1:
        return None
    return jax.make_mesh((k,), (DESIGN_AXIS,))


def shard_design_axis(mesh, x: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Place ``x`` with dimension ``axis`` sharded over ``mesh``'s design
    axis (no-op when ``mesh`` is None).  Sharding the operands is all it
    takes: the padded scans are jitted, so GSPMD propagates the design
    partitioning through the whole fit/assign program — per-design
    arithmetic is untouched and results stay bit-identical to the
    unsharded run."""
    if mesh is None:
        return x
    spec = PartitionSpec(*((None,) * axis + (DESIGN_AXIS,)))
    return jax.device_put(x, NamedSharding(mesh, spec))


# ----------------------------------------- persistent compilation cache
# Compilation must be a one-time, cross-process cost: a bench restart, a
# resumed DSE run, or a service process coming up must never re-pay XLA
# compilation for an envelope any prior process already compiled.  This
# is the ONE switch for JAX's persistent compilation cache — nothing else
# in the tree touches ``jax_compilation_cache_dir``.
COMPILE_CACHE_ENV = "REPRO_COMPILE_CACHE"

_compile_cache_path: Optional[str] = None


def compile_cache(dir) -> Optional[str]:
    """Enable JAX's persistent compilation cache under ``dir``.

    Opt-in, explicit: call this (or export ``REPRO_COMPILE_CACHE=<dir>``,
    honored at import) to make every XLA compilation land in ``dir`` and
    every later process that enables the same directory skip straight to
    the cached executable — zero envelope compiles, bit-identical results
    (pinned by ``tests/test_aot_cache.py``).  The same directory also
    holds whole serialized AOT envelope executables (``aot/``, see
    ``_aot_store``), which additionally skip tracing + lowering — the
    cost JAX's own cache still pays every process.  ``dse.explore``
    enables it automatically next to its journal.  The entry-size/
    compile-time thresholds are dropped to zero because the padded
    envelope traces are exactly the small-but-slow tail the defaults
    would skip.

    The directory is created (and re-created — a deleted cache dir on a
    resumed run is repaired, not fatal) and probed for writability.  An
    unusable directory degrades gracefully: a ``RuntimeWarning`` and a
    ``None`` return, with compilation simply staying in-process — never
    an error on a hot path.  Returns the absolute cache path on success.
    JAX keys entries on jaxlib version + compiled module + compile
    options, so a stale directory is merely ignored, never wrong.
    """
    global _compile_cache_path
    try:
        path = os.path.abspath(os.fspath(dir))
        os.makedirs(path, exist_ok=True)
        probe = os.path.join(path, ".write-probe")
        with open(probe, "w"):
            pass
        os.remove(probe)
    except OSError as e:
        warnings.warn(
            f"persistent compilation cache disabled: {dir!r} is not a "
            f"writable directory ({e})",
            RuntimeWarning,
            stacklevel=2,
        )
        return None
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    _compile_cache_path = path
    return path


def compile_cache_dir() -> Optional[str]:
    """Directory of the persistent compilation cache enabled through
    ``compile_cache`` (None when it never was)."""
    return _compile_cache_path


# --------------------------------------- AOT envelope executable cache
# In-process twin of the persistent cache: one ahead-of-time compiled
# executable per (entry point, envelope, statics).  PR 5 deduped traces
# across equal-envelope buckets only within a single
# ``cluster_time_series_many`` call (the jit cache keyed on the Python
# callable); this cache keys on the envelope itself, so equal-envelope
# buckets share ONE executable across sweep calls, network layers, and
# DSE rounds in the same process — and the persistent cache extends the
# same guarantee across processes.
_AOT_CACHE: dict[tuple, object] = {}


def aot_cache_size() -> int:
    """Number of distinct (entry point, envelope) executables compiled."""
    return len(_AOT_CACHE)


def aot_cache_clear() -> None:
    """Drop the in-process executables (tests; the persistent cache — if
    enabled — still makes recompiles near-free)."""
    _AOT_CACHE.clear()


# JAX's persistent cache only skips ``backend_compile`` — a fresh process
# still pays tracing + StableHLO lowering for every envelope, and for the
# big blocked reference traces that cost rivals the compile itself.  So
# when ``compile_cache`` is enabled, the AOT executables are ALSO
# serialized whole (``jax.experimental.serialize_executable``) into
# ``<cache dir>/aot/``: a warm process deserializes the finished
# executable (~ms) and never traces at all.  Entries are keyed on the
# envelope key + jax version + platform + device count; a stale or
# corrupt entry deserializes as a failure and falls back to a fresh
# compile that overwrites it — never wrong, at worst slow once.
def _aot_disk_path(key: tuple) -> Optional[str]:
    if _compile_cache_path is None:
        return None
    tag = hashlib.sha256(repr(
        (key, jax.__version__, jax.default_backend(),
         jax.local_device_count())
    ).encode()).hexdigest()[:32]
    return os.path.join(_compile_cache_path, "aot", f"{tag}.pkl")


def _aot_load(key: tuple):
    path = _aot_disk_path(key)
    if path is None or not os.path.exists(path):
        return None
    try:
        from jax.experimental.serialize_executable import (
            deserialize_and_load,
        )
        with open(path, "rb") as f:
            payload = pickle.load(f)
        return deserialize_and_load(*payload)
    except Exception:
        return None


def _aot_store(key: tuple, exe) -> None:
    path = _aot_disk_path(key)
    if path is None:
        return
    try:
        from jax.experimental.serialize_executable import serialize
        payload = serialize(exe)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        # write-then-rename, same publish discipline as the DSE journal:
        # concurrent writers race to an identical payload, readers never
        # see a torn file
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "wb") as f:
            pickle.dump(payload, f)
        os.replace(tmp, path)
    except Exception as e:  # serialization is an optimization, never fatal
        warnings.warn(
            f"could not persist AOT executable: {e}", RuntimeWarning
        )


def _coerce(x, dtype):
    """Dtype coercion with a fast path: the AOT dispatchers normalize all
    five operands on every call, and ``jnp.asarray`` costs ~20us of pure
    Python even when it has nothing to do — on already-correct device
    arrays (the common case: simulator and network pass exactly these)
    that is visible dispatch overhead, so skip it."""
    if isinstance(x, jax.Array) and x.dtype == dtype:
        return x
    return jnp.asarray(x, dtype)


def _fit_key(
    w_shape, xs_shape, t_window, w_max, wta_k, stabilize, response,
    epochs, lowering, t_blk, v_blk,
) -> tuple:
    """AOT cache key for one fit envelope: shapes + statics, never values."""
    return (
        "fit", tuple(w_shape), tuple(xs_shape), t_window, w_max, wta_k,
        bool(stabilize), response, epochs, lowering, t_blk, v_blk,
    )


def _assign_key(
    w_shape, xs_shape, t_window, wta_k, response, lowering, t_blk, v_blk,
    w_max,
) -> tuple:
    return (
        "assign", tuple(w_shape), tuple(xs_shape), t_window, wta_k, response,
        lowering, t_blk, v_blk, w_max,
    )


def _resolve_executable(key: tuple, build):
    """Executable lookup ladder: in-process -> serialized on disk -> compile.

    The single resolution path under ``fit_padded``/``assign_padded`` and
    the ``warm_*`` pre-compilers, so a warmed key and a traffic-time key
    hit the SAME entry by construction."""
    exe = _AOT_CACHE.get(key)
    if exe is None:
        exe = _aot_load(key)
    if exe is None:
        exe = build()
        _aot_store(key, exe)
    _AOT_CACHE[key] = exe
    return exe


def warm_fit_padded(
    d: int,
    p_pad: int,
    q_pad: int,
    n_volleys: int,
    *,
    t_window: int,
    w_max: int,
    wta_k: int,
    stabilize: bool,
    response: str,
    epochs: int,
    lowering: str,
    t_blk: Optional[int] = None,
    v_blk: Optional[int] = None,
) -> bool:
    """Make one envelope's fit executable resident *before* traffic.

    Long-lived callers (the streaming service, a resumed DSE run) know
    their envelopes up front; warming moves the one-time trace/compile —
    or the millisecond disk deserialize under ``compile_cache`` — out of
    the first request's latency.  No operands are needed and nothing is
    donated.  Unset ``v_blk``/``t_blk`` resolve through
    ``execution_plan`` — the same deterministic resolution ``fit_padded``
    performs, so a warmed key and a traffic key always coincide.  Returns
    True when the executable was already resident in-process (a later
    ``fit_padded`` with the same shapes+statics is then dispatch-only).
    When the module entry point has been replaced by a plain callable
    (the fault-injection seam — see ``fit_padded``) there is nothing to
    compile and this is a no-op returning False.
    """
    if not hasattr(fused_column.fit_scan_padded, "lower"):
        return False
    v_blk, t_blk = _plan_blocks(
        "fit", lowering, d, p_pad, q_pad, t_window, n_volleys, epochs,
        w_max, response, v_blk, t_blk,
    )
    key = _fit_key(
        (d, p_pad, q_pad), (n_volleys, d, p_pad), t_window, w_max, wta_k,
        stabilize, response, epochs, lowering, t_blk, v_blk,
    )
    hot = key in _AOT_CACHE
    _resolve_executable(
        key,
        lambda: fused_column.precompile_fit_scan_padded(
            d, p_pad, q_pad, n_volleys,
            t_window=t_window, w_max=w_max, wta_k=wta_k,
            stabilize=bool(stabilize), response=response, epochs=epochs,
            lowering=lowering, t_blk=t_blk, v_blk=v_blk,
        ),
    )
    return hot


def warm_assign_padded(
    d: int,
    p_pad: int,
    q_pad: int,
    n_volleys: int,
    *,
    t_window: int,
    wta_k: int,
    response: str,
    lowering: str,
    t_blk: Optional[int] = None,
    v_blk: Optional[int] = None,
    w_max: Optional[int] = None,
) -> bool:
    """Assignment twin of ``warm_fit_padded`` (same contract)."""
    if not hasattr(fused_column.assign_padded, "lower"):
        return False
    v_blk, t_blk = _plan_blocks(
        "assign", lowering, d, p_pad, q_pad, t_window, n_volleys, 1,
        w_max, response, v_blk, t_blk,
    )
    key = _assign_key(
        (d, p_pad, q_pad), (n_volleys, d, p_pad), t_window, wta_k, response,
        lowering, t_blk, v_blk, w_max,
    )
    hot = key in _AOT_CACHE
    _resolve_executable(
        key,
        lambda: fused_column.precompile_assign_padded(
            d, p_pad, q_pad, n_volleys,
            t_window=t_window, wta_k=wta_k, response=response,
            lowering=lowering, t_blk=t_blk, v_blk=v_blk, w_max=w_max,
        ),
    )
    return hot


@functools.lru_cache(maxsize=None)
def _f32_scalar(v: float):
    """Memoized scalar device transfer: the AOT dispatchers pass the STDP
    mus as f32 device scalars on EVERY call, and three fresh host-to-
    device puts per dispatch are pure overhead on a parity-level case —
    the sweep bench sits at ~24 ms/call, where ~0.3 ms of scalar puts is
    a visible warm regression.  Values come from config floats, so the
    working set is tiny and the cache never grows past a handful."""
    return jnp.float32(v)


def fit_padded(
    w,
    xs,
    thresholds,
    t_maxes,
    q_actives,
    *,
    t_window: int,
    w_max: int,
    wta_k: int,
    mu_capture,
    mu_backoff,
    mu_search,
    stabilize: bool,
    response: str,
    epochs: int,
    lowering: str,
    t_blk: Optional[int] = None,
    v_blk: Optional[int] = None,
):
    """Envelope-cached AOT front door to ``fused_column.fit_scan_padded``.

    Dispatches to a ``jit(...).lower().compile()`` executable cached on
    the padded envelope ``(D, N, p, q, v_blk, lowering, statics)`` — see
    ``fused_column.precompile_fit_scan_padded`` — and bit-identical to
    calling the jitted entry point directly.  Operand *values* (weights,
    volleys, per-design thresholds/windows/mus) are runtime inputs and
    never part of the key, so designs that share an envelope share an
    executable while their results stay their own.  Like the underlying
    scan, the weight buffer ``w`` is donated: pass a fresh array.

    Unset ``v_blk``/``t_blk`` resolve through ``execution_plan`` (cost
    model when a calibration is active, the documented constants
    otherwise) BEFORE the cache key is formed, so plan choices and AOT
    keys can never disagree between warmup and traffic.

    Callers with sharded operands must use ``fit_scan_padded`` directly —
    these executables are compiled against unsharded specs, while the jit
    path lets GSPMD propagate the design partitioning at trace time.
    """
    w = _coerce(w, jnp.float32)
    xs = _coerce(xs, TIME_DTYPE)
    thresholds = _coerce(thresholds, jnp.float32)
    t_maxes = _coerce(t_maxes, TIME_DTYPE)
    q_actives = _coerce(q_actives, TIME_DTYPE)
    d, p_pad, q_pad = w.shape
    v_blk, t_blk = _plan_blocks(
        "fit", lowering, d, p_pad, q_pad, t_window, xs.shape[0], epochs,
        w_max, response, v_blk, t_blk,
    )
    if not hasattr(fused_column.fit_scan_padded, "lower"):
        # the module entry point has been replaced by a plain callable —
        # the fault-injection / instrumentation seam the fault tests (and
        # any profiling wrapper) rely on.  A wrapper cannot be .lower()ed
        # into an executable, and dispatching a cached executable AROUND
        # it would silently disarm the seam, so honor the wrapper.
        return fused_column.fit_scan_padded(
            w, xs, thresholds, t_maxes, q_actives,
            t_window=t_window, w_max=w_max, wta_k=wta_k,
            mu_capture=mu_capture, mu_backoff=mu_backoff,
            mu_search=mu_search, stabilize=stabilize, response=response,
            epochs=epochs, lowering=lowering, t_blk=t_blk, v_blk=v_blk,
        )
    key = _fit_key(
        w.shape, xs.shape, t_window, w_max, wta_k, stabilize, response,
        epochs, lowering, t_blk, v_blk,
    )
    exe = _resolve_executable(
        key,
        lambda: fused_column.precompile_fit_scan_padded(
            d, p_pad, q_pad, xs.shape[0],
            t_window=t_window, w_max=w_max, wta_k=wta_k,
            stabilize=bool(stabilize), response=response, epochs=epochs,
            lowering=lowering, t_blk=t_blk, v_blk=v_blk,
        ),
    )
    # the call must mirror the precompile specs exactly: five positional
    # arrays, mus by keyword, as f32 scalars
    return exe(
        w, xs, thresholds, t_maxes, q_actives,
        mu_capture=_f32_scalar(float(mu_capture)),
        mu_backoff=_f32_scalar(float(mu_backoff)),
        mu_search=_f32_scalar(float(mu_search)),
    )


def assign_padded(
    w,
    xs,
    thresholds,
    t_maxes,
    q_actives,
    *,
    t_window: int,
    wta_k: int,
    response: str,
    lowering: str,
    t_blk: Optional[int] = None,
    v_blk: Optional[int] = None,
    w_max: Optional[int] = None,
):
    """Envelope-cached AOT front door to ``fused_column.assign_padded``.

    Same contract as ``fit_padded`` (envelope-keyed executable, runtime
    operands, plan-resolved blocking, bit-identical to the jit path) for
    the batched assignment pass; nothing is donated.
    """
    w = _coerce(w, jnp.float32)
    xs = _coerce(xs, TIME_DTYPE)
    thresholds = _coerce(thresholds, jnp.float32)
    t_maxes = _coerce(t_maxes, TIME_DTYPE)
    q_actives = _coerce(q_actives, TIME_DTYPE)
    v_blk, t_blk = _plan_blocks(
        "assign", lowering, w.shape[0], w.shape[1], w.shape[2], t_window,
        xs.shape[0], 1, w_max, response, v_blk, t_blk,
    )
    if not hasattr(fused_column.assign_padded, "lower"):
        # same instrumentation-seam rule as fit_padded above
        return fused_column.assign_padded(
            w, xs, thresholds, t_maxes, q_actives,
            t_window=t_window, wta_k=wta_k, response=response,
            lowering=lowering, t_blk=t_blk, v_blk=v_blk, w_max=w_max,
        )
    key = _assign_key(
        w.shape, xs.shape, t_window, wta_k, response, lowering, t_blk,
        v_blk, w_max,
    )
    exe = _resolve_executable(
        key,
        lambda: fused_column.precompile_assign_padded(
            w.shape[0], w.shape[1], w.shape[2], xs.shape[0],
            t_window=t_window, wta_k=wta_k, response=response,
            lowering=lowering, t_blk=t_blk, v_blk=v_blk, w_max=w_max,
        ),
    )
    return exe(w, xs, thresholds, t_maxes, q_actives)


# ------------------------------------------------------------- generic fit
def solver_volley_step(
    w: jnp.ndarray,
    x_t: jnp.ndarray,
    key: jax.Array,
    cfg: ColumnConfig,
    solver_mode: str,
    y_target: Optional[jnp.ndarray] = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One online-STDP step on the event/cycle solvers: fire -> WTA -> STDP.

    This is the shared scan body of the generic (non-fused) training path —
    ``_solver_fit_scan`` folds it over a column's volleys and
    ``network._layer_solver_fit_scan`` additionally ``vmap``s it over a
    layer's columns.  ``key`` must already be folded per volley; it is split
    here for the WTA tie-break and stochastic STDP independently.

    Returns (updated weights [p, q], post-WTA winner times [q]).
    """
    solver = (
        neuron.fire_times_event
        if solver_mode == "event"
        else neuron.fire_times_cycle
    )
    k_wta, k_stdp = jax.random.split(key)
    t = solver(x_t[None], w, cfg.neuron, cfg.t_max)[0]
    y, _ = wta.wta(
        t, cfg.wta, cfg.t_max,
        rng=k_wta if cfg.wta.tie_break == "random" else None,
    )
    teacher = y if y_target is None else y_target
    w2 = stdp.stdp_update(
        w, x_t, teacher, cfg.stdp, cfg.neuron.w_max, cfg.t_max,
        rng=k_stdp if cfg.stdp.mode == "stochastic" else None,
    )
    return w2, y


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "mode", "epochs", "trace", "supervised"),
    donate_argnums=(0,),
)
def _solver_fit_scan(
    w: jnp.ndarray,
    xs: jnp.ndarray,
    y_target: Optional[jnp.ndarray],
    rng: jax.Array,
    cfg: ColumnConfig,
    mode: str,
    epochs: int,
    trace: bool,
    supervised: bool,
):
    """Online STDP as one compiled scan using the event/cycle solvers.

    Handles the full config surface (LIF, stochastic STDP, random/all WTA
    tie-breaks, supervised targets) that the fused step does not.
    """
    n = xs.shape[0]

    def volley(carry, inp):
        wc, key = carry
        xt, yt, i = inp
        kv = jax.random.fold_in(key, i)
        w2, y = solver_volley_step(
            wc, xt, kv, cfg, mode, y_target=yt if supervised else None
        )
        return (w2, key), (y if trace else None)

    yts = y_target if supervised else jnp.zeros((n, 1), TIME_DTYPE)

    def epoch(carry, e):
        wc, key = carry
        ke = jax.random.fold_in(key, e)
        (w2, _), ys = jax.lax.scan(
            volley, (wc, ke), (xs, yts, jnp.arange(n))
        )
        return (w2, key), ys

    (w, _), ys = jax.lax.scan(epoch, (w, rng), jnp.arange(epochs))
    return w, ys


def _solver_fit(
    params: dict,
    x: jnp.ndarray,
    cfg: ColumnConfig,
    mode: str,
    epochs: int,
    rng: Optional[jax.Array],
    trace: bool,
    y_target: Optional[jnp.ndarray] = None,
):
    if rng is None:
        if cfg.wta.tie_break == "random":
            raise ValueError("tie_break='random' requires a PRNG key")
        if cfg.stdp.mode == "stochastic":
            raise ValueError("stochastic STDP requires a PRNG key")
        rng = jax.random.key(0)
    w = jnp.array(params["w"], jnp.float32, copy=True)  # scan donates w
    w_new, ys = _solver_fit_scan(
        w, x, y_target, rng, cfg, mode, epochs,
        trace, y_target is not None,
    )
    return {"w": w_new}, ys


def _solver_fire(mode: str):
    def fire(params, x, cfg, rng=None):
        t = neuron.fire_times(x, params["w"], cfg.neuron, cfg.t_max, mode)
        return wta.wta(t, cfg.wta, cfg.t_max, rng=rng)

    return fire


# -------------------------------------------------------------- pallas side
def _pallas_fire(params, x, cfg: ColumnConfig, rng=None):
    """Kernel-backed batched forward: integer-grid fire + WTA.

    Response-aware like the fused fit paths: RNL uses the kernel where one
    exists, SNL falls to the reference body of the same algebra (a
    lowering choice), anything else (LIF) raises.
    """
    from repro.kernels import ops  # late import: ops depends on this module

    allowed = fused_column.fire_responses("reference")
    if cfg.neuron.response not in allowed:
        raise ValueError(
            f"pallas forward supports response {allowed}, got "
            f"{cfg.neuron.response!r}; use mode='cycle'"
        )
    lowering = padded_lowering(cfg.neuron.response)
    w = jnp.round(jnp.clip(params["w"], 0.0, cfg.neuron.w_max))
    if lowering == "reference":
        # lax.map over volley *blocks* (vmapped inside): bounds the
        # [v_blk, p, q, t] dense transient while amortizing per-volley
        # dispatch — same arithmetic per volley, just batched.
        xb = x.reshape((-1, cfg.p))
        v_blk = volley_block("reference", xb.shape[0])
        xsb, _ = fused_column._pad_volley_blocks(xb, v_blk, cfg.t_max)

        def block(xt_blk):
            return jax.vmap(
                lambda xt: fused_column.fire_dense_ref(
                    w, xt, cfg.neuron.threshold, cfg.t_max,
                    response=cfg.neuron.response,
                )
            )(xt_blk)

        t = jax.lax.map(block, xsb).reshape((-1, cfg.q))
        t = t[: xb.shape[0]].reshape(x.shape[:-1] + (cfg.q,))
    else:
        t = ops.rnl_fire(
            x.reshape((-1, cfg.p)), w, cfg.neuron.threshold, cfg.t_max,
            cfg.neuron.w_max,
        ).reshape(x.shape[:-1] + (cfg.q,))
    return wta.wta(t, cfg.wta, cfg.t_max, rng=rng)


def _pallas_fit(params, x, cfg, mode, epochs, rng, trace, y_target=None):
    if y_target is not None:
        # Supervised targets need the generic scan.  That is a silent
        # semantic switch (float-weight fire instead of the fused integer
        # grid), so it is only legal when the caller asked for 'auto'.
        if mode == "pallas":
            raise ValueError(
                "the fused pallas backend has no supervised (y_target) "
                "path; use mode='auto', 'event' or 'cycle'"
            )
        fallback = "cycle" if cfg.neuron.response == "lif" else "event"
        return _solver_fit(
            params, x, cfg, fallback, epochs, rng, trace, y_target
        )
    return fused_column.fit_fused(
        params, x, cfg, epochs,
        lowering=padded_lowering(cfg.neuron.response), trace=trace,
    )


# ---------------------------------------------------------------- registry
@dataclasses.dataclass(frozen=True)
class Backend:
    """One simulation backend: batched forward + online-STDP training.

    fire(params, x, cfg, rng) -> (post-WTA times [..., q], winner mask).
    fit(params, x, cfg, mode, epochs, rng, trace, y_target)
        -> (params, ys or None); ys is [epochs, N, q] online winner times.
    """

    name: str
    fire: Callable
    fit: Callable


_REGISTRY: dict[str, Backend] = {}


def register(backend: Backend) -> None:
    _REGISTRY[backend.name] = backend


def get(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend: {name!r} (have {sorted(_REGISTRY)})"
        ) from None


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register(
    Backend(
        "event",
        _solver_fire("event"),
        lambda params, x, cfg, mode, epochs, rng, trace, y_target=None:
            _solver_fit(params, x, cfg, "event", epochs, rng, trace, y_target),
    )
)
register(
    Backend(
        "cycle",
        _solver_fire("cycle"),
        lambda params, x, cfg, mode, epochs, rng, trace, y_target=None:
            _solver_fit(params, x, cfg, "cycle", epochs, rng, trace, y_target),
    )
)
register(Backend("pallas", _pallas_fire, _pallas_fit))


def _fused_ok(cfg: ColumnConfig) -> bool:
    # Evaluated against the STRICTEST lowering ('mosaic', RNL-only).  SNL
    # *could* now train fused uniformly on every host (padded_lowering
    # routes it to the reference body), but 'auto' has always trained SNL
    # on the float-weight event solver, and the fused path's integer-grid
    # fire gives different (not wrong, different) results — so routing SNL
    # fused under 'auto' would silently change established results.  Users
    # who want SNL on the fused path opt in with mode='pallas'.
    try:
        fused_column.check_fusable(cfg, "mosaic")
        return True
    except ValueError:
        return False


def resolve(mode: str, cfg: ColumnConfig, training: bool = False) -> str:
    """Map the public mode knob to a registry name.

    Forward 'auto' keeps the paper's hybrid: event where exact, cycle for
    LIF.  Training 'auto' prefers the fused pallas path whenever the config
    fits its contract, falling back to the hybrid solvers otherwise.
    """
    if mode != "auto":
        get(mode)  # validate
        return mode
    if cfg.neuron.response == "lif":
        return "cycle"
    if training and _fused_ok(cfg):
        return "pallas"
    return "event"


# Environment opt-in for the persistent compilation cache: launchers (CI,
# bench, services) export REPRO_COMPILE_CACHE=<dir> instead of editing
# code.  Runs at import so every compile in the process lands in the
# cache, including ones issued before any explicit compile_cache() call.
if os.environ.get(COMPILE_CACHE_ENV):
    compile_cache(os.environ[COMPILE_CACHE_ENV])
