"""Temporal (spike-time) encodings of real-valued signals.

Following Chaudhari et al. (ICASSP'21), a time series of length L feeds a
single column with p = L synapses; each sample's amplitude is converted to a
spike *latency* within the gamma window: larger amplitude -> earlier spike.
An optional on/off-center pair doubles the synapse count and encodes signed
deviations, mirroring DoG receptive fields in sensory pathways.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import TIME_DTYPE


ENCODERS = ("latency", "onoff")


def minmax_normalize(x: jnp.ndarray, axis: int = -1, eps: float = 1e-9) -> jnp.ndarray:
    lo = x.min(axis=axis, keepdims=True)
    hi = x.max(axis=axis, keepdims=True)
    return (x - lo) / (hi - lo + eps)


def encoded_width(length: int, encoder: str) -> int:
    """Synapse count a series of ``length`` samples encodes to.

    The admission contract of every front-end (simulator sweeps, the
    streaming service): a design with ``p`` synapses accepts exactly the
    series lengths for which ``encoded_width(L, encoder) == p``.
    """
    if encoder == "latency":
        return length
    if encoder == "onoff":
        return 2 * length
    raise ValueError(f"unknown encoder: {encoder!r} (have {ENCODERS})")


def encode(x: jnp.ndarray, t_max: int, encoder: str = "latency") -> jnp.ndarray:
    """Dispatch on the encoder name: [..., L] -> [..., encoded_width(L)]."""
    if encoder == "latency":
        return latency_encode(x, t_max)
    if encoder == "onoff":
        return onoff_encode(x, t_max)
    raise ValueError(f"unknown encoder: {encoder!r} (have {ENCODERS})")


def latency_encode(
    x: jnp.ndarray, t_max: int, normalize: bool = True
) -> jnp.ndarray:
    """Intensity-to-latency coding: v in [0,1] -> t = round((1-v)*(t_max-1)).

    Args:
      x: [..., L] real signal.
      t_max: gamma window length in cycles.

    Returns:
      [..., L] int32 spike times in [0, t_max).
    """
    v = minmax_normalize(x) if normalize else jnp.clip(x, 0.0, 1.0)
    t = jnp.round((1.0 - v) * (t_max - 1))
    return jnp.clip(t, 0, t_max - 1).astype(TIME_DTYPE)


def onoff_encode(x: jnp.ndarray, t_max: int) -> jnp.ndarray:
    """On/off-center pair coding: [..., L] -> [..., 2L] spike times.

    The on channel spikes early for positive deviations from the series mean,
    the off channel for negative deviations; the silent channel of each pair
    emits no spike (t_max).
    """
    mu = x.mean(axis=-1, keepdims=True)
    dev = x - mu
    mag = minmax_normalize(jnp.abs(dev))
    t = jnp.round((1.0 - mag) * (t_max - 1)).astype(TIME_DTYPE)
    no = jnp.asarray(t_max, TIME_DTYPE)
    on = jnp.where(dev >= 0, t, no)
    off = jnp.where(dev < 0, t, no)
    return jnp.concatenate([on, off], axis=-1)
