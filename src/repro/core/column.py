"""Single-column TNN: the paper's NSPU building block.

A column is p synapses x q neurons + WTA inhibition + STDP.  Inference for
one input volley:

  volley [p] --(response fn + threshold)--> spikes [q] --(WTA)--> winners [q]

Training is online: each volley's (input, winner) pair drives one STDP step.
Weights, being the only state, live in a plain dict pytree.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import neuron, stdp, wta
from repro.core.types import ColumnConfig, TIME_DTYPE, WEIGHT_DTYPE


def init_params(rng: jax.Array, cfg: ColumnConfig) -> dict:
    """Initialize weights uniformly over [0, w_max] (hardware reset state
    randomizes the unary counters)."""
    w = jax.random.uniform(
        rng, (cfg.p, cfg.q), WEIGHT_DTYPE, 0.0, float(cfg.neuron.w_max)
    )
    return {"w": w}


@functools.partial(jax.jit, static_argnames=("cfg", "mode"))
def apply(
    params: dict,
    x_times: jnp.ndarray,
    cfg: ColumnConfig,
    mode: str = "auto",
    rng: Optional[jax.Array] = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Forward one or a batch of volleys.

    Args:
      params: {'w': [p, q]}.
      x_times: [..., p] input spike times.
      cfg: column config.
      mode: 'auto' | 'event' | 'cycle' simulation mode.
      rng: only needed for random WTA tie-break.

    Returns:
      (post-WTA spike times [..., q], winner mask [..., q]).
    """
    t_out = neuron.fire_times(x_times, params["w"], cfg.neuron, cfg.t_max, mode)
    return wta.wta(t_out, cfg.wta, cfg.t_max, rng=rng)


def train_step(
    params: dict,
    x_times: jnp.ndarray,
    cfg: ColumnConfig,
    mode: str = "auto",
    rng: Optional[jax.Array] = None,
    y_target: Optional[jnp.ndarray] = None,
) -> tuple[dict, jnp.ndarray]:
    """One online training step on a batch of volleys.

    Unsupervised: the WTA winners are the STDP teacher (paper default).
    Supervised: ``y_target`` [..., q] spike times override the winners.

    Returns (new params, winner spike times).
    """
    y, _ = apply(params, x_times, cfg, mode, rng)
    teacher = y if y_target is None else y_target
    xb = x_times.reshape((-1, cfg.p))
    yb = teacher.reshape((-1, cfg.q))
    w = stdp.stdp_update_batch(
        params["w"], xb, yb, cfg.stdp, cfg.neuron.w_max, cfg.t_max, rng=rng
    )
    return {"w": w}, y


def fit(
    params: dict,
    x_times: jnp.ndarray,
    cfg: ColumnConfig,
    epochs: int = 8,
    mode: str = "auto",
    rng: Optional[jax.Array] = None,
) -> dict:
    """Run unsupervised STDP for several passes over the dataset [N, p]."""
    if rng is None:
        rng = jax.random.key(0)
    for e in range(epochs):
        rng, sub = jax.random.split(rng)
        params, _ = train_step(params, x_times, cfg, mode, rng=sub)
    return params


def cluster_assignments(
    params: dict, x_times: jnp.ndarray, cfg: ColumnConfig, mode: str = "auto"
) -> jnp.ndarray:
    """Winner neuron index per volley = cluster id (paper's clustering use).

    Volleys where no neuron spikes are assigned cluster q (an 'unclustered'
    bucket), matching the simulator's rand-index accounting.
    """
    y, win = apply(params, x_times, cfg, mode)
    any_spike = win.any(axis=-1)
    idx = jnp.argmin(y, axis=-1)
    return jnp.where(any_spike, idx, cfg.q).astype(TIME_DTYPE)
