"""Single-column TNN: the paper's NSPU building block.

A column is p synapses x q neurons + WTA inhibition + STDP.  Inference for
one input volley:

  volley [p] --(response fn + threshold)--> spikes [q] --(WTA)--> winners [q]

Training is online: each volley's (input, winner) pair drives one STDP step.
Weights, being the only state, live in a plain dict pytree.

Execution is dispatched through the backend registry
(``repro.core.backend``): ``mode`` accepts 'auto' | 'event' | 'cycle' |
'pallas'.  ``fit`` runs the whole training loop as ONE jitted, donated
``lax.scan`` over epochs x volleys (a single compilation per config); on the
'pallas' backend the scan body is the fused column step of
``repro.kernels.fused_column`` (fire + WTA + STDP in one kernel).

Grids of columns with inter-layer connectivity are ``repro.core.network``;
the same ``mode`` knob resolves there layer by layer, so a column trains
identically standalone or as a network layer.  The full backend contract is
documented in ``docs/backends.md``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import backend as backend_lib
from repro.core import stdp
from repro.core.types import ColumnConfig, TIME_DTYPE, WEIGHT_DTYPE


def init_params(rng: jax.Array, cfg: ColumnConfig) -> dict:
    """Initialize weights uniformly over [0, w_max] (hardware reset state
    randomizes the unary counters)."""
    w = jax.random.uniform(
        rng, (cfg.p, cfg.q), WEIGHT_DTYPE, 0.0, float(cfg.neuron.w_max)
    )
    return {"w": w}


@functools.partial(jax.jit, static_argnames=("cfg", "mode"))
def apply(
    params: dict,
    x_times: jnp.ndarray,
    cfg: ColumnConfig,
    mode: str = "auto",
    rng: Optional[jax.Array] = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Forward one or a batch of volleys.

    Args:
      params: {'w': [p, q]}.
      x_times: [..., p] input spike times.
      cfg: column config.
      mode: 'auto' | 'event' | 'cycle' | 'pallas' simulation backend.
      rng: only needed for random WTA tie-break.

    Returns:
      (post-WTA spike times [..., q], winner mask [..., q]).
    """
    be = backend_lib.get(backend_lib.resolve(mode, cfg))
    return be.fire(params, x_times, cfg, rng=rng)


def train_step(
    params: dict,
    x_times: jnp.ndarray,
    cfg: ColumnConfig,
    mode: str = "auto",
    rng: Optional[jax.Array] = None,
    y_target: Optional[jnp.ndarray] = None,
    update: str = "online",
) -> tuple[dict, jnp.ndarray]:
    """One training pass over a batch of volleys.

    ``update`` selects the fold semantics:

      'online' (default) — true online rule, matching the hardware: each
        volley's winners are computed from the weights as updated by every
        preceding volley (one fused forward+STDP step per volley).
      'batch' — legacy semantics: ALL winners are computed from the stale
        pre-batch weights, then the STDP updates fold sequentially.  Kept as
        an explicit option because it approximates minibatch training, but
        it diverges from the generated RTL.

    Unsupervised: the WTA winners are the STDP teacher (paper default).
    Supervised: ``y_target`` [..., q] spike times override the winners.

    Returns (new params, winner spike times [..., q]).
    """
    if update == "batch":
        y, _ = apply(params, x_times, cfg, mode, rng)
        teacher = y if y_target is None else y_target
        xb = x_times.reshape((-1, cfg.p))
        yb = teacher.reshape((-1, cfg.q))
        w = stdp.stdp_update_batch(
            params["w"], xb, yb, cfg.stdp, cfg.neuron.w_max, cfg.t_max,
            rng=rng,
        )
        return {"w": w}, y
    if update != "online":
        raise ValueError(f"unknown update: {update!r}")

    batch_shape = x_times.shape[:-1]
    xb = x_times.reshape((-1, cfg.p))
    yt = None if y_target is None else y_target.reshape((-1, cfg.q))
    name = backend_lib.resolve(mode, cfg, training=True)
    new_params, ys = backend_lib.get(name).fit(
        params, xb, cfg, mode, 1, rng, True, yt
    )
    y = ys[0].reshape(batch_shape + (cfg.q,)).astype(TIME_DTYPE)
    return new_params, y


def fit(
    params: dict,
    x_times: jnp.ndarray,
    cfg: ColumnConfig,
    epochs: int = 8,
    mode: str = "auto",
    rng: Optional[jax.Array] = None,
) -> dict:
    """Run unsupervised online STDP for several passes over the data [N, p].

    The whole run — every epoch, every volley — is one compiled scan with a
    donated weight buffer; nothing is re-traced or re-padded per volley.
    """
    name = backend_lib.resolve(mode, cfg, training=True)
    new_params, _ = backend_lib.get(name).fit(
        params, x_times, cfg, mode, epochs, rng, False, None
    )
    return new_params


def cluster_assignments(
    params: dict, x_times: jnp.ndarray, cfg: ColumnConfig, mode: str = "auto"
) -> jnp.ndarray:
    """Winner neuron index per volley = cluster id (paper's clustering use).

    Volleys where no neuron spikes are assigned cluster q (an 'unclustered'
    bucket), matching the simulator's rand-index accounting.  Assignment is
    batched, never scanned: the solver backends forward the whole stream in
    one call, and the 'pallas' forward fires volley *blocks*
    (``backend.volley_block``) off-TPU / the kernel grid on TPU.
    """
    y, win = apply(params, x_times, cfg, mode)
    any_spike = win.any(axis=-1)
    idx = jnp.argmin(y, axis=-1)
    return jnp.where(any_spike, idx, cfg.q).astype(TIME_DTYPE)
