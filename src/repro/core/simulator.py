"""TNNGen functional simulator front-end (paper §II-A).

Ties encoding + column/network inference + online STDP + clustering metrics
into the "rapid application exploration" loop the paper describes.  The
``mode`` knob selects a backend from the unified registry
(``repro.core.backend``) and means the same thing for single columns and
multi-layer networks:

  'auto'   — hybrid: event-driven closed form where exact (RNL/SNL),
             cycle-accurate scan where required (LIF); training routes to
             the fused column step whenever the config fits its contract.
  'event'  — force the closed form.
  'cycle'  — force cycle-accurate lax.scan (bit-identical to generated RTL).
  'pallas' — force the fused kernel path (Mosaic on TPU; the jnp reference
             lowering of the same fused step elsewhere).

Three clustering front-ends share the loop:

* ``cluster_time_series`` — one column design, one stream.
* ``cluster_time_series_many`` — a whole *design sweep*, envelope-bucketed:
  designs partition into shared (p, q, t_max) padding envelopes under the
  central waste cap (``backend.envelope_buckets``), each bucket runs as ONE
  compiled program with the fused training step over the design axis
  (threshold / window / live-neuron count become traced per-design
  scalars), advancing ``backend.volley_block`` volleys per scan step, the
  design axis sharded across local devices where ``backend.design_mesh``
  finds one; assignment batches the whole stream instead of scanning it.
  The padded scans live in ``repro.kernels.fused_column``.  This is the
  engine ``repro.dse.explore`` drives for design-space exploration.
* ``cluster_time_series_network`` — a multi-layer ``NetworkConfig`` design
  through the same encode -> fit -> assign -> rand-index loop, trained
  greedily layer-by-layer via ``network.fit_greedy`` (each layer one jitted
  donated scan on the resolved backend).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_lib
from repro.core import column as column_lib
from repro.core import encoding
from repro.core.types import ColumnConfig, NetworkConfig, TIME_DTYPE
from repro.kernels import fused_column


@dataclasses.dataclass
class ClusteringResult:
    assignments: np.ndarray  # [N] cluster ids (q == unclustered)
    rand_index: float
    # Trained parameters, one dict shape across every front-end so
    # downstream consumers (forecaster features, examples, DSE) can rely
    # on it: single-column front-ends (``cluster_time_series`` and each
    # sweep member of ``cluster_time_series_many``) return ``{'w': [p, q]}``
    # cropped to the design's true size; the network front-end returns
    # ``{'layers': [{'w': [columns, p, q]}, ...]}``.
    params: dict
    train_seconds: float
    mode: str
    # Lowering the fused training path actually ran on this host
    # ('mosaic' | 'interpret' | 'reference'), '' when training resolved to
    # the event/cycle solvers only, comma-joined when a network's fused
    # layers mixed lowerings (e.g. 'mosaic,reference' for RNL + SNL layers
    # on TPU).
    lowering: str = ""
    # Sweep metadata (``cluster_time_series_many``): how many envelope
    # buckets the sweep split into, and how many devices this design's
    # bucket sharded its design axis across (1 = single-device fallback).
    buckets: int = 1
    shards: int = 1
    # Degradation count (``on_error='isolate'`` sweeps only): how many
    # ladder rungs failed before the one recorded in ``lowering`` ran.
    # 0 = first-choice lowering succeeded.
    retries: int = 0
    # ExecutionPlan metadata of the fused fit that produced these params
    # (``ExecutionPlan.meta()``: v_blk/t_blk/shards/waste_cap/predicted
    # step time + whether the cost model or the constants chose them);
    # None when training took a solver path with no plan.  Observability
    # only — a plan changes blocking, never the result recorded here.
    plan: Optional[dict] = None


@dataclasses.dataclass
class EvalFailure:
    """A quarantined design evaluation — the structured no-crash outcome.

    Fault-isolated sweeps (``cluster_time_series_many(on_error='isolate')``
    and ``dse.explore``) convert per-design failures into these records
    instead of aborting the run: the design is quarantined, every other
    design's result is untouched (bit-identical to a failure-free sweep —
    bucketing and the degradation ladder never change surviving results).

    Attributes:
      index: the design's position in the sweep's input order.
      stage: where it failed — 'fit' (every ladder rung raised), 'assign'
        (training succeeded, assignment raised), 'weights' (non-finite
        weights after training), or 'silent' (no output spikes on any
        volley, so the Rand index is undefined).
      error: the final exception repr, or a diagnostic for the
        weights/silent guards.
      lowerings: the ladder rungs attempted, in order.
      retries: failed attempts before giving up (== len(lowerings) for
        'fit' failures; the rung that *ran* for post-check failures is
        last in ``lowerings``).
    """

    index: int
    stage: str
    error: str
    lowerings: tuple = ()
    retries: int = 0

    @property
    def rand_index(self) -> float:
        """NaN — a quarantined design carries no quality information
        (lets failure records ride result lists without isinstance
        checks at every consumer)."""
        return float("nan")


SweepOutcome = Union[ClusteringResult, EvalFailure]


def suggest_threshold(cfg: ColumnConfig) -> float:
    """Default firing threshold scaling used by the simulator.

    Expected saturated potential is p * w_max / 2 for uniform weights; firing
    around a quarter of that keeps spike times mid-window, the operating
    point the TNN microarchitecture calibrates for.
    """
    return max(1.0, 0.25 * cfg.p * cfg.neuron.w_max / 2.0)


def _encode_width(
    x: jnp.ndarray, t_max: int, width: int, encoder: str
) -> jnp.ndarray:
    volleys = encoding.encode(x, t_max, encoder)
    if volleys.shape[-1] != width:
        raise ValueError(
            f"encoded width {volleys.shape[-1]} != design input width {width}"
        )
    return volleys


def _encode(x: jnp.ndarray, cfg: ColumnConfig, encoder: str) -> jnp.ndarray:
    return _encode_width(x, cfg.t_max, cfg.p, encoder)


def cluster_time_series(
    series: np.ndarray,
    labels: Optional[np.ndarray],
    cfg: ColumnConfig,
    epochs: int = 8,
    mode: str = "auto",
    seed: int = 0,
    encoder: str = "latency",
) -> ClusteringResult:
    """End-to-end: encode -> online STDP -> assign clusters -> rand index.

    Args:
      series: [N, L] real-valued time series (L == cfg.p for 'latency',
        2L == cfg.p for 'onoff').
      labels: [N] integer class labels, or None (rand_index = nan).
      cfg: column config (p x q).
      epochs: STDP passes over the data.
      mode: simulation backend, resolved by ``backend.resolve`` (see module
        docstring); forcing 'pallas' on a config outside the fused contract
        raises rather than silently switching semantics.
      seed: PRNG seed — the one source of randomness (weight init, plus the
        per-volley keys stochastic/random configs consume); equal seeds
        reproduce the run exactly on every host.
      encoder: 'latency' or 'onoff'.

    The returned ``ClusteringResult.lowering`` records which lowering of the
    fused algebra training actually ran ('mosaic' on TPU, 'reference'
    elsewhere), or '' when it trained on the event/cycle solvers.
    """
    from repro.clustering.metrics import rand_index as rand_index_fn

    volleys = _encode(jnp.asarray(series), cfg, encoder)
    rng = jax.random.key(seed)
    rng, init_key = jax.random.split(rng)
    params = column_lib.init_params(init_key, cfg)

    t0 = time.perf_counter()
    params = column_lib.fit(params, volleys, cfg, epochs=epochs, mode=mode, rng=rng)
    assignments = np.asarray(
        column_lib.cluster_assignments(params, volleys, cfg, mode)
    )
    train_seconds = time.perf_counter() - t0

    ri = float("nan")
    if labels is not None:
        ri = float(rand_index_fn(np.asarray(labels), assignments))
    resolved = backend_lib.resolve(mode, cfg, training=True)
    lowering = (
        backend_lib.padded_lowering(cfg.neuron.response)
        if resolved == "pallas"
        else ""
    )
    return ClusteringResult(
        assignments, ri, params, train_seconds, mode, lowering
    )


def assign_time_series(
    series: np.ndarray,
    cfg: ColumnConfig,
    params: dict,
    encoder: str = "latency",
) -> np.ndarray:
    """Assignment-only entry: cluster ids from frozen trained weights.

    The inference half of ``cluster_time_series`` on its own — encode one
    series ``[L]`` (returns a scalar id) or a micro-batch ``[N, L]``
    (returns ``[N]`` ids) and fire it against ``params['w']`` with no
    training pass.  Configs inside the fused fire contract route through
    ``backend.assign_padded``, the envelope-keyed AOT executable cache, so
    repeated calls at the same batch shape dispatch ONE cached executable
    (the streaming service batches requests into exactly this path);
    everything else (LIF) falls back to the solver-backed
    ``column.cluster_assignments``.  Ids follow the assignment contract:
    earliest-firing neuron index, ``cfg.q`` for a silent (unclustered)
    volley.
    """
    x = jnp.asarray(series)
    single = x.ndim == 1
    if single:
        x = x[None]
    volleys = _encode(x, cfg, encoder)
    try:
        fused_column.check_fusable(cfg, "reference")
    except ValueError:
        ids = np.asarray(
            column_lib.cluster_assignments(params, volleys, cfg, "auto")
        )
        return ids[0] if single else ids
    w = jnp.asarray(params["w"], jnp.float32)[None]  # [1, p, q]
    asg = np.asarray(
        backend_lib.assign_padded(
            w,
            volleys[:, None, :],  # [N, 1, p]
            jnp.asarray([cfg.neuron.threshold], jnp.float32),
            jnp.asarray([cfg.t_max], TIME_DTYPE),
            jnp.asarray([cfg.q], TIME_DTYPE),
            t_window=cfg.t_max,
            wta_k=cfg.wta.k,
            response=cfg.neuron.response,
            lowering=backend_lib.assign_lowering(cfg.neuron.response, w[0]),
            w_max=cfg.neuron.w_max,
        )[0]
    )
    return asg[0] if single else asg


# --------------------------------------------------- batched design sweep
def _sweep_bucket(
    cfgs: Sequence[ColumnConfig],
    idxs: Sequence[int],
    envelope: tuple[int, int, int],
    enc: Sequence[jnp.ndarray],
    w_init: Sequence[np.ndarray],
    epochs: int,
    lowering: str,
) -> tuple[np.ndarray, list[jnp.ndarray], int, dict]:
    """Train + assign one envelope bucket of a design sweep.

    Pads the bucket's members into its shared (p_env, q_env, t_window)
    envelope, shards the design axis across local devices when the central
    policy finds a mesh (``backend.design_mesh``; None = single-device
    fallback, arrays stay put), and drives one volley-blocked
    ``fit_scan_padded`` plus one batched ``assign_padded``.  On a single
    device the calls route through ``backend.fit_padded`` /
    ``backend.assign_padded`` — the envelope-keyed AOT executable cache —
    so buckets with equal envelope shapes and member counts share ONE
    compiled executable across sweep calls in this process, and across
    processes once ``backend.compile_cache`` is enabled; sharded buckets
    keep the jit path so GSPMD sees the design partitioning.

    Blocking and sharding come from the bucket's ``ExecutionPlan``
    (``backend.execution_plan``; the documented constants when no device
    calibration is active) — observability rides along in the returned
    plan metadata.

    Returns (assignments [Db, N], cropped per-design weights, shard
    count, plan metadata dict).
    """
    c0 = cfgs[idxs[0]]
    p_env, q_env, t_window = envelope
    db = len(idxs)
    n = enc[idxs[0]].shape[0]

    # Stack padded volleys [Db, N, p_env] in ONE shot: the members' encodes
    # are stacked and the whole [Db, N, p] block lands in the silent-padded
    # buffer with a single set — no per-design ``.at[i].set`` dispatch
    # chain, O(1) graph nodes however many designs ride the bucket.
    # (Designs currently share p — the encoder pins it — so the stack is
    # uniform; the single set keeps the p < p_env envelope case working
    # should a future per-design front-end relax that.)
    encb = jnp.stack([enc[i] for i in idxs])  # [Db, N, p]
    xs = jnp.full((db, n, p_env), t_window, TIME_DTYPE)
    xs = xs.at[:, :, : encb.shape[-1]].set(encb)
    xs = jnp.swapaxes(xs, 0, 1)  # scan axis leading: [N, Db, p_env]

    # Per-design init draws stay per-(key, shape) — seed semantics — but
    # the padded stack is assembled host-side and shipped as ONE buffer
    # instead of a D-deep ``.at[i].set`` graph.
    w0_np = np.zeros((db, p_env, q_env), np.float32)
    for j, i in enumerate(idxs):
        c = cfgs[i]
        w0_np[j, : c.p, : c.q] = w_init[i]
    w0 = jnp.asarray(w0_np)
    thresholds = jnp.asarray(
        [cfgs[i].neuron.threshold for i in idxs], jnp.float32
    )
    t_maxes = jnp.asarray([cfgs[i].t_max for i in idxs], TIME_DTYPE)
    q_actives = jnp.asarray([cfgs[i].q for i in idxs], TIME_DTYPE)

    # the bucket's execution plan: blocking + sharding for this envelope
    # (cost model when calibrated, the documented constants otherwise);
    # returned as metadata so ClusteringResult/DSE journals record WHY
    fit_plan = backend_lib.execution_plan(
        "fit", lowering, db, p_env, q_env, t_window, n, epochs,
        w_max=c0.neuron.w_max, response=c0.neuron.response,
    )

    # shard the design axis across local devices: per-design work is
    # independent, so GSPMD splits the jitted scans with no collectives;
    # mesh=None (single device / indivisible Db) leaves every array put.
    # The mesh is built from the plan's shard count — ONE policy output,
    # so the recorded plan and the actual placement cannot disagree.  The
    # legacy call shape is kept whenever the plan agrees with the default
    # divisor policy (always, uncalibrated) so tests stubbing
    # ``design_mesh`` to force the unsharded path keep working.
    if fit_plan.shards == backend_lib.design_shards(db):
        mesh = backend_lib.design_mesh(db)
    else:
        mesh = backend_lib.design_mesh(db, shards=fit_plan.shards)
    shards = fit_plan.shards if mesh is not None else 1
    w0 = backend_lib.shard_design_axis(mesh, w0, axis=0)
    xs = backend_lib.shard_design_axis(mesh, xs, axis=1)
    thresholds = backend_lib.shard_design_axis(mesh, thresholds)
    t_maxes = backend_lib.shard_design_axis(mesh, t_maxes)
    q_actives = backend_lib.shard_design_axis(mesh, q_actives)

    fit_kw = dict(
        t_window=t_window, w_max=c0.neuron.w_max, wta_k=c0.wta.k,
        mu_capture=c0.stdp.mu_capture, mu_backoff=c0.stdp.mu_backoff,
        mu_search=c0.stdp.mu_search,
        stabilize=c0.stdp.stabilizer == "half",
        response=c0.neuron.response, epochs=epochs, lowering=lowering,
        # v_blk defaults to the central backend.volley_block policy
    )
    if mesh is None:
        # single-device: go through the envelope-keyed AOT executable
        # cache, so equal-envelope buckets share ONE executable across
        # sweep calls (and across processes under backend.compile_cache)
        w = backend_lib.fit_padded(
            w0, xs, thresholds, t_maxes, q_actives, **fit_kw
        )
    else:
        # sharded operands stay on the jit path: GSPMD propagates the
        # design partitioning at trace time, which a sharding-free AOT
        # executable would not; the plan rides along as a hashable static
        w = fused_column.fit_scan_padded(
            w0, xs, thresholds, t_maxes, q_actives, plan=fit_plan,
            **fit_kw
        )
    # assignment batches volleys (kernel grid / vmapped blocks); the kernel
    # fires on the integer weight grid, so it is only auto-selected when
    # the trained weights concretely sit on that grid (pure lowering
    # choice) — float weights keep the reference fire on every host.
    asg_lowering = backend_lib.assign_lowering(c0.neuron.response, w)
    asg_kw = dict(
        t_window=t_window, wta_k=c0.wta.k,
        response=c0.neuron.response, lowering=asg_lowering,
        w_max=c0.neuron.w_max,
    )
    if mesh is None:
        asg = np.asarray(
            backend_lib.assign_padded(
                w, xs, thresholds, t_maxes, q_actives, **asg_kw
            )
        )
    else:
        asg = np.asarray(
            fused_column.assign_padded(
                w, xs, thresholds, t_maxes, q_actives, **asg_kw
            )
        )
    w_out = [
        jnp.asarray(w[j, : cfgs[i].p, : cfgs[i].q])
        for j, i in enumerate(idxs)
    ]
    return asg, w_out, shards, fit_plan.meta()


def _eval_design_solver(
    cfg: ColumnConfig, volleys: jnp.ndarray, w0: np.ndarray, epochs: int
) -> tuple[np.ndarray, jnp.ndarray]:
    """Bottom-rung ('cycle') evaluation of ONE design on the solver scan.

    Only reached when ``backend.cycle_exact`` holds for the design, i.e.
    the solver is bit-identical to the fused path (integer STDP steps, no
    stabilizer, integer init weights) — the ladder never trades semantics
    for availability.
    """
    params = column_lib.fit(
        {"w": jnp.asarray(w0)}, volleys, cfg, epochs=epochs, mode="cycle"
    )
    asg = np.asarray(
        column_lib.cluster_assignments(params, volleys, cfg, "cycle")
    )
    return asg, jnp.asarray(params["w"])


def _design_guard(
    cfg: ColumnConfig, asg_i: np.ndarray, w_i
) -> Optional[tuple[str, str]]:
    """Post-training degeneracy checks for one design (guarded sweeps).

    Returns (stage, diagnostic) for a quarantinable outcome, None for a
    healthy design: non-finite trained weights (a NaN/inf anywhere makes
    the design's assignments meaningless), or a fully silent design (no
    volley produced an output spike, so every assignment is the
    'unclustered' bucket and the Rand index carries no information).
    """
    w_np = np.asarray(w_i)
    if not np.all(np.isfinite(w_np)):
        return (
            "weights",
            f"non-finite weights after training "
            f"(nan={int(np.isnan(w_np).sum())}, "
            f"inf={int(np.isinf(w_np).sum())})",
        )
    if np.all(np.asarray(asg_i) == cfg.q):
        return (
            "silent",
            "silent design: no output spikes on any volley, "
            "Rand index undefined",
        )
    return None


def _eval_bucket_guarded(
    cfgs: Sequence[ColumnConfig],
    idxs: Sequence[int],
    envelope: tuple[int, int, int],
    enc: Sequence[jnp.ndarray],
    w_init: Sequence[np.ndarray],
    epochs: int,
    lowering: str,
) -> list:
    """Fault-isolated evaluation of one envelope bucket.

    Walks the central degradation ladder (``backend.lowering_ladder``)
    bucket-wise first — a rung failure (Mosaic lowering error, OOM) is
    usually envelope-wide, and one retry at the next rung fixes every
    member with one compilation.  Only when *every* fused rung fails
    bucket-wise does it isolate per design: each member re-runs alone
    (its own envelope — bit-identical by the padding contract) down the
    same ladder, then the 'cycle' solver rung where that is provably
    exact, so one degenerate design quarantines itself and never its
    bucket-mates.

    Returns one outcome per member, aligned with ``idxs``: either a
    tuple ``('ok', asg, w, shards, lowering_ran, retries, plan_meta)``
    or an ``EvalFailure``.
    """
    ladder = backend_lib.lowering_ladder(lowering)
    attempts: list[tuple[str, str]] = []
    for low in ladder:
        try:
            asg_b, w_b, shards, plan_meta = _sweep_bucket(
                cfgs, idxs, envelope, enc, w_init, epochs, low
            )
            return [
                ("ok", asg_b[j], w_b[j], shards, low, len(attempts),
                 plan_meta)
                for j in range(len(idxs))
            ]
        except Exception as e:  # noqa: BLE001 — the guard IS the feature
            attempts.append((low, repr(e)))
    out = []
    for i in idxs:
        c = cfgs[i]
        d_attempts = list(attempts)
        done = None
        solo_ladder = backend_lib.lowering_ladder(
            lowering, cycle_exact=backend_lib.cycle_exact(
                c, jnp.asarray(w_init[i])
            ),
        )[: backend_lib.MAX_EVAL_RETRIES]
        for low in solo_ladder:
            try:
                if low == "cycle":
                    asg_i, w_i = _eval_design_solver(
                        c, enc[i], w_init[i], epochs
                    )
                    plan_i = None
                else:
                    asg_1, w_1, _, plan_i = _sweep_bucket(
                        cfgs, [i], (c.p, c.q, c.t_max), enc, w_init,
                        epochs, low,
                    )
                    asg_i, w_i = asg_1[0], w_1[0]
                done = ("ok", asg_i, w_i, 1, low, len(d_attempts), plan_i)
                break
            except Exception as e:  # noqa: BLE001
                d_attempts.append((low, repr(e)))
        if done is None:
            out.append(
                EvalFailure(
                    index=i,
                    stage="fit",
                    error=d_attempts[-1][1],
                    lowerings=tuple(l for l, _ in d_attempts),
                    retries=len(d_attempts),
                )
            )
        else:
            out.append(done)
    return out


def cluster_time_series_many(
    series: np.ndarray,
    labels: Optional[np.ndarray],
    cfgs: Sequence[ColumnConfig],
    epochs: int = 8,
    seed: int = 0,
    encoder: str = "latency",
    waste_cap: Optional[float] = None,
    max_bucket: Optional[int] = None,
    on_error: str = "raise",
    w_init: Optional[Sequence[np.ndarray]] = None,
    bucket_callback: Optional[Callable] = None,
    monitor=None,
) -> list[SweepOutcome]:
    """Sweep several column designs over one stream, envelope-bucketed.

    Designs are partitioned into **envelope buckets** by the central
    policy ``backend.envelope_buckets``: members pack into a shared
    (p, q, t_max) padding envelope while padding keeps every member's
    per-volley fire volume within ``waste_cap`` (default
    ``backend.ENVELOPE_WASTE_CAP``) of its true volume — so a 5-neuron
    design never pays a 96-neuron design's padding on every volley.  Each
    bucket runs as ONE compiled program: per-design threshold / window /
    live-neuron count become traced scalars — runtime SMEM operands of the
    Mosaic kernel on TPU, ``vmap``-ed operands of the reference body
    elsewhere (``backend.padded_lowering`` picks) — driving a single
    jitted volley-blocked scan (``backend.volley_block`` volleys folded
    per step) plus one batched assignment pass.  Compilation cost is one
    trace per distinct bucket (envelope shape, member count) pair:
    buckets agreeing on both — e.g. same-shape designs split into full
    ``max_bucket`` groups — share one trace, and bucketing never changes
    results: every design trains bit-identically under any envelope that
    contains it, including the old single-global-envelope sweep
    (``waste_cap=float('inf')`` reproduces that exactly).

    Each bucket's design axis is **sharded across local devices** when the
    central shard policy finds a usable mesh (``backend.design_mesh``;
    per-design work is embarrassingly parallel, so GSPMD splits the scans
    with no collectives).  Single-device hosts fall back to the unsharded
    path with identical results; the shard count rides on
    ``ClusteringResult.shards``.

    This front-end always trains on the fused path (there is no ``mode``
    knob): every design must fit the fused contract — expected-mode STDP,
    index tie-break WTA, and a response the selected lowering supports —
    or the sweep raises up front.  The fused path is deterministic, so
    ``seed`` only feeds weight initialization — split per design BEFORE
    bucketing, so equal seeds reproduce the sweep bit-for-bit on every
    host under every bucketing/sharding.  An empty stream (N=0) raises a
    ValueError up front; ``epochs=0`` is well-defined and returns the
    designs' init weights with assignments from those weights.

    Designs must share the response function, STDP rule, WTA config and
    w_max (they are compile-time constants of the fused step); q, t_max and
    threshold may vary freely.  p is pinned by the encoder — every design
    sees the same stream, so ``cfg.p`` must equal the encoded width for all
    of them.  ``train_seconds`` on every result is the wall time of the
    whole sweep (all buckets), not a per-design share; ``lowering`` records
    the lowering that ran, ``buckets``/``shards`` the bucket count and the
    design's bucket shard count.

    **Fault isolation** (``on_error``): the default ``'raise'`` propagates
    any evaluation failure — one degenerate design aborts the sweep, the
    right behavior for interactive runs and tests.  ``'isolate'`` instead
    converts per-design failures into structured ``EvalFailure`` records
    in the result list and keeps sweeping: a failing bucket retries down
    the central lowering-degradation ladder
    (``backend.lowering_ladder``; a fallback changes the lowering, never
    the semantics), a bucket failing every rung is re-run design-by-design
    so one bad design never quarantines its bucket-mates, and trained
    designs with non-finite weights or no output spikes at all are
    quarantined post-hoc (``EvalFailure.stage`` 'weights' / 'silent').
    Surviving designs are bit-identical to a failure-free sweep.

    ``w_init`` overrides the seed-derived per-design init weights (one
    ``[p, q]`` array per config) — ``dse.explore`` uses it to key inits
    by *candidate* rather than by position, so journal-resumed partial
    sweeps reproduce the full run exactly.  ``bucket_callback(idxs,
    results)`` fires after each bucket's outcomes are final (the journal
    hook: a kill loses at most one bucket); ``monitor`` is an optional
    ``distributed.straggler.StepMonitor`` whose ``start``/``stop``
    bracket every bucket, flagging wall-time outliers.

    Returns one outcome per config, in input order: ``ClusteringResult``
    everywhere under ``'raise'``, ``ClusteringResult | EvalFailure``
    under ``'isolate'``.
    """
    from repro.clustering.metrics import rand_index as rand_index_fn

    if on_error not in ("raise", "isolate"):
        raise ValueError(
            f"unknown on_error: {on_error!r} ('raise' | 'isolate')"
        )
    if not cfgs:
        return []
    c0 = cfgs[0]
    lowering = backend_lib.padded_lowering(c0.neuron.response)
    for c in cfgs:
        fused_column.check_fusable(c, lowering)
        same = (
            c.neuron.response == c0.neuron.response
            and c.neuron.w_max == c0.neuron.w_max
            and c.stdp == c0.stdp
            and c.wta == c0.wta
        )
        if not same:
            raise ValueError(
                "cluster_time_series_many needs designs sharing response, "
                "w_max, STDP and WTA configs"
            )

    x = jnp.asarray(series)
    if x.shape[0] == 0:
        raise ValueError(
            "cluster_time_series_many needs a non-empty stream (got N=0 "
            "series)"
        )
    d = len(cfgs)

    # Encode + init per design BEFORE bucketing: the per-design PRNG key
    # assignment (and with it every result) is a function of the input
    # order alone, never of how designs were bucketed.
    enc = [_encode(x, c, encoder) for c in cfgs]  # D x [N, p]
    if w_init is None:
        rng = jax.random.key(seed)
        rng, init_key = jax.random.split(rng)
        keys = jax.random.split(init_key, d)
        w_init = [
            np.asarray(column_lib.init_params(k, c)["w"])
            for k, c in zip(keys, cfgs)
        ]
    else:
        if len(w_init) != d:
            raise ValueError(
                f"w_init must provide one array per config "
                f"({len(w_init)} != {d})"
            )
        w_init = [np.asarray(w, np.float32) for w in w_init]
        for w, c in zip(w_init, cfgs):
            if w.shape != (c.p, c.q):
                raise ValueError(
                    f"w_init shape {w.shape} != design shape {(c.p, c.q)}"
                )

    buckets = backend_lib.envelope_buckets(
        [(c.p, c.q, c.t_max) for c in cfgs],
        waste_cap=waste_cap, max_bucket=max_bucket,
        # stream-length hint: lets a calibrated host derive the waste cap
        # from the compile-vs-recurring-waste break-even (constants cap
        # otherwise; an explicit waste_cap always wins either way)
        n_volleys=series.shape[0], epochs=epochs,
    )

    out: list[Optional[SweepOutcome]] = [None] * d
    n_buckets = len(buckets)
    t0 = time.perf_counter()
    for envelope, idxs in buckets:
        if monitor is not None:
            monitor.start()
        if on_error == "isolate":
            evals = _eval_bucket_guarded(
                cfgs, idxs, envelope, enc, w_init, epochs, lowering
            )
        else:
            asg_b, w_b, shards, plan_meta = _sweep_bucket(
                cfgs, idxs, envelope, enc, w_init, epochs, lowering
            )
            evals = [
                ("ok", asg_b[j], w_b[j], shards, lowering, 0, plan_meta)
                for j in range(len(idxs))
            ]
        bucket_out: list[SweepOutcome] = []
        for j, i in enumerate(idxs):
            ev = evals[j]
            if isinstance(ev, EvalFailure):
                out[i] = ev
                bucket_out.append(ev)
                continue
            _, asg_i, w_i, shards_i, low_i, retries_i, plan_i = ev
            if on_error == "isolate":
                bad = _design_guard(cfgs[i], asg_i, w_i)
                if bad is not None:
                    out[i] = EvalFailure(
                        index=i, stage=bad[0], error=bad[1],
                        lowerings=(low_i,), retries=retries_i,
                    )
                    bucket_out.append(out[i])
                    continue
            ri = float("nan")
            if labels is not None:
                ri = float(
                    rand_index_fn(np.asarray(labels), np.asarray(asg_i))
                )
            res = ClusteringResult(
                np.asarray(asg_i), ri, {"w": w_i}, 0.0, "pallas", low_i,
                buckets=n_buckets, shards=shards_i, retries=retries_i,
                plan=plan_i,
            )
            out[i] = res
            bucket_out.append(res)
        if monitor is not None:
            monitor.stop()
        if bucket_callback is not None:
            bucket_callback(list(idxs), bucket_out)
    train_seconds = time.perf_counter() - t0
    # every result reports the whole sweep's wall time (documented
    # contract) — patched after the loop so bucket callbacks always see
    # otherwise-final records
    for r in out:
        if isinstance(r, ClusteringResult):
            r.train_seconds = train_seconds
    return out


# --------------------------------------------------- multi-layer networks
def cluster_time_series_network(
    series: np.ndarray,
    labels: Optional[np.ndarray],
    cfg: NetworkConfig,
    epochs: int = 8,
    mode: str = "auto",
    seed: int = 0,
    encoder: str = "latency",
) -> ClusteringResult:
    """End-to-end clustering with a multi-layer TNN design.

    Same loop as ``cluster_time_series`` — encode -> greedy layer-wise
    online STDP -> assign clusters -> rand index — but the design is a
    ``NetworkConfig``: layer l's post-WTA volleys feed layer l+1, each layer
    trains as ONE jitted donated scan on the backend ``mode`` resolves to
    (see ``network.fit_greedy``), and the cluster id of a volley is the
    winner index in the final layer's concatenated output (out_width ==
    the 'unclustered' bucket).

    ``mode`` is resolved per layer (same knob semantics as
    ``network.fit_greedy``); fused layers run the lowering
    ``backend.padded_lowering`` selects, recorded on the result.  ``seed``
    derives both the weight init and the training key handed to
    ``fit_greedy``, so stochastic layer configs are always legally keyed
    here and equal seeds reproduce the run exactly.

    The encoded width must match layer 0's connectivity plan
    (``network.validate``); ``cfg.layers[0]`` fixes the encoder geometry the
    way ``cfg.p`` does for single columns.
    """
    from repro.clustering.metrics import rand_index as rand_index_fn
    from repro.core import network as network_lib

    volleys = _encode_width(
        jnp.asarray(series), cfg.layers[0].column.t_max,
        network_lib.in_width(cfg), encoder,
    )
    rng = jax.random.key(seed)
    rng, init_key = jax.random.split(rng)
    params = network_lib.init_params(init_key, cfg, volleys.shape[-1])

    t0 = time.perf_counter()
    layer_plans: list = []
    params = network_lib.fit_greedy(
        params, volleys, cfg, epochs=epochs, mode=mode, rng=rng,
        plan_sink=layer_plans,
    )
    assignments = np.asarray(
        network_lib.cluster_assignments(params, volleys, cfg, mode)
    )
    train_seconds = time.perf_counter() - t0

    ri = float("nan")
    if labels is not None:
        ri = float(rand_index_fn(np.asarray(labels), assignments))
    lows = {
        backend_lib.padded_lowering(layer.column.neuron.response)
        for layer in cfg.layers
        if backend_lib.resolve(mode, layer.column, training=True) == "pallas"
    }
    # '' when no layer trained fused; comma-joined when fused layers mixed
    # lowerings (e.g. RNL on the Mosaic kernel + SNL on the reference body)
    return ClusteringResult(
        # unified params contract (see ClusteringResult): always a dict —
        # the per-layer param list rides under 'layers'
        assignments, ri, {"layers": params}, train_seconds, mode,
        ",".join(sorted(lows)),
        plan={"layers": layer_plans} if layer_plans else None,
    )
