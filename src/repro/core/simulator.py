"""TNNGen functional simulator front-end (paper §II-A).

Ties encoding + column/network inference + online STDP + clustering metrics
into the "rapid application exploration" loop the paper describes.  The
``mode`` knob selects a backend from the unified registry
(``repro.core.backend``):

  'auto'   — hybrid: event-driven closed form where exact (RNL/SNL),
             cycle-accurate scan where required (LIF); training routes to
             the fused column step whenever the config fits its contract.
  'event'  — force the closed form.
  'cycle'  — force cycle-accurate lax.scan (bit-identical to generated RTL).
  'pallas' — force the fused kernel path (Mosaic on TPU; the jnp reference
             lowering of the same fused step elsewhere).

``cluster_time_series_many`` runs a whole *design sweep* — multiple column
configs over the same sensory stream — as ONE compiled program by padding
every design into a shared (p, q, t_max) envelope and ``vmap``-ing the fused
training step over the design axis (threshold / window / live-neuron count
become traced per-design scalars).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import column as column_lib
from repro.core import encoding
from repro.core.types import ColumnConfig, TIME_DTYPE
from repro.kernels import fused_column, ref


@dataclasses.dataclass
class ClusteringResult:
    assignments: np.ndarray  # [N] cluster ids (q == unclustered)
    rand_index: float
    params: dict
    train_seconds: float
    mode: str


def suggest_threshold(cfg: ColumnConfig) -> float:
    """Default firing threshold scaling used by the simulator.

    Expected saturated potential is p * w_max / 2 for uniform weights; firing
    around a quarter of that keeps spike times mid-window, the operating
    point the TNN microarchitecture calibrates for.
    """
    return max(1.0, 0.25 * cfg.p * cfg.neuron.w_max / 2.0)


def _encode(x: jnp.ndarray, cfg: ColumnConfig, encoder: str) -> jnp.ndarray:
    if encoder == "latency":
        volleys = encoding.latency_encode(x, cfg.t_max)
    elif encoder == "onoff":
        volleys = encoding.onoff_encode(x, cfg.t_max)
    else:
        raise ValueError(f"unknown encoder: {encoder!r}")
    if volleys.shape[-1] != cfg.p:
        raise ValueError(
            f"encoded width {volleys.shape[-1]} != cfg.p {cfg.p}"
        )
    return volleys


def cluster_time_series(
    series: np.ndarray,
    labels: Optional[np.ndarray],
    cfg: ColumnConfig,
    epochs: int = 8,
    mode: str = "auto",
    seed: int = 0,
    encoder: str = "latency",
) -> ClusteringResult:
    """End-to-end: encode -> online STDP -> assign clusters -> rand index.

    Args:
      series: [N, L] real-valued time series (L == cfg.p for 'latency',
        2L == cfg.p for 'onoff').
      labels: [N] integer class labels, or None (rand_index = nan).
      cfg: column config (p x q).
      epochs: STDP passes over the data.
      mode: simulation backend (see module docstring).
      seed: PRNG seed.
      encoder: 'latency' or 'onoff'.
    """
    from repro.clustering.metrics import rand_index as rand_index_fn

    volleys = _encode(jnp.asarray(series), cfg, encoder)
    rng = jax.random.key(seed)
    rng, init_key = jax.random.split(rng)
    params = column_lib.init_params(init_key, cfg)

    t0 = time.perf_counter()
    params = column_lib.fit(params, volleys, cfg, epochs=epochs, mode=mode, rng=rng)
    assignments = np.asarray(
        column_lib.cluster_assignments(params, volleys, cfg, mode)
    )
    train_seconds = time.perf_counter() - t0

    ri = float("nan")
    if labels is not None:
        ri = float(rand_index_fn(np.asarray(labels), assignments))
    return ClusteringResult(assignments, ri, params, train_seconds, mode)


# --------------------------------------------------- batched design sweep
@functools.partial(
    jax.jit,
    static_argnames=(
        "t_window", "w_max", "wta_k", "mu_capture", "mu_backoff",
        "mu_search", "stabilize", "response", "epochs",
    ),
    donate_argnums=(0,),
)
def _sweep_fit_scan(
    w,  # [D, p_max, q_max]
    xs,  # [N, D, p_max] volleys (scan axis leading)
    thresholds,  # [D]
    t_maxes,  # [D]
    q_actives,  # [D]
    t_window: int,
    w_max: int,
    wta_k: int,
    mu_capture: float,
    mu_backoff: float,
    mu_search: float,
    stabilize: bool,
    response: str,
    epochs: int,
):
    """All designs x all epochs x all volleys in one compiled program."""

    def volley(wc, xt):  # wc: [D, p, q]; xt: [D, p]
        w2, _ = jax.vmap(
            lambda wd, xd, th, tm, qa: fused_column.fused_step_ref(
                wd, xd, th, t_window, w_max, wta_k, mu_capture, mu_backoff,
                mu_search, stabilize, t_max=tm, response=response,
                integer_fire=True, q_active=qa,
            )
        )(wc, xt, thresholds, t_maxes, q_actives)
        return w2, None

    def epoch(wc, _):
        return jax.lax.scan(volley, wc, xs)

    w, _ = jax.lax.scan(epoch, w, None, length=epochs)
    return w


@functools.partial(
    jax.jit, static_argnames=("t_window", "wta_k", "response")
)
def _sweep_assign(
    w, xs, thresholds, t_maxes, q_actives,
    t_window: int, wta_k: int, response: str,
):
    """Cluster ids for every design: [N, D, p] -> [D, N]."""

    def volley(_, xt):
        def one(wd, xd, th, tm, qa):
            t = fused_column.fire_dense_ref(
                wd, xd, th, t_window, t_max=tm, response=response
            )
            qi = jnp.arange(wd.shape[1], dtype=TIME_DTYPE)
            t = jnp.where(qi < qa, t, tm)
            y = ref.wta_ref(t[None], wta_k, tm)[0]
            spiked = (y < tm).any()
            return jnp.where(spiked, jnp.argmin(y), qa).astype(TIME_DTYPE)

        return 0, jax.vmap(one)(w, xt, thresholds, t_maxes, q_actives)

    _, asg = jax.lax.scan(volley, 0, xs)  # [N, D]
    return asg.T


def cluster_time_series_many(
    series: np.ndarray,
    labels: Optional[np.ndarray],
    cfgs: Sequence[ColumnConfig],
    epochs: int = 8,
    seed: int = 0,
    encoder: str = "latency",
) -> list[ClusteringResult]:
    """Sweep several column designs over one stream as ONE compiled program.

    Every design is padded into the shared (max p, max q, max t_max)
    envelope; per-design threshold / window / live-neuron count become
    traced scalars, and the fused training step is ``vmap``-ed over the
    design axis — the whole sweep is a single jitted scan (plus one more for
    assignments), compiled once.

    Designs must share the response function, STDP rule, WTA config and
    w_max (they are compile-time constants of the fused step); q, t_max and
    threshold may vary freely.  p is pinned by the encoder — every design
    sees the same stream, so ``cfg.p`` must equal the encoded width for all
    of them (the padding machinery itself handles unequal p, should a
    future per-design front-end need it).  ``train_seconds`` on every
    result is the wall time of the whole batched sweep, not a per-design
    share.

    Returns one ClusteringResult per config, in input order.
    """
    from repro.clustering.metrics import rand_index as rand_index_fn

    if not cfgs:
        return []
    c0 = cfgs[0]
    for c in cfgs:
        fused_column.check_fusable(c, "reference")
        same = (
            c.neuron.response == c0.neuron.response
            and c.neuron.w_max == c0.neuron.w_max
            and c.stdp == c0.stdp
            and c.wta == c0.wta
        )
        if not same:
            raise ValueError(
                "cluster_time_series_many needs designs sharing response, "
                "w_max, STDP and WTA configs"
            )

    x = jnp.asarray(series)
    n = x.shape[0]
    p_max = max(c.p for c in cfgs)
    q_max = max(c.q for c in cfgs)
    t_window = max(c.t_max for c in cfgs)
    d = len(cfgs)

    # Stack padded volleys [D, N, p_max]; padding is silent (>= t_window).
    xs = jnp.full((d, n, p_max), t_window, TIME_DTYPE)
    for i, c in enumerate(cfgs):
        xs = xs.at[i, :, : c.p].set(_encode(x, c, encoder))
    xs = jnp.swapaxes(xs, 0, 1)  # scan axis leading: [N, D, p_max]

    rng = jax.random.key(seed)
    rng, init_key = jax.random.split(rng)
    keys = jax.random.split(init_key, d)
    w0 = jnp.stack([
        jnp.zeros((p_max, q_max), jnp.float32)
        .at[: c.p, : c.q]
        .set(column_lib.init_params(k, c)["w"])
        for k, c in zip(keys, cfgs)
    ])
    thresholds = jnp.asarray([c.neuron.threshold for c in cfgs], jnp.float32)
    t_maxes = jnp.asarray([c.t_max for c in cfgs], TIME_DTYPE)
    q_actives = jnp.asarray([c.q for c in cfgs], TIME_DTYPE)

    t0 = time.perf_counter()
    w = _sweep_fit_scan(
        w0, xs, thresholds, t_maxes, q_actives,
        t_window=t_window, w_max=c0.neuron.w_max, wta_k=c0.wta.k,
        mu_capture=c0.stdp.mu_capture, mu_backoff=c0.stdp.mu_backoff,
        mu_search=c0.stdp.mu_search,
        stabilize=c0.stdp.stabilizer == "half",
        response=c0.neuron.response, epochs=epochs,
    )
    asg = np.asarray(
        _sweep_assign(
            w, xs, thresholds, t_maxes, q_actives,
            t_window=t_window, wta_k=c0.wta.k,
            response=c0.neuron.response,
        )
    )
    train_seconds = time.perf_counter() - t0

    results = []
    for i, c in enumerate(cfgs):
        ri = float("nan")
        if labels is not None:
            ri = float(rand_index_fn(np.asarray(labels), asg[i]))
        params = {"w": jnp.asarray(w[i, : c.p, : c.q])}
        results.append(
            ClusteringResult(asg[i], ri, params, train_seconds, "pallas")
        )
    return results
