"""TNNGen functional simulator front-end (paper §II-A).

Ties encoding + column/network inference + online STDP + clustering metrics
into the "rapid application exploration" loop the paper describes.  The
``mode`` knob exposes the paper's hybrid timing model:

  'auto'  — event-driven closed form where exact (RNL/SNL), cycle-accurate
            scan where required (LIF); this is the paper's dynamic switch.
  'event' — force the closed form.
  'cycle' — force cycle-accurate lax.scan (bit-identical to generated RTL).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import column as column_lib
from repro.core import encoding
from repro.core.types import ColumnConfig


@dataclasses.dataclass
class ClusteringResult:
    assignments: np.ndarray  # [N] cluster ids (q == unclustered)
    rand_index: float
    params: dict
    train_seconds: float
    mode: str


def suggest_threshold(cfg: ColumnConfig) -> float:
    """Default firing threshold scaling used by the simulator.

    Expected saturated potential is p * w_max / 2 for uniform weights; firing
    around a quarter of that keeps spike times mid-window, the operating
    point the TNN microarchitecture calibrates for.
    """
    return max(1.0, 0.25 * cfg.p * cfg.neuron.w_max / 2.0)


def cluster_time_series(
    series: np.ndarray,
    labels: Optional[np.ndarray],
    cfg: ColumnConfig,
    epochs: int = 8,
    mode: str = "auto",
    seed: int = 0,
    encoder: str = "latency",
) -> ClusteringResult:
    """End-to-end: encode -> online STDP -> assign clusters -> rand index.

    Args:
      series: [N, L] real-valued time series (L == cfg.p for 'latency',
        2L == cfg.p for 'onoff').
      labels: [N] integer class labels, or None (rand_index = nan).
      cfg: column config (p x q).
      epochs: STDP passes over the data.
      mode: simulation mode.
      seed: PRNG seed.
      encoder: 'latency' or 'onoff'.
    """
    from repro.clustering.metrics import rand_index as rand_index_fn

    x = jnp.asarray(series)
    if encoder == "latency":
        volleys = encoding.latency_encode(x, cfg.t_max)
    elif encoder == "onoff":
        volleys = encoding.onoff_encode(x, cfg.t_max)
    else:
        raise ValueError(f"unknown encoder: {encoder!r}")
    if volleys.shape[-1] != cfg.p:
        raise ValueError(
            f"encoded width {volleys.shape[-1]} != cfg.p {cfg.p}"
        )

    rng = jax.random.key(seed)
    rng, init_key = jax.random.split(rng)
    params = column_lib.init_params(init_key, cfg)

    t0 = time.perf_counter()
    params = column_lib.fit(params, volleys, cfg, epochs=epochs, mode=mode, rng=rng)
    assignments = np.asarray(
        column_lib.cluster_assignments(params, volleys, cfg, mode)
    )
    train_seconds = time.perf_counter() - t0

    ri = float("nan")
    if labels is not None:
        ri = float(rand_index_fn(np.asarray(labels), assignments))
    return ClusteringResult(assignments, ri, params, train_seconds, mode)
