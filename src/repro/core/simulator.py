"""TNNGen functional simulator front-end (paper §II-A).

Ties encoding + column/network inference + online STDP + clustering metrics
into the "rapid application exploration" loop the paper describes.  The
``mode`` knob selects a backend from the unified registry
(``repro.core.backend``) and means the same thing for single columns and
multi-layer networks:

  'auto'   — hybrid: event-driven closed form where exact (RNL/SNL),
             cycle-accurate scan where required (LIF); training routes to
             the fused column step whenever the config fits its contract.
  'event'  — force the closed form.
  'cycle'  — force cycle-accurate lax.scan (bit-identical to generated RTL).
  'pallas' — force the fused kernel path (Mosaic on TPU; the jnp reference
             lowering of the same fused step elsewhere).

Three clustering front-ends share the loop:

* ``cluster_time_series`` — one column design, one stream.
* ``cluster_time_series_many`` — a whole *design sweep* as ONE compiled
  program: every design is padded into a shared (p, q, t_max) envelope and
  the fused training step runs over the design axis (threshold / window /
  live-neuron count become traced per-design scalars), advancing
  ``backend.volley_block`` volleys per scan step; assignment batches the
  whole stream instead of scanning it.  The padded scans live in
  ``repro.kernels.fused_column``.
* ``cluster_time_series_network`` — a multi-layer ``NetworkConfig`` design
  through the same encode -> fit -> assign -> rand-index loop, trained
  greedily layer-by-layer via ``network.fit_greedy`` (each layer one jitted
  donated scan on the resolved backend).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_lib
from repro.core import column as column_lib
from repro.core import encoding
from repro.core.types import ColumnConfig, NetworkConfig, TIME_DTYPE
from repro.kernels import fused_column


@dataclasses.dataclass
class ClusteringResult:
    assignments: np.ndarray  # [N] cluster ids (q == unclustered)
    rand_index: float
    params: dict
    train_seconds: float
    mode: str
    # Lowering the fused training path actually ran on this host
    # ('mosaic' | 'interpret' | 'reference'), '' when training resolved to
    # the event/cycle solvers only, comma-joined when a network's fused
    # layers mixed lowerings (e.g. 'mosaic,reference' for RNL + SNL layers
    # on TPU).
    lowering: str = ""


def suggest_threshold(cfg: ColumnConfig) -> float:
    """Default firing threshold scaling used by the simulator.

    Expected saturated potential is p * w_max / 2 for uniform weights; firing
    around a quarter of that keeps spike times mid-window, the operating
    point the TNN microarchitecture calibrates for.
    """
    return max(1.0, 0.25 * cfg.p * cfg.neuron.w_max / 2.0)


def _encode_width(
    x: jnp.ndarray, t_max: int, width: int, encoder: str
) -> jnp.ndarray:
    if encoder == "latency":
        volleys = encoding.latency_encode(x, t_max)
    elif encoder == "onoff":
        volleys = encoding.onoff_encode(x, t_max)
    else:
        raise ValueError(f"unknown encoder: {encoder!r}")
    if volleys.shape[-1] != width:
        raise ValueError(
            f"encoded width {volleys.shape[-1]} != design input width {width}"
        )
    return volleys


def _encode(x: jnp.ndarray, cfg: ColumnConfig, encoder: str) -> jnp.ndarray:
    return _encode_width(x, cfg.t_max, cfg.p, encoder)


def cluster_time_series(
    series: np.ndarray,
    labels: Optional[np.ndarray],
    cfg: ColumnConfig,
    epochs: int = 8,
    mode: str = "auto",
    seed: int = 0,
    encoder: str = "latency",
) -> ClusteringResult:
    """End-to-end: encode -> online STDP -> assign clusters -> rand index.

    Args:
      series: [N, L] real-valued time series (L == cfg.p for 'latency',
        2L == cfg.p for 'onoff').
      labels: [N] integer class labels, or None (rand_index = nan).
      cfg: column config (p x q).
      epochs: STDP passes over the data.
      mode: simulation backend, resolved by ``backend.resolve`` (see module
        docstring); forcing 'pallas' on a config outside the fused contract
        raises rather than silently switching semantics.
      seed: PRNG seed — the one source of randomness (weight init, plus the
        per-volley keys stochastic/random configs consume); equal seeds
        reproduce the run exactly on every host.
      encoder: 'latency' or 'onoff'.

    The returned ``ClusteringResult.lowering`` records which lowering of the
    fused algebra training actually ran ('mosaic' on TPU, 'reference'
    elsewhere), or '' when it trained on the event/cycle solvers.
    """
    from repro.clustering.metrics import rand_index as rand_index_fn

    volleys = _encode(jnp.asarray(series), cfg, encoder)
    rng = jax.random.key(seed)
    rng, init_key = jax.random.split(rng)
    params = column_lib.init_params(init_key, cfg)

    t0 = time.perf_counter()
    params = column_lib.fit(params, volleys, cfg, epochs=epochs, mode=mode, rng=rng)
    assignments = np.asarray(
        column_lib.cluster_assignments(params, volleys, cfg, mode)
    )
    train_seconds = time.perf_counter() - t0

    ri = float("nan")
    if labels is not None:
        ri = float(rand_index_fn(np.asarray(labels), assignments))
    resolved = backend_lib.resolve(mode, cfg, training=True)
    lowering = (
        backend_lib.padded_lowering(cfg.neuron.response)
        if resolved == "pallas"
        else ""
    )
    return ClusteringResult(
        assignments, ri, params, train_seconds, mode, lowering
    )


# --------------------------------------------------- batched design sweep
def cluster_time_series_many(
    series: np.ndarray,
    labels: Optional[np.ndarray],
    cfgs: Sequence[ColumnConfig],
    epochs: int = 8,
    seed: int = 0,
    encoder: str = "latency",
) -> list[ClusteringResult]:
    """Sweep several column designs over one stream as ONE compiled program.

    Every design is padded into the shared (max p, max q, max t_max)
    envelope; per-design threshold / window / live-neuron count become
    traced scalars — runtime SMEM operands of the Mosaic kernel on TPU,
    ``vmap``-ed operands of the reference body elsewhere
    (``backend.padded_lowering`` picks) — and the whole sweep is a single
    jitted volley-blocked scan (``backend.volley_block`` volleys folded
    per step) plus one batched assignment pass, compiled ONCE per
    envelope shape, never per design.

    This front-end always trains on the fused path (there is no ``mode``
    knob): every design must fit the fused contract — expected-mode STDP,
    index tie-break WTA, and a response the selected lowering supports —
    or the sweep raises up front.  The fused path is deterministic, so
    ``seed`` only feeds weight initialization; equal seeds reproduce the
    sweep bit-for-bit on every host.

    Designs must share the response function, STDP rule, WTA config and
    w_max (they are compile-time constants of the fused step); q, t_max and
    threshold may vary freely.  p is pinned by the encoder — every design
    sees the same stream, so ``cfg.p`` must equal the encoded width for all
    of them (the padding machinery itself handles unequal p, should a
    future per-design front-end need it).  ``train_seconds`` on every
    result is the wall time of the whole batched sweep, not a per-design
    share; ``lowering`` records the lowering that actually ran.

    Returns one ClusteringResult per config, in input order.
    """
    from repro.clustering.metrics import rand_index as rand_index_fn

    if not cfgs:
        return []
    c0 = cfgs[0]
    lowering = backend_lib.padded_lowering(c0.neuron.response)
    for c in cfgs:
        fused_column.check_fusable(c, lowering)
        same = (
            c.neuron.response == c0.neuron.response
            and c.neuron.w_max == c0.neuron.w_max
            and c.stdp == c0.stdp
            and c.wta == c0.wta
        )
        if not same:
            raise ValueError(
                "cluster_time_series_many needs designs sharing response, "
                "w_max, STDP and WTA configs"
            )

    x = jnp.asarray(series)
    n = x.shape[0]
    p_max = max(c.p for c in cfgs)
    q_max = max(c.q for c in cfgs)
    t_window = max(c.t_max for c in cfgs)
    d = len(cfgs)

    # Stack padded volleys [D, N, p_max] in ONE shot: every design's encode
    # is stacked and the whole [D, N, p] block lands in the silent-padded
    # buffer with a single set — no per-design ``.at[i].set`` dispatch
    # chain, O(1) graph nodes however many designs ride the sweep.
    # (Designs currently share p — the encoder pins it — so the stack is
    # uniform; the single set keeps the p < p_max envelope case working
    # should a future front-end relax that.)
    enc = jnp.stack([_encode(x, c, encoder) for c in cfgs])  # [D, N, p]
    xs = jnp.full((d, n, p_max), t_window, TIME_DTYPE)
    xs = xs.at[:, :, : enc.shape[-1]].set(enc)
    xs = jnp.swapaxes(xs, 0, 1)  # scan axis leading: [N, D, p_max]

    rng = jax.random.key(seed)
    rng, init_key = jax.random.split(rng)
    keys = jax.random.split(init_key, d)
    # Per-design init draws stay per-(key, shape) — seed semantics — but
    # the padded stack is assembled host-side and shipped as ONE buffer
    # instead of a D-deep ``.at[i].set`` graph.
    w0_np = np.zeros((d, p_max, q_max), np.float32)
    for i, (k, c) in enumerate(zip(keys, cfgs)):
        w0_np[i, : c.p, : c.q] = np.asarray(
            column_lib.init_params(k, c)["w"]
        )
    w0 = jnp.asarray(w0_np)
    thresholds = jnp.asarray([c.neuron.threshold for c in cfgs], jnp.float32)
    t_maxes = jnp.asarray([c.t_max for c in cfgs], TIME_DTYPE)
    q_actives = jnp.asarray([c.q for c in cfgs], TIME_DTYPE)

    t0 = time.perf_counter()
    w = fused_column.fit_scan_padded(
        w0, xs, thresholds, t_maxes, q_actives,
        t_window=t_window, w_max=c0.neuron.w_max, wta_k=c0.wta.k,
        mu_capture=c0.stdp.mu_capture, mu_backoff=c0.stdp.mu_backoff,
        mu_search=c0.stdp.mu_search,
        stabilize=c0.stdp.stabilizer == "half",
        response=c0.neuron.response, epochs=epochs, lowering=lowering,
        # v_blk defaults to the central backend.volley_block policy
    )
    # assignment batches volleys (kernel grid / vmapped blocks); the kernel
    # fires on the integer weight grid, so it is only auto-selected when
    # the trained weights concretely sit on that grid (pure lowering
    # choice) — float weights keep the reference fire on every host.
    asg_lowering = backend_lib.assign_lowering(c0.neuron.response, w)
    asg = np.asarray(
        fused_column.assign_padded(
            w, xs, thresholds, t_maxes, q_actives,
            t_window=t_window, wta_k=c0.wta.k,
            response=c0.neuron.response, lowering=asg_lowering,
            w_max=c0.neuron.w_max,
        )
    )
    train_seconds = time.perf_counter() - t0

    results = []
    for i, c in enumerate(cfgs):
        ri = float("nan")
        if labels is not None:
            ri = float(rand_index_fn(np.asarray(labels), asg[i]))
        params = {"w": jnp.asarray(w[i, : c.p, : c.q])}
        results.append(
            ClusteringResult(
                asg[i], ri, params, train_seconds, "pallas", lowering
            )
        )
    return results


# --------------------------------------------------- multi-layer networks
def cluster_time_series_network(
    series: np.ndarray,
    labels: Optional[np.ndarray],
    cfg: NetworkConfig,
    epochs: int = 8,
    mode: str = "auto",
    seed: int = 0,
    encoder: str = "latency",
) -> ClusteringResult:
    """End-to-end clustering with a multi-layer TNN design.

    Same loop as ``cluster_time_series`` — encode -> greedy layer-wise
    online STDP -> assign clusters -> rand index — but the design is a
    ``NetworkConfig``: layer l's post-WTA volleys feed layer l+1, each layer
    trains as ONE jitted donated scan on the backend ``mode`` resolves to
    (see ``network.fit_greedy``), and the cluster id of a volley is the
    winner index in the final layer's concatenated output (out_width ==
    the 'unclustered' bucket).

    ``mode`` is resolved per layer (same knob semantics as
    ``network.fit_greedy``); fused layers run the lowering
    ``backend.padded_lowering`` selects, recorded on the result.  ``seed``
    derives both the weight init and the training key handed to
    ``fit_greedy``, so stochastic layer configs are always legally keyed
    here and equal seeds reproduce the run exactly.

    The encoded width must match layer 0's connectivity plan
    (``network.validate``); ``cfg.layers[0]`` fixes the encoder geometry the
    way ``cfg.p`` does for single columns.
    """
    from repro.clustering.metrics import rand_index as rand_index_fn
    from repro.core import network as network_lib

    volleys = _encode_width(
        jnp.asarray(series), cfg.layers[0].column.t_max,
        network_lib.in_width(cfg), encoder,
    )
    rng = jax.random.key(seed)
    rng, init_key = jax.random.split(rng)
    params = network_lib.init_params(init_key, cfg, volleys.shape[-1])

    t0 = time.perf_counter()
    params = network_lib.fit_greedy(
        params, volleys, cfg, epochs=epochs, mode=mode, rng=rng
    )
    assignments = np.asarray(
        network_lib.cluster_assignments(params, volleys, cfg, mode)
    )
    train_seconds = time.perf_counter() - t0

    ri = float("nan")
    if labels is not None:
        ri = float(rand_index_fn(np.asarray(labels), assignments))
    lows = {
        backend_lib.padded_lowering(layer.column.neuron.response)
        for layer in cfg.layers
        if backend_lib.resolve(mode, layer.column, training=True) == "pallas"
    }
    # '' when no layer trained fused; comma-joined when fused layers mixed
    # lowerings (e.g. RNL on the Mosaic kernel + SNL on the reference body)
    return ClusteringResult(
        assignments, ri, params, train_seconds, mode, ",".join(sorted(lows))
    )
