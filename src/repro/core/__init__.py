# The paper's primary contribution: the TNNGen functional simulator —
# temporal (spike-time) neural networks with RNL/SNL/LIF response functions,
# WTA inhibition, online STDP, and hybrid event-driven / cycle-accurate
# timing, implemented in JAX.
from repro.core.types import (  # noqa: F401
    ColumnConfig,
    LayerConfig,
    NetworkConfig,
    NeuronConfig,
    STDPConfig,
    TIME_DTYPE,
    WEIGHT_DTYPE,
    WTAConfig,
    no_spike,
)
from repro.core import column, encoding, network, neuron, simulator, stdp, wta  # noqa: F401
