"""Multi-layer TNNs: grids of columns with configurable connectivity.

Paper §II-A: "large multi-layer TNNs with an arbitrary number of layers and
columns per layer with configurable inter-layer connectivity".  Layer l holds
``columns`` parallel columns; their post-WTA spike volleys concatenate into
the next layer's input volley.  Training is greedy layer-wise unsupervised
STDP (the standard TNN recipe — each layer converges on the spike statistics
of the layer below).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import column as column_lib
from repro.core.types import LayerConfig, NetworkConfig, TIME_DTYPE


def _layer_input_width(layer: LayerConfig, in_width: int) -> int:
    if layer.connectivity == "full":
        return in_width
    if in_width % layer.columns != 0:
        raise ValueError(
            f"tiled connectivity needs in_width % columns == 0, got "
            f"{in_width} % {layer.columns}"
        )
    return in_width // layer.columns


def validate(cfg: NetworkConfig, in_width: int) -> None:
    """Check that declared column widths match the connectivity plan."""
    width = in_width
    for li, layer in enumerate(cfg.layers):
        need = _layer_input_width(layer, width)
        if layer.column.p != need:
            raise ValueError(
                f"layer {li}: column.p={layer.column.p} but connectivity "
                f"provides {need} inputs"
            )
        width = layer.columns * layer.column.q


def init_params(rng: jax.Array, cfg: NetworkConfig, in_width: int) -> list:
    """Per-layer params: list of {'w': [columns, p, q]} stacked over columns."""
    validate(cfg, in_width)
    params = []
    for layer in cfg.layers:
        rng, sub = jax.random.split(rng)
        keys = jax.random.split(sub, layer.columns)
        w = jax.vmap(lambda k: column_lib.init_params(k, layer.column)["w"])(keys)
        params.append({"w": w})
    return params


def _apply_layer(
    lp: dict, x: jnp.ndarray, layer: LayerConfig, mode: str
) -> jnp.ndarray:
    """x: [..., in_width] -> [..., columns * q] post-WTA spike times."""
    c = layer.columns
    if layer.connectivity == "full":
        xc = jnp.broadcast_to(x[..., None, :], x.shape[:-1] + (c, x.shape[-1]))
    else:
        xc = x.reshape(x.shape[:-1] + (c, layer.column.p))

    def one(w, xi):  # w: [p, q]; xi: [..., p]
        y, _ = column_lib.apply({"w": w}, xi, layer.column, mode)
        return y

    y = jax.vmap(one, in_axes=(0, -2), out_axes=-2)(lp["w"], xc)
    return y.reshape(y.shape[:-2] + (c * layer.column.q,))


def apply(
    params: list, x_times: jnp.ndarray, cfg: NetworkConfig, mode: str = "auto"
) -> jnp.ndarray:
    """Forward a volley through all layers; returns final spike volley."""
    h = x_times
    for lp, layer in zip(params, cfg.layers):
        h = _apply_layer(lp, h, layer, mode)
    return h


def fit_greedy(
    params: list,
    x_times: jnp.ndarray,
    cfg: NetworkConfig,
    epochs: int = 8,
    mode: str = "auto",
    rng: Optional[jax.Array] = None,
) -> list:
    """Greedy layer-wise unsupervised STDP training.

    Each layer is trained to convergence on the (frozen) output of the stack
    below it, then frozen in turn — the online-learning recipe the hardware
    implements with per-column local learning only.
    """
    if rng is None:
        rng = jax.random.key(0)
    h = x_times
    new_params = []
    for li, (lp, layer) in enumerate(zip(params, cfg.layers)):
        c = layer.columns
        if layer.connectivity == "full":
            hc = jnp.broadcast_to(h[..., None, :], h.shape[:-1] + (c, h.shape[-1]))
        else:
            hc = h.reshape(h.shape[:-1] + (c, layer.column.p))

        w = lp["w"]
        for e in range(epochs):
            rng, sub = jax.random.split(rng)
            keys = jax.random.split(sub, c)

            def one(wi, xi, ki):
                p, _ = column_lib.train_step(
                    {"w": wi}, xi, layer.column, mode, rng=ki
                )
                return p["w"]

            w = jax.vmap(one, in_axes=(0, -2, 0))(w, hc, keys)
        new_params.append({"w": w})
        h = _apply_layer({"w": w}, h, layer, mode)
    return new_params
