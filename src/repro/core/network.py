"""Multi-layer TNNs: grids of columns with configurable connectivity.

Paper §II-A: "large multi-layer TNNs with an arbitrary number of layers and
columns per layer with configurable inter-layer connectivity".  Layer l holds
``columns`` parallel columns; their post-WTA spike volleys concatenate into
the next layer's input volley.  Training is greedy layer-wise unsupervised
STDP (the standard TNN recipe — each layer converges on the spike statistics
of the layer below).

Execution is dispatched through the backend registry (``repro.core.backend``)
exactly as for single columns: ``mode`` accepts 'auto' | 'event' | 'cycle' |
'pallas' and is resolved *per layer* against that layer's column config, so
the knob means the same thing for networks as for columns ('auto' routes
each layer's training to the fused path whenever its config fits the fused
contract, and falls back to the hybrid solvers otherwise).

``fit_greedy`` runs each layer's whole epochs x volleys loop as ONE jitted,
donated ``lax.scan``:

* layers that resolve to 'pallas' share the padded-envelope fused scan of
  ``repro.kernels.fused_column.fit_scan_padded`` — fused layers that can
  share a compiled step (same column count and static hyper-parameters,
  sizes within ``backend.ENVELOPE_WASTE_CAP`` of each other) are padded into one
  (p, q, t_max) envelope and the fused column step runs over the layer's
  columns axis, so heterogeneous layers reuse one compiled step when close
  enough in size that padding compute stays bounded (at most one
  compilation per distinct layer shape).  The scan is volley-blocked
  (``backend.volley_block`` volleys folded per step, bit-identical to the
  per-volley fold) and lowers through ``backend.padded_lowering``: the
  Mosaic kernel on TPU (per-layer threshold / window / live-q / STDP mus
  are runtime SMEM operands of one static envelope), the jnp reference
  body of the same algebra elsewhere — bit-identical on integer weight
  grids either way;
* layers that resolve to 'event' / 'cycle' (LIF, stochastic STDP, random
  tie-break, ...) run the same solver volley body as ``column.fit``
  (``backend.solver_volley_step``) scanned over epochs x volleys and
  ``vmap``-ed over columns — one compilation per layer *config* (the
  solver scan specializes on the full column config, threshold included).

An explicit ``mode='pallas'`` validates layers against the fused contract
exactly like single-column ``fit``: RNL trains on the kernel wherever one
exists; SNL layers are legal too and take the reference body of the same
fused algebra on every host (``backend.padded_lowering`` picks the
lowering, never the semantics).

The greedy handoff (``apply`` of the frozen stack below) is jitted per
layer as well; no Python-level per-epoch dispatch survives anywhere in
network training.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import backend as backend_lib
from repro.core import column as column_lib
from repro.core.types import (
    ColumnConfig,
    LayerConfig,
    NetworkConfig,
    TIME_DTYPE,
)
from repro.kernels import fused_column


def _layer_input_width(layer: LayerConfig, in_width: int) -> int:
    if layer.connectivity == "full":
        return in_width
    if in_width % layer.columns != 0:
        raise ValueError(
            f"tiled connectivity needs in_width % columns == 0, got "
            f"{in_width} % {layer.columns}"
        )
    return in_width // layer.columns


def validate(cfg: NetworkConfig, in_width: int) -> None:
    """Check that declared column widths match the connectivity plan, and
    that temporal windows never grow across layers.

    A layer's no-spike sentinel IS its ``t_max`` (``types.no_spike``), so a
    downstream layer with a *larger* window would read upstream silence as
    a live late spike — silently corrupting every backend identically.
    Nonincreasing ``t_max`` keeps the sentinel silent everywhere; shrinking
    windows are fine (late spikes fall outside the next window).
    """
    width = in_width
    for li, layer in enumerate(cfg.layers):
        need = _layer_input_width(layer, width)
        if layer.column.p != need:
            raise ValueError(
                f"layer {li}: column.p={layer.column.p} but connectivity "
                f"provides {need} inputs"
            )
        if li > 0 and layer.column.t_max > cfg.layers[li - 1].column.t_max:
            raise ValueError(
                f"layer {li}: t_max={layer.column.t_max} exceeds layer "
                f"{li - 1}'s t_max={cfg.layers[li - 1].column.t_max}; the "
                "upstream no-spike sentinel would alias into a live spike"
            )
        width = layer.columns * layer.column.q


def in_width(cfg: NetworkConfig) -> int:
    """Input volley width layer 0's connectivity plan expects.

    The inverse of ``_layer_input_width`` for the first layer — front-ends
    (e.g. the simulator's encoder) size their volleys from this instead of
    re-deriving connectivity semantics.
    """
    layer0 = cfg.layers[0]
    if layer0.connectivity == "full":
        return layer0.column.p
    return layer0.columns * layer0.column.p


def out_width(cfg: NetworkConfig) -> int:
    """Width of the final layer's concatenated post-WTA volley."""
    last = cfg.layers[-1]
    return last.columns * last.column.q


def init_params(rng: jax.Array, cfg: NetworkConfig, in_width: int) -> list:
    """Per-layer params: list of {'w': [columns, p, q]} stacked over columns."""
    validate(cfg, in_width)
    params = []
    for layer in cfg.layers:
        rng, sub = jax.random.split(rng)
        keys = jax.random.split(sub, layer.columns)
        w = jax.vmap(lambda k: column_lib.init_params(k, layer.column)["w"])(keys)
        params.append({"w": w})
    return params


def _split_columns(x: jnp.ndarray, layer: LayerConfig) -> jnp.ndarray:
    """Distribute a volley over a layer's columns: [..., in_w] -> [..., c, p]."""
    c = layer.columns
    if layer.connectivity == "full":
        return jnp.broadcast_to(x[..., None, :], x.shape[:-1] + (c, x.shape[-1]))
    return x.reshape(x.shape[:-1] + (c, layer.column.p))


@functools.partial(jax.jit, static_argnames=("layer", "mode"))
def _apply_layer(
    lp: dict, x: jnp.ndarray, layer: LayerConfig, mode: str
) -> jnp.ndarray:
    """x: [..., in_width] -> [..., columns * q] post-WTA spike times.

    Jitted per (layer, mode): the greedy handoff between layers is one
    compiled call, not a Python loop over columns.
    """
    xc = _split_columns(x, layer)

    def one(w, xi):  # w: [p, q]; xi: [..., p]
        y, _ = column_lib.apply({"w": w}, xi, layer.column, mode)
        return y

    y = jax.vmap(one, in_axes=(0, -2), out_axes=-2)(lp["w"], xc)
    return y.reshape(y.shape[:-2] + (layer.columns * layer.column.q,))


def apply(
    params: list, x_times: jnp.ndarray, cfg: NetworkConfig, mode: str = "auto"
) -> jnp.ndarray:
    """Forward a volley through all layers; returns final spike volley.

    ``mode`` resolves per layer through ``backend.resolve`` (inside
    ``column.apply``), so the hybrid 'auto' forward — event where exact,
    cycle for LIF — applies layer by layer.
    """
    validate(cfg, x_times.shape[-1])
    h = x_times
    for lp, layer in zip(params, cfg.layers):
        h = _apply_layer(lp, h, layer, mode)
    return h


def cluster_assignments(
    params: list, x_times: jnp.ndarray, cfg: NetworkConfig, mode: str = "auto"
) -> jnp.ndarray:
    """Winner index in the final concatenated volley = cluster id.

    Volleys where no output neuron spikes map to ``out_width(cfg)`` (the
    'unclustered' bucket), mirroring ``column.cluster_assignments``.
    """
    y = apply(params, x_times, cfg, mode)
    t_max = cfg.layers[-1].column.t_max
    any_spike = (y < t_max).any(axis=-1)
    idx = jnp.argmin(y, axis=-1)
    return jnp.where(any_spike, idx, out_width(cfg)).astype(TIME_DTYPE)


# ------------------------------------------------------------ layer training
def _fused_group_key(layer: LayerConfig):
    """Layers can share one compiled padded scan iff they vmap the same
    column count with the same static hyper-parameters; only then is a
    shared padding envelope worth paying for."""
    c = layer.column
    return (layer.columns, c.neuron.w_max, c.neuron.response, c.wta.k, c.stdp)


def _fused_envelopes(
    layers: list[LayerConfig],
    n_volleys: Optional[int] = None,
    epochs: int = 1,
) -> list[tuple[int, int, int]]:
    """Per-layer (p, q, t_window) padding envelope, in input order.

    Layers group by ``_fused_group_key``; within a group, members pack
    into shared envelopes via the central bucket policy
    (``backend.envelope_buckets``, greedy largest-first under the plan's
    waste cap — ``backend.ENVELOPE_WASTE_CAP`` unless a device
    calibration plus the stream-length hint derive a break-even cap) —
    size-compatible heterogeneous layers share one compiled step, badly
    mismatched ones get their own envelope.  The same policy buckets
    heterogeneous design sweeps in
    ``simulator.cluster_time_series_many``.
    """
    by_key: dict[tuple, list[int]] = {}
    for i, l in enumerate(layers):
        by_key.setdefault(_fused_group_key(l), []).append(i)
    envs: list = [None] * len(layers)
    for idxs in by_key.values():
        shapes = [
            (layers[i].column.p, layers[i].column.q, layers[i].column.t_max)
            for i in idxs
        ]
        for env, members in backend_lib.envelope_buckets(
            shapes, n_volleys=n_volleys, epochs=epochs
        ):
            for m in members:
                envs[idxs[m]] = env
    return envs


def _fit_layer_fused(
    w: jnp.ndarray,
    hc: jnp.ndarray,
    cfg: ColumnConfig,
    envelope: tuple[int, int, int],
    epochs: int,
    plan_sink: Optional[list] = None,
) -> jnp.ndarray:
    """Train one layer's columns on the fused path.  [c,p,q],[N,c,p] -> [c,p,q].

    Pads weights and volleys into the layer group's shared envelope and
    drives ``backend.fit_padded`` — the envelope-keyed AOT executable
    cache over ``fused_column.fit_scan_padded`` — with the layer's columns
    as the design axis: shape-compatible layers (and equal-envelope design
    sweeps in the same process) share ONE compiled executable, and a
    persistent cache (``backend.compile_cache``) extends that across
    processes.  The
    lowering comes from ``backend.padded_lowering``: the Mosaic kernel on
    TPU (the layer's threshold / window / live-q / mus ride along as
    runtime operands), the jnp reference body elsewhere — and fusability is
    checked against that lowering.
    """
    lowering = backend_lib.padded_lowering(cfg.neuron.response)
    fused_column.check_fusable(cfg, lowering)
    c = w.shape[0]
    p_env, q_env, t_window = envelope
    w_pad = (
        jnp.zeros((c, p_env, q_env), jnp.float32)
        .at[:, : cfg.p, : cfg.q]
        .set(w.astype(jnp.float32))
    )
    # padding synapses are silent: any time >= the traced t_max never fires
    xs = jnp.full(hc.shape[:-1] + (p_env,), t_window, TIME_DTYPE)
    xs = xs.at[..., : cfg.p].set(hc.astype(TIME_DTYPE))
    thresholds = jnp.full((c,), cfg.neuron.threshold, jnp.float32)
    t_maxes = jnp.full((c,), cfg.t_max, TIME_DTYPE)
    q_actives = jnp.full((c,), cfg.q, TIME_DTYPE)
    # one ExecutionPlan per (layer, envelope): blocking comes from the
    # roofline cost model when a calibration is active, the hand-tuned
    # constants otherwise — fit_padded would resolve the same plan from the
    # same inputs, so pinning v_blk/t_blk here changes nothing but lets the
    # choice be recorded alongside the trained weights.
    plan = backend_lib.execution_plan(
        "fit", lowering, c, p_env, q_env, t_window, hc.shape[0], epochs,
        w_max=cfg.neuron.w_max, response=cfg.neuron.response,
    )
    w_new = backend_lib.fit_padded(
        w_pad, xs, thresholds, t_maxes, q_actives,
        t_window=t_window, w_max=cfg.neuron.w_max, wta_k=cfg.wta.k,
        mu_capture=cfg.stdp.mu_capture, mu_backoff=cfg.stdp.mu_backoff,
        mu_search=cfg.stdp.mu_search,
        stabilize=cfg.stdp.stabilizer == "half",
        response=cfg.neuron.response, epochs=epochs, lowering=lowering,
        v_blk=plan.v_blk, t_blk=plan.t_blk,
    )
    if plan_sink is not None:
        plan_sink.append(plan.meta())
    return w_new[:, : cfg.p, : cfg.q]


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "solver_mode", "epochs"),
    donate_argnums=(0,),
)
def _layer_solver_fit_scan(
    w: jnp.ndarray,
    xs: jnp.ndarray,
    rng: jax.Array,
    cfg: ColumnConfig,
    solver_mode: str,
    epochs: int,
) -> jnp.ndarray:
    """One layer's epochs x volleys on the event/cycle solvers, one program.

    ``w``: [c, p, q] (donated), ``xs``: [N, c, p].  The scan body is the
    shared ``backend.solver_volley_step`` vmapped over the columns axis, so
    the full config surface (LIF, stochastic STDP, random tie-break) trains
    with a single compilation per (layer config, shape) — ``cfg`` is a
    static argument here, so unlike the fused path a threshold change does
    retrace.
    """
    n = xs.shape[0]
    c = w.shape[0]

    def volley(carry, inp):
        wc, key = carry
        xt, i = inp  # xt: [c, p]
        kv = jax.random.fold_in(key, i)
        keys = jax.random.split(kv, c)
        w2, _ = jax.vmap(
            lambda wi, xi, ki: backend_lib.solver_volley_step(
                wi, xi, ki, cfg, solver_mode
            )
        )(wc, xt, keys)
        return (w2, key), None

    def epoch(carry, e):
        wc, key = carry
        ke = jax.random.fold_in(key, e)
        (w2, _), _ = jax.lax.scan(volley, (wc, ke), (xs, jnp.arange(n)))
        return (w2, key), None

    (w, _), _ = jax.lax.scan(epoch, (w, rng), jnp.arange(epochs))
    return w


def fit_greedy(
    params: list,
    x_times: jnp.ndarray,
    cfg: NetworkConfig,
    epochs: int = 8,
    mode: str = "auto",
    rng: Optional[jax.Array] = None,
    plan_sink: Optional[list] = None,
) -> list:
    """Greedy layer-wise unsupervised STDP training.

    Each layer is trained to convergence on the (frozen) output of the stack
    below it, then frozen in turn — the online-learning recipe the hardware
    implements with per-column local learning only.

    Per layer, the entire epochs x volleys loop is ONE jitted, donated
    ``lax.scan`` on the backend ``mode`` resolves to for that layer's column
    config, and the handoff forward of the frozen layer is one jitted call.
    Layers sharing a shape compile once; refitting recompiles nothing.

    Args:
      mode: 'auto' | 'event' | 'cycle' | 'pallas', resolved *per layer*
        through ``backend.resolve`` — 'auto' routes each layer to the fused
        padded scan whenever its config fits the fused contract (RNL,
        expected STDP, index tie-break) and to the event/cycle solvers
        otherwise; explicit names force that backend for every layer and
        raise on layers outside its contract.  Under 'pallas' the padded
        scan lowers via ``backend.padded_lowering`` (Mosaic kernel on TPU,
        reference body elsewhere).
      rng: PRNG key.  Required whenever any layer's config is stochastic —
        ``wta.tie_break == 'random'`` or ``stdp.mode == 'stochastic'`` —
        and never silently defaulted for those (a loud ValueError instead);
        deterministic configs may omit it.  Fused layers are deterministic
        by contract and consume no randomness.
      plan_sink: optional list; each fused layer appends its
        ``ExecutionPlan.meta()`` dict (in layer order) so callers can
        record which blocking policy trained the weights without changing
        the returned params contract.  Solver layers append nothing.
    """
    if rng is None:
        # mirror the single-column guards: never silently substitute a
        # fixed key where training is meant to be randomized
        for li, layer in enumerate(cfg.layers):
            if layer.column.wta.tie_break == "random":
                raise ValueError(
                    f"layer {li}: tie_break='random' requires a PRNG key"
                )
            if layer.column.stdp.mode == "stochastic":
                raise ValueError(
                    f"layer {li}: stochastic STDP requires a PRNG key"
                )
        rng = jax.random.key(0)
    validate(cfg, x_times.shape[-1])
    h = x_times.reshape((-1, x_times.shape[-1]))

    names = [
        backend_lib.resolve(mode, layer.column, training=True)
        for layer in cfg.layers
    ]
    fused_idx = [i for i, nm in enumerate(names) if nm == "pallas"]
    env_by_layer = dict(zip(
        fused_idx,
        _fused_envelopes(
            [cfg.layers[i] for i in fused_idx],
            n_volleys=h.shape[0], epochs=epochs,
        ),
    ))

    new_params = []
    for li, (lp, layer, name) in enumerate(zip(params, cfg.layers, names)):
        rng, sub = jax.random.split(rng)
        hc = _split_columns(h, layer)  # [N, c, p]
        if name == "pallas":
            w = _fit_layer_fused(
                lp["w"], hc, layer.column, env_by_layer[li], epochs,
                plan_sink=plan_sink,
            )
        else:
            # copy: the scan donates its weight buffer; the caller keeps params
            w0 = jnp.array(lp["w"], jnp.float32, copy=True)
            w = _layer_solver_fit_scan(w0, hc, sub, layer.column, name, epochs)
        new_params.append({"w": w})
        if li < len(cfg.layers) - 1:  # the last handoff has no consumer
            h = _apply_layer({"w": w}, h, layer, mode)
    return new_params
