"""Core datatypes for temporal (spike-time) computation.

Spike times are integer clock cycles in ``[0, t_max)``; the sentinel
``NO_SPIKE`` (== t_inf, a value >= t_max) encodes "never spiked", matching the
unary-temporal hardware encoding in Nair et al. (ISVLSI'21) where absence of a
spike is an all-zeros unary wavefront.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

# Integer dtype used for spike times throughout. int32 keeps MXU/VPU lanes
# dense; hardware uses log2(t_max)-bit counters.
TIME_DTYPE = jnp.int32
WEIGHT_DTYPE = jnp.float32


def no_spike(t_max: int) -> int:
    """Sentinel spike time representing 'no spike' (one past the window)."""
    return int(t_max)


@dataclasses.dataclass(frozen=True)
class NeuronConfig:
    """Response-function configuration for one neuron population.

    Attributes:
      response: 'rnl' (ramp-no-leak), 'snl' (step-no-leak) or 'lif'.
      threshold: body-potential firing threshold (integer-valued in hardware).
      w_max: maximum synaptic weight (3-bit weights -> 7, as in TNN7 macros).
      leak: LIF leak per cycle (ignored for rnl/snl).
      refractory: cycles after firing during which the neuron is silent.
    """

    response: str = "rnl"
    threshold: float = 32.0
    w_max: int = 7
    leak: float = 0.0
    refractory: int = 0

    def __post_init__(self):
        if self.response not in ("rnl", "snl", "lif"):
            raise ValueError(f"unknown response function: {self.response!r}")
        if self.w_max < 1:
            raise ValueError("w_max must be >= 1")


@dataclasses.dataclass(frozen=True)
class WTAConfig:
    """Winner-take-all lateral inhibition.

    Attributes:
      k: number of winners that keep their spikes (1 = classic 1-WTA).
      tie_break: 'index' (lowest neuron index wins, hardware priority
        encoder), 'random' (PRNG tie-break), or 'all' (ties all win).
    """

    k: int = 1
    tie_break: str = "index"

    def __post_init__(self):
        if self.tie_break not in ("index", "random", "all"):
            raise ValueError(f"unknown tie_break: {self.tie_break!r}")


@dataclasses.dataclass(frozen=True)
class STDPConfig:
    """Probabilistic TNN STDP (Smith 2020; Chaudhari et al. 2021).

    Update cases for an input spike at x and (post-WTA) output spike at y:
      capture : x and y spike, x <= y  -> w += mu_capture * B(w)
      backoff : x and y spike, x >  y  -> w -= mu_backoff * B(w)
      search  : x spikes, y does not   -> w += mu_search
      backoff2: y spikes, x does not   -> w -= mu_backoff * B(w)
    B(w) is the stabilizing function; 'half' uses the standard
    B(w) = ceil-expectation form that slows updates near the rails.
    """

    mu_capture: float = 1.0 / 2
    mu_backoff: float = 1.0 / 2
    mu_search: float = 1.0 / 1024
    stabilizer: str = "half"  # 'half' or 'none'
    mode: str = "expected"  # 'expected' (deterministic) or 'stochastic'

    def __post_init__(self):
        if self.stabilizer not in ("half", "none"):
            raise ValueError(f"unknown stabilizer: {self.stabilizer!r}")
        if self.mode not in ("expected", "stochastic"):
            raise ValueError(f"unknown mode: {self.mode!r}")


@dataclasses.dataclass(frozen=True)
class ColumnConfig:
    """A single-column TNN: p synapses (rows) x q neurons (columns).

    This is the paper's NSPU building block; Table II uses (p x q) in
    {65x2, 96x2, 152x2, 343x2, 637x2, 470x5, 270x25}.
    """

    p: int
    q: int
    t_max: int = 256  # temporal window in clock cycles (8-bit time)
    neuron: NeuronConfig = dataclasses.field(default_factory=NeuronConfig)
    wta: WTAConfig = dataclasses.field(default_factory=WTAConfig)
    stdp: STDPConfig = dataclasses.field(default_factory=STDPConfig)

    @property
    def synapse_count(self) -> int:
        return self.p * self.q

    def with_threshold(self, threshold: float) -> "ColumnConfig":
        return dataclasses.replace(
            self, neuron=dataclasses.replace(self.neuron, threshold=threshold)
        )


def column_config_from_dict(d: dict) -> ColumnConfig:
    """Inverse of ``dataclasses.asdict(ColumnConfig(...))`` — the config
    serialization used by the DSE journal and the serving durability
    metadata, whose recovery paths must reconstruct the exact config
    (every field is an int/float/str, so the JSON round trip is exact)."""
    return ColumnConfig(
        p=int(d["p"]),
        q=int(d["q"]),
        t_max=int(d["t_max"]),
        neuron=NeuronConfig(**d["neuron"]),
        wta=WTAConfig(**d["wta"]),
        stdp=STDPConfig(**d["stdp"]),
    )


@dataclasses.dataclass(frozen=True)
class LayerConfig:
    """One layer of a multi-layer TNN: a grid of columns.

    Attributes:
      columns: number of parallel columns in the layer.
      column: per-column config (shared).
      connectivity: 'full' (every column sees all inputs) or 'tiled'
        (column c sees the c-th contiguous slice of the input).
    """

    columns: int
    column: ColumnConfig
    connectivity: str = "full"

    def __post_init__(self):
        if self.connectivity not in ("full", "tiled"):
            raise ValueError(f"unknown connectivity: {self.connectivity!r}")


@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    """Multi-layer TNN (paper §II-A: arbitrary layers/columns)."""

    layers: tuple  # tuple[LayerConfig, ...]
    name: str = "tnn"
