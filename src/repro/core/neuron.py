"""Neuron response functions and firing-time solvers.

Semantics (matching the unary-temporal microarchitecture of Nair et al.
ISVLSI'21, which TNNGen's generated RTL implements):

* An input volley is one spike time per synapse, integer cycles in
  ``[0, t_max)``; ``t >= t_max`` means "no spike".
* RNL (ramp-no-leak): synapse i's response ramps up by 1/cycle starting the
  cycle after the input spike, saturating at the weight ``w_i``:
  ``r_i(t) = min(relu(t - t_i), w_i)``.
* SNL (step-no-leak): ``r_i(t) = w_i * (t >= t_i)``.
* LIF: impulse input ``w_i`` at ``t_i`` into a leaky accumulator
  ``V(t) = max(V(t-1) - leak, 0) + sum_i w_i * (t_i == t)``.
* Body potential ``V(t) = sum_i r_i(t)`` (RNL/SNL); the neuron emits a single
  output spike at the first cycle where ``V(t) >= threshold`` within the
  window, else no spike.

Two solvers are provided and cross-validated in tests:

* ``fire_times_event``: closed-form event-driven solve (the paper's fast
  path).  RNL's V(t) is piecewise linear with breakpoints at ``t_i`` and
  ``t_i + w_i``; we sort the 2p slope-change events, prefix-sum the slope and
  solve the first threshold crossing analytically.  Exact for RNL/SNL.
* ``fire_times_cycle``: lax.scan over hardware clock cycles, bit-identical to
  the generated RTL (the paper's cycle-accurate path; required for LIF).

These solvers are the 'event' / 'cycle' members of the backend registry
(``repro.core.backend``); the third member, 'pallas', is the fused column
step in ``repro.kernels.fused_column`` (same firing semantics, integer
weight grid, fire+WTA+STDP in one kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.types import NeuronConfig, TIME_DTYPE


def rnl_potential(t: jnp.ndarray, t_in: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Body potential V(t) for RNL neurons.

    Args:
      t: [...] integer cycle(s) at which to evaluate.
      t_in: [p] input spike times.
      w: [p, q] synaptic weights.

    Returns:
      [..., q] potentials.
    """
    t = jnp.asarray(t)[..., None, None]  # [..., 1, 1]
    ramp = jnp.minimum(
        jax.nn.relu(t - t_in[..., None].astype(w.dtype)), w
    )  # [..., p, q]
    return ramp.sum(axis=-2)


def snl_potential(t: jnp.ndarray, t_in: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Body potential V(t) for SNL neurons (step response)."""
    t = jnp.asarray(t)[..., None, None]
    step = (t >= t_in[..., None]).astype(w.dtype) * w
    return step.sum(axis=-2)


def _first_crossing_from_events(
    ev_t: jnp.ndarray, ev_ds: jnp.ndarray, threshold: float, t_max: int
) -> jnp.ndarray:
    """Solve first integer t with V(t) >= threshold from sorted slope events.

    V is the continuous piecewise-linear potential whose slope changes by
    ``ev_ds[k]`` at time ``ev_t[k]`` (RNL: +1 at ramp start, -1 at ramp
    saturation, so V is nondecreasing).  Because V is nondecreasing, the
    first *integer* crossing (what the hardware comparator latches) is
    ``ceil(t*)`` of the continuous first crossing ``t*``.

    Args:
      ev_t: [e] sorted event times (may be fractional for fractional weights).
      ev_ds: [e] slope delta at each event.
      threshold: firing threshold.
      t_max: window length (cycles scanned are 0..t_max-1).

    Returns:
      scalar int32 firing time, or t_max if no crossing in-window.
    """
    slope = jnp.cumsum(ev_ds)  # slope within segment k: [ev_t[k], ev_t[k+1])
    t_next = jnp.concatenate(
        [ev_t[1:].astype(jnp.float32), jnp.asarray([jnp.inf], jnp.float32)]
    )
    seg_len = jnp.where(
        jnp.isfinite(t_next), t_next - ev_t.astype(jnp.float32), 0.0
    )
    # V at each event time: integrate slope over preceding segments.
    v_at_ev = jnp.concatenate(
        [jnp.zeros((1,), slope.dtype), jnp.cumsum(slope * seg_len)[:-1]]
    )
    need = threshold - v_at_ev
    dt = jnp.where(slope > 0, need / jnp.maximum(slope, 1e-30), jnp.inf)
    dt = jnp.maximum(dt, 0.0)
    t_cross = ev_t.astype(jnp.float32) + dt
    valid = (t_cross <= t_next) & jnp.isfinite(t_cross)
    t_fire = jnp.min(jnp.where(valid, t_cross, jnp.inf))
    t_fire = jnp.where(threshold <= 0, 0.0, t_fire)
    t_disc = jnp.where(jnp.isfinite(t_fire), jnp.ceil(t_fire), float(t_max))
    return jnp.minimum(t_disc, float(t_max)).astype(TIME_DTYPE)


def _rnl_fire_event_1n(
    t_in: jnp.ndarray, w: jnp.ndarray, threshold: float, t_max: int
) -> jnp.ndarray:
    """Event-driven RNL firing time for ONE neuron. t_in:[p] w:[p] -> scalar."""
    no = t_in >= t_max  # non-spiking synapses contribute nothing
    start = jnp.where(no, t_max, t_in).astype(jnp.float32)
    # ramp increments occur at cycles (t_i, t_i + w_i]; slope +1 from t_i
    # (potential first exceeds at t_i + 1 when evaluated at integer cycles;
    # using continuous-time linear segments with integer ceil solve matches
    # the discrete min(relu(t - t_i), w) exactly).
    end = jnp.where(no | (w <= 0), t_max, t_in.astype(jnp.float32) + w)
    ev_t = jnp.concatenate([start, end])
    ev_ds = jnp.concatenate([jnp.where(no | (w <= 0), 0.0, 1.0),
                             jnp.where(no | (w <= 0), 0.0, -1.0)])
    order = jnp.argsort(ev_t)
    return _first_crossing_from_events(ev_t[order], ev_ds[order], threshold, t_max)


def _snl_fire_event_1n(
    t_in: jnp.ndarray, w: jnp.ndarray, threshold: float, t_max: int
) -> jnp.ndarray:
    """Event-driven SNL firing time for ONE neuron (sorted cumsum of steps)."""
    no = t_in >= t_max
    times = jnp.where(no, t_max, t_in)
    order = jnp.argsort(times)
    tt = times[order].astype(TIME_DTYPE)
    ww = jnp.where(no, 0.0, w)[order]
    v = jnp.cumsum(ww)
    hit = v >= threshold
    idx = jnp.argmax(hit)  # first True
    t_fire = jnp.where(jnp.any(hit), tt[idx], t_max)
    t_fire = jnp.where(threshold <= 0, 0, t_fire)
    return jnp.where(t_fire < t_max, t_fire, t_max).astype(TIME_DTYPE)


def fire_times_event(
    t_in: jnp.ndarray, w: jnp.ndarray, cfg: NeuronConfig, t_max: int
) -> jnp.ndarray:
    """Closed-form firing times. t_in: [..., p]; w: [p, q] -> [..., q].

    Exact for 'rnl' and 'snl'.  For 'lif' there is no closed form under leak;
    callers must use ``fire_times_cycle`` (enforced here).
    """
    if cfg.response == "lif":
        raise ValueError("event mode is undefined for LIF; use cycle mode")
    solver = _rnl_fire_event_1n if cfg.response == "rnl" else _snl_fire_event_1n
    per_neuron = jax.vmap(solver, in_axes=(None, 1, None, None))  # over q

    def solve(ti):
        return per_neuron(ti, w, cfg.threshold, t_max)

    batch_shape = t_in.shape[:-1]
    flat = t_in.reshape((-1, t_in.shape[-1]))
    out = jax.vmap(solve)(flat)
    return out.reshape(batch_shape + (w.shape[1],))


def fire_times_cycle(
    t_in: jnp.ndarray, w: jnp.ndarray, cfg: NeuronConfig, t_max: int
) -> jnp.ndarray:
    """Cycle-accurate firing times via lax.scan over hardware clock cycles.

    Mirrors the generated RTL: per-cycle response increments accumulate into
    the body potential; a comparator latches the first crossing.
    Supports rnl / snl / lif.  t_in: [..., p]; w: [p, q] -> [..., q].
    """
    batch_shape = t_in.shape[:-1]
    p, q = w.shape
    ti = t_in.reshape((-1, p))  # [B, p]
    B = ti.shape[0]
    no = (ti >= t_max)[..., None]  # [B, p, 1]
    wf = w[None].astype(jnp.float32)  # [1, p, q]

    def step(carry, t):
        v, fired_at = carry
        if cfg.response == "rnl":
            # increment = min(relu(t - t_i), w) - min(relu(t-1 - t_i), w)
            a = jnp.clip(t - ti[..., None].astype(jnp.float32), 0.0, None)
            b = jnp.clip(t - 1 - ti[..., None].astype(jnp.float32), 0.0, None)
            inc = jnp.minimum(a, wf) - jnp.minimum(b, wf)
            inc = jnp.where(no, 0.0, inc).sum(axis=1)  # [B, q]
            v = v + inc
        elif cfg.response == "snl":
            inc = jnp.where((ti[..., None] == t) & ~no, wf, 0.0).sum(axis=1)
            v = v + inc
        else:  # lif
            v = jnp.maximum(v - cfg.leak, 0.0)
            inc = jnp.where((ti[..., None] == t) & ~no, wf, 0.0).sum(axis=1)
            v = v + inc
        newly = (v >= cfg.threshold) & (fired_at >= t_max)
        fired_at = jnp.where(newly, t, fired_at)
        return (v, fired_at), None

    v0 = jnp.zeros((B, q), jnp.float32)
    f0 = jnp.full((B, q), t_max, TIME_DTYPE)
    (_, fired_at), _ = jax.lax.scan(
        step, (v0, f0), jnp.arange(t_max, dtype=TIME_DTYPE)
    )
    return fired_at.reshape(batch_shape + (q,))


@functools.partial(jax.jit, static_argnames=("cfg", "t_max", "mode"))
def fire_times(
    t_in: jnp.ndarray,
    w: jnp.ndarray,
    cfg: NeuronConfig,
    t_max: int,
    mode: str = "auto",
) -> jnp.ndarray:
    """Dispatch: 'auto' picks the paper's hybrid strategy (event when exact,
    cycle when required by the response function)."""
    if mode == "auto":
        mode = "cycle" if cfg.response == "lif" else "event"
    if mode == "event":
        return fire_times_event(t_in, w, cfg, t_max)
    if mode == "cycle":
        return fire_times_cycle(t_in, w, cfg, t_max)
    raise ValueError(f"unknown mode: {mode!r}")
