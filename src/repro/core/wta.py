"""Winner-take-all lateral inhibition over a column's output spikes.

Hardware: a priority encoder over the earliest output spike wavefronts
(1-WTA), generalized to k-WTA.  Losers' spikes are inhibited (set to
no-spike); non-spiking neurons can never win.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import TIME_DTYPE, WTAConfig


def wta(
    t_out: jnp.ndarray,
    cfg: WTAConfig,
    t_max: int,
    rng: jax.Array | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Apply k-WTA inhibition.

    Args:
      t_out: [..., q] output spike times (t_max == no spike).
      cfg: WTA configuration.
      t_max: window length.
      rng: PRNG key, required iff cfg.tie_break == 'random'.

    Returns:
      (inhibited [..., q] spike times, winner mask [..., q] bool).
    """
    q = t_out.shape[-1]
    t = t_out.astype(jnp.int64) if q * (t_max + 1) > 2**31 else t_out.astype(TIME_DTYPE)

    if cfg.tie_break == "index":
        rank = jnp.arange(q, dtype=t.dtype)
        rank = jnp.broadcast_to(rank, t_out.shape)
    elif cfg.tie_break == "random":
        if rng is None:
            raise ValueError("tie_break='random' requires a PRNG key")
        # independent random ranks per volley
        u = jax.random.uniform(rng, t_out.shape)
        rank = jnp.argsort(jnp.argsort(u, axis=-1), axis=-1).astype(t.dtype)
    else:  # 'all' — ties share the win; rank contributes nothing
        rank = jnp.zeros(t_out.shape, t.dtype)

    # lexicographic (time, rank) packed into one integer key; for 'all' the
    # rank is constant so tied times share the k-th key and all win.
    key = t * q + jnp.minimum(rank, q - 1)
    kth = jnp.sort(key, axis=-1)[..., cfg.k - 1 : cfg.k]  # [..., 1]
    win = (key <= kth) & (t_out < t_max)  # non-spiking neurons never win
    inhibited = jnp.where(win, t_out, t_max).astype(TIME_DTYPE)
    return inhibited, win
