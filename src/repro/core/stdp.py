"""Spike-timing-dependent plasticity for TNN columns.

Implements the classic TNN STDP rule (Smith 2020, arXiv:2011.13844; used by
Chaudhari et al. ICASSP'21 for time-series clustering).  For synapse (i, j)
with input spike time x_i and post-WTA output spike time y_j (t_max == none):

  case                         update
  x and y spike, x <= y        w += mu_capture * s_plus(w)    (capture)
  x and y spike, x >  y        w -= mu_backoff * s_minus(w)   (backoff)
  x spikes, y silent           w += mu_search                 (search)
  x silent, y spikes           w -= mu_backoff * s_minus(w)   (backoff)
  neither spikes               no change

With the 'half' (bimodal) stabilizer, s_plus(w) = 1 - w/w_max + eps and
s_minus(w) = w/w_max + eps, which drives converged weights toward the rails
{0, w_max} — the behaviour the TNN7 unary weight counters implement with
LFSR-gated increments.  'none' sets both to 1.

Two execution modes:
  'expected'   — deterministic, applies the expected update (float weights).
  'stochastic' — Bernoulli(mu * s) unit-magnitude updates via threefry PRNG,
                 matching the integer LSB increments of the hardware.

Supervised mode simply substitutes the label-derived target spike volley for
y (the caller picks y; the rule itself is unchanged), as in the paper's
"supervised and unsupervised modes".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import STDPConfig


def _stabilizers(w: jnp.ndarray, w_max: int, cfg: STDPConfig):
    if cfg.stabilizer == "none":
        one = jnp.ones_like(w)
        return one, one
    frac = jnp.clip(w / w_max, 0.0, 1.0)
    eps = 1.0 / (2 * w_max)
    return (1.0 - frac) + eps, frac + eps


def stdp_delta(
    w: jnp.ndarray,
    x_times: jnp.ndarray,
    y_times: jnp.ndarray,
    cfg: STDPConfig,
    w_max: int,
    t_max: int,
) -> jnp.ndarray:
    """Expected STDP update for one volley.

    Args:
      w: [p, q] weights.
      x_times: [p] input spike times.
      y_times: [q] post-WTA output spike times.
      cfg: STDP config.
      w_max: weight ceiling.
      t_max: window length (>= t_max means no spike).

    Returns:
      [p, q] weight delta (expected value).
    """
    x = x_times[:, None]  # [p, 1]
    y = y_times[None, :]  # [1, q]
    xs = x < t_max
    ys = y < t_max
    s_plus, s_minus = _stabilizers(w, w_max, cfg)

    capture = xs & ys & (x <= y)
    backoff = (xs & ys & (x > y)) | (~xs & ys)
    search = xs & ~ys

    delta = jnp.zeros_like(w)
    delta = jnp.where(capture, cfg.mu_capture * s_plus, delta)
    delta = jnp.where(backoff, -cfg.mu_backoff * s_minus, delta)
    delta = jnp.where(search, cfg.mu_search * jnp.ones_like(w), delta)
    return delta


def stdp_update(
    w: jnp.ndarray,
    x_times: jnp.ndarray,
    y_times: jnp.ndarray,
    cfg: STDPConfig,
    w_max: int,
    t_max: int,
    rng: jax.Array | None = None,
) -> jnp.ndarray:
    """Apply one STDP step and clamp to [0, w_max].

    In 'stochastic' mode the magnitudes of ``stdp_delta`` are treated as
    per-synapse Bernoulli probabilities of a +/-1 LSB update (hardware
    semantics); 'expected' applies the float expectation directly.
    """
    delta = stdp_delta(w, x_times, y_times, cfg, w_max, t_max)
    if cfg.mode == "stochastic":
        if rng is None:
            raise ValueError("stochastic STDP requires a PRNG key")
        prob = jnp.clip(jnp.abs(delta), 0.0, 1.0)
        fire = jax.random.bernoulli(rng, prob)
        delta = jnp.sign(delta) * fire.astype(w.dtype)
    return jnp.clip(w + delta, 0.0, float(w_max))


def stdp_update_batch(
    w: jnp.ndarray,
    x_times: jnp.ndarray,
    y_times: jnp.ndarray,
    cfg: STDPConfig,
    w_max: int,
    t_max: int,
    rng: jax.Array | None = None,
) -> jnp.ndarray:
    """Sequentially fold a batch of volleys into the weights (online rule).

    x_times: [B, p]; y_times: [B, q].  Hardware processes volleys one gamma
    window at a time; lax.scan preserves that online semantics exactly.
    """
    B = x_times.shape[0]
    if cfg.mode == "stochastic":
        if rng is None:
            raise ValueError("stochastic STDP requires a PRNG key")
        keys = jax.random.split(rng, B)
    else:
        keys = jnp.zeros((B, 2), jnp.uint32)

    def step(wc, inp):
        xt, yt, key = inp
        k = key if cfg.mode == "stochastic" else None
        return stdp_update(wc, xt, yt, cfg, w_max, t_max, rng=k), None

    w_new, _ = jax.lax.scan(step, w, (x_times, y_times, keys))
    return w_new
