"""The paper's own seven TNN column designs (Table II) as configs."""
from __future__ import annotations

from repro.core.types import ColumnConfig, NeuronConfig
from repro.data.ucr import PAPER_COLUMNS
from repro.hwgen.rtl import ColumnSpec

T_MAX = 64  # gamma window used by the simulator configs


def column_config(benchmark: str, t_max: int = T_MAX) -> ColumnConfig:
    p, q = PAPER_COLUMNS[benchmark]
    # threshold at the simulator's default operating point (see
    # core/simulator.suggest_threshold): p * w_max / 8
    thr = max(1.0, 0.25 * p * 7 / 2.0)
    return ColumnConfig(p=p, q=q, t_max=t_max, neuron=NeuronConfig(threshold=thr))


def hardware_spec(benchmark: str, t_max: int = T_MAX) -> ColumnSpec:
    p, q = PAPER_COLUMNS[benchmark]
    safe = benchmark.replace("-", "_").lower()
    return ColumnSpec(name=safe, p=p, q=q, theta=int(max(1, p * 7 // 8)), t_max=t_max)


def all_benchmarks() -> list:
    return list(PAPER_COLUMNS)
