"""Qwen2-VL-7B backbone (arXiv:2409.12191, hf-verified): M-RoPE decoder.

28L, d_model 3584, 28 heads (kv=4), d_ff 18944, vocab 152064.  The vision
frontend (dynamic-resolution patch embed) is a STUB per the brief:
``input_specs`` provides token ids plus the 3-stream M-RoPE position ids.
"""
from repro.models.config import ArchConfig

ARCH_ID = "qwen2-vl-7b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_head=128,
        d_ff=18944, vocab_size=152064, mrope=True, rope_theta=1e6,
        remat="full",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, mrope=True, dtype="float32", kv_chunk=16,
    )
