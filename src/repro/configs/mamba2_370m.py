"""Mamba2-370m (arXiv:2405.21060): pure SSD, attention-free.

48L, d_model 1024, ssm_state 128, vocab 50280.
"""
from repro.models.config import ArchConfig

ARCH_ID = "mamba2-370m"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="ssm",
        n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_head=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
        remat="full",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_head=0,
        d_ff=0, vocab_size=256, ssm_state=16, ssm_head_dim=16,
        ssm_chunk=16, dtype="float32",
    )
