"""OLMoE-1B-7B (arXiv:2409.02060, hf-verified).

16L, d_model 2048, 16 heads (kv=16 -> MHA), 64 experts top-8, expert
d_ff 1024, vocab 50304.
"""
from repro.models.config import ArchConfig

ARCH_ID = "olmoe-1b-7b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1024, vocab_size=50304, n_experts=64, top_k=8,
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=32, vocab_size=256, n_experts=8, top_k=2,
        dtype="float32", kv_chunk=16, moe_capacity_factor=4.0,
    )
