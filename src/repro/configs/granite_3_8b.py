"""Granite-3 8B (hf:ibm-granite/granite-3.0 family, hf-verified): dense GQA.

40L, d_model 4096, 32 heads (kv=8), d_ff 12800, vocab 49155.
"""
from repro.models.config import ArchConfig

ARCH_ID = "granite-3-8b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
        d_ff=12800, vocab_size=49155, remat="full",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, dtype="float32", kv_chunk=16,
    )
