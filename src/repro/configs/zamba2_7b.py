"""Zamba2-7B (arXiv:2411.15242): Mamba2 backbone + shared attention blocks.

81 mamba2 blocks, d_model 3584, ssm_state 64; ONE shared attention+MLP
block (32 heads, kv=32, d_ff 14336) applied every 6 blocks with
per-application LoRA on W_q (rank 128), vocab 32000.
"""
from repro.models.config import ArchConfig

ARCH_ID = "zamba2-7b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_head=112,
        d_ff=14336, vocab_size=32000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
        attn_every=6, shared_attn_lora_rank=128, remat="full",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=256, ssm_state=16, ssm_head_dim=16,
        ssm_chunk=16, attn_every=2, shared_attn_lora_rank=4,
        dtype="float32", kv_chunk=16,
    )
