"""Qwen3-14B (hf:Qwen/Qwen3-8B family, hf-verified): dense, qk_norm, GQA.

40L, d_model 5120, 40 heads (kv=8), d_ff 17408, vocab 151936.
"""
from repro.models.config import ArchConfig

ARCH_ID = "qwen3-14b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
        d_ff=17408, vocab_size=151936, qk_norm=True, rope_theta=1e6,
        remat="full",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, qk_norm=True, dtype="float32", kv_chunk=16,
    )
