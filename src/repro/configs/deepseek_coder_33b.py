"""DeepSeek-Coder-33B (arXiv:2401.14196, hf-verified): llama-arch dense GQA.

62L, d_model 7168, 56 heads (kv=8), d_ff 19200, vocab 32256.
"""
from repro.models.config import ArchConfig

ARCH_ID = "deepseek-coder-33b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
        d_ff=19200, vocab_size=32256, rope_theta=1e5, remat="full",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, dtype="float32", kv_chunk=16,
    )
