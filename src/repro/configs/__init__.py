"""Config registry: ``--arch <id>`` resolution for the 10 assigned
architectures (plus the paper's own TNN column designs in tnn_columns).

Each arch module exposes ``full()`` (exact published config) and ``smoke()``
(reduced CPU-testable config).  ``input_specs`` builds the ShapeDtypeStruct
stand-ins each (arch x shape) dry-run cell lowers against.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import (
    deepseek_coder_33b,
    granite_3_8b,
    kimi_k2_1t_a32b,
    mamba2_370m,
    olmoe_1b_7b,
    qwen2_vl_7b,
    qwen3_14b,
    starcoder2_15b,
    whisper_medium,
    zamba2_7b,
)
from repro.models.config import ArchConfig

_MODULES = (
    kimi_k2_1t_a32b,
    olmoe_1b_7b,
    qwen3_14b,
    granite_3_8b,
    starcoder2_15b,
    deepseek_coder_33b,
    whisper_medium,
    qwen2_vl_7b,
    zamba2_7b,
    mamba2_370m,
)

REGISTRY = {m.ARCH_ID: m for m in _MODULES}
ARCH_IDS = tuple(REGISTRY)


def get_arch(arch_id: str, smoke: bool = False) -> ArchConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = REGISTRY[arch_id]
    return mod.smoke() if smoke else mod.full()


# --------------------------------------------------------------------------
# shapes (assigned per-arch input-shape set)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# long_500k needs sub-quadratic attention: run only for SSM/hybrid (their
# attention state is O(1) / sharded-KV); skip for the 8 pure full-attention
# archs, per the brief (also recorded in DESIGN.md §5).
_LONG_OK_FAMILIES = ("ssm", "hybrid")


def applicable(cfg: ArchConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.family in _LONG_OK_FAMILIES
    return True


def skip_reason(cfg: ArchConfig, shape: str) -> Optional[str]:
    if applicable(cfg, shape):
        return None
    return (
        f"{cfg.name} is pure full-attention; long_500k (seq 524288) requires "
        "sub-quadratic attention (run for ssm/hybrid only)"
    )


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   {'tokens','labels'} [B, S]  (+ 'frames' for audio,
             + 'positions' [3, B, S] for M-RoPE VLM)
    prefill: {'tokens'} [B, S] (+ 'frames')
    decode:  {'tokens'} [B, 1] + the cache built by init_cache(B, S).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), i32)

    if shape.kind == "train":
        specs = {"tokens": tok(B, S), "labels": tok(B, S)}
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16
            )
        if cfg.mrope:
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": tok(B, S)}
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_seq, cfg.d_model), jnp.bfloat16
            )
        return specs
    if shape.kind == "decode":
        return {"tokens": tok(B, 1)}
    raise ValueError(shape.kind)
