"""Kimi K2 — trillion-parameter MoE (arXiv:2501.kimi2, paper-table).

61L, d_model 7168, 64 q heads (GQA kv=8, d_head 112), 384 experts top-8
with d_ff(expert)=2048, vocab 163840.  Factored-second-moment optimizer
(adafactor) — at 1T params AdamW's fp32 moments alone exceed the 512-chip
HBM budget; see DESIGN.md.
"""
from repro.models.config import ArchConfig

ARCH_ID = "kimi-k2-1t-a32b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_head=112,
        d_ff=2048, vocab_size=163840, n_experts=384, top_k=8,
        optimizer="adafactor", remat="full",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=32, vocab_size=256, n_experts=8, top_k=2,
        dtype="float32", kv_chunk=16, moe_capacity_factor=4.0,
    )
