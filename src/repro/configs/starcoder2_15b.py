"""StarCoder2-15B (arXiv:2402.19173, hf-verified): dense GQA + RoPE.

40L, d_model 6144, 48 heads (kv=4), d_ff 24576, vocab 49152.
"""
from repro.models.config import ArchConfig

ARCH_ID = "starcoder2-15b"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="dense",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_head=128,
        d_ff=24576, vocab_size=49152, rope_theta=1e5, remat="full",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, dtype="float32", kv_chunk=16,
    )
