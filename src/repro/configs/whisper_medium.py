"""Whisper-medium backbone (arXiv:2212.04356): encoder-decoder transformer.

24 encoder + 24 decoder layers, d_model 1024, 16 heads (kv=16), d_ff 4096,
vocab 51865.  The conv frontend is a STUB per the brief: ``input_specs``
provides precomputed frame embeddings [B, 1500, 1024].
"""
from repro.models.config import ArchConfig

ARCH_ID = "whisper-medium"


def full() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID, family="audio",
        n_layers=24, enc_layers=24, encoder_decoder=True, enc_seq=1500,
        d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
        d_ff=4096, vocab_size=51865, remat="full",
    )


def smoke() -> ArchConfig:
    return ArchConfig(
        name=ARCH_ID + "-smoke", family="audio",
        n_layers=2, enc_layers=2, encoder_decoder=True, enc_seq=16,
        d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
        d_ff=128, vocab_size=256, dtype="float32", kv_chunk=16,
    )
