"""Straggler detection and mitigation hooks.

On a real pod, per-host step times diverge when a host degrades (thermals,
ECC retries, network incast).  The monitor keeps a robust running estimate
of the step-time distribution and flags outliers; the mitigation policy is
pluggable — the trainer consumes ``should_rebalance`` to shrink the slow
host's microbatch share (the data pipeline's ``shard_at`` is elastic in the
shard->slice mapping, so re-balancing is a pure metadata change).

``StepMonitor`` is consumer-agnostic: the design sweep wraps each bucket
evaluation in ``start()``/``stop()`` the same way (``dse.explore`` surfaces
the flagged stalls on ``DSEResult.meta['stalls']``).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float
    ratio: float
    label: str = ""  # pipeline stage ('assign', 'refit', ...) or ""


class StepMonitor:
    """EWMA/median hybrid step-time monitor with an outlier threshold.

    ``start(label=...)`` tags the step with a pipeline stage so a
    multi-stage consumer (the streaming service times its assignment
    batches and online re-fits through one monitor) can attribute a
    flagged stall; the label is observability metadata only — the
    outlier threshold compares against the pooled median.
    """

    def __init__(self, window: int = 50, threshold: float = 2.0, warmup: int = 5):
        self.window = window
        self.threshold = threshold
        self.warmup = warmup
        self.times: deque = deque(maxlen=window)
        self.events: list = []
        self._t0: Optional[float] = None
        self._label = ""
        self._step = 0

    def start(self, label: str = "") -> None:
        self._t0 = time.perf_counter()
        self._label = label

    def stop(self) -> Optional[StragglerEvent]:
        if self._t0 is None:
            return None
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self._step += 1
        return self.observe(self._step, dt, label=self._label)

    def observe(
        self, step: int, duration_s: float, label: str = ""
    ) -> Optional[StragglerEvent]:
        """Record a step duration; returns an event if it is a straggler."""
        ev = None
        if len(self.times) >= self.warmup:
            med = sorted(self.times)[len(self.times) // 2]
            if med > 0 and duration_s > self.threshold * med:
                ev = StragglerEvent(
                    step, duration_s, med, duration_s / med, label
                )
                self.events.append(ev)
        self.times.append(duration_s)
        return ev

    @property
    def median_s(self) -> float:
        if not self.times:
            return 0.0
        return sorted(self.times)[len(self.times) // 2]

    def should_rebalance(self, patience: int = 3) -> bool:
        """True when `patience` straggler events landed within one window —
        a persistent slow host rather than a one-off hiccup."""
        if len(self.events) < patience:
            return False
        recent = self.events[-patience:]
        return recent[-1].step - recent[0].step < self.window


class RebalancePolicy:
    """Maps straggler evidence to per-shard microbatch weights.

    ``weights[i]`` scales shard i's slice of the global batch; the trainer
    applies it through the data pipeline.  Here: shave `shave` fraction off
    the slowest shard and spread it uniformly (the classic backup-worker
    alternative that does not duplicate compute).
    """

    def __init__(self, num_shards: int, shave: float = 0.25):
        self.weights = [1.0] * num_shards
        self.shave = shave

    def apply(self, slow_shard: int) -> list:
        take = self.weights[slow_shard] * self.shave
        self.weights[slow_shard] -= take
        others = len(self.weights) - 1
        for i in range(len(self.weights)):
            if i != slow_shard:
                self.weights[i] += take / others
        return list(self.weights)
