"""Elastic scaling: resume a run on a different device count / mesh.

The pieces that make this work are deliberately spread across the stack:
  * checkpoints store host arrays + a manifest (checkpoint.py) — restore
    re-places leaves under the *current* mesh's shardings;
  * the data pipeline is addressed by (step, shard, num_shards)
    (data/tokens.py) — re-sharding is a pure metadata change;
  * sharding rules are derived from the mesh at build time (sharding.py).

``resume_elastic`` is the orchestration helper the launcher calls after a
topology change (scale-up, scale-down, or failed-host replacement).
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.data.tokens import DataConfig
from repro.distributed.train_loop import TrainConfig, Trainer
from repro.models.config import ArchConfig


def resume_elastic(
    arch: ArchConfig,
    data_cfg: DataConfig,
    train_cfg: TrainConfig,
    new_mesh: Optional[jax.sharding.Mesh],
) -> Trainer:
    """Build a Trainer on the new mesh; its run() restores the latest
    checkpoint with the new shardings and continues the step sequence.

    Requirements checked here rather than discovered mid-run:
      * global batch must divide the new data-parallel shard count,
      * MoE experts must divide the new model-axis size.
    """
    if new_mesh is not None:
        dp = 1
        for a in new_mesh.axis_names:
            if a != "model":
                dp *= new_mesh.shape[a]
        if data_cfg.global_batch % dp:
            raise ValueError(
                f"global_batch {data_cfg.global_batch} does not divide over "
                f"{dp} data shards on the new mesh"
            )
        if arch.n_experts and arch.n_experts % new_mesh.shape["model"]:
            raise ValueError(
                f"{arch.n_experts} experts do not divide over model axis "
                f"{new_mesh.shape['model']}"
            )
    return Trainer(arch, data_cfg, train_cfg, mesh=new_mesh)
