"""Optimizers and distributed-optimization transforms.

* ``AdamW`` — standard, fp32 moments, global-norm clip, cosine schedule.
* ``Adafactor`` — factored second moments (row/col statistics for matrices),
  bf16 first moment; the memory-viable choice for the 1T-param arch (see
  kimi config): optimizer state is ~0.5 byte/param instead of 8.
* ``ErrorFeedbackInt8`` — gradient-compression transform: int8 symmetric
  per-tensor quantization with an fp32 error-feedback residual carried in
  the optimizer state.  Applied to gradients before the update — the
  quantized values are what SPMD's gradient all-reduce moves on the wire on
  a pod; the residual guarantees the quantization error is re-injected the
  next step (Karimireddy et al., "EF-SGD").

All states are plain pytrees that shard exactly like their parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Schedule:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_ratio: float = 0.1

    def __call__(self, step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(self.warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (step - self.warmup_steps)
            / max(self.total_steps - self.warmup_steps, 1),
            0.0, 1.0,
        )
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return self.peak_lr * warm * (self.min_ratio + (1 - self.min_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    """Scale math in fp32; gradients keep their storage dtype (bf16 grads
    stay bf16 — no fp32 materialization of the full gradient tree)."""
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree
    ), n


class AdamW:
    def __init__(
        self,
        schedule: Schedule,
        b1: float = 0.9,
        b2: float = 0.95,
        eps: float = 1e-8,
        weight_decay: float = 0.1,
        clip_norm: float = 1.0,
        compressor: Optional["ErrorFeedbackInt8"] = None,
    ):
        self.schedule, self.b1, self.b2 = schedule, b1, b2
        self.eps, self.weight_decay, self.clip_norm = eps, weight_decay, clip_norm
        self.compressor = compressor

    def init(self, params) -> dict:
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        state = {
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32),
        }
        if self.compressor is not None:
            state["ef"] = self.compressor.init(params)
        return state

    def update(self, params, grads, state):
        step = state["step"] + 1
        if self.compressor is not None:
            grads, ef = self.compressor.apply(grads, state["ef"])
        else:
            ef = None
        grads, _ = clip_by_global_norm(grads, self.clip_norm)
        lr = self.schedule(step)
        b1, b2 = self.b1, self.b2
        # moment math in fp32 regardless of gradient storage dtype (the
        # upcast fuses per-leaf; no full-tree fp32 materialization)
        m = jax.tree.map(
            lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda vv, g: b2 * vv
            + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )
        t = step.astype(jnp.float32)
        bc1, bc2 = 1 - b1**t, 1 - b2**t

        def upd(p, mm, vv):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        new_state = {"m": m, "v": v, "step": step}
        if ef is not None:
            new_state["ef"] = ef
        return new_params, new_state


class Adafactor:
    """Factored 2nd-moment optimizer (Shazeer & Stern 2018), bf16 momentum."""

    def __init__(
        self,
        schedule: Schedule,
        b1: float = 0.9,
        decay: float = 0.8,
        eps: float = 1e-30,
        clip_norm: float = 1.0,
        weight_decay: float = 0.0,
        compressor: Optional["ErrorFeedbackInt8"] = None,
    ):
        self.schedule, self.b1, self.decay = schedule, b1, decay
        self.eps, self.clip_norm, self.weight_decay = eps, clip_norm, weight_decay
        self.compressor = compressor

    def _factored(self, p) -> bool:
        return p.ndim >= 2

    def init(self, params) -> dict:
        def vrow(p):
            return (
                jnp.zeros(p.shape[:-1], jnp.float32)
                if self._factored(p)
                else jnp.zeros(p.shape, jnp.float32)
            )

        def vcol(p):
            return (
                jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if self._factored(p)
                else jnp.zeros((1,), jnp.float32)
            )

        state = {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
            "vr": jax.tree.map(vrow, params),
            "vc": jax.tree.map(vcol, params),
            "step": jnp.zeros((), jnp.int32),
        }
        if self.compressor is not None:
            state["ef"] = self.compressor.init(params)
        return state

    def update(self, params, grads, state):
        step = state["step"] + 1
        if self.compressor is not None:
            grads, ef = self.compressor.apply(grads, state["ef"])
        else:
            ef = None
        grads, _ = clip_by_global_norm(grads, self.clip_norm)
        lr = self.schedule(step)
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-self.decay)

        def upd(p, g, m, vr, vc):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps
            if self._factored(p):
                vr_n = beta2 * vr + (1 - beta2) * g2.mean(axis=-1)
                vc_n = beta2 * vc + (1 - beta2) * g2.mean(axis=-2)
                denom = (
                    vr_n[..., None]
                    * vc_n[..., None, :]
                    / jnp.maximum(vr_n.mean(axis=-1)[..., None, None], self.eps)
                )
                u = g * jax.lax.rsqrt(jnp.maximum(denom, self.eps))
            else:
                vr_n = beta2 * vr + (1 - beta2) * g2
                vc_n = vc
                u = g * jax.lax.rsqrt(jnp.maximum(vr_n, self.eps))
            # update clipping (RMS <= 1), per Shazeer & Stern
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            m_n = (self.b1 * m.astype(jnp.float32) + (1 - self.b1) * u).astype(
                jnp.bfloat16
            )
            pw = p.astype(jnp.float32)
            if self.weight_decay and p.ndim >= 2:
                pw = pw * (1 - lr * self.weight_decay)
            return (pw - lr * m_n.astype(jnp.float32)).astype(p.dtype), m_n, vr_n, vc_n

        out = jax.tree.map(
            upd, params, grads, state["m"], state["vr"], state["vc"],
            is_leaf=lambda x: isinstance(x, jnp.ndarray),
        )
        # unzip the 4-tuples
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        vr = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        vc = jax.tree.map(lambda o: o[3], out, is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"m": m, "vr": vr, "vc": vc, "step": step}
        if ef is not None:
            new_state["ef"] = ef
        return new_params, new_state


class ErrorFeedbackInt8:
    """Int8 symmetric gradient compression with error feedback.

    apply(): g_q = dequant(quant(g + residual)); residual' = (g + residual)
    - g_q.  The dequantized g_q is what downstream consumes (and what the
    DP all-reduce would move as int8 on the wire); convergence impact is
    bounded by the residual carry (tests measure it).
    """

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    @staticmethod
    def _q(x):
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * scale

    def apply(self, grads, residual):
        def one(g, r):
            acc = g.astype(jnp.float32) + r
            gq = self._q(acc)
            return gq, acc - gq

        out = jax.tree.map(one, grads, residual)
        gq = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        res = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return gq, res


def make_optimizer(name: str, schedule: Schedule, compress: bool = False):
    comp = ErrorFeedbackInt8() if compress else None
    if name == "adamw":
        return AdamW(schedule, compressor=comp)
    if name == "adafactor":
        return Adafactor(schedule, compressor=comp)
    raise ValueError(f"unknown optimizer {name!r}")
