"""Sharding rules: parameter, activation and cache layouts on the
production mesh (pod, data, model).

Strategy (see DESIGN.md §6):
  * TP over ``model``: attention heads / FFN hidden / expert dim / vocab.
  * EP over ``model``: MoE expert dim (E % model_size == 0 for all archs).
  * FSDP over (pod, data): the non-TP dim of every large matrix.
  * DP over (pod, data): the global batch.
  * SP over ``model``: decode KV caches shard the *sequence* dim (kv-head
    counts are below the model-axis size for several archs, sequence is
    not) — flash-decode style; XLA inserts the softmax partial reductions.

Specs are derived from parameter *path names*, so they apply uniformly to
the layer-stacked pytrees produced by scan-based models.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


def fit_axes(size: int, mesh: Mesh, axes: tuple) -> Optional[tuple]:
    """Largest prefix of ``axes`` whose product divides ``size`` (None if
    none fits) — lets small batches (e.g. long_500k's batch=1) fall back to
    replication instead of an invalid sharding."""
    best: Optional[tuple] = None
    prod = 1
    for i, a in enumerate(axes):
        prod *= mesh.shape[a]
        if size % prod == 0:
            best = tuple(axes[: i + 1])
    return best


def _path_names(path) -> list:
    names = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            names.append(str(e.key))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            names.append(str(e.name))
    return names


# rules: param leaf name -> spec builder over (fsdp_axes,) for the
# *unstacked* (per-layer) shape; a leading layer-stack dim gets None.
def _leaf_spec(names: list, ndim: int, fsdp) -> P:
    name = names[-1]
    stacked = any(n in ("blocks", "enc_blocks", "cross_blocks") for n in names)
    base: tuple
    if name == "embed":
        base = ("model", fsdp)  # vocab x d_model
    elif name == "lm_head":
        base = (fsdp, "model")
    elif name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj"):
        if names[-2] in ("moe",) or ndim - (1 if stacked else 0) == 3:
            base = ("model", fsdp, None)  # experts [E, D, F]
        else:
            base = (fsdp, "model")
    elif name in ("wo", "w_down", "out_proj"):
        if names[-2] in ("moe",) or ndim - (1 if stacked else 0) == 3:
            base = ("model", None, fsdp)  # [E, F, D]
        else:
            base = ("model", fsdp)
    elif name == "router":
        base = (None, None)
    elif name == "conv_w":
        base = (None, "model")
    elif name in ("a_q",):
        base = (None, fsdp, None)  # lora [apps, D, r]
    elif name in ("b_q",):
        base = (None, None, "model")  # lora [apps, r, Hq]
    else:
        # norms, biases, scalars: replicate
        base = tuple(None for _ in range(ndim))
        return P(*base)
    if stacked:
        base = (None,) + base
    # pad/truncate to ndim defensively
    if len(base) < ndim:
        base = base + tuple(None for _ in range(ndim - len(base)))
    return P(*base[:ndim])


def param_specs(params_tree, mesh: Mesh, serve: bool = False):
    """PartitionSpec pytree for a (possibly layer-stacked) param tree.

    ``serve=True`` drops the FSDP factor (params replicate over the dp
    axes, TP/EP over model only): inference has no optimizer state to
    amortize and the per-layer FSDP weight all-gathers dominate the
    collective term at small per-step compute (§Perf granite prefill).
    Weights must then fit HBM without the dp factor — true for every
    assigned arch except kimi-k2 (which keeps FSDP in serve mode too).
    """
    fsdp = None if serve else dp_axes(mesh)

    def spec(path, leaf):
        return _leaf_spec(_path_names(path), leaf.ndim, fsdp)

    return jax.tree_util.tree_map_with_path(spec, params_tree)


def param_shardings(params_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params_tree, mesh)
    )


def batch_specs(batch_tree, mesh: Mesh):
    """Inputs: batch dim over (pod, data) — or the largest prefix that
    divides it; M-RoPE positions lead with a size-3 stream dim."""
    dp = dp_axes(mesh)

    def spec(path, leaf):
        names = _path_names(path)
        if names and names[-1] == "positions":
            b = fit_axes(leaf.shape[1], mesh, dp)
            return P(None, b, *(None,) * (leaf.ndim - 2))
        b = fit_axes(leaf.shape[0], mesh, dp)
        return P(b, *(None,) * (leaf.ndim - 1))

    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def cache_specs(cache_tree, mesh: Mesh):
    """Decode caches: KV tensors [L, B, S, H, dh] shard the *sequence* over
    model (SP — kv-head counts are often < model-axis size, sequence never
    is) and batch over (pod, data); SSM states [L, B, H, N, dh] shard heads
    over model; conv states shard channels over model."""
    dp = dp_axes(mesh)

    def spec(path, leaf):
        names = _path_names(path)
        n = names[-1]
        if n == "len":
            return P()
        b = fit_axes(leaf.shape[1], mesh, dp)
        if n in ("k", "v", "xk", "xv"):  # [L, B, S, H, dh]
            s = "model" if leaf.shape[2] % mesh.shape["model"] == 0 else None
            return P(None, b, s, None, None)
        if n == "S":  # [L, B, H, N, dh]
            h = "model" if leaf.shape[2] % mesh.shape["model"] == 0 else None
            return P(None, b, h, None, None)
        if n == "conv":  # [L, B, K-1, C]
            c = "model" if leaf.shape[3] % mesh.shape["model"] == 0 else None
            return P(None, b, None, c)
        return P(*(None,) * leaf.ndim)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def logits_spec(mesh: Mesh) -> P:
    return P(dp_axes(mesh), None, "model")


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)
