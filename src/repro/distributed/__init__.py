# Distributed runtime: sharding rules (DP/FSDP/TP/EP/SP over pod/data/model),
# optimizers (AdamW, factored Adafactor, int8 error-feedback compression),
# async checkpointing with elastic restore, straggler monitoring, trainer.
from repro.distributed import (  # noqa: F401
    checkpoint,
    elastic,
    optimizer,
    sharding,
    straggler,
    train_loop,
)
