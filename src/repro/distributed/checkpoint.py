"""Checkpoint / restore with async save, atomic publish, elastic restore.

Layout (one directory per step):
    <root>/step_<k>.tmp/...   (while writing)
    <root>/step_<k>/manifest.json   + one .npy per leaf
    <root>/LATEST              (atomic pointer file)

* Writes happen on a background thread (training continues; ``wait()``
  joins).  The directory is renamed into place only after all leaves and
  the manifest are fsynced — a preempted save can never be mistaken for a
  complete one (restart tests exercise this).
* Restore is *elastic*: leaves are loaded as host arrays and re-placed with
  whatever sharding the CURRENT mesh prescribes, so a 512-chip checkpoint
  restores onto any mesh that fits it.
* In a multi-process deployment each process writes its addressable shards
  (the manifest records the layout); this single-process environment writes
  full arrays — the interface and atomicity protocol are identical.

The write-then-rename atomic-publish protocol here is also the durability
story of the DSE journal (``repro.dse.journal``), which applies it per
appended record batch instead of per checkpoint step, and of the
streaming service's live-weight snapshots (``repro.serve.durability``),
which pair a ``Checkpointer`` with a between-snapshots re-fit WAL.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> tuple[list, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _leaf in flat:
        names.append(
            "/".join(
                str(getattr(e, "key", getattr(e, "idx", getattr(e, "name", e))))
                for e in path
            )
        )
    return names


class Checkpointer:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---------------- save ----------------
    def save(self, step: int, tree, blocking: bool = False) -> None:
        """Snapshot to host memory synchronously, write asynchronously."""
        self.wait()
        leaves, treedef = _flatten(tree)
        names = _tree_paths(tree)
        host = [np.asarray(x) for x in leaves]  # device -> host now
        spec = {
            "step": step,
            "names": names,
            "dtypes": [str(h.dtype) for h in host],
            "shapes": [list(h.shape) for h in host],
        }

        def write():
            try:
                tmp = os.path.join(self.root, f"step_{step}.tmp")
                final = os.path.join(self.root, f"step_{step}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                for i, h in enumerate(host):
                    np.save(os.path.join(tmp, f"leaf_{i}.npy"), h)
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(spec, f)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                latest_tmp = os.path.join(self.root, "LATEST.tmp")
                with open(latest_tmp, "w") as f:
                    f.write(str(step))
                    f.flush()
                    os.fsync(f.fileno())
                os.replace(latest_tmp, os.path.join(self.root, "LATEST"))
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # ---------------- introspection / retention ----------------
    def steps(self) -> list:
        """Published snapshot steps, ascending (``.tmp`` dirs excluded —
        an in-flight or preempted save is never listed)."""
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[len("step_"):]))
                except ValueError:
                    continue
        return sorted(out)

    def prune(self, keep: int = 2) -> None:
        """Delete all but the newest ``keep`` published snapshots.  The
        serving snapshot+WAL loop calls this after each publish so a
        long-lived service's disk footprint stays bounded; ``LATEST``
        always points at the newest snapshot, which is always kept."""
        if keep < 1:
            raise ValueError("prune must keep at least one snapshot")
        self.wait()
        for step in self.steps()[:-keep]:
            shutil.rmtree(
                os.path.join(self.root, f"step_{step}"), ignore_errors=True
            )

    # ---------------- restore ----------------
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.root, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip())

    def restore(self, like, step: Optional[int] = None, shardings=None):
        """Restore into the structure of ``like``; if ``shardings`` (a
        pytree of jax.sharding.Sharding) is given, device_put each leaf —
        the elastic path (new mesh != save-time mesh)."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = os.path.join(self.root, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            spec = json.load(f)
        leaves, treedef = _flatten(like)
        if len(leaves) != len(spec["names"]):
            raise ValueError(
                f"checkpoint has {len(spec['names'])} leaves, template has "
                f"{len(leaves)} — structure changed?"
            )
        loaded = [
            np.load(os.path.join(d, f"leaf_{i}.npy")) for i in range(len(leaves))
        ]
        for h, l in zip(loaded, leaves):
            if tuple(h.shape) != tuple(np.shape(l)):
                raise ValueError(f"shape mismatch {h.shape} vs {np.shape(l)}")
        tree = jax.tree_util.tree_unflatten(treedef, loaded)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree, step
