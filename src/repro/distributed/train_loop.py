"""The training runtime: jit'd sharded train step + fault-tolerance loop.

``Trainer`` wires together:
  * model train step (grads + optimizer) jit'd with in/out shardings from
    ``sharding.py`` (params/opt donated — no double-buffered copies),
  * microbatch gradient accumulation (compute/comm overlap: each
    microbatch's reduce-scatter overlaps the next microbatch's backward
    under XLA async collectives),
  * step-granular checkpoint/restart (async; survives simulated preemption),
  * straggler monitoring hooks,
  * deterministic data (restart replays the exact batch sequence).

Works identically on the CPU smoke configs (tests) and on the production
mesh (launch/train.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.data.tokens import DataConfig, TokenSource
from repro.distributed import sharding
from repro.distributed.checkpoint import Checkpointer
from repro.distributed.optimizer import Schedule, make_optimizer
from repro.distributed.straggler import StepMonitor
from repro.models import transformer as T
from repro.models.config import ArchConfig


@dataclasses.dataclass
class TrainConfig:
    steps: int = 20
    microbatches: int = 1
    checkpoint_every: int = 10
    checkpoint_dir: Optional[str] = None
    peak_lr: float = 3e-4
    warmup_steps: int = 10
    compress_grads: bool = False
    seed: int = 0


def _accumulate_microbatches(loss_grad_fn, params, batch, n_micro: int):
    """Split the per-step batch into microbatches along batch dim and
    accumulate grads; scan keeps HLO small and lets XLA overlap each
    microbatch's collectives with the next one's compute."""
    if n_micro == 1:
        (loss, metrics), grads = loss_grad_fn(params, batch)
        return loss, metrics, grads

    def reshape(x):
        b = x.shape[0]
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    mb = jax.tree.map(reshape, batch)

    def body(carry, micro):
        acc, loss_acc = carry
        (loss, _metrics), grads = loss_grad_fn(params, micro)
        acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
        return (acc, loss_acc + loss), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), mb)
    grads = jax.tree.map(lambda g: g / n_micro, gsum)
    loss = loss_sum / n_micro
    return loss, {"nll": loss}, grads


class Trainer:
    def __init__(
        self,
        arch: ArchConfig,
        data_cfg: DataConfig,
        train_cfg: TrainConfig,
        mesh: Optional[jax.sharding.Mesh] = None,
    ):
        self.arch, self.data_cfg, self.cfg = arch, data_cfg, train_cfg
        self.mesh = mesh
        self.data = TokenSource(data_cfg)
        self.monitor = StepMonitor()
        self.ckpt = (
            Checkpointer(train_cfg.checkpoint_dir)
            if train_cfg.checkpoint_dir
            else None
        )
        sched = Schedule(
            peak_lr=train_cfg.peak_lr,
            warmup_steps=train_cfg.warmup_steps,
            total_steps=train_cfg.steps,
        )
        self.optimizer = make_optimizer(
            arch.optimizer, sched, compress=train_cfg.compress_grads
        )
        T.set_mesh(mesh)
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        arch, cfg = self.arch, self.cfg

        def step_fn(params, opt_state, batch):
            loss_grad = jax.value_and_grad(
                lambda p, b: T.loss_fn(p, b, arch), has_aux=True
            )
            loss, metrics, grads = _accumulate_microbatches(
                loss_grad, params, batch, cfg.microbatches
            )
            params, opt_state = self.optimizer.update(params, grads, opt_state)
            return params, opt_state, dict(metrics, loss=loss)

        if self.mesh is None:
            self.step = jax.jit(step_fn, donate_argnums=(0, 1))
            self.p_shard = self.o_shard = None
            return

        # shape-only param/opt trees -> shardings
        p_shapes = jax.eval_shape(
            lambda: T.init_params(jax.random.key(self.cfg.seed), arch)
        )
        o_shapes = jax.eval_shape(lambda: self.optimizer.init(_zeros_like(p_shapes)))
        self.p_shard = sharding.to_shardings(
            sharding.param_specs(p_shapes, self.mesh), self.mesh
        )
        # optimizer states mirror the param tree inside; reuse param rules
        self.o_shard = sharding.to_shardings(
            _opt_specs(o_shapes, p_shapes, self.mesh), self.mesh
        )
        b_shapes = jax.eval_shape(lambda: self.data.global_batch_at(0))
        b_shard = sharding.to_shardings(
            sharding.batch_specs(b_shapes, self.mesh), self.mesh
        )
        self.b_shard = b_shard
        self.step = jax.jit(
            step_fn,
            in_shardings=(self.p_shard, self.o_shard, b_shard),
            out_shardings=(self.p_shard, self.o_shard, None),
            donate_argnums=(0, 1),
        )

    # ------------------------------------------------------------------
    def init_state(self):
        params = T.init_params(jax.random.key(self.cfg.seed), self.arch)
        opt = self.optimizer.init(params)
        if self.mesh is not None:
            params = jax.device_put(params, self.p_shard)
            opt = jax.device_put(opt, self.o_shard)
        return params, opt

    def run(self, start_step: int = 0, params=None, opt=None, hooks=(),
            stop_after=None) -> dict:
        """Run to cfg.steps; resumable (restores latest checkpoint if any).

        ``stop_after`` simulates a preemption: the loop exits after that
        many steps (checkpoints written on schedule still stand; a later
        run() resumes from the last complete one with the SAME config).
        """
        if params is None:
            if self.ckpt and self.ckpt.latest_step() is not None:
                params, opt, start_step = self.restore()
            else:
                params, opt = self.init_state()
        losses = []
        end = self.cfg.steps if stop_after is None else min(
            self.cfg.steps, start_step + stop_after
        )
        for step in range(start_step, end):
            batch = self.data.global_batch_at(step)
            if self.mesh is not None:
                batch = jax.device_put(batch, self.b_shard)
            self.monitor.start()
            params, opt, metrics = self.step(params, opt, batch)
            loss = float(metrics["loss"])
            self.monitor.stop()
            losses.append(loss)
            for h in hooks:
                h(step, loss, params)
            if self.ckpt and (step + 1) % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step + 1, {"params": params, "opt": opt})
        if self.ckpt:
            # label with the last COMPLETED step (a preempted run must not
            # claim steps it never took)
            self.ckpt.save(end, {"params": params, "opt": opt}, blocking=True)
        return {"losses": losses, "params": params, "opt": opt}

    def restore(self):
        """Elastic restore: load latest checkpoint onto the CURRENT mesh."""
        p_shapes = jax.eval_shape(
            lambda: T.init_params(jax.random.key(self.cfg.seed), self.arch)
        )
        o_shapes = jax.eval_shape(lambda: self.optimizer.init(_zeros_like(p_shapes)))
        like = {"params": _zeros_like(p_shapes), "opt": _zeros_like(o_shapes)}
        shardings = None
        if self.mesh is not None:
            shardings = {"params": self.p_shard, "opt": self.o_shard}
        tree, step = self.ckpt.restore(like, shardings=shardings)
        return tree["params"], tree["opt"], step


def _zeros_like(shapes_tree):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes_tree)


def _opt_specs(o_shapes, p_shapes, mesh):
    """Optimizer-state specs: param-shaped leaves reuse the param rules
    (paths inside 'm'/'v'/... mirror the param tree); factored/scalar
    leaves replicate."""
    from jax.sharding import PartitionSpec as P

    def spec(path, leaf):
        names = sharding._path_names(path)
        inner = [n for n in names if n not in ("m", "v", "vr", "vc", "ef")]
        if not inner or leaf.ndim == 0:
            return P()
        # reuse the param rule when shapes align; else replicate
        sp = sharding._leaf_spec(inner, leaf.ndim, sharding.dp_axes(mesh))
        return sp

    return jax.tree_util.tree_map_with_path(spec, o_shapes)
