# Pallas TPU kernels for the TNN compute hot-spots (the layers TNNGen's
# silicon implements with unary temporal logic):
#   fused_column    — the training hot path: RNL fire + k-WTA + expected STDP
#                     in ONE kernel invocation, scanned over epochs x volleys
#                     with resident weights (in-kernel plane decomposition)
#   rnl_response    — fused RNL potential + first-crossing (one-hot plane MXU matmuls)
#   stdp_update     — fused per-synapse STDP case-select/stabilize/clamp (VPU)
#   flash_attention — fused causal flash attention (the §Perf structural fix
#                     for the LM pillar's memory-bound attention cells)
# Each has a pure-jnp oracle in ref.py; ops.py holds the jit'd wrappers.
# Execution policy (Mosaic vs interpreter vs reference lowering) is decided
# in ONE place: repro.core.backend — kernels never default interpret=True.
from repro.kernels import fused_column, ops, ref  # noqa: F401
from repro.kernels.flash_attention import flash_attention_pallas  # noqa: F401
from repro.kernels.rnl_response import rnl_fire_pallas  # noqa: F401
from repro.kernels.stdp_update import stdp_update_pallas  # noqa: F401
