# Pallas TPU kernels for the TNN compute hot-spots (the layers TNNGen's
# silicon implements with unary temporal logic):
#   rnl_response    — fused RNL potential + first-crossing (one-hot plane MXU matmuls)
#   stdp_update     — fused per-synapse STDP case-select/stabilize/clamp (VPU)
#   flash_attention — fused causal flash attention (the §Perf structural fix
#                     for the LM pillar's memory-bound attention cells)
# Each has a pure-jnp oracle in ref.py; ops.py holds the jit'd wrappers.
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.flash_attention import flash_attention_pallas  # noqa: F401
from repro.kernels.rnl_response import rnl_fire_pallas  # noqa: F401
from repro.kernels.stdp_update import stdp_update_pallas  # noqa: F401
