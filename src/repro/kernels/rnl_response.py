"""Pallas TPU kernel: fused RNL body-potential + first-crossing detection.

This is the compute hot-spot of the TNN column (in silicon: the bank of
unary ramp units + threshold comparators).  TPU-native adaptation:

  V[b, j, t] = sum_i min(relu(t - t_i), w_ij)
             = sum_i relu(t - t_i)  -  sum_v sum_i 1[w_ij == v] relu(t - t_i - v)

Integer weights (w in {0..w_max}, 3-bit in TNN7) decompose into one-hot
*value planes* ``W_v[i, j]`` for v = 0..w_max; the second term becomes
(w_max + 1) dense (q x p)@(p x B*T) matmuls — MXU work — while the first
term is a cheap column-sum.  (The v = 0 plane is required: it cancels the
base term for zero-weight synapses.)  Because V is nondecreasing in t (ramps
never decay), the firing time equals the COUNT of sub-threshold cycles:

  t_fire[b, j] = sum_t 1[V[b, j, t] < threshold]   (== t_max if never fires)

so the time dimension is a pure reduction: no cross-block "first hit" state,
the grid just accumulates partial counts into the output block.

Layout: the batch tile is folded into the lane dimension next to time —
A[p_pad, B_blk * t_blk] — so every plane matmul is one
(q_pad x p_pad) @ (p_pad x B_blk*t_blk) contraction with p padded to the
128-lane contraction dim and q padded to sublanes.  VMEM budget (defaults
B_blk=8, t_blk=128, p_pad<=2048): A + one transient + planes ~= 10 MB.

Non-spiking synapses (t_in >= t_max) contribute 0 automatically (their ramps
never start inside the window); synapse padding uses t_in = 2*t_max and
zero planes; neuron padding (q_pad > q) produces garbage counts that the
ops.py wrapper slices off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane/sublane alignment for TPU tiling.
LANE = 128
SUBLANE = 8


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _rnl_kernel(
    t_in_ref,  # [B_blk, p_pad]              f32 (no-spike >= t_max)
    planes_ref,  # [n_planes, p_pad, q_pad]  f32 one-hot planes, v = 0..w_max
    out_ref,  # [B_blk, q_pad]               f32 sub-threshold cycle counts
    *,
    t_blk: int,
    n_planes: int,
    threshold: float,
):
    b_blk, p_pad = t_in_ref.shape
    q_pad = planes_ref.shape[2]
    t0 = (pl.program_id(1) * t_blk).astype(jnp.float32)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # A[p, b*t] = relu(t - t_in[b, i]) with (b, t) folded into lanes.
    tv = t0 + jax.lax.iota(jnp.float32, t_blk)  # [t_blk]
    ti = t_in_ref[...].T  # [p_pad, B_blk]
    a = jnp.maximum(tv[None, None, :] - ti[:, :, None], 0.0)  # [p, B, t]
    a = a.reshape(p_pad, b_blk * t_blk)

    base = jnp.sum(a, axis=0, keepdims=True)  # [1, B*t]
    acc = jnp.zeros((q_pad, b_blk * t_blk), jnp.float32)
    for v in range(n_planes):  # static unroll: w_max + 1 plane matmuls
        wv = planes_ref[v, :, :]  # [p_pad, q_pad]
        av = a if v == 0 else jnp.maximum(a - float(v), 0.0)
        acc = acc + jax.lax.dot_general(
            wv, av, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [q_pad, B*t]

    vqt = base - acc  # [q_pad, B*t]
    below = (vqt < threshold).astype(jnp.float32)
    counts = below.reshape(q_pad, b_blk, t_blk).sum(axis=2)  # [q_pad, B_blk]
    out_ref[...] += counts.T


def make_weight_planes(w: jnp.ndarray, w_max: int) -> jnp.ndarray:
    """One-hot weight value planes: [p, q] int-valued -> [w_max+1, p, q] f32."""
    wi = jnp.round(w).astype(jnp.int32)
    v = jnp.arange(w_max + 1, dtype=jnp.int32)
    return (wi[None, :, :] == v[:, None, None]).astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("threshold", "t_max", "w_max", "b_blk", "t_blk", "interpret"),
)
def rnl_fire_pallas(
    t_in: jnp.ndarray,
    w: jnp.ndarray,
    threshold: float,
    t_max: int,
    w_max: int,
    b_blk: int = 8,
    t_blk: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused RNL firing-time kernel entry point.

    Args:
      t_in: [B, p] int32 spike times (>= t_max means no spike).
      w: [p, q] integer-valued weights in [0, w_max].
      threshold: firing threshold.
      t_max: window length in cycles.
      w_max: weight ceiling (3-bit TNN7 -> 7).
      b_blk / t_blk: batch tile and time tile (lane-aligned).
      interpret: None (default) defers to the central dispatch policy
        (``repro.core.backend.pallas_interpret()``: Mosaic on TPU,
        interpreter elsewhere); pass an explicit bool only in tests.

    Returns:
      [B, q] int32 firing times (t_max if the neuron never fires).
    """
    if interpret is None:
        from repro.core import backend as backend_lib

        interpret = backend_lib.pallas_interpret()
    B, p = t_in.shape
    q = w.shape[1]
    t_pad = _pad_to(t_max, t_blk)
    b_pad = _pad_to(B, b_blk)
    p_pad = _pad_to(p, LANE)
    q_pad = _pad_to(q, SUBLANE)
    n_planes = w_max + 1

    ti = jnp.full((b_pad, p_pad), 2.0 * t_pad, jnp.float32)
    ti = ti.at[:B, :p].set(t_in.astype(jnp.float32))
    # clamp genuine no-spikes to a value outside every time block
    ti = jnp.where(ti >= t_max, 2.0 * t_pad, ti)

    planes = jnp.zeros((n_planes, p_pad, q_pad), jnp.float32)
    planes = planes.at[:, :p, :q].set(make_weight_planes(w, w_max))

    grid = (b_pad // b_blk, t_pad // t_blk)
    out = pl.pallas_call(
        functools.partial(
            _rnl_kernel, t_blk=t_blk, n_planes=n_planes, threshold=threshold
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_blk, p_pad), lambda b, t: (b, 0)),
            pl.BlockSpec((n_planes, p_pad, q_pad), lambda b, t: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b_blk, q_pad), lambda b, t: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((b_pad, q_pad), jnp.float32),
        interpret=interpret,
    )(ti, planes)

    # padded time blocks beyond t_max count as sub-threshold only if V stays
    # below threshold; we clamp to t_max and slice padding off.
    counts = jnp.minimum(out[:B, :q], float(t_max))
    return counts.astype(jnp.int32)
