"""Jit'd public wrappers around the Pallas kernels.

``use_pallas`` dispatch: on CPU the kernels run under the Pallas interpreter
(bit-exact validation); on TPU set ``interpret=False``.  The pure-jnp oracle
path (``repro.kernels.ref``) is always available as a fallback and is what
the core library uses for differentiable / fractional-weight paths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import ColumnConfig, TIME_DTYPE
from repro.kernels import ref
from repro.kernels.rnl_response import rnl_fire_pallas
from repro.kernels.stdp_update import stdp_update_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rnl_fire(
    t_in: jnp.ndarray,
    w: jnp.ndarray,
    threshold: float,
    t_max: int,
    w_max: int,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Firing times for integer-weight RNL neurons. [B,p],[p,q] -> [B,q]."""
    if not use_pallas:
        return ref.rnl_fire_ref(t_in, w, threshold, t_max)
    return rnl_fire_pallas(
        t_in, w, threshold, t_max, w_max, interpret=not _on_tpu()
    )


def column_forward(
    params: dict, t_in: jnp.ndarray, cfg: ColumnConfig, use_pallas: bool = True
) -> jnp.ndarray:
    """Kernel-backed column forward (integer weights): response + 1-WTA.

    Weights are rounded to the hardware integer grid first (the kernel's
    one-hot plane decomposition requires w in {0..w_max}).
    """
    w = jnp.round(jnp.clip(params["w"], 0.0, cfg.neuron.w_max))
    t_out = rnl_fire(
        t_in, w, cfg.neuron.threshold, cfg.t_max, cfg.neuron.w_max, use_pallas
    )
    return ref.wta_ref(t_out, cfg.wta.k, cfg.t_max)


def stdp_step(
    w: jnp.ndarray,
    x_times: jnp.ndarray,
    y_times: jnp.ndarray,
    cfg: ColumnConfig,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Kernel-backed expected-mode STDP update for one volley."""
    s = cfg.stdp
    if not use_pallas:
        return ref.stdp_ref(
            w, x_times, y_times, s.mu_capture, s.mu_backoff, s.mu_search,
            cfg.neuron.w_max, cfg.t_max, stabilize=s.stabilizer == "half",
        )
    return stdp_update_pallas(
        w, x_times, y_times, s.mu_capture, s.mu_backoff, s.mu_search,
        cfg.neuron.w_max, cfg.t_max, stabilize=s.stabilizer == "half",
        interpret=not _on_tpu(),
    )


def train_volleys(
    params: dict, x: jnp.ndarray, cfg: ColumnConfig, use_pallas: bool = True
) -> dict:
    """Online STDP over a batch of volleys using the fused kernels.

    x: [B, p].  Semantically identical to core/column.train_step with
    mode='event', integer weights, expected STDP.
    """

    def step(w, xt):
        t_out = rnl_fire(
            xt[None], jnp.round(jnp.clip(w, 0.0, cfg.neuron.w_max)),
            cfg.neuron.threshold, cfg.t_max, cfg.neuron.w_max, use_pallas,
        )[0]
        y = ref.wta_ref(t_out[None], cfg.wta.k, cfg.t_max)[0]
        return stdp_step(w, xt, y, cfg, use_pallas), None

    w, _ = jax.lax.scan(step, params["w"], x)
    return {"w": w}
