"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy lives in ONE place — ``repro.core.backend``: kernels lower
through Mosaic on TPU and the Pallas interpreter is only ever selected
explicitly (``interpret=True``) for validation.  The pure-jnp oracle path
(``repro.kernels.ref``) remains available as a fallback and is what the core
library uses for differentiable / fractional-weight paths.

``train_volleys`` is a thin wrapper over the fused training scan in
``repro.kernels.fused_column`` — one kernel invocation per volley (fire +
WTA + STDP fused), weights resident across the scan, no per-volley padding
or one-hot plane rebuild.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import ColumnConfig
from repro.kernels import fused_column, ref
from repro.kernels.rnl_response import rnl_fire_pallas
from repro.kernels.stdp_update import stdp_update_pallas


def rnl_fire(
    t_in: jnp.ndarray,
    w: jnp.ndarray,
    threshold: float,
    t_max: int,
    w_max: int,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Firing times for integer-weight RNL neurons. [B,p],[p,q] -> [B,q]."""
    if not use_pallas:
        return ref.rnl_fire_ref(t_in, w, threshold, t_max)
    return rnl_fire_pallas(t_in, w, threshold, t_max, w_max)


def column_forward(
    params: dict, t_in: jnp.ndarray, cfg: ColumnConfig, use_pallas: bool = True
) -> jnp.ndarray:
    """Kernel-backed column forward (integer weights): response + 1-WTA.

    Weights are rounded to the hardware integer grid first (the kernel's
    one-hot plane decomposition requires w in {0..w_max}).
    """
    w = jnp.round(jnp.clip(params["w"], 0.0, cfg.neuron.w_max))
    t_out = rnl_fire(
        t_in, w, cfg.neuron.threshold, cfg.t_max, cfg.neuron.w_max, use_pallas
    )
    return ref.wta_ref(t_out, cfg.wta.k, cfg.t_max)


def stdp_step(
    w: jnp.ndarray,
    x_times: jnp.ndarray,
    y_times: jnp.ndarray,
    cfg: ColumnConfig,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Kernel-backed expected-mode STDP update for one volley."""
    s = cfg.stdp
    if not use_pallas:
        return ref.stdp_ref(
            w, x_times, y_times, s.mu_capture, s.mu_backoff, s.mu_search,
            cfg.neuron.w_max, cfg.t_max, stabilize=s.stabilizer == "half",
        )
    return stdp_update_pallas(
        w, x_times, y_times, s.mu_capture, s.mu_backoff, s.mu_search,
        cfg.neuron.w_max, cfg.t_max, stabilize=s.stabilizer == "half",
    )


def train_volleys(
    params: dict, x: jnp.ndarray, cfg: ColumnConfig, use_pallas: bool = True
) -> dict:
    """Online STDP over a batch of volleys via the fused column step.

    x: [B, p].  Integer-grid fire, expected STDP, index tie-break — the
    hardware semantics.  ``use_pallas=True`` always runs the actual Pallas
    kernel (Mosaic on TPU, interpreter elsewhere — this entry point's job
    is kernel validation); ``use_pallas=False`` runs the jnp reference
    lowering of the same fused step (identical results).
    """
    from repro.core import backend as backend_lib

    if use_pallas:
        lowering = "mosaic" if backend_lib.on_tpu() else "interpret"
    else:
        lowering = "reference"
    new_params, _ = fused_column.fit_fused(
        params, x, cfg, epochs=1, lowering=lowering, trace=False
    )
    return new_params
