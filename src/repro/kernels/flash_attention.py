"""Pallas TPU kernel: fused causal flash attention (prototype).

The §Perf hillclimbs all converged on the same structural conclusion: the
dominant memory term of the LM cells is the unfused fp32 score/softmax
chain that pure-jnp chunked attention materializes per KV block.  This
kernel keeps the whole online-softmax update (scores, masking, exp,
running max/denominator, accumulator) in VMEM — the HBM traffic per layer
collapses to reading Q/K/V once and writing O once.

Layout: grid (batch*kv_head*group, q_blocks); the kernel body loops over
KV blocks with `jax.lax.fori_loop`, carrying (m, l, acc) in registers/VMEM.
Block sizes default to (q_blk=128, kv_blk=128) — MXU-aligned.  Causal
masking skips fully-masked KV blocks via the loop upper bound.

Validated against the pure-jnp oracle (layers.chunked_attention) under the
Pallas interpreter; on-TPU deployment plugs in via
``attention(..., impl='pallas')`` (future work — the dry-run's CPU
cost-model cannot see fusion wins, see EXPERIMENTS.md §Perf cell 2 iter4).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_kernel(
    q_ref,  # [q_blk, d]
    k_ref,  # [Skv, d]
    v_ref,  # [Skv, d]
    o_ref,  # [q_blk, d]
    *,
    kv_blk: int,
    causal: bool,
    scale: float,
):
    q_blk, d = q_ref.shape
    skv = k_ref.shape[0]
    qi = pl.program_id(1)
    q0 = qi * q_blk

    q = q_ref[...].astype(jnp.float32) * scale
    n_kv = skv // kv_blk
    if causal:
        # only KV blocks that intersect the causal triangle
        n_kv_needed = (q0 + q_blk + kv_blk - 1) // kv_blk
    else:
        n_kv_needed = n_kv

    def body(ki, carry):
        m, l, acc = carry
        k = k_ref[pl.dslice(ki * kv_blk, kv_blk), :].astype(jnp.float32)
        v = v_ref[pl.dslice(ki * kv_blk, kv_blk), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [q_blk, kv_blk]
        if causal:
            q_pos = q0 + jax.lax.iota(jnp.int32, q_blk)[:, None]
            kv_pos = ki * kv_blk + jax.lax.iota(jnp.int32, kv_blk)[None, :]
            s = jnp.where(kv_pos <= q_pos, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((q_blk,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((q_blk,), jnp.float32)
    a0 = jnp.zeros((q_blk, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kv_needed, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l[:, None], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "q_blk", "kv_blk", "interpret")
)
def flash_attention_pallas(
    q: jnp.ndarray,  # [B, Sq, H, d]
    k: jnp.ndarray,  # [B, Skv, H, d]
    v: jnp.ndarray,  # [B, Skv, H, d]
    causal: bool = True,
    q_blk: int = 128,
    kv_blk: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused flash attention (MHA layout; GQA callers pre-broadcast K/V).

    Sequence lengths must be multiples of the block sizes (callers pad).
    Returns [B, Sq, H, d] in q's dtype.  ``interpret=None`` defers to the
    central dispatch policy (``repro.core.backend.pallas_interpret()``).
    """
    if interpret is None:
        from repro.core import backend as backend_lib

        interpret = backend_lib.pallas_interpret()
    B, Sq, H, d = q.shape
    Skv = k.shape[1]
    assert Sq % q_blk == 0 and Skv % kv_blk == 0, (Sq, Skv, q_blk, kv_blk)
    scale = 1.0 / (d ** 0.5)

    # [B, S, H, d] -> [B*H, S, d]
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, d)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, Skv, d)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, Skv, d)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, kv_blk=kv_blk, causal=causal, scale=scale
        ),
        grid=(B * H, Sq // q_blk),
        in_specs=[
            pl.BlockSpec((None, q_blk, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, Skv, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, Skv, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_blk, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, Sq, d).transpose(0, 2, 1, 3)
