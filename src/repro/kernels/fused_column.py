"""Fused TNN column training step: RNL fire + k-WTA + expected STDP.

This is the hot path of the paper's "rapid application exploration" loop:
online STDP folds one volley at a time into the weights, so training is a
``lax.scan`` over epochs x volleys whose body is ONE fused column step.  The
step exists in two lowerings behind the same semantics:

* ``fused_step_pallas_padded`` — a single ``pl.pallas_call`` over a grid of
  (designs, time blocks): the RNL body potential is evaluated via the
  one-hot weight-plane decomposition (MXU matmuls, planes built *in-kernel*
  from the VMEM-resident weights — ``make_weight_planes`` never runs per
  volley), firing times fall out as sub-threshold cycle counts, the k-WTA
  priority encoder and the per-synapse expected-STDP update run in the same
  kernel invocation, and the updated weights are written back.  Per-design
  scalars (threshold, effective ``t_max``, live-neuron count, STDP mus)
  enter as a *runtime* SMEM operand (``design_operands``) masked against a
  single static envelope — one compiled kernel serves a whole heterogeneous
  design batch, and changing a threshold never retraces.  Weights stay
  padded/resident across the whole scan; padding happens once per ``fit``.
* ``fused_step_ref`` — the pure-jnp lowering of the same algebra (dense
  sub-threshold count over the time window).  Exact for RNL/SNL: V(t) is
  nondecreasing, so the count of sub-threshold integer cycles *is* the first
  crossing — bit-identical to ``mode='cycle'``.  This is what the central
  dispatch (``repro.core.backend``) lowers to off-TPU, where the Pallas
  interpreter would serialize 100x slower; the interpreter remains available
  for validation via ``lowering='interpret'``.

Scope (enforced by ``check_fusable``): ``response in ('rnl', 'snl')``
(``'rnl'`` only for the Pallas lowering), expected-mode STDP, index
tie-break WTA.  Other configs take the generic per-solver scan in
``repro.core.backend``.

The per-design quantities (threshold, t_max, active q, STDP mus) are traced
values in *both* lowerings — the reference ``vmap``s over them, the kernel
reads them from SMEM — so a stacked sweep of heterogeneous designs
(``simulator.cluster_time_series_many``) or network layers
(``network.fit_greedy``) compiles once per envelope shape, never per
design.  The full kernel contract is documented in ``docs/kernels.md``.

The padded scans advance in **volley blocks** (``v_blk=``): each step of the
outer ``lax.scan`` folds ``v_blk`` sequential online-STDP volleys in one
fused body — ONE kernel invocation whose in-kernel loop keeps the weight
buffer VMEM-resident for the whole block (Mosaic), or one statically
unrolled jnp block sharing precomputed input ramps (reference) — exactly
online either way: volley i inside a block still sees the weights updated
by volley i-1, bit-identical to ``v_blk=1`` and to ``mode='cycle'`` on
integer weight grids.  Block tails are silent-padded (the sentinel
contract) AND masked out of the weight fold by a per-block valid count,
so a tail step is an exact weight no-op for any design — even degenerate
``threshold <= 0`` ones.  ``assign_padded``, which has no sequential
dependency at all, batches volleys into the kernel grid instead (one
``pallas_call`` for the whole assignment pass).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.types import ColumnConfig, TIME_DTYPE
from repro.kernels import ref

LANE = 128
SUBLANE = 8

LOWERINGS = ("mosaic", "interpret", "reference")

# Columns of the runtime design-operand array (see ``design_operands``):
# one row of per-design scalars the kernel reads from SMEM at run time.
OPERAND_COLS = (
    "threshold", "t_max", "q_active", "mu_capture", "mu_backoff", "mu_search"
)
N_OPERANDS = len(OPERAND_COLS)


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_volleys_silent(x: jnp.ndarray, p_pad: int, sentinel: float):
    """Widen volleys [..., p] -> [..., p_pad] f32, padding with ``sentinel``.

    The kernel's silence contract is ``time >= design t_max`` (see
    docs/kernels.md); any sentinel satisfying that for every design in the
    batch is equivalent — this helper is the one place the fill happens.
    """
    xs = jnp.full(x.shape[:-1] + (p_pad,), float(sentinel), jnp.float32)
    return xs.at[..., : x.shape[-1]].set(x.astype(jnp.float32))


def pad_stream_silent(xs, n_total: int, sentinel):
    """Ragged micro-batch seam: pad a volley stream [n, ...] to [n_total, ...]
    with silent rows (every time set to ``sentinel``, which must be >= every
    design's ``t_max``).

    A serving front-end keeps ONE compiled executable per envelope by
    padding partial request batches up to the compiled batch size; silent
    rows assign to the "unclustered" id (``q_active``) and are sliced away
    by the caller, and — for the positive thresholds real designs use — a
    silent volley is an exact weight no-op under the fused STDP step, so
    the same trick pads ragged re-fit windows.  Accepts numpy or jax
    arrays and stays in that family (serving assembles batches host-side).
    """
    n = xs.shape[0]
    if n > n_total:
        raise ValueError(f"stream of {n} volleys exceeds batch of {n_total}")
    if n == n_total:
        return xs
    if isinstance(xs, np.ndarray):
        pad = np.full((n_total - n,) + xs.shape[1:], sentinel, xs.dtype)
        return np.concatenate([xs, pad], axis=0)
    pad = jnp.full((n_total - n,) + xs.shape[1:], sentinel, xs.dtype)
    return jnp.concatenate([xs, pad], axis=0)


def fire_responses(lowering: str) -> tuple[str, ...]:
    """Response functions the fused fire supports under a given lowering
    (the Pallas kernel implements the RNL plane decomposition only)."""
    return ("rnl", "snl") if lowering == "reference" else ("rnl",)


def check_fusable(cfg: ColumnConfig, lowering: str) -> None:
    """Raise ValueError if cfg falls outside the fused step's contract."""
    if lowering not in LOWERINGS:
        raise ValueError(f"unknown lowering: {lowering!r}")
    ok_resp = fire_responses(lowering)
    if cfg.neuron.response not in ok_resp:
        raise ValueError(
            f"fused step ({lowering}) supports response {ok_resp}, got "
            f"{cfg.neuron.response!r}"
        )
    if cfg.stdp.mode != "expected":
        raise ValueError("fused step supports expected-mode STDP only")
    if cfg.wta.tie_break != "index":
        raise ValueError("fused step supports index tie-break WTA only")


# --------------------------------------------------------------- reference
def fire_dense_ref(
    w: jnp.ndarray,
    t_in: jnp.ndarray,
    threshold,
    t_window: int,
    t_max=None,
    response: str = "rnl",
) -> jnp.ndarray:
    """Firing times by dense sub-threshold cycle count.  [p],[p,q] -> [q].

    ``t_window`` is the static evaluation length; ``t_max`` (traced OK) is
    the effective window — spike times >= t_max are silent and crossings at
    or past t_max report t_max.  Exact for RNL/SNL (V nondecreasing).
    """
    if t_max is None:
        t_max = t_window
    tv = jnp.arange(t_window, dtype=jnp.float32)  # [T]
    ti = t_in.astype(jnp.float32)
    live = ti < t_max  # [p]
    if response == "rnl":
        a = jax.nn.relu(tv[None, :] - ti[:, None])  # [p, T]
        a = jnp.where(live[:, None], a, 0.0)
        contrib = jnp.minimum(a[:, None, :], w[:, :, None])  # [p, q, T]
    else:  # snl
        s = (tv[None, :] >= ti[:, None]) & live[:, None]
        contrib = s[:, None, :].astype(w.dtype) * w[:, :, None]
    v = contrib.sum(axis=0)  # [q, T]
    below = (v < threshold) & (tv[None, :] < t_max)
    count = below.sum(axis=-1)
    return jnp.minimum(count, t_max).astype(TIME_DTYPE)


def _masked_steps(t_in: jnp.ndarray, t_max, t_window: int) -> jnp.ndarray:
    """Input-only fire transient: binary step functions [..., p, T].

    ``s[p, t] = 1[t >= t_in[p]]`` for live inputs, 0 for silent ones — the
    one weight-independent ingredient of the fire under BOTH responses
    (see ``fire_planes_ref``), so a volley block precomputes it ONCE and
    reuses it across the block's sequential weight updates.  ``t_max`` may
    be traced (and broadcast against leading batch axes).
    """
    tv = jnp.arange(t_window, dtype=jnp.float32)
    ti = t_in.astype(jnp.float32)
    live = ti < t_max
    return ((tv >= ti[..., None]) & live[..., None]).astype(jnp.float32)


def fire_planes_ref(
    w: jnp.ndarray,
    s: jnp.ndarray,
    threshold,
    t_window: int,
    t_max,
    response: str,
    w_max: int,
) -> jnp.ndarray:
    """Firing times from precomputed step transients, shift-GEMM form. -> [q].

    The plane algebra of the Mosaic kernel (docs/kernels.md) restructured
    for a memory-bound host.  With *integer* spike times and integer-grid
    weights, ``min(relu(t - ti), w) = sum_{v=1..w_max} 1[w >= v] *
    1[t - ti >= v]``, and the v-th indicator is just the step function
    delayed by v cycles: ``1[t - ti >= v] == s[t - v]``.  So the RNL
    potential needs NO ramp values and NO base term at all: the ``w_max``
    cumulative weight planes ``1[w >= v]`` contract against the one small
    shared binary step block in a single GEMM, and the per-plane delays
    are applied afterwards on the tiny ``[q, T]`` products — a fraction of
    the memory traffic of materializing per-plane ramp operands.  For SNL
    the potential IS a matmul of the same steps against the weights.  All
    intermediates are small integers in f32, so this is bit-identical to
    ``fire_dense_ref`` on the integer weight grid (weights are rounded
    here, mirroring the kernel) — integer spike times are a precondition
    (they are the repo's time contract, ``types.TIME_DTYPE``).

    Args:
      w: [p, q] weights (rounded to the integer grid internally).
      s: [p, T] step transient from ``_masked_steps``.
    """
    p, q = w.shape
    tv = jnp.arange(t_window, dtype=jnp.float32)
    wi = jnp.round(jnp.clip(w, 0.0, float(w_max)))
    if response == "rnl":
        vs = jnp.arange(1, w_max + 1, dtype=jnp.float32)
        ge = (wi[:, None, :] >= vs[None, :, None]).astype(jnp.float32)
        g = jax.lax.dot_general(
            ge.reshape(p, w_max * q), s,
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        ).reshape(w_max, q, t_window)  # per-plane products, undelayed
        gp = jnp.pad(g, ((0, 0), (0, 0), (w_max, 0)))
        v = gp[0, :, w_max - 1: w_max - 1 + t_window]  # plane v=1
        for sh in range(2, w_max + 1):  # static unroll: tiny [q, T] slices
            v = v + gp[sh - 1, :, w_max - sh: w_max - sh + t_window]
    else:  # snl: V = w^T @ steps
        v = jax.lax.dot_general(
            wi, s, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [q, T]
    below = (v < threshold) & (tv[None, :] < t_max)
    count = below.sum(axis=-1)
    return jnp.minimum(count, t_max).astype(TIME_DTYPE)


def fused_step_ref(
    w: jnp.ndarray,
    t_in: jnp.ndarray,
    threshold,
    t_window: int,
    w_max: int,
    wta_k: int,
    mu_capture: float,
    mu_backoff: float,
    mu_search: float,
    stabilize: bool,
    t_max=None,
    response: str = "rnl",
    integer_fire: bool = False,
    q_active=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One fused column step, jnp lowering.  Returns (w_new, y).

    Args:
      w: [p, q] resident weights.
      t_in: [p] one input volley.
      threshold / t_max / q_active: traced-friendly per-design scalars
        (q_active masks neurons >= q_active out of WTA and STDP — used by the
        padded multi-design sweep; None means all q are live).
      t_window: static dense evaluation length (>= t_max).
      integer_fire: round weights to the hardware integer grid for the fire
        step (the Pallas lowering always does; planes need w in {0..w_max}).
    """
    if t_max is None:
        t_max = t_window
    w_fire = jnp.round(jnp.clip(w, 0.0, w_max)) if integer_fire else w
    t_fire = fire_dense_ref(w_fire, t_in, threshold, t_window, t_max, response)
    if q_active is not None:
        qi = jnp.arange(w.shape[1], dtype=TIME_DTYPE)
        t_fire = jnp.where(qi < q_active, t_fire, t_max)
    y = ref.wta_ref(t_fire[None], wta_k, t_max)[0]
    w_new = ref.stdp_ref(
        w, t_in, y, mu_capture, mu_backoff, mu_search, w_max, t_max,
        stabilize=stabilize,
    )
    if q_active is not None:
        qi = jnp.arange(w.shape[1], dtype=TIME_DTYPE)
        w_new = jnp.where(qi[None, :] < q_active, w_new, w)
    return w_new, y


def _block_step_ref(
    w: jnp.ndarray,
    s: jnp.ndarray,
    xt: jnp.ndarray,
    threshold,
    t_max,
    q_active,
    *,
    t_window: int,
    w_max: int,
    wta_k: int,
    mu_capture,
    mu_backoff,
    mu_search,
    stabilize: bool,
    response: str,
    valid=True,
) -> jnp.ndarray:
    """One volley of a reference volley block: GEMM fire + WTA + STDP.

    Same semantics as ``fused_step_ref`` with ``integer_fire=True`` (the
    fused contract), but fed the precomputed step transient so the block's
    unrolled loop shares the input-side work, and with the kernel's
    min-round k-WTA (identical to ``ref.wta_ref`` — keys are unique —
    without a sort in the hot loop).  ``valid`` (traced bool OK) marks
    silent-padded block-tail volleys, which must fold nothing for ANY
    design; it rides the existing out-of-envelope mask, costing no extra
    op.  [p, q], [p, T], [p] -> [p, q].
    """
    q = w.shape[1]
    qi = jnp.arange(q, dtype=TIME_DTYPE)
    t_fire = fire_planes_ref(
        w, s, threshold, t_window, t_max, response, w_max
    )
    t_fire = jnp.where(qi < q_active, t_fire, t_max)
    # the kernels' WTA helper, shared verbatim (dtype-generic), so WTA
    # semantics live in exactly one place
    y = _kernel_wta(
        t_fire, qi, t_max, wta_k=wta_k, t_window=t_window
    ).astype(TIME_DTYPE)
    w_new = ref.stdp_ref(
        w, xt, y, mu_capture, mu_backoff, mu_search, w_max, t_max,
        stabilize=stabilize,
    )
    return jnp.where((qi[None, :] < q_active) & valid, w_new, w)


def _pad_volley_blocks(
    xs: jnp.ndarray, v_blk: int, sentinel
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """[N, ...] volleys -> ([S, v_blk, ...] blocks, [S] valid counts).

    Tail volleys of the last block are silent-padded (the sentinel
    contract: every synapse at/past ``t_max``) AND masked out of the weight
    fold by the per-block valid count — tail steps carry the weights
    through unchanged for any design, unconditionally (a silent volley is
    already a no-op for the positive thresholds real designs use, but the
    explicit mask keeps bit-identity across ``v_blk`` even for degenerate
    ``threshold <= 0`` designs, where silence still fires every neuron).
    """
    n = xs.shape[0]
    s = -(-n // v_blk)
    n_valid = jnp.minimum(
        jnp.full((s,), v_blk, TIME_DTYPE),
        n - v_blk * jnp.arange(s, dtype=TIME_DTYPE),
    )
    if s * v_blk != n:
        pad = jnp.full((s * v_blk - n,) + xs.shape[1:], sentinel, xs.dtype)
        xs = jnp.concatenate([xs, pad], axis=0)
    return xs.reshape((s, v_blk) + xs.shape[1:]), n_valid


# ------------------------------------------------------------ pallas kernel
def design_operands(
    thresholds,
    t_maxes,
    q_actives,
    mu_capture,
    mu_backoff,
    mu_search,
) -> jnp.ndarray:
    """Pack per-design runtime scalars into the kernel's SMEM operand array.

    Returns [D, N_OPERANDS] f32, one row per design, columns ordered as
    ``OPERAND_COLS``.  Every entry is a *runtime* value: the kernel masks
    against them inside one static envelope, so heterogeneous designs share
    a single compiled kernel and changing any of them never retraces.  The
    mus may be Python floats (broadcast across designs) or [D] arrays.
    """
    d = jnp.shape(thresholds)[0]
    cols = (thresholds, t_maxes, q_actives, mu_capture, mu_backoff, mu_search)
    return jnp.stack(
        [
            jnp.broadcast_to(jnp.asarray(c, jnp.float32), (d,))
            for c in cols
        ],
        axis=1,
    )


# The three kernels below (per-volley fused step, volley-blocked fused
# step, batched assignment fire) share one in-kernel algebra.  It lives in
# the value-level helpers here — plain jnp on values, traced into each
# kernel — so a change to the fire/WTA/STDP semantics lands in every
# lowering path at once (the cross-lowering bit-identity contract).
def _kernel_fire_counts(wi, ti_col, t0, threshold, t_max, *, t_blk, n_planes):
    """Sub-threshold cycle counts of one time block starting at ``t0``.

    ``wi``: [p_pad, q_pad] integer-grid weights; ``ti_col``: [p_pad, 1]
    input times down the sublanes.  Returns [1, q_pad] counts to add to the
    design's accumulator: the RNL body potential via the in-kernel one-hot
    plane matmuls, compared against the runtime threshold and masked by the
    runtime window ``t_max``.
    """
    q_pad = wi.shape[1]
    tv = t0 + jax.lax.broadcasted_iota(jnp.float32, (1, t_blk), 1)
    a = jnp.maximum(tv - ti_col, 0.0)  # [p_pad, t_blk] ramps
    base = jnp.sum(a, axis=0, keepdims=True)  # [1, t_blk]
    acc = jnp.zeros((q_pad, t_blk), jnp.float32)
    for v in range(n_planes):  # static unroll: planes from resident weights
        plane = (wi == float(v)).astype(jnp.float32)  # [p_pad, q_pad]
        av = a if v == 0 else jnp.maximum(a - float(v), 0.0)
        acc = acc + jax.lax.dot_general(
            plane, av, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [q_pad, t_blk]
    vqt = base - acc  # [q_pad, t_blk] body potential
    below = (vqt < threshold) & (tv < t_max)  # mask window padding
    return jnp.sum(below.astype(jnp.float32), axis=1)[None, :]


def _kernel_wta(t_fire, qi, t_max, *, wta_k, t_window):
    """k-WTA priority encoder on [1, q_pad] firing times -> winner times.

    Lexicographic (time, index) packed key; keys are unique, so k unrolled
    min rounds find the k-th smallest.  ``big`` only needs to exceed every
    live key, so the static envelope bound serves all designs.  Dtype
    follows ``t_fire``/``qi`` (f32 in the kernels, TIME_DTYPE on the
    blocked reference path — keys are small integers, exact either way).
    """
    q_pad = t_fire.shape[-1]
    big = (t_window + 1) * q_pad  # python int: weakly typed either way
    key = t_fire * q_pad + qi
    rem = key
    kth = key.dtype.type(0)
    for _ in range(wta_k):
        kth = jnp.min(rem)
        rem = jnp.where(rem <= kth, big, rem)
    win = (key <= kth) & (t_fire < t_max)
    return jnp.where(win, t_fire, t_max)  # [1, q_pad]


def _kernel_stdp(
    w, ti_col, y, qi, t_max, q_live,
    mu_capture, mu_backoff, mu_search, *, w_max, stabilize,
):
    """Expected STDP on the resident float weights (same algebra as
    ``kernels/ref.stdp_ref``), padded neurons (>= ``q_live``) frozen."""
    xs = ti_col < t_max
    ys = y < t_max
    if stabilize:
        frac = jnp.clip(w * (1.0 / w_max), 0.0, 1.0)
        eps = 1.0 / (2 * w_max)
        s_plus = (1.0 - frac) + eps
        s_minus = frac + eps
    else:
        s_plus = s_minus = jnp.ones_like(w)
    capture = xs & ys & (ti_col <= y)
    backoff = (xs & ys & (ti_col > y)) | ((~xs) & ys)
    search = xs & (~ys)
    delta = jnp.where(capture, mu_capture * s_plus, 0.0)
    delta = jnp.where(backoff, -mu_backoff * s_minus, delta)
    delta = jnp.where(search, mu_search, delta)
    delta = jnp.where(qi < q_live, delta, 0.0)
    return jnp.clip(w + delta, 0.0, float(w_max))


def _fused_kernel(
    scal_ref,  # [D, N_OPERANDS] f32 SMEM runtime design operands
    t_ref,  # [1, p_pad]         f32 input volley (silent >= design t_max)
    w_ref,  # [1, p_pad, q_pad]  f32 resident weights
    w_out,  # [1, p_pad, q_pad]  f32 updated weights
    y_out,  # [1, q_pad]         f32 counts accumulator -> winner times
    *,
    t_blk: int,
    t_window: int,
    n_planes: int,
    wta_k: int,
    w_max: int,
    stabilize: bool,
):
    """Fused fire + k-WTA + expected-STDP body, grid = (designs, time blocks).

    Static envelope: block shapes, ``t_window`` (padded evaluation length),
    ``n_planes``/``w_max``, ``wta_k`` and the stabilizer flag.  Everything
    per-design — threshold, effective window ``t_max``, live-neuron count
    ``q_active``, STDP mus — is read from ``scal_ref`` at run time and
    masked against the envelope, so one compiled kernel serves a whole
    heterogeneous design batch.
    """
    _, p_pad, q_pad = w_ref.shape
    d = pl.program_id(0)
    i = pl.program_id(1)
    last = pl.num_programs(1) - 1

    threshold = scal_ref[d, 0]
    t_max = scal_ref[d, 1]
    q_live = scal_ref[d, 2]
    mu_capture = scal_ref[d, 3]
    mu_backoff = scal_ref[d, 4]
    mu_search = scal_ref[d, 5]

    @pl.when(i == 0)
    def _init():
        y_out[...] = jnp.zeros_like(y_out)

    # --- fire: accumulate sub-threshold cycle counts for this time block.
    ti = t_ref[...].T  # [p_pad, 1] input times down the sublanes
    w = w_ref[0]
    wi = jnp.round(jnp.clip(w, 0.0, float(w_max)))  # integer fire grid
    y_out[...] += _kernel_fire_counts(
        wi, ti, (i * t_blk).astype(jnp.float32), threshold, t_max,
        t_blk=t_blk, n_planes=n_planes,
    )

    # --- WTA + STDP once all time blocks have accumulated.
    @pl.when(i == last)
    def _finalize():
        counts = y_out[...]  # [1, q_pad]
        qi = jax.lax.broadcasted_iota(jnp.float32, (1, q_pad), 1)
        t_fire = jnp.minimum(counts, t_max)
        t_fire = jnp.where(qi < q_live, t_fire, t_max)  # pad neurons silent
        y = _kernel_wta(t_fire, qi, t_max, wta_k=wta_k, t_window=t_window)
        y_out[...] = y
        w_out[0] = _kernel_stdp(
            w, t_ref[...].T, y, qi, t_max, q_live,
            mu_capture, mu_backoff, mu_search,
            w_max=w_max, stabilize=stabilize,
        )

    @pl.when(i != last)
    def _carry():
        w_out[0] = w


def fused_step_pallas_padded(
    w: jnp.ndarray,
    t_in: jnp.ndarray,
    operands: jnp.ndarray,
    *,
    t_window: int,
    w_max: int,
    wta_k: int,
    stabilize: bool,
    t_blk: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One fused Pallas step for a whole padded design batch.

    Args:
      w: [D, p_pad, q_pad] resident weights (pad rows/cols zero).
      t_in: [D, p_pad] f32 volley, one per design; any time >= that design's
        runtime ``t_max`` operand is silent (padding synapses included).
      operands: [D, N_OPERANDS] f32 runtime design operands
        (``design_operands``) — lives in SMEM, read per grid step.
      t_window: static evaluation length of the envelope (>= every design's
        ``t_max``); padded up to a ``t_blk`` multiple.
      interpret: run under the Pallas interpreter — pass the value from
        ``repro.core.backend.pallas_interpret()``; do not hardcode.

    Returns:
      (w_new [D, p_pad, q_pad], y [D, q_pad] post-WTA winner times, f32).
    """
    d, p_pad, q_pad = w.shape
    t_pad = _pad_to(t_window, t_blk)
    kern = functools.partial(
        _fused_kernel,
        t_blk=t_blk,
        t_window=t_pad,
        n_planes=w_max + 1,
        wta_k=wta_k,
        w_max=w_max,
        stabilize=stabilize,
    )
    w_new, y = pl.pallas_call(
        kern,
        grid=(d, t_pad // t_blk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, p_pad), lambda di, i: (di, 0)),
            pl.BlockSpec((1, p_pad, q_pad), lambda di, i: (di, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, p_pad, q_pad), lambda di, i: (di, 0, 0)),
            pl.BlockSpec((1, q_pad), lambda di, i: (di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, p_pad, q_pad), jnp.float32),
            jax.ShapeDtypeStruct((d, q_pad), jnp.float32),
        ],
        interpret=interpret,
    )(operands, t_in, w)
    return w_new, y


def _fused_block_kernel(
    scal_ref,  # [D, N_OPERANDS] f32 SMEM runtime design operands
    nv_ref,  # [1] i32 SMEM      valid volleys in this block (tail masking)
    t_ref,  # [1, v_blk, p_pad]  f32 volley block (silent >= design t_max)
    w_ref,  # [1, p_pad, q_pad]  f32 resident weights
    w_out,  # [1, p_pad, q_pad]  f32 updated weights
    *,
    v_blk: int,
    t_blk: int,
    t_window: int,
    n_planes: int,
    wta_k: int,
    w_max: int,
    stabilize: bool,
):
    """Volley-blocked fused body: fire + k-WTA + STDP x ``v_blk`` volleys.

    Grid = (designs,).  ONE kernel invocation advances a whole volley block:
    the weights live in VMEM for the entire block, and the in-kernel
    ``fori_loop`` folds the block's volleys *sequentially* — volley i fires
    against the weights volley i-1 wrote, exactly the online rule of the
    per-volley kernel (``_fused_kernel``), with kernel launch, HBM weight
    round-trips and plane rebuild setup amortized over ``v_blk`` updates.
    Time blocks are an inner ``fori_loop`` here (they were the grid's inner
    axis in the per-volley kernel); everything per-design still arrives as
    runtime SMEM operands against the one static envelope.  Volleys at or
    past the runtime valid count (the silent-padded block tail) fold
    nothing.
    """
    _, p_pad, q_pad = w_ref.shape
    d = pl.program_id(0)
    nv = nv_ref[0]

    threshold = scal_ref[d, 0]
    t_max = scal_ref[d, 1]
    q_live = scal_ref[d, 2]
    mu_capture = scal_ref[d, 3]
    mu_backoff = scal_ref[d, 4]
    mu_search = scal_ref[d, 5]

    t_all = t_ref[0]  # [v_blk, p_pad] resident volley block
    qi = jax.lax.broadcasted_iota(jnp.float32, (1, q_pad), 1)
    n_tb = t_window // t_blk

    def volley(vi, w):
        ti = jax.lax.dynamic_slice_in_dim(t_all, vi, 1, axis=0)  # [1, p_pad]
        ti_col = ti.T  # [p_pad, 1] input times down the sublanes
        wi = jnp.round(jnp.clip(w, 0.0, float(w_max)))  # integer fire grid

        def time_block(bi, counts):
            return counts + _kernel_fire_counts(
                wi, ti_col, (bi * t_blk).astype(jnp.float32),
                threshold, t_max, t_blk=t_blk, n_planes=n_planes,
            )

        counts = jax.lax.fori_loop(
            0, n_tb, time_block, jnp.zeros((1, q_pad), jnp.float32)
        )
        t_fire = jnp.minimum(counts, t_max)
        t_fire = jnp.where(qi < q_live, t_fire, t_max)
        y = _kernel_wta(t_fire, qi, t_max, wta_k=wta_k, t_window=t_window)
        w_new = _kernel_stdp(
            w, ti_col, y, qi, t_max, q_live,
            mu_capture, mu_backoff, mu_search,
            w_max=w_max, stabilize=stabilize,
        )
        return jnp.where(vi < nv, w_new, w)  # tail volleys fold nothing

    w_out[0] = jax.lax.fori_loop(0, v_blk, volley, w_ref[0])


def fused_block_pallas_padded(
    w: jnp.ndarray,
    t_in: jnp.ndarray,
    operands: jnp.ndarray,
    n_valid: jnp.ndarray | None = None,
    *,
    t_window: int,
    w_max: int,
    wta_k: int,
    stabilize: bool,
    v_blk: int,
    t_blk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """One volley-blocked fused Pallas step for a whole padded design batch.

    Args:
      w: [D, p_pad, q_pad] resident weights (pad rows/cols zero).
      t_in: [D, v_blk, p_pad] f32 volley block per design; any time >= that
        design's runtime ``t_max`` operand is silent (padding synapses and
        block-tail volleys included).
      operands: [D, N_OPERANDS] f32 runtime design operands
        (``design_operands``).
      n_valid: [1] i32 count of live volleys in the block (None = all
        ``v_blk``); volleys at or past it fold nothing (tail masking).
      interpret: run under the Pallas interpreter — pass the value from
        ``repro.core.backend.pallas_interpret()``; do not hardcode.

    Returns:
      w_new [D, p_pad, q_pad] — the weights after the block's ``v_blk``
      sequential online-STDP updates.
    """
    d, p_pad, q_pad = w.shape
    t_pad = _pad_to(t_window, t_blk)
    if n_valid is None:
        n_valid = jnp.full((1,), v_blk, TIME_DTYPE)
    kern = functools.partial(
        _fused_block_kernel,
        v_blk=v_blk,
        t_blk=t_blk,
        t_window=t_pad,
        n_planes=w_max + 1,
        wta_k=wta_k,
        w_max=w_max,
        stabilize=stabilize,
    )
    return pl.pallas_call(
        kern,
        grid=(d,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, v_blk, p_pad), lambda di: (di, 0, 0)),
            pl.BlockSpec((1, p_pad, q_pad), lambda di: (di, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, p_pad, q_pad), lambda di: (di, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, p_pad, q_pad), jnp.float32),
        interpret=interpret,
    )(operands, n_valid.astype(TIME_DTYPE), t_in, w)


def fused_step_pallas(
    w_pad: jnp.ndarray,
    t_in_pad: jnp.ndarray,
    cfg: ColumnConfig,
    t_blk: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One fused Pallas column step on pre-padded single-column operands.

    Thin D=1 wrapper over ``fused_step_pallas_padded`` — the config's
    threshold / window / q / mus become runtime operands of the same kernel
    that serves the padded design batch.

    Args:
      w_pad: [p_pad, q_pad] resident weights (pad rows/cols zero).
      t_in_pad: [1, p_pad] volley (padding/silent >= cfg.t_max).
      interpret: run under the Pallas interpreter — pass the value from
        ``repro.core.backend.pallas_interpret()``; do not hardcode.

    Returns:
      (w_new [p_pad, q_pad], y [1, q_pad] post-WTA winner times, float).
    """
    operands = design_operands(
        jnp.full((1,), cfg.neuron.threshold, jnp.float32),
        jnp.full((1,), cfg.t_max, jnp.float32),
        jnp.full((1,), cfg.q, jnp.float32),
        cfg.stdp.mu_capture,
        cfg.stdp.mu_backoff,
        cfg.stdp.mu_search,
    )
    w_new, y = fused_step_pallas_padded(
        w_pad[None], t_in_pad, operands,
        t_window=cfg.t_max, w_max=cfg.neuron.w_max, wta_k=cfg.wta.k,
        stabilize=cfg.stdp.stabilizer == "half",
        t_blk=t_blk, interpret=interpret,
    )
    return w_new[0], y


# ------------------------------------------------------------- fused fit
@functools.partial(
    jax.jit,
    static_argnames=("cfg", "epochs", "lowering", "trace", "t_blk"),
    donate_argnums=(0,),
)
def _fused_fit_scan(
    w: jnp.ndarray,
    xs: jnp.ndarray,
    cfg: ColumnConfig,
    epochs: int,
    lowering: str,
    trace: bool,
    t_blk: int = 128,
):
    """One compiled program for the whole fit: scan(epochs) o scan(volleys).

    ``w`` is donated — the weight buffer is updated in place across the
    entire training run instead of round-tripping per volley.
    """
    if lowering == "reference":

        def volley(wc, xt):
            # integer_fire mirrors the Pallas lowering (planes need the
            # hardware integer grid) so results agree across lowerings.
            w2, y = fused_step_ref(
                wc, xt, cfg.neuron.threshold, cfg.t_max, cfg.neuron.w_max,
                cfg.wta.k, cfg.stdp.mu_capture, cfg.stdp.mu_backoff,
                cfg.stdp.mu_search, cfg.stdp.stabilizer == "half",
                response=cfg.neuron.response, integer_fire=True,
            )
            return w2, (y if trace else None)

    else:

        def volley(wc, xt):
            w2, y = fused_step_pallas(
                wc, xt[None], cfg, t_blk=t_blk,
                interpret=lowering == "interpret",
            )
            yq = y[0, : cfg.q].astype(TIME_DTYPE)
            return w2, (yq if trace else None)

    def epoch(wc, _):
        return jax.lax.scan(volley, wc, xs)

    w, ys = jax.lax.scan(epoch, w, None, length=epochs)
    return w, ys


# ----------------------------------------------------- padded envelope scan
@functools.partial(
    jax.jit,
    static_argnames=(
        "t_window", "w_max", "wta_k", "stabilize", "response", "epochs",
        "lowering", "t_blk", "v_blk", "plan",
    ),
    donate_argnums=(0,),
)
def fit_scan_padded(
    w,  # [D, p_pad, q_pad]
    xs,  # [N, D, p_pad] volleys (scan axis leading; padding silent >= t_window)
    thresholds,  # [D]
    t_maxes,  # [D]
    q_actives,  # [D]
    t_window: int,
    w_max: int,
    wta_k: int,
    mu_capture: float,
    mu_backoff: float,
    mu_search: float,
    stabilize: bool,
    response: str,
    epochs: int,
    lowering: str = "reference",
    t_blk: int | None = None,
    v_blk: int | None = None,
    plan=None,
):
    """All designs x all epochs x all volleys in ONE compiled program.

    The padding-envelope contract: every member design is padded into a
    shared (p_pad, q_pad, t_window) envelope, its per-design threshold /
    effective window / live-neuron count / STDP mus become *traced* scalars
    (runtime SMEM operands under the kernel lowerings, ``vmap``-ed operands
    under the reference lowering), and the fused column step runs over the
    leading design axis.  Callers with the same envelope shapes and static
    hyper-parameters share one compiled trace — this is what lets a
    heterogeneous design sweep (``simulator.cluster_time_series_many``) and
    heterogeneous network layers (``network.fit_greedy``) reuse each
    other's compilations: ONE compilation per envelope shape, never per
    design.

    The scan advances in volley blocks of ``v_blk``: each outer scan step
    folds ``v_blk`` sequential online-STDP volleys in one fused body — one
    kernel invocation with the weights VMEM-resident for the whole block
    (kernel lowerings), one statically-unrolled jnp block sharing
    precomputed input ramps (reference).  Exact online semantics either
    way: results are bit-identical across every ``v_blk`` (enforced by
    ``tests/test_blocked_scan.py``); blocking is a throughput knob, never a
    semantic one.  Tail volleys of the last block are silent-padded and
    masked out of the weight fold by a per-block valid count — exact
    no-ops unconditionally.

    Args:
      lowering: 'mosaic' (TPU Mosaic kernel), 'interpret' (Pallas
        interpreter, validation only) or 'reference' (pure jnp).  Callers
        should pass ``repro.core.backend.padded_lowering(response)`` rather
        than hardcoding a host assumption; the kernel lowerings support RNL
        only (``check_fusable``).  All lowerings are bit-identical on
        integer weight grids.
      t_blk: kernel time-block length (kernel lowerings only); None takes
        the plan's choice (or the lane-aligned 128 default).
      v_blk: volleys advanced per scan step; None takes the plan's
        choice, falling back to the central constants policy
        ``repro.core.backend.volley_block(lowering, n, d=D)`` —
        envelope-aware, so small-D batches get a slimmer unrolled
        reference block (cheap traces) than large-D ones.
      plan: an optional ``repro.roofline.costmodel.ExecutionPlan`` (a
        frozen, hashable static) supplying defaults for unset
        ``v_blk``/``t_blk``.  Callers that dispatch through
        ``backend.fit_padded`` never need it (the backend resolves the
        plan to concrete ints before keying its AOT cache); it exists for
        direct jit-path callers — notably the sharded bucketed sweep,
        where GSPMD needs the jit trace.  A plan changes blocking only,
        never results (value-equal plans share one trace).

    This entry point is deterministic — expected-mode STDP and index
    tie-break WTA need no PRNG key (that is part of the fused contract;
    stochastic configs take the solver path via ``backend.resolve``).

    ``w`` is donated: the weight buffer stays resident across the whole
    epochs x volleys scan.
    """
    if lowering not in LOWERINGS:
        raise ValueError(f"unknown lowering: {lowering!r}")
    if xs.shape[0] == 0:
        # an empty stream is a caller bug: volley_block would degenerate to
        # a zero-length blocked scan — refuse loudly instead of compiling it
        raise ValueError(
            "fit_scan_padded needs at least one volley (got an empty "
            "stream, N=0)"
        )
    if epochs == 0:
        # zero training passes are well-defined: the weights are returned
        # unchanged (trivially, without building the blocked scan)
        return w
    if plan is not None:
        if v_blk is None:
            v_blk = plan.v_blk
        if t_blk is None:
            t_blk = plan.t_blk
    if t_blk is None:
        t_blk = 128
    if v_blk is None:
        from repro.core import backend  # late: backend imports this module

        v_blk = backend.volley_block(lowering, xs.shape[0], d=w.shape[0])
    if lowering != "reference":
        if response not in fire_responses(lowering):
            raise ValueError(
                f"the padded kernel lowering supports response "
                f"{fire_responses(lowering)}, got {response!r}; use "
                "lowering='reference'"
            )
        return _fit_scan_padded_kernel(
            w, xs, thresholds, t_maxes, q_actives,
            t_window, w_max, wta_k, mu_capture, mu_backoff, mu_search,
            stabilize, epochs, lowering, t_blk, v_blk,
        )

    xsb, n_valid = _pad_volley_blocks(xs, v_blk, t_window)  # [S, v_blk, D, p]
    kw = dict(
        t_window=t_window, w_max=w_max, wta_k=wta_k, mu_capture=mu_capture,
        mu_backoff=mu_backoff, mu_search=mu_search, stabilize=stabilize,
        response=response,
    )

    def block(wc, inp):  # wc: [D, p, q]; xt_blk: [v_blk, D, p]
        xt_blk, nv = inp
        # the input-side step transient of the whole block at once — the
        # reference analogue of the kernel's VMEM-resident volley block:
        # only the cumulative weight planes, one GEMM and the plane delays
        # stay inside the sequential (unrolled) loop
        s = _masked_steps(
            xt_blk, t_maxes[None, :, None], t_window
        )  # [v_blk, D, p, T]
        for i in range(v_blk):  # static unroll: one fused XLA body
            valid = i < nv  # tail volleys fold nothing
            wc = jax.vmap(
                lambda wd, sd, xd, th, tm, qa: _block_step_ref(
                    wd, sd, xd, th, tm, qa, valid=valid, **kw
                )
            )(wc, s[i], xt_blk[i], thresholds, t_maxes, q_actives)
        return wc, None

    def epoch(wc, _):
        return jax.lax.scan(block, wc, (xsb, n_valid))

    w, _ = jax.lax.scan(epoch, w, None, length=epochs)
    return w


def _fit_scan_padded_kernel(
    w, xs, thresholds, t_maxes, q_actives,
    t_window, w_max, wta_k, mu_capture, mu_backoff, mu_search,
    stabilize, epochs, lowering, t_blk, v_blk,
):
    """Kernel-lowering body of ``fit_scan_padded`` (called inside its jit).

    Re-pads the caller's envelope up to the Mosaic tile grid (p to a LANE
    multiple, q to a SUBLANE multiple, t_window to a ``t_blk`` multiple),
    packs the per-design scalars into the runtime SMEM operand array once,
    and scans ``fused_block_pallas_padded`` over epochs x volley blocks —
    each scan step is ONE kernel invocation advancing ``v_blk`` volleys.
    Alignment padding is masked exactly like caller padding: extra synapses
    are silent, extra neurons sit above every ``q_active``.
    """
    d, p_env, q_env = w.shape
    p_pad = _pad_to(p_env, LANE)
    q_pad = _pad_to(q_env, SUBLANE)
    operands = design_operands(
        thresholds, t_maxes, q_actives, mu_capture, mu_backoff, mu_search
    )
    w_k = (
        jnp.zeros((d, p_pad, q_pad), jnp.float32)
        .at[:, :p_env, :q_env]
        .set(w.astype(jnp.float32))
    )
    # alignment rows (and block-tail volleys below) reuse the caller's
    # sentinel convention: any time >= t_window is silent for all designs
    xs_k = _pad_volleys_silent(xs, p_pad, t_window)
    xsb, n_valid = _pad_volley_blocks(xs_k, v_blk, float(t_window))
    xsb = jnp.swapaxes(xsb, 1, 2)  # [S, D, v_blk, p_pad]: design axis leads

    def block(wc, inp):  # wc: [D, p_pad, q_pad]; xt: [D, v_blk, p_pad]
        xt, nv = inp
        w2 = fused_block_pallas_padded(
            wc, xt, operands, nv.reshape((1,)),
            t_window=t_window, w_max=w_max, wta_k=wta_k,
            stabilize=stabilize, v_blk=v_blk, t_blk=t_blk,
            interpret=lowering == "interpret",
        )
        return w2, None

    def epoch(wc, _):
        return jax.lax.scan(block, wc, (xsb, n_valid))

    w_k, _ = jax.lax.scan(epoch, w_k, None, length=epochs)
    return w_k[:, :p_env, :q_env]


def _fire_block_kernel(
    scal_ref,  # [D, N_OPERANDS] f32 SMEM runtime design operands
    t_ref,  # [1, 1, p_pad]      f32 one volley (silent >= design t_max)
    w_ref,  # [1, p_pad, q_pad]  f32 frozen weights
    y_out,  # [1, 1, q_pad]      f32 counts accumulator -> firing times
    *,
    t_blk: int,
    n_planes: int,
    w_max: int,
):
    """Batched fire body, grid = (designs, volleys, time blocks).

    Inference has no sequential dependency, so instead of scanning volleys
    on the host the whole batch rides the kernel grid: ONE ``pallas_call``
    fires every volley of every design (the fire half of ``_fused_kernel``
    with a volley grid axis and no WTA/STDP — assignment only needs raw
    per-neuron firing times).
    """
    _, p_pad, q_pad = w_ref.shape
    d = pl.program_id(0)
    i = pl.program_id(2)
    last = pl.num_programs(2) - 1

    threshold = scal_ref[d, 0]
    t_max = scal_ref[d, 1]
    q_live = scal_ref[d, 2]

    @pl.when(i == 0)
    def _init():
        y_out[...] = jnp.zeros_like(y_out)

    wi = jnp.round(jnp.clip(w_ref[0], 0.0, float(w_max)))
    y_out[0] += _kernel_fire_counts(
        wi, t_ref[0].T, (i * t_blk).astype(jnp.float32), threshold, t_max,
        t_blk=t_blk, n_planes=n_planes,
    )

    @pl.when(i == last)
    def _finalize():
        qi = jax.lax.broadcasted_iota(jnp.float32, (1, q_pad), 1)
        t_fire = jnp.minimum(y_out[0], t_max)
        y_out[0] = jnp.where(qi < q_live, t_fire, t_max)


def _ids_from_times(t_fire, t_maxes, q_actives):
    """Firing times [D, N, q] -> cluster ids [D, N].

    The id of a volley is the earliest-firing neuron's index (index
    tie-break — and therefore independent of ``wta_k``: the k-WTA keeps the
    global minimum for every k >= 1), or the design's live-neuron count
    when no neuron spikes (the 'unclustered' bucket)."""
    tm = t_maxes.astype(jnp.float32)[:, None]
    tf = t_fire.astype(jnp.float32)
    spiked = (tf < tm[..., None]).any(axis=-1)
    idx = jnp.argmin(tf, axis=-1)
    return jnp.where(spiked, idx, q_actives[:, None]).astype(TIME_DTYPE)


@functools.partial(
    jax.jit,
    static_argnames=("t_window", "wta_k", "response", "lowering", "t_blk",
                     "v_blk", "w_max", "plan"),
)
def assign_padded(
    w, xs, thresholds, t_maxes, q_actives,
    t_window: int, wta_k: int, response: str,
    lowering: str = "reference", t_blk: int | None = None,
    v_blk: int | None = None, w_max: int | None = None,
    plan=None,
):
    """Cluster ids for every padded design: [N, D, p_pad] -> [D, N].

    Same envelope contract as ``fit_scan_padded``, but embarrassingly
    parallel: no volley ever depends on another, so volleys are *batched*
    rather than scanned.  Under the kernel lowerings the whole stream rides
    the kernel grid — ONE ``pallas_call`` with grid (designs, volleys, time
    blocks), no host scan at all (``w_max`` is required: the kernel fires
    on the integer weight grid, so auto-selecting it is only a pure
    lowering choice when the weights are on the grid — see
    ``backend.assign_lowering``).  Under the reference lowering volleys are
    fired in vmapped blocks of ``v_blk`` (a ``lax.map`` over blocks bounds
    the dense transient instead of materializing it for the full stream),
    keeping the established float-weight fire semantics bit-for-bit.

    The id of a volley is the winner neuron index, or the design's
    live-neuron count ``q_active`` when no neuron spikes (the 'unclustered'
    bucket); it is independent of ``wta_k`` (the k-WTA keeps the global
    minimum for every k >= 1).

    ``plan`` carries the same optional ``ExecutionPlan`` defaults as
    ``fit_scan_padded`` (unset ``v_blk``/``t_blk`` only; blocking, never
    semantics).
    """
    if lowering not in LOWERINGS:
        raise ValueError(f"unknown lowering: {lowering!r}")
    if xs.shape[0] == 0:
        # same up-front guard as fit_scan_padded: an empty stream has no
        # volleys to assign, and the kernel grid would degenerate
        raise ValueError(
            "assign_padded needs at least one volley (got an empty "
            "stream, N=0)"
        )
    if plan is not None:
        if v_blk is None:
            v_blk = plan.v_blk
        if t_blk is None:
            t_blk = plan.t_blk
    if t_blk is None:
        t_blk = 128
    if v_blk is None:
        from repro.core import backend  # late: backend imports this module

        v_blk = backend.volley_block(lowering, xs.shape[0])
    n = xs.shape[0]
    if lowering != "reference":
        if response not in fire_responses(lowering):
            raise ValueError(
                f"the padded kernel lowering supports response "
                f"{fire_responses(lowering)}, got {response!r}; use "
                "lowering='reference'"
            )
        if w_max is None:
            raise ValueError(
                "the kernel assign lowering needs w_max (integer-grid "
                "weight planes)"
            )
        d, p_env, q_env = w.shape
        p_pad = _pad_to(p_env, LANE)
        q_pad = _pad_to(q_env, SUBLANE)
        t_pad = _pad_to(t_window, t_blk)
        operands = design_operands(
            thresholds, t_maxes, q_actives, 0.0, 0.0, 0.0
        )
        w_k = (
            jnp.zeros((d, p_pad, q_pad), jnp.float32)
            .at[:, :p_env, :q_env]
            .set(w.astype(jnp.float32))
        )
        xs_k = jnp.swapaxes(
            _pad_volleys_silent(xs, p_pad, t_window), 0, 1
        )  # [D, N, p_pad]
        kern = functools.partial(
            _fire_block_kernel,
            t_blk=t_blk, n_planes=w_max + 1, w_max=w_max,
        )
        t_fire = pl.pallas_call(
            kern,
            grid=(d, n, t_pad // t_blk),
            in_specs=[
                pl.BlockSpec(memory_space=pltpu.SMEM),
                pl.BlockSpec((1, 1, p_pad), lambda di, vi, ti: (di, vi, 0)),
                pl.BlockSpec(
                    (1, p_pad, q_pad), lambda di, vi, ti: (di, 0, 0)
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, q_pad), lambda di, vi, ti: (di, vi, 0)
            ),
            out_shape=jax.ShapeDtypeStruct((d, n, q_pad), jnp.float32),
            interpret=lowering == "interpret",
        )(operands, xs_k, w_k)
        return _ids_from_times(t_fire[:, :, :q_env], t_maxes, q_actives)

    qi = jnp.arange(w.shape[2], dtype=TIME_DTYPE)
    # tail rows are sliced away below, so the valid counts are unused here
    xsb, _ = _pad_volley_blocks(xs, v_blk, t_window)  # [S, v_blk, D, p]

    def block(xt_blk):  # [v_blk, D, p] -> [v_blk, D, q]
        def one(wd, xd, th, tm, qa):
            # float-weight dense fire: the established assignment
            # arithmetic, volley for volley (only the batching is new)
            t = fire_dense_ref(
                wd, xd, th, t_window, t_max=tm, response=response
            )
            return jnp.where(qi < qa, t, tm)

        return jax.vmap(  # volleys in the block
            jax.vmap(one, in_axes=(0, 0, 0, 0, 0)),  # designs
            in_axes=(None, 0, None, None, None),
        )(w, xt_blk, thresholds, t_maxes, q_actives)

    t_all = jax.lax.map(block, xsb)  # [S, v_blk, D, q]
    t_all = t_all.reshape((-1,) + t_all.shape[2:])[:n]  # [N, D, q]
    return _ids_from_times(
        jnp.moveaxis(t_all, 0, 1), t_maxes, q_actives
    )


# -------------------------------------------------- AOT precompilation
# ``jit(...).lower().compile()`` entry points for the padded scans: an
# envelope is fully described by shapes + statics, so its executable can
# be built ahead of the first real operands — a service can pre-compile
# its envelope set at startup, and ``backend.fit_padded`` /
# ``backend.assign_padded`` cache these per envelope so equal-envelope
# buckets share ONE executable across sweep calls and (with
# ``backend.compile_cache``) across processes.  The executables are the
# very programs the jit path would build: bit-identical results, same
# donation (``tests/test_aot_cache.py``).

def _fit_scan_padded_specs(d: int, p_pad: int, q_pad: int, n_volleys: int):
    """(args, mu kwargs) abstract specs mirroring one fit call exactly."""
    f32 = jnp.float32
    args = (
        jax.ShapeDtypeStruct((d, p_pad, q_pad), f32),          # w
        jax.ShapeDtypeStruct((n_volleys, d, p_pad), TIME_DTYPE),  # xs
        jax.ShapeDtypeStruct((d,), f32),                       # thresholds
        jax.ShapeDtypeStruct((d,), TIME_DTYPE),                # t_maxes
        jax.ShapeDtypeStruct((d,), TIME_DTYPE),                # q_actives
    )
    mus = {
        name: jax.ShapeDtypeStruct((), f32)
        for name in ("mu_capture", "mu_backoff", "mu_search")
    }
    return args, mus


def precompile_fit_scan_padded(
    d: int,
    p_pad: int,
    q_pad: int,
    n_volleys: int,
    *,
    t_window: int,
    w_max: int,
    wta_k: int,
    stabilize: bool,
    response: str,
    epochs: int,
    lowering: str = "reference",
    t_blk: int = 128,
    v_blk: int | None = None,
):
    """AOT-compile ``fit_scan_padded`` for one envelope; no operands needed.

    Returns a ``jax.stages.Compiled`` executable.  Call it exactly like
    the dynamic half of the jitted entry point — five positional arrays
    ``(w, xs, thresholds, t_maxes, q_actives)`` matching the spec shapes
    plus the three STDP mus by keyword as f32 scalars (the call's
    args/kwargs pytree must mirror the lowering's) — and it behaves
    bit-for-bit like the jit path, including donating ``w``.
    """
    if v_blk is None:
        from repro.core import backend  # late: backend imports this module

        v_blk = backend.volley_block(lowering, n_volleys, d=d)
    args, mus = _fit_scan_padded_specs(d, p_pad, q_pad, n_volleys)
    return fit_scan_padded.lower(
        *args,
        t_window=t_window, w_max=w_max, wta_k=wta_k, **mus,
        stabilize=stabilize, response=response, epochs=epochs,
        lowering=lowering, t_blk=t_blk, v_blk=v_blk,
    ).compile()


def precompile_assign_padded(
    d: int,
    p_pad: int,
    q_pad: int,
    n_volleys: int,
    *,
    t_window: int,
    wta_k: int,
    response: str,
    lowering: str = "reference",
    t_blk: int = 128,
    v_blk: int | None = None,
    w_max: int | None = None,
):
    """AOT-compile ``assign_padded`` for one envelope.

    Same contract as ``precompile_fit_scan_padded``: the returned
    ``Compiled`` takes the five positional arrays and is bit-identical to
    the jitted assignment (nothing donated).
    """
    if v_blk is None:
        from repro.core import backend  # late: backend imports this module

        v_blk = backend.volley_block(lowering, n_volleys)
    args, _ = _fit_scan_padded_specs(d, p_pad, q_pad, n_volleys)
    return assign_padded.lower(
        *args,
        t_window=t_window, wta_k=wta_k, response=response,
        lowering=lowering, t_blk=t_blk, v_blk=v_blk, w_max=w_max,
    ).compile()


def fit_fused(
    params: dict,
    x: jnp.ndarray,
    cfg: ColumnConfig,
    epochs: int = 8,
    lowering: str = "reference",
    trace: bool = False,
    t_blk: int = 128,
) -> tuple[dict, jnp.ndarray | None]:
    """Online STDP over [N, p] volleys as ONE jitted, donated scan.

    Weight padding / plane setup happens here, once per fit — never per
    volley.  Returns (params, ys) where ys is [epochs, N, q] winner times
    when ``trace`` else None.
    """
    check_fusable(cfg, lowering)
    # copy: the scan donates its weight buffer; the caller keeps params.
    w = jnp.array(params["w"], jnp.float32, copy=True)
    if lowering == "reference":
        w_new, ys = _fused_fit_scan(w, x, cfg, epochs, lowering, trace)
        return {"w": w_new}, ys

    p_pad = _pad_to(cfg.p, LANE)
    q_pad = _pad_to(cfg.q, SUBLANE)
    t_pad = _pad_to(cfg.t_max, t_blk)
    w_pad = jnp.zeros((p_pad, q_pad), jnp.float32).at[: cfg.p, : cfg.q].set(w)
    xs = _pad_volleys_silent(x, p_pad, 2.0 * t_pad)
    xs = jnp.where(xs >= cfg.t_max, 2.0 * t_pad, xs)
    w_new, ys = _fused_fit_scan(w_pad, xs, cfg, epochs, lowering, trace, t_blk)
    return {"w": w_new[: cfg.p, : cfg.q]}, ys
