"""Fused TNN column training step: RNL fire + k-WTA + expected STDP.

This is the hot path of the paper's "rapid application exploration" loop:
online STDP folds one volley at a time into the weights, so training is a
``lax.scan`` over epochs x volleys whose body is ONE fused column step.  The
step exists in two lowerings behind the same semantics:

* ``fused_step_pallas_padded`` — a single ``pl.pallas_call`` over a grid of
  (designs, time blocks): the RNL body potential is evaluated via the
  one-hot weight-plane decomposition (MXU matmuls, planes built *in-kernel*
  from the VMEM-resident weights — ``make_weight_planes`` never runs per
  volley), firing times fall out as sub-threshold cycle counts, the k-WTA
  priority encoder and the per-synapse expected-STDP update run in the same
  kernel invocation, and the updated weights are written back.  Per-design
  scalars (threshold, effective ``t_max``, live-neuron count, STDP mus)
  enter as a *runtime* SMEM operand (``design_operands``) masked against a
  single static envelope — one compiled kernel serves a whole heterogeneous
  design batch, and changing a threshold never retraces.  Weights stay
  padded/resident across the whole scan; padding happens once per ``fit``.
* ``fused_step_ref`` — the pure-jnp lowering of the same algebra (dense
  sub-threshold count over the time window).  Exact for RNL/SNL: V(t) is
  nondecreasing, so the count of sub-threshold integer cycles *is* the first
  crossing — bit-identical to ``mode='cycle'``.  This is what the central
  dispatch (``repro.core.backend``) lowers to off-TPU, where the Pallas
  interpreter would serialize 100x slower; the interpreter remains available
  for validation via ``lowering='interpret'``.

Scope (enforced by ``check_fusable``): ``response in ('rnl', 'snl')``
(``'rnl'`` only for the Pallas lowering), expected-mode STDP, index
tie-break WTA.  Other configs take the generic per-solver scan in
``repro.core.backend``.

The per-design quantities (threshold, t_max, active q, STDP mus) are traced
values in *both* lowerings — the reference ``vmap``s over them, the kernel
reads them from SMEM — so a stacked sweep of heterogeneous designs
(``simulator.cluster_time_series_many``) or network layers
(``network.fit_greedy``) compiles once per envelope shape, never per
design.  The full kernel contract is documented in ``docs/kernels.md``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.types import ColumnConfig, TIME_DTYPE
from repro.kernels import ref

LANE = 128
SUBLANE = 8

LOWERINGS = ("mosaic", "interpret", "reference")

# Columns of the runtime design-operand array (see ``design_operands``):
# one row of per-design scalars the kernel reads from SMEM at run time.
OPERAND_COLS = (
    "threshold", "t_max", "q_active", "mu_capture", "mu_backoff", "mu_search"
)
N_OPERANDS = len(OPERAND_COLS)


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_volleys_silent(x: jnp.ndarray, p_pad: int, sentinel: float):
    """Widen volleys [..., p] -> [..., p_pad] f32, padding with ``sentinel``.

    The kernel's silence contract is ``time >= design t_max`` (see
    docs/kernels.md); any sentinel satisfying that for every design in the
    batch is equivalent — this helper is the one place the fill happens.
    """
    xs = jnp.full(x.shape[:-1] + (p_pad,), float(sentinel), jnp.float32)
    return xs.at[..., : x.shape[-1]].set(x.astype(jnp.float32))


def fire_responses(lowering: str) -> tuple[str, ...]:
    """Response functions the fused fire supports under a given lowering
    (the Pallas kernel implements the RNL plane decomposition only)."""
    return ("rnl", "snl") if lowering == "reference" else ("rnl",)


def check_fusable(cfg: ColumnConfig, lowering: str) -> None:
    """Raise ValueError if cfg falls outside the fused step's contract."""
    if lowering not in LOWERINGS:
        raise ValueError(f"unknown lowering: {lowering!r}")
    ok_resp = fire_responses(lowering)
    if cfg.neuron.response not in ok_resp:
        raise ValueError(
            f"fused step ({lowering}) supports response {ok_resp}, got "
            f"{cfg.neuron.response!r}"
        )
    if cfg.stdp.mode != "expected":
        raise ValueError("fused step supports expected-mode STDP only")
    if cfg.wta.tie_break != "index":
        raise ValueError("fused step supports index tie-break WTA only")


# --------------------------------------------------------------- reference
def fire_dense_ref(
    w: jnp.ndarray,
    t_in: jnp.ndarray,
    threshold,
    t_window: int,
    t_max=None,
    response: str = "rnl",
) -> jnp.ndarray:
    """Firing times by dense sub-threshold cycle count.  [p],[p,q] -> [q].

    ``t_window`` is the static evaluation length; ``t_max`` (traced OK) is
    the effective window — spike times >= t_max are silent and crossings at
    or past t_max report t_max.  Exact for RNL/SNL (V nondecreasing).
    """
    if t_max is None:
        t_max = t_window
    tv = jnp.arange(t_window, dtype=jnp.float32)  # [T]
    ti = t_in.astype(jnp.float32)
    live = ti < t_max  # [p]
    if response == "rnl":
        a = jax.nn.relu(tv[None, :] - ti[:, None])  # [p, T]
        a = jnp.where(live[:, None], a, 0.0)
        contrib = jnp.minimum(a[:, None, :], w[:, :, None])  # [p, q, T]
    else:  # snl
        s = (tv[None, :] >= ti[:, None]) & live[:, None]
        contrib = s[:, None, :].astype(w.dtype) * w[:, :, None]
    v = contrib.sum(axis=0)  # [q, T]
    below = (v < threshold) & (tv[None, :] < t_max)
    count = below.sum(axis=-1)
    return jnp.minimum(count, t_max).astype(TIME_DTYPE)


def fused_step_ref(
    w: jnp.ndarray,
    t_in: jnp.ndarray,
    threshold,
    t_window: int,
    w_max: int,
    wta_k: int,
    mu_capture: float,
    mu_backoff: float,
    mu_search: float,
    stabilize: bool,
    t_max=None,
    response: str = "rnl",
    integer_fire: bool = False,
    q_active=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One fused column step, jnp lowering.  Returns (w_new, y).

    Args:
      w: [p, q] resident weights.
      t_in: [p] one input volley.
      threshold / t_max / q_active: traced-friendly per-design scalars
        (q_active masks neurons >= q_active out of WTA and STDP — used by the
        padded multi-design sweep; None means all q are live).
      t_window: static dense evaluation length (>= t_max).
      integer_fire: round weights to the hardware integer grid for the fire
        step (the Pallas lowering always does; planes need w in {0..w_max}).
    """
    if t_max is None:
        t_max = t_window
    w_fire = jnp.round(jnp.clip(w, 0.0, w_max)) if integer_fire else w
    t_fire = fire_dense_ref(w_fire, t_in, threshold, t_window, t_max, response)
    if q_active is not None:
        qi = jnp.arange(w.shape[1], dtype=TIME_DTYPE)
        t_fire = jnp.where(qi < q_active, t_fire, t_max)
    y = ref.wta_ref(t_fire[None], wta_k, t_max)[0]
    w_new = ref.stdp_ref(
        w, t_in, y, mu_capture, mu_backoff, mu_search, w_max, t_max,
        stabilize=stabilize,
    )
    if q_active is not None:
        qi = jnp.arange(w.shape[1], dtype=TIME_DTYPE)
        w_new = jnp.where(qi[None, :] < q_active, w_new, w)
    return w_new, y


# ------------------------------------------------------------ pallas kernel
def design_operands(
    thresholds,
    t_maxes,
    q_actives,
    mu_capture,
    mu_backoff,
    mu_search,
) -> jnp.ndarray:
    """Pack per-design runtime scalars into the kernel's SMEM operand array.

    Returns [D, N_OPERANDS] f32, one row per design, columns ordered as
    ``OPERAND_COLS``.  Every entry is a *runtime* value: the kernel masks
    against them inside one static envelope, so heterogeneous designs share
    a single compiled kernel and changing any of them never retraces.  The
    mus may be Python floats (broadcast across designs) or [D] arrays.
    """
    d = jnp.shape(thresholds)[0]
    cols = (thresholds, t_maxes, q_actives, mu_capture, mu_backoff, mu_search)
    return jnp.stack(
        [
            jnp.broadcast_to(jnp.asarray(c, jnp.float32), (d,))
            for c in cols
        ],
        axis=1,
    )


def _fused_kernel(
    scal_ref,  # [D, N_OPERANDS] f32 SMEM runtime design operands
    t_ref,  # [1, p_pad]         f32 input volley (silent >= design t_max)
    w_ref,  # [1, p_pad, q_pad]  f32 resident weights
    w_out,  # [1, p_pad, q_pad]  f32 updated weights
    y_out,  # [1, q_pad]         f32 counts accumulator -> winner times
    *,
    t_blk: int,
    t_window: int,
    n_planes: int,
    wta_k: int,
    w_max: int,
    stabilize: bool,
):
    """Fused fire + k-WTA + expected-STDP body, grid = (designs, time blocks).

    Static envelope: block shapes, ``t_window`` (padded evaluation length),
    ``n_planes``/``w_max``, ``wta_k`` and the stabilizer flag.  Everything
    per-design — threshold, effective window ``t_max``, live-neuron count
    ``q_active``, STDP mus — is read from ``scal_ref`` at run time and
    masked against the envelope, so one compiled kernel serves a whole
    heterogeneous design batch.
    """
    _, p_pad, q_pad = w_ref.shape
    d = pl.program_id(0)
    i = pl.program_id(1)
    last = pl.num_programs(1) - 1

    threshold = scal_ref[d, 0]
    t_max = scal_ref[d, 1]
    q_live = scal_ref[d, 2]
    mu_capture = scal_ref[d, 3]
    mu_backoff = scal_ref[d, 4]
    mu_search = scal_ref[d, 5]

    @pl.when(i == 0)
    def _init():
        y_out[...] = jnp.zeros_like(y_out)

    # --- fire: accumulate sub-threshold cycle counts for this time block.
    t0 = (i * t_blk).astype(jnp.float32)
    tv = t0 + jax.lax.broadcasted_iota(jnp.float32, (1, t_blk), 1)  # [1, t_blk]
    ti = t_ref[...].T  # [p_pad, 1] input times down the sublanes
    a = jnp.maximum(tv - ti, 0.0)  # [p_pad, t_blk] ramps
    base = jnp.sum(a, axis=0, keepdims=True)  # [1, t_blk]

    w = w_ref[0]
    wi = jnp.round(jnp.clip(w, 0.0, float(w_max)))  # integer fire grid
    acc = jnp.zeros((q_pad, t_blk), jnp.float32)
    for v in range(n_planes):  # static unroll: planes from resident weights
        plane = (wi == float(v)).astype(jnp.float32)  # [p_pad, q_pad]
        av = a if v == 0 else jnp.maximum(a - float(v), 0.0)
        acc = acc + jax.lax.dot_general(
            plane, av, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [q_pad, t_blk]
    vqt = base - acc  # [q_pad, t_blk] body potential
    below = (vqt < threshold) & (tv < t_max)  # mask window padding
    y_out[...] += jnp.sum(below.astype(jnp.float32), axis=1)[None, :]

    # --- WTA + STDP once all time blocks have accumulated.
    @pl.when(i == last)
    def _finalize():
        counts = y_out[...]  # [1, q_pad]
        qi = jax.lax.broadcasted_iota(jnp.float32, (1, q_pad), 1)
        t_fire = jnp.minimum(counts, t_max)
        t_fire = jnp.where(qi < q_live, t_fire, t_max)  # pad neurons silent

        # k-WTA priority encoder: lexicographic (time, index) packed key;
        # keys are unique, so k unrolled min rounds find the k-th smallest.
        # ``big`` only needs to exceed every live key, so the static
        # envelope bound serves all designs.
        big = float((t_window + 1) * q_pad)
        key = t_fire * q_pad + qi
        rem = key
        kth = jnp.float32(0)
        for _ in range(wta_k):
            kth = jnp.min(rem)
            rem = jnp.where(rem <= kth, big, rem)
        win = (key <= kth) & (t_fire < t_max)
        y = jnp.where(win, t_fire, t_max)  # [1, q_pad]
        y_out[...] = y

        # expected STDP on the resident float weights (same algebra as
        # kernels/ref.stdp_ref), padded neurons frozen.
        x = t_ref[...].T  # [p_pad, 1]
        xs = x < t_max
        ys = y < t_max
        if stabilize:
            frac = jnp.clip(w * (1.0 / w_max), 0.0, 1.0)
            eps = 1.0 / (2 * w_max)
            s_plus = (1.0 - frac) + eps
            s_minus = frac + eps
        else:
            s_plus = s_minus = jnp.ones_like(w)
        capture = xs & ys & (x <= y)
        backoff = (xs & ys & (x > y)) | ((~xs) & ys)
        search = xs & (~ys)
        delta = jnp.where(capture, mu_capture * s_plus, 0.0)
        delta = jnp.where(backoff, -mu_backoff * s_minus, delta)
        delta = jnp.where(search, mu_search, delta)
        delta = jnp.where(qi < q_live, delta, 0.0)
        w_out[0] = jnp.clip(w + delta, 0.0, float(w_max))

    @pl.when(i != last)
    def _carry():
        w_out[0] = w


def fused_step_pallas_padded(
    w: jnp.ndarray,
    t_in: jnp.ndarray,
    operands: jnp.ndarray,
    *,
    t_window: int,
    w_max: int,
    wta_k: int,
    stabilize: bool,
    t_blk: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One fused Pallas step for a whole padded design batch.

    Args:
      w: [D, p_pad, q_pad] resident weights (pad rows/cols zero).
      t_in: [D, p_pad] f32 volley, one per design; any time >= that design's
        runtime ``t_max`` operand is silent (padding synapses included).
      operands: [D, N_OPERANDS] f32 runtime design operands
        (``design_operands``) — lives in SMEM, read per grid step.
      t_window: static evaluation length of the envelope (>= every design's
        ``t_max``); padded up to a ``t_blk`` multiple.
      interpret: run under the Pallas interpreter — pass the value from
        ``repro.core.backend.pallas_interpret()``; do not hardcode.

    Returns:
      (w_new [D, p_pad, q_pad], y [D, q_pad] post-WTA winner times, f32).
    """
    d, p_pad, q_pad = w.shape
    t_pad = _pad_to(t_window, t_blk)
    kern = functools.partial(
        _fused_kernel,
        t_blk=t_blk,
        t_window=t_pad,
        n_planes=w_max + 1,
        wta_k=wta_k,
        w_max=w_max,
        stabilize=stabilize,
    )
    w_new, y = pl.pallas_call(
        kern,
        grid=(d, t_pad // t_blk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, p_pad), lambda di, i: (di, 0)),
            pl.BlockSpec((1, p_pad, q_pad), lambda di, i: (di, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, p_pad, q_pad), lambda di, i: (di, 0, 0)),
            pl.BlockSpec((1, q_pad), lambda di, i: (di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, p_pad, q_pad), jnp.float32),
            jax.ShapeDtypeStruct((d, q_pad), jnp.float32),
        ],
        interpret=interpret,
    )(operands, t_in, w)
    return w_new, y


def fused_step_pallas(
    w_pad: jnp.ndarray,
    t_in_pad: jnp.ndarray,
    cfg: ColumnConfig,
    t_blk: int = 128,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One fused Pallas column step on pre-padded single-column operands.

    Thin D=1 wrapper over ``fused_step_pallas_padded`` — the config's
    threshold / window / q / mus become runtime operands of the same kernel
    that serves the padded design batch.

    Args:
      w_pad: [p_pad, q_pad] resident weights (pad rows/cols zero).
      t_in_pad: [1, p_pad] volley (padding/silent >= cfg.t_max).
      interpret: run under the Pallas interpreter — pass the value from
        ``repro.core.backend.pallas_interpret()``; do not hardcode.

    Returns:
      (w_new [p_pad, q_pad], y [1, q_pad] post-WTA winner times, float).
    """
    operands = design_operands(
        jnp.full((1,), cfg.neuron.threshold, jnp.float32),
        jnp.full((1,), cfg.t_max, jnp.float32),
        jnp.full((1,), cfg.q, jnp.float32),
        cfg.stdp.mu_capture,
        cfg.stdp.mu_backoff,
        cfg.stdp.mu_search,
    )
    w_new, y = fused_step_pallas_padded(
        w_pad[None], t_in_pad, operands,
        t_window=cfg.t_max, w_max=cfg.neuron.w_max, wta_k=cfg.wta.k,
        stabilize=cfg.stdp.stabilizer == "half",
        t_blk=t_blk, interpret=interpret,
    )
    return w_new[0], y


# ------------------------------------------------------------- fused fit
@functools.partial(
    jax.jit,
    static_argnames=("cfg", "epochs", "lowering", "trace", "t_blk"),
    donate_argnums=(0,),
)
def _fused_fit_scan(
    w: jnp.ndarray,
    xs: jnp.ndarray,
    cfg: ColumnConfig,
    epochs: int,
    lowering: str,
    trace: bool,
    t_blk: int = 128,
):
    """One compiled program for the whole fit: scan(epochs) o scan(volleys).

    ``w`` is donated — the weight buffer is updated in place across the
    entire training run instead of round-tripping per volley.
    """
    if lowering == "reference":

        def volley(wc, xt):
            # integer_fire mirrors the Pallas lowering (planes need the
            # hardware integer grid) so results agree across lowerings.
            w2, y = fused_step_ref(
                wc, xt, cfg.neuron.threshold, cfg.t_max, cfg.neuron.w_max,
                cfg.wta.k, cfg.stdp.mu_capture, cfg.stdp.mu_backoff,
                cfg.stdp.mu_search, cfg.stdp.stabilizer == "half",
                response=cfg.neuron.response, integer_fire=True,
            )
            return w2, (y if trace else None)

    else:

        def volley(wc, xt):
            w2, y = fused_step_pallas(
                wc, xt[None], cfg, t_blk=t_blk,
                interpret=lowering == "interpret",
            )
            yq = y[0, : cfg.q].astype(TIME_DTYPE)
            return w2, (yq if trace else None)

    def epoch(wc, _):
        return jax.lax.scan(volley, wc, xs)

    w, ys = jax.lax.scan(epoch, w, None, length=epochs)
    return w, ys


# ----------------------------------------------------- padded envelope scan
@functools.partial(
    jax.jit,
    static_argnames=(
        "t_window", "w_max", "wta_k", "stabilize", "response", "epochs",
        "lowering", "t_blk",
    ),
    donate_argnums=(0,),
)
def fit_scan_padded(
    w,  # [D, p_pad, q_pad]
    xs,  # [N, D, p_pad] volleys (scan axis leading; padding silent >= t_window)
    thresholds,  # [D]
    t_maxes,  # [D]
    q_actives,  # [D]
    t_window: int,
    w_max: int,
    wta_k: int,
    mu_capture: float,
    mu_backoff: float,
    mu_search: float,
    stabilize: bool,
    response: str,
    epochs: int,
    lowering: str = "reference",
    t_blk: int = 128,
):
    """All designs x all epochs x all volleys in ONE compiled program.

    The padding-envelope contract: every member design is padded into a
    shared (p_pad, q_pad, t_window) envelope, its per-design threshold /
    effective window / live-neuron count / STDP mus become *traced* scalars
    (runtime SMEM operands under the kernel lowerings, ``vmap``-ed operands
    under the reference lowering), and the fused column step runs over the
    leading design axis.  Callers with the same envelope shapes and static
    hyper-parameters share one compiled trace — this is what lets a
    heterogeneous design sweep (``simulator.cluster_time_series_many``) and
    heterogeneous network layers (``network.fit_greedy``) reuse each
    other's compilations: ONE compilation per envelope shape, never per
    design.

    Args:
      lowering: 'mosaic' (TPU Mosaic kernel), 'interpret' (Pallas
        interpreter, validation only) or 'reference' (pure jnp).  Callers
        should pass ``repro.core.backend.padded_lowering(response)`` rather
        than hardcoding a host assumption; the kernel lowerings support RNL
        only (``check_fusable``).  All lowerings are bit-identical on
        integer weight grids.
      t_blk: kernel time-block length (kernel lowerings only).

    This entry point is deterministic — expected-mode STDP and index
    tie-break WTA need no PRNG key (that is part of the fused contract;
    stochastic configs take the solver path via ``backend.resolve``).

    ``w`` is donated: the weight buffer stays resident across the whole
    epochs x volleys scan.
    """
    if lowering not in LOWERINGS:
        raise ValueError(f"unknown lowering: {lowering!r}")
    if lowering != "reference":
        if response not in fire_responses(lowering):
            raise ValueError(
                f"the padded kernel lowering supports response "
                f"{fire_responses(lowering)}, got {response!r}; use "
                "lowering='reference'"
            )
        return _fit_scan_padded_kernel(
            w, xs, thresholds, t_maxes, q_actives,
            t_window, w_max, wta_k, mu_capture, mu_backoff, mu_search,
            stabilize, epochs, lowering, t_blk,
        )

    def volley(wc, xt):  # wc: [D, p, q]; xt: [D, p]
        w2, _ = jax.vmap(
            lambda wd, xd, th, tm, qa: fused_step_ref(
                wd, xd, th, t_window, w_max, wta_k, mu_capture, mu_backoff,
                mu_search, stabilize, t_max=tm, response=response,
                integer_fire=True, q_active=qa,
            )
        )(wc, xt, thresholds, t_maxes, q_actives)
        return w2, None

    def epoch(wc, _):
        return jax.lax.scan(volley, wc, xs)

    w, _ = jax.lax.scan(epoch, w, None, length=epochs)
    return w


def _fit_scan_padded_kernel(
    w, xs, thresholds, t_maxes, q_actives,
    t_window, w_max, wta_k, mu_capture, mu_backoff, mu_search,
    stabilize, epochs, lowering, t_blk,
):
    """Kernel-lowering body of ``fit_scan_padded`` (called inside its jit).

    Re-pads the caller's envelope up to the Mosaic tile grid (p to a LANE
    multiple, q to a SUBLANE multiple, t_window to a ``t_blk`` multiple),
    packs the per-design scalars into the runtime SMEM operand array once,
    and scans ``fused_step_pallas_padded`` over epochs x volleys.  Alignment
    padding is masked exactly like caller padding: extra synapses are
    silent, extra neurons sit above every ``q_active``.
    """
    d, p_env, q_env = w.shape
    p_pad = _pad_to(p_env, LANE)
    q_pad = _pad_to(q_env, SUBLANE)
    operands = design_operands(
        thresholds, t_maxes, q_actives, mu_capture, mu_backoff, mu_search
    )
    w_k = (
        jnp.zeros((d, p_pad, q_pad), jnp.float32)
        .at[:, :p_env, :q_env]
        .set(w.astype(jnp.float32))
    )
    # alignment rows reuse the caller's sentinel convention (any time >=
    # t_window is silent for all designs)
    xs_k = _pad_volleys_silent(xs, p_pad, t_window)

    def volley(wc, xt):  # wc: [D, p_pad, q_pad]; xt: [D, p_pad]
        w2, _ = fused_step_pallas_padded(
            wc, xt, operands,
            t_window=t_window, w_max=w_max, wta_k=wta_k,
            stabilize=stabilize, t_blk=t_blk,
            interpret=lowering == "interpret",
        )
        return w2, None

    def epoch(wc, _):
        return jax.lax.scan(volley, wc, xs_k)

    w_k, _ = jax.lax.scan(epoch, w_k, None, length=epochs)
    return w_k[:, :p_env, :q_env]


@functools.partial(
    jax.jit, static_argnames=("t_window", "wta_k", "response")
)
def assign_padded(
    w, xs, thresholds, t_maxes, q_actives,
    t_window: int, wta_k: int, response: str,
):
    """Cluster ids for every padded design: [N, D, p_pad] -> [D, N].

    Same envelope contract as ``fit_scan_padded``; the id of a volley is the
    winner neuron index, or the design's live-neuron count ``q_active`` when
    no neuron spikes (the 'unclustered' bucket)."""

    def volley(_, xt):
        def one(wd, xd, th, tm, qa):
            t = fire_dense_ref(
                wd, xd, th, t_window, t_max=tm, response=response
            )
            qi = jnp.arange(wd.shape[1], dtype=TIME_DTYPE)
            t = jnp.where(qi < qa, t, tm)
            y = ref.wta_ref(t[None], wta_k, tm)[0]
            spiked = (y < tm).any()
            return jnp.where(spiked, jnp.argmin(y), qa).astype(TIME_DTYPE)

        return 0, jax.vmap(one)(w, xt, thresholds, t_maxes, q_actives)

    _, asg = jax.lax.scan(volley, 0, xs)  # [N, D]
    return asg.T


def fit_fused(
    params: dict,
    x: jnp.ndarray,
    cfg: ColumnConfig,
    epochs: int = 8,
    lowering: str = "reference",
    trace: bool = False,
    t_blk: int = 128,
) -> tuple[dict, jnp.ndarray | None]:
    """Online STDP over [N, p] volleys as ONE jitted, donated scan.

    Weight padding / plane setup happens here, once per fit — never per
    volley.  Returns (params, ys) where ys is [epochs, N, q] winner times
    when ``trace`` else None.
    """
    check_fusable(cfg, lowering)
    # copy: the scan donates its weight buffer; the caller keeps params.
    w = jnp.array(params["w"], jnp.float32, copy=True)
    if lowering == "reference":
        w_new, ys = _fused_fit_scan(w, x, cfg, epochs, lowering, trace)
        return {"w": w_new}, ys

    p_pad = _pad_to(cfg.p, LANE)
    q_pad = _pad_to(cfg.q, SUBLANE)
    t_pad = _pad_to(cfg.t_max, t_blk)
    w_pad = jnp.zeros((p_pad, q_pad), jnp.float32).at[: cfg.p, : cfg.q].set(w)
    xs = _pad_volleys_silent(x, p_pad, 2.0 * t_pad)
    xs = jnp.where(xs >= cfg.t_max, 2.0 * t_pad, xs)
    w_new, ys = _fused_fit_scan(w_pad, xs, cfg, epochs, lowering, trace, t_blk)
    return {"w": w_new[: cfg.p, : cfg.q]}, ys
