"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth; kernels must match them exactly
(spike times are integers, so comparisons are equality, not allclose —
except the STDP update, which is float and checked with allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import TIME_DTYPE


def rnl_fire_ref(
    t_in: jnp.ndarray, w: jnp.ndarray, threshold: float, t_max: int
) -> jnp.ndarray:
    """Reference RNL firing times via dense time evaluation.

    V[b, j, t] = sum_i min(relu(t - t_in[b, i]), w[i, j]); the firing time is
    the first integer t with V >= threshold (t_max if none).  Because V is
    nondecreasing in t, this equals the count of sub-threshold cycles.

    Args:
      t_in: [B, p] int spike times (>= t_max means no spike).
      w: [p, q] non-negative weights (int-valued in hardware).
      threshold: firing threshold.
      t_max: window length.

    Returns:
      [B, q] int32 firing times.
    """
    t = jnp.arange(t_max, dtype=jnp.float32)  # [T]
    # [B, p, T] ramp; min against w per neuron then reduce synapses.
    a = jax.nn.relu(t[None, None, :] - t_in[:, :, None].astype(jnp.float32))
    ramp = jnp.minimum(a[:, :, None, :], w[None, :, :, None])  # [B, p, q, T]
    v = ramp.sum(axis=1)  # [B, q, T]
    below = (v < threshold).astype(jnp.int32)
    return below.sum(axis=-1).astype(TIME_DTYPE)  # count of sub-threshold cycles


def rnl_fire_ref_planes(
    t_in: jnp.ndarray, w: jnp.ndarray, threshold: float, t_max: int, w_max: int
) -> jnp.ndarray:
    """Oracle for the one-hot weight-plane decomposition (integer weights).

    min(relu(d), w) = relu(d) - sum_v 1[w == v] * relu(d - v)  for w in
    {0..w_max}: validates the algebra the MXU kernel uses.
    """
    t = jnp.arange(t_max, dtype=jnp.float32)
    a = jax.nn.relu(t[None, None, :] - t_in[:, :, None].astype(jnp.float32))
    base = a.sum(axis=1)  # [B, T]
    wi = jnp.round(w).astype(jnp.int32)
    acc = jnp.zeros((t_in.shape[0], w.shape[1], t_max), jnp.float32)
    for v in range(w_max + 1):
        plane = (wi == v).astype(jnp.float32)  # [p, q]
        acc = acc + jnp.einsum("pq,bpt->bqt", plane, jax.nn.relu(a - v))
    vbt = base[:, None, :] - acc  # [B, q, T]
    below = (vbt < threshold).astype(jnp.int32)
    return below.sum(axis=-1).astype(TIME_DTYPE)


def wta_ref(t_out: jnp.ndarray, k: int, t_max: int) -> jnp.ndarray:
    """Index tie-break k-WTA reference: [B, q] -> [B, q] inhibited times."""
    q = t_out.shape[-1]
    key = t_out.astype(jnp.int32) * q + jnp.arange(q, dtype=jnp.int32)
    kth = jnp.sort(key, axis=-1)[..., k - 1 : k]
    win = (key <= kth) & (t_out < t_max)
    return jnp.where(win, t_out, t_max).astype(TIME_DTYPE)


def stdp_ref(
    w: jnp.ndarray,
    x_times: jnp.ndarray,
    y_times: jnp.ndarray,
    mu_capture: float,
    mu_backoff: float,
    mu_search: float,
    w_max: int,
    t_max: int,
    stabilize: bool = True,
) -> jnp.ndarray:
    """Expected-mode STDP update oracle (mirrors core/stdp.py for one volley).

    Args:
      w: [p, q]; x_times: [p]; y_times: [q].

    Returns:
      [p, q] updated (clamped) weights.
    """
    x = x_times[:, None]
    y = y_times[None, :]
    xs = x < t_max
    ys = y < t_max
    if stabilize:
        frac = jnp.clip(w / w_max, 0.0, 1.0)
        eps = 1.0 / (2 * w_max)
        s_plus, s_minus = (1.0 - frac) + eps, frac + eps
    else:
        s_plus = s_minus = jnp.ones_like(w)
    capture = xs & ys & (x <= y)
    backoff = (xs & ys & (x > y)) | (~xs & ys)
    search = xs & ~ys
    delta = jnp.zeros_like(w)
    delta = jnp.where(capture, mu_capture * s_plus, delta)
    delta = jnp.where(backoff, -mu_backoff * s_minus, delta)
    delta = jnp.where(search, mu_search * jnp.ones_like(w), delta)
    return jnp.clip(w + delta, 0.0, float(w_max))
