"""Pallas TPU kernel: fused expected-mode STDP weight update for one volley.

In silicon this is the per-synapse update unit array (one tiny FSM per
synapse); on TPU it is a pure VPU elementwise kernel over the [p, q] weight
tile with two broadcast operands (input spike times along p, output spike
times along q).  Fusing case-select + stabilizer + clamp into one kernel
avoids materializing the [p, q] case masks in HBM.

Grid: (p_blocks, q_blocks); every block is independent (embarrassingly
parallel), lane-aligned on q and sublane-aligned on p.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUBLANE = 8


def _pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _stdp_kernel(
    w_ref,  # [p_blk, q_blk] f32
    x_ref,  # [p_blk, 1]     f32 input spike times (>= t_max: silent)
    y_ref,  # [1, q_blk]     f32 output spike times
    out_ref,  # [p_blk, q_blk] f32 updated weights
    *,
    mu_capture: float,
    mu_backoff: float,
    mu_search: float,
    w_max: int,
    t_max: int,
    stabilize: bool,
):
    w = w_ref[...]
    x = x_ref[...]  # [p_blk, 1] broadcasts over q
    y = y_ref[...]  # [1, q_blk] broadcasts over p
    xs = x < t_max
    ys = y < t_max

    if stabilize:
        frac = jnp.clip(w * (1.0 / w_max), 0.0, 1.0)
        eps = 1.0 / (2 * w_max)
        s_plus = (1.0 - frac) + eps
        s_minus = frac + eps
    else:
        s_plus = s_minus = jnp.ones_like(w)

    capture = xs & ys & (x <= y)
    backoff = (xs & ys & (x > y)) | ((~xs) & ys)
    search = xs & (~ys)

    delta = jnp.where(capture, mu_capture * s_plus, 0.0)
    delta = jnp.where(backoff, -mu_backoff * s_minus, delta)
    delta = jnp.where(search, mu_search, delta)
    out_ref[...] = jnp.clip(w + delta, 0.0, float(w_max))


@functools.partial(
    jax.jit,
    static_argnames=(
        "mu_capture", "mu_backoff", "mu_search", "w_max", "t_max",
        "stabilize", "p_blk", "q_blk", "interpret",
    ),
)
def stdp_update_pallas(
    w: jnp.ndarray,
    x_times: jnp.ndarray,
    y_times: jnp.ndarray,
    mu_capture: float,
    mu_backoff: float,
    mu_search: float,
    w_max: int,
    t_max: int,
    stabilize: bool = True,
    p_blk: int = 256,
    q_blk: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused expected STDP update.  w: [p, q]; x: [p]; y: [q] -> new w.

    ``interpret=None`` defers to the central dispatch policy
    (``repro.core.backend.pallas_interpret()``); pass a bool only in tests.
    """
    if interpret is None:
        from repro.core import backend as backend_lib

        interpret = backend_lib.pallas_interpret()
    p, q = w.shape
    if p <= p_blk:
        p_pad = p_blk = _pad_to(p, SUBLANE)
    else:
        p_pad = _pad_to(p, p_blk)
    if q <= q_blk:
        q_pad = q_blk = _pad_to(q, LANE)
    else:
        q_pad = _pad_to(q, q_blk)

    wp = jnp.zeros((p_pad, q_pad), jnp.float32).at[:p, :q].set(w)
    # silent padding: both x and y padded entries use t_max (no spike) so the
    # "neither spikes" case leaves padded weights untouched.
    xp = jnp.full((p_pad, 1), float(t_max), jnp.float32).at[:p, 0].set(
        x_times.astype(jnp.float32)
    )
    yp = jnp.full((1, q_pad), float(t_max), jnp.float32).at[0, :q].set(
        y_times.astype(jnp.float32)
    )

    grid = (p_pad // p_blk, q_pad // q_blk)
    out = pl.pallas_call(
        functools.partial(
            _stdp_kernel,
            mu_capture=mu_capture,
            mu_backoff=mu_backoff,
            mu_search=mu_search,
            w_max=w_max,
            t_max=t_max,
            stabilize=stabilize,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p_blk, q_blk), lambda i, j: (i, j)),
            pl.BlockSpec((p_blk, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, q_blk), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((p_blk, q_blk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p_pad, q_pad), jnp.float32),
        interpret=interpret,
    )(wp, xp, yp)
    return out[:p, :q]
