"""Device-calibrated cost model driving the fused-path execution policy.

Every performance-critical knob of the fused scans used to be a constant
tuned on one noisy 2-core host: ``backend.volley_block``'s 8/32, the
``t_blk=128`` time-block default, ``ENVELOPE_WASTE_CAP=4.0``, and the
largest-divisor shard policy.  This module replaces the *numbers* with a
*model* while keeping the constants as the documented fallback:

* **DeviceProfile** — the calibration record: peak FLOP/s, HBM/memory
  bandwidth, inter-device link bandwidth, per-dispatch launch overhead,
  per-trace compile cost, and the on-chip footprint bound (VMEM on TPU,
  a cache-resident working-set bound on CPU).  Named default profiles
  ship for TPU v5e (the numbers ``roofline/analysis.py`` used to
  hard-code) and a generic host CPU.
* **calibrate()** — measures the peaks once per host/platform with a
  tiny probe suite (a jitted matmul for FLOP/s, a streaming add for
  bandwidth, a no-op dispatch loop for launch overhead, one fresh
  compile for trace cost) and caches the record on disk next to the
  persistent compilation cache (``backend.compile_cache``), exactly like
  the AOT executable layer: measured once, deserialized forever after.
* **envelope_cost()** — FLOPs/bytes per volley for the *actual* fused
  scan envelope, read from XLA's ``cost_analysis`` on the lowered
  1-volley program when the backend can provide it, with the closed-form
  kernel algebra (the documented MXU plane-matmul count) as fallback.
* **choose_plan()** — enumerates candidate ``(v_blk, t_blk, shards)``
  triples, predicts warm step time for each from the three-term roofline
  (compute, memory, dispatch amortization) plus a trace-cost term for
  the statically-unrolled reference block, discards candidates whose
  transient footprint exceeds the profile's bound, and returns the
  argmin as an ``ExecutionPlan``.

The ONE invariant: a plan changes blocking/sharding/bucketing, never
semantics.  Every candidate the model may pick is bit-identical to every
other (the ``v_blk``/``t_blk``/shard bit-identity contracts pinned in
``tests/test_blocked_scan.py`` and ``docs/kernels.md``), so the model can
be wrong about *speed* but never about *results*.

**No implicit probing**: policy code consults :func:`profile` which
returns the profile explicitly activated in this process (via
``calibrate()``, ``load_profile()`` or ``set_profile()``) — or None, in
which case every policy falls back to the hand-tuned constants.  Library
imports never trigger a probe; benches and launchers opt in with
``load_or_calibrate()``.  See ``docs/costmodel.md`` for the full
contract.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import json
import math
import os
import time
from typing import Optional

# Lane/sublane geometry of the Mosaic kernels (mirrors
# kernels/fused_column.py; duplicated as plain ints so this module never
# imports jax at module scope — policy lookups must stay import-light).
LANE = 128
SUBLANE = 8

CALIBRATION_FILE = "calibration.json"
CALIBRATION_VERSION = 1
# XLA cost_analysis results per envelope, persisted next to the
# calibration record: the ~tens-of-ms trace probe runs once per host per
# envelope, not once per process (a fresh process inside the cold-start
# path would otherwise re-pay it inside the very region being measured)
COSTS_FILE = "envelope_costs.json"
COSTS_VERSION = 1

# Fallback constants — the pre-costmodel hand-tuned policy, still the
# behavior whenever no profile is active (see ``constants_plan``).
CONST_V_BLK_REFERENCE = 8
CONST_V_BLK_KERNEL = 32
CONST_T_BLK = 128
CONST_WASTE_CAP = 4.0


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """One host/platform calibration record.

    ``peak_flops``/``hbm_bw``/``link_bw`` are the classic roofline peaks
    (FLOP/s, B/s, B/s per link).  ``dispatch_s`` is the measured overhead
    of dispatching one jitted executable (the cost volley-blocking
    amortizes); ``compile_s`` the cost of one small trace+compile (the
    cost envelope sharing and bounded reference unrolls amortize);
    ``footprint_bytes`` the working-set bound a step's transients must
    respect (VMEM per core on TPU, a cache-resident bound on CPU).
    ``calibrated`` distinguishes measured records from the named
    defaults.
    """

    name: str
    platform: str           # jax.default_backend() at calibration time
    device_kind: str
    peak_flops: float
    hbm_bw: float
    link_bw: float
    dispatch_s: float
    compile_s: float
    footprint_bytes: float
    n_devices: int = 1
    calibrated: bool = False
    # Measured fused-path efficiency: predicted-roofline / measured warm
    # seconds on a small REAL fused-fit probe envelope.  The raw roofline
    # over-counts on hosts where the step's transients stay cache-resident
    # (XLA's 'bytes accessed' assumes every byte hits HBM), so the fused
    # probe anchors absolute predictions to reality; relative ordering of
    # candidates is unaffected (the scalar divides every candidate alike).
    fused_eff: float = 1.0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["version"] = CALIBRATION_VERSION
        return d

    @staticmethod
    def from_json(d: dict) -> "DeviceProfile":
        d = {k: v for k, v in d.items() if k != "version"}
        return DeviceProfile(**d)


# Named default profiles.  'tpu-v5e' carries the numbers
# roofline/analysis.py used to hard-code (197 Tf/s bf16, 819 GB/s HBM,
# 50 GB/s per ICI link) plus the ~16 MB/core VMEM bound; 'host-cpu' is a
# deliberately conservative generic CPU (runs that want real numbers
# calibrate).  Neither is ever *active* implicitly — they are reference
# records and the roofline report's fallback, not a silent policy input.
PROFILES: dict[str, DeviceProfile] = {
    "tpu-v5e": DeviceProfile(
        name="tpu-v5e", platform="tpu", device_kind="TPU v5e",
        peak_flops=197e12, hbm_bw=819e9, link_bw=50e9,
        dispatch_s=5e-6, compile_s=2.0, footprint_bytes=16 * 2**20,
    ),
    "host-cpu": DeviceProfile(
        name="host-cpu", platform="cpu", device_kind="cpu",
        peak_flops=5e10, hbm_bw=1e10, link_bw=1e10,
        dispatch_s=3e-5, compile_s=0.05, footprint_bytes=32 * 2**20,
    ),
}


# ------------------------------------------------------------ activation
# The active profile is process state, set EXPLICITLY (calibrate /
# load_profile / set_profile) — policy functions read it, never populate
# it, so tests and libraries stay hermetic by default.
_ACTIVE: Optional[DeviceProfile] = None


def profile() -> Optional[DeviceProfile]:
    """The active calibration record, or None (constants fallback)."""
    return _ACTIVE


def set_profile(p: Optional[DeviceProfile]) -> Optional[DeviceProfile]:
    """Activate ``p`` (or deactivate with None).  Returns the previous
    active profile.  Plan lookups are memoized on the active profile, so
    switching invalidates nothing stale — the profile is part of the
    memo key."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = p
    return prev


@contextlib.contextmanager
def override(p: Optional[DeviceProfile]):
    """Temporarily activate ``p`` (None = force the constants fallback).
    The bench head-to-heads use this to time plan-vs-constants on the
    same code path."""
    prev = set_profile(p)
    try:
        yield
    finally:
        set_profile(prev)


def calibration_path() -> Optional[str]:
    """Where the calibration record persists: next to the persistent
    compilation cache (``backend.compile_cache``), so the two caches
    travel together (CI caches one directory and gets both).  None when
    no cache directory is enabled — calibration then lives only in this
    process."""
    from repro.core import backend as backend_lib

    root = backend_lib.compile_cache_dir()
    if root is None:
        return None
    return os.path.join(root, CALIBRATION_FILE)


def save_profile(p: DeviceProfile, path: Optional[str] = None) -> Optional[str]:
    """Persist ``p`` (atomic write-then-rename, same publish discipline
    as the AOT store).  Returns the path written, or None when no
    persistence root is available."""
    path = path or calibration_path()
    if path is None:
        return None
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(p.to_json(), f, indent=2)
    os.replace(tmp, path)
    return path


def load_profile(path: Optional[str] = None) -> Optional[DeviceProfile]:
    """Load and ACTIVATE a persisted calibration record, if one exists
    and matches this host (platform + device kind + device count — a
    record measured on different silicon is ignored, never wrong).
    Returns the activated profile or None."""
    import jax

    path = path or calibration_path()
    if path is None or not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            d = json.load(f)
        if d.get("version") != CALIBRATION_VERSION:
            return None
        p = DeviceProfile.from_json(d)
    except (OSError, ValueError, TypeError):
        return None
    if (
        p.platform != jax.default_backend()
        or p.device_kind != jax.devices()[0].device_kind
        or p.n_devices != jax.local_device_count()
    ):
        return None
    set_profile(p)
    return p


# ------------------------------------------------------------ probe suite
def _probe_peak_flops() -> float:
    """Peak f32 FLOP/s via a jitted square matmul (min over rounds)."""
    import jax
    import jax.numpy as jnp

    n = 384
    f = jax.jit(lambda a, b: a @ b)
    a = jnp.ones((n, n), jnp.float32)
    jax.block_until_ready(f(a, a))
    best = math.inf
    for _ in range(5):
        t0 = time.perf_counter()
        jax.block_until_ready(f(a, a))
        best = min(best, time.perf_counter() - t0)
    return 2.0 * n**3 / max(best, 1e-9)


def _probe_hbm_bw() -> float:
    """Streaming bandwidth via a jitted elementwise add over ~64 MB
    (read + write counted)."""
    import jax
    import jax.numpy as jnp

    n = 16 * 2**20  # 16M f32 = 64 MB
    f = jax.jit(lambda x: x + 1.0)
    x = jnp.zeros((n,), jnp.float32)
    jax.block_until_ready(f(x))
    best = math.inf
    for _ in range(5):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        best = min(best, time.perf_counter() - t0)
    return 2.0 * 4.0 * n / max(best, 1e-9)


def _probe_dispatch_s() -> float:
    """Per-call overhead of dispatching one tiny jitted executable."""
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda x: x + 1.0)
    x = jnp.zeros((8,), jnp.float32)
    jax.block_until_ready(f(x))
    best = math.inf
    for _ in range(7):
        t0 = time.perf_counter()
        for _ in range(20):
            f(x)
        jax.block_until_ready(f(x))
        best = min(best, (time.perf_counter() - t0) / 21)
    return best


def _probe_compile_s() -> float:
    """Cost of one small trace+compile (fresh function each round so the
    jit cache cannot answer).  Against a populated persistent cache this
    measures trace+deserialize — which IS the marginal cost a new trace
    pays in that environment, so the number stays honest."""
    import jax
    import jax.numpy as jnp

    x = jnp.zeros((64, 64), jnp.float32)
    times = []
    for i in range(2):
        c = float(i) + 0.5

        def fresh(a, _c=c):
            return (a * _c + _c).sum()

        t0 = time.perf_counter()
        jax.block_until_ready(jax.jit(fresh)(x))
        times.append(time.perf_counter() - t0)
    return min(times)


def _probe_fused_eff(p: DeviceProfile) -> float:
    """Anchor the roofline to a REAL fused fit: run one small reference
    envelope warm and return predicted/measured.  Pinned ``v_blk``/
    ``t_blk`` so the probe never consults the (not yet active) plan
    policy; any failure (instrumented entry points, missing kernels)
    answers a neutral 1.0 — calibration must never be fatal."""
    try:
        import numpy as np
        import jax
        import jax.numpy as jnp
        from repro.core import backend as backend_lib
        from repro.core.types import TIME_DTYPE

        d, pp, qp, tw, nb, ep, vb = 2, 64, 8, 64, 32, 1, 2
        rng = np.random.default_rng(0)
        xs = jnp.asarray(rng.integers(0, tw, (nb, d, pp)), TIME_DTYPE)
        thr = jnp.full((d,), float(pp) / 3, jnp.float32)
        tm = jnp.full((d,), tw, TIME_DTYPE)
        qa = jnp.full((d,), qp - 2, TIME_DTYPE)
        kw = dict(
            t_window=tw, w_max=7, wta_k=1, mu_capture=2.0, mu_backoff=1.0,
            mu_search=1.0, stabilize=False, response="rnl", epochs=ep,
            lowering="reference", t_blk=CONST_T_BLK, v_blk=vb,
        )

        def run():
            w = jnp.asarray(rng.integers(0, 8, (d, pp, qp)), jnp.float32)
            t0 = time.perf_counter()
            jax.block_until_ready(backend_lib.fit_padded(w, xs, thr, tm, qa, **kw))
            return time.perf_counter() - t0

        run()  # compile
        measured = min(run() for _ in range(3)) / (nb * ep)
        flops, byts, _ = envelope_cost(
            d, pp, qp, tw, w_max=7, lowering="reference", t_blk=CONST_T_BLK
        )
        predicted = (
            max(flops / p.peak_flops, byts / p.hbm_bw)
            + p.dispatch_s / vb
        )
        return float(min(max(predicted / max(measured, 1e-9), 0.05), 50.0))
    except Exception:
        return 1.0


def _footprint_bound(platform: str) -> float:
    """On-chip working-set bound for one step's transients: VMEM per
    core on TPU (~16 MB, see the Pallas guide), a cache-resident bound
    elsewhere (the reference block's dense transient should stay near
    LLC-sized or the unrolled body thrashes)."""
    return float(16 * 2**20 if platform == "tpu" else 32 * 2**20)


def calibrate(force: bool = False, persist: bool = True) -> DeviceProfile:
    """Measure this host's peaks, ACTIVATE the record, and persist it
    next to the compile cache (when one is enabled).

    Idempotent per process: an already-active calibrated profile is
    returned as-is unless ``force``.  The probe suite costs well under a
    second warm; results are cached on disk like the AOT layer so later
    processes ``load_or_calibrate()`` in milliseconds.
    """
    import jax

    if _ACTIVE is not None and _ACTIVE.calibrated and not force:
        return _ACTIVE
    platform = jax.default_backend()
    p = DeviceProfile(
        name=f"calibrated-{platform}",
        platform=platform,
        device_kind=jax.devices()[0].device_kind,
        peak_flops=_probe_peak_flops(),
        hbm_bw=_probe_hbm_bw(),
        link_bw=PROFILES["tpu-v5e"].link_bw if platform == "tpu" else 1e10,
        dispatch_s=_probe_dispatch_s(),
        compile_s=_probe_compile_s(),
        footprint_bytes=_footprint_bound(platform),
        n_devices=jax.local_device_count(),
        calibrated=True,
    )
    p = dataclasses.replace(p, fused_eff=_probe_fused_eff(p))
    set_profile(p)
    if persist:
        save_profile(p)
    return p


def load_or_calibrate() -> DeviceProfile:
    """The launcher entry point: reuse a persisted record when one
    matches this host, probe (and persist) otherwise."""
    return load_profile() or calibrate()


# --------------------------------------------------------- envelope cost
@functools.lru_cache(maxsize=256)
def analytic_volley_cost(
    d: int, p_pad: int, q_pad: int, t_window: int, w_max: int
) -> tuple[float, float]:
    """Closed-form (flops, bytes) per volley of the fused step.

    FLOPs: the one-hot plane matmuls of the kernel algebra —
    ``2 * (w_max+1) * p * q * t`` per design per volley (the documented
    MXU count every bench row reports) plus the O(p*q) WTA/STDP tail.
    Bytes: weights read+written, the volley row, and the dense
    plane/step transients the reference body materializes.
    """
    flops = d * (2.0 * (w_max + 1) * p_pad * q_pad * t_window
                 + 6.0 * p_pad * q_pad)
    byts = 4.0 * d * (
        2.0 * p_pad * q_pad      # w in + out
        + p_pad                  # volley
        + p_pad * t_window       # masked-step transient
        + q_pad * t_window       # plane-response transient
    )
    return flops, byts


def xla_volley_cost(
    d: int, p_pad: int, q_pad: int, t_window: int,
    *, w_max: int, response: str, lowering: str,
    t_blk: int, epochs: int = 1,
) -> Optional[tuple[float, float]]:
    """(flops, bytes) per volley from XLA ``cost_analysis`` of the
    ACTUAL fused-scan envelope, lowered with ``v_blk=1`` over a single
    volley (tracing one block body is cheap; the totals scale linearly
    in volleys, which the caller applies).  None when the backend cannot
    answer (older jaxlib, instrumented entry point) — callers fall back
    to the closed form."""
    import jax
    from repro.kernels import fused_column

    if not hasattr(fused_column.fit_scan_padded, "lower"):
        return None
    try:
        w = jax.ShapeDtypeStruct((d, p_pad, q_pad), "float32")
        from repro.core.types import TIME_DTYPE

        xs = jax.ShapeDtypeStruct((1, d, p_pad), TIME_DTYPE)
        vec = jax.ShapeDtypeStruct((d,), TIME_DTYPE)
        thr = jax.ShapeDtypeStruct((d,), "float32")
        mu = jax.ShapeDtypeStruct((), "float32")
        lowered = fused_column.fit_scan_padded.lower(
            w, xs, thr, vec, vec,
            mu_capture=mu, mu_backoff=mu, mu_search=mu,
            t_window=t_window, w_max=w_max, wta_k=1, stabilize=False,
            response=response, epochs=1, lowering=lowering,
            t_blk=t_blk, v_blk=1,
        )
        cost = lowered.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        flops = float(cost.get("flops", 0.0))
        byts = float(cost.get("bytes accessed", 0.0))
        if flops <= 0.0:
            return None
        return flops, byts
    except Exception:
        return None


# in-process view of the persisted cost store: (path, mapping) — reloaded
# when the cache directory changes, merged-and-republished on new probes
_disk_costs: tuple = (None, None)


def _costs_path() -> Optional[str]:
    root_cal = calibration_path()
    if root_cal is None:
        return None
    return os.path.join(os.path.dirname(root_cal), COSTS_FILE)


def _load_disk_costs(path: str) -> dict:
    """Read the persisted envelope-cost map (empty on any mismatch —
    jaxlib upgrades change ``cost_analysis`` totals, so entries key on
    the jax version and a stale file is ignored, never wrong)."""
    import jax

    try:
        with open(path) as f:
            rec = json.load(f)
        if (rec.get("version") == COSTS_VERSION
                and rec.get("jax") == jax.__version__):
            return dict(rec.get("costs", {}))
    except (OSError, ValueError):
        pass
    return {}


def _publish_disk_costs(path: str, costs: dict) -> None:
    import jax

    merged = _load_disk_costs(path)  # merge concurrent writers' probes
    merged.update(costs)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(tmp, "w") as f:
            json.dump(
                {"version": COSTS_VERSION, "jax": jax.__version__,
                 "costs": merged}, f,
            )
        os.replace(tmp, path)
    except OSError:
        pass  # persistence is an optimization, never fatal


@functools.lru_cache(maxsize=256)
def envelope_cost(
    d: int, p_pad: int, q_pad: int, t_window: int,
    *, w_max: int, response: str = "rnl", lowering: str = "reference",
    t_blk: int = CONST_T_BLK, use_xla: bool = True,
) -> tuple[float, float, str]:
    """(flops, bytes, source) per volley for one fit envelope: XLA
    ``cost_analysis`` of the real lowered program when available
    (source='xla'), the closed-form kernel algebra otherwise
    (source='analytic').  Memoized twice — in-process (one trace per
    envelope per process) and on disk next to the calibration record
    (one trace per envelope per host: the probe costs tens of ms, which
    a fresh process would otherwise re-pay inside its own cold start)."""
    global _disk_costs
    if use_xla:
        key = (f"{d}x{p_pad}x{q_pad}x{t_window}"
               f":w{w_max}:{response}:{lowering}:t{t_blk}")
        path = _costs_path()
        if path is not None and _disk_costs[0] != path:
            _disk_costs = (path, _load_disk_costs(path))
        cached = (
            _disk_costs[1].get(key)
            if path is not None and _disk_costs[0] == path else None
        )
        if cached is not None:
            return float(cached[0]), float(cached[1]), "xla"
        got = xla_volley_cost(
            d, p_pad, q_pad, t_window, w_max=w_max, response=response,
            lowering=lowering, t_blk=t_blk,
        )
        if got is not None:
            if path is not None:
                _disk_costs[1][key] = [got[0], got[1]]
                _publish_disk_costs(path, _disk_costs[1])
            return got[0], got[1], "xla"
    flops, byts = analytic_volley_cost(d, p_pad, q_pad, t_window, w_max)
    return flops, byts, "analytic"


# -------------------------------------------------------- plan + chooser
@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """One execution policy decision for a padded fused scan.

    Carries every knob the policy seams used to hard-code — the volley
    block, the kernel time block, the design-axis shard count, the
    envelope waste cap in force — plus the prediction that chose them,
    so every consumer (bench rows, DSE journal meta, serve stats) can
    record *why* the knobs are what they are.  Frozen and hashable: a
    plan rides through ``jit`` static args and memo keys untouched.

    Contract (property-tested in ``tests/test_costmodel.py``): ``1 <=
    v_blk <= n_volleys``; ``t_blk`` is lane-aligned (a positive multiple
    of 128); ``shards`` divides ``d``; ``waste_cap >= 1``.  A plan NEVER
    changes semantics — every legal plan is bit-identical to every
    other.
    """

    kind: str               # 'fit' | 'assign'
    lowering: str
    d: int
    n_volleys: int
    v_blk: int
    t_blk: int
    shards: int
    waste_cap: float
    predicted_step_s: float  # predicted warm seconds per volley
    source: str              # 'costmodel' | 'constants'
    profile: str             # profile name ('' when constants)

    def meta(self) -> dict:
        """JSON-ready metadata record (bench rows, journal, stats)."""
        return {
            "kind": self.kind,
            "lowering": self.lowering,
            "v_blk": self.v_blk,
            "t_blk": self.t_blk,
            "shards": self.shards,
            "waste_cap": self.waste_cap,
            "predicted_step_us": self.predicted_step_s * 1e6,
            "source": self.source,
            "profile": self.profile,
        }


def _const_v_blk(lowering: str, n_volleys: int, d: Optional[int]) -> int:
    """The hand-tuned fallback block policy (mirrors the documented
    history in ``backend.volley_block``)."""
    base = (
        CONST_V_BLK_REFERENCE if lowering == "reference"
        else CONST_V_BLK_KERNEL
    )
    if d is not None and lowering == "reference":
        base = min(base, max(2, 2 * int(d)))
    return max(1, min(base, int(n_volleys)))


def _const_shards(d: int) -> int:
    import jax

    n_dev = jax.local_device_count()
    k = min(int(d), n_dev)
    while k > 1 and d % k:
        k -= 1
    return max(k, 1)


def constants_plan(
    kind: str, lowering: str, d: int, n_volleys: int,
    p_pad: int = 0, q_pad: int = 0, t_window: int = 0,
) -> ExecutionPlan:
    """The documented fallback when no calibration exists: exactly the
    pre-costmodel constants, packaged as a plan so consumers see ONE
    shape either way (``source='constants'`` says which policy ran)."""
    return ExecutionPlan(
        kind=kind, lowering=lowering, d=d, n_volleys=max(int(n_volleys), 1),
        v_blk=_const_v_blk(lowering, n_volleys, d if kind == "fit" else None),
        t_blk=CONST_T_BLK,
        shards=_const_shards(d),
        waste_cap=CONST_WASTE_CAP,
        predicted_step_s=0.0,
        source="constants",
        profile="",
    )


def step_footprint_bytes(
    lowering: str, d: int, p_pad: int, q_pad: int, t_window: int,
    v_blk: int, t_blk: int,
) -> float:
    """Transient working set of ONE blocked step under a candidate
    (v_blk, t_blk).

    Reference lowering: the statically-unrolled block shares one dense
    ``[v_blk, d, p, t]`` masked-step transient plus the weight planes —
    the buffer that must stay cache-resident for the unroll to win.
    Kernel lowerings: the per-grid-step VMEM residency — weight planes,
    the volley block, and one (q x t_blk) + (p x t_blk) response tile.
    """
    if lowering == "reference":
        return 4.0 * (
            v_blk * d * p_pad * t_window     # masked-step transient
            + 2.0 * d * p_pad * q_pad        # weights in/out
            + v_blk * d * q_pad * t_window / max(t_window, 1)  # winners
        )
    t_eff = min(t_blk, max(t_window, 1))
    return 4.0 * (
        2.0 * p_pad * q_pad                    # w + its plane decomposition
        + v_blk * p_pad                        # volley block (SMEM-ish)
        + (p_pad + q_pad) * t_eff              # response tiles
    )


def _candidate_v_blks(lowering: str, n_volleys: int) -> list[int]:
    """Volley-block candidates: powers of two from 2 up to the
    lowering's constants base (8 reference / 32 kernel), clamped to the
    stream.

    Never 1 unless the stream itself is — a block of 1 forfeits all
    per-step amortization for nothing (measured ~7% warm loss on the
    tracked sweep geometry), so the model doesn't get to pick it.  Never
    above the constants base either: the measured warm cliff past the
    base (the unrolled reference body regresses beyond ~8 on the bench
    hosts) is a code-size effect the roofline cannot see, so the
    hand-tuned cap stays the upper bound and the model arbitrates below
    it.
    """
    cap = CONST_V_BLK_REFERENCE if lowering == "reference" else CONST_V_BLK_KERNEL
    out = []
    v = 2
    while v <= min(n_volleys, cap):
        out.append(v)
        v *= 2
    if not out:
        out.append(max(1, min(int(n_volleys), cap)))
    return out


def _candidate_t_blks(lowering: str, t_window: int) -> list[int]:
    if lowering == "reference":
        # the reference body has no time blocking — t_blk is carried for
        # key/plan symmetry only, pinned at the lane-aligned default
        return [CONST_T_BLK]
    # kernel lowerings tile time in lane-aligned blocks; offering one
    # larger block lets big windows trade grid steps for VMEM
    cands = [CONST_T_BLK]
    if t_window > CONST_T_BLK:
        cands.append(2 * CONST_T_BLK)
    return cands


def _divisor_shards(d: int, n_dev: int) -> list[int]:
    return [k for k in range(1, min(d, n_dev) + 1) if d % k == 0]


# Two candidates whose predicted warm times differ by less than this
# are a tie — the prediction's resolution, not a real difference (the
# measured warm spread across v_blk 2..8 on the tracked geometry is ~1%).
WARM_TIE_TOL = 0.05


def trace_unroll(kind: str, lowering: str, d: int, v_blk: int) -> float:
    """Relative trace/compile cost proxy of a candidate: the reference
    fit block statically unrolls ``v_blk * d`` copies of the fused body
    into ONE XLA computation (compile time measured ~linear in that
    count), while kernel lowerings fold the block in an in-kernel
    ``fori_loop`` and the assignment fire is one vmapped body — both
    trace a single copy regardless of block size."""
    if kind == "fit" and lowering == "reference":
        return float(v_blk * d)
    return 1.0


def predict_step_s(
    prof: DeviceProfile,
    kind: str,
    lowering: str,
    d: int, p_pad: int, q_pad: int, t_window: int,
    n_volleys: int, epochs: int,
    v_blk: int, t_blk: int, shards: int,
    *, w_max: int = 7, response: str = "rnl",
) -> float:
    """Predicted WARM seconds per volley under a candidate plan.

    Two terms, both per volley:

      max(flops/peak, bytes/bw) / shards     the sharded roofline bound
      + dispatch_s * shards / v_blk          per-step overhead, amortized
                                             over the block, paid per
                                             participating device

    Warm time is THE objective: under the persistent AOT cache
    (``backend.compile_cache``) trace+compile is a once-ever cost, so it
    never belongs in the per-volley prediction — it enters the chooser
    only as the tie-breaker between warm-equivalent candidates (see
    ``trace_unroll`` / ``WARM_TIE_TOL``), which is exactly how the
    hand-tuned constants treated it (v_blk capped for compile growth,
    not warm loss).
    """
    flops, byts, _ = envelope_cost(
        d, p_pad, q_pad, t_window, w_max=w_max, response=response,
        lowering=lowering, t_blk=t_blk,
    )
    roofline_s = max(flops / prof.peak_flops, byts / prof.hbm_bw)
    roofline_s /= max(prof.fused_eff, 1e-6)
    step_s = roofline_s / max(shards, 1)
    step_s += prof.dispatch_s * shards / max(v_blk, 1)
    return step_s


@functools.lru_cache(maxsize=512)
def _choose_plan_cached(
    prof: DeviceProfile,
    kind: str, lowering: str,
    d: int, p_pad: int, q_pad: int, t_window: int,
    n_volleys: int, epochs: int, w_max: int, response: str,
) -> ExecutionPlan:
    import jax

    n_dev = jax.local_device_count()
    cands = []
    for t_blk in _candidate_t_blks(lowering, t_window):
        for v_blk in _candidate_v_blks(lowering, n_volleys):
            admissible = (
                step_footprint_bytes(
                    lowering, d, p_pad, q_pad, t_window, v_blk, t_blk
                ) <= prof.footprint_bytes
            )
            for shards in _divisor_shards(d, n_dev):
                s = predict_step_s(
                    prof, kind, lowering, d, p_pad, q_pad, t_window,
                    n_volleys, epochs, v_blk, t_blk, shards,
                    w_max=w_max, response=response,
                )
                cands.append((admissible, s, v_blk, t_blk, shards))
    # footprint bound first (an inadmissible candidate survives only if
    # nothing fits — then the smallest-footprint one, i.e. the smallest
    # block, limps through); within the admissible set, minimize warm
    # time, then break warm ties (within WARM_TIE_TOL — prediction
    # resolution) toward the cheapest trace, the largest block (launch
    # amortization beyond the model), the default tile, fewest shards.
    if any(a for (a, *_rest) in cands):
        cands = [c for c in cands if c[0]]
    best_s = min(s for (_a, s, *_rest) in cands)
    ties = [c for c in cands if c[1] <= best_s * (1.0 + WARM_TIE_TOL)]
    _a, s, v_blk, t_blk, shards = min(
        ties,
        key=lambda c: (
            trace_unroll(kind, lowering, d, c[2]), -c[2], c[3], c[4]
        ),
    )
    return ExecutionPlan(
        kind=kind, lowering=lowering, d=d, n_volleys=n_volleys,
        v_blk=v_blk, t_blk=t_blk, shards=shards,
        waste_cap=choose_waste_cap(prof, d, p_pad, q_pad, t_window,
                                   n_volleys, epochs, w_max=w_max),
        predicted_step_s=s, source="costmodel", profile=prof.name,
    )


def choose_plan(
    kind: str,
    lowering: str,
    d: int,
    p_pad: int,
    q_pad: int,
    t_window: int,
    n_volleys: int,
    epochs: int = 1,
    *,
    w_max: int = 7,
    response: str = "rnl",
    prof: Optional[DeviceProfile] = None,
) -> ExecutionPlan:
    """The policy front door: an ``ExecutionPlan`` for one padded scan.

    With an active (or explicitly passed) profile, candidates are
    enumerated and the predicted-fastest admissible one wins
    (``source='costmodel'``); with none, the hand-tuned constants are
    returned unchanged (``source='constants'``) — the documented
    fallback, so un-calibrated hosts behave exactly as before this
    module existed.  Deterministic for fixed inputs: a warmed executable
    key and a traffic-time key always agree.
    """
    prof = prof if prof is not None else profile()
    n_volleys = max(int(n_volleys), 1)
    if prof is None:
        return constants_plan(
            kind, lowering, d, n_volleys, p_pad, q_pad, t_window
        )
    return _choose_plan_cached(
        prof, kind, lowering, int(d), int(p_pad), int(q_pad),
        int(t_window), n_volleys, int(max(epochs, 1)), int(w_max),
        response,
    )


def choose_waste_cap(
    prof: Optional[DeviceProfile] = None,
    d: int = 1, p_pad: int = 1, q_pad: int = 1, t_window: int = 1,
    n_volleys: int = 0, epochs: int = 1, *, w_max: int = 7,
) -> float:
    """Envelope waste cap from the roofline: padding waste recurs on
    every volley (cost ~ cap * per-volley envelope seconds * total
    volleys), while sharing an envelope saves ONE trace+compile.  The
    cap where the two break even is ``1 + compile_s / (volley_s *
    total_volleys)``, clamped to [1.5, 8] so degenerate inputs (empty
    streams, enormous envelopes) stay sane.  Falls back to the
    hand-tuned 4.0 without a profile or stream length."""
    prof = prof if prof is not None else profile()
    total = max(int(n_volleys), 0) * max(int(epochs), 1)
    if prof is None or total <= 0:
        return CONST_WASTE_CAP
    flops, byts, _ = envelope_cost(
        max(d, 1), max(p_pad, 1), max(q_pad, 1), max(t_window, 1),
        w_max=w_max, use_xla=False,
    )
    volley_s = max(
        flops / prof.peak_flops, byts / prof.hbm_bw, 1e-12
    ) / max(prof.fused_eff, 1e-6)
    cap = 1.0 + prof.compile_s / (volley_s * total)
    return float(min(max(cap, 1.5), 8.0))


def choose_shards(d: int, volume: Optional[float] = None) -> int:
    """Design-axis shard count.  Without a profile (or a compute-volume
    hint), the classic largest-divisor policy; with both, shard only
    while the per-volley compute saved exceeds the added per-device
    dispatch — tiny buckets stay unsharded instead of paying k dispatches
    to split microseconds of work."""
    prof = profile()
    base = _const_shards(d)
    if prof is None or volume is None:
        return base
    import jax

    n_dev = jax.local_device_count()
    vol_s = 2.0 * float(volume) / prof.peak_flops
    best, best_s = 1, math.inf
    for k in _divisor_shards(d, n_dev):
        s = vol_s / k + prof.dispatch_s * (k - 1)
        if s < best_s:
            best, best_s = k, s
    return best


def plan_is_valid(plan: ExecutionPlan) -> bool:
    """The plan contract, as one predicate (property-tested): clamped
    volley block, lane-aligned time block, shard count dividing the
    design axis, sane waste cap."""
    return (
        1 <= plan.v_blk <= max(plan.n_volleys, 1)
        and plan.t_blk > 0
        and plan.t_blk % LANE == 0
        and plan.shards >= 1
        and plan.d % plan.shards == 0
        and plan.waste_cap >= 1.0
    )


def main(argv=None) -> int:
    """CLI: calibrate this host and persist the record next to the
    compile cache (``REPRO_COMPILE_CACHE`` honored at import)."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--force", action="store_true",
        help="re-probe even if a persisted record matches this host",
    )
    args = ap.parse_args(argv)
    p = load_profile() if not args.force else None
    if p is None:
        p = calibrate(force=args.force)
    path = calibration_path()
    print(
        f"profile {p.name}: peak={p.peak_flops / 1e9:.1f} GF/s "
        f"bw={p.hbm_bw / 1e9:.1f} GB/s dispatch={p.dispatch_s * 1e6:.1f} us "
        f"compile={p.compile_s * 1e3:.1f} ms "
        f"({'calibrated' if p.calibrated else 'default'}; "
        f"persisted at {path or 'nowhere — no compile cache enabled'})"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
