"""Three-term roofline analysis over the dry-run artifacts (§Roofline).

Hardware model: the peaks come from a ``costmodel.DeviceProfile`` — the
*active* device calibration when one is loaded (``costmodel.calibrate`` /
``load_profile``), the named ``'tpu-v5e'`` default profile otherwise:
    PEAK_FLOPS = 197e12   bf16 FLOP/s   (v5e default)
    HBM_BW     = 819e9    B/s           (v5e default)
    LINK_BW    = 50e9     B/s per ICI link (v5e default)

Terms, in seconds per step (all quantities are PER DEVICE — XLA's
``cost_analysis`` reports the per-device SPMD module, and the collective
parser counts per-device wire bytes; dividing global totals by chip count
is algebraically identical for balanced SPMD):

    compute_s    = HLO_FLOPs / PEAK_FLOPS
    memory_s     = HLO_bytes / HBM_BW
    collective_s = collective_bytes / LINK_BW

Where the dry-run recorded extrapolated (unrolled 1/2-layer) costs, those
are used — the scanned compile undercounts loop bodies (see launch/hlo.py).

MODEL_FLOPS = 6 * N(_active) * tokens for training (2N fwd + 4N bwd)
and 2 * N(_active) * tokens for inference;
``useful_ratio`` = MODEL_FLOPS / HLO_FLOPS_global catches remat/redundancy
waste.  ``roofline_fraction`` = useful compute time / dominant term — the
MFU the step would achieve if it ran exactly at the binding roofline.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Optional

from repro.roofline import costmodel

# Module-level constants stay as the NAMED DEFAULT profile's numbers
# (import-time snapshot of costmodel.PROFILES['tpu-v5e']) for callers that
# want the classic v5e targets; analysis itself resolves peaks per call
# through peaks(), which prefers the active device calibration.
_DEFAULT_PROFILE = costmodel.PROFILES["tpu-v5e"]
PEAK_FLOPS = _DEFAULT_PROFILE.peak_flops
HBM_BW = _DEFAULT_PROFILE.hbm_bw
LINK_BW = _DEFAULT_PROFILE.link_bw

RESULTS_DIR = "results/dryrun"


def peaks(
    prof: Optional[costmodel.DeviceProfile] = None,
) -> tuple[float, float, float, str]:
    """``(peak_flops, hbm_bw, link_bw, profile_name)`` for the analysis.

    Resolution order: explicit ``prof`` argument, then the active
    calibration (``costmodel.profile()``), then the ``'tpu-v5e'`` default.
    A calibrated single-host profile has no measured interconnect, so a
    zero/unset ``link_bw`` falls back to the v5e ICI number rather than
    dividing by zero.
    """
    p = prof if prof is not None else costmodel.profile()
    if p is None:
        p = _DEFAULT_PROFILE
    link = p.link_bw if p.link_bw and p.link_bw > 0 else _DEFAULT_PROFILE.link_bw
    return p.peak_flops, p.hbm_bw, link, p.name


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    n_chips: int = 0
    compute_s: float = 0.0
    memory_s: float = 0.0
    memory_min_s: float = 0.0  # fused lower bound (see analyze_cell)
    collective_s: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    hlo_flops_global: float = 0.0
    useful_ratio: float = 0.0
    roofline_fraction: float = 0.0
    fraction_fused: float = 0.0
    peak_mem_gb: float = 0.0
    note: str = ""
    # which DeviceProfile supplied the peaks ('tpu-v5e' default, or the
    # host's calibration when one is active)
    profile: str = ""

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bound_fused_s(self) -> float:
        return max(self.compute_s, self.memory_min_s, self.collective_s)


def load_cells(results_dir: str = RESULTS_DIR) -> list:
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def analyze_cell(cell: dict) -> RooflineRow:
    if cell.get("status") != "ok":
        return RooflineRow(
            arch=cell["arch"], shape=cell["shape"], mesh=cell.get("mesh", "?"),
            status=cell.get("status", "?"), note=cell.get("reason", ""),
        )
    ex = cell.get("extrapolated") or {}
    cost = cell.get("cost_analysis") or {}
    flops = ex.get("flops", cost.get("flops", 0.0))
    byts = ex.get("bytes accessed", cost.get("bytes accessed", 0.0))
    coll = (ex.get("collective_bytes") or cell.get("collective_bytes", {})).get(
        "total", 0.0
    )
    n = cell["n_chips"]
    peak_flops, hbm_bw, link_bw, prof_name = peaks()
    compute_s = flops / peak_flops
    memory_s = byts / hbm_bw
    collective_s = coll / link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    # MODEL_FLOPS (standard convention): training = 6*N_active per token
    # (2N fwd + 4N bwd); inference (prefill/decode) = 2*N_active per token.
    from repro.configs import SHAPES

    sh = SHAPES[cell["shape"]]
    n_active = cell.get("active_params", 0)
    if sh.kind == "train":
        model_flops = 6.0 * n_active * sh.global_batch * sh.seq_len
    elif sh.kind == "prefill":
        model_flops = 2.0 * n_active * sh.global_batch * sh.seq_len
    else:
        model_flops = 2.0 * n_active * sh.global_batch
    hlo_global = flops * n
    useful = model_flops / hlo_global if hlo_global else 0.0
    useful_time = model_flops / (n * peak_flops)
    bound = max(terms.values())
    frac = useful_time / bound if bound else 0.0
    mem = cell.get("memory_analysis", {})
    peak = (
        mem.get("argument_size_in_bytes", 0)
        + mem.get("temp_size_in_bytes", 0)
        + mem.get("output_size_in_bytes", 0)
        - mem.get("alias_size_in_bytes", 0)
    )
    # Fused lower bound on HBM traffic: the HLO 'bytes accessed' proxy
    # reflects this backend's (CPU) fusion decisions and overcounts what a
    # fused TPU program moves.  Minimum = read every argument + write every
    # output once + layer-boundary activation traffic (saved fwd / read
    # bwd / written grads for train; streamed once for serve).
    from repro.configs import get_arch

    try:
        cfg = get_arch(cell["arch"])
        dp = n // 16  # model axis is 16 on both meshes
        tokens_local = sh.global_batch * (
            sh.seq_len if sh.kind != "decode" else 1
        ) / max(dp, 1)
        bound_factor = 3.0 if sh.kind == "train" else 1.0
        boundary = bound_factor * cfg.n_layers * tokens_local * cfg.d_model * 2
    except Exception:
        boundary = 0.0
    min_bytes = (
        mem.get("argument_size_in_bytes", 0)
        + mem.get("output_size_in_bytes", 0)
        + boundary
    )
    memory_min_s = min_bytes / hbm_bw
    bound_fused = max(compute_s, memory_min_s, collective_s)
    frac_fused = useful_time / bound_fused if bound_fused else 0.0
    return RooflineRow(
        arch=cell["arch"], shape=cell["shape"], mesh=cell["mesh"], status="ok",
        n_chips=n, compute_s=compute_s, memory_s=memory_s,
        memory_min_s=memory_min_s,
        collective_s=collective_s, dominant=dominant,
        model_flops=model_flops, hlo_flops_global=hlo_global,
        useful_ratio=useful, roofline_fraction=frac,
        fraction_fused=frac_fused,
        peak_mem_gb=peak / 1e9,
        profile=prof_name,
    )


def analyze_all(results_dir: str = RESULTS_DIR, mesh: Optional[str] = "single") -> list:
    rows = [analyze_cell(c) for c in load_cells(results_dir)]
    if mesh:
        rows = [r for r in rows if r.mesh == mesh or r.status != "ok"]
    return rows


def render_markdown(rows: list) -> str:
    hdr = (
        "| arch | shape | chips | compute_s | memory_s (hlo/min) | "
        "collective_s | dominant | MODEL/HLO | frac (hlo/fused) | "
        "peak GB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in sorted(rows, key=lambda r: (r.arch, r.shape)):
        if r.status == "skipped":
            lines.append(
                f"| {r.arch} | {r.shape} | — | — | — | — | skipped | — | — | — |"
            )
            continue
        lines.append(
            f"| {r.arch} | {r.shape} | {r.n_chips} | {r.compute_s:.4f} | "
            f"{r.memory_s:.3f} / {r.memory_min_s:.3f} | {r.collective_s:.4f} | "
            f"**{r.dominant}** | {r.useful_ratio:.3f} | "
            f"{r.roofline_fraction:.3f} / {r.fraction_fused:.3f} | "
            f"{r.peak_mem_gb:.1f} |"
        )
    return hdr + "\n".join(lines) + "\n"


def pick_hillclimb_cells(rows: list) -> dict:
    """The three §Perf cells: worst roofline fraction, most collective-bound,
    most representative of the paper's technique (the TNN pillar is separate;
    for the LM pillar we take the largest-scale MoE cell — the arch whose
    silicon-cost-forecasting analogue the paper motivates)."""
    ok = [r for r in rows if r.status == "ok"]
    worst = min(ok, key=lambda r: r.roofline_fraction)
    coll = max(ok, key=lambda r: (r.collective_s / max(r.bound_s, 1e-12)))
    moe = [r for r in ok if r.arch.startswith("kimi")] or ok
    rep = max(moe, key=lambda r: r.model_flops)
    return {"worst_fraction": worst, "most_collective_bound": coll,
            "paper_representative": rep}
