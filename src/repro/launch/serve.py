"""Serving launcher: prefill a batch of prompts, decode with KV/state caches.

    python -m repro.launch.serve --arch mamba2-370m --smoke --tokens 16

Exercises the exact serve_step paths the decode/long dry-run cells lower:
prefill -> init caches -> N decode steps, with batched requests.
"""
import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch
    from repro.models import transformer as T

    cfg = get_arch(args.arch, smoke=args.smoke)
    rng = jax.random.key(0)
    params = T.init_params(rng, cfg)
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.fold_in(rng, 1), (B, S), 0, cfg.vocab_size)
    frames = None
    if cfg.family == "audio":
        frames = jnp.zeros((B, cfg.enc_seq, cfg.d_model),
                           jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)

    max_len = S + args.tokens + 1
    t0 = time.perf_counter()
    cache, logits = T.prefill(params, prompts, cfg, max_len=max_len, frames=frames)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(lambda c, t: T.decode_step(params, c, t, cfg))
    tok = jnp.argmax(logits[:, -1:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        cache, logits = decode(cache, tok)
        tok = jnp.argmax(logits[:, -1:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(np.asarray(tok))
    t_decode = time.perf_counter() - t0
    gen = np.concatenate(out, axis=1)
    print(f"[serve] {cfg.name}: prefill[{B}x{S}] {t_prefill*1e3:.1f} ms, "
          f"{args.tokens} tokens in {t_decode*1e3:.1f} ms "
          f"({t_decode/max(args.tokens-1,1)*1e3:.1f} ms/tok)")
    print(f"[serve] sample generations: {gen[:2, :8].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
