import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)
# ^ MUST precede any jax import (device count locks at first init).
"""§Perf hillclimb harness: re-measure one dry-run cell under a config
override and report the three roofline terms for before/after logging.

    python -m repro.launch.hillclimb --arch kimi-k2-1t-a32b --shape train_4k \
        --set moe_impl=ragged --tag baseline_ragged

Writes results/perf/<arch>__<shape>__<tag>.json and prints the terms.
Overrides are dataclasses.replace fields on the arch's full() config.
"""
import argparse
import dataclasses
import json
import sys


def _coerce(cfg, key: str, val: str):
    f = {f.name: f for f in dataclasses.fields(cfg)}[key]
    t = f.type if isinstance(f.type, type) else type(getattr(cfg, key))
    cur = getattr(cfg, key)
    if isinstance(cur, bool):
        return val.lower() in ("1", "true", "yes")
    if isinstance(cur, int):
        return int(val)
    if isinstance(cur, float):
        return float(val)
    return val


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--set", nargs="*", default=[], metavar="KEY=VALUE")
    ap.add_argument("--tag", required=True)
    args = ap.parse_args()

    import repro.configs as C
    from repro.launch import dryrun
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import analysis

    cfg = C.get_arch(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = _coerce(cfg, k, v)
    cfg = dataclasses.replace(cfg, **overrides)

    shape = C.SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    main_res = dryrun._compile_and_analyze(cfg, shape, mesh)
    u1, u2 = 2, 4
    c1 = dryrun._compile_and_analyze(dryrun._cost_variant(cfg, u1, shape.seq_len), shape, mesh)
    c2 = dryrun._compile_and_analyze(dryrun._cost_variant(cfg, u2, shape.seq_len), shape, mesh)
    ex = dryrun._extrapolate(c1, c2, u1, u2, dryrun._full_units(cfg))

    cell = {
        "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
        "status": "ok", "n_chips": 512 if args.mesh == "multi" else 256,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "tag": args.tag, "overrides": overrides,
        **main_res, "extrapolated": ex,
    }
    row = analysis.analyze_cell(cell)
    cell["roofline"] = {
        "compute_s": row.compute_s, "memory_s": row.memory_s,
        "collective_s": row.collective_s, "dominant": row.dominant,
        "useful_ratio": row.useful_ratio,
        "roofline_fraction": row.roofline_fraction,
        "peak_mem_gb": row.peak_mem_gb,
    }
    os.makedirs("results/perf", exist_ok=True)
    path = f"results/perf/{args.arch}__{args.shape}__{args.tag}.json"
    with open(path, "w") as f:
        json.dump(cell, f, indent=2)
    print(f"[hillclimb] {args.tag}: compute={row.compute_s:.4f}s "
          f"memory={row.memory_s:.4f}s collective={row.collective_s:.4f}s "
          f"dominant={row.dominant} frac={row.roofline_fraction:.4f} "
          f"peak={row.peak_mem_gb:.1f}GB -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
