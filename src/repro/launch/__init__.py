# Launchers: production mesh construction (mesh.py), the multi-pod dry-run
# (dryrun.py — sets XLA_FLAGS before importing jax; import it first or run
# as __main__), training (train.py) and serving (serve.py) drivers.
from repro.launch import mesh  # noqa: F401
