"""Training launcher.

    python -m repro.launch.train --arch qwen3-14b --smoke --steps 20
    python -m repro.launch.train --arch olmoe-1b-7b --mesh 2x2 ...

On real hardware this process is started once per host by the cluster
manager (GKE/Borg); ``jax.distributed.initialize()`` picks up the pod
topology.  Here it drives the same Trainer on CPU (smoke configs) or on a
forced host-device mesh, exercising the identical code paths: sharded jit,
microbatching, async checkpointing, straggler monitoring, elastic resume.
"""
import argparse
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="e.g. '2x4' -> (data=2, model=4) host-device mesh")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    if args.mesh:
        n = 1
        for d in args.mesh.split("x"):
            n *= int(d)
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n} "
            + os.environ.get("XLA_FLAGS", "")
        )

    import jax

    from repro.configs import get_arch
    from repro.data.tokens import DataConfig
    from repro.distributed.train_loop import TrainConfig, Trainer

    arch = get_arch(args.arch, smoke=args.smoke)
    mesh = None
    if args.mesh:
        dims = tuple(int(d) for d in args.mesh.split("x"))
        axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        mesh = jax.make_mesh(dims, axes)

    data_cfg = DataConfig(
        vocab_size=arch.vocab_size,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
    )
    train_cfg = TrainConfig(
        steps=args.steps,
        microbatches=args.microbatches,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
        peak_lr=args.lr,
        compress_grads=args.compress_grads,
    )
    trainer = Trainer(arch, data_cfg, train_cfg, mesh=mesh)
    out = trainer.run()
    losses = out["losses"]
    print(f"[train] {args.arch}: {len(losses)} steps, "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}, "
          f"median step {trainer.monitor.median_s*1e3:.1f} ms, "
          f"stragglers {len(trainer.monitor.events)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
