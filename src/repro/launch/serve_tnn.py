"""TNN serving launcher: a live ClusteringService under synthetic streams.

    python -m repro.launch.serve_tnn --smoke
    python -m repro.launch.serve_tnn --streams 64 --requests 8

Stands up the streaming NSPU clustering service (``repro.serve``) over a
small fleet of heterogeneous column designs, warms every envelope bucket's
executables, then multiplexes ``--streams`` synthetic time-series streams
round-robin through admission -> encode -> bucket-dispatch -> assign ->
online re-fit, and prints sustained requests/sec, latency percentiles and
the service stats.  ``--smoke`` shrinks everything for CI.  See
``docs/serving.md``.
"""
import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometry + few requests (CI)")
    ap.add_argument("--designs", type=int, default=4)
    ap.add_argument("--streams", type=int, default=64,
                    help="concurrent synthetic streams (round-robin)")
    ap.add_argument("--requests", type=int, default=8,
                    help="requests per stream")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--refit-every", type=int, default=64)
    ap.add_argument("--length", type=int, default=24,
                    help="series length (= synapses under latency coding)")
    ap.add_argument("--t-max", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.smoke:
        args.designs = min(args.designs, 2)
        args.streams = min(args.streams, 8)
        args.requests = min(args.requests, 4)
        args.batch = min(args.batch, 4)
        args.refit_every = min(args.refit_every, 8)
        args.length = min(args.length, 12)
        args.t_max = min(args.t_max, 16)

    import numpy as np

    from repro.core import simulator
    from repro.core.types import ColumnConfig
    from repro.serve import ClusteringService, RequestRejected

    # heterogeneous q/t_max so several designs share one stream length but
    # (beyond the tightened waste cap below) split into more than one
    # envelope bucket at the default geometry
    cfgs = {}
    for i in range(args.designs):
        c = ColumnConfig(
            p=args.length, q=3 + 2 * (i % 2),
            t_max=args.t_max * (1 + (i // 2) % 2),
        )
        cfgs[f"nspu{i}"] = c.with_threshold(simulator.suggest_threshold(c))

    service = ClusteringService(
        cfgs, batch_size=args.batch, refit_every=args.refit_every,
        refit_window=max(args.batch, args.refit_every), seed=args.seed,
        waste_cap=2.0,
    )
    warm = service.warmup()
    print(f"[serve_tnn] {len(cfgs)} designs in {warm['buckets']} bucket(s), "
          f"warmup {warm['seconds']*1e3:.0f} ms")
    for b in service.buckets():
        print(f"[serve_tnn]   envelope {b['envelope']} <- {b['designs']}")

    names = list(cfgs)
    streams = [
        np.random.default_rng(args.seed + s) for s in range(args.streams)
    ]
    handles = []
    t0 = time.perf_counter()
    for r in range(args.requests):
        for s, rng in enumerate(streams):
            design = names[s % len(names)]
            series = rng.normal(size=args.length)
            try:
                handles.append(service.submit(series, design))
            except RequestRejected as e:  # not expected on this driver
                print(f"[serve_tnn] rejected: {e}")
    service.flush()
    elapsed = time.perf_counter() - t0

    lat = sorted(
        h.result().latency_s for h in handles if h.result() is not None
    )
    stats = service.stats()
    n = len(lat)
    rps = n / max(elapsed, 1e-9)
    p50 = lat[n // 2] * 1e3 if n else float("nan")
    p99 = lat[min(n - 1, int(n * 0.99))] * 1e3 if n else float("nan")
    print(f"[serve_tnn] {n} requests over {args.streams} streams in "
          f"{elapsed*1e3:.0f} ms -> {rps:.0f} req/s "
          f"(p50 {p50:.2f} ms, p99 {p99:.2f} ms)")
    print(f"[serve_tnn] stats: {stats}")
    if stats.served != len(handles) or stats.failed or stats.pending:
        print("[serve_tnn] FAILED: not every request served")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
