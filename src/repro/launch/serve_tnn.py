"""TNN serving launcher: a live ClusteringService under synthetic streams.

    python -m repro.launch.serve_tnn --smoke
    python -m repro.launch.serve_tnn --streams 64 --requests 8
    python -m repro.launch.serve_tnn --durable-dir /tmp/svc   # crash-safe

Stands up the streaming NSPU clustering service (``repro.serve``) over a
small fleet of heterogeneous column designs, warms every envelope bucket's
executables, then multiplexes ``--streams`` synthetic time-series streams
round-robin through admission -> encode -> bucket-dispatch -> assign ->
online re-fit, and prints sustained requests/sec, latency percentiles and
the service stats.

SIGTERM triggers a graceful drain: admission stops, every in-flight
request is served, and (with ``--durable-dir``) a final snapshot is
published before exit — zero dropped requests, exit 0.  ``--smoke``
shrinks everything for CI and raises SIGTERM on itself mid-run so the
drain path is exercised on every CI pass.  A ``--durable-dir`` that
already holds a durable service is resumed via
``ClusteringService.recover`` (weights restored bit-identical from
snapshot + WAL).  See ``docs/serving.md``.
"""
import argparse
import os
import signal
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometry + few requests + self-SIGTERM (CI)")
    ap.add_argument("--designs", type=int, default=4)
    ap.add_argument("--streams", type=int, default=64,
                    help="concurrent synthetic streams (round-robin)")
    ap.add_argument("--requests", type=int, default=8,
                    help="requests per stream")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--refit-every", type=int, default=64)
    ap.add_argument("--length", type=int, default=24,
                    help="series length (= synapses under latency coding)")
    ap.add_argument("--t-max", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--durable-dir", default=None,
                    help="snapshot+WAL directory; an existing durable "
                         "service there is resumed, a fresh directory is "
                         "initialized")
    args = ap.parse_args(argv)
    if args.smoke:
        args.designs = min(args.designs, 2)
        args.streams = min(args.streams, 8)
        args.requests = min(args.requests, 4)
        args.batch = min(args.batch, 4)
        args.refit_every = min(args.refit_every, 8)
        args.length = min(args.length, 12)
        args.t_max = min(args.t_max, 16)

    import numpy as np

    from repro.core import simulator
    from repro.core.types import ColumnConfig
    from repro.serve import ClusteringService, RequestRejected, durability

    # heterogeneous q/t_max so several designs share one stream length but
    # (beyond the tightened waste cap below) split into more than one
    # envelope bucket at the default geometry
    cfgs = {}
    for i in range(args.designs):
        c = ColumnConfig(
            p=args.length, q=3 + 2 * (i % 2),
            t_max=args.t_max * (1 + (i // 2) % 2),
        )
        cfgs[f"nspu{i}"] = c.with_threshold(simulator.suggest_threshold(c))

    resumed = bool(
        args.durable_dir
        and os.path.exists(os.path.join(args.durable_dir,
                                        durability.META_FILE))
    )
    if resumed:
        service = ClusteringService.recover(
            args.durable_dir, batch_size=args.batch,
            refit_every=args.refit_every,
        )
        print(f"[serve_tnn] resumed durable service from "
              f"{args.durable_dir} (replayed "
              f"{service.stats().replayed} WAL re-fit(s))")
    else:
        service = ClusteringService(
            cfgs, batch_size=args.batch, refit_every=args.refit_every,
            refit_window=max(args.batch, args.refit_every), seed=args.seed,
            waste_cap=2.0, durable_dir=args.durable_dir,
        )
    warm = service.warmup()
    print(f"[serve_tnn] {len(service.designs())} designs in "
          f"{warm['buckets']} bucket(s), warmup {warm['seconds']*1e3:.0f} ms")
    for b in service.buckets():
        print(f"[serve_tnn]   envelope {b['envelope']} <- {b['designs']}")

    # graceful shutdown: SIGTERM stops admission and drains in-flight work
    term_requested = []
    prev_handler = signal.signal(
        signal.SIGTERM, lambda *_: term_requested.append(True)
    )

    names = list(service.designs())
    streams = [
        np.random.default_rng(args.seed + s) for s in range(args.streams)
    ]
    handles = []
    drained = False
    t0 = time.perf_counter()
    try:
        for r in range(args.requests):
            if term_requested:
                break
            for s, rng in enumerate(streams):
                if term_requested:
                    break
                design = names[s % len(names)]
                series = rng.normal(size=args.length)
                try:
                    handles.append(service.submit(series, design))
                except RequestRejected as e:  # not expected on this driver
                    print(f"[serve_tnn] rejected: {e}")
                if (args.smoke and r == args.requests // 2
                        and s == args.streams // 2):
                    # exercise the drain path on every CI pass: ask
                    # ourselves to shut down mid-round, with requests
                    # still queued behind a partial batch
                    print("[serve_tnn] smoke: raising SIGTERM on self")
                    signal.raise_signal(signal.SIGTERM)
        if term_requested:
            in_flight = sum(1 for h in handles if not h.done)
            print(f"[serve_tnn] SIGTERM: draining "
                  f"({in_flight} request(s) in flight)")
            service.drain()
            drained = True
        else:
            service.flush()
    finally:
        signal.signal(signal.SIGTERM, prev_handler)
    elapsed = time.perf_counter() - t0

    lat = sorted(
        h.result().latency_s for h in handles if h.result() is not None
    )
    stats = service.stats()
    n = len(lat)
    rps = n / max(elapsed, 1e-9)
    p50 = lat[n // 2] * 1e3 if n else float("nan")
    p99 = lat[min(n - 1, int(n * 0.99))] * 1e3 if n else float("nan")
    print(f"[serve_tnn] {n} requests over {args.streams} streams in "
          f"{elapsed*1e3:.0f} ms -> {rps:.0f} req/s "
          f"(p50 {p50:.2f} ms, p99 {p99:.2f} ms)")
    print(f"[serve_tnn] stats: {stats}")

    dropped = sum(1 for h in handles if not h.done)
    if drained:
        if dropped or stats.failed or stats.pending:
            print(f"[serve_tnn] FAILED: drain dropped {dropped} request(s)")
            return 1
        print(f"[serve_tnn] drained cleanly: {len(handles)} admitted, "
              "0 dropped")
        return 0
    if stats.served != len(handles) or stats.failed or stats.pending:
        print("[serve_tnn] FAILED: not every request served")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
