"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; callers decide when devices are materialized.

Topology targeted: TPU v5e pods — 16x16 (256 chips) per pod; the multi-pod
mesh adds a leading "pod" axis (2 pods = 512 chips).  Axis semantics:
  pod   — data parallelism across pods (DCN links; gradient compression
          applies here),
  data  — FSDP + data parallelism within a pod,
  model — TP / EP / SP.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary meshes for elastic-scaling tests and CPU smokes."""
    return jax.make_mesh(shape, axes)
