import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS_EXTRA", "")
)
# ^ MUST precede any jax import (device count locks at first init).
"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory/cost/collective analyses.

Usage:
    python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--jobs 2]
    python -m repro.launch.dryrun --list

Each cell writes results/dryrun/<arch>__<shape>__<mesh>.json containing:
  memory_analysis (per-device bytes), cost_analysis (flops / bytes),
  collective byte totals by op kind (parsed from post-SPMD HLO), and
  analytic MODEL_FLOPS for the roofline report (benchmarks/roofline.py).

``--all`` fans cells out to subprocesses (fresh XLA per cell: compile RAM
is returned to the OS, and a pathological cell cannot wedge the sweep).
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.distributed import sharding
from repro.distributed.optimizer import Schedule, make_optimizer
from repro.launch.hlo import collective_bytes_by_kind
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.config import ArchConfig

RESULTS_DIR = "results/dryrun"


def _param_shapes(cfg: ArchConfig):
    return jax.eval_shape(lambda: T.init_params(jax.random.key(0), cfg))


def _cache_shapes(cfg: ArchConfig, batch: int, max_len: int):
    enc = cfg.enc_seq if cfg.family == "audio" else 0
    return jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len, enc_len=enc))


def build_lowered(cfg: ArchConfig, shape: C.ShapeSpec, mesh):
    """Construct and lower the cell's step function (no allocation)."""
    T.set_mesh(mesh)
    p_shapes = _param_shapes(cfg)
    # serving drops the FSDP factor (kills per-layer weight all-gathers)
    # whenever the TP-sharded weights fit HBM (everything but kimi-k2)
    serve = (
        shape.kind != "train"
        and cfg.param_count() * 2 / mesh.shape["model"] < 12e9
    )
    p_shard = sharding.to_shardings(
        sharding.param_specs(p_shapes, mesh, serve=serve), mesh
    )
    specs = C.input_specs(cfg, shape)
    b_shard = sharding.to_shardings(sharding.batch_specs(specs, mesh), mesh)

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer, Schedule())
        o_shapes = jax.eval_shape(
            lambda: opt.init(jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p_shapes))
        )
        from repro.distributed.train_loop import _opt_specs

        o_shard = sharding.to_shardings(_opt_specs(o_shapes, p_shapes, mesh), mesh)

        def step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: T.loss_fn(p, batch, cfg), has_aux=True
            )(params)
            params, opt_state = opt.update(params, grads, opt_state)
            return params, opt_state, loss

        fn = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        return fn.lower(p_shapes, o_shapes, specs)

    if shape.kind == "prefill":
        c_shapes = _cache_shapes(cfg, shape.global_batch, shape.seq_len)
        c_shard = sharding.to_shardings(sharding.cache_specs(c_shapes, mesh), mesh)

        def step(params, batch):
            return T.prefill(
                params, batch["tokens"], cfg, max_len=shape.seq_len,
                frames=batch.get("frames"),
            )

        fn = jax.jit(
            step,
            in_shardings=(p_shard, b_shard),
            out_shardings=(c_shard, None),
        )
        return fn.lower(p_shapes, specs)

    # decode: one new token against a seq_len cache
    c_shapes = _cache_shapes(cfg, shape.global_batch, shape.seq_len)
    c_shard = sharding.to_shardings(sharding.cache_specs(c_shapes, mesh), mesh)

    def step(params, cache, batch):
        return T.decode_step(params, cache, batch["tokens"], cfg)

    fn = jax.jit(
        step,
        in_shardings=(p_shard, c_shard, sharding.to_shardings(
            sharding.batch_specs(C.input_specs(cfg, shape), mesh), mesh)),
        out_shardings=(c_shard, None),
        donate_argnums=(1,),
    )
    return fn.lower(p_shapes, c_shapes, specs)


def _compile_and_analyze(cfg, shape, mesh) -> dict:
    t0 = time.time()
    lowered = build_lowered(cfg, shape, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_d[k] = int(v)
    cost = compiled.cost_analysis() or {}
    cost_d = {k: float(v) for k, v in cost.items()
              if isinstance(v, (int, float)) and k in
              ("flops", "bytes accessed", "bytes accessed output",
               "utilization", "transcendentals")}
    n_dev = mesh.devices.size
    coll = collective_bytes_by_kind(compiled.as_text(), total_devices=n_dev)
    return {
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem_d, "cost_analysis": cost_d,
        "collective_bytes": coll,
    }


def _cost_variant(cfg: ArchConfig, units: int, seq_len: int) -> ArchConfig:
    """Unrolled small variant for per-layer cost measurement.

    units = #layers (dense/moe/vlm/ssm), #superblocks (hybrid: attn_every
    ssm blocks + 1 shared attn each), or #(enc+dec) layer pairs (audio).
    unroll_scans=True unrolls BOTH the layer scan and the flash-attention
    kv-chunk scan, so cost analysis (which counts while bodies once) sees
    every layer and every kv chunk of the REAL chunked program — kv_chunk
    stays unchanged so byte counts reflect the flash working set, not a
    materialized quadratic attention.
    """
    kw = dict(unroll_scans=True)
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, n_layers=units * cfg.attn_every, **kw)
    if cfg.family == "audio":
        return dataclasses.replace(cfg, n_layers=units, enc_layers=units, **kw)
    return dataclasses.replace(cfg, n_layers=units, **kw)


def _full_units(cfg: ArchConfig) -> float:
    if cfg.family == "hybrid":
        n_apps = cfg.n_layers // cfg.attn_every
        tail = cfg.n_layers - n_apps * cfg.attn_every
        return n_apps + tail / cfg.attn_every  # tail ssm blocks ~ fractional
    return float(cfg.n_layers)


def _extrapolate(c1: dict, c2: dict, u1: int, u2: int, units: float) -> dict:
    """Linear-in-units extrapolation from unrolled variants at u1 < u2
    units: per_unit = (c(u2) - c(u1)) / (u2 - u1), total(u) = c(u1) +
    (u - u1) * per_unit.  Per-unit deltas are clamped at >= 0 (XLA
    sometimes optimizes small variants differently; a negative slope is an
    artifact, not physics)."""
    du = float(u2 - u1)

    def extrap(a, b):
        per = max((b - a) / du, 0.0)
        return a + (units - u1) * per, per

    out = {}
    for key in ("flops", "bytes accessed", "transcendentals"):
        a = c1["cost_analysis"].get(key)
        b = c2["cost_analysis"].get(key)
        if a is not None and b is not None:
            out[key], _ = extrap(a, b)
    coll = {}
    for k in c1["collective_bytes"]:
        if k in ("counts", "largest", "total"):
            continue
        coll[k], _ = extrap(c1["collective_bytes"][k], c2["collective_bytes"][k])
    coll["total"] = sum(v for k, v in coll.items() if k != "total")
    out["collective_bytes"] = coll
    out["units_full"] = units
    out["per_unit"] = {
        "flops": max(
            (c2["cost_analysis"].get("flops", 0.0)
             - c1["cost_analysis"].get("flops", 0.0)) / du, 0.0),
        "collective_total": max(
            (c2["collective_bytes"]["total"]
             - c1["collective_bytes"]["total"]) / du, 0.0),
    }
    return out


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             with_cost_variants: bool = None) -> dict:
    cfg = C.get_arch(arch_id)
    shape = C.SHAPES[shape_name]
    reason = C.skip_reason(cfg, shape_name)
    if reason:
        return {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    if with_cost_variants is None:
        # the roofline table is single-pod; multi-pod cells only need the
        # main compile (the pod-axis sharding proof)
        with_cost_variants = mesh_kind == "single"
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    main = _compile_and_analyze(cfg, shape, mesh)

    n_chips = 512 if mesh_kind == "multi" else 256
    toks = shape.global_batch * (shape.seq_len if shape.kind == "train" else 1)
    result = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok",
        "n_chips": n_chips,
        "tokens_per_step": toks,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "model_flops_per_step": cfg.model_flops_per_token() * toks
        * (3.0 if shape.kind == "train" else 1.0),
        **main,
    }
    print(f"[dryrun] {arch_id} x {shape_name} x {mesh_kind}: "
          f"compile {main['compile_s']:.0f}s")
    print(f"  memory_analysis: {main['memory_analysis']}")
    print(f"  cost_analysis:   {main['cost_analysis']}")
    print(f"  collectives:     {main['collective_bytes']}")

    if with_cost_variants:
        # per-layer cost from unrolled 1- and 2-unit variants (while bodies
        # are otherwise counted once by HloCostAnalysis; see launch/hlo.py)
        u1, u2 = 2, 4
        c1 = _compile_and_analyze(_cost_variant(cfg, u1, shape.seq_len), shape, mesh)
        c2 = _compile_and_analyze(_cost_variant(cfg, u2, shape.seq_len), shape, mesh)
        result["extrapolated"] = _extrapolate(c1, c2, u1, u2, _full_units(cfg))
        result["cost_variants"] = {"c1": c1, "c2": c2}
        print(f"  extrapolated:    {result['extrapolated']}")
    return result


def save_result(res: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(
        RESULTS_DIR, f"{res['arch']}__{res['shape']}__{res['mesh']}.json"
    )
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    return path


def all_cells(meshes=("single", "multi")) -> list:
    cells = []
    for arch_id in C.ARCH_IDS:
        cfg = C.get_arch(arch_id)
        for shape_name in C.SHAPES:
            for mesh_kind in meshes:
                cells.append((arch_id, shape_name, mesh_kind))
    return cells


def _run_all(meshes, jobs: int, force: bool) -> int:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    todo = []
    for arch_id, shape_name, mesh_kind in all_cells(meshes):
        path = os.path.join(
            RESULTS_DIR, f"{arch_id}__{shape_name}__{mesh_kind}.json"
        )
        if not force and os.path.exists(path):
            continue
        todo.append((arch_id, shape_name, mesh_kind))
    print(f"[dryrun] {len(todo)} cells to run, {jobs} jobs")
    procs: list = []
    failed = []
    while todo or procs:
        while todo and len(procs) < jobs:
            a, s, m = todo.pop(0)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m]
            procs.append(((a, s, m), subprocess.Popen(cmd)))
            print(f"[dryrun] started {a} x {s} x {m}")
        time.sleep(2)
        still = []
        for cell, p in procs:
            if p.poll() is None:
                still.append((cell, p))
            elif p.returncode != 0:
                failed.append(cell)
                print(f"[dryrun] FAILED {cell}")
            else:
                print(f"[dryrun] done {cell}")
        procs = still
    if failed:
        print(f"[dryrun] {len(failed)} failures: {failed}")
        return 1
    print("[dryrun] all cells complete")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=C.ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(C.SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.list:
        for cell in all_cells():
            print(*cell)
        return 0
    if args.all:
        meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
        return _run_all(meshes, args.jobs, args.force)
    assert args.arch and args.shape, "--arch/--shape required without --all"
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    for m in meshes:
        res = run_cell(args.arch, args.shape, m)
        save_result(res)
        if res["status"] not in ("ok", "skipped"):
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
