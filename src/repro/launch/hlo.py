"""Post-SPMD HLO analysis: collective byte accounting for the roofline.

``cost_analysis`` does not expose collective traffic, so we parse the
compiled module text.  Per-device wire-byte accounting with ring algorithms
over a group of N participants (result shape R bytes is always printed;
operand shapes often are not):

  all-gather          R * (N-1)/N        (result is the gathered buffer)
  all-reduce          R * 2(N-1)/N       (reduce-scatter + all-gather phases)
  reduce-scatter      R * (N-1)          (operand = N*R, each device sends
                                          (N-1)/N of it)
  all-to-all          R * (N-1)/N
  collective-permute  R                  (point-to-point)

Group size N comes from ``replica_groups``: iota form `[G,N]<=[...]`,
explicit `{{0,1},{2,3}}`, or empty (= all devices).  NOTE: ops inside
`while` bodies are counted ONCE — the roofline pipeline therefore measures
per-layer costs on UNROLLED 1/2-layer variants and extrapolates (see
launch/dryrun.py and benchmarks/roofline.py).
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(
    r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]"
)

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)

_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _result_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return max(total_devices, 1)


def collective_bytes_by_kind(hlo_text: str, total_devices: int = 1) -> dict:
    """Sum per-device collective wire bytes by op kind from compiled HLO.

    '-done' ops are skipped (async pairs would double count with their
    '-start').  Returns {kind: bytes, 'total': ..., 'counts': {...}}.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    largest: list = []
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        result_text, kind, _start = m.groups()
        r = _result_bytes(result_text)
        n = _group_size(line, total_devices)
        if kind == "all-gather":
            b = r * (n - 1) // max(n, 1)
        elif kind == "all-reduce":
            b = 2 * r * (n - 1) // max(n, 1)
        elif kind == "reduce-scatter":
            b = r * (n - 1)
        elif kind == "all-to-all":
            b = r * (n - 1) // max(n, 1)
        else:  # collective-permute
            b = r
        out[kind] += b
        counts[kind] += 1
        meta = ""
        mm = re.search(r'op_name="([^"]{0,120})', line)
        if mm:
            meta = mm.group(1)
        largest.append((b, kind, result_text.strip()[:60], meta))
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    largest.sort(key=lambda t: -t[0])
    out["largest"] = [
        {"bytes": b, "kind": k, "shape": sh, "op": op}
        for b, k, sh, op in largest[:8]
    ]
    return out
