"""Streaming NSPU clustering service — the request front-end of the repo.

``ClusteringService`` holds a set of trained (or training) TNN column
designs behind an admission -> encode -> bucket-dispatch -> assign ->
re-fit pipeline: designs pack into shared padding envelopes
(``backend.envelope_buckets``), each bucket keeps ONE compiled assignment
executable and ONE re-fit executable resident through the AOT front doors
(``backend.fit_padded`` / ``assign_padded``), incoming series are
latency-encoded and micro-batched by envelope into the grid-batched
assignment fire, and the live weights keep learning via periodic online
STDP re-fits that resume the fused scan from the served stream (the
donated-weight contract).  With a ``durable_dir`` the service is also
crash-safe: live-weight snapshots plus a re-fit volley WAL
(``serve.durability``) let ``ClusteringService.recover(dir)`` restore
weights bit-identical to the uninterrupted service.  Admission is
overload-safe — bounded queues and per-request deadline budgets shed
structured ``RequestRejected`` / ``ServeShed`` before any JAX work — and
failed re-fits degrade to serving from last-good weights instead of
taking the service down.  See ``docs/serving.md``.
"""
from repro.serve import durability
from repro.serve.service import (
    ClusteringService,
    PendingRequest,
    RequestRejected,
    ServeFailure,
    ServeResult,
    ServeShed,
    ServeStats,
)

__all__ = [
    "ClusteringService",
    "PendingRequest",
    "RequestRejected",
    "ServeFailure",
    "ServeResult",
    "ServeShed",
    "ServeStats",
    "durability",
]
