"""Durable serving state: live-weight snapshots + a re-fit volley WAL.

Layout under one durable directory:

    <dir>/meta.json        service identity + serving knobs (atomic publish)
    <dir>/snapshots/       ``distributed.checkpoint.Checkpointer`` steps —
                           one step per re-fit sequence number, step 0 is
                           the initial weights, pruned to the newest two
    <dir>/wal.jsonl        append-only re-fit log SINCE the last snapshot

Durability contract (the serving analogue of the DSE journal's
kill-and-resume story, see ``docs/dse.md``):

* Live weights mutate ONLY at a successful online re-fit, so the full
  weight history is (snapshot at seq k) + (the exact re-fit windows for
  seqs k+1..n).  The WAL records each committed re-fit's input window —
  appended *after* the in-memory commit, fsync'd per append — and the
  fused scan is deterministic, so replaying the WAL on top of the
  snapshot restores weights **bit-identical** to the uninterrupted
  service.  A kill at any instant loses at most the re-fit in flight.
* Snapshots publish via the ``Checkpointer`` write-then-rename protocol
  (a preempted snapshot is never visible); the WAL is truncated only
  after its covering snapshot has published, so every committed re-fit
  is always reachable from (some published snapshot) + (the WAL tail).
* The WAL reader tolerates a torn trailing line (the un-fsync'd tail of
  a crash) by skipping it — exactly the journal's defensive-read rule.
* ``meta.json`` carries a fingerprint over the replay-relevant service
  spec (configs, encoder, seed, re-fit geometry); recovery refuses a
  directory whose fingerprint does not match the reconstructed service,
  rather than silently replaying volleys into a different fleet.
"""
from __future__ import annotations

import hashlib
import json
import os

import numpy as np

from repro.distributed.checkpoint import Checkpointer

DURABLE_VERSION = 1
META_FILE = "meta.json"
WAL_FILE = "wal.jsonl"
SNAPSHOT_DIR = "snapshots"
SNAPSHOTS_KEPT = 2


def service_fingerprint(spec: dict) -> str:
    """Deterministic identity of the replay-relevant service spec (the
    serving counterpart of ``dse.candidate_fingerprint``)."""
    blob = json.dumps(spec, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _atomic_write(path: str, text: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class VolleyWAL:
    """Append-only re-fit log with fsync'd appends and a torn-tail
    tolerant reader.

    Unlike the DSE journal (atomic full-rewrite per append, right for a
    few hundred records), the WAL is a true O(1) append per re-fit —
    the durable prefix is whatever has been fsync'd, and ``load`` skips
    any torn tail.  ``truncate_through`` (called under a fresh covering
    snapshot) rewrites atomically, journal-style.
    """

    def __init__(self, path: str):
        self.path = str(path)

    def create(self, fingerprint: str) -> None:
        header = {
            "kind": "meta", "version": DURABLE_VERSION,
            "fingerprint": fingerprint,
        }
        _atomic_write(self.path, json.dumps(header) + "\n")

    def load(self) -> list:
        """All intact records (header included); torn lines skipped."""
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    continue  # torn tail of a kill mid-append
        return out

    def validate(self, fingerprint: str) -> list:
        """Header-checked ``load``: refuses a WAL written by a service
        with a different replay spec; returns the refit records."""
        records = self.load()
        if not records or records[0].get("kind") != "meta":
            raise ValueError(f"{self.path}: missing WAL header")
        head = records[0]
        if head.get("version") != DURABLE_VERSION:
            raise ValueError(
                f"{self.path}: WAL version {head.get('version')} != "
                f"{DURABLE_VERSION}"
            )
        if head.get("fingerprint") != fingerprint:
            raise ValueError(
                f"{self.path}: WAL fingerprint {head.get('fingerprint')} "
                f"does not match this service ({fingerprint}) — refusing "
                "to replay volleys into a different fleet"
            )
        return [r for r in records[1:] if r.get("kind") == "refit"]

    def append(self, record: dict) -> None:
        with open(self.path, "a") as f:
            f.write(json.dumps(record) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def truncate_through(self, seq: int, fingerprint: str) -> None:
        """Atomically drop records with ``seq`` <= the covering snapshot's
        (they are now redundant); keep any newer tail."""
        keep = [
            r for r in self.load()
            if r.get("kind") == "refit" and r.get("seq", 0) > seq
        ]
        header = {
            "kind": "meta", "version": DURABLE_VERSION,
            "fingerprint": fingerprint,
        }
        lines = [json.dumps(header)] + [json.dumps(r) for r in keep]
        _atomic_write(self.path, "\n".join(lines) + "\n")


class DurableStore:
    """One durable directory: meta + snapshots + WAL, with the
    snapshot/WAL interplay (publish-then-truncate, prune) in one place."""

    def __init__(self, root: str):
        self.root = os.path.abspath(str(root))
        os.makedirs(self.root, exist_ok=True)
        self.ckpt = Checkpointer(os.path.join(self.root, SNAPSHOT_DIR))
        self.wal = VolleyWAL(os.path.join(self.root, WAL_FILE))
        self.fingerprint = ""
        self.pending = 0  # WAL refit records not yet covered by a snapshot

    @property
    def meta_path(self) -> str:
        return os.path.join(self.root, META_FILE)

    def exists(self) -> bool:
        return os.path.exists(self.meta_path)

    def load_meta(self) -> dict:
        if not self.exists():
            raise FileNotFoundError(
                f"{self.root}: no durable service here (missing {META_FILE})"
            )
        with open(self.meta_path) as f:
            return json.load(f)

    def create(self, meta: dict, blocks: list) -> None:
        """Initialize a fresh durable directory: meta, WAL header, and a
        blocking snapshot of the initial weights at seq 0 — recovery
        never re-derives init weights, it always restores a snapshot."""
        if self.exists():
            raise ValueError(
                f"{self.root} already holds a durable service — use "
                "ClusteringService.recover(dir) to resume it, or point "
                "durable_dir at a fresh directory"
            )
        self.fingerprint = meta["fingerprint"]
        _atomic_write(self.meta_path, json.dumps(meta, indent=2) + "\n")
        self.wal.create(self.fingerprint)
        self.ckpt.save(0, [np.asarray(b) for b in blocks], blocking=True)

    def attach(self, fingerprint: str) -> tuple:
        """Open an existing durable directory for recovery: validate the
        fingerprint, find the newest published snapshot, and return
        ``(snapshot_seq, records_to_replay)`` (WAL records newer than the
        snapshot, in sequence order)."""
        meta = self.load_meta()
        if meta.get("fingerprint") != fingerprint:
            raise ValueError(
                f"{self.meta_path}: fingerprint {meta.get('fingerprint')} "
                f"does not match the reconstructed service ({fingerprint})"
            )
        self.fingerprint = fingerprint
        step = self.ckpt.latest_step()
        if step is None:
            raise FileNotFoundError(
                f"{self.root}: no published snapshot to recover from"
            )
        records = [
            r for r in self.wal.validate(fingerprint)
            if r.get("seq", 0) > step
        ]
        records.sort(key=lambda r: r["seq"])
        self.pending = len(records)
        return step, records

    def log_refit(
        self, seq: int, bucket: int, epochs: int, lowering: str,
        xs: np.ndarray,
    ) -> None:
        self.wal.append({
            "kind": "refit", "seq": int(seq), "bucket": int(bucket),
            "epochs": int(epochs), "lowering": lowering,
            "xs": np.asarray(xs).tolist(),
        })
        self.pending += 1

    def snapshot(self, seq: int, blocks: list) -> None:
        """Publish a snapshot at ``seq`` then truncate the WAL through it
        — strictly in that order, so every committed re-fit stays
        reachable at every instant — and prune old snapshots."""
        self.ckpt.save(int(seq), [np.asarray(b) for b in blocks],
                       blocking=True)
        self.wal.truncate_through(int(seq), self.fingerprint)
        self.ckpt.prune(keep=SNAPSHOTS_KEPT)
        self.pending = 0
