"""Long-lived streaming clustering service over envelope-bucketed NSPUs.

The serving pipeline, stage by stage (each independently testable):

* **admission** — ``submit`` validates a request against its design's
  compiled envelope *before anything touches JAX*: an unknown design, a
  series whose encoded width does not match any compiled bucket, or
  non-finite samples raise a structured ``RequestRejected`` — never a
  fresh trace.
* **encode** — the series becomes a spike volley via the central encoder
  dispatch (``encoding.encode``), using the target design's gamma window.
* **bucket dispatch** — designs are packed into shared padding envelopes
  at construction (``backend.envelope_buckets``); a request rides the
  queue of its design's bucket and is batched with requests for *any*
  design in that bucket.
* **assign** — a full micro-batch (or a ``flush``-forced partial one,
  silent-padded to the compiled batch size through
  ``fused_column.pad_stream_silent``) dispatches ONE envelope-keyed AOT
  executable (``backend.assign_padded``).  After ``warmup`` the steady
  state performs zero XLA compiles: executables are keyed on
  shapes + statics, and the batch geometry never changes.
* **re-fit** — every ``refit_every`` served requests per bucket, the live
  weights take an online-STDP pass over the most recent
  ``refit_window`` volleys each design served
  (``backend.fit_padded`` — the fused scan resumed from live weights via
  its donated-weight contract).  Ragged buffers are silent-padded: for
  the positive thresholds the service enforces, a silent volley is an
  exact weight no-op, so the re-fit is bit-identical to an offline
  ``fit_padded`` resume on the same volleys.

Failures quarantine per request: if a batch raises, each live request
re-runs alone against the same executable (assignment is per-volley
independent, so batch-mates' answers are bit-identical to the batched
run) and only the poisoned request surfaces a ``ServeFailure``.

The service is synchronous and single-threaded; "concurrent streams" are
interleaved logical streams multiplexed by the caller (see
``benchmarks/serve_bench.py``, which sustains 64+ of them).  Stage
timings feed a ``distributed.straggler.StepMonitor`` so stalls are
observable through ``stats()``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_lib
from repro.core import column as column_lib
from repro.core import encoding
from repro.core.types import ColumnConfig, TIME_DTYPE
from repro.distributed.straggler import StepMonitor
from repro.kernels import fused_column


class RequestRejected(Exception):
    """Structured admission failure — raised by ``submit`` before any JAX
    work happens, so a bad request can never trigger a trace storm.

    ``reason`` is machine-readable: ``'unknown-design'``, ``'shape'``,
    ``'envelope'`` (encoded width fits no compiled bucket) or
    ``'non-finite'``.
    """

    def __init__(self, reason: str, detail: str):
        super().__init__(f"{reason}: {detail}")
        self.reason = reason
        self.detail = detail


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """One served assignment: ``cluster`` is the earliest-firing neuron
    index of the target design, or its ``q`` when the volley was silent
    (unclustered)."""

    request_id: int
    design: str
    cluster: int
    latency_s: float


@dataclasses.dataclass(frozen=True)
class ServeFailure:
    """A quarantined request: the batch it rode failed, and so did its
    solo re-run.  Batch-mates are unaffected."""

    request_id: int
    design: str
    stage: str
    error: str


@dataclasses.dataclass(frozen=True)
class ServeStats:
    submitted: int
    served: int
    rejected: int
    failed: int
    batches: int
    isolations: int
    refits: int
    stalls: int
    pending: int


class PendingRequest:
    """Handle returned by ``submit``; ``result()`` forces the request's
    bucket to flush if it is still queued."""

    def __init__(self, service: "ClusteringService", rid: int, design: str):
        self._service = service
        self.id = rid
        self.design = design
        self.outcome: Optional[Union[ServeResult, ServeFailure]] = None

    @property
    def done(self) -> bool:
        return self.outcome is not None

    def result(self) -> Union[ServeResult, ServeFailure]:
        if self.outcome is None:
            self._service.flush(self.design)
        assert self.outcome is not None
        return self.outcome


class _Request:
    __slots__ = ("pending", "lane", "enc", "t_submit")

    def __init__(self, pending, lane, enc, t_submit):
        self.pending = pending
        self.lane = lane
        self.enc = enc
        self.t_submit = t_submit


class _Bucket:
    """One envelope bucket: live weights + compiled-shape metadata + queue."""

    def __init__(self, envelope, names, cfgs, w0):
        self.envelope = envelope  # (p_env, q_env, t_window)
        self.names = list(names)
        self.cfgs = list(cfgs)
        self.w = w0  # [Db, p_env, q_env] jnp — donated through every re-fit
        self.thresholds = jnp.asarray(
            [c.neuron.threshold for c in cfgs], jnp.float32
        )
        self.t_maxes = jnp.asarray([c.t_max for c in cfgs], TIME_DTYPE)
        self.q_actives = jnp.asarray([c.q for c in cfgs], TIME_DTYPE)
        c0 = cfgs[0]
        self.fit_lowering = backend_lib.padded_lowering(c0.neuron.response)
        self.asg_lowering = backend_lib.assign_lowering(
            c0.neuron.response, self.w[0]
        )
        self.queue: list[_Request] = []
        self.buffers: list[list[np.ndarray]] = [[] for _ in cfgs]
        self.served_since_refit = 0


def _design_map(
    designs: Union[Mapping[str, ColumnConfig],
                   Sequence[tuple[str, ColumnConfig]]],
) -> dict[str, ColumnConfig]:
    if isinstance(designs, Mapping):
        return dict(designs)
    return dict(designs)


class ClusteringService:
    """Streaming front-end over a fleet of NSPU column designs.

    Args:
      designs: ``{name: ColumnConfig}`` (or ``(name, cfg)`` pairs).  All
        designs must share the fused statics (response, ``w_max``, WTA k,
        STDP mus/mode) — the same constraint as the sweep front-end — and
        every threshold must be positive (the silent-volley no-op that
        partial batches and ragged re-fits rely on).
      encoder: ``'latency'`` or ``'onoff'`` (admission uses
        ``encoding.encoded_width`` to pin series length to design width).
      batch_size: requests per compiled assignment micro-batch; a full
        queue auto-executes, ``flush`` silent-pads a partial one.
      refit_every: served requests per bucket between online re-fits
        (0 disables re-fitting).
      refit_window: volleys per design each re-fit trains on (the most
        recent served; fixes the re-fit executable's shape).
      refit_epochs: STDP epochs per re-fit.
      weights: optional ``{name: [p, q] array}`` initial weights (e.g.
        from an offline ``cluster_time_series`` fit); designs without an
        entry draw ``column.init_params`` from ``fold_in(seed, index)``.
      monitor: a ``StepMonitor`` for stage timings (one is created by
        default; stalls surface in ``stats()``).
    """

    def __init__(
        self,
        designs,
        *,
        encoder: str = "latency",
        batch_size: int = 16,
        refit_every: int = 64,
        refit_window: int = 32,
        refit_epochs: int = 1,
        seed: int = 0,
        weights: Optional[Mapping[str, np.ndarray]] = None,
        waste_cap: Optional[float] = None,
        max_bucket: Optional[int] = None,
        monitor: Optional[StepMonitor] = None,
    ):
        cfg_map = _design_map(designs)
        if not cfg_map:
            raise ValueError("service needs at least one design")
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if refit_window < 1:
            raise ValueError("refit_window must be >= 1")
        # unknown encoder raises here, at construction
        encoding.encoded_width(1, encoder)
        self.encoder = encoder
        self.batch_size = int(batch_size)
        self.refit_every = int(refit_every)
        self.refit_window = int(refit_window)
        self.refit_epochs = int(refit_epochs)
        self.monitor = monitor if monitor is not None else StepMonitor(
            threshold=4.0, warmup=3
        )

        names = list(cfg_map)
        cfgs = [cfg_map[n] for n in names]
        c0 = cfgs[0]
        for n, c in zip(names, cfgs):
            fused_column.check_fusable(
                c, backend_lib.padded_lowering(c.neuron.response)
            )
            if c.neuron.threshold <= 0:
                raise ValueError(
                    f"design {n!r}: threshold must be > 0 — the service "
                    "pads partial batches and ragged re-fit windows with "
                    "silent volleys, which are weight no-ops only above "
                    "threshold 0"
                )
            same = (
                c.neuron.response == c0.neuron.response
                and c.neuron.w_max == c0.neuron.w_max
                and c.wta == c0.wta
                and c.stdp == c0.stdp
            )
            if not same:
                raise ValueError(
                    f"design {n!r}: all designs must share response/w_max/"
                    "WTA/STDP statics (one compiled program per bucket)"
                )
        self._cfgs = cfg_map
        self._statics = dict(
            w_max=c0.neuron.w_max, wta_k=c0.wta.k,
            mu_capture=c0.stdp.mu_capture, mu_backoff=c0.stdp.mu_backoff,
            mu_search=c0.stdp.mu_search,
            stabilize=c0.stdp.stabilizer == "half",
            response=c0.neuron.response,
        )

        # ---- bucket construction: pack design shapes into envelopes and
        # assemble each bucket's live weight block host-side (the sweep
        # idiom), per-design init keys folded from the service seed
        shapes = [(c.p, c.q, c.t_max) for c in cfgs]
        buckets = backend_lib.envelope_buckets(shapes, waste_cap, max_bucket)
        key = jax.random.key(seed)
        self._buckets: list[_Bucket] = []
        self._route: dict[str, tuple[_Bucket, int]] = {}
        for env, members in buckets:
            p_env, q_env, t_window = env
            w0 = np.zeros((len(members), p_env, q_env), np.float32)
            for lane, i in enumerate(members):
                c = cfgs[i]
                if weights is not None and names[i] in weights:
                    wi = np.asarray(weights[names[i]], np.float32)
                    if wi.shape != (c.p, c.q):
                        raise ValueError(
                            f"weights[{names[i]!r}]: expected shape "
                            f"{(c.p, c.q)}, got {wi.shape}"
                        )
                else:
                    wi = np.asarray(
                        column_lib.init_params(
                            jax.random.fold_in(key, i), c
                        )["w"]
                    )
                w0[lane, : c.p, : c.q] = wi
            bucket = _Bucket(
                env, [names[i] for i in members],
                [cfgs[i] for i in members], jnp.asarray(w0),
            )
            self._buckets.append(bucket)
            for lane, i in enumerate(members):
                self._route[names[i]] = (bucket, lane)

        self._next_id = 0
        self._submitted = 0
        self._served = 0
        self._rejected = 0
        self._failed = 0
        self._batches = 0
        self._isolations = 0
        self._refits = 0

    # ------------------------------------------------------------- intro
    def designs(self) -> tuple[str, ...]:
        return tuple(self._cfgs)

    def buckets(self) -> list[dict]:
        """Bucket-dispatch summary: one dict per compiled envelope."""
        return [
            {
                "envelope": b.envelope,
                "designs": tuple(b.names),
                "batch_shape": (self.batch_size, len(b.names), b.envelope[0]),
                "refit_shape": (
                    self.refit_window, len(b.names), b.envelope[0]
                ),
            }
            for b in self._buckets
        ]

    def weights(self, design: str) -> np.ndarray:
        """Copy of a design's live weights, cropped to its own (p, q)."""
        bucket, lane = self._route[design]
        c = self._cfgs[design]
        return np.asarray(bucket.w[lane, : c.p, : c.q])

    def stats(self) -> ServeStats:
        return ServeStats(
            submitted=self._submitted,
            served=self._served,
            rejected=self._rejected,
            failed=self._failed,
            batches=self._batches,
            isolations=self._isolations,
            refits=self._refits,
            stalls=len(self.monitor.events),
            pending=sum(len(b.queue) for b in self._buckets),
        )

    # ------------------------------------------------------------ warmup
    def warmup(self) -> dict:
        """Compile (or disk-load) every executable and warm every eager-op
        shape the steady state dispatches, so traffic performs ZERO XLA
        compiles afterwards.

        Per bucket: the batch-shaped assignment executable and the
        window-shaped re-fit executable become resident via the backend
        ``warm_*`` pre-compilers, then one all-silent batch and one
        all-silent re-fit run end-to-end through the real serving path —
        silent volleys assign to "unclustered" (discarded) and are exact
        weight no-ops, so warmup changes no answers and no weights while
        exercising the same ops as live traffic (including the
        per-design encode shapes).
        """
        t0 = time.perf_counter()
        hot = 0
        for name, c in self._cfgs.items():
            length = c.p if self.encoder == "latency" else c.p // 2
            np.asarray(encoding.encode(
                jnp.asarray(np.zeros(length)), c.t_max, self.encoder
            ))
        for b in self._buckets:
            db = len(b.names)
            p_env, q_env, t_window = b.envelope
            hot += backend_lib.warm_assign_padded(
                db, p_env, q_env, self.batch_size,
                t_window=t_window, wta_k=self._statics["wta_k"],
                response=self._statics["response"],
                lowering=b.asg_lowering, w_max=self._statics["w_max"],
            )
            self._assign(b, self._silent_batch(b))  # warm eager shapes
            if self.refit_every > 0:
                hot += backend_lib.warm_fit_padded(
                    db, p_env, q_env, self.refit_window,
                    t_window=t_window, w_max=self._statics["w_max"],
                    wta_k=self._statics["wta_k"],
                    stabilize=self._statics["stabilize"],
                    response=self._statics["response"],
                    epochs=self.refit_epochs, lowering=b.fit_lowering,
                )
                self._refit(b, warm=True)  # silent window: exact no-op
        return {
            "buckets": len(self._buckets),
            "already_resident": hot,
            "seconds": time.perf_counter() - t0,
        }

    # --------------------------------------------------------- admission
    def submit(self, series, design: str) -> PendingRequest:
        """Admit one series for ``design``; raises ``RequestRejected`` on
        admission failure, returns a ``PendingRequest`` otherwise.  A full
        bucket queue executes immediately (the returned handle is then
        already ``done``)."""
        route = self._route.get(design)
        if route is None:
            self._rejected += 1
            raise RequestRejected(
                "unknown-design",
                f"{design!r} not served (have {sorted(self._route)})",
            )
        bucket, lane = route
        cfg = self._cfgs[design]
        x = np.asarray(series, np.float64)
        if x.ndim != 1:
            self._rejected += 1
            raise RequestRejected(
                "shape", f"expected one series [L], got shape {x.shape}"
            )
        width = encoding.encoded_width(x.shape[0], self.encoder)
        if width != cfg.p:
            self._rejected += 1
            raise RequestRejected(
                "envelope",
                f"series of length {x.shape[0]} encodes to width {width}, "
                f"which no compiled bucket accepts (design {design!r} "
                f"envelope takes width {cfg.p})",
            )
        if not np.isfinite(x).all():
            self._rejected += 1
            raise RequestRejected(
                "non-finite", f"series for {design!r} has non-finite samples"
            )
        enc = np.asarray(
            encoding.encode(jnp.asarray(x), cfg.t_max, self.encoder)
        )
        pending = PendingRequest(self, self._next_id, design)
        self._next_id += 1
        self._submitted += 1
        bucket.queue.append(
            _Request(pending, lane, enc, time.perf_counter())
        )
        if len(bucket.queue) >= self.batch_size:
            self._execute(bucket)
        return pending

    def flush(self, design: Optional[str] = None) -> None:
        """Execute partial batches now (all buckets, or ``design``'s)."""
        buckets = (
            self._buckets if design is None else [self._route[design][0]]
        )
        for b in buckets:
            while b.queue:
                self._execute(b)

    # --------------------------------------------------------- execution
    def _silent_batch(self, bucket: _Bucket) -> np.ndarray:
        p_env, _, t_window = bucket.envelope
        return np.full(
            (self.batch_size, len(bucket.names), p_env), t_window, np.int32
        )

    def _batch_xs(self, bucket: _Bucket, reqs: list[_Request]) -> np.ndarray:
        """Assemble [B, Db, p_env] host-side: each request's volley in its
        design's lane, every other lane silent, partial batches padded to
        the compiled batch size through the ragged-batch seam."""
        p_env, _, t_window = bucket.envelope
        xs = np.full(
            (len(reqs), len(bucket.names), p_env), t_window, np.int32
        )
        for n, r in enumerate(reqs):
            xs[n, r.lane, : r.enc.shape[0]] = r.enc
        return fused_column.pad_stream_silent(xs, self.batch_size, t_window)

    def _assign(self, bucket: _Bucket, xs_np: np.ndarray) -> np.ndarray:
        ids = backend_lib.assign_padded(
            bucket.w, jnp.asarray(xs_np),
            bucket.thresholds, bucket.t_maxes, bucket.q_actives,
            t_window=bucket.envelope[2], wta_k=self._statics["wta_k"],
            response=self._statics["response"],
            lowering=bucket.asg_lowering, w_max=self._statics["w_max"],
        )
        return np.asarray(ids)  # [Db, B]

    def _execute(self, bucket: _Bucket) -> None:
        reqs = bucket.queue[: self.batch_size]
        del bucket.queue[: self.batch_size]
        if not reqs:
            return
        self.monitor.start()
        try:
            ids = self._assign(bucket, self._batch_xs(bucket, reqs))
        except Exception:
            self.monitor.stop()
            self._isolate(bucket, reqs)
            return
        self.monitor.stop()
        done = time.perf_counter()
        self._batches += 1
        for n, r in enumerate(reqs):
            self._complete(
                bucket, r,
                ServeResult(
                    r.pending.id, r.pending.design,
                    int(ids[r.lane, n]), done - r.t_submit,
                ),
            )
        self._maybe_refit(bucket)

    def _isolate(self, bucket: _Bucket, reqs: list[_Request]) -> None:
        """Quarantine: re-run each request of a failed batch alone against
        the SAME executable (one live row, rest silent) — assignment is
        per-volley independent, so survivors' answers are bit-identical
        to the batched run; only the poisoned request fails."""
        self._isolations += 1
        for r in reqs:
            self.monitor.start()
            try:
                ids = self._assign(bucket, self._batch_xs(bucket, [r]))
            except Exception as e:
                self.monitor.stop()
                self._failed += 1
                r.pending.outcome = ServeFailure(
                    r.pending.id, r.pending.design, "assign", repr(e)
                )
                continue
            self.monitor.stop()
            self._batches += 1
            self._complete(
                bucket, r,
                ServeResult(
                    r.pending.id, r.pending.design,
                    int(ids[r.lane, 0]), time.perf_counter() - r.t_submit,
                ),
            )
        self._maybe_refit(bucket)

    def _complete(
        self, bucket: _Bucket, r: _Request, result: ServeResult
    ) -> None:
        r.pending.outcome = result
        self._served += 1
        bucket.served_since_refit += 1
        if self.refit_every > 0:
            buf = bucket.buffers[r.lane]
            buf.append(r.enc)
            if len(buf) > self.refit_window:
                del buf[: len(buf) - self.refit_window]

    # ------------------------------------------------------------ re-fit
    def _refit_xs(self, bucket: _Bucket) -> np.ndarray:
        """[R, Db, p_env] re-fit window: each design's buffered volleys in
        arrival order, ragged tails silent (exact no-ops above threshold
        0, so training on the padded window == training on the buffered
        volleys alone)."""
        p_env, _, t_window = bucket.envelope
        xs = np.full(
            (self.refit_window, len(bucket.names), p_env), t_window, np.int32
        )
        for lane, buf in enumerate(bucket.buffers):
            for k, enc in enumerate(buf):
                xs[k, lane, : enc.shape[0]] = enc
        return xs

    def _refit(self, bucket: _Bucket, warm: bool = False) -> None:
        self.monitor.start()
        bucket.w = backend_lib.fit_padded(
            bucket.w, jnp.asarray(self._refit_xs(bucket)),
            bucket.thresholds, bucket.t_maxes, bucket.q_actives,
            t_window=bucket.envelope[2],
            epochs=self.refit_epochs, lowering=bucket.fit_lowering,
            **self._statics,
        )
        # off the integer grid the assignment lowering stays 'reference'
        # on every host; re-checking after each re-fit keeps the kernel
        # available on TPU should the weights land back on the grid
        bucket.asg_lowering = backend_lib.assign_lowering(
            self._statics["response"], bucket.w[0]
        )
        self.monitor.stop()
        for buf in bucket.buffers:
            buf.clear()
        bucket.served_since_refit = 0
        if not warm:
            self._refits += 1

    def _maybe_refit(self, bucket: _Bucket) -> None:
        if (
            self.refit_every > 0
            and bucket.served_since_refit >= self.refit_every
            and any(bucket.buffers)
        ):
            self._refit(bucket)
