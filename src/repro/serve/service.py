"""Long-lived streaming clustering service over envelope-bucketed NSPUs.

The serving pipeline, stage by stage (each independently testable):

* **admission** — ``submit`` validates a request against its design's
  compiled envelope *before anything touches JAX*: an unknown design, a
  series whose encoded width does not match any compiled bucket, or
  non-finite samples raise a structured ``RequestRejected`` — never a
  fresh trace.  Admission is also where overload control bites: a
  bounded pending queue (``max_pending``) sheds with
  ``reason='overloaded'`` and a retry-after hint, and a per-request
  deadline budget sheds with ``reason='deadline'`` when the predicted
  queue wait already exceeds it.
* **encode** — the series becomes a spike volley via the central encoder
  dispatch (``encoding.encode``), using the target design's gamma window.
* **bucket dispatch** — designs are packed into shared padding envelopes
  at construction (``backend.envelope_buckets``); a request rides the
  queue of its design's bucket and is batched with requests for *any*
  design in that bucket.
* **assign** — a full micro-batch (or a ``flush``-forced partial one,
  silent-padded to the compiled batch size through
  ``fused_column.pad_stream_silent``) dispatches ONE envelope-keyed AOT
  executable (``backend.assign_padded``).  After ``warmup`` the steady
  state performs zero XLA compiles: executables are keyed on
  shapes + statics, and the batch geometry never changes.  A request
  whose deadline expired while queued is shed at dispatch (a structured
  ``ServeShed``) — before its batch touches JAX.
* **re-fit** — every ``refit_every`` served requests per bucket, the live
  weights take an online-STDP pass over the most recent
  ``refit_window`` volleys each design served (``backend.fit_padded``).
  The candidate runs on a *copy* of the live block (the fused scan
  donates its weight operand, and a failed attempt must never destroy
  the last-good weights) and commits only if it returns finite weights
  within the watchdog budget; otherwise the attempt degrades down
  ``backend.lowering_ladder`` and, if every rung fails, the bucket
  enters **degraded mode** — serving continues from last-good weights
  while re-fit attempts back off exponentially
  (``backend.refit_backoff``).  Ragged buffers are silent-padded: for
  the positive thresholds the service enforces, a silent volley is an
  exact weight no-op, so the re-fit is bit-identical to an offline
  ``fit_padded`` resume on the same volleys.
* **durability** — with ``durable_dir`` set, every committed re-fit is
  appended to a volley WAL and every ``snapshot_every`` re-fits the live
  weights snapshot atomically; ``ClusteringService.recover(dir)``
  replays WAL re-fits on top of the latest snapshot and restores weights
  bit-identical to the uninterrupted service (``serve.durability``).

Failures quarantine per request: if a batch raises, each live request
re-runs alone against the same executable (assignment is per-volley
independent, so batch-mates' answers are bit-identical to the batched
run) and only the poisoned request surfaces a ``ServeFailure``.

The service is synchronous and single-threaded; "concurrent streams" are
interleaved logical streams multiplexed by the caller (see
``benchmarks/serve_bench.py``, which sustains 64+ of them).  Stage
timings feed a ``distributed.straggler.StepMonitor`` (stages labelled
``'assign'`` / ``'refit'``) so stalls are observable through ``stats()``.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Mapping, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend as backend_lib
from repro.core import column as column_lib
from repro.core import encoding
from repro.core.types import ColumnConfig, TIME_DTYPE, column_config_from_dict
from repro.distributed.straggler import StepMonitor
from repro.kernels import fused_column
from repro.serve import durability


class RequestRejected(Exception):
    """Structured admission failure — raised by ``submit`` before any JAX
    work happens, so a bad request can never trigger a trace storm.

    ``reason`` is machine-readable: ``'unknown-design'``, ``'shape'``,
    ``'envelope'`` (encoded width fits no compiled bucket),
    ``'non-finite'``, ``'overloaded'`` (bounded queue full),
    ``'deadline'`` (predicted wait exceeds the request's budget) or
    ``'draining'`` (the service is shutting down).  Load-shedding
    rejections carry ``retry_after_s``, a hint for when capacity should
    free up.
    """

    def __init__(self, reason: str, detail: str,
                 retry_after_s: Optional[float] = None):
        super().__init__(f"{reason}: {detail}")
        self.reason = reason
        self.detail = detail
        self.retry_after_s = retry_after_s


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """One served assignment: ``cluster`` is the earliest-firing neuron
    index of the target design, or its ``q`` when the volley was silent
    (unclustered)."""

    request_id: int
    design: str
    cluster: int
    latency_s: float


@dataclasses.dataclass(frozen=True)
class ServeFailure:
    """A quarantined request: the batch it rode failed, and so did its
    solo re-run.  Batch-mates are unaffected."""

    request_id: int
    design: str
    stage: str
    error: str


@dataclasses.dataclass(frozen=True)
class ServeShed:
    """A request shed at dispatch: admitted, but its deadline expired
    while it queued — no JAX work was spent on it."""

    request_id: int
    design: str
    reason: str
    waited_s: float


@dataclasses.dataclass(frozen=True)
class ServeStats:
    offered: int          # every submit() call, accepted or not
    submitted: int        # admitted into a queue
    served: int
    rejected: int         # admission rejections, total
    rejections: dict      # per-reason admission rejection counts
    shed: int             # admitted but deadline-expired at dispatch
    failed: int
    batches: int
    isolations: int
    refits: int           # committed online re-fits
    refit_failures: int   # re-fit windows where every ladder rung failed
    refit_stalls: int     # rung attempts discarded by the watchdog budget
    recoveries: int       # degraded buckets that re-fit successfully again
    degraded: int         # buckets currently serving from last-good weights
    stalls: int
    pending: int
    snapshots: int        # snapshots published this process
    wal_records: int      # WAL re-fits not yet covered by a snapshot
    replayed: int         # WAL re-fits replayed during recover()
    # per-bucket ExecutionPlan.meta() dicts — ({assign}, {fit|None}) per
    # bucket; 'source' says whether the roofline cost model or the
    # constants fallback chose each bucket's blocking
    plans: tuple = ()


class PendingRequest:
    """Handle returned by ``submit``; ``result()`` forces the request's
    bucket to flush if it is still queued."""

    def __init__(self, service: "ClusteringService", rid: int, design: str):
        self._service = service
        self.id = rid
        self.design = design
        self.outcome: Optional[
            Union[ServeResult, ServeFailure, ServeShed]
        ] = None

    @property
    def done(self) -> bool:
        return self.outcome is not None

    def result(self) -> Union[ServeResult, ServeFailure, ServeShed]:
        if self.outcome is None:
            self._service.flush(self.design)
        assert self.outcome is not None
        return self.outcome


class _Request:
    __slots__ = ("pending", "lane", "enc", "t_submit", "deadline")

    def __init__(self, pending, lane, enc, t_submit, deadline):
        self.pending = pending
        self.lane = lane
        self.enc = enc
        self.t_submit = t_submit
        self.deadline = deadline


class _Bucket:
    """One envelope bucket: live weights + compiled-shape metadata + queue
    + degraded-mode state."""

    def __init__(self, index, envelope, names, cfgs, w0):
        self.index = index
        self.envelope = envelope  # (p_env, q_env, t_window)
        self.names = list(names)
        self.cfgs = list(cfgs)
        self.w = w0  # [Db, p_env, q_env] jnp — replaced by every re-fit
        self.thresholds = jnp.asarray(
            [c.neuron.threshold for c in cfgs], jnp.float32
        )
        self.t_maxes = jnp.asarray([c.t_max for c in cfgs], TIME_DTYPE)
        self.q_actives = jnp.asarray([c.q for c in cfgs], TIME_DTYPE)
        c0 = cfgs[0]
        self.fit_lowering = backend_lib.padded_lowering(c0.neuron.response)
        self.asg_lowering = backend_lib.assign_lowering(
            c0.neuron.response, self.w[0]
        )
        self.queue: list[_Request] = []
        self.buffers: list[list[np.ndarray]] = [[] for _ in cfgs]
        # ExecutionPlans for this bucket's two compiled shapes (filled in
        # by the service right after construction — it owns the batch /
        # re-fit geometry).  Reporting only: the backend re-derives the
        # identical plan inside assign_padded / fit_padded.
        self.asg_plan = None
        self.fit_plan = None
        self.served_since_refit = 0
        # degraded-mode state: after every ladder rung fails a re-fit
        # window, the bucket keeps serving from the last-good weights and
        # sits out `cooldown` re-fit windows before retrying
        self.degraded = False
        self.failed_refits = 0
        self.cooldown = 0
        self.last_refit_errors: list[str] = []


def _design_map(
    designs: Union[Mapping[str, ColumnConfig],
                   Sequence[tuple[str, ColumnConfig]]],
) -> dict[str, ColumnConfig]:
    if isinstance(designs, Mapping):
        return dict(designs)
    return dict(designs)


class ClusteringService:
    """Streaming front-end over a fleet of NSPU column designs.

    Args:
      designs: ``{name: ColumnConfig}`` (or ``(name, cfg)`` pairs).  All
        designs must share the fused statics (response, ``w_max``, WTA k,
        STDP mus/mode) — the same constraint as the sweep front-end — and
        every threshold must be positive (the silent-volley no-op that
        partial batches and ragged re-fits rely on).
      encoder: ``'latency'`` or ``'onoff'`` (admission uses
        ``encoding.encoded_width`` to pin series length to design width).
      batch_size: requests per compiled assignment micro-batch; a full
        queue auto-executes, ``flush`` silent-pads a partial one.
      refit_every: served requests per bucket between online re-fits
        (0 disables re-fitting).
      refit_window: volleys per design each re-fit trains on (the most
        recent served; fixes the re-fit executable's shape).
      refit_epochs: STDP epochs per re-fit.
      weights: optional ``{name: [p, q] array}`` initial weights (e.g.
        from an offline ``cluster_time_series`` fit); designs without an
        entry draw ``column.init_params`` from ``fold_in(seed, index)``.
      max_pending: bound on the total queued (unexecuted) requests across
        all buckets; beyond it ``submit`` sheds with
        ``RequestRejected(reason='overloaded')`` and a retry-after hint.
        ``None`` (default) leaves admission unbounded.
      default_deadline_s: deadline budget applied to every request that
        does not pass its own ``deadline_s``; a request whose predicted
        queue wait exceeds its budget is shed at admission
        (``reason='deadline'``), and one whose budget expires while
        queued is shed at dispatch (a ``ServeShed`` outcome) — either
        way, before any JAX work is spent on it.
      refit_budget_s: watchdog budget for one re-fit attempt; an attempt
        exceeding it is discarded as stalled (the rung's result is
        thrown away) and the ladder moves on.  ``None`` disables the
        budget.
      durable_dir: directory for crash durability (snapshots + re-fit
        WAL — see ``serve.durability``).  Must be fresh; resume an
        existing one with ``ClusteringService.recover(dir)``.
      snapshot_every: committed re-fits between snapshots (with
        ``durable_dir``); the WAL covers the gap.
      monitor: a ``StepMonitor`` for stage timings (one is created by
        default; stalls surface in ``stats()``).
    """

    def __init__(
        self,
        designs,
        *,
        encoder: str = "latency",
        batch_size: int = 16,
        refit_every: int = 64,
        refit_window: int = 32,
        refit_epochs: int = 1,
        seed: int = 0,
        weights: Optional[Mapping[str, np.ndarray]] = None,
        waste_cap: Optional[float] = None,
        max_bucket: Optional[int] = None,
        max_pending: Optional[int] = None,
        default_deadline_s: Optional[float] = None,
        refit_budget_s: Optional[float] = None,
        durable_dir: Optional[str] = None,
        snapshot_every: int = 4,
        monitor: Optional[StepMonitor] = None,
        _attach: bool = False,
    ):
        cfg_map = _design_map(designs)
        if not cfg_map:
            raise ValueError("service needs at least one design")
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        if refit_window < 1:
            raise ValueError("refit_window must be >= 1")
        if max_pending is not None and max_pending < 1:
            raise ValueError("max_pending must be >= 1 (or None)")
        if snapshot_every < 1:
            raise ValueError("snapshot_every must be >= 1")
        # unknown encoder raises here, at construction
        encoding.encoded_width(1, encoder)
        self.encoder = encoder
        self.batch_size = int(batch_size)
        self.refit_every = int(refit_every)
        self.refit_window = int(refit_window)
        self.refit_epochs = int(refit_epochs)
        self.max_pending = max_pending
        self.default_deadline_s = default_deadline_s
        self.refit_budget_s = refit_budget_s
        self.snapshot_every = int(snapshot_every)
        self._seed = int(seed)
        self._waste_cap = waste_cap
        self._max_bucket = max_bucket
        self.monitor = monitor if monitor is not None else StepMonitor(
            threshold=4.0, warmup=3
        )

        names = list(cfg_map)
        cfgs = [cfg_map[n] for n in names]
        c0 = cfgs[0]
        for n, c in zip(names, cfgs):
            fused_column.check_fusable(
                c, backend_lib.padded_lowering(c.neuron.response)
            )
            if c.neuron.threshold <= 0:
                raise ValueError(
                    f"design {n!r}: threshold must be > 0 — the service "
                    "pads partial batches and ragged re-fit windows with "
                    "silent volleys, which are weight no-ops only above "
                    "threshold 0"
                )
            same = (
                c.neuron.response == c0.neuron.response
                and c.neuron.w_max == c0.neuron.w_max
                and c.wta == c0.wta
                and c.stdp == c0.stdp
            )
            if not same:
                raise ValueError(
                    f"design {n!r}: all designs must share response/w_max/"
                    "WTA/STDP statics (one compiled program per bucket)"
                )
        self._cfgs = cfg_map
        self._statics = dict(
            w_max=c0.neuron.w_max, wta_k=c0.wta.k,
            mu_capture=c0.stdp.mu_capture, mu_backoff=c0.stdp.mu_backoff,
            mu_search=c0.stdp.mu_search,
            stabilize=c0.stdp.stabilizer == "half",
            response=c0.neuron.response,
        )

        # ---- bucket construction: pack design shapes into envelopes and
        # assemble each bucket's live weight block host-side (the sweep
        # idiom), per-design init keys folded from the service seed
        shapes = [(c.p, c.q, c.t_max) for c in cfgs]
        buckets = backend_lib.envelope_buckets(shapes, waste_cap, max_bucket)
        key = jax.random.key(seed)
        self._buckets: list[_Bucket] = []
        self._route: dict[str, tuple[_Bucket, int]] = {}
        for bi, (env, members) in enumerate(buckets):
            p_env, q_env, t_window = env
            w0 = np.zeros((len(members), p_env, q_env), np.float32)
            for lane, i in enumerate(members):
                c = cfgs[i]
                if weights is not None and names[i] in weights:
                    wi = np.asarray(weights[names[i]], np.float32)
                    if wi.shape != (c.p, c.q):
                        raise ValueError(
                            f"weights[{names[i]!r}]: expected shape "
                            f"{(c.p, c.q)}, got {wi.shape}"
                        )
                else:
                    wi = np.asarray(
                        column_lib.init_params(
                            jax.random.fold_in(key, i), c
                        )["w"]
                    )
                w0[lane, : c.p, : c.q] = wi
            bucket = _Bucket(
                bi, env, [names[i] for i in members],
                [cfgs[i] for i in members], jnp.asarray(w0),
            )
            # record which blocking policy this bucket's executables will
            # resolve to (cost-model plan when a calibration is active,
            # constants otherwise) — assign_padded / fit_padded re-derive
            # the same plan from the same inputs at dispatch time
            bucket.asg_plan = backend_lib.execution_plan(
                "assign", bucket.asg_lowering, len(members),
                p_env, q_env, t_window, self.batch_size, 1,
                w_max=self._statics["w_max"],
                response=self._statics["response"],
            )
            if self.refit_every > 0:
                bucket.fit_plan = backend_lib.execution_plan(
                    "fit", bucket.fit_lowering, len(members),
                    p_env, q_env, t_window,
                    self.refit_window, self.refit_epochs,
                    w_max=self._statics["w_max"],
                    response=self._statics["response"],
                )
            self._buckets.append(bucket)
            for lane, i in enumerate(members):
                self._route[names[i]] = (bucket, lane)

        self._next_id = 0
        self._offered = 0
        self._submitted = 0
        self._served = 0
        self._rejected = 0
        self._rejections: dict[str, int] = {}
        self._shed = 0
        self._failed = 0
        self._batches = 0
        self._isolations = 0
        self._refits = 0
        self._refit_failures = 0
        self._refit_stalls = 0
        self._recoveries = 0
        self._snapshots = 0
        self._replayed = 0
        self._refit_seq = 0
        self._batch_ewma: Optional[float] = None
        self._draining = False

        # ---- durability: fresh directories get meta + WAL header + a
        # seq-0 snapshot of the initial weights; recover() attaches to an
        # existing directory, restores the latest snapshot and replays
        # the WAL tail (bit-identical — weights only ever mutate at
        # committed re-fits, and each WAL record is one committed
        # re-fit's exact input window)
        self._store: Optional[durability.DurableStore] = None
        if durable_dir is not None:
            spec = self._replay_spec()
            fingerprint = durability.service_fingerprint(spec)
            store = durability.DurableStore(durable_dir)
            if _attach:
                step, records = store.attach(fingerprint)
                blocks, _ = store.ckpt.restore(
                    [b.w for b in self._buckets], step=step
                )
                for b, wb in zip(self._buckets, blocks):
                    self._commit_weights(b, wb)
                self._store = store
                self._refit_seq = step
                for rec in records:
                    self._replay(rec)
            else:
                store.create(
                    {
                        "version": durability.DURABLE_VERSION,
                        "fingerprint": fingerprint,
                        "spec": spec,
                        "serving": {
                            "batch_size": self.batch_size,
                            "refit_every": self.refit_every,
                            "snapshot_every": self.snapshot_every,
                            "max_pending": self.max_pending,
                            "default_deadline_s": self.default_deadline_s,
                            "refit_budget_s": self.refit_budget_s,
                        },
                    },
                    [b.w for b in self._buckets],
                )
                self._store = store

    # -------------------------------------------------------- durability
    def _replay_spec(self) -> dict:
        """The replay-relevant service identity: everything that pins
        bucket structure, init weights and re-fit semantics — NOT the
        serving knobs (batch size, deadlines...), which a recovered
        service may legitimately change."""
        return {
            "names": list(self._cfgs),
            "cfgs": [dataclasses.asdict(c) for c in self._cfgs.values()],
            "encoder": self.encoder,
            "seed": self._seed,
            "refit_window": self.refit_window,
            "refit_epochs": self.refit_epochs,
            "waste_cap": self._waste_cap,
            "max_bucket": self._max_bucket,
            "statics": {
                k: v for k, v in self._statics.items()
            },
        }

    @classmethod
    def recover(cls, durable_dir: str, *, monitor: Optional[StepMonitor] =
                None, **overrides) -> "ClusteringService":
        """Rebuild a service from its durable directory: reconstruct the
        fleet from ``meta.json``, restore the latest published snapshot,
        and replay the WAL's committed re-fits on top — weights come back
        **bit-identical** to the uninterrupted service at its last
        committed re-fit (a kill loses at most the re-fit in flight, and
        the served-but-unrefit volley buffers).

        Serving knobs (``batch_size``, ``max_pending``, deadlines, ...)
        default to the values recorded at creation; pass ``overrides`` to
        change them.  Call ``warmup()`` on the recovered service before
        taking traffic, as usual.
        """
        meta = durability.DurableStore(durable_dir).load_meta()
        if meta.get("version") != durability.DURABLE_VERSION:
            raise ValueError(
                f"{durable_dir}: durable format version "
                f"{meta.get('version')} != {durability.DURABLE_VERSION}"
            )
        spec = meta["spec"]
        designs = {
            n: column_config_from_dict(d)
            for n, d in zip(spec["names"], spec["cfgs"])
        }
        kwargs = dict(meta.get("serving", {}))
        kwargs.update(
            encoder=spec["encoder"], seed=spec["seed"],
            refit_window=spec["refit_window"],
            refit_epochs=spec["refit_epochs"],
            waste_cap=spec["waste_cap"], max_bucket=spec["max_bucket"],
        )
        kwargs.update(overrides)
        return cls(
            designs, monitor=monitor, durable_dir=durable_dir,
            _attach=True, **kwargs,
        )

    def _replay(self, rec: dict) -> None:
        """Apply one WAL re-fit record — same ladder, same commit path as
        the live re-fit, no budget (a recovering process pays compiles
        here) and no re-logging."""
        bucket = self._buckets[rec["bucket"]]
        xs = np.asarray(rec["xs"], np.int32)
        w_new, _low, errors = self._attempt_window(
            bucket, xs, ladder=backend_lib.lowering_ladder(
                bucket.fit_lowering
            ),
            label="replay", enforce_budget=False,
        )
        if w_new is None:
            # the record committed in a prior life; failing here means the
            # environment changed — keep serving from the snapshot weights
            warnings.warn(
                f"WAL replay: re-fit seq {rec['seq']} failed every rung "
                f"({errors}); continuing from pre-record weights"
            )
            self._refit_failures += 1
        else:
            self._commit_weights(bucket, w_new)
        self._refit_seq = int(rec["seq"])
        self._replayed += 1

    def _snapshot(self) -> None:
        if self._store is None:
            return
        self._store.snapshot(
            self._refit_seq, [b.w for b in self._buckets]
        )
        self._snapshots += 1

    def drain(self) -> ServeStats:
        """Graceful shutdown: stop admission (``submit`` now sheds with
        ``reason='draining'``), serve every queued request, and publish a
        final snapshot so recovery replays nothing.  Idempotent; the
        SIGTERM path of ``launch/serve_tnn.py`` calls this."""
        self._draining = True
        self.flush()
        self._snapshot()
        return self.stats()

    @property
    def draining(self) -> bool:
        return self._draining

    # ------------------------------------------------------------- intro
    def designs(self) -> tuple[str, ...]:
        return tuple(self._cfgs)

    def buckets(self) -> list[dict]:
        """Bucket-dispatch summary: one dict per compiled envelope."""
        return [
            {
                "envelope": b.envelope,
                "designs": tuple(b.names),
                "batch_shape": (self.batch_size, len(b.names), b.envelope[0]),
                "refit_shape": (
                    self.refit_window, len(b.names), b.envelope[0]
                ),
                "degraded": b.degraded,
                "cooldown": b.cooldown,
                "assign_plan": b.asg_plan.meta() if b.asg_plan else None,
                "fit_plan": b.fit_plan.meta() if b.fit_plan else None,
            }
            for b in self._buckets
        ]

    def weights(self, design: str) -> np.ndarray:
        """Copy of a design's live weights, cropped to its own (p, q)."""
        bucket, lane = self._route[design]
        c = self._cfgs[design]
        return np.asarray(bucket.w[lane, : c.p, : c.q])

    def stats(self) -> ServeStats:
        return ServeStats(
            offered=self._offered,
            submitted=self._submitted,
            served=self._served,
            rejected=self._rejected,
            rejections=dict(self._rejections),
            shed=self._shed,
            failed=self._failed,
            batches=self._batches,
            isolations=self._isolations,
            refits=self._refits,
            refit_failures=self._refit_failures,
            refit_stalls=self._refit_stalls,
            recoveries=self._recoveries,
            degraded=sum(1 for b in self._buckets if b.degraded),
            stalls=len(self.monitor.events),
            pending=sum(len(b.queue) for b in self._buckets),
            snapshots=self._snapshots,
            wal_records=self._store.pending if self._store else 0,
            replayed=self._replayed,
            plans=tuple(
                (
                    b.asg_plan.meta() if b.asg_plan else None,
                    b.fit_plan.meta() if b.fit_plan else None,
                )
                for b in self._buckets
            ),
        )

    # ------------------------------------------------------------ warmup
    def warmup(self) -> dict:
        """Compile (or disk-load) every executable and warm every eager-op
        shape the steady state dispatches, so traffic performs ZERO XLA
        compiles afterwards.

        Per bucket: the batch-shaped assignment executable and the
        window-shaped re-fit executable become resident via the backend
        ``warm_*`` pre-compilers, then one all-silent batch and one
        all-silent re-fit run end-to-end through the real serving path —
        silent volleys assign to "unclustered" (discarded) and are exact
        weight no-ops, so warmup changes no answers and no weights while
        exercising the same ops as live traffic (including the
        per-design encode shapes).
        """
        t0 = time.perf_counter()
        hot = 0
        for name, c in self._cfgs.items():
            length = c.p if self.encoder == "latency" else c.p // 2
            np.asarray(encoding.encode(
                jnp.asarray(np.zeros(length)), c.t_max, self.encoder
            ))
        for b in self._buckets:
            db = len(b.names)
            p_env, q_env, t_window = b.envelope
            hot += backend_lib.warm_assign_padded(
                db, p_env, q_env, self.batch_size,
                t_window=t_window, wta_k=self._statics["wta_k"],
                response=self._statics["response"],
                lowering=b.asg_lowering, w_max=self._statics["w_max"],
            )
            self._assign(b, self._silent_batch(b))  # warm eager shapes
            if self.refit_every > 0:
                hot += backend_lib.warm_fit_padded(
                    db, p_env, q_env, self.refit_window,
                    t_window=t_window, w_max=self._statics["w_max"],
                    wta_k=self._statics["wta_k"],
                    stabilize=self._statics["stabilize"],
                    response=self._statics["response"],
                    epochs=self.refit_epochs, lowering=b.fit_lowering,
                )
                self._refit(b, warm=True)  # silent window: exact no-op
        return {
            "buckets": len(self._buckets),
            "already_resident": hot,
            "seconds": time.perf_counter() - t0,
        }

    # --------------------------------------------------------- admission
    def _reject(self, reason: str, detail: str,
                retry_after_s: Optional[float] = None) -> None:
        self._rejected += 1
        self._rejections[reason] = self._rejections.get(reason, 0) + 1
        raise RequestRejected(reason, detail, retry_after_s)

    def _batch_seconds(self) -> float:
        """Recent EWMA of one batched assignment's wall time (0.0 until
        the first post-warmup batch lands)."""
        return self._batch_ewma if self._batch_ewma is not None else 0.0

    def _wait_estimate_s(self, bucket: _Bucket) -> float:
        """Predicted queue wait for a request admitted to ``bucket`` now:
        batches ahead of it (its own included) times the recent batch
        time."""
        batches_ahead = len(bucket.queue) // self.batch_size + 1
        return batches_ahead * self._batch_seconds()

    def submit(self, series, design: str,
               deadline_s: Optional[float] = None) -> PendingRequest:
        """Admit one series for ``design``; raises ``RequestRejected`` on
        admission failure (including load shedding), returns a
        ``PendingRequest`` otherwise.  A full bucket queue executes
        immediately (the returned handle is then already ``done``).

        ``deadline_s`` is this request's latency budget (defaults to the
        service-wide ``default_deadline_s``): the request is shed at
        admission if the predicted queue wait already exceeds it, and at
        dispatch if it expired while queued.
        """
        self._offered += 1
        if self._draining:
            self._reject(
                "draining", "service is draining; no new work accepted"
            )
        if self.max_pending is not None:
            pending = sum(len(b.queue) for b in self._buckets)
            if pending >= self.max_pending:
                self._reject(
                    "overloaded",
                    f"{pending} pending requests >= max_pending="
                    f"{self.max_pending}",
                    retry_after_s=(
                        pending / self.batch_size
                    ) * self._batch_seconds(),
                )
        route = self._route.get(design)
        if route is None:
            self._reject(
                "unknown-design",
                f"{design!r} not served (have {sorted(self._route)})",
            )
        bucket, lane = route
        cfg = self._cfgs[design]
        x = np.asarray(series, np.float64)
        if x.ndim != 1:
            self._reject(
                "shape", f"expected one series [L], got shape {x.shape}"
            )
        width = encoding.encoded_width(x.shape[0], self.encoder)
        if width != cfg.p:
            self._reject(
                "envelope",
                f"series of length {x.shape[0]} encodes to width {width}, "
                f"which no compiled bucket accepts (design {design!r} "
                f"envelope takes width {cfg.p})",
            )
        if not np.isfinite(x).all():
            self._reject(
                "non-finite", f"series for {design!r} has non-finite samples"
            )
        deadline = (
            deadline_s if deadline_s is not None else self.default_deadline_s
        )
        if deadline is not None:
            est = self._wait_estimate_s(bucket)
            if est > deadline:
                self._reject(
                    "deadline",
                    f"predicted wait {est:.4f}s exceeds deadline budget "
                    f"{deadline:.4f}s",
                    retry_after_s=est,
                )
        enc = np.asarray(
            encoding.encode(jnp.asarray(x), cfg.t_max, self.encoder)
        )
        pending = PendingRequest(self, self._next_id, design)
        self._next_id += 1
        self._submitted += 1
        bucket.queue.append(
            _Request(pending, lane, enc, time.perf_counter(), deadline)
        )
        if len(bucket.queue) >= self.batch_size:
            self._execute(bucket)
        return pending

    def flush(self, design: Optional[str] = None) -> None:
        """Execute partial batches now (all buckets, or ``design``'s)."""
        buckets = (
            self._buckets if design is None else [self._route[design][0]]
        )
        for b in buckets:
            while b.queue:
                self._execute(b)

    # --------------------------------------------------------- execution
    def _silent_batch(self, bucket: _Bucket) -> np.ndarray:
        p_env, _, t_window = bucket.envelope
        return np.full(
            (self.batch_size, len(bucket.names), p_env), t_window, np.int32
        )

    def _batch_xs(self, bucket: _Bucket, reqs: list[_Request]) -> np.ndarray:
        """Assemble [B, Db, p_env] host-side: each request's volley in its
        design's lane, every other lane silent, partial batches padded to
        the compiled batch size through the ragged-batch seam."""
        p_env, _, t_window = bucket.envelope
        xs = np.full(
            (len(reqs), len(bucket.names), p_env), t_window, np.int32
        )
        for n, r in enumerate(reqs):
            xs[n, r.lane, : r.enc.shape[0]] = r.enc
        return fused_column.pad_stream_silent(xs, self.batch_size, t_window)

    def _assign(self, bucket: _Bucket, xs_np: np.ndarray) -> np.ndarray:
        ids = backend_lib.assign_padded(
            bucket.w, jnp.asarray(xs_np),
            bucket.thresholds, bucket.t_maxes, bucket.q_actives,
            t_window=bucket.envelope[2], wta_k=self._statics["wta_k"],
            response=self._statics["response"],
            lowering=bucket.asg_lowering, w_max=self._statics["w_max"],
        )
        return np.asarray(ids)  # [Db, B]

    def _shed_expired(self, reqs: list[_Request]) -> list[_Request]:
        """Drop deadline-expired requests from a popped batch BEFORE any
        JAX work — their budget is already blown, serving them would only
        delay the live ones."""
        now = time.perf_counter()
        live = []
        for r in reqs:
            waited = now - r.t_submit
            if r.deadline is not None and waited > r.deadline:
                self._shed += 1
                r.pending.outcome = ServeShed(
                    r.pending.id, r.pending.design, "deadline", waited
                )
            else:
                live.append(r)
        return live

    def _execute(self, bucket: _Bucket) -> None:
        reqs = bucket.queue[: self.batch_size]
        del bucket.queue[: self.batch_size]
        if not reqs:
            return
        reqs = self._shed_expired(reqs)
        if not reqs:
            return
        self.monitor.start("assign")
        t0 = time.perf_counter()
        try:
            ids = self._assign(bucket, self._batch_xs(bucket, reqs))
        except Exception:
            self.monitor.stop()
            self._isolate(bucket, reqs)
            return
        self.monitor.stop()
        done = time.perf_counter()
        dt = done - t0
        self._batch_ewma = (
            dt if self._batch_ewma is None
            else 0.8 * self._batch_ewma + 0.2 * dt
        )
        self._batches += 1
        for n, r in enumerate(reqs):
            self._complete(
                bucket, r,
                ServeResult(
                    r.pending.id, r.pending.design,
                    int(ids[r.lane, n]), done - r.t_submit,
                ),
            )
        self._maybe_refit(bucket)

    def _isolate(self, bucket: _Bucket, reqs: list[_Request]) -> None:
        """Quarantine: re-run each request of a failed batch alone against
        the SAME executable (one live row, rest silent) — assignment is
        per-volley independent, so survivors' answers are bit-identical
        to the batched run; only the poisoned request fails."""
        self._isolations += 1
        for r in reqs:
            self.monitor.start("assign")
            try:
                ids = self._assign(bucket, self._batch_xs(bucket, [r]))
            except Exception as e:
                self.monitor.stop()
                self._failed += 1
                r.pending.outcome = ServeFailure(
                    r.pending.id, r.pending.design, "assign", repr(e)
                )
                continue
            self.monitor.stop()
            self._batches += 1
            self._complete(
                bucket, r,
                ServeResult(
                    r.pending.id, r.pending.design,
                    int(ids[r.lane, 0]), time.perf_counter() - r.t_submit,
                ),
            )
        self._maybe_refit(bucket)

    def _complete(
        self, bucket: _Bucket, r: _Request, result: ServeResult
    ) -> None:
        r.pending.outcome = result
        self._served += 1
        bucket.served_since_refit += 1
        if self.refit_every > 0:
            buf = bucket.buffers[r.lane]
            buf.append(r.enc)
            if len(buf) > self.refit_window:
                del buf[: len(buf) - self.refit_window]

    # ------------------------------------------------------------ re-fit
    def _refit_xs(self, bucket: _Bucket) -> np.ndarray:
        """[R, Db, p_env] re-fit window: each design's buffered volleys in
        arrival order, ragged tails silent (exact no-ops above threshold
        0, so training on the padded window == training on the buffered
        volleys alone)."""
        p_env, _, t_window = bucket.envelope
        xs = np.full(
            (self.refit_window, len(bucket.names), p_env), t_window, np.int32
        )
        for lane, buf in enumerate(bucket.buffers):
            for k, enc in enumerate(buf):
                xs[k, lane, : enc.shape[0]] = enc
        return xs

    def _fit_window(self, bucket: _Bucket, xs_np: np.ndarray,
                    lowering: str) -> jnp.ndarray:
        """One fused online-STDP pass over a host-side window, on a COPY
        of the live block — ``fit_padded`` donates its weight operand, and
        a failed or discarded attempt must never destroy the last-good
        weights (donation is a memory optimization; the copy is
        value-identical, so commit-on-success keeps the resume contract
        bit-exact)."""
        w_new = backend_lib.fit_padded(
            jnp.array(bucket.w, copy=True), jnp.asarray(xs_np),
            bucket.thresholds, bucket.t_maxes, bucket.q_actives,
            t_window=bucket.envelope[2],
            epochs=self.refit_epochs, lowering=lowering,
            **self._statics,
        )
        return jax.block_until_ready(w_new)

    def _attempt_window(self, bucket: _Bucket, xs_np: np.ndarray, *,
                        ladder, label: str = "refit",
                        enforce_budget: bool = True):
        """Try one re-fit window down ``ladder``; a rung fails on raise,
        non-finite weights, or (with the watchdog budget enforced) a wall
        time over ``refit_budget_s``.  Returns ``(w_new, lowering,
        errors)`` — ``w_new`` is ``None`` when every rung failed."""
        errors: list[str] = []
        for low in ladder:
            self.monitor.start(label)
            t0 = time.perf_counter()
            try:
                w_new = self._fit_window(bucket, xs_np, low)
            except Exception as e:
                self.monitor.stop()
                errors.append(f"{low}: {e!r}")
                continue
            self.monitor.stop()
            dt = time.perf_counter() - t0
            if (
                enforce_budget
                and self.refit_budget_s is not None
                and dt > self.refit_budget_s
            ):
                self._refit_stalls += 1
                errors.append(
                    f"{low}: stalled ({dt:.3f}s > refit_budget_s="
                    f"{self.refit_budget_s:.3f}s) — result discarded"
                )
                continue
            if not bool(jnp.isfinite(w_new).all()):
                errors.append(f"{low}: non-finite weights (poisoned re-fit)")
                continue
            return w_new, low, errors
        return None, None, errors

    def _commit_weights(self, bucket: _Bucket, w_new) -> None:
        bucket.w = jnp.asarray(w_new)
        # off the integer grid the assignment lowering stays 'reference'
        # on every host; re-checking after each commit keeps the kernel
        # available on TPU should the weights land back on the grid
        bucket.asg_lowering = backend_lib.assign_lowering(
            self._statics["response"], bucket.w[0]
        )

    def _refit(self, bucket: _Bucket, warm: bool = False) -> None:
        xs = self._refit_xs(bucket)
        if warm:
            # warmup's all-silent window: single rung, no budget (first
            # dispatch may still be cold), no WAL, no counters
            w_new, _, _ = self._attempt_window(
                bucket, xs, ladder=(bucket.fit_lowering,),
                enforce_budget=False,
            )
            if w_new is not None:
                self._commit_weights(bucket, w_new)
        else:
            w_new, _low, errors = self._attempt_window(
                bucket, xs,
                ladder=backend_lib.lowering_ladder(bucket.fit_lowering),
            )
            if w_new is None:
                # degraded mode: keep serving from last-good weights;
                # retry after an exponentially growing number of windows
                self._refit_failures += 1
                bucket.failed_refits += 1
                bucket.cooldown = backend_lib.refit_backoff(
                    bucket.failed_refits
                )
                bucket.degraded = True
                bucket.last_refit_errors = errors
            else:
                self._commit_weights(bucket, w_new)
                self._refits += 1
                self._refit_seq += 1
                if bucket.degraded:
                    bucket.degraded = False
                    bucket.failed_refits = 0
                    bucket.cooldown = 0
                    bucket.last_refit_errors = []
                    self._recoveries += 1
                if self._store is not None:
                    self._store.log_refit(
                        self._refit_seq, bucket.index, self.refit_epochs,
                        _low, xs,
                    )
                    if self._refit_seq % self.snapshot_every == 0:
                        self._snapshot()
        for buf in bucket.buffers:
            buf.clear()
        bucket.served_since_refit = 0

    def _maybe_refit(self, bucket: _Bucket) -> None:
        if (
            self.refit_every <= 0
            or bucket.served_since_refit < self.refit_every
            or not any(bucket.buffers)
        ):
            return
        if bucket.cooldown > 0:
            # degraded backoff: sit this window out (buffers keep rolling,
            # capped at refit_window) and wait a full window before the
            # next decision
            bucket.cooldown -= 1
            bucket.served_since_refit = 0
            return
        self._refit(bucket)
