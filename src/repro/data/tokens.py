"""Deterministic synthetic token pipeline for the LM training substrate.

Production framing: each data-parallel shard owns a disjoint slice of the
global batch, derived from a counter-based PRNG keyed by (epoch, step,
shard) — restart-safe (resuming at step k regenerates identical batches,
which the checkpoint tests rely on) and elastic (re-sharding only re-slices
the same global batch).  A real deployment swaps `TokenSource` for a
tokenized corpus reader with the same interface.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0


class TokenSource:
    """Stateless, index-addressable synthetic corpus."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def global_batch_at(self, step: int) -> dict:
        """Full global batch for a step: {'tokens','labels'} [B, S] int32.

        Markov-ish stream: tokens are a deterministic mix of a per-sequence
        seed and position so models can learn non-trivial statistics, while
        remaining reproducible from (seed, step) alone.
        """
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.key(cfg.seed), step)
        toks = jax.random.randint(
            key, (cfg.global_batch, cfg.seq_len + 1), 0, cfg.vocab_size, jnp.int32
        )
        # inject learnable structure: every even position repeats prev token
        pos = jnp.arange(cfg.seq_len + 1)
        toks = jnp.where(
            (pos[None, :] % 4 == 3), jnp.roll(toks, 1, axis=1), toks
        )
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def shard_at(self, step: int, shard: int, num_shards: int) -> dict:
        """This shard's slice of the global batch (restart/elastic safe)."""
        if self.cfg.global_batch % num_shards:
            raise ValueError(
                f"global_batch {self.cfg.global_batch} not divisible by "
                f"{num_shards} shards"
            )
        b = self.cfg.global_batch // num_shards
        full = self.global_batch_at(step)
        sl = slice(shard * b, (shard + 1) * b)
        return {k: v[sl] for k, v in full.items()}
