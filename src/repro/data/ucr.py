"""UCR time-series archive access (paper §III-A).

``load(name)`` reads the real UCR 2018 ``.tsv`` format if a local archive is
available (env ``UCR_ROOT`` or ./data/UCR); otherwise it falls back to
*synthetic doubles* — generated datasets matching each benchmark's length,
class count, sample count and qualitative character (modality-appropriate
waveform families).  The paper's own rand-index numbers are kept as
reference constants so benchmarks can report both "paper" and "ours".
"""
from __future__ import annotations

import dataclasses
import os
import zlib
from typing import Optional

import numpy as np

# (length, n_classes, n_train+test used, modality) per paper Table II.
BENCHMARKS = {
    "SonyAIBORobotSurface2": dict(length=65, classes=2, n=980, modality="accelerometer"),
    "ECG200": dict(length=96, classes=2, n=200, modality="ecg"),
    "Wafer": dict(length=152, classes=2, n=1000, modality="fabrication"),
    "ToeSegmentation2": dict(length=343, classes=2, n=166, modality="motion"),
    "Lightning2": dict(length=637, classes=2, n=121, modality="optical_rf"),
    "Beef": dict(length=470, classes=5, n=60, modality="spectrograph"),
    "WordSynonyms": dict(length=270, classes=25, n=905, modality="word_outline"),
}

# Paper Table II rand indices (normalized to k-means), for reference output.
PAPER_RAND_INDEX = {
    "SonyAIBORobotSurface2": dict(dtcr=0.8354, tnn=0.6066),
    "ECG200": dict(dtcr=0.6648, tnn=0.6648),
    "Wafer": dict(dtcr=0.7338, tnn=0.555),
    "ToeSegmentation2": dict(dtcr=0.8286, tnn=0.6683),
    "Lightning2": dict(dtcr=0.5913, tnn=0.577),
    "Beef": dict(dtcr=0.8046, tnn=0.731),
    "WordSynonyms": dict(dtcr=0.8984, tnn=0.8473),
}

# Table II column geometries (p x q); p = series length, q = neurons.
PAPER_COLUMNS = {
    "SonyAIBORobotSurface2": (65, 2),
    "ECG200": (96, 2),
    "Wafer": (152, 2),
    "ToeSegmentation2": (343, 2),
    "Lightning2": (637, 2),
    "Beef": (470, 5),
    "WordSynonyms": (270, 25),
}


@dataclasses.dataclass
class Dataset:
    name: str
    x: np.ndarray  # [N, L]
    y: np.ndarray  # [N]
    synthetic: bool

    @property
    def n_classes(self) -> int:
        return len(np.unique(self.y))


def _ucr_root() -> Optional[str]:
    for cand in (os.environ.get("UCR_ROOT"), "data/UCR", "/root/data/UCR"):
        if cand and os.path.isdir(cand):
            return cand
    return None


def _load_real(root: str, name: str) -> Optional[Dataset]:
    rows = []
    for split in ("TRAIN", "TEST"):
        path = os.path.join(root, name, f"{name}_{split}.tsv")
        if not os.path.exists(path):
            return None
        rows.append(np.loadtxt(path, delimiter="\t"))
    data = np.concatenate(rows, axis=0)
    return Dataset(name, data[:, 1:], data[:, 0].astype(np.int64), synthetic=False)


def _class_prototype(rng: np.random.Generator, L: int, modality: str) -> np.ndarray:
    """Modality-flavored smooth prototype waveform."""
    t = np.linspace(0, 1, L)
    if modality in ("accelerometer", "motion"):
        # bursts + piecewise trends
        proto = np.zeros(L)
        for _ in range(3):
            c, wdt, amp = rng.uniform(0.1, 0.9), rng.uniform(0.03, 0.15), rng.normal(0, 2)
            proto += amp * np.exp(-0.5 * ((t - c) / wdt) ** 2)
        proto += rng.normal(0, 0.5) * t
    elif modality == "ecg":
        # QRS-like spike train with class-specific morphology
        proto = np.zeros(L)
        spike_pos = rng.uniform(0.2, 0.8)
        proto += rng.uniform(2, 4) * np.exp(-0.5 * ((t - spike_pos) / 0.02) ** 2)
        proto -= rng.uniform(0.5, 1.5) * np.exp(-0.5 * ((t - spike_pos - 0.05) / 0.03) ** 2)
        proto += 0.3 * np.sin(2 * np.pi * rng.integers(1, 4) * t)
    elif modality in ("fabrication", "spectrograph"):
        # plateaus / absorption-band shapes
        proto = np.cumsum(rng.normal(0, 0.15, L))
        for _ in range(2):
            a, b = sorted(rng.uniform(0, 1, 2))
            proto += rng.normal(0, 1.5) * ((t > a) & (t < b))
    elif modality == "optical_rf":
        proto = rng.uniform(0.5, 2) * np.sin(
            2 * np.pi * rng.uniform(2, 8) * t + rng.uniform(0, 2 * np.pi)
        ) * np.exp(-rng.uniform(0, 3) * t)
    else:  # word_outline and default: band-limited random shapes
        proto = np.zeros(L)
        for k in range(1, 6):
            proto += rng.normal(0, 1.0 / k) * np.sin(2 * np.pi * k * t + rng.uniform(0, 6.28))
    return proto


def make_synthetic(name: str, seed: int = 0) -> Dataset:
    """Synthetic double of a UCR benchmark (see module docstring)."""
    if name not in BENCHMARKS:
        raise KeyError(f"unknown benchmark {name!r}")
    meta = BENCHMARKS[name]
    # zlib.crc32, not hash(): str hashing is randomized per process
    # (PYTHONHASHSEED), which made the "deterministic" doubles differ
    # between runs — benchmarks were not comparable across invocations.
    rng = np.random.default_rng((zlib.crc32(name.encode()) + seed) % 2**32)
    L, k, n = meta["length"], meta["classes"], meta["n"]
    # Shared background component makes classes overlap (as real UCR data
    # does); per-class prototypes sit on top of it.
    background = _class_prototype(rng, L, meta["modality"]) * 1.5
    protos = [_class_prototype(rng, L, meta["modality"]) for _ in range(k)]
    xs, ys = [], []
    per = max(n // k, 8)
    for c in range(k):
        warp = rng.uniform(0.9, 1.1, size=per)
        shift = rng.integers(-L // 20 - 1, L // 20 + 1, size=per)
        for i in range(per):
            # time-warp + shift + amplitude scale + heavy noise
            tt = np.clip(np.linspace(0, 1, L) * warp[i], 0, 1)
            base = background + np.interp(tt, np.linspace(0, 1, L), protos[c])
            base = np.roll(base, int(shift[i]))
            xs.append(base * rng.uniform(0.7, 1.3) + rng.normal(0, 0.6, L))
            ys.append(c)
    x = np.stack(xs)
    y = np.asarray(ys, np.int64)
    perm = rng.permutation(len(y))
    return Dataset(name, x[perm], y[perm], synthetic=True)


def load(name: str, seed: int = 0) -> Dataset:
    """Real UCR data if available, else the synthetic double."""
    root = _ucr_root()
    if root:
        ds = _load_real(root, name)
        if ds is not None:
            return ds
    return make_synthetic(name, seed)
