# Data substrate: UCR archive access (real format or synthetic doubles) for
# the TNN clustering pillar, and the deterministic token pipeline for the
# LM-architecture pillar.
from repro.data import tokens, ucr  # noqa: F401
