"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block in JAX.

Implements the chunked SSD algorithm: within a chunk the recurrence is
computed in its quadratic "attention" dual form (MXU-friendly), and a
cross-chunk associative state pass stitches chunks together — O(L) total
with matmul-dominated inner loops, exactly the trade the paper's hardware
analysis motivates.  A recurrent single-step path serves decode (O(1) per
token with state cache), used by the decode_32k / long_500k cells.

Block structure (mamba2, conv + gate):
  in_proj -> [z | x | B | C | dt]; short causal depthwise conv on (x, B, C);
  SSD over heads (scalar-identity A per head); y = y * silu(z); RMSNorm;
  out_proj.  n_groups = 1 (B/C shared across heads).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import _dtype, dense_init, rmsnorm

CONV_K = 4  # mamba2 depthwise conv width


def init_ssm_block(rng, cfg: ArchConfig) -> dict:
    D, Di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(rng, 4)
    dt = _dtype(cfg)
    proj_out = 2 * Di + 2 * N + H
    return {
        "in_proj": dense_init(ks[0], (D, proj_out), dt),
        "out_proj": dense_init(ks[1], (Di, D), dt),
        "conv_w": dense_init(ks[2], (CONV_K, Di + 2 * N), dt, scale=0.5),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) = -1 init
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((Di,), dt),
    }


def _split_proj(p, cfg: ArchConfig):
    Di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, x, B, C, dt = jnp.split(p, [Di, 2 * Di, 2 * Di + N, 2 * Di + 2 * N], axis=-1)
    return z, x, B, C, dt


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv, width CONV_K.  u: [B, L, C]; w: [K, C].

    With a ``state`` [B, K-1, C] (decode), prepends it; else zero-pads.
    Returns (out [B, L, C], new_state [B, K-1, C]).
    """
    Bsz, L, C = u.shape
    if state is None:
        state = jnp.zeros((Bsz, CONV_K - 1, C), u.dtype)
    full = jnp.concatenate([state, u], axis=1)  # [B, K-1+L, C]
    out = jnp.zeros((Bsz, L, C), jnp.float32)
    for k in range(CONV_K):
        out = out + full[:, k : k + L, :].astype(jnp.float32) * w[k][None, None, :]
    new_state = full[:, L:, :]
    return jax.nn.silu(out).astype(u.dtype), new_state


def ssd_chunked(
    x: jnp.ndarray,  # [B, L, H, dh] (dt-unweighted input)
    dt: jnp.ndarray,  # [B, L, H] positive step sizes
    A: jnp.ndarray,  # [H] negative decay rates
    Bm: jnp.ndarray,  # [B, L, N]
    Cm: jnp.ndarray,  # [B, L, N]
    chunk: int,
    initial_state: Optional[jnp.ndarray] = None,  # [B, H, N, dh]
    unroll: bool = False,
    compute_dtype=jnp.float32,
):
    """Chunked SSD scan.  Returns (y [B, L, H, dh], final_state).

    ``compute_dtype=bfloat16`` runs the quadratic dual form (the O(L*q)
    intra-chunk tensors — the block's dominant HBM traffic) in bf16 with
    fp32 accumulation; the inter-chunk state recurrence stays fp32 (long-
    horizon decay products are precision-critical).  §Perf mamba2 iter2.
    """
    Bsz, L, H, dh = x.shape
    N = Bm.shape[-1]
    nc = -(-L // chunk)
    pad = nc * chunk - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    xw = (x * dt[..., None]).astype(jnp.float32)  # dt-weighted input
    dA = dt * A[None, None, :]  # [B, L', H] log-decay (negative)
    q = chunk
    xw = xw.reshape(Bsz, nc, q, H, dh)
    dA = dA.reshape(Bsz, nc, q, H)
    Bc = Bm.reshape(Bsz, nc, q, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, q, N).astype(jnp.float32)

    dA_cs = jnp.cumsum(dA, axis=2)  # [B, nc, q, H]

    # --- intra-chunk (quadratic dual form) ---
    # L_mask[b,c,i,j,h] = exp(dA_cs_i - dA_cs_j) for j <= i else 0
    cd = compute_dtype
    diff = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]  # [B,nc,q,q,H]
    causal = jnp.tril(jnp.ones((q, q), bool))
    Lmask = jnp.where(
        causal[None, None, :, :, None], jnp.exp(diff), 0.0
    ).astype(cd)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc.astype(cd), Bc.astype(cd),
                        preferred_element_type=cd)  # [B,nc,q,q]
    y_intra = jnp.einsum(
        "bcij,bcijh,bcjhd->bcihd", scores, Lmask, xw.astype(cd),
        preferred_element_type=jnp.float32,
    )

    # --- chunk boundary states ---
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # [B,nc,q,H]
    S_contrib = jnp.einsum("bcqn,bcqh,bcqhd->bchnd", Bc, decay_to_end, xw)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # [B,nc,H]

    def scan_fn(S, inp):
        contrib, cd = inp  # [B,H,N,dh], [B,H]
        S_out = S  # state BEFORE this chunk
        S = S * cd[:, :, None, None] + contrib
        return S, S_out

    S0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((Bsz, H, N, dh), jnp.float32)
    )
    S_final, S_prev = jax.lax.scan(
        scan_fn,
        S0,
        (S_contrib.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
        unroll=unroll,
    )
    S_prev = S_prev.transpose(1, 0, 2, 3, 4)  # [B,nc,H,N,dh]

    # --- inter-chunk contribution ---
    y_inter = jnp.einsum(
        "bcqn,bchnd,bcqh->bcqhd", Cc, S_prev, jnp.exp(dA_cs)
    )
    y = (y_intra + y_inter).reshape(Bsz, nc * q, H, dh)[:, :L]
    return y, S_final


def ssd_sequential(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    initial_state: Optional[jnp.ndarray] = None,
):
    """Step-by-step SSD recurrence oracle (tests validate ssd_chunked):

      S_t = exp(dt_t * A) * S_{t-1} + dt_t * (B_t (x) x_t);   y_t = C_t . S_t
    """
    Bsz, L, H, dh = x.shape
    N = Bm.shape[-1]
    S0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((Bsz, H, N, dh), jnp.float32)
    )

    def step(S, inp):
        xt, dtt, bt, ct = inp  # [B,H,dh], [B,H], [B,N], [B,N]
        decay = jnp.exp(dtt * A[None, :])  # [B,H]
        S = S * decay[:, :, None, None] + jnp.einsum(
            "bn,bh,bhd->bhnd", bt, dtt, xt.astype(jnp.float32)
        )
        y = jnp.einsum("bn,bhnd->bhd", ct, S)
        return S, y

    xs = (
        x.transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2),
        Bm.astype(jnp.float32).transpose(1, 0, 2),
        Cm.astype(jnp.float32).transpose(1, 0, 2),
    )
    S_final, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3), S_final


def ssm_block_apply(
    params: dict,
    h: jnp.ndarray,  # [B, L, D]
    cfg: ArchConfig,
    cache: Optional[dict] = None,
) -> tuple[jnp.ndarray, Optional[dict]]:
    """One mamba2 block (pre-norm residual handled by caller).

    cache (decode): {'conv': [B, K-1, Di+2N], 'S': [B, H, N, dh]}.
    """
    Di, N, H, dh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    Bsz, L, D = h.shape
    proj = h @ params["in_proj"]
    z, x, Bm, Cm, dt = _split_proj(proj, cfg)

    conv_in = jnp.concatenate([x, Bm, Cm], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"], conv_state)
    x, Bm, Cm = jnp.split(conv_out, [Di, Di + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = x.reshape(Bsz, L, H, dh)

    cd = _dtype(cfg)  # bf16 models run the dual form in bf16 (see ssd_chunked)
    if cache is None:
        y, _ = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk,
                           unroll=cfg.unroll_scans, compute_dtype=cd)
        new_cache = None
    else:
        # O(1) recurrent steps (decode): fold L steps sequentially
        y, S = ssd_chunked(xh, dt, A, Bm, Cm, chunk=max(L, 1),
                           initial_state=cache["S"], compute_dtype=cd)
        new_cache = {"conv": new_conv, "S": S}

    y = y + params["D_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, L, Di).astype(h.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype)
    y = rmsnorm(y, params["norm"])
    return y @ params["out_proj"], new_cache
