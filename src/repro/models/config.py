"""Architecture config schema for the assigned LM-family architectures.

Every assigned architecture is a frozen ``ArchConfig``; ``src/repro/configs/``
holds one module per arch with the exact published hyper-parameters plus a
reduced ``smoke()`` variant for CPU tests.  The same schema drives model
construction, sharding rules, the dry-run, and the roofline analytics.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'audio' | 'vlm'
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 2.0
    # expert-compute implementation: 'gathered' (index-dispatch, per-expert
    # capacity, batched GEMMs — FLOP-exact) or 'ragged' (sort + ragged_dot;
    # XLA's default lowering is dense over all local groups — see §Perf).
    moe_impl: str = "gathered"
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # --- attention details ---
    qk_norm: bool = False
    rope_theta: float = 1e4
    mrope: bool = False  # qwen2-vl multimodal rope (3 position streams)
    # --- hybrid (zamba2): shared attention block applied every N ssm blocks
    attn_every: int = 0
    shared_attn_lora_rank: int = 0
    # --- encoder-decoder (whisper): n_layers == decoder layers
    encoder_decoder: bool = False
    enc_layers: int = 0
    enc_seq: int = 1500  # audio frames after the (stubbed) conv frontend
    # --- numerics / memory policy ---
    dtype: str = "bfloat16"
    remat: str = "none"  # 'none' | 'full'
    optimizer: str = "adamw"  # 'adamw' | 'adafactor' (factored 2nd moment)
    # attention working-set policy: kv-chunked online-softmax attention
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # score dtype: fp32 scores are the conservative default; bf16 scores
    # (with fp32 running max/denominator/accumulator) halve the dominant
    # attention HBM traffic at <0.5% softmax error (§Perf granite iter4)
    attn_bf16_scores: bool = False
    # cost-analysis mode: unroll layer/chunk scans so XLA's HloCostAnalysis
    # (which visits while bodies ONCE) counts every layer.  The roofline
    # pipeline compiles 1- and 2-layer unrolled variants and extrapolates;
    # the real (scanned) compile provides memory analysis + sharding proof.
    unroll_scans: bool = False

    # vocab is padded to this multiple so the vocab dim shards cleanly over
    # the model axis (Megatron-style); loss masks the padded logit columns.
    vocab_pad_to: int = 256

    def __post_init__(self):
        if self.d_head is None and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    # ---- analytics used by roofline + forecasting -------------------------
    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        n = V * D  # embedding
        n += V * D  # lm head (untied)
        Hq = (self.n_heads or 0) * (self.d_head or 0)
        Hkv = (self.n_kv_heads or 0) * (self.d_head or 0)
        attn = D * Hq + 2 * D * Hkv + Hq * D
        dense_mlp = 3 * D * F  # SwiGLU
        if self.family == "moe":
            mlp = self.n_experts * 3 * D * self.d_ff
        else:
            mlp = dense_mlp
        if self.family == "ssm":
            n += L * self._ssm_block_params()
        elif self.family == "hybrid":
            n_attn_apps = (L + self.attn_every - 1) // self.attn_every if self.attn_every else 0
            n += L * self._ssm_block_params()
            n += attn + dense_mlp  # one shared block
            n += n_attn_apps * self._lora_params()
        elif self.family == "audio":
            n += self.enc_layers * (attn + dense_mlp)  # encoder
            n += L * (2 * attn + dense_mlp)  # decoder: self + cross attn
        else:
            n += L * (attn + mlp)
        return n

    def _ssm_block_params(self) -> int:
        D, Di, Ns = self.d_model, self.d_inner, self.ssm_state
        H = self.ssm_heads
        in_proj = D * (2 * Di + 2 * Ns + H)  # z, x, B, C, dt
        out_proj = Di * D
        return in_proj + out_proj + Di + 2 * H  # conv-less variant + A, D gains

    def _lora_params(self) -> int:
        r = self.shared_attn_lora_rank
        if not r:
            return 0
        D = self.d_model
        Hq = self.n_heads * self.d_head
        Hkv = self.n_kv_heads * self.d_head
        return r * (2 * D + Hq + 2 * Hkv + D) // 1

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        total = self.param_count()
        expert = self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
        active = self.n_layers * self.top_k * 3 * self.d_model * self.d_ff
        return total - expert + active

    def model_flops_per_token(self) -> float:
        """6 * N(_active) — the §Roofline MODEL_FLOPS convention."""
        return 6.0 * self.active_param_count()
