"""Shared transformer layers: norms, RoPE / M-RoPE, chunked flash attention
(train/prefill), cached decode attention, SwiGLU MLP.

All layers are pure functions over param dicts; weights are created by
``init_*`` functions and stored bf16 (compute in bf16, reductions fp32).
Attention uses an online-softmax KV-chunked scan (flash-attention algorithm
in pure JAX) so the working set stays linear in sequence length — required
for the 32k prefill cells and a better roofline than materialized scores.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

Initializer = jax.nn.initializers.Initializer


def _dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def dense_init(rng, shape, dtype, scale: float = 1.0):
    fan_in = shape[0]
    return (jax.random.normal(rng, shape) * (scale / jnp.sqrt(fan_in))).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, d]; positions: [B, S] -> rotated x."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [d/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, d/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    r1 = xf1 * cos - xf2 * sin
    r2 = xf2 * cos + xf1 * sin
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape).astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float, sections=(16, 24, 24)
) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE: positions [3, B, S] (t, h, w streams), the
    head dim is split into three frequency sections, one per stream.
    ``sections`` are half-dim section sizes (sum == d_head // 2)."""
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)  # [d/2]
    # pick which stream drives each frequency pair
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=d // 2
    )  # [d/2]
    pos = positions.astype(jnp.float32)  # [3, B, S]
    ang_all = pos[..., None] * freqs  # [3, B, S, d/2]
    # per-frequency-pair stream selection via one-hot contraction
    ang = jnp.einsum("sbtd,ds->btd", ang_all, jax.nn.one_hot(sec_id, 3))
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    r1 = xf1 * cos - xf2 * sin
    r2 = xf2 * cos + xf1 * sin
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape).astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------
def init_attention(rng, cfg: ArchConfig, d_model: Optional[int] = None) -> dict:
    D = d_model or cfg.d_model
    dh, Hq, Hkv = cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 4)
    dt = _dtype(cfg)
    p = {
        "wq": dense_init(ks[0], (D, Hq * dh), dt),
        "wk": dense_init(ks[1], (D, Hkv * dh), dt),
        "wv": dense_init(ks[2], (D, Hkv * dh), dt),
        "wo": dense_init(ks[3], (Hq * dh, D), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dt)
        p["k_norm"] = jnp.ones((dh,), dt)
    return p


def _merge_gqa(q: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """[B, S, Hq, d] -> [B, S, Hkv, G, d]."""
    B, S, Hq, d = q.shape
    return q.reshape(B, S, n_kv, Hq // n_kv, d)


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool,
    q_offset: int = 0,
    kv_chunk: int = 1024,
    unroll: bool = False,
    bf16_scores: bool = False,
) -> jnp.ndarray:
    """Online-softmax (flash) attention via a scan over KV chunks.

    q: [B, Sq, Hkv, G, d]; k/v: [B, Skv, Hkv, d].  Returns [B, Sq, Hkv, G, d].
    ``q_offset`` is the absolute position of q[0] (for causal masking during
    chunked prefill / decode).  Memory: O(Sq * kv_chunk) per head instead of
    O(Sq * Skv).
    """
    B, Sq, Hkv, G, d = q.shape
    Skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    n_chunks = -(-Skv // kv_chunk)
    pad = n_chunks * kv_chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, kv_chunk, Hkv, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, Hkv, d).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(Sq)

    # scores in bf16 (optional) with fp32 running max/denominator/accumulator
    sd = jnp.bfloat16 if bf16_scores else jnp.float32
    neg = jnp.asarray(-jnp.inf, sd)

    def step(carry, inp):
        m, l, acc = carry  # [B,Sq,Hkv,G], [B,Sq,Hkv,G], [B,Sq,Hkv,G,d]
        kci, vci, c_idx = inp
        kv_pos = c_idx * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum(
            "bqhgd,bkhd->bqhgk", q, kci, preferred_element_type=sd
        ) * scale.astype(sd)
        valid = kv_pos[None, :] < Skv  # mask kv padding
        if causal:
            valid = valid & (kv_pos[None, :] <= q_pos[:, None])
        s = jnp.where(valid[None, :, None, None, :], s, neg)
        m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None].astype(sd))  # [.., kc] in sd
        p = jnp.where(valid[None, :, None, None, :], p, jnp.asarray(0, sd))
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1, dtype=jnp.float32)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(v.dtype), vci,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, jnp.arange(n_chunks)), unroll=unroll
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def attention(
    params: dict,
    x: jnp.ndarray,
    cfg: ArchConfig,
    positions: jnp.ndarray,
    causal: bool = True,
    kv_cache: Optional[dict] = None,
    cross_kv: Optional[tuple] = None,
) -> tuple[jnp.ndarray, Optional[dict]]:
    """GQA attention, all modes.

    * training / prefill: kv_cache is None -> chunked flash attention.
    * decode: kv_cache = {'k','v','len'} -> append one step, attend to cache.
    * cross-attention: cross_kv = (k, v) precomputed from the encoder.

    x: [B, S, D]; positions: [B, S] (or [3, B, S] for mrope).
    Returns (out [B, S, D], updated kv_cache or None).
    """
    B, S, D = x.shape
    dh, Hq, Hkv = cfg.d_head, cfg.n_heads, cfg.n_kv_heads
    q = (x @ params["wq"]).reshape(B, S, Hq, dh)
    if cross_kv is None:
        k = (x @ params["wk"]).reshape(B, S, Hkv, dh)
        v = (x @ params["wv"]).reshape(B, S, Hkv, dh)
    else:
        k, v = cross_kv

    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        if cross_kv is None:
            k = rmsnorm(k, params["k_norm"])

    if cross_kv is None:  # rope only applies to self-attention
        if cfg.mrope:
            q = apply_mrope(q, positions, cfg.rope_theta, _mrope_sections(dh))
            k = apply_mrope(k, positions, cfg.rope_theta, _mrope_sections(dh))
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_cache is not None:
        # serving: append this step's k/v into the cache at index 'len'
        idx = kv_cache["len"]
        ck = jax.lax.dynamic_update_slice_in_dim(kv_cache["k"], k, idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(kv_cache["v"], v, idx, axis=1)
        new_cache = {"k": ck, "v": cv, "len": idx + S}
        if S > 1:
            # prefill (assumes an empty cache, idx == 0): causal chunked
            # flash attention over the prompt only — O(S * kv_chunk) memory.
            qg = _merge_gqa(q, Hkv)
            o = chunked_attention(
                qg, k, v, causal=True, kv_chunk=cfg.kv_chunk,
                unroll=cfg.unroll_scans, bf16_scores=cfg.attn_bf16_scores,
            )
        else:
            # decode: attend to the whole cache, masking beyond 'len' + S
            # and keeping causality within the step.
            k, v = ck, cv
            qg = _merge_gqa(q, Hkv)
            s = jnp.einsum(
                "bqhgd,bkhd->bqhgk", qg, k, preferred_element_type=jnp.float32
            ) / jnp.sqrt(dh)
            kv_pos = jnp.arange(k.shape[1])
            q_pos = idx + jnp.arange(S)
            valid = kv_pos[None, :] <= q_pos[:, None]
            s = jnp.where(valid[None, :, None, None, :], s, -jnp.inf)
            p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
            o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v)
    else:
        qg = _merge_gqa(q, Hkv)
        o = chunked_attention(
            qg, k, v, causal=causal and cross_kv is None,
            kv_chunk=cfg.kv_chunk, unroll=cfg.unroll_scans,
            bf16_scores=cfg.attn_bf16_scores,
        )
    o = o.reshape(B, S, Hq * dh)
    return o @ params["wo"], new_cache


def _mrope_sections(d_head: int) -> tuple:
    """Qwen2-VL uses (16, 24, 24) half-dim sections for d_head=128; scale
    proportionally for other head dims."""
    half = d_head // 2
    a = half // 4
    b = (half - a) // 2
    return (a, b, half - a - b)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------
def init_mlp(rng, cfg: ArchConfig, d_ff: Optional[int] = None) -> dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    dt = _dtype(cfg)
    return {
        "w_gate": dense_init(ks[0], (D, F), dt),
        "w_up": dense_init(ks[1], (D, F), dt),
        "w_down": dense_init(ks[2], (F, D), dt),
    }


def mlp(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    g = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    return (g * (x @ params["w_up"])) @ params["w_down"]
