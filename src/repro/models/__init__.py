# LM-architecture substrate: the 10 assigned architectures as one functional
# model with family dispatch (dense / moe / ssm / hybrid / audio / vlm).
from repro.models import config, layers, moe, ssm, transformer  # noqa: F401
from repro.models.config import ArchConfig  # noqa: F401
