"""Model assembly for all assigned architecture families.

One functional model with family dispatch:
  dense / vlm : pre-norm GQA transformer (RoPE or M-RoPE), SwiGLU MLP
  moe         : same, MLP replaced by expert-parallel MoE
  ssm         : stack of mamba2 blocks (attention-free)
  hybrid      : mamba2 backbone + ONE shared attention block applied every
                ``attn_every`` layers with per-application LoRA (zamba2)
  audio       : whisper-style encoder-decoder (stub frame embeddings)

Layers are stacked with ``jax.lax.scan`` over per-layer param pytrees (small
HLO, fast 61-layer compiles); ``cfg.remat`` wraps the block in
``jax.checkpoint``.  Entry points: ``init_params``, ``forward`` (logits),
``loss_fn``, ``prefill``/``decode_step`` (serving with KV/state caches).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ArchConfig


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def _init_block(rng, cfg: ArchConfig) -> dict:
    """One decoder block's params (family-dependent)."""
    if cfg.family == "ssm":
        k1, k2 = jax.random.split(rng)
        return {
            "norm1": jnp.ones((cfg.d_model,), L._dtype(cfg)),
            "ssm": ssm_lib.init_ssm_block(k1, cfg),
        }
    if cfg.family == "hybrid":
        k1, = jax.random.split(rng, 1)
        return {
            "norm1": jnp.ones((cfg.d_model,), L._dtype(cfg)),
            "ssm": ssm_lib.init_ssm_block(k1, cfg),
        }
    k1, k2 = jax.random.split(rng)
    blk = {
        "norm1": jnp.ones((cfg.d_model,), L._dtype(cfg)),
        "norm2": jnp.ones((cfg.d_model,), L._dtype(cfg)),
        "attn": L.init_attention(k1, cfg),
    }
    if cfg.family == "moe":
        blk["moe"] = moe_lib.init_moe(k2, cfg)
    else:
        blk["mlp"] = L.init_mlp(k2, cfg)
    return blk


def _init_shared_attn(rng, cfg: ArchConfig) -> dict:
    """Zamba2 shared attention+MLP block + per-application LoRA stacks."""
    k1, k2, k3 = jax.random.split(rng, 3)
    n_apps = _n_attn_apps(cfg)
    r = max(cfg.shared_attn_lora_rank, 1)
    dt = L._dtype(cfg)
    Hq = cfg.n_heads * cfg.d_head
    lora = {
        "a_q": jax.random.normal(k3, (n_apps, cfg.d_model, r), dt) * 0.02,
        "b_q": jnp.zeros((n_apps, r, Hq), dt),
    }
    return {
        "norm1": jnp.ones((cfg.d_model,), dt),
        "norm2": jnp.ones((cfg.d_model,), dt),
        "attn": L.init_attention(k1, cfg),
        "mlp": L.init_mlp(k2, cfg),
        "lora": lora,
    }


def _n_attn_apps(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.attn_every if cfg.attn_every else 0


def init_params(rng, cfg: ArchConfig) -> dict:
    keys = jax.random.split(rng, 8)
    dt = L._dtype(cfg)
    V, D = cfg.vocab_padded, cfg.d_model
    params = {
        "embed": (jax.random.normal(keys[0], (V, D)) * 0.02).astype(dt),
        "lm_head": L.dense_init(keys[1], (D, V), dt),
        "final_norm": jnp.ones((D,), dt),
    }
    n_layers = cfg.n_layers
    layer_keys = jax.random.split(keys[2], n_layers)
    params["blocks"] = jax.vmap(lambda k: _init_block(k, cfg))(layer_keys)
    if cfg.family == "hybrid":
        params["shared_attn"] = _init_shared_attn(keys[3], cfg)
    if cfg.family == "audio":
        enc_keys = jax.random.split(keys[4], cfg.enc_layers)
        enc_cfg = cfg  # same dims for encoder
        params["enc_blocks"] = jax.vmap(
            lambda k: {
                "norm1": jnp.ones((D,), dt),
                "norm2": jnp.ones((D,), dt),
                "attn": L.init_attention(jax.random.fold_in(k, 0), enc_cfg),
                "mlp": L.init_mlp(jax.random.fold_in(k, 1), enc_cfg),
            }
        )(enc_keys)
        params["enc_norm"] = jnp.ones((D,), dt)
        dec_keys = jax.random.split(keys[5], n_layers)
        params["cross_blocks"] = jax.vmap(
            lambda k: {
                "norm": jnp.ones((D,), dt),
                "attn": L.init_attention(k, cfg),
            }
        )(dec_keys)
    return params


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------
def _dense_block(blk, h, cfg, positions, causal=True, cross_kv=None, kv_cache=None):
    a, new_cache = L.attention(
        blk["attn"], L.rmsnorm(h, blk["norm1"]), cfg, positions,
        causal=causal, kv_cache=kv_cache,
    )
    h = h + a
    if cross_kv is not None:
        c, _ = L.attention(
            cross_kv["params"]["attn"],
            L.rmsnorm(h, cross_kv["params"]["norm"]),
            cfg, positions, causal=False, cross_kv=(cross_kv["k"], cross_kv["v"]),
        )
        h = h + c
    if cfg.family == "moe":
        h = h + moe_lib.moe_apply(
            L.rmsnorm(h, blk["norm2"]), blk["moe"], cfg, mesh=_MESH[0]
        )
    else:
        h = h + L.mlp(blk["mlp"], L.rmsnorm(h, blk["norm2"]))
    return h, new_cache


# Mesh handle for the MoE shard_map path; set by the launcher / dryrun via
# ``set_mesh`` (None -> single-shard local compute, used by CPU smokes).
_MESH: list = [None]


def set_mesh(mesh) -> None:
    _MESH[0] = mesh


def _constrain_tokens(h: jnp.ndarray) -> jnp.ndarray:
    """Pin activation sharding: batch over (pod, data), d_model replicated.

    Without this, GSPMD propagates the embedding table's (model, data)
    layout through the gather and leaves the BATCH dim replicated — every
    device then does dp-times redundant work (measured 16x on the 16x16
    mesh; see EXPERIMENTS.md §Perf iteration 1).  No-op without a mesh.
    """
    mesh = _MESH[0]
    if mesh is None:
        return h
    from jax.sharding import NamedSharding, PartitionSpec as P

    dp = tuple(a for a in mesh.axis_names if a != "model")
    batch = h.shape[0]
    prod = 1
    axes = None
    for i, a in enumerate(dp):
        prod *= mesh.shape[a]
        if batch % prod == 0:
            axes = dp[: i + 1]
    spec = P(axes, *(None,) * (h.ndim - 1))
    return jax.lax.with_sharding_constraint(h, NamedSharding(mesh, spec))


def _scan_blocks(params, h, cfg, positions, body):
    """Scan ``body`` over stacked per-layer params."""
    def f(carry, blk):
        out = _constrain_tokens(body(blk, carry))
        return out, None

    if cfg.remat == "full":
        f = jax.checkpoint(f, prevent_cse=False)
    h, _ = jax.lax.scan(f, h, params["blocks"], unroll=cfg.unroll_scans)
    return h


def _hybrid_forward(params, h, cfg, positions):
    """Zamba2: scan mamba blocks; every ``attn_every`` layers apply the
    shared attention block with that application's LoRA delta on W_q."""
    n_apps = _n_attn_apps(cfg)
    per = cfg.attn_every
    blocks = params["blocks"]
    shared = params["shared_attn"]

    def ssm_body(blk, hh):
        y, _ = ssm_lib.ssm_block_apply(blk["ssm"], L.rmsnorm(hh, blk["norm1"]), cfg)
        return _constrain_tokens(hh + y)

    def superblock(carry, inp):
        hh = carry
        blk_group, app_idx = inp  # stacked group of ``per`` ssm blocks

        def inner(c, blk):
            return ssm_body(blk, c), None

        hh, _ = jax.lax.scan(inner, hh, blk_group, unroll=cfg.unroll_scans)
        # shared attention with per-application LoRA on W_q
        lora_a = shared["lora"]["a_q"][app_idx]
        lora_b = shared["lora"]["b_q"][app_idx]
        attn_p = dict(shared["attn"])
        attn_p["wq"] = attn_p["wq"] + lora_a @ lora_b
        a, _ = L.attention(attn_p, L.rmsnorm(hh, shared["norm1"]), cfg, positions)
        hh = hh + a
        hh = hh + L.mlp(shared["mlp"], L.rmsnorm(hh, shared["norm2"]))
        return _constrain_tokens(hh), None

    n_super = n_apps * per
    grouped = jax.tree.map(
        lambda x: x[:n_super].reshape((n_apps, per) + x.shape[1:]), blocks
    )
    fn = superblock
    if cfg.remat == "full":
        fn = jax.checkpoint(fn, prevent_cse=False)
    h, _ = jax.lax.scan(fn, h, (grouped, jnp.arange(n_apps)), unroll=cfg.unroll_scans)
    # trailing ssm blocks (n_layers % attn_every)
    tail = jax.tree.map(lambda x: x[n_super:], blocks)
    if cfg.n_layers - n_super > 0:
        def inner2(c, blk):
            return ssm_body(blk, c), None
        h, _ = jax.lax.scan(inner2, h, tail, unroll=cfg.unroll_scans)
    return h


def _encode_audio(params, frames, cfg):
    """Whisper encoder over stub frame embeddings [B, T_enc, D]."""
    pos = jnp.broadcast_to(jnp.arange(frames.shape[1]), frames.shape[:2])
    def body(blk, hh):
        a, _ = L.attention(
            blk["attn"], L.rmsnorm(hh, blk["norm1"]), cfg, pos, causal=False
        )
        hh = hh + a
        return _constrain_tokens(hh + L.mlp(blk["mlp"], L.rmsnorm(hh, blk["norm2"])))

    def f(carry, blk):
        return body(blk, carry), None
    if cfg.remat == "full":
        f = jax.checkpoint(f, prevent_cse=False)
    h, _ = jax.lax.scan(f, frames, params["enc_blocks"], unroll=cfg.unroll_scans)
    return L.rmsnorm(h, params["enc_norm"])


def forward(
    params: dict,
    tokens: jnp.ndarray,
    cfg: ArchConfig,
    positions: Optional[jnp.ndarray] = None,
    frames: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Token logits [B, S, V] (training / prefill path, no caches)."""
    B, S = tokens.shape
    h = _constrain_tokens(params["embed"][tokens])  # gather
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions, (3, B, S))

    if cfg.family in ("dense", "vlm", "moe"):
        def body(blk, hh):
            out, _ = _dense_block(blk, hh, cfg, positions)
            return out
        h = _scan_blocks(params, h, cfg, positions, body)
    elif cfg.family == "ssm":
        def body(blk, hh):
            y, _ = ssm_lib.ssm_block_apply(blk["ssm"], L.rmsnorm(hh, blk["norm1"]), cfg)
            return hh + y
        h = _scan_blocks(params, h, cfg, positions, body)
    elif cfg.family == "hybrid":
        h = _hybrid_forward(params, h, cfg, positions)
    elif cfg.family == "audio":
        if frames is None:
            raise ValueError("audio family needs `frames` (stub embeddings)")
        enc = _encode_audio(params, frames, cfg)

        def body(carry, blks):
            hh = carry
            blk, xblk = blks
            # precompute cross K/V from encoder output for this layer
            kx = (enc @ xblk["attn"]["wk"]).reshape(
                B, enc.shape[1], cfg.n_kv_heads, cfg.d_head
            )
            vx = (enc @ xblk["attn"]["wv"]).reshape(
                B, enc.shape[1], cfg.n_kv_heads, cfg.d_head
            )
            cross = {"params": {"attn": xblk["attn"], "norm": xblk["norm"]},
                     "k": kx, "v": vx}
            out, _ = _dense_block(blk, hh, cfg, positions, cross_kv=cross)
            return _constrain_tokens(out), None

        f = body
        if cfg.remat == "full":
            f = jax.checkpoint(f, prevent_cse=False)
        h, _ = jax.lax.scan(
            f, h, (params["blocks"], params["cross_blocks"]),
            unroll=cfg.unroll_scans,
        )
    else:
        raise ValueError(f"unknown family {cfg.family!r}")

    h = L.rmsnorm(h, params["final_norm"])
    return h @ params["lm_head"]


def loss_fn(
    params: dict, batch: dict, cfg: ArchConfig
) -> tuple[jnp.ndarray, dict]:
    """Next-token cross-entropy (fp32 softmax) + z-loss, mean over tokens."""
    logits = forward(
        params, batch["tokens"], cfg,
        positions=batch.get("positions"), frames=batch.get("frames"),
    ).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab_size:
        # mask padded vocab columns out of the softmax
        pad = jnp.arange(cfg.vocab_padded) >= cfg.vocab_size
        logits = logits - pad.astype(jnp.float32) * 1e9
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    # label log-prob via a masked reduction instead of take_along_axis: the
    # vocab dim is model-sharded and a gather would force an all-gather of
    # the fp32 logits; the iota-compare reduces locally and psums a scalar.
    v_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    ll = jnp.sum(
        jnp.where(v_iota == labels[..., None], logits, 0.0), axis=-1
    )
    nll = (logz - ll).mean()
    zloss = 1e-4 * (logz**2).mean()
    return nll + zloss, {"nll": nll, "zloss": zloss}


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int = 0) -> dict:
    """Decode caches: per-layer KV for attention families, (conv, S) state
    for SSM/hybrid; cross-KV for audio."""
    dt = L._dtype(cfg)
    dh, Hkv, Lr = cfg.d_head or 0, cfg.n_kv_heads or 0, cfg.n_layers
    if cfg.family in ("dense", "vlm", "moe"):
        return {
            "k": jnp.zeros((Lr, batch, max_len, Hkv, dh), dt),
            "v": jnp.zeros((Lr, batch, max_len, Hkv, dh), dt),
            "len": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "ssm":
        return {
            "conv": jnp.zeros((Lr, batch, ssm_lib.CONV_K - 1, cfg.d_inner + 2 * cfg.ssm_state), dt),
            "S": jnp.zeros((Lr, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
            "len": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "hybrid":
        n_apps = _n_attn_apps(cfg)
        return {
            "conv": jnp.zeros((Lr, batch, ssm_lib.CONV_K - 1, cfg.d_inner + 2 * cfg.ssm_state), dt),
            "S": jnp.zeros((Lr, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32),
            "k": jnp.zeros((n_apps, batch, max_len, Hkv, dh), dt),
            "v": jnp.zeros((n_apps, batch, max_len, Hkv, dh), dt),
            "len": jnp.zeros((), jnp.int32),
        }
    if cfg.family == "audio":
        return {
            "k": jnp.zeros((Lr, batch, max_len, Hkv, dh), dt),
            "v": jnp.zeros((Lr, batch, max_len, Hkv, dh), dt),
            "xk": jnp.zeros((Lr, batch, enc_len, Hkv, dh), dt),
            "xv": jnp.zeros((Lr, batch, enc_len, Hkv, dh), dt),
            "len": jnp.zeros((), jnp.int32),
        }
    raise ValueError(cfg.family)


def decode_step(
    params: dict, cache: dict, tokens: jnp.ndarray, cfg: ArchConfig
) -> tuple[dict, jnp.ndarray]:
    """One decode step: tokens [B, 1] -> (updated cache, logits [B, 1, V]).

    Layer caches are stacked on axis 0 and the block scan threads per-layer
    slices through, so decode is a single fused scan like training.
    """
    B, S = tokens.shape
    h = _constrain_tokens(params["embed"][tokens])
    pos = jnp.broadcast_to(cache["len"] + jnp.arange(S), (B, S))
    if cfg.mrope:
        pos = jnp.broadcast_to(pos, (3, B, S))

    if cfg.family in ("dense", "vlm", "moe"):
        def body(hh, inp):
            blk, kc, vc = inp
            out, nc = _dense_block(
                blk, hh, cfg, pos,
                kv_cache={"k": kc, "v": vc, "len": cache["len"]},
            )
            return out, (nc["k"], nc["v"])

        h, (ks, vs) = jax.lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]), unroll=cfg.unroll_scans)
        new_cache = {"k": ks, "v": vs, "len": cache["len"] + S}
    elif cfg.family == "ssm":
        def body(hh, inp):
            blk, conv, S_ = inp
            y, nc = ssm_lib.ssm_block_apply(
                blk["ssm"], L.rmsnorm(hh, blk["norm1"]), cfg,
                cache={"conv": conv, "S": S_},
            )
            return hh + y, (nc["conv"], nc["S"])

        h, (convs, Ss) = jax.lax.scan(body, h, (params["blocks"], cache["conv"], cache["S"]), unroll=cfg.unroll_scans)
        new_cache = {"conv": convs, "S": Ss, "len": cache["len"] + S}
    elif cfg.family == "hybrid":
        h, new_cache = _hybrid_decode(params, cache, h, pos, cfg)
    elif cfg.family == "audio":
        def body(hh, inp):
            blk, xblk, kc, vc, xk, xv = inp
            cross = {"params": {"attn": xblk["attn"], "norm": xblk["norm"]},
                     "k": xk, "v": xv}
            out, nc = _dense_block(
                blk, hh, cfg, pos, cross_kv=cross,
                kv_cache={"k": kc, "v": vc, "len": cache["len"]},
            )
            return out, (nc["k"], nc["v"])

        h, (ks, vs) = jax.lax.scan(
            body, h,
            (params["blocks"], params["cross_blocks"], cache["k"], cache["v"],
             cache["xk"], cache["xv"]),
            unroll=cfg.unroll_scans,
        )
        new_cache = dict(cache)
        new_cache.update({"k": ks, "v": vs, "len": cache["len"] + S})
    else:
        raise ValueError(cfg.family)

    h = L.rmsnorm(h, params["final_norm"])
    return new_cache, h @ params["lm_head"]


def _hybrid_decode(params, cache, h, pos, cfg):
    per = cfg.attn_every
    n_apps = _n_attn_apps(cfg)
    n_super = n_apps * per
    blocks = params["blocks"]
    shared = params["shared_attn"]
    grouped = jax.tree.map(
        lambda x: x[:n_super].reshape((n_apps, per) + x.shape[1:]), blocks
    )
    conv_g = cache["conv"][:n_super].reshape((n_apps, per) + cache["conv"].shape[1:])
    S_g = cache["S"][:n_super].reshape((n_apps, per) + cache["S"].shape[1:])

    def superblock(hh, inp):
        blk_group, conv_grp, S_grp, kc, vc, app_idx = inp

        def inner(c, blk_state):
            blk, conv, S_ = blk_state
            y, nc = ssm_lib.ssm_block_apply(
                blk["ssm"], L.rmsnorm(c, blk["norm1"]), cfg,
                cache={"conv": conv, "S": S_},
            )
            return c + y, (nc["conv"], nc["S"])

        hh, (convs, Ss) = jax.lax.scan(inner, hh, (blk_group, conv_grp, S_grp), unroll=cfg.unroll_scans)
        lora_a = shared["lora"]["a_q"][app_idx]
        lora_b = shared["lora"]["b_q"][app_idx]
        attn_p = dict(shared["attn"])
        attn_p["wq"] = attn_p["wq"] + lora_a @ lora_b
        a, nc = L.attention(
            attn_p, L.rmsnorm(hh, shared["norm1"]), cfg, pos,
            kv_cache={"k": kc, "v": vc, "len": cache["len"]},
        )
        hh = hh + a
        hh = hh + L.mlp(shared["mlp"], L.rmsnorm(hh, shared["norm2"]))
        return hh, (convs, Ss, nc["k"], nc["v"])

    h, (convs, Ss, ks, vs) = jax.lax.scan(
        superblock, h,
        (grouped, conv_g, S_g, cache["k"], cache["v"], jnp.arange(n_apps)),
        unroll=cfg.unroll_scans,
    )
    new_conv = convs.reshape((n_super,) + convs.shape[2:])
    new_S = Ss.reshape((n_super,) + Ss.shape[2:])
    # trailing blocks
    tail_n = cfg.n_layers - n_super
    if tail_n > 0:
        tail = jax.tree.map(lambda x: x[n_super:], blocks)

        def inner2(c, blk_state):
            blk, conv, S_ = blk_state
            y, nc = ssm_lib.ssm_block_apply(
                blk["ssm"], L.rmsnorm(c, blk["norm1"]), cfg,
                cache={"conv": conv, "S": S_},
            )
            return c + y, (nc["conv"], nc["S"])

        h, (tc, tS) = jax.lax.scan(
            inner2, h, (tail, cache["conv"][n_super:], cache["S"][n_super:]),
            unroll=cfg.unroll_scans,
        )
        new_conv = jnp.concatenate([new_conv, tc], axis=0)
        new_S = jnp.concatenate([new_S, tS], axis=0)
    new_cache = {
        "conv": new_conv, "S": new_S, "k": ks, "v": vs,
        "len": cache["len"] + h.shape[1],
    }
    return h, new_cache


def prefill(
    params: dict,
    tokens: jnp.ndarray,
    cfg: ArchConfig,
    max_len: int,
    frames: Optional[jnp.ndarray] = None,
) -> tuple[dict, jnp.ndarray]:
    """Prefill a prompt and build decode caches.

    Implemented as forward + cache construction; attention families re-derive
    K/V per layer through the decode path of the scan (cheap relative to the
    forward), SSM families capture final states.
    """
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len, enc_len=frames.shape[1] if frames is not None else 0)
    if cfg.family == "audio":
        enc = _encode_audio(params, frames, cfg)
        def xkv(xblk):
            kx = (enc @ xblk["attn"]["wk"]).reshape(B, enc.shape[1], cfg.n_kv_heads, cfg.d_head)
            vx = (enc @ xblk["attn"]["wv"]).reshape(B, enc.shape[1], cfg.n_kv_heads, cfg.d_head)
            return kx, vx
        xks, xvs = jax.vmap(xkv)(params["cross_blocks"])
        cache["xk"], cache["xv"] = xks, xvs
    # run the decode path over the whole prompt at once (S-token "step")
    cache, logits = decode_step(params, cache, tokens, cfg)
    return cache, logits


def train_step_fn(cfg: ArchConfig, optimizer):
    """Returns a jit-able (params, opt_state, batch) -> (params, opt_state,
    metrics) closure for this arch + optimizer (see distributed/optimizer)."""

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True
        )(params)
        params, opt_state = optimizer.update(params, grads, opt_state)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return step
