"""Mixture-of-Experts FFN with expert parallelism.

Two implementations with identical semantics (tests assert allclose):

* ``moe_reference`` — pure-jnp dense compute of every expert for every token
  (O(E) flops; only for tests / tiny smokes).
* ``moe_apply`` — production path.  Experts are sharded over the ``model``
  mesh axis (EP); tokens are sharded over (pod, data) and *replicated* over
  ``model``, matching the activation layout of the surrounding TP layers, so
  expert dispatch needs NO all-to-all: each model shard computes the FFN of
  its local experts for the tokens routed to them (sort + ragged grouped
  GEMM via ``jax.lax.ragged_dot``) and one reduce over ``model`` combines
  contributions — the same wire cost as a standard TP FFN all-reduce.

  Within a shard, assignments beyond ``capacity = local_assignments *
  capacity_factor`` are dropped (Switch/GShard-style dropping MoE); the
  capacity factor is per-arch config.  Dropping happens after a local sort
  by expert id, so overflow is biased against the *highest-id local expert*
  under pathological routing; with jitter-free top-k routing and cf >= 2 the
  drop rate is negligible (tests measure it).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import _dtype, dense_init


def init_moe(rng, cfg: ArchConfig) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 4)
    dt = _dtype(cfg)
    return {
        "router": dense_init(ks[0], (D, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, D, F), dt),
        "w_up": dense_init(ks[2], (E, D, F), dt),
        "w_down": dense_init(ks[3], (E, F, D), dt),
    }


def router_topk(x: jnp.ndarray, router: jnp.ndarray, top_k: int):
    """Softmax-after-topk routing (Mixtral/OLMoE convention).

    x: [T, D] -> (probs [T, k] fp32, ids [T, k] int32, aux_loss scalar).
    """
    logits = (x.astype(jnp.float32) @ router).astype(jnp.float32)  # [T, E]
    vals, ids = jax.lax.top_k(logits, top_k)
    probs = jax.nn.softmax(vals, axis=-1)
    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    E = router.shape[1]
    full_probs = jax.nn.softmax(logits, axis=-1)
    me = full_probs.mean(axis=0)
    onehot = jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32)
    ce = onehot.mean(axis=0)
    aux = E * jnp.sum(me * ce)
    return probs, ids, aux


def _expert_ffn_ragged(x_sel, w_gate, w_up, w_down, group_sizes):
    """Grouped SwiGLU over sorted token rows: [M, D] x [El, D, F] -> [M, D]."""
    g = jax.lax.ragged_dot(x_sel, w_gate, group_sizes)
    u = jax.lax.ragged_dot(x_sel, w_up, group_sizes)
    h = (jax.nn.silu(g.astype(jnp.float32)).astype(x_sel.dtype)) * u
    return jax.lax.ragged_dot(h, w_down, group_sizes)


def _local_shard_ragged(x, params, cfg, local_ids, flat_probs, local, E_loc):
    """Sort + ragged grouped GEMM path.  NOTE: XLA's default ragged_dot
    lowering is DENSE over all groups (measured E_loc x the ideal FLOPs) —
    kept as ``moe_impl='ragged'`` for the §Perf before/after; the 'gathered'
    path below is the default."""
    T, D = x.shape
    k = cfg.top_k
    cap = int(max(k, round(T * k / max(1, cfg.n_experts // E_loc)
                           * cfg.moe_capacity_factor)))
    cap = min(cap, T * k)
    order = jnp.argsort(local_ids)  # local experts first, overflow last
    sel = order[:cap]
    sel_ids = local_ids[sel]
    sel_tok = sel // k
    x_sel = x[sel_tok]
    group_sizes = jnp.bincount(
        jnp.where(sel_ids < E_loc, sel_ids, E_loc), length=E_loc + 1
    )[:E_loc].astype(jnp.int32)
    y_sel = _expert_ffn_ragged(
        x_sel, params["w_gate"], params["w_up"], params["w_down"], group_sizes
    )
    in_group = jnp.arange(cap) < group_sizes.sum()
    y_sel = jnp.where(in_group[:, None], y_sel, 0.0)
    scale = (flat_probs[sel] * local[sel]).astype(y_sel.dtype)
    return jnp.zeros((T, D), y_sel.dtype).at[sel_tok].add(y_sel * scale[:, None])


def _local_shard_gathered(x, params, cfg, local_ids, flat_probs, local, E_loc):
    """Index-gather dispatch (Switch/GShard semantics, memory- and
    FLOP-exact): per-expert capacity slots, batched [E_loc, cap_e, D] GEMMs.

    Position-in-expert comes from a cumsum over the one-hot assignment
    matrix; assignments beyond an expert's capacity are dropped (classic
    dropping MoE — drop rate measured in tests, negligible at cf >= 1.25
    for jitter-free top-k routing).
    """
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cap_e = int(max(1, round(T * k / E * cfg.moe_capacity_factor)))
    onehot = jax.nn.one_hot(local_ids, E_loc, dtype=jnp.int32)  # [T*k, E_loc]
    pie = jnp.cumsum(onehot, axis=0) * onehot - 1  # position in expert, -1 if none
    pie = pie.max(axis=1)  # [T*k]
    keep = local & (pie >= 0) & (pie < cap_e)
    dest = jnp.where(keep, local_ids * cap_e + pie, E_loc * cap_e)  # overflow slot
    tok_idx = jnp.arange(T * k) // k
    slot_tok = jnp.zeros((E_loc * cap_e + 1,), jnp.int32).at[dest].set(
        tok_idx, mode="drop"
    )
    slot_used = jnp.zeros((E_loc * cap_e + 1,), jnp.bool_).at[dest].set(
        True, mode="drop"
    )
    slot_prob = jnp.zeros((E_loc * cap_e + 1,), jnp.float32).at[dest].set(
        flat_probs, mode="drop"
    )
    slot_tok, slot_used, slot_prob = (
        slot_tok[:-1], slot_used[:-1], slot_prob[:-1]
    )
    x_e = x[slot_tok].reshape(E_loc, cap_e, D)
    x_e = x_e * slot_used.reshape(E_loc, cap_e, 1).astype(x_e.dtype)
    g = jnp.einsum("ecd,edf->ecf", x_e, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", x_e, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x_e.dtype) * u
    y_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # [E_loc, cap_e, D]
    w = (slot_prob * slot_used).reshape(E_loc, cap_e, 1).astype(y_e.dtype)
    flat_y = (y_e * w).reshape(E_loc * cap_e, D)
    return jnp.zeros((T, D), y_e.dtype).at[slot_tok].add(
        jnp.where(slot_used[:, None], flat_y, 0.0)
    )


def moe_local_shard(
    x: jnp.ndarray,
    params: dict,
    cfg: ArchConfig,
    shard_idx: jnp.ndarray,
    n_shards: int,
) -> jnp.ndarray:
    """Per-model-shard expert compute (called under shard_map).

    x: [T_loc, D] local tokens (replicated over model);
    params' expert tensors are the LOCAL slices [E_loc, ...].
    Returns this shard's partial MoE output [T_loc, D] (caller psums).
    """
    T, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // n_shards
    probs, ids, _ = router_topk(x, params["router"], k)

    e_start = shard_idx * E_loc
    flat_ids = ids.reshape(-1)  # [T*k]
    flat_probs = probs.reshape(-1)
    local = (flat_ids >= e_start) & (flat_ids < e_start + E_loc)
    local_ids = jnp.where(local, flat_ids - e_start, E_loc)  # E_loc = overflow

    impl = (
        _local_shard_ragged if cfg.moe_impl == "ragged" else _local_shard_gathered
    )
    y = impl(x, params, cfg, local_ids, flat_probs, local, E_loc)
    return y.astype(x.dtype)


def moe_apply(
    x: jnp.ndarray,
    params: dict,
    cfg: ArchConfig,
    mesh: Optional[jax.sharding.Mesh] = None,
    model_axis: str = "model",
) -> jnp.ndarray:
    """MoE FFN over [B, S, D] activations.

    With a mesh: shard_map expert parallelism (see module docstring).
    Without (CPU smokes / tests): single-shard local compute.
    """
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    if mesh is None or model_axis not in mesh.axis_names or mesh.shape[model_axis] == 1:
        y = moe_local_shard(xt, params, cfg, jnp.int32(0), 1)
        return y.reshape(B, S, D)

    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    n_shards = mesh.shape[model_axis]
    batch_axes = tuple(a for a in mesh.axis_names if a != model_axis)

    def shard_fn(xt_l, router, w_gate, w_up, w_down):
        idx = jax.lax.axis_index(model_axis)
        p = {"router": router, "w_gate": w_gate, "w_up": w_up, "w_down": w_down}
        y = moe_local_shard(xt_l, p, cfg, idx, n_shards)
        return jax.lax.psum(y, model_axis)

    y = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None),      # tokens: sharded over batch axes
            P(None, None),            # router replicated
            P(model_axis, None, None),  # experts sharded over model
            P(model_axis, None, None),
            P(model_axis, None, None),
        ),
        out_specs=P(batch_axes, None),
        check_rep=False,
    )(xt, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    return y.reshape(B, S, D)


def moe_reference(x: jnp.ndarray, params: dict, cfg: ArchConfig) -> jnp.ndarray:
    """Dense all-experts oracle: O(E) compute, exact dropless semantics."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    probs, ids, _ = router_topk(xt, params["router"], cfg.top_k)
    g = jnp.einsum("td,edf->tef", xt, params["w_gate"])
    u = jnp.einsum("td,edf->tef", xt, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y_all = jnp.einsum("tef,efd->ted", h, params["w_down"])  # [T, E, D]
    E = cfg.n_experts
    w = jnp.zeros((xt.shape[0], E), jnp.float32)
    w = jax.vmap(lambda wi, i, p: wi.at[i].add(p))(w, ids, probs)
    y = jnp.einsum("ted,te->td", y_all, w.astype(y_all.dtype))
    return y.reshape(B, S, D).astype(x.dtype)
