"""Deterministic fault injection at the ``fused_column`` seam.

The contract this harness rides is the **instrumentation seam** of the
AOT front doors (see *docs/backends.md*): ``backend.fit_padded`` /
``backend.assign_padded`` dispatch a cached executable only while the
module entry points ``fused_column.fit_scan_padded`` /
``fused_column.assign_padded`` are still the jitted originals.  Replace
either with a plain callable and the front door calls the callable
directly — no executable is dispatched around it — so a wrapper
installed here intercepts EVERY fused fit/assign in the process:
sweeps, DSE, and the streaming service alike.

Each injector below takes the original entry point and returns a
wrapper that reproduces one concrete failure mode deterministically:

* ``fail_on_lowering``  — a lowering-specific compile/kernel failure
  (e.g. the Mosaic rung is down, the reference rung still works);
* ``fail_on_threshold`` — one poisoned *design* detonates any batch it
  rides, keyed by its threshold (distinct thresholds make a design
  individually addressable inside a shared envelope);
* ``fail_on_volley``    — one poisoned *request* detonates its batch,
  keyed by its encoded volley (mid-batch crash);
* ``nan_poison``        — the call "succeeds" but returns NaN-poisoned
  weights (a miscompiled or numerically-broken re-fit);
* ``slow_call``         — a stalled executable: correct results, pathologic
  latency (trips watchdog budgets deterministically);
* ``fail_always``       — the executable is simply down.

Install a wrapper with ``monkeypatch.setattr`` in tests, or with the
``injected(...)`` context manager outside pytest (the serve-bench chaos
case).  All injected errors are ``InjectedFault`` (a ``RuntimeError``)
whose message contains ``"injected fault"``.
"""
from __future__ import annotations

import contextlib
import time

import numpy as np


class InjectedFault(RuntimeError):
    """An error raised by this harness — never by real code paths."""


def fail_on_lowering(orig, lowerings=("mosaic",)):
    """Fail whenever the call targets one of ``lowerings`` — other rungs
    pass through, so the degradation ladder has somewhere to land."""

    def wrapper(*args, **kwargs):
        low = kwargs.get("lowering", "reference")
        if low in lowerings:
            raise InjectedFault(f"injected fault: lowering {low!r} down")
        return orig(*args, **kwargs)

    return wrapper


def fail_on_threshold(orig, threshold, lowerings=None):
    """Fail whenever the poisoned design's threshold rides the batch (at
    one of ``lowerings``, or at any lowering when ``None``) — the
    per-design poison for shared-envelope quarantine tests."""

    def wrapper(w, xs, thresholds, *args, **kwargs):
        low = kwargs.get("lowering", "reference")
        if (lowerings is None or low in lowerings) and np.any(
            np.isclose(np.asarray(thresholds), threshold)
        ):
            raise InjectedFault("injected fault: poisoned design present")
        return orig(w, xs, thresholds, *args, **kwargs)

    return wrapper


def fail_on_volley(orig, volley):
    """Fail whenever the encoded ``volley`` rides ``xs`` in any lane —
    the per-request poison for mid-batch quarantine tests."""
    volley = np.asarray(volley)

    def wrapper(w, xs, *args, **kwargs):
        if (np.asarray(xs) == volley).all(axis=-1).any():
            raise InjectedFault("injected fault: poisoned volley")
        return orig(w, xs, *args, **kwargs)

    return wrapper


def nan_poison(orig):
    """Return the original result with one NaN planted in it — a re-fit
    that 'succeeds' with corrupt weights (the caller's finite-weights
    guard must catch it)."""

    def wrapper(*args, **kwargs):
        out = np.array(orig(*args, **kwargs), np.float32)
        out.flat[0] = np.nan
        return out

    return wrapper


def slow_call(orig, delay_s):
    """Correct results, ``delay_s`` extra wall time — a stalled
    executable for watchdog-budget tests."""

    def wrapper(*args, **kwargs):
        out = orig(*args, **kwargs)
        time.sleep(delay_s)
        return out

    return wrapper


def fail_always(orig=None, detail="executable down"):
    """Unconditional failure (``orig`` accepted and ignored, so the same
    callable works bare or through ``injected``)."""

    def wrapper(*args, **kwargs):
        raise InjectedFault(f"injected fault: {detail}")

    return wrapper


@contextlib.contextmanager
def injected(name, make_wrapper, *args, module=None, **kwargs):
    """Install ``make_wrapper(original, *args, **kwargs)`` over
    ``fused_column.<name>`` (or ``module.<name>``) for the duration of
    the block — the non-pytest counterpart of ``monkeypatch.setattr``,
    used by the serve-bench chaos case."""
    if module is None:
        from repro.kernels import fused_column as module  # noqa: PLW0127
    orig = getattr(module, name)
    setattr(module, name, make_wrapper(orig, *args, **kwargs))
    try:
        yield orig
    finally:
        setattr(module, name, orig)
