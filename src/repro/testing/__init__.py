"""Shared test/benchmark instrumentation for the repro package.

``repro.testing.faults`` is the deterministic fault-injection harness at
the ``fused_column`` seam — the single library behind the fault tests,
the serving chaos tests and ``benchmarks/serve_bench.py``'s chaos case.
"""
from repro.testing import faults

__all__ = ["faults"]
