"""The TNNGen hardware process flow (paper Fig. 1, right half).

``run_flow`` takes a ``ColumnSpec`` through RTL generation -> TCL script
generation -> synthesis -> place-and-route, producing report files and a
``FlowResult``.  The *executor* is pluggable:

* ``CadenceExecutor`` shells out to Genus/Innovus using the generated TCL —
  the real TNNGen path; it raises immediately here (no EDA install).
* ``ModelExecutor`` (default) evaluates the analytical PDK silicon models
  calibrated to the paper's own post-layout tables (see pdk.py), writes
  tool-style report files, and reports flow runtimes from the calibrated
  runtime model.  A deterministic per-design jitter (seeded by the design
  hash) models P&R noise at the magnitude the paper's Table V residuals
  exhibit (~±2% for large designs).

This keeps every artifact of the real flow (RTL, TCL, reports, a design
database for forecasting) while substituting only the proprietary tool
execution, as discussed in DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Optional

from repro.hwgen import pdk, rtl, tcl


@dataclasses.dataclass
class FlowResult:
    name: str
    library: str
    synapses: int
    area_um2: float
    leakage_uw: float
    latency_ns: float
    synth_runtime_s: float
    pnr_runtime_s: float
    total_runtime_s: float
    build_dir: Optional[str]
    stats: dict

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        return d


class CadenceExecutor:
    """Shells out to the Cadence toolchain (requires a licensed install)."""

    def run(self, spec: rtl.ColumnSpec, library: str, build_dir: str) -> FlowResult:
        raise RuntimeError(
            "Cadence Genus/Innovus are not available in this environment; "
            "use ModelExecutor (see DESIGN.md §2)."
        )


class ModelExecutor:
    """Analytical EDA executor calibrated to the paper's published results."""

    def __init__(self, jitter: float = 0.02, seed: int = 0):
        self.jitter = jitter
        self.seed = seed

    def _jitter(self, spec: rtl.ColumnSpec, library: str, what: str) -> float:
        h = hashlib.sha256(
            f"{spec.name}/{spec.p}x{spec.q}/{library}/{what}/{self.seed}".encode()
        ).digest()
        u = int.from_bytes(h[:8], "little") / 2**64  # [0, 1)
        return 1.0 + self.jitter * (2.0 * u - 1.0)

    def run(self, spec: rtl.ColumnSpec, library: str, build_dir: str) -> FlowResult:
        model = pdk.MODELS[library]
        s = spec.synapse_count
        area = model.area_um2(s) * self._jitter(spec, library, "area")
        leak = model.leakage_uw(s) * self._jitter(spec, library, "leak")
        lat = pdk.latency_model_ns(spec.p, spec.q)
        synth_s = model.synth_runtime_s(s) * self._jitter(spec, library, "synth")
        pnr_s = model.pnr_runtime_s(s) * self._jitter(spec, library, "pnr")
        stats = rtl.netlist_stats(spec)

        if build_dir:
            rep = os.path.join(build_dir, "reports")
            os.makedirs(rep, exist_ok=True)
            top = f"tnn_column_{spec.name}"
            with open(os.path.join(rep, f"{top}_{library}_pnr_summary.rpt"), "w") as f:
                f.write(
                    "# post-P&R summary (ModelExecutor — calibrated to paper tables)\n"
                    f"design        : {top}\nlibrary       : {library}\n"
                    f"synapses      : {s}\nflops         : {stats['flops']}\n"
                    f"total area    : {area:.3f} um^2\n"
                    f"leakage power : {leak:.4f} uW\n"
                    f"comp latency  : {lat:.2f} ns\n"
                    f"synth runtime : {synth_s:.1f} s\npnr runtime   : {pnr_s:.1f} s\n"
                )
        return FlowResult(
            name=spec.name, library=library, synapses=s,
            area_um2=area, leakage_uw=leak, latency_ns=lat,
            synth_runtime_s=synth_s, pnr_runtime_s=pnr_s,
            total_runtime_s=synth_s + pnr_s, build_dir=build_dir, stats=stats,
        )


def run_flow(
    spec: rtl.ColumnSpec,
    library: str = "tnn7",
    build_root: Optional[str] = None,
    executor=None,
    write_rtl: bool = True,
) -> FlowResult:
    """PyTorch-model-spec -> RTL -> TCL -> synthesis -> P&R (paper Fig. 1).

    Returns the post-layout metrics; writes RTL, flow scripts and reports
    under ``build_root/<name>/`` when a build root is given.
    """
    if library not in pdk.LIBRARIES:
        raise ValueError(f"unknown library {library!r}; choose from {pdk.LIBRARIES}")
    executor = executor or ModelExecutor()
    build_dir = None
    if build_root:
        build_dir = os.path.join(build_root, f"{spec.name}_{library}")
        os.makedirs(build_dir, exist_ok=True)
        if write_rtl:
            for fname, text in rtl.generate_column(spec).items():
                with open(os.path.join(build_dir, fname), "w") as f:
                    f.write(text)
            for fname, text in tcl.generate_flow_scripts(spec, library).items():
                with open(os.path.join(build_dir, fname), "w") as f:
                    f.write(text)
    result = executor.run(spec, library, build_dir)
    if build_dir:
        with open(os.path.join(build_dir, "flow_result.json"), "w") as f:
            json.dump(result.to_json(), f, indent=2)
    return result


def run_design_sweep(
    specs: list,
    libraries=pdk.LIBRARIES,
    build_root: Optional[str] = None,
    executor=None,
) -> list:
    """Run the full flow for a sweep of designs x libraries (the paper's
    Tables III/IV loop); returns a flat list of FlowResults and appends them
    to the forecasting design database."""
    results = []
    for spec in specs:
        for lib in libraries:
            results.append(run_flow(spec, lib, build_root, executor))
    return results
