# TNNGen hardware generator (paper §II-B): PyTorch-model-spec -> Verilog RTL
# -> TCL flow scripts -> (simulated) synthesis/P&R -> post-layout metrics,
# plus the paper's forecasting feature.  See DESIGN.md §2 for what is real
# (RTL/TCL generation, forecasting) vs modeled (Cadence execution).
from repro.hwgen import flow, forecast, pdk, rtl, tcl  # noqa: F401
from repro.hwgen.flow import FlowResult, ModelExecutor, run_flow  # noqa: F401
from repro.hwgen.rtl import ColumnSpec  # noqa: F401
