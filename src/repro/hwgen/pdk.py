"""Process design kit (PDK) models: FreePDK45, ASAP7, TNN7.

The real TNNGen invokes Cadence Genus/Innovus against these libraries.  That
toolchain is proprietary and unavailable offline, so this module carries the
paper's *own measured results* (Tables III and IV — post-place-and-route
leakage and die area for the seven UCR column designs) as calibration
points, plus least-squares linear models fitted to them.  ``flow.py`` uses
these models as its analytical "EDA executor"; the paper itself demonstrates
(Table V, Fig. 4) that silicon area/leakage of these designs is linear in
synapse count, which is what makes this substitution faithful.

All areas in um^2; leakage in uW (FreePDK45 values are reported by the paper
in mW and converted here); runtimes in seconds.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# (benchmark, synapse_count) in paper order.
PAPER_DESIGNS = (
    ("SonyAIBORobotSurface2", 130),
    ("ECG200", 192),
    ("Wafer", 304),
    ("ToeSegmentation2", 686),
    ("Lightning2", 1274),
    ("Beef", 2350),
    ("WordSynonyms", 6750),
)

# Table IV: post-P&R die area (um^2) per library.
PAPER_AREA = {
    "freepdk45": (14284.466, 21036.08, 33868.98, 75654.82, 140502.84, 259167.4, 744422.4),
    "asap7": (1028.67, 1513.05, 2394.01, 5388.72, 10184.45, 18298.1, 51158.20),
    "tnn7": (692.06, 1015.8, 1608.52, 3682.63, 6860.68, 12634.83, 35303.88),
}

# Table III: post-P&R leakage power (uW) per library.
PAPER_LEAKAGE = {
    "freepdk45": (299.0, 442.0, 717.0, 1590.0, 2950.0, 5452.0, 15660.0),  # mW -> uW
    "asap7": (0.961, 1.41, 2.26, 5.09, 9.81, 17.4, 46.69),
    "tnn7": (0.57, 0.84, 1.34, 3.14, 5.84, 11.06, 31.13),
}

# Fig. 2 / §III-B: computation latency (ns) for fitted columns, keyed by
# (p, q).  The paper reports these four points.
PAPER_LATENCY_NS = {
    (65, 2): 79.2,
    (96, 2): 93.36,
    (152, 2): 98.4,
    (270, 25): 180.0,
}

# §III-B: total (leakage + dynamic) power for the largest column, TNN7.
PAPER_TOTAL_POWER_LARGEST = {"tnn7": 67.0, "asap7": 47.0, "freepdk45": 15660.0}  # uW

LIBRARIES = ("freepdk45", "asap7", "tnn7")


def _linfit(x: np.ndarray, y: np.ndarray) -> tuple[float, float]:
    """Least-squares y = a*x + b."""
    a, b = np.polyfit(np.asarray(x, np.float64), np.asarray(y, np.float64), 1)
    return float(a), float(b)


@dataclasses.dataclass(frozen=True)
class LibraryModel:
    """Silicon model for one cell library.

    Area/leakage interpolate monotonically THROUGH the paper's seven
    post-layout calibration points (exact at the published designs) and
    extrapolate linearly outside with the end-segment slope — the paper's
    own Table V shows pure linear regression deviates up to ~30% for the
    smallest designs, so the flow "ground truth" uses the table itself; the
    *forecaster* (forecast.py) stays linear, reproducing those errors.
    ``area_per_syn``/``leak_per_syn`` keep the fitted slopes for reporting.
    """

    name: str
    cal_syn: tuple          # calibration synapse counts (ascending)
    cal_area: tuple         # um^2 at cal_syn
    cal_leak: tuple         # uW at cal_syn
    area_per_syn: float     # fitted um^2 / synapse (reporting)
    area_base: float
    leak_per_syn: float     # fitted uW / synapse (reporting)
    leak_base: float
    # runtime models (see flow.py for the calibration discussion):
    synth_base_s: float
    synth_per_syn_s: float
    pnr_base_s: float
    pnr_per_syn_s: float

    def _interp(self, x: float, ys: tuple) -> float:
        xs = self.cal_syn
        if x <= xs[0]:
            slope = (ys[1] - ys[0]) / (xs[1] - xs[0])
            return max(ys[0] + slope * (x - xs[0]), 0.0)
        if x >= xs[-1]:
            slope = (ys[-1] - ys[-2]) / (xs[-1] - xs[-2])
            return ys[-1] + slope * (x - xs[-1])
        return float(np.interp(x, xs, ys))

    def area_um2(self, synapses: int) -> float:
        return self._interp(float(synapses), self.cal_area)

    def leakage_uw(self, synapses: int) -> float:
        return max(self._interp(float(synapses), self.cal_leak), 0.0)

    def synth_runtime_s(self, synapses: int) -> float:
        return self.synth_base_s + self.synth_per_syn_s * synapses

    def pnr_runtime_s(self, synapses: int) -> float:
        return self.pnr_base_s + self.pnr_per_syn_s * synapses


def _build_models() -> dict:
    syn = np.array([s for _, s in PAPER_DESIGNS], np.float64)
    models = {}
    # Runtime calibration (absolute values are not machine-readable from
    # Fig. 3; the model is pinned to the paper's *stated* relations):
    #   - TNN7 logic synthesis is ~3x faster than ASAP7 ([8], confirmed §III-C)
    #   - TNN7 P&R averages ~32% faster than ASAP7 (Fig. 3)
    #   - total flow speedup reaches ~47% for the 6750-synapse design (§III-C)
    # Solving those constraints at syn=6750 gives ASAP7 synth ~1500 s and
    # P&R ~1965 s; linear-in-synapses with small bases.
    asap7_synth = (30.0, (1500.0 - 30.0) / 6750.0)
    asap7_pnr = (45.0, (1965.0 - 45.0) / 6750.0)
    runtime = {
        "freepdk45": (asap7_synth, (60.0, (2400.0 - 60.0) / 6750.0)),  # 45nm: denser netlist, slower P&R
        "asap7": (asap7_synth, asap7_pnr),
        "tnn7": (
            (asap7_synth[0] / 3.0, asap7_synth[1] / 3.0),
            (asap7_pnr[0] * 0.68, asap7_pnr[1] * 0.68),
        ),
    }
    for lib in LIBRARIES:
        a_slope, a_base = _linfit(syn, np.array(PAPER_AREA[lib]))
        l_slope, l_base = _linfit(syn, np.array(PAPER_LEAKAGE[lib]))
        (sb, ss), (pb, ps) = runtime[lib]
        models[lib] = LibraryModel(
            name=lib,
            cal_syn=tuple(float(s) for s in syn),
            cal_area=PAPER_AREA[lib],
            cal_leak=PAPER_LEAKAGE[lib],
            area_per_syn=a_slope, area_base=a_base,
            leak_per_syn=l_slope, leak_base=l_base,
            synth_base_s=sb, synth_per_syn_s=ss,
            pnr_base_s=pb, pnr_per_syn_s=ps,
        )
    return models


MODELS: dict = _build_models()


def latency_model_ns(p: int, q: int) -> float:
    """Computation latency model, log-linear in synapse count.

    Fit to the paper's four reported latencies (Fig. 2 + §III-B); the
    microarchitecture's latency is dominated by the temporal wavefront
    traversal, which grows sub-linearly with column size.
    """
    pts = sorted((pp * qq, ns) for (pp, qq), ns in PAPER_LATENCY_NS.items())
    x = np.log([s for s, _ in pts])
    y = np.array([ns for _, ns in pts])
    b, a = np.polyfit(x, y, 1)
    return float(a + b * np.log(max(p * q, 2)))
