"""Silicon-metric forecasting (paper §III-D) and its roofline generalization.

The paper trains linear-regression models on accumulated TNNGen flow runs so
that users without EDA access can predict post-layout area/leakage from the
synapse count alone:

    area_um2   = 5.56    * synapses - 94.9      (TNN7, 7 nm)
    leakage_uw = 0.00541 * synapses - 0.725     (TNN7, 7 nm)

``PaperForecaster`` carries those published coefficients verbatim;
``Forecaster`` refits the same model family from a design database of
``FlowResult`` runs (the paper: "trained on many TNNGen runs with varying
TNN sizes ... can be continually refined with more actual design data
points").

``RooflineForecaster`` is the beyond-paper generalization described in
DESIGN.md §5: the identical predict-silicon-from-size idea applied to the LM
dry-run — it regresses the compiled roofline terms (compute/memory/
collective seconds) on analytic model descriptors (params, FLOPs/token,
bytes moved), so new configs get cost estimates without re-lowering.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence

import numpy as np


def _lstsq(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    coef, *_ = np.linalg.lstsq(
        np.concatenate([X, np.ones((len(X), 1))], axis=1), y, rcond=None
    )
    return coef  # [k + 1] with intercept last


@dataclasses.dataclass
class LinearModel:
    coef: np.ndarray  # [k]
    intercept: float

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.atleast_2d(np.asarray(X, np.float64))
        return X @ self.coef + self.intercept

    @classmethod
    def fit(cls, X: np.ndarray, y: np.ndarray) -> "LinearModel":
        c = _lstsq(np.atleast_2d(np.asarray(X, np.float64)), np.asarray(y, np.float64))
        return cls(coef=c[:-1], intercept=float(c[-1]))


# --- paper §III-D verbatim coefficients (TNN7) -------------------------------
PAPER_AREA_MODEL = LinearModel(coef=np.array([5.56]), intercept=-94.9)
PAPER_LEAKAGE_MODEL = LinearModel(coef=np.array([0.00541]), intercept=-0.725)


class PaperForecaster:
    """Forecast TNN7 post-layout area/leakage with the paper's equations."""

    def area_um2(self, synapses: int) -> float:
        return float(PAPER_AREA_MODEL.predict([[synapses]])[0])

    def leakage_uw(self, synapses: int) -> float:
        return float(PAPER_LEAKAGE_MODEL.predict([[synapses]])[0])


class Forecaster:
    """Refittable forecaster over a design database of FlowResults."""

    def __init__(self):
        self.area_model: Optional[LinearModel] = None
        self.leak_model: Optional[LinearModel] = None
        self._rows: list = []

    def add_runs(self, results: Sequence) -> None:
        for r in results:
            self._rows.append((r.synapses, r.area_um2, r.leakage_uw, r.library))

    def fit(self, library: str = "tnn7") -> None:
        rows = [r for r in self._rows if r[3] == library]
        if len(rows) < 2:
            raise ValueError("need >= 2 design points to fit the forecaster")
        syn = np.array([[r[0]] for r in rows], np.float64)
        self.area_model = LinearModel.fit(syn, np.array([r[1] for r in rows]))
        self.leak_model = LinearModel.fit(syn, np.array([r[2] for r in rows]))

    def area_um2(self, synapses: int) -> float:
        if self.area_model is None:
            raise RuntimeError("fit() first")
        return float(self.area_model.predict([[synapses]])[0])

    def leakage_uw(self, synapses: int) -> float:
        if self.leak_model is None:
            raise RuntimeError("fit() first")
        return float(self.leak_model.predict([[synapses]])[0])

    @staticmethod
    def error_pct(forecast: float, actual: float) -> float:
        return 100.0 * (forecast - actual) / actual


class RooflineForecaster:
    """Beyond-paper: predict dry-run roofline terms from arch descriptors.

    Features per (arch, shape) cell: [params_B, flops_per_step_P,
    activation_bytes_G, seq_len_k].  Targets: the three roofline terms in
    seconds.  Fitted on the dry-run table (benchmarks/roofline.py) the same
    way the paper fits silicon models on flow runs.
    """

    TERMS = ("compute_s", "memory_s", "collective_s")

    def __init__(self):
        self.models: dict = {}

    def fit(self, feats: np.ndarray, targets: dict) -> None:
        for term in self.TERMS:
            self.models[term] = LinearModel.fit(feats, np.asarray(targets[term]))

    def predict(self, feats: np.ndarray) -> dict:
        if not self.models:
            raise RuntimeError("fit() first")
        return {t: self.models[t].predict(feats) for t in self.TERMS}

    def save(self, path: str) -> None:
        blob = {
            t: {"coef": m.coef.tolist(), "intercept": m.intercept}
            for t, m in self.models.items()
        }
        with open(path, "w") as f:
            json.dump(blob, f, indent=2)

    @classmethod
    def load(cls, path: str) -> "RooflineForecaster":
        with open(path) as f:
            blob = json.load(f)
        fc = cls()
        for t, m in blob.items():
            fc.models[t] = LinearModel(np.asarray(m["coef"]), m["intercept"])
        return fc
