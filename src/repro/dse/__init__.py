"""Design-space exploration (DSE) for NSPU column designs.

Closes the TNNGen loop from functional simulation to *forecasted*
silicon: ``explore`` sweeps a ``DesignSpace`` over a labeled stream via
the envelope-bucketed, device-sharded design sweep
(``simulator.cluster_time_series_many``), pairs every design's Rand
index with forecasted area/leakage (``repro.hwgen.forecast``), and
returns the Pareto frontier of quality vs silicon cost.

Long runs are fault-tolerant by default: failing candidates are
quarantined as ``EvalFailure`` records instead of aborting the sweep
(kernel-path failures degrade down the central lowering ladder first),
and ``explore(journal=..., resume=True)`` makes completed evaluations
durable across kills via an atomically-published ``Journal``.  See
``docs/dse.md``.
"""
from repro.core.simulator import EvalFailure
from repro.dse.explore import DSEResult, explore, summarize
from repro.dse.journal import Journal, candidate_fingerprint
from repro.dse.pareto import DesignPoint, dominates, pareto_front
from repro.dse.space import (
    Candidate,
    DesignSpace,
    candidate_config,
)

__all__ = [
    "Candidate",
    "DSEResult",
    "DesignPoint",
    "DesignSpace",
    "EvalFailure",
    "Journal",
    "candidate_config",
    "candidate_fingerprint",
    "dominates",
    "explore",
    "pareto_front",
    "summarize",
]
