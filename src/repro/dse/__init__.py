"""Design-space exploration (DSE) for NSPU column designs.

Closes the TNNGen loop from functional simulation to *forecasted*
silicon: ``explore`` sweeps a ``DesignSpace`` over a labeled stream via
the envelope-bucketed, device-sharded design sweep
(``simulator.cluster_time_series_many``), pairs every design's Rand
index with forecasted area/leakage (``repro.hwgen.forecast``), and
returns the Pareto frontier of quality vs silicon cost.  See
``docs/dse.md``.
"""
from repro.dse.explore import DSEResult, explore, summarize
from repro.dse.pareto import DesignPoint, dominates, pareto_front
from repro.dse.space import (
    Candidate,
    DesignSpace,
    candidate_config,
)

__all__ = [
    "Candidate",
    "DSEResult",
    "DesignPoint",
    "DesignSpace",
    "candidate_config",
    "dominates",
    "explore",
    "pareto_front",
    "summarize",
]
