"""Durable journal for design-space exploration runs.

Long exploration runs (hundreds of candidate evaluations, minutes to
hours — the cluster-scale sweeps the ROADMAP targets) need the same
durability story the training stack has: a killed process must lose at
most the work in flight, never the completed evaluations.  This module
provides it for ``dse.explore``:

* Every completed evaluation — scored *or* quarantined — is one JSON
  record keyed by a **deterministic candidate fingerprint**
  (``candidate_fingerprint``: config + encoder + seed + epochs), so a
  record is valid exactly as long as re-evaluating the candidate would
  reproduce it.
* The journal is an **append-only JSONL file published atomically**: each
  append rewrites the full record list to ``<path>.tmp``, fsyncs, and
  ``os.replace``s it into place — the write-then-rename protocol of
  ``distributed/checkpoint.py``.  A SIGKILL mid-write can never corrupt
  the journal or be mistaken for a complete one; readers always see the
  last published state.  (DSE journals are small — hundreds of records of
  a few KB — so the rewrite stays cheap; appends happen once per
  completed *bucket*, which is also the resume granularity.)
* ``explore(journal=..., resume=True)`` skips every journaled candidate
  and re-evaluates only the rest; because init weights are keyed per
  candidate (not per sweep position), the resumed frontier is
  bit-identical to an uninterrupted run.

``tests/test_faults.py`` exercises the kill-and-resume loop end to end.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Optional, Sequence

from repro.core.types import ColumnConfig

JOURNAL_VERSION = 1


def candidate_fingerprint(
    cfg: ColumnConfig, encoder: str, seed: int, epochs: int
) -> str:
    """Deterministic identity of one candidate evaluation.

    Hashes the full column config (every nested dataclass field), the
    encoder, and the run's seed and epoch count — everything the
    evaluation's result is a function of.  Equal fingerprints mean
    re-running the evaluation would reproduce the journaled result
    bit-for-bit; any config/seed/epochs change misses the journal and
    re-evaluates.  Stable across processes and hosts (canonical JSON +
    SHA-256, no Python hash randomization).
    """
    spec = {
        "cfg": dataclasses.asdict(cfg),
        "encoder": str(encoder),
        "seed": int(seed),
        "epochs": int(epochs),
    }
    blob = json.dumps(spec, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()[:16]


class Journal:
    """Append-only JSONL evaluation journal with atomic publishes.

    Record kinds (one JSON object per line):

    * ``{"kind": "meta", "version", "seed", "epochs", "search"}`` — the
      run header, written by ``begin`` and validated on resume.
    * ``{"kind": "point", "fp", "index", "encoder", "cand", "rand_index",
      "synapses", "area_um2", "leakage_uw", "lowering", "buckets",
      "shards", "retries", "w"}`` — one scored design; ``w`` is the
      trained weight matrix (float32 values round-trip JSON exactly, so
      restored ``DesignPoint.params`` are bit-identical).
    * ``{"kind": "failure", "fp", "index", "encoder", "stage", "error",
      "lowerings", "retries"}`` — one quarantined design; resumed runs
      keep it quarantined instead of re-paying the failure.
    """

    def __init__(self, path):
        self.path = str(path)
        self._records: Optional[list[dict]] = None

    # ---------------- read side ----------------
    def load(self) -> list[dict]:
        """All records currently published, oldest first.

        Missing file -> [].  Undecodable lines are skipped (publishes are
        atomic, so they cannot normally occur; skipping keeps a journal
        on a non-atomic filesystem readable rather than fatal).
        """
        if not os.path.exists(self.path):
            return []
        records = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    records.append(json.loads(line))
                except json.JSONDecodeError:
                    continue
        return records

    def completed(self) -> dict:
        """fingerprint -> record for every journaled evaluation (scored
        and quarantined alike)."""
        return {
            r["fp"]: r
            for r in self.load()
            if r.get("kind") in ("point", "failure") and "fp" in r
        }

    # ---------------- write side ----------------
    def begin(self, meta: dict, resume: bool) -> dict:
        """Open the journal for a run; returns ``completed()``.

        A fresh path publishes the meta header and returns {} (with or
        without ``resume`` — resuming from nothing is a fresh start).  An
        existing journal requires ``resume=True`` (never silently clobber
        completed work) and a matching header: mismatched seed / epochs /
        search means the journal describes a *different* run, and
        resuming it would silently mix incompatible evaluations.
        """
        existing = self.load()
        if existing and not resume:
            raise ValueError(
                f"journal {self.path!r} already exists with "
                f"{len(existing) - 1} record(s); pass resume=True to "
                "continue it, or point at a fresh path"
            )
        if existing:
            head = existing[0]
            if head.get("kind") != "meta":
                raise ValueError(
                    f"journal {self.path!r} has no meta header — not an "
                    "explore journal?"
                )
            for key, want in meta.items():
                have = head.get(key)
                if have != want:
                    raise ValueError(
                        f"journal {self.path!r} was written by a run with "
                        f"{key}={have!r}; this run has {key}={want!r} — "
                        "resume requires an identical run configuration"
                    )
            self._records = existing
        else:
            self._records = [
                {"kind": "meta", "version": JOURNAL_VERSION, **meta}
            ]
            self._publish()
        return {
            r["fp"]: r
            for r in self._records
            if r.get("kind") in ("point", "failure") and "fp" in r
        }

    def append(self, records: Sequence[dict]) -> None:
        """Append records and publish atomically (write-then-rename)."""
        if not records:
            return
        if self._records is None:
            self._records = self.load()
        self._records.extend(records)
        self._publish()

    def _publish(self) -> None:
        # the checkpoint.py protocol: full content to a temp file, fsync,
        # atomic rename — a kill at any instant leaves either the old or
        # the new journal, never a torn one
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            for rec in self._records:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
