"""Design-space definitions for NSPU exploration.

A ``DesignSpace`` names the axes the TNNGen papers sweep when sizing a
sensory processing unit for a stream: neuron count ``q`` (cluster
capacity), temporal window ``t_max`` (gamma-cycle length), firing
threshold (as a scale on the simulator's operating-point suggestion,
so one scale means the same thing across geometries), and the spike
encoder.  ``grid`` enumerates the full cross product; ``sample`` draws a
random subset for large spaces — the two search modes ``dse.explore``
offers.
"""
from __future__ import annotations

import dataclasses
import itertools
import random
from typing import Sequence

from repro.core import simulator
from repro.core.types import ColumnConfig

ENCODERS = ("latency", "onoff")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the design space — the free axes of a column design.

    ``threshold_scale`` multiplies ``simulator.suggest_threshold`` for the
    candidate's geometry, so thresholds stay meaningful as p and q vary.
    """

    q: int
    t_max: int
    threshold_scale: float = 1.0
    encoder: str = "latency"


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """Axes of a column-design sweep; the cross product is the space.

    Attributes:
      q: neuron counts to sweep (cluster capacity).
      t_max: temporal windows to sweep.
      threshold_scale: multiples of the suggested operating-point
        threshold.
      encoder: spike encoders ('latency' and/or 'onoff'); 'onoff' doubles
        the input width p, so candidates with different encoders sweep in
        separate compiled programs.
    """

    q: Sequence[int]
    t_max: Sequence[int]
    threshold_scale: Sequence[float] = (1.0,)
    encoder: Sequence[str] = ("latency",)

    def __post_init__(self):
        for axis in ("q", "t_max", "threshold_scale", "encoder"):
            if not tuple(getattr(self, axis)):
                raise ValueError(f"DesignSpace.{axis} must be non-empty")
        bad = set(self.encoder) - set(ENCODERS)
        if bad:
            raise ValueError(f"unknown encoders: {sorted(bad)}")

    def size(self) -> int:
        return (
            len(self.q) * len(self.t_max)
            * len(self.threshold_scale) * len(self.encoder)
        )

    def grid(self) -> list[Candidate]:
        """The full cross product, in deterministic axis-major order."""
        return [
            Candidate(q=q, t_max=t, threshold_scale=s, encoder=e)
            for e, q, t, s in itertools.product(
                self.encoder, self.q, self.t_max, self.threshold_scale
            )
        ]

    def sample(self, n: int, seed: int = 0) -> list[Candidate]:
        """``n`` distinct candidates drawn uniformly from the grid
        (deterministic per seed; ``n`` is clamped to the space size)."""
        grid = self.grid()
        rng = random.Random(seed)
        n = min(int(n), len(grid))
        if n <= 0:
            raise ValueError("sample needs a positive candidate budget")
        return rng.sample(grid, n)


def candidate_config(cand: Candidate, series_len: int) -> ColumnConfig:
    """Materialize a candidate into a ``ColumnConfig`` for an [N, L] stream.

    The encoder pins the input width (latency: p == L, on/off: p == 2L);
    the threshold is ``threshold_scale`` times the suggested operating
    point for the resulting geometry.
    """
    p = series_len if cand.encoder == "latency" else 2 * series_len
    cfg = ColumnConfig(p=p, q=cand.q, t_max=cand.t_max)
    return cfg.with_threshold(
        cand.threshold_scale * simulator.suggest_threshold(cfg)
    )
