"""Quality-vs-silicon Pareto analysis for design sweeps.

The paper's closing loop: every functional-simulation result is paired
with *forecasted* silicon metrics (area / leakage from the synapse count,
``repro.hwgen.forecast``) so designs can be ranked without running the
hardware flow.  ``pareto_front`` extracts the nondominated set — the
designs for which no other design is at least as good on every objective
and strictly better on one.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core.types import ColumnConfig


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One evaluated design: clustering quality + forecasted silicon cost.

    ``index`` is the candidate's position in the explore order;
    ``params`` follows the unified ``ClusteringResult.params`` contract
    (``{'w': [p, q]}``).
    """

    index: int
    cfg: ColumnConfig
    encoder: str
    rand_index: float
    synapses: int
    area_um2: float
    leakage_uw: float
    params: dict
    lowering: str = ""
    buckets: int = 1
    shards: int = 1
    # Fault-tolerance provenance: the journal fingerprint of the
    # evaluation ('' outside journaled explore runs) and how many
    # degradation-ladder rungs failed before ``lowering`` ran (0 = the
    # first-choice lowering succeeded).
    fingerprint: str = ""
    retries: int = 0
    # ExecutionPlan.meta() of the fit that trained this design's bucket
    # (None when the cycle-solver fallback trained it, or on rows
    # restored from a pre-plan journal).
    plan: Optional[dict] = None


def dominates(a: DesignPoint, b: DesignPoint) -> bool:
    """True iff ``a`` is at least as good as ``b`` on every objective
    (rand index up, area and leakage down) and strictly better on one.
    NaN objectives never dominate and are never dominated (they carry no
    ordering information)."""
    ge = (
        a.rand_index >= b.rand_index
        and a.area_um2 <= b.area_um2
        and a.leakage_uw <= b.leakage_uw
    )
    gt = (
        a.rand_index > b.rand_index
        or a.area_um2 < b.area_um2
        or a.leakage_uw < b.leakage_uw
    )
    return ge and gt


def pareto_front(points: Sequence[DesignPoint]) -> list[DesignPoint]:
    """Nondominated subset of ``points``, sorted cheapest-area first.

    Points with a NaN rand index (unlabeled streams) are excluded — they
    cannot be ranked on quality, so a frontier over them would be
    meaningless.  An empty input (e.g. every candidate of a
    fault-isolated run quarantined) returns an empty frontier, never
    raises; ``DSEResult.best`` is the entry point that turns an empty
    frontier into a diagnostic error.
    """
    ranked = [p for p in points if not math.isnan(p.rand_index)]
    front = [
        p
        for p in ranked
        if not any(dominates(o, p) for o in ranked if o is not p)
    ]
    return sorted(front, key=lambda p: (p.area_um2, -p.rand_index))
