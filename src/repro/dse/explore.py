"""Design-space exploration: bucketed sweep -> silicon forecast -> Pareto.

This is the paper's headline loop closed end to end: grid or random
search over (q, t_max, threshold, encoder) runs through the functional
simulator's envelope-bucketed, device-sharded design sweep
(``simulator.cluster_time_series_many``), each design's clustering
quality is paired with forecasted post-layout area/leakage from its
synapse count (``repro.hwgen.forecast`` — the TNN7 regression by
default), and the result is a Pareto frontier of Rand index vs silicon
cost — no hardware flow run required.

Exploration is built for *long* runs: evaluations are fault-isolated by
default (one degenerate candidate is quarantined as an ``EvalFailure``
record in ``meta['failures']`` instead of aborting the sweep, with
kernel-path failures retried down the central lowering-degradation
ladder), per-bucket wall times are watched for stalls
(``distributed.straggler.StepMonitor``), and passing ``journal=`` makes
every completed bucket durable so ``resume=True`` after a kill
re-evaluates only the missing candidates — bit-identical to an
uninterrupted run.  See ``docs/dse.md``.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional, Union

import jax
import numpy as np

from repro.core import backend as backend_lib
from repro.core import column as column_lib
from repro.core import simulator
from repro.distributed.straggler import StepMonitor
from repro.dse import journal as journal_lib
from repro.dse.pareto import DesignPoint, pareto_front
from repro.dse.space import Candidate, DesignSpace, candidate_config
from repro.roofline import costmodel


@dataclasses.dataclass
class DSEResult:
    """Outcome of one exploration run.

    ``points`` holds every *scored* candidate in explore order (a
    quarantined candidate has no point); ``pareto`` the nondominated
    subset (Rand index up, forecasted area and leakage down),
    cheapest-area first.  ``meta`` records how the sweep executed,
    per encoder group where applicable:

      * ``'buckets'`` / ``'lowering'`` — dicts keyed by encoder: the
        bucket count and the comma-joined lowerings that actually ran
        for that group (every group, not just the last one).
      * ``'failures'`` — one dict per quarantined candidate (index,
        encoder, stage, error, lowerings attempted, retries); empty on
        a clean run.  ``'quarantined'`` is its length.
      * ``'retries'`` / ``'fallbacks'`` — total failed ladder-rung
        attempts across the run, and how many scored designs ran on a
        degraded lowering.
      * ``'stalls'`` — straggler events (bucket wall-time outliers)
        flagged by the step monitor.
      * ``'resumed'`` — candidates restored from the journal instead of
        re-evaluated (0 without ``resume=True``).
    """

    points: list[DesignPoint]
    pareto: list[DesignPoint]
    seconds: float
    meta: dict

    def best(self) -> DesignPoint:
        """Highest Rand index per forecasted area — the NSPU design
        objective the example sweeps optimize.

        Raises a diagnostic ``ValueError`` when the frontier is empty:
        either nothing was scored (all candidates quarantined — the
        error says how many and points at ``meta['failures']``) or the
        stream was unlabeled (NaN Rand indices rank nothing).
        """
        if not self.pareto:
            quarantined = len(self.meta.get("failures", ()))
            detail = (
                f"{quarantined} candidate(s) quarantined — see "
                "DSEResult.meta['failures']"
                if quarantined
                else "was the stream labeled? NaN Rand indices rank nothing"
            )
            raise ValueError(
                f"empty Pareto frontier: {len(self.points)} of "
                f"{len(self.points) + quarantined} candidate(s) scored; "
                + detail
            )
        return max(self.pareto, key=lambda p: p.rand_index / p.area_um2)


def explore(
    series: np.ndarray,
    labels: Optional[np.ndarray],
    space: DesignSpace,
    epochs: int = 4,
    search: str = "grid",
    budget: Optional[int] = None,
    seed: int = 0,
    forecaster=None,
    waste_cap: Optional[float] = None,
    max_bucket: Optional[int] = None,
    on_error: str = "isolate",
    journal: Union[str, journal_lib.Journal, None] = None,
    resume: bool = False,
    monitor: Optional[StepMonitor] = None,
) -> DSEResult:
    """Explore a column design space over one stream, silicon-forecasted.

    Args:
      series: [N, L] real-valued stream (N >= 1; an empty stream raises).
      labels: [N] ground-truth classes; required — the Pareto frontier
        ranks on the Rand index, which needs labels.
      space: the axes to search (see ``DesignSpace``).
      epochs: STDP passes per design.
      search: 'grid' (the full cross product) or 'random' (``budget``
        uniform draws from it, deterministic per ``seed``).
      budget: candidate cap; required for 'random', optional for 'grid'
        (truncates the deterministic grid order).
      seed: feeds both candidate sampling and per-design weight init,
        so equal seeds reproduce the exploration exactly.  Init weights
        are keyed by (seed, candidate index) — never by sweep position —
        so results are invariant to grouping, bucketing, and resume
        subsets.
      forecaster: any object with ``area_um2(synapses)`` /
        ``leakage_uw(synapses)`` — ``hwgen.forecast.PaperForecaster``
        (TNN7 regression) by default; pass a refit
        ``hwgen.forecast.Forecaster`` to use an accumulated design
        database instead.
      waste_cap / max_bucket: envelope-bucketing knobs forwarded to
        ``cluster_time_series_many`` (None defers to central policy).
      on_error: 'isolate' (default) quarantines failing candidates as
        ``EvalFailure`` records in ``meta['failures']`` and keeps
        sweeping, retrying kernel-path failures down the lowering
        ladder; 'raise' propagates the first failure (debugging).
      journal: path (or ``Journal``) to an append-only evaluation
        journal; every completed bucket is published atomically, so a
        killed run loses at most one bucket.  An existing journal
        requires ``resume=True``.  Journaled runs also enable the
        persistent compilation cache in a ``compile_cache/`` directory
        next to the journal (unless one is already configured — see
        ``backend.compile_cache``), so resumed and repeated runs
        compile zero envelope traces.
      resume: skip candidates already in the journal (scored *and*
        quarantined); the resumed run's frontier is bit-identical to an
        uninterrupted one.
      monitor: optional ``StepMonitor`` override for stall detection
        (a fresh one per run by default); its events land in
        ``meta['stalls']``.

    Candidates sharing an encoder sweep together (the encoder pins the
    input width); within each encoder group the sweep is envelope-bucketed
    and design-sharded by the central backend policy.

    Returns a ``DSEResult`` whose ``pareto`` pairs each surviving design's
    Rand index with its forecasted area/leakage.
    """
    if labels is None:
        raise ValueError(
            "explore ranks designs on the Rand index; labels are required"
        )
    if forecaster is None:
        from repro.hwgen.forecast import PaperForecaster

        forecaster = PaperForecaster()

    if search == "grid":
        candidates = space.grid()
        if budget is not None:
            candidates = candidates[: int(budget)]
    elif search == "random":
        if budget is None:
            raise ValueError("search='random' needs a candidate budget")
        candidates = space.sample(budget, seed=seed)
    else:
        raise ValueError(f"unknown search: {search!r} (grid | random)")

    series = np.asarray(series)
    n_cand = len(candidates)
    cfgs_all = [candidate_config(c, series.shape[1]) for c in candidates]
    fps = [
        journal_lib.candidate_fingerprint(cfg, c.encoder, seed, epochs)
        for cfg, c in zip(cfgs_all, candidates)
    ]

    jr = journal
    if jr is not None and not isinstance(jr, journal_lib.Journal):
        jr = journal_lib.Journal(jr)
    restored: dict = {}
    if jr is not None:
        restored = jr.begin(
            {"seed": int(seed), "epochs": int(epochs), "search": search},
            resume=resume,
        )
        # journaled runs are the long-lived ones: default the persistent
        # compilation cache next to the journal, so a resumed (or merely
        # repeated) exploration re-pays ZERO envelope compiles.  A deleted
        # cache dir is recreated (re-enabling our own default repairs it,
        # even mid-process); an explicit compile_cache() /
        # REPRO_COMPILE_CACHE choice made earlier wins.
        default_cache = os.path.join(
            os.path.dirname(os.path.abspath(jr.path)), "compile_cache"
        )
        if backend_lib.compile_cache_dir() in (None, default_cache):
            backend_lib.compile_cache(default_cache)
        # a device calibration saved next to the cache (costmodel.calibrate
        # once per host) upgrades every policy seam below from the
        # hand-tuned constants to the roofline plan.  Disk-load only —
        # exploration never probes the device itself, so an uncalibrated
        # host just keeps the constants fallback.
        try:
            costmodel.load_profile()
        except Exception:
            pass
    mon = monitor if monitor is not None else StepMonitor(
        threshold=4.0, warmup=3
    )

    points: list[Optional[DesignPoint]] = [None] * n_cand
    failures: list[dict] = []
    resumed = 0
    pending: list[int] = []
    for i, (cand, cfg, fp) in enumerate(zip(candidates, cfgs_all, fps)):
        rec = restored.get(fp)
        if rec is None:
            pending.append(i)
            continue
        resumed += 1
        if rec["kind"] == "point":
            points[i] = DesignPoint(
                index=i,
                cfg=cfg,
                encoder=cand.encoder,
                rand_index=float(rec["rand_index"]),
                synapses=int(rec["synapses"]),
                area_um2=float(rec["area_um2"]),
                leakage_uw=float(rec["leakage_uw"]),
                params={"w": np.asarray(rec["w"], np.float32)},
                lowering=rec.get("lowering", ""),
                buckets=int(rec.get("buckets", 1)),
                shards=int(rec.get("shards", 1)),
                fingerprint=fp,
                retries=int(rec.get("retries", 0)),
                plan=rec.get("plan"),
            )
        else:
            failures.append(
                {
                    "index": i,
                    "encoder": cand.encoder,
                    "stage": rec.get("stage", ""),
                    "error": rec.get("error", ""),
                    "lowerings": list(rec.get("lowerings", ())),
                    "retries": int(rec.get("retries", 0)),
                    "restored": True,
                }
            )

    # init weights keyed per CANDIDATE index (fold_in), not per sweep
    # position: a resumed partial sweep hands every design the same init
    # the full sweep would have, so resume is bit-identical
    _, init_key = jax.random.split(jax.random.key(seed))

    t0 = time.perf_counter()
    for encoder in dict.fromkeys(candidates[i].encoder for i in pending):
        idxs = [i for i in pending if candidates[i].encoder == encoder]
        cfgs = [cfgs_all[i] for i in idxs]
        w_init = [
            np.asarray(
                column_lib.init_params(
                    jax.random.fold_in(init_key, i), cfgs_all[i]
                )["w"]
            )
            for i in idxs
        ]

        def on_bucket(local_idxs, results, idxs=idxs, encoder=encoder):
            recs = []
            for li, r in zip(local_idxs, results):
                gi = idxs[li]
                if isinstance(r, simulator.EvalFailure):
                    f = {
                        "index": gi,
                        "encoder": encoder,
                        "stage": r.stage,
                        "error": r.error,
                        "lowerings": list(r.lowerings),
                        "retries": r.retries,
                    }
                    failures.append({**f, "restored": False})
                    recs.append({"kind": "failure", "fp": fps[gi], **f})
                    continue
                syn = cfgs_all[gi].synapse_count
                p = DesignPoint(
                    index=gi,
                    cfg=cfgs_all[gi],
                    encoder=encoder,
                    rand_index=r.rand_index,
                    synapses=syn,
                    area_um2=float(forecaster.area_um2(syn)),
                    leakage_uw=float(forecaster.leakage_uw(syn)),
                    params=r.params,
                    lowering=r.lowering,
                    buckets=r.buckets,
                    shards=r.shards,
                    fingerprint=fps[gi],
                    retries=r.retries,
                    plan=r.plan,
                )
                points[gi] = p
                recs.append(
                    {
                        "kind": "point",
                        "fp": fps[gi],
                        "index": gi,
                        "encoder": encoder,
                        "cand": dataclasses.asdict(candidates[gi]),
                        "rand_index": p.rand_index,
                        "synapses": p.synapses,
                        "area_um2": p.area_um2,
                        "leakage_uw": p.leakage_uw,
                        "lowering": p.lowering,
                        "buckets": p.buckets,
                        "shards": p.shards,
                        "retries": p.retries,
                        "plan": p.plan,
                        "w": np.asarray(r.params["w"], np.float32).tolist(),
                    }
                )
            if jr is not None:
                jr.append(recs)

        simulator.cluster_time_series_many(
            series, labels, cfgs, epochs=epochs, seed=seed, encoder=encoder,
            waste_cap=waste_cap, max_bucket=max_bucket, on_error=on_error,
            w_init=w_init, bucket_callback=on_bucket, monitor=mon,
        )
    seconds = time.perf_counter() - t0

    done = [p for p in points if p is not None]
    encoders = list(dict.fromkeys(c.encoder for c in candidates))
    lowering_by_encoder = {
        e: ",".join(
            sorted({p.lowering for p in done if p.encoder == e and p.lowering})
        )
        for e in encoders
        if any(p.encoder == e for p in done)
    }
    buckets_by_encoder = {
        e: max(p.buckets for p in done if p.encoder == e)
        for e in encoders
        if any(p.encoder == e for p in done)
    }
    return DSEResult(
        points=done,
        pareto=pareto_front(done),
        seconds=seconds,
        meta={
            "search": search,
            "candidates": len(done),
            "buckets": buckets_by_encoder,
            "shards": max((p.shards for p in done), default=1),
            "lowering": lowering_by_encoder,
            "epochs": epochs,
            "seed": seed,
            "on_error": on_error,
            "failures": failures,
            "quarantined": len(failures),
            "retries": (
                sum(p.retries for p in done)
                + sum(f["retries"] for f in failures)
            ),
            "fallbacks": sum(1 for p in done if p.retries > 0),
            "stalls": [dataclasses.asdict(ev) for ev in mon.events],
            "resumed": resumed,
            "journal": jr.path if jr is not None else None,
            # '' = constants fallback; otherwise the calibrated
            # DeviceProfile whose cost model chose every bucket's blocking
            "profile": getattr(costmodel.profile(), "name", ""),
        },
    )


def summarize(result: DSEResult) -> str:
    """Human-readable frontier table (the example prints this)."""
    meta = result.meta
    lines = [
        f"{len(result.points)} designs explored in {result.seconds:.2f}s "
        f"(buckets={meta['buckets']}, shards={meta['shards']}, "
        f"lowering={meta['lowering']})",
    ]
    if meta.get("quarantined"):
        by_stage: dict[str, int] = {}
        for f in meta["failures"]:
            by_stage[f["stage"]] = by_stage.get(f["stage"], 0) + 1
        lines.append(
            f"{meta['quarantined']} candidate(s) quarantined "
            f"({', '.join(f'{k}: {v}' for k, v in sorted(by_stage.items()))})"
            " — see meta['failures']"
        )
    if meta.get("resumed"):
        lines.append(f"{meta['resumed']} candidate(s) restored from journal")
    if meta.get("stalls"):
        lines.append(f"{len(meta['stalls'])} stalled bucket(s) flagged")
    lines.append("Pareto frontier (Rand index vs forecasted TNN area/leakage):")
    for p in result.pareto:
        lines.append(
            f"  enc={p.encoder:7s} q={p.cfg.q:3d} t_max={p.cfg.t_max:4d} "
            f"th={p.cfg.neuron.threshold:7.1f}  RI={p.rand_index:.3f}  "
            f"syn={p.synapses:6d}  area={p.area_um2:9.0f} um^2  "
            f"leak={p.leakage_uw:7.2f} uW"
        )
    return "\n".join(lines)
