"""Design-space exploration: bucketed sweep -> silicon forecast -> Pareto.

This is the paper's headline loop closed end to end: grid or random
search over (q, t_max, threshold, encoder) runs through the functional
simulator's envelope-bucketed, device-sharded design sweep
(``simulator.cluster_time_series_many``), each design's clustering
quality is paired with forecasted post-layout area/leakage from its
synapse count (``repro.hwgen.forecast`` — the TNN7 regression by
default), and the result is a Pareto frontier of Rand index vs silicon
cost — no hardware flow run required.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.core import simulator
from repro.dse.pareto import DesignPoint, pareto_front
from repro.dse.space import Candidate, DesignSpace, candidate_config


@dataclasses.dataclass
class DSEResult:
    """Outcome of one exploration run.

    ``points`` holds every evaluated candidate in explore order;
    ``pareto`` the nondominated subset (Rand index up, forecasted area
    and leakage down), cheapest-area first.  ``meta`` records how the
    sweep executed: per-encoder bucket counts, shard counts, the lowering
    that ran, and the candidate count.
    """

    points: list[DesignPoint]
    pareto: list[DesignPoint]
    seconds: float
    meta: dict

    def best(self) -> DesignPoint:
        """Highest Rand index per forecasted area — the NSPU design
        objective the example sweeps optimize."""
        if not self.pareto:
            raise ValueError("no Pareto points (unlabeled stream?)")
        return max(self.pareto, key=lambda p: p.rand_index / p.area_um2)


def explore(
    series: np.ndarray,
    labels: Optional[np.ndarray],
    space: DesignSpace,
    epochs: int = 4,
    search: str = "grid",
    budget: Optional[int] = None,
    seed: int = 0,
    forecaster=None,
    waste_cap: Optional[float] = None,
    max_bucket: Optional[int] = None,
) -> DSEResult:
    """Explore a column design space over one stream, silicon-forecasted.

    Args:
      series: [N, L] real-valued stream (N >= 1; an empty stream raises).
      labels: [N] ground-truth classes; required — the Pareto frontier
        ranks on the Rand index, which needs labels.
      space: the axes to search (see ``DesignSpace``).
      epochs: STDP passes per design.
      search: 'grid' (the full cross product) or 'random' (``budget``
        uniform draws from it, deterministic per ``seed``).
      budget: candidate cap; required for 'random', optional for 'grid'
        (truncates the deterministic grid order).
      seed: feeds both candidate sampling and per-design weight init,
        so equal seeds reproduce the exploration exactly.
      forecaster: any object with ``area_um2(synapses)`` /
        ``leakage_uw(synapses)`` — ``hwgen.forecast.PaperForecaster``
        (TNN7 regression) by default; pass a refit
        ``hwgen.forecast.Forecaster`` to use an accumulated design
        database instead.
      waste_cap / max_bucket: envelope-bucketing knobs forwarded to
        ``cluster_time_series_many`` (None defers to central policy).

    Candidates sharing an encoder sweep together (the encoder pins the
    input width); within each encoder group the sweep is envelope-bucketed
    and design-sharded by the central backend policy.

    Returns a ``DSEResult`` whose ``pareto`` pairs each surviving design's
    Rand index with its forecasted area/leakage.
    """
    if labels is None:
        raise ValueError(
            "explore ranks designs on the Rand index; labels are required"
        )
    if forecaster is None:
        from repro.hwgen.forecast import PaperForecaster

        forecaster = PaperForecaster()

    if search == "grid":
        candidates = space.grid()
        if budget is not None:
            candidates = candidates[: int(budget)]
    elif search == "random":
        if budget is None:
            raise ValueError("search='random' needs a candidate budget")
        candidates = space.sample(budget, seed=seed)
    else:
        raise ValueError(f"unknown search: {search!r} (grid | random)")

    series = np.asarray(series)
    t0 = time.perf_counter()
    points: list[Optional[DesignPoint]] = [None] * len(candidates)
    buckets_by_encoder: dict[str, int] = {}
    shards = 1
    lowering = ""
    for encoder in dict.fromkeys(c.encoder for c in candidates):
        idxs = [i for i, c in enumerate(candidates) if c.encoder == encoder]
        cfgs = [
            candidate_config(candidates[i], series.shape[1]) for i in idxs
        ]
        results = simulator.cluster_time_series_many(
            series, labels, cfgs, epochs=epochs, seed=seed, encoder=encoder,
            waste_cap=waste_cap, max_bucket=max_bucket,
        )
        buckets_by_encoder[encoder] = results[0].buckets
        lowering = results[0].lowering
        for i, cfg, res in zip(idxs, cfgs, results):
            syn = cfg.synapse_count
            shards = max(shards, res.shards)
            points[i] = DesignPoint(
                index=i,
                cfg=cfg,
                encoder=encoder,
                rand_index=res.rand_index,
                synapses=syn,
                area_um2=float(forecaster.area_um2(syn)),
                leakage_uw=float(forecaster.leakage_uw(syn)),
                params=res.params,
                lowering=res.lowering,
                buckets=res.buckets,
                shards=res.shards,
            )
    seconds = time.perf_counter() - t0
    done = [p for p in points if p is not None]
    return DSEResult(
        points=done,
        pareto=pareto_front(done),
        seconds=seconds,
        meta={
            "search": search,
            "candidates": len(done),
            "buckets": buckets_by_encoder,
            "shards": shards,
            "lowering": lowering,
            "epochs": epochs,
            "seed": seed,
        },
    )


def summarize(result: DSEResult) -> str:
    """Human-readable frontier table (the example prints this)."""
    lines = [
        f"{len(result.points)} designs explored in {result.seconds:.2f}s "
        f"(buckets={result.meta['buckets']}, shards={result.meta['shards']}, "
        f"lowering={result.meta['lowering']!r})",
        "Pareto frontier (Rand index vs forecasted TNN area/leakage):",
    ]
    for p in result.pareto:
        lines.append(
            f"  enc={p.encoder:7s} q={p.cfg.q:3d} t_max={p.cfg.t_max:4d} "
            f"th={p.cfg.neuron.threshold:7.1f}  RI={p.rand_index:.3f}  "
            f"syn={p.synapses:6d}  area={p.area_um2:9.0f} um^2  "
            f"leak={p.leakage_uw:7.2f} uW"
        )
    return "\n".join(lines)
