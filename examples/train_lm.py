"""End-to-end LM training driver: train a ~100M-param model for a few
hundred steps with the full production runtime (sharded jit when a mesh is
present, microbatching, async checkpointing, straggler monitor).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

The default config is a ~100M-param granite-style dense decoder (real
vocab, 8 layers, d_model 512) — sized so a few hundred steps run on CPU in
minutes.  `--arch/--smoke` selects any registry architecture instead.
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_arch
from repro.data.tokens import DataConfig
from repro.distributed.train_loop import TrainConfig, Trainer
from repro.models.config import ArchConfig


def default_100m() -> ArchConfig:
    return ArchConfig(
        name="granite-100m", family="dense",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=2, d_head=64,
        d_ff=1536, vocab_size=49155, dtype="float32", kv_chunk=256,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    arch = get_arch(args.arch, smoke=True) if args.arch else default_100m()
    print(f"training {arch.name}: {arch.param_count()/1e6:.0f}M params")
    dc = DataConfig(vocab_size=arch.vocab_size,
                    global_batch=args.global_batch, seq_len=args.seq_len)
    with tempfile.TemporaryDirectory() as d:
        tc = TrainConfig(steps=args.steps, microbatches=2,
                         checkpoint_every=100, checkpoint_dir=d,
                         warmup_steps=20, peak_lr=3e-4)
        tr = Trainer(arch, dc, tc)
        out = tr.run()
        losses = out["losses"]
        for i in range(0, len(losses), max(1, len(losses) // 10)):
            print(f"step {i:4d}  loss {losses[i]:.4f}")
        print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
              f"median step {tr.monitor.median_s*1e3:.0f} ms; "
              f"stragglers flagged: {len(tr.monitor.events)}")


if __name__ == "__main__":
    main()
