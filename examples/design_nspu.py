"""Design-space exploration for a custom NSPU (paper's intended workflow).

    PYTHONPATH=src python examples/design_nspu.py

Sweeps column geometry (q neurons) and gamma window for a target sensory
stream, evaluates clustering quality in the functional simulator, then
takes the best design through the hardware generator and compares the
silicon cost of all candidates via forecasting — the "rapid application
exploration" loop TNNGen §II-A describes.  A multi-layer variant of the
winning column (two fully-connected columns feeding a read-out column)
runs through the same clustering loop via
``simulator.cluster_time_series_network``.
"""
import tempfile

import numpy as np

from repro.clustering.metrics import rand_index
from repro.core import simulator
from repro.core.types import (
    ColumnConfig, LayerConfig, NetworkConfig, NeuronConfig,
)
from repro.data import ucr
from repro.hwgen import run_flow
from repro.hwgen.forecast import PaperForecaster
from repro.hwgen.rtl import ColumnSpec

BENCH = "Beef"  # 470-sample food spectrographs, 5 classes

ds = ucr.load(BENCH)
L, k = ds.x.shape[1], ds.n_classes
fc = PaperForecaster()

# All candidate designs are padded into one (p, q, t_max) envelope and
# trained as ONE compiled program — per-design threshold/window/live-q ride
# as runtime operands, so the whole heterogeneous sweep is one trace (the
# Mosaic kernel on TPU, its jnp reference body elsewhere; the result
# records which lowering actually ran on this host).
cfgs = []
for q in (k, 2 * k):
    for t_max in (32, 64):
        cfg = ColumnConfig(p=L, q=q, t_max=t_max)
        cfgs.append(cfg.with_threshold(simulator.suggest_threshold(cfg)))
sweep = simulator.cluster_time_series_many(ds.x[:120], ds.y[:120], cfgs, epochs=3)
print(f"swept {len(cfgs)} designs in one compiled program "
      f"({sweep[0].train_seconds:.2f}s total, "
      f"lowering={sweep[0].lowering!r})")

candidates = []
for cfg, res in zip(cfgs, sweep):
    syn = L * cfg.q
    candidates.append({
        "q": cfg.q, "t_max": cfg.t_max, "ri": res.rand_index, "synapses": syn,
        "fc_area_um2": fc.area_um2(syn), "fc_leak_uw": fc.leakage_uw(syn),
    })
    print(f"q={cfg.q:2d} t_max={cfg.t_max:3d}: RI={res.rand_index:.3f} "
          f"synapses={syn}  forecast area={fc.area_um2(syn):8.0f} um^2 "
          f"leak={fc.leakage_uw(syn):6.2f} uW")

# quality per silicon area — the NSPU design objective
best = max(candidates, key=lambda c: c["ri"] / c["fc_area_um2"])
print(f"\nselected design: q={best['q']} t_max={best['t_max']} "
      f"(RI {best['ri']:.3f}, forecast {best['fc_area_um2']:.0f} um^2)")

# multi-layer variant: two copies of the winning column feed a k-way
# read-out column; each layer trains as ONE jitted scan on the backend
# 'auto' resolves to (fused off the bat for these RNL configs).
l1_col = ColumnConfig(p=L, q=best["q"], t_max=best["t_max"])
l1_col = l1_col.with_threshold(simulator.suggest_threshold(l1_col))
l2_col = ColumnConfig(p=2 * best["q"], q=k, t_max=best["t_max"])
l2_col = l2_col.with_threshold(simulator.suggest_threshold(l2_col))
net = NetworkConfig(layers=(
    LayerConfig(columns=2, column=l1_col),
    LayerConfig(columns=1, column=l2_col),
), name="beef_2layer")
net_res = simulator.cluster_time_series_network(
    ds.x[:120], ds.y[:120], net, epochs=3
)
net_syn = sum(l.columns * l.column.p * l.column.q for l in net.layers)
print(f"2-layer variant ({net_syn} synapses): RI={net_res.rand_index:.3f} "
      f"vs best single column RI={best['ri']:.3f} "
      f"({net_res.train_seconds:.2f}s, one fused scan per layer, "
      f"lowering={net_res.lowering!r})")

with tempfile.TemporaryDirectory() as build:
    spec = ColumnSpec(name="beef_nspu", p=L, q=best["q"],
                      theta=int(L * 7 // 8), t_max=best["t_max"])
    fr = run_flow(spec, "tnn7", build_root=build)
    print(f"post-layout: {fr.area_um2:.0f} um^2 ({fr.leakage_uw:.2f} uW), "
          f"forecast error {100*(best['fc_area_um2']-fr.area_um2)/fr.area_um2:+.1f}%")
