"""Design-space exploration for a custom NSPU (paper's intended workflow).

    PYTHONPATH=src python examples/design_nspu.py

Explores column geometry (q neurons), gamma window and firing threshold
for a target sensory stream via ``repro.dse.explore``: the candidates are
envelope-bucketed under the central waste cap (so small designs never pay
a big design's padding every volley), each bucket trains as ONE compiled
volley-blocked scan with the design axis sharded across local devices
where a mesh exists, and every design's clustering quality is paired with
*forecasted* post-layout area/leakage (paper §III-D) into a Pareto
frontier — the "rapid application exploration" loop TNNGen §II-A
describes, closed without an EDA run.  The selected design then goes
through the hardware generator to check the forecast, and a multi-layer
variant runs through the same clustering loop via
``simulator.cluster_time_series_network``.
"""
import tempfile

from repro import dse
from repro.core import simulator
from repro.core.types import ColumnConfig, LayerConfig, NetworkConfig
from repro.data import ucr
from repro.hwgen import run_flow
from repro.hwgen.rtl import ColumnSpec

BENCH = "Beef"  # 470-sample food spectrographs, 5 classes

ds = ucr.load(BENCH)
L, k = ds.x.shape[1], ds.n_classes

space = dse.DesignSpace(q=(k, 2 * k), t_max=(32, 64))
res = dse.explore(ds.x[:120], ds.y[:120], space, epochs=3)
print(dse.summarize(res))

# quality per forecasted silicon area — the NSPU design objective
bp = res.best()
best = {
    "q": bp.cfg.q, "t_max": bp.cfg.t_max, "ri": bp.rand_index,
    "fc_area_um2": bp.area_um2,
}
print(f"\nselected design: q={best['q']} t_max={best['t_max']} "
      f"(RI {best['ri']:.3f}, forecast {best['fc_area_um2']:.0f} um^2)")

# multi-layer variant: two copies of the winning column feed a k-way
# read-out column; each layer trains as ONE jitted scan on the backend
# 'auto' resolves to (fused off the bat for these RNL configs).
l1_col = ColumnConfig(p=L, q=best["q"], t_max=best["t_max"])
l1_col = l1_col.with_threshold(simulator.suggest_threshold(l1_col))
l2_col = ColumnConfig(p=2 * best["q"], q=k, t_max=best["t_max"])
l2_col = l2_col.with_threshold(simulator.suggest_threshold(l2_col))
net = NetworkConfig(layers=(
    LayerConfig(columns=2, column=l1_col),
    LayerConfig(columns=1, column=l2_col),
), name="beef_2layer")
net_res = simulator.cluster_time_series_network(
    ds.x[:120], ds.y[:120], net, epochs=3
)
net_syn = sum(l.columns * l.column.p * l.column.q for l in net.layers)
print(f"2-layer variant ({net_syn} synapses): RI={net_res.rand_index:.3f} "
      f"vs best single column RI={best['ri']:.3f} "
      f"({net_res.train_seconds:.2f}s, one fused scan per layer, "
      f"lowering={net_res.lowering!r})")

with tempfile.TemporaryDirectory() as build:
    spec = ColumnSpec(name="beef_nspu", p=L, q=best["q"],
                      theta=int(L * 7 // 8), t_max=best["t_max"])
    fr = run_flow(spec, "tnn7", build_root=build)
    print(f"post-layout: {fr.area_um2:.0f} um^2 ({fr.leakage_uw:.2f} uW), "
          f"forecast error {100*(best['fc_area_um2']-fr.area_um2)/fr.area_um2:+.1f}%")
