"""Quickstart: the TNNGen flow in ~40 lines (paper Fig. 1).

    PYTHONPATH=src python examples/quickstart.py

1. Model a TNN column in the functional simulator and cluster a time-series
   benchmark (paper §II-A / Table II).
2. Generate its hardware: Verilog RTL + TCL flow scripts + post-layout
   metrics (paper §II-B / Tables III-IV).
3. Forecast silicon cost without the flow (paper §III-D / Table V).
"""
import tempfile

from repro.clustering.kmeans import kmeans
from repro.clustering.metrics import normalized_rand, rand_index
from repro.configs.tnn_columns import column_config, hardware_spec
from repro.core import simulator
from repro.data import ucr
from repro.hwgen import run_flow
from repro.hwgen.forecast import PaperForecaster

BENCH = "ECG200"

# 1 — functional simulation + clustering ---------------------------------
ds = ucr.load(BENCH)
cfg = column_config(BENCH)
cfg = cfg.with_threshold(simulator.suggest_threshold(cfg))
res = simulator.cluster_time_series(ds.x, ds.y, cfg, epochs=4)
_, km = kmeans(ds.x, ds.n_classes)
ri_km = rand_index(ds.y, km)
print(f"[1] {BENCH} ({'synthetic double' if ds.synthetic else 'real UCR'}): "
      f"TNN rand index {res.rand_index:.3f} "
      f"(normalized to k-means: {normalized_rand(res.rand_index, ri_km):.3f}) "
      f"in {res.train_seconds:.1f}s")

# 2 — hardware generation -------------------------------------------------
with tempfile.TemporaryDirectory() as build:
    fr = run_flow(hardware_spec(BENCH), library="tnn7", build_root=build)
    print(f"[2] generated RTL+TCL under {fr.build_dir}")
    print(f"    post-layout (TNN7 7nm): {fr.area_um2:.0f} um^2, "
          f"{fr.leakage_uw:.2f} uW leakage, {fr.latency_ns:.0f} ns/sample, "
          f"flow runtime {fr.total_runtime_s:.0f}s")

# 3 — forecasting ----------------------------------------------------------
fc = PaperForecaster()
syn = fr.synapses
print(f"[3] forecast from synapse count alone ({syn}): "
      f"area {fc.area_um2(syn):.0f} um^2, leakage {fc.leakage_uw(syn):.2f} uW "
      f"(paper eqns: 5.56*s-94.9 / 0.00541*s-0.725)")
