"""Property-based round-trip tests for the latency encoder (ISSUE 8).

The encoder is the admission boundary of every front-end (simulator
sweeps, the streaming service): these properties pin the degenerate
inputs real traffic produces — constant series, single-sample series,
extreme gamma windows — plus the two invariants everything downstream
assumes: spike times live on the ``[0, t_max)`` integer grid in
``TIME_DTYPE``, and larger samples spike earlier (order preservation
per feature, which is what makes latency-coded clustering meaningful).

Runs on the vendored hypothesis shim in ``conftest.py`` (deterministic,
dependency-free) or the real library when installed.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import encoding
from repro.core.types import TIME_DTYPE


def _enc(x, t_max, **kw):
    return np.asarray(encoding.latency_encode(jnp.asarray(x), t_max, **kw))


@settings(max_examples=25, deadline=None)
@given(
    t_max=st.integers(2, 512),
    length=st.integers(1, 32),
    value=st.floats(-1e6, 1e6),
)
def test_constant_series_encodes_to_latest_spike(t_max, length, value):
    """A constant series (zero dynamic range — silence, a stuck sensor)
    normalizes to 0 everywhere and must encode to the LAST grid slot for
    every feature, never to out-of-range or mid-window times.  Covers the
    single-sample series at length 1."""
    t = _enc(np.full(length, value), t_max)
    assert t.dtype == TIME_DTYPE
    assert (t == t_max - 1).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**6), length=st.integers(2, 48))
def test_times_live_on_the_spike_grid(seed, length):
    x = np.random.default_rng(seed).normal(scale=100.0, size=length)
    for t_max in (2, 3, 257):
        t = _enc(x, t_max)
        assert t.dtype == TIME_DTYPE
        assert ((0 <= t) & (t < t_max)).all()
        # the dynamic range is used end to end: the max sample spikes at
        # 0, the min sample at the last slot
        assert t[np.argmax(x)] == 0
        assert t[np.argmin(x)] == t_max - 1


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_degenerate_gamma_window(seed):
    """Extreme gamma: a one-slot window (t_max=1) collapses every sample
    to time 0 — degenerate but well-defined, never negative/NaN."""
    x = np.random.default_rng(seed).normal(size=16)
    t = _enc(x, 1)
    assert (t == 0).all() and t.dtype == TIME_DTYPE


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10**6),
    t_max=st.sampled_from([2, 8, 32, 256]),
    length=st.integers(2, 48),
)
def test_monotone_order_preserving_per_feature(seed, t_max, length):
    """Larger sample => earlier (or equal) spike time, feature by
    feature: sorting the samples ascending must sort the times
    descending (ties allowed — the grid quantizes)."""
    x = np.random.default_rng(seed).normal(size=length)
    t = _enc(x, t_max)
    by_value = np.argsort(x, kind="stable")
    assert (np.diff(t[by_value].astype(np.int64)) <= 0).all()


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6), t_max=st.sampled_from([2, 8, 32, 256]))
def test_round_trip_through_decode(seed, t_max):
    """Grid times decode to intensities (v = 1 - t/(t_max-1)) that
    re-encode to the SAME times (normalize=False: the decoded values are
    already in [0, 1]) — the encoder loses only sub-grid precision, once."""
    x = np.random.default_rng(seed).normal(size=24)
    t = _enc(x, t_max)
    v = 1.0 - t.astype(np.float64) / (t_max - 1)
    t2 = _enc(v, t_max, normalize=False)
    assert np.array_equal(t, t2)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6), t_max=st.sampled_from([4, 32]))
def test_onoff_round_trip_width_and_silence(seed, t_max):
    """On/off coding doubles the width and keeps exactly one of the two
    channels silent per sample (the sentinel ``t_max``), so downstream
    synapse counts stay ``encoded_width`` exact."""
    x = np.random.default_rng(seed).normal(size=9)
    t = np.asarray(encoding.onoff_encode(jnp.asarray(x), t_max))
    assert t.shape == (18,)
    on, off = t[:9], t[9:]  # concatenated channel halves
    assert ((on == t_max) != (off == t_max)).all()  # exactly one silent
    assert ((0 <= t) & (t <= t_max)).all()


def test_encode_dispatch_matches_width_contract():
    x = jnp.asarray(np.linspace(-1, 1, 10))
    for encoder in encoding.ENCODERS:
        out = np.asarray(encoding.encode(x, 16, encoder))
        assert out.shape == (encoding.encoded_width(10, encoder),)
    assert encoding.encoded_width(10, "latency") == 10
    assert encoding.encoded_width(10, "onoff") == 20
    with pytest.raises(ValueError, match="unknown encoder"):
        encoding.encoded_width(10, "morse")
    with pytest.raises(ValueError, match="unknown encoder"):
        encoding.encode(x, 16, "morse")
