"""End-to-end behaviour of the paper's system (TNNGen): PyTorch-model-spec
-> functional simulation -> clustering metrics -> hardware flow -> forecast,
plus the LM-pillar end-to-end (train a model, losses descend, serve it)."""
import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest

from repro.configs.tnn_columns import column_config, hardware_spec
from repro.core import simulator
from repro.data import ucr
from repro.hwgen import run_flow
from repro.hwgen.forecast import PaperForecaster


def test_tnngen_end_to_end_small():
    """The paper's Fig. 1 flow on one benchmark: simulate + cluster, then
    generate hardware and forecast — every stage producing sane output."""
    name = "ECG200"
    ds = ucr.load(name)
    x, y = ds.x[:120], ds.y[:120]
    cfg = column_config(name)
    cfg = cfg.with_threshold(simulator.suggest_threshold(cfg))
    res = simulator.cluster_time_series(x, y, cfg, epochs=3)
    assert np.isfinite(res.rand_index)
    # a trained TNN column must beat chance (random 2-class RI ~0.5 - eps)
    assert res.rand_index > 0.45

    with tempfile.TemporaryDirectory() as d:
        fr = run_flow(hardware_spec(name), "tnn7", build_root=d)
        assert fr.area_um2 > 0 and fr.leakage_uw > 0
        fc = PaperForecaster()
        # forecast within 20% of the flow's post-layout area (Table V regime)
        assert abs(fc.area_um2(fr.synapses) - fr.area_um2) / fr.area_um2 < 0.2


def test_tnn_beats_untrained_column():
    name = "SonyAIBORobotSurface2"
    ds = ucr.load(name)
    x, y = ds.x[:160], ds.y[:160]
    cfg = column_config(name).with_threshold(
        simulator.suggest_threshold(column_config(name))
    )
    trained = simulator.cluster_time_series(x, y, cfg, epochs=4)
    untrained = simulator.cluster_time_series(x, y, cfg, epochs=0)
    assert trained.rand_index >= untrained.rand_index - 0.05


def test_cluster_modes_agree():
    """Event-driven and cycle-accurate simulation produce identical
    clusterings (the paper's hybrid timing claim, end-to-end)."""
    name = "ECG200"
    ds = ucr.load(name)
    x = ds.x[:60]
    cfg = column_config(name).with_threshold(
        simulator.suggest_threshold(column_config(name))
    )
    a = simulator.cluster_time_series(x, ds.y[:60], cfg, epochs=2, mode="event")
    b = simulator.cluster_time_series(x, ds.y[:60], cfg, epochs=2, mode="cycle")
    np.testing.assert_array_equal(a.assignments, b.assignments)


def test_lm_pillar_train_and_serve_end_to_end():
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.data.tokens import DataConfig
    from repro.distributed.train_loop import TrainConfig, Trainer
    from repro.models import transformer as T

    arch = get_arch("granite-3-8b", smoke=True)
    dc = DataConfig(vocab_size=arch.vocab_size, global_batch=8, seq_len=32)
    out = Trainer(
        arch, dc, TrainConfig(steps=30, warmup_steps=3, peak_lr=2e-3)
    ).run()
    losses = out["losses"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])  # learning happens
    cache, lg = T.prefill(out["params"], jnp.ones((2, 8), jnp.int32), arch,
                          max_len=16)
    cache, lg = T.decode_step(out["params"], cache, jnp.ones((2, 1), jnp.int32), arch)
    assert np.isfinite(np.asarray(lg, np.float32)).all()


def test_dryrun_cell_on_tiny_mesh():
    """The dry-run machinery end-to-end on an 8-device CPU mesh (subprocess:
    device count must precede jax init), real sharding + analyses path."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import repro.configs as C
        from repro.distributed import sharding
        from repro.launch.hlo import collective_bytes_by_kind
        from repro.models import transformer as T

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = C.get_arch("olmoe-1b-7b", smoke=True)
        T.set_mesh(mesh)
        p_shapes = jax.eval_shape(lambda: T.init_params(jax.random.key(0), cfg))
        p_shard = sharding.to_shardings(sharding.param_specs(p_shapes, mesh), mesh)
        specs = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        b_shard = sharding.to_shardings(sharding.batch_specs(specs, mesh), mesh)
        fn = jax.jit(lambda p, b: T.loss_fn(p, b, cfg)[0],
                     in_shardings=(p_shard, b_shard))
        compiled = fn.lower(p_shapes, specs).compile()
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca  # jax version compat
        assert ca["flops"] > 0
        coll = collective_bytes_by_kind(compiled.as_text(), total_devices=8)
        assert coll["total"] > 0  # TP/EP must move bytes
        print("DRYRUN_TINY_OK", coll["total"])
    """)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=dict(os.environ, PYTHONPATH="src"),
        timeout=600,
    )
    assert "DRYRUN_TINY_OK" in r.stdout, r.stderr[-3000:]
