"""Fault-isolated, journaled, resumable exploration (ISSUE 6 acceptance).

The contract under test:
  * **degradation ladder** (`backend.lowering_ladder`): a kernel-path
    failure re-resolves the bucket one rung down (mosaic -> reference)
    and the fallback is BIT-IDENTICAL — a fallback changes the lowering,
    never the semantics; the 'cycle' solver joins a ladder only where
    `backend.cycle_exact` proves it identical;
  * **failure isolation**: a design failing every rung is quarantined as
    a structured `EvalFailure` — alone, never its bucket-mates, whose
    results stay bit-identical to a failure-free sweep; non-finite
    weights and fully-silent designs quarantine post-hoc;
  * **journal + resume** (`dse.journal`): completed buckets are
    published atomically (write-then-rename); a SIGKILLed run resumed
    with `explore(journal=..., resume=True)` re-evaluates only the
    missing candidates and reproduces the uninterrupted frontier
    exactly;
  * **explore meta**: failures/retries/fallbacks/stalls surface in
    `DSEResult.meta`, per-encoder values are recorded for ALL encoder
    groups, and an all-quarantined run yields an empty frontier with a
    diagnostic `best()` error, not an IndexError.

All faults are injected through the shared deterministic harness
(`repro.testing.faults`) at the `fused_column` instrumentation seam —
the same injectors the serving tests and the serve-bench chaos case
use, so every consumer exercises one fault model.
"""
import json
import os
import signal
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import dse
from repro.core import backend, simulator
from repro.core.types import ColumnConfig, STDPConfig
from repro.distributed.straggler import StepMonitor
from repro.kernels import fused_column
from repro.testing import faults


def _cfg(p, q, t_max, scale=1.0):
    c = ColumnConfig(p=p, q=q, t_max=t_max)
    return c.with_threshold(scale * simulator.suggest_threshold(c))


def _grid_cfg(p, q, t_max):
    """A config whose training provably stays on the integer weight grid
    (integer STDP steps, no stabilizer) — the `cycle_exact` regime."""
    c = ColumnConfig(
        p=p, q=q, t_max=t_max,
        stdp=STDPConfig(
            mu_capture=1.0, mu_backoff=1.0, mu_search=1.0, stabilizer="none"
        ),
    )
    return c.with_threshold(simulator.suggest_threshold(c))


def _stream(n=14, length=8, classes=3, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, length)), rng.integers(0, classes, n)


def _poisoning_patch(monkeypatch, poison_threshold, lowerings=("reference",)):
    """Make `fit_scan_padded` raise whenever the poisoned design's
    threshold rides the batch at one of the given lowerings (shared
    harness injector)."""
    orig = fused_column.fit_scan_padded
    monkeypatch.setattr(
        fused_column, "fit_scan_padded",
        faults.fail_on_threshold(orig, poison_threshold, lowerings),
    )
    return orig


# ------------------------------------------------------- shared harness
def test_injected_context_manager_installs_and_restores():
    """`faults.injected` wraps a fused_column entry point for the block
    and restores the original even when the wrapper raises."""
    orig = fused_column.fit_scan_padded
    with faults.injected(
        "fit_scan_padded", faults.fail_always, detail="down"
    ) as saved:
        assert saved is orig
        assert fused_column.fit_scan_padded is not orig
        with pytest.raises(faults.InjectedFault, match="injected fault"):
            fused_column.fit_scan_padded()
    assert fused_column.fit_scan_padded is orig


def test_slow_call_and_nan_poison_wrappers():
    import time as _time

    calls = []

    def orig(a):
        calls.append(a)
        return np.ones((2, 2), np.float32)

    t0 = _time.perf_counter()
    out = faults.slow_call(orig, 0.02)(1)
    assert _time.perf_counter() - t0 >= 0.02
    assert np.array_equal(out, np.ones((2, 2)))
    poisoned = faults.nan_poison(orig)(2)
    assert np.isnan(poisoned).sum() == 1
    assert calls == [1, 2]


# --------------------------------------------------------- ladder policy
def test_lowering_ladder_policy():
    assert backend.lowering_ladder("mosaic") == ("mosaic", "reference")
    assert backend.lowering_ladder("reference") == ("reference",)
    assert backend.lowering_ladder("cycle") == ("cycle",)
    # the interpreter is never degraded INTO, only out of
    assert backend.lowering_ladder("interpret") == ("interpret", "reference")
    assert backend.lowering_ladder("mosaic", cycle_exact=True) == (
        "mosaic", "reference", "cycle",
    )
    with pytest.raises(ValueError, match="unknown lowering"):
        backend.lowering_ladder("vulkan")
    # the retry bound covers the whole ladder incl. the solver rung
    assert backend.MAX_EVAL_RETRIES >= len(
        backend.lowering_ladder("mosaic", cycle_exact=True)
    )


def test_cycle_exact_policy():
    w_int = jnp.asarray([[3.0, 0.0], [7.0, 2.0]])
    w_float = jnp.asarray([[3.5, 0.0], [7.0, 2.0]])
    default = _cfg(2, 2, 16)  # stabilizer='half': off-grid updates
    grid = _grid_cfg(2, 2, 16)
    assert not backend.cycle_exact(default, w_int)
    assert backend.cycle_exact(grid, w_int)
    assert not backend.cycle_exact(grid, w_float)
    # abstract weights answer False (same probe as assign_lowering)
    seen = []
    jax.eval_shape(
        lambda w: seen.append(backend.cycle_exact(grid, w)) or w,
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
    )
    assert seen == [False]


# --------------------------------------------- kernel failure -> reference
def test_kernel_failure_degrades_to_reference_bit_identically(monkeypatch):
    """Acceptance (a): a kernel that raises on a bucket falls back to the
    reference lowering with bit-identical results, recording the retry."""
    x, y = _stream(seed=1)
    cfgs = [_cfg(8, 2, 16), _cfg(8, 3, 16), _cfg(8, 2, 24)]
    clean = simulator.cluster_time_series_many(x, y, cfgs, epochs=2, seed=3)

    # pretend-TPU: first-choice lowering is the Mosaic kernel, which the
    # injected fault fails; the ladder must land on 'reference'
    monkeypatch.setattr(backend, "padded_lowering", lambda response: "mosaic")
    monkeypatch.setattr(
        fused_column, "fit_scan_padded",
        faults.fail_on_lowering(fused_column.fit_scan_padded, ("mosaic",)),
    )
    res = simulator.cluster_time_series_many(
        x, y, cfgs, epochs=2, seed=3, on_error="isolate"
    )
    for i, (a, b) in enumerate(zip(res, clean)):
        assert isinstance(a, simulator.ClusteringResult)
        assert a.lowering == "reference" and a.retries == 1
        np.testing.assert_array_equal(
            a.assignments, b.assignments,
            err_msg=f"design {i}: fallback changed assignments",
        )
        np.testing.assert_array_equal(
            np.asarray(a.params["w"]), np.asarray(b.params["w"]),
            err_msg=f"design {i}: fallback changed weights",
        )
        assert a.rand_index == b.rand_index


def test_on_error_raise_propagates(monkeypatch):
    """The default mode keeps failing loudly — no silent degradation."""
    x, y = _stream(seed=1)
    cfgs = [_cfg(8, 2, 16)]
    _poisoning_patch(monkeypatch, cfgs[0].neuron.threshold)
    with pytest.raises(RuntimeError, match="injected fault"):
        simulator.cluster_time_series_many(x, y, cfgs, epochs=1, seed=3)
    with pytest.raises(ValueError, match="on_error"):
        simulator.cluster_time_series_many(
            x, y, cfgs, epochs=1, on_error="retry"
        )


# ------------------------------------------------- per-design quarantine
def test_poisoned_design_quarantined_alone(monkeypatch):
    """Acceptance (b): when the fallback fails too, ONLY the poisoned
    design is quarantined; bucket-mates re-run individually and stay
    bit-identical to a failure-free sweep."""
    x, y = _stream(seed=2)
    cfgs = [
        _cfg(8, 2, 16, 0.9), _cfg(8, 2, 16, 1.25),
        _cfg(8, 3, 16, 1.0), _cfg(8, 3, 16, 1.1),
    ]
    clean = simulator.cluster_time_series_many(x, y, cfgs, epochs=2, seed=5)
    poison = cfgs[1].neuron.threshold
    _poisoning_patch(monkeypatch, poison)  # every fused rung fails

    res = simulator.cluster_time_series_many(
        x, y, cfgs, epochs=2, seed=5, on_error="isolate"
    )
    fail = res[1]
    assert isinstance(fail, simulator.EvalFailure)
    assert fail.index == 1 and fail.stage == "fit"
    assert "injected fault" in fail.error
    assert fail.lowerings and fail.retries == len(fail.lowerings)
    # 'cycle' must NOT appear: stabilizer='half' designs are off-grid, so
    # the solver rung would change semantics and is gated out
    assert "cycle" not in fail.lowerings
    for i in (0, 2, 3):
        r = res[i]
        assert isinstance(r, simulator.ClusteringResult), f"design {i}"
        np.testing.assert_array_equal(r.assignments, clean[i].assignments)
        np.testing.assert_array_equal(
            np.asarray(r.params["w"]), np.asarray(clean[i].params["w"])
        )
        assert r.rand_index == clean[i].rand_index


def test_cycle_rung_bit_identical_when_exact(monkeypatch):
    """Integer-grid designs may degrade all the way to the 'cycle'
    solver — and the result is still bit-identical to the fused path."""
    x, y = _stream(seed=3)
    cfgs = [_grid_cfg(8, 2, 16), _grid_cfg(8, 3, 16)]
    rng = np.random.default_rng(11)
    w_init = [
        rng.integers(0, 8, (8, 2)).astype(np.float32),
        rng.integers(0, 8, (8, 3)).astype(np.float32),
    ]
    clean = simulator.cluster_time_series_many(
        x, y, cfgs, epochs=2, w_init=w_init
    )
    orig = fused_column.fit_scan_padded
    monkeypatch.setattr(
        fused_column, "fit_scan_padded",
        faults.fail_always(detail="all fused rungs down"),
    )
    res = simulator.cluster_time_series_many(
        x, y, cfgs, epochs=2, w_init=w_init, on_error="isolate"
    )
    monkeypatch.setattr(fused_column, "fit_scan_padded", orig)
    for i, (a, b) in enumerate(zip(res, clean)):
        assert isinstance(a, simulator.ClusteringResult)
        assert a.lowering == "cycle", f"design {i} should have degraded"
        assert a.retries >= 1
        np.testing.assert_array_equal(a.assignments, b.assignments)
        np.testing.assert_array_equal(
            np.asarray(a.params["w"]), np.asarray(b.params["w"])
        )


# ---------------------------------------------------- degeneracy guards
def test_nan_weights_and_silent_designs_quarantined():
    x, y = _stream(seed=4)
    cfgs = [_cfg(8, 2, 16) for _ in range(3)]
    rng = np.random.default_rng(6)
    w_init = [
        (rng.uniform(0, 7, (8, 2))).astype(np.float32) for _ in range(3)
    ]
    clean = simulator.cluster_time_series_many(
        x, y, cfgs, epochs=1, w_init=[w.copy() for w in w_init]
    )
    w_init[1][3, 1] = np.nan  # poisons design 1's training lane only
    res = simulator.cluster_time_series_many(
        x, y, cfgs, epochs=1, w_init=w_init, on_error="isolate"
    )
    assert isinstance(res[1], simulator.EvalFailure)
    assert res[1].stage == "weights" and "non-finite" in res[1].error
    assert float("nan") != res[1].rand_index  # NaN property, not a crash
    for i in (0, 2):
        assert isinstance(res[i], simulator.ClusteringResult)
        np.testing.assert_array_equal(
            res[i].assignments, clean[i].assignments
        )

    # a threshold no potential can reach -> no spikes -> 'silent'
    cfgs_sil = [_cfg(8, 2, 16), _cfg(8, 2, 16).with_threshold(1e9)]
    res_sil = simulator.cluster_time_series_many(
        x, y, cfgs_sil, epochs=1, on_error="isolate"
    )
    assert isinstance(res_sil[0], simulator.ClusteringResult)
    assert isinstance(res_sil[1], simulator.EvalFailure)
    assert res_sil[1].stage == "silent"


def test_w_init_validation():
    x, y = _stream(seed=5)
    cfgs = [_cfg(8, 2, 16)]
    with pytest.raises(ValueError, match="one array per config"):
        simulator.cluster_time_series_many(x, y, cfgs, w_init=[])
    with pytest.raises(ValueError, match="shape"):
        simulator.cluster_time_series_many(
            x, y, cfgs, w_init=[np.zeros((4, 4), np.float32)]
        )


# ------------------------------------------------------- explore surface
def test_explore_injected_failure_isolates_candidate(monkeypatch):
    """Acceptance: one injected failure in an 8-candidate explore run —
    the other 7 Rand indices are bit-identical to a failure-free run and
    the failed design lands in meta['failures']."""
    x, y = _stream(n=16, seed=7)
    # 8 distinct threshold scales: suggest_threshold depends only on the
    # geometry's input width, so distinct scales give every candidate a
    # unique threshold — the marker the injected fault keys on
    space = dse.DesignSpace(
        q=(2,), t_max=(16,),
        threshold_scale=(0.8, 0.9, 0.95, 1.0, 1.05, 1.1, 1.2, 1.3),
    )
    assert space.size() == 8
    clean = dse.explore(x, y, space, epochs=1, seed=2)
    assert len(clean.points) == 8 and not clean.meta["failures"]

    victim = clean.points[3]
    _poisoning_patch(monkeypatch, victim.cfg.neuron.threshold)
    res = dse.explore(x, y, space, epochs=1, seed=2)
    assert len(res.points) == 7
    assert res.meta["quarantined"] == 1
    (fail,) = res.meta["failures"]
    assert fail["index"] == victim.index and fail["stage"] == "fit"
    assert res.meta["retries"] >= fail["retries"] >= 1
    clean_by_index = {p.index: p for p in clean.points}
    for p in res.points:
        assert p.rand_index == clean_by_index[p.index].rand_index
        np.testing.assert_array_equal(
            np.asarray(p.params["w"]),
            np.asarray(clean_by_index[p.index].params["w"]),
        )
    assert "quarantined" in dse.summarize(res)


def test_explore_meta_per_encoder_group():
    """Satellite: multi-encoder runs record lowering/buckets for EVERY
    encoder group, not just the last one swept."""
    x, y = _stream(n=12, seed=8)
    space = dse.DesignSpace(
        q=(2,), t_max=(16,), encoder=("latency", "onoff")
    )
    res = dse.explore(x, y, space, epochs=1, seed=4)
    assert set(res.meta["lowering"]) == {"latency", "onoff"}
    assert set(res.meta["buckets"]) == {"latency", "onoff"}
    assert all(low for low in res.meta["lowering"].values())
    assert all(b >= 1 for b in res.meta["buckets"].values())


def test_explore_all_quarantined_empty_frontier_contract(monkeypatch):
    """Satellite: an all-quarantined run yields an empty (not raising)
    frontier and a diagnostic best() error — no opaque IndexError."""
    assert dse.pareto_front([]) == []
    x, y = _stream(seed=9)
    space = dse.DesignSpace(q=(2, 3), t_max=(16,))
    monkeypatch.setattr(
        fused_column, "fit_scan_padded",
        faults.fail_always(detail="every evaluation down"),
    )
    res = dse.explore(x, y, space, epochs=1, seed=5)
    assert res.points == [] and res.pareto == []
    assert res.meta["quarantined"] == space.size()
    with pytest.raises(ValueError, match="quarantined"):
        res.best()
    assert "quarantined" in dse.summarize(res)


def test_explore_stall_detection_surfaces_events():
    x, y = _stream(seed=10)
    # two envelope buckets -> two monitored steps; threshold 0 flags any
    # post-warmup bucket as a stall
    space = dse.DesignSpace(q=(2, 3), t_max=(16, 64))
    mon = StepMonitor(threshold=0.0, warmup=1)
    res = dse.explore(x, y, space, epochs=1, seed=6, monitor=mon)
    assert res.meta["stalls"], "post-warmup buckets must flag at threshold 0"
    ev = res.meta["stalls"][0]
    assert ev["duration_s"] > 0 and ev["ratio"] > 0


# ------------------------------------------------------------- journal
def test_candidate_fingerprint_deterministic_and_sensitive():
    cfg = _cfg(8, 2, 16)
    fp = dse.candidate_fingerprint(cfg, "latency", 0, 4)
    assert fp == dse.candidate_fingerprint(cfg, "latency", 0, 4)
    others = {
        dse.candidate_fingerprint(cfg, "onoff", 0, 4),
        dse.candidate_fingerprint(cfg, "latency", 1, 4),
        dse.candidate_fingerprint(cfg, "latency", 0, 5),
        dse.candidate_fingerprint(_cfg(8, 3, 16), "latency", 0, 4),
        dse.candidate_fingerprint(
            _cfg(8, 2, 16, 1.1), "latency", 0, 4
        ),
    }
    assert fp not in others and len(others) == 5


def test_journal_atomic_publish_and_guards(tmp_path):
    path = tmp_path / "run.jsonl"
    jr = dse.Journal(path)
    assert jr.load() == [] and jr.completed() == {}
    assert jr.begin({"seed": 0, "epochs": 1, "search": "grid"}, False) == {}
    jr.append([{"kind": "point", "fp": "aa", "rand_index": 0.5}])
    jr.append([{"kind": "failure", "fp": "bb", "stage": "fit"}])
    assert not os.path.exists(str(path) + ".tmp"), "publish must rename"
    assert set(dse.Journal(path).completed()) == {"aa", "bb"}

    # a fresh run must not clobber completed work
    with pytest.raises(ValueError, match="resume=True"):
        dse.Journal(path).begin(
            {"seed": 0, "epochs": 1, "search": "grid"}, False
        )
    # resuming under a different run configuration is an error
    with pytest.raises(ValueError, match="seed"):
        dse.Journal(path).begin(
            {"seed": 9, "epochs": 1, "search": "grid"}, True
        )
    got = dse.Journal(path).begin(
        {"seed": 0, "epochs": 1, "search": "grid"}, True
    )
    assert set(got) == {"aa", "bb"}

    # defensive read: a torn trailing line (non-atomic filesystem) is
    # skipped, never fatal
    with open(path, "a") as f:
        f.write('{"kind": "point", "fp": "cc", "rand_in')
    assert set(dse.Journal(path).completed()) == {"aa", "bb"}


def test_journal_torn_line_followed_by_valid_record(tmp_path):
    """A torn line with a valid record AFTER it (a non-atomic filesystem
    interleaving appends with a crash) loses only the torn record: later
    valid lines are kept, the header still validates, and resume picks up
    every intact evaluation."""
    path = tmp_path / "run.jsonl"
    meta = {
        "kind": "meta", "version": dse.journal.JOURNAL_VERSION,
        "seed": 0, "epochs": 1, "search": "grid",
    }
    good = {"kind": "point", "fp": "dd", "rand_index": 0.25}
    with open(path, "w") as f:
        f.write(json.dumps(meta) + "\n")
        f.write('{"kind": "point", "fp": "cc", "rand_in\n')  # torn
        f.write(json.dumps(good) + "\n")
    jr = dse.Journal(path)
    assert jr.load() == [meta, good]
    assert jr.completed() == {"dd": good}
    restored = jr.begin(
        {"seed": 0, "epochs": 1, "search": "grid"}, resume=True
    )
    assert set(restored) == {"dd"}
    # appending re-publishes atomically: the torn line is gone for good
    jr.append([{"kind": "point", "fp": "ee", "rand_index": 0.75}])
    assert set(dse.Journal(path).completed()) == {"dd", "ee"}
    raw = open(path).read()
    assert '"cc"' not in raw


def test_explore_resume_with_deleted_compile_cache_dir(tmp_path):
    """Journaled explorations default the persistent compilation cache to
    ``compile_cache/`` next to the journal.  Resuming with a matching
    meta-header after that directory vanished (cleaned scratch space)
    must repair the directory and reproduce the run, never fail."""
    import shutil

    x, y = _stream(n=10, seed=13)
    space = dse.DesignSpace(q=(2, 3), t_max=(16,))
    path = tmp_path / "dse.jsonl"
    cache_dir = tmp_path / "compile_cache"
    prev_dir = jax.config.jax_compilation_cache_dir
    prev_path = backend._compile_cache_path
    backend._compile_cache_path = None  # a fresh process picks the default
    try:
        full = dse.explore(x, y, space, epochs=1, seed=7, journal=str(path))
        assert backend.compile_cache_dir() == str(cache_dir)
        assert cache_dir.is_dir()
        shutil.rmtree(cache_dir)
        again = dse.explore(
            x, y, space, epochs=1, seed=7, journal=str(path), resume=True
        )
        assert cache_dir.is_dir(), "resume must repair the cache dir"
        assert again.meta["resumed"] == space.size()
        for a, b in zip(full.points, again.points):
            assert a.rand_index == b.rand_index
    finally:
        jax.config.update("jax_compilation_cache_dir", prev_dir)
        backend._compile_cache_path = prev_path


def test_explore_resume_skips_completed_and_is_bit_identical(tmp_path):
    x, y = _stream(n=12, seed=11)
    space = dse.DesignSpace(q=(2, 3), t_max=(16, 24))
    path = tmp_path / "dse.jsonl"
    full = dse.explore(x, y, space, epochs=1, seed=7, journal=str(path))
    assert full.meta["resumed"] == 0
    again = dse.explore(
        x, y, space, epochs=1, seed=7, journal=str(path), resume=True
    )
    assert again.meta["resumed"] == space.size()
    assert again.seconds < full.seconds  # nothing re-evaluated
    for a, b in zip(full.points, again.points):
        assert a.index == b.index and a.rand_index == b.rand_index
        assert a.area_um2 == b.area_um2 and a.leakage_uw == b.leakage_uw
        np.testing.assert_array_equal(
            np.asarray(a.params["w"]), np.asarray(b.params["w"])
        )
    assert [p.index for p in full.pareto] == [p.index for p in again.pareto]


def test_explore_resume_keeps_quarantine(tmp_path, monkeypatch):
    """A journaled failure stays quarantined on resume — the run never
    re-pays a known-degenerate evaluation."""
    x, y = _stream(seed=12)
    space = dse.DesignSpace(q=(2,), t_max=(16,), threshold_scale=(0.9, 1.2))
    path = tmp_path / "q.jsonl"
    poison = dse.candidate_config(
        space.grid()[1], x.shape[1]
    ).neuron.threshold
    _poisoning_patch(monkeypatch, poison)
    res = dse.explore(x, y, space, epochs=1, seed=8, journal=str(path))
    assert res.meta["quarantined"] == 1
    res2 = dse.explore(
        x, y, space, epochs=1, seed=8, journal=str(path), resume=True
    )
    assert res2.meta["resumed"] == space.size()
    (fail,) = res2.meta["failures"]
    assert fail["restored"] and fail["stage"] == "fit"
    assert [p.rand_index for p in res2.points] == [
        p.rand_index for p in res.points
    ]


def test_explore_sigkill_resume_reproduces_frontier(tmp_path):
    """Acceptance: a journaled explore run SIGKILLed mid-sweep, resumed
    with resume=True, reproduces the uninterrupted frontier exactly —
    losing at most one bucket of work (subprocess; the kill must take
    down a real process, not a pytest frame)."""
    path = tmp_path / "kill.jsonl"
    code = textwrap.dedent(f"""
        import os, signal
        import numpy as np
        from repro import dse

        class KillingJournal(dse.Journal):
            def append(self, records):
                super().append(records)
                os.kill(os.getpid(), signal.SIGKILL)  # die mid-run

        rng = np.random.default_rng(13)
        x = rng.normal(size=(12, 8)); y = rng.integers(0, 3, 12)
        space = dse.DesignSpace(q=(2, 3), t_max=(16, 64))
        dse.explore(x, y, space, epochs=1, seed=9,
                    journal=KillingJournal({str(path)!r}))
        raise SystemExit("unreachable: journal append must have killed us")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=dict(os.environ, PYTHONPATH="src"),
        timeout=600,
    )
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr[-2000:])
    with open(path) as f:
        recs = [json.loads(line) for line in f if line.strip()]
    n_done = sum(1 for rec in recs if rec["kind"] == "point")
    assert 1 <= n_done < 4, "the kill must land mid-run with partial work"

    rng = np.random.default_rng(13)
    x = rng.normal(size=(12, 8))
    y = rng.integers(0, 3, 12)
    space = dse.DesignSpace(q=(2, 3), t_max=(16, 64))
    resumed = dse.explore(
        x, y, space, epochs=1, seed=9, journal=str(path), resume=True
    )
    assert resumed.meta["resumed"] == n_done
    uninterrupted = dse.explore(x, y, space, epochs=1, seed=9)
    assert len(resumed.points) == len(uninterrupted.points) == 4
    for a, b in zip(uninterrupted.points, resumed.points):
        assert a.index == b.index
        assert a.rand_index == b.rand_index
        assert a.area_um2 == b.area_um2
        np.testing.assert_array_equal(
            np.asarray(a.params["w"]), np.asarray(b.params["w"])
        )
    assert [p.index for p in resumed.pareto] == [
        p.index for p in uninterrupted.pareto
    ]
