"""Cost-model contract tests (ISSUE 10).

Four guarantees:

  * the ExecutionPlan CONTRACT holds for every plan the chooser can emit
    (property-tested over randomized envelopes and synthetic devices):
    clamped ``v_blk``, lane-aligned ``t_blk``, ``shards`` dividing the
    design axis, sane waste cap;
  * the constants FALLBACK is exact — with no active profile every policy
    seam resolves to precisely the pre-costmodel hand-tuned constants
    (``backend.volley_block``, ``t_blk=128``, ``ENVELOPE_WASTE_CAP``);
  * a plan NEVER changes semantics — plan-chosen blocking and the
    constants blocking train bit-identical weights on both tracked bench
    geometries (blocking is a schedule, not math);
  * calibration records round-trip through disk and never activate on a
    mismatched host.

Tests never activate a profile implicitly: the autouse fixture restores
the active-profile state and keeps the cost terms analytic (the XLA
cost-analysis probe would trace+compile one real envelope per distinct
property-test shape).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import backend, simulator
from repro.core.types import ColumnConfig, NeuronConfig, TIME_DTYPE
from repro.roofline import costmodel

import jax.numpy as jnp


@pytest.fixture(autouse=True)
def _hermetic_costmodel(monkeypatch):
    """Restore the active profile after every test and keep the cost
    terms analytic — the XLA probe would compile one throwaway module
    per distinct property-example shape for numbers no contract here
    depends on."""
    prev = costmodel.profile()
    monkeypatch.setattr(
        costmodel, "envelope_cost",
        functools.partial(costmodel.envelope_cost.__wrapped__, use_xla=False)
        if hasattr(costmodel.envelope_cost, "__wrapped__")
        else functools.partial(costmodel.envelope_cost, use_xla=False),
    )
    costmodel._choose_plan_cached.cache_clear()
    yield
    costmodel.set_profile(prev)
    costmodel._choose_plan_cached.cache_clear()


def _synth_profile(**kw) -> costmodel.DeviceProfile:
    base = dict(
        name="synth", platform="cpu", device_kind="synth",
        peak_flops=5e10, hbm_bw=1e10, link_bw=1e10,
        dispatch_s=3e-5, compile_s=0.05, footprint_bytes=32 * 2**20,
        calibrated=True,
    )
    base.update(kw)
    return costmodel.DeviceProfile(**base)


# ------------------------------------------------------ plan contract
@settings(max_examples=60, deadline=None)
@given(
    d=st.integers(1, 16),
    p=st.integers(1, 512),
    q=st.integers(1, 64),
    t=st.integers(1, 512),
    n=st.integers(1, 1024),
    epochs=st.integers(1, 8),
    kind=st.sampled_from(["fit", "assign"]),
    lowering=st.sampled_from(["reference", "mosaic", "interpret"]),
    peak=st.floats(1e9, 1e15),
    bw=st.floats(1e8, 1e13),
    dispatch=st.floats(1e-7, 1e-3),
    compile_s=st.floats(1e-3, 10.0),
    footprint=st.floats(1e4, 1e9),
)
def test_any_plan_is_valid(
    d, p, q, t, n, epochs, kind, lowering, peak, bw, dispatch, compile_s,
    footprint,
):
    prof = _synth_profile(
        peak_flops=peak, hbm_bw=bw, link_bw=bw, dispatch_s=dispatch,
        compile_s=compile_s, footprint_bytes=footprint,
    )
    plan = costmodel.choose_plan(
        kind, lowering, d, p, q, t, n, epochs, prof=prof
    )
    assert costmodel.plan_is_valid(plan), plan
    assert plan.source == "costmodel"
    assert plan.profile == prof.name
    # the chooser never exceeds the hand-tuned upper bound: the warm
    # cliff past the constants base is a code-size effect outside the
    # roofline's sight
    cap = (
        costmodel.CONST_V_BLK_REFERENCE if lowering == "reference"
        else costmodel.CONST_V_BLK_KERNEL
    )
    assert plan.v_blk <= max(cap, 1)
    assert 1.5 <= plan.waste_cap <= 8.0
    # constants fallback obeys the same contract on the same inputs
    cplan = costmodel.constants_plan(kind, lowering, d, n, p, q, t)
    assert costmodel.plan_is_valid(cplan), cplan
    assert cplan.source == "constants"


def test_plan_is_hashable_and_deterministic():
    prof = _synth_profile()
    a = costmodel.choose_plan("fit", "reference", 4, 96, 10, 64, 64, 4,
                              prof=prof)
    b = costmodel.choose_plan("fit", "reference", 4, 96, 10, 64, 64, 4,
                              prof=prof)
    assert a == b and hash(a) == hash(b)
    assert {a: "plan"}[b] == "plan"  # usable as a jit static / memo key


# ------------------------------------------------- constants fallback
def test_constants_fallback_matches_legacy_policy():
    """With no active profile, every seam resolves to exactly the
    pre-costmodel constants."""
    assert costmodel.profile() is None or costmodel.set_profile(None) or True
    costmodel.set_profile(None)
    for lowering in ("reference", "mosaic"):
        for n in (1, 7, 64):
            for d in (1, 3, 4):
                plan = backend.execution_plan(
                    "fit", lowering, d, 96, 10, 64, n, 4
                )
                assert plan.source == "constants"
                assert plan.v_blk == backend.volley_block(lowering, n, d=d)
                assert plan.t_blk == backend.DEFAULT_T_BLK == 128
                assert plan.waste_cap == backend.ENVELOPE_WASTE_CAP
                assert plan.shards == backend.design_shards(d)
            aplan = backend.execution_plan(
                "assign", lowering, 4, 96, 10, 64, n, 1
            )
            # assign blocking historically ignored d (no unroll cap)
            assert aplan.v_blk == backend.volley_block(lowering, n)
    assert costmodel.choose_waste_cap() == backend.ENVELOPE_WASTE_CAP
    assert costmodel.choose_shards(4) == backend.design_shards(4)


def test_envelope_buckets_default_cap_unchanged():
    costmodel.set_profile(None)
    shapes = [(96, 2, 32), (96, 2, 32), (96, 10, 64), (96, 10, 64)]
    base = backend.envelope_buckets(shapes)
    hinted = backend.envelope_buckets(shapes, n_volleys=64, epochs=4)
    assert hinted == base  # no profile: the hint must not change policy


def test_waste_cap_with_profile_is_clamped_and_breaks_even():
    prof = _synth_profile()
    # a short stream cannot amortize a compile: the cap opens up (more
    # sharing); a long stream can: the cap tightens toward 1.5
    short = costmodel.choose_waste_cap(prof, 4, 96, 10, 64, n_volleys=1)
    long = costmodel.choose_waste_cap(
        prof, 4, 96, 10, 64, n_volleys=200_000, epochs=8
    )
    assert 1.5 <= long <= short <= 8.0


# ------------------------------------------------------- bit identity
# the two tracked bench geometries: the heterogeneous design sweep and
# the 2-layer network's fused layers (see benchmarks/train_bench.py)
_GEOMETRIES = (
    # (d, p, q_pad, t_window, q_actives, t_maxes)
    (4, 96, 10, 64, (5, 5, 10, 10), (32, 64, 32, 64)),   # sweep4x96p
    (4, 96, 8, 64, (8, 8, 8, 8), (64, 64, 64, 64)),      # net layer 0
    (1, 32, 5, 64, (5,), (64,)),                          # net layer 1
)


@pytest.mark.parametrize("geom", _GEOMETRIES)
def test_plan_blocking_is_bit_identical_to_constants(geom):
    d, p, q_pad, t_window, q_actives, t_maxes = geom
    B, epochs = 24, 2
    rng = np.random.default_rng(7)
    w0 = np.asarray(rng.integers(0, 8, (d, p, q_pad)), np.float32)
    xs = jnp.asarray(rng.integers(0, 32, (B, d, p)), TIME_DTYPE)
    thresholds = jnp.full((d,), p * 7 / 8.0, jnp.float32)
    tm = jnp.asarray(t_maxes, TIME_DTYPE)
    qa = jnp.asarray(q_actives, TIME_DTYPE)
    lowering = backend.padded_lowering("rnl")

    def fit():
        return np.asarray(backend.fit_padded(
            jnp.asarray(w0), xs, thresholds, tm, qa,
            t_window=t_window, w_max=7, wta_k=1,
            mu_capture=0.5, mu_backoff=-0.5, mu_search=0.1,
            stabilize=True, response="rnl", epochs=epochs,
            lowering=lowering,
        ))

    with costmodel.override(None):
        w_const = fit()
        const_plan = backend.execution_plan(
            "fit", lowering, d, p, q_pad, t_window, B, epochs
        )
    # low dispatch overhead puts the candidate blocks within the warm
    # tie tolerance, so the tie-break picks the cheapest trace (v_blk=2)
    # — a genuinely different schedule than the constants' 8 when d > 1
    prof = _synth_profile(dispatch_s=5e-6)
    with costmodel.override(prof):
        plan = backend.execution_plan(
            "fit", lowering, d, p, q_pad, t_window, B, epochs
        )
        w_plan = fit()
    assert plan.source == "costmodel"
    assert const_plan.source == "constants"
    # the schedules genuinely differ on at least the sweep geometry —
    # equality would make this test vacuous there
    if d > 1:
        assert plan.v_blk != const_plan.v_blk
    np.testing.assert_array_equal(w_plan, w_const)


# ------------------------------------------------------- persistence
def test_calibration_round_trip(tmp_path):
    path = str(tmp_path / "calibration.json")
    prof = _synth_profile(
        platform=jax.default_backend(),
        device_kind=jax.devices()[0].device_kind,
        n_devices=jax.local_device_count(),
    )
    assert costmodel.save_profile(prof, path) == path
    costmodel.set_profile(None)
    got = costmodel.load_profile(path)
    assert got == prof
    assert costmodel.profile() == prof  # load ACTIVATES


def test_calibration_rejects_mismatched_host(tmp_path):
    path = str(tmp_path / "calibration.json")
    alien = _synth_profile(platform="tpu", device_kind="TPU v99")
    costmodel.save_profile(alien, path)
    costmodel.set_profile(None)
    assert costmodel.load_profile(path) is None
    assert costmodel.profile() is None


def test_calibration_rejects_unknown_version(tmp_path):
    path = str(tmp_path / "calibration.json")
    prof = _synth_profile(
        platform=jax.default_backend(),
        device_kind=jax.devices()[0].device_kind,
        n_devices=jax.local_device_count(),
    )
    d = prof.to_json()
    d["version"] = costmodel.CALIBRATION_VERSION + 1
    import json

    (tmp_path / "calibration.json").write_text(json.dumps(d))
    assert costmodel.load_profile(path) is None


# -------------------------------------------------- consumer threading
def test_sweep_records_plan_metadata():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(16, 24))
    cfgs = []
    for q in (2, 3):
        c = ColumnConfig(p=24, q=q, t_max=16)
        cfgs.append(c.with_threshold(simulator.suggest_threshold(c)))
    res = simulator.cluster_time_series_many(x, None, cfgs, epochs=1)
    for r in res:
        assert r.plan is not None
        assert r.plan["kind"] == "fit"
        assert r.plan["source"] in ("constants", "costmodel")
        assert r.plan["v_blk"] >= 1


def test_service_surfaces_plans():
    from repro.serve.service import ClusteringService

    c = ColumnConfig(p=8, q=2, t_max=16)
    c = c.with_threshold(simulator.suggest_threshold(c))
    svc = ClusteringService({"d0": c}, batch_size=2, refit_every=4,
                           refit_window=4)
    stats = svc.stats()
    assert len(stats.plans) == len(svc.buckets())
    asg_meta, fit_meta = stats.plans[0]
    assert asg_meta["kind"] == "assign"
    assert fit_meta["kind"] == "fit"
    for b in svc.buckets():
        assert b["assign_plan"]["source"] in ("constants", "costmodel")
