"""Streaming clustering service — the ISSUE 8 serving pipeline.

Pins the serving contract stage by stage:

* steady state is COMPILE-FREE: after ``warmup()`` an arbitrary traffic
  mix (full batches, partial flushes, online re-fits) performs zero XLA
  compiles, counted at the ``compile_counter`` seam;
* the online re-fit is bit-identical to an offline ``backend.fit_padded``
  resume from the same weights on the same volleys — including ragged
  windows, where the silent-volley no-op carries the proof;
* served assignments are bit-identical to the single-design assignment
  entry (``simulator.assign_time_series``) — the cross-envelope padding
  contract, request by request;
* admission failures raise structured ``RequestRejected`` (no tracing),
  and a poisoned request quarantines ALONE: batch-mates of a failing
  batch re-run against the same executable and answer bit-identically;
* overload control sheds structurally (bounded queues, deadline
  budgets) BEFORE any JAX work, with per-reason counters in ``stats()``;
* a failing or stalling online re-fit degrades the bucket to serving
  from last-good weights — compile-free, request-failure-free — and the
  bucket recovers once re-fits succeed again (faults injected through
  the shared ``repro.testing.faults`` harness).

Durability (snapshot+WAL crash recovery) is pinned separately in
``test_serve_recovery.py``.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend, encoding, simulator
from repro.core.types import ColumnConfig, TIME_DTYPE
from repro.kernels import fused_column
from repro.serve import (
    ClusteringService,
    RequestRejected,
    ServeFailure,
    ServeResult,
    ServeShed,
)
from repro.testing import faults

P, T_MAX = 12, 16


def _cfg(q=4, t_max=T_MAX, p=P) -> ColumnConfig:
    c = ColumnConfig(p=p, q=q, t_max=t_max)
    return c.with_threshold(simulator.suggest_threshold(c))


def _fleet(n=4) -> dict:
    return {
        f"d{i}": _cfg(q=3 + (i % 2), t_max=T_MAX * (1 + (i // 2) % 2))
        for i in range(n)
    }


def _stream(rng, n):
    return [rng.normal(size=P) for _ in range(n)]


# ------------------------------------------------------------- pipeline
def test_serves_full_and_partial_batches():
    service = ClusteringService(_fleet(2), batch_size=4, refit_every=0)
    service.warmup()
    rng = np.random.default_rng(0)
    handles = [
        service.submit(s, f"d{i % 2}")
        for i, s in enumerate(_stream(rng, 6))
    ]
    # 4 submitted -> one auto-executed batch; 2 still queued
    assert [h.done for h in handles] == [True] * 4 + [False] * 2
    assert service.stats().pending == 2
    # result() on a queued request force-flushes its bucket (silent-padded
    # partial batch, same executable)
    res = handles[-1].result()
    assert isinstance(res, ServeResult)
    assert all(h.done for h in handles)
    stats = service.stats()
    assert stats.served == 6 and stats.pending == 0 and not stats.failed
    for h in handles:
        r = h.result()
        assert 0 <= r.cluster <= service._cfgs[r.design].q
        assert r.latency_s >= 0


def test_results_match_single_design_assignment_entry():
    """Bucket-batched serving answers == the D=1 assignment entry on the
    design's own envelope — the padding contract, request by request."""
    service = ClusteringService(_fleet(4), batch_size=4, refit_every=0,
                                seed=3, waste_cap=2.0)
    service.warmup()
    assert len(service.buckets()) >= 2  # tight cap splits the t_max pairs
    rng = np.random.default_rng(1)
    names = service.designs()
    cases = [(s, names[i % 4]) for i, s in enumerate(_stream(rng, 12))]
    handles = [service.submit(s, d) for s, d in cases]
    service.flush()
    for h, (s, d) in zip(handles, cases):
        expect = simulator.assign_time_series(
            s, service._cfgs[d], {"w": service.weights(d)}
        )
        assert h.result().cluster == int(expect)


def test_steady_state_is_compile_free(compile_counter):
    """The acceptance bar: after warmup, a traffic mix spanning full
    batches, partial flushes and online re-fits performs ZERO XLA
    compiles — one resident executable per (bucket, shape)."""
    service = ClusteringService(
        _fleet(4), batch_size=8, refit_every=16, refit_window=16, seed=0,
        waste_cap=2.0,  # two buckets: steady state spans both executables
    )
    service.warmup()
    assert compile_counter.compiles > 0  # warmup did the compiling
    base = compile_counter.compiles
    rng = np.random.default_rng(2)
    names = service.designs()
    handles = []
    for r in range(3):
        for s in range(24):
            handles.append(service.submit(
                rng.normal(size=P), names[s % len(names)]
            ))
        service.flush()  # partial batches ride the same executables
    stats = service.stats()
    assert stats.served == len(handles) and not stats.failed
    assert stats.refits >= 1  # re-fits happened inside the window
    assert compile_counter.compiles == base, (
        f"steady state compiled {compile_counter.compiles - base} "
        f"module(s): {compile_counter.names[base:]}"
    )


# -------------------------------------------------------------- re-fit
def test_online_refit_bit_identical_to_offline_resume():
    """Live re-fit == offline ``backend.fit_padded`` resume from the same
    weights on the same volleys (full window: shapes match exactly)."""
    cfg = _cfg()
    service = ClusteringService(
        {"d0": cfg}, batch_size=4, refit_every=8, refit_window=8, seed=7
    )
    service.warmup()
    w0 = service.weights("d0")  # silent warmup re-fit is a weight no-op
    rng = np.random.default_rng(3)
    series = _stream(rng, 8)
    for s in series:
        service.submit(s, "d0")
    assert service.stats().refits == 1

    enc = np.stack([
        np.asarray(encoding.encode(jnp.asarray(s), cfg.t_max))
        for s in series
    ])
    w_off = backend.fit_padded(
        jnp.asarray(w0[None]), jnp.asarray(enc[:, None, :], TIME_DTYPE),
        jnp.asarray([cfg.neuron.threshold], jnp.float32),
        jnp.asarray([cfg.t_max], TIME_DTYPE),
        jnp.asarray([cfg.q], TIME_DTYPE),
        t_window=cfg.t_max, w_max=cfg.neuron.w_max, wta_k=cfg.wta.k,
        mu_capture=cfg.stdp.mu_capture, mu_backoff=cfg.stdp.mu_backoff,
        mu_search=cfg.stdp.mu_search,
        stabilize=cfg.stdp.stabilizer == "half",
        response=cfg.neuron.response, epochs=1,
        lowering=backend.padded_lowering(cfg.neuron.response),
    )
    assert np.array_equal(service.weights("d0"), np.asarray(w_off[0]))


def test_ragged_refit_window_matches_unpadded_resume():
    """A re-fit window only partially filled (6 live volleys, window 8)
    trains bit-identically to an offline resume on the 6 volleys ALONE:
    the silent tail rows are exact weight no-ops above threshold 0."""
    cfg = _cfg()
    service = ClusteringService(
        {"d0": cfg}, batch_size=2, refit_every=6, refit_window=8, seed=11
    )
    service.warmup()
    w0 = service.weights("d0")
    rng = np.random.default_rng(5)
    series = _stream(rng, 6)
    for s in series:
        service.submit(s, "d0")
    assert service.stats().refits == 1

    enc = np.stack([
        np.asarray(encoding.encode(jnp.asarray(s), cfg.t_max))
        for s in series
    ])  # [6, p] — no padding on the offline side
    w_off = backend.fit_padded(
        jnp.asarray(w0[None]), jnp.asarray(enc[:, None, :], TIME_DTYPE),
        jnp.asarray([cfg.neuron.threshold], jnp.float32),
        jnp.asarray([cfg.t_max], TIME_DTYPE),
        jnp.asarray([cfg.q], TIME_DTYPE),
        t_window=cfg.t_max, w_max=cfg.neuron.w_max, wta_k=cfg.wta.k,
        mu_capture=cfg.stdp.mu_capture, mu_backoff=cfg.stdp.mu_backoff,
        mu_search=cfg.stdp.mu_search,
        stabilize=cfg.stdp.stabilizer == "half",
        response=cfg.neuron.response, epochs=1,
        lowering=backend.padded_lowering(cfg.neuron.response),
    )
    assert np.array_equal(service.weights("d0"), np.asarray(w_off[0]))


def test_refit_actually_learns():
    """The live weights move under traffic (the re-fit is not a no-op on
    real volleys) and keep serving afterwards."""
    service = ClusteringService(
        _fleet(1), batch_size=4, refit_every=4, refit_window=4, seed=2
    )
    service.warmup()
    w0 = service.weights("d0")
    rng = np.random.default_rng(9)
    for s in _stream(rng, 4):
        service.submit(s, "d0")
    assert service.stats().refits == 1
    assert not np.array_equal(service.weights("d0"), w0)
    h = service.submit(rng.normal(size=P), "d0")
    assert isinstance(h.result(), ServeResult)


# ----------------------------------------------------------- admission
def test_structured_rejection_without_tracing(compile_counter):
    """Admission failures raise structured RequestRejected BEFORE any JAX
    work — zero compiles, zero traces, and the service keeps serving."""
    service = ClusteringService(_fleet(2), batch_size=4, refit_every=0)
    service.warmup()
    base = compile_counter.compiles
    cases = [
        (np.zeros(P + 3), "d0", "envelope"),       # width fits no bucket
        (np.zeros(P), "nope", "unknown-design"),
        (np.zeros((2, P)), "d0", "shape"),
        (np.full(P, np.nan), "d0", "non-finite"),
    ]
    for series, design, reason in cases:
        with pytest.raises(RequestRejected) as ei:
            service.submit(series, design)
        assert ei.value.reason == reason
        assert ei.value.detail  # human-readable, machine-checkable
    assert compile_counter.compiles == base
    stats = service.stats()
    assert stats.rejected == len(cases)
    # per-reason counters: one rejection each, nothing double-counted
    assert stats.rejections == {
        "envelope": 1, "unknown-design": 1, "shape": 1, "non-finite": 1,
    }
    assert stats.offered == len(cases) and stats.submitted == 0
    h = service.submit(np.random.default_rng(0).normal(size=P), "d0")
    assert isinstance(h.result(), ServeResult)


def test_rejects_incompatible_fleets_at_construction():
    import dataclasses

    # threshold 0: silent-padding would stop being a weight no-op
    with pytest.raises(ValueError, match="threshold"):
        ClusteringService(
            {"bad": ColumnConfig(p=P, q=4, t_max=T_MAX).with_threshold(0.0)}
        )
    # mismatched statics cannot share one compiled program per bucket
    a = _cfg()
    b = dataclasses.replace(
        a, neuron=dataclasses.replace(a.neuron, w_max=a.neuron.w_max + 1)
    )
    with pytest.raises(ValueError, match="statics"):
        ClusteringService({"a": a, "b": b})
    with pytest.raises(ValueError, match="at least one design"):
        ClusteringService({})
    with pytest.raises(ValueError, match="encoder"):
        ClusteringService({"a": a}, encoder="morse")


# ------------------------------------------------------------ quarantine
def test_poisoned_request_quarantines_alone(monkeypatch):
    """A request that detonates the batch executable fails ALONE: every
    batch-mate re-runs against the same executable and answers
    bit-identically to an unpoisoned run."""
    cfg = _cfg()
    service = ClusteringService(
        {"d0": cfg}, batch_size=4, refit_every=0, seed=4
    )
    service.warmup()
    rng = np.random.default_rng(7)
    clean = _stream(rng, 3)
    expect = [
        int(simulator.assign_time_series(
            s, cfg, {"w": service.weights("d0")}
        ))
        for s in clean
    ]
    # the poison: a constant series encodes to an all-(t_max-1) volley —
    # distinctive, and never produced by the clean normal draws above
    poison = np.full(P, 2.5)
    poison_enc = np.asarray(encoding.encode(jnp.asarray(poison), cfg.t_max))

    # the instrumentation seam: backend.assign_padded honors a plain
    # callable in place of the jitted entry point (shared harness)
    monkeypatch.setattr(
        fused_column, "assign_padded",
        faults.fail_on_volley(fused_column.assign_padded, poison_enc),
    )

    handles = [service.submit(s, "d0") for s in clean]
    handles.append(service.submit(poison, "d0"))  # fills + detonates batch
    outcomes = [h.result() for h in handles]
    # batch-mates: bit-identical answers, served despite the poisoned mate
    for got, want in zip(outcomes[:3], expect):
        assert isinstance(got, ServeResult)
        assert got.cluster == want
    # the poison: quarantined as a structured failure
    assert isinstance(outcomes[3], ServeFailure)
    assert outcomes[3].stage == "assign"
    assert "poisoned" in outcomes[3].error
    stats = service.stats()
    assert stats.failed == 1 and stats.isolations == 1
    assert stats.served == 3 and stats.pending == 0


# ------------------------------------------------------ overload control
def test_overload_sheds_structured_with_retry_hint():
    """Beyond ``max_pending`` queued requests, admission sheds with
    ``reason='overloaded'`` and a retry-after hint — before any encode or
    JAX work — and capacity frees up again after a flush."""
    service = ClusteringService(
        _fleet(2), batch_size=8, refit_every=0, max_pending=3
    )
    service.warmup()
    rng = np.random.default_rng(0)
    for _ in range(3):
        service.submit(rng.normal(size=P), "d0")
    with pytest.raises(RequestRejected) as ei:
        service.submit(rng.normal(size=P), "d0")
    assert ei.value.reason == "overloaded"
    assert ei.value.retry_after_s is not None
    service.flush()
    h = service.submit(rng.normal(size=P), "d0")  # capacity is back
    assert isinstance(h.result(), ServeResult)
    stats = service.stats()
    assert stats.rejections == {"overloaded": 1}
    assert stats.offered == 5 and stats.submitted == 4 and stats.served == 4


def test_deadline_budget_sheds_at_dispatch_and_admission():
    """A request whose budget expires while queued is shed at dispatch (a
    ``ServeShed`` outcome, no JAX work); once a batch-time estimate
    exists, a budget below the predicted wait is rejected at admission."""
    service = ClusteringService(_fleet(2), batch_size=4, refit_every=0)
    service.warmup()
    rng = np.random.default_rng(1)
    # pre-traffic the wait estimate is 0, so admission is permissive
    h = service.submit(rng.normal(size=P), "d0", deadline_s=0.005)
    time.sleep(0.02)
    service.flush()
    shed = h.result()
    assert isinstance(shed, ServeShed)
    assert shed.reason == "deadline" and shed.waited_s >= 0.005
    # serve real traffic to establish the batch-time EWMA
    for _ in range(4):
        service.submit(rng.normal(size=P), "d0")
    assert service._batch_ewma is not None
    with pytest.raises(RequestRejected) as ei:
        service.submit(rng.normal(size=P), "d0", deadline_s=1e-12)
    assert ei.value.reason == "deadline"
    assert ei.value.retry_after_s > 0
    stats = service.stats()
    assert stats.shed == 1 and stats.rejections == {"deadline": 1}
    assert stats.served == 4 and not stats.failed


def test_drain_serves_inflight_then_stops_admission():
    service = ClusteringService(_fleet(2), batch_size=4, refit_every=0)
    service.warmup()
    rng = np.random.default_rng(2)
    handles = [service.submit(rng.normal(size=P), "d0") for _ in range(2)]
    assert not any(h.done for h in handles)  # queued behind a partial batch
    service.drain()
    assert all(isinstance(h.result(), ServeResult) for h in handles)
    with pytest.raises(RequestRejected) as ei:
        service.submit(rng.normal(size=P), "d0")
    assert ei.value.reason == "draining"
    stats = service.stats()
    assert stats.served == 2 and stats.pending == 0
    assert stats.rejections == {"draining": 1}


# ------------------------------------------------------- degraded re-fit
def test_refit_outage_degrades_to_last_good_compile_free(
    compile_counter, monkeypatch
):
    """The acceptance bar for degraded mode: with the re-fit path down
    hard, the service keeps answering from last-good weights — zero
    request failures, zero XLA compiles — and recovers (weights learning
    again) once the fault lifts."""
    service = ClusteringService(
        _fleet(2), batch_size=4, refit_every=4, refit_window=4, seed=0
    )
    service.warmup()
    w0 = {d: service.weights(d) for d in service.designs()}
    base = compile_counter.compiles
    rng = np.random.default_rng(3)
    with monkeypatch.context() as m:
        m.setattr(
            fused_column, "fit_scan_padded",
            faults.fail_always(detail="refit executable down"),
        )
        for _ in range(12):  # 3 re-fit windows under the outage
            service.submit(rng.normal(size=P), "d0")
            service.submit(rng.normal(size=P), "d1")
        service.flush()
    mid = service.stats()
    assert mid.served == 24 and not mid.failed  # every request answered
    assert mid.degraded == 1 and mid.refit_failures >= 1
    assert mid.refits == 0 and mid.recoveries == 0
    assert compile_counter.compiles == base  # no compile under the outage
    for d in service.designs():
        assert np.array_equal(service.weights(d), w0[d])  # last-good held

    # fault lifted: the backoff cooldown expires, a window commits, the
    # bucket recovers, and the weights move again
    for _ in range(16):
        service.submit(rng.normal(size=P), "d0")
        service.submit(rng.normal(size=P), "d1")
    service.flush()
    stats = service.stats()
    assert stats.recoveries == 1 and stats.degraded == 0
    assert stats.refits >= 1 and not stats.failed
    assert any(
        not np.array_equal(service.weights(d), w0[d])
        for d in service.designs()
    )
    assert compile_counter.compiles == base  # recovery reused executables


def test_nan_poisoned_refit_is_never_committed(monkeypatch):
    """A re-fit that 'succeeds' with NaN weights is rejected by the
    finite-weights guard — the live weights stay finite and last-good."""
    service = ClusteringService(
        _fleet(1), batch_size=4, refit_every=4, refit_window=4, seed=1
    )
    service.warmup()
    w0 = service.weights("d0")
    rng = np.random.default_rng(4)
    with monkeypatch.context() as m:
        m.setattr(
            fused_column, "fit_scan_padded",
            faults.nan_poison(fused_column.fit_scan_padded),
        )
        for s in _stream(rng, 4):
            service.submit(s, "d0")
    stats = service.stats()
    assert stats.refit_failures == 1 and stats.degraded == 1
    assert stats.refits == 0 and not stats.failed
    assert np.array_equal(service.weights("d0"), w0)
    assert np.isfinite(service.weights("d0")).all()


def test_refit_watchdog_discards_stalled_attempt(monkeypatch):
    """An attempt exceeding ``refit_budget_s`` is discarded as a stall
    (its result thrown away) even though it returned fine weights."""
    service = ClusteringService(
        _fleet(1), batch_size=4, refit_every=4, refit_window=4, seed=2,
        refit_budget_s=0.01,
    )
    service.warmup()
    w0 = service.weights("d0")
    rng = np.random.default_rng(5)
    with monkeypatch.context() as m:
        m.setattr(
            fused_column, "fit_scan_padded",
            faults.slow_call(fused_column.fit_scan_padded, 0.05),
        )
        for s in _stream(rng, 4):
            service.submit(s, "d0")
    stats = service.stats()
    assert stats.refit_stalls >= 1 and stats.refit_failures == 1
    assert stats.degraded == 1 and not stats.failed
    assert np.array_equal(service.weights("d0"), w0)


# ------------------------------------------------- seams used by serving
def test_pad_stream_silent_seam():
    xs = np.arange(12, dtype=np.int32).reshape(2, 2, 3)
    out = fused_column.pad_stream_silent(xs, 5, 99)
    assert out.shape == (5, 2, 3) and isinstance(out, np.ndarray)
    assert np.array_equal(out[:2], xs) and (out[2:] == 99).all()
    assert fused_column.pad_stream_silent(xs, 2, 99) is xs  # no-op path
    j = fused_column.pad_stream_silent(jnp.asarray(xs), 4, 7)
    assert j.shape == (4, 2, 3) and bool((np.asarray(j)[2:] == 7).all())
    with pytest.raises(ValueError, match="exceeds"):
        fused_column.pad_stream_silent(xs, 1, 99)


def test_warm_front_doors_make_dispatch_compile_free(compile_counter):
    """backend.warm_fit_padded / warm_assign_padded compile an envelope's
    executables with NO operands; the later operand-carrying front-door
    calls are then dispatch-only (key identity by construction)."""
    cfg = _cfg()
    kw = dict(
        t_window=cfg.t_max, wta_k=cfg.wta.k,
        response=cfg.neuron.response, lowering="reference",
    )
    assert backend.warm_assign_padded(
        1, cfg.p, cfg.q, 4, w_max=cfg.neuron.w_max, **kw
    ) in (False, True)
    assert backend.warm_assign_padded(  # second warm: already resident
        1, cfg.p, cfg.q, 4, w_max=cfg.neuron.w_max, **kw
    ) is True
    # operands built BEFORE the baseline: eager zeros/asarray ops compile
    # tiny modules of their own the first time a shape appears in-process,
    # and those are not what this test pins
    w0 = jnp.zeros((1, cfg.p, cfg.q))
    xs4 = jnp.zeros((4, 1, cfg.p), TIME_DTYPE)
    xs8 = jnp.zeros((8, 1, cfg.p), TIME_DTYPE)
    thr = jnp.asarray([cfg.neuron.threshold], jnp.float32)
    t_maxes = jnp.asarray([cfg.t_max], TIME_DTYPE)
    q_actives = jnp.asarray([cfg.q], TIME_DTYPE)
    base = compile_counter.compiles
    ids = backend.assign_padded(
        w0, xs4, thr, t_maxes, q_actives, w_max=cfg.neuron.w_max, **kw
    )
    assert ids.shape == (1, 4)
    assert compile_counter.compiles == base  # dispatch-only

    assert backend.warm_fit_padded(
        1, cfg.p, cfg.q, 8, t_window=cfg.t_max, w_max=cfg.neuron.w_max,
        wta_k=cfg.wta.k, stabilize=False, response=cfg.neuron.response,
        epochs=1, lowering="reference",
    ) in (False, True)
    base = compile_counter.compiles
    w = backend.fit_padded(
        w0, xs8, thr, t_maxes, q_actives,
        t_window=cfg.t_max, w_max=cfg.neuron.w_max, wta_k=cfg.wta.k,
        mu_capture=cfg.stdp.mu_capture, mu_backoff=cfg.stdp.mu_backoff,
        mu_search=cfg.stdp.mu_search, stabilize=False,
        response=cfg.neuron.response, epochs=1, lowering="reference",
    )
    assert w.shape == (1, cfg.p, cfg.q)
    assert compile_counter.compiles == base  # dispatch-only


def test_assign_time_series_single_and_micro_batch():
    cfg = _cfg()
    rng = np.random.default_rng(6)
    params = {"w": rng.integers(0, cfg.neuron.w_max + 1, (cfg.p, cfg.q))}
    batch = rng.normal(size=(5, P))
    ids = simulator.assign_time_series(batch, cfg, params)
    assert ids.shape == (5,)
    assert ((0 <= ids) & (ids <= cfg.q)).all()
    for i in range(5):
        one = simulator.assign_time_series(batch[i], cfg, params)
        assert int(one) == int(ids[i])  # micro-batch == single requests
