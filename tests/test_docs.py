"""Docs and tooling stay truthful (ISSUE 3 CI satellite).

Two cheap guards wired into the tier-1 run:

  * every relative markdown link / file reference in ``README.md`` and
    ``docs/*.md`` must resolve to a real file in the repo — kernel/backend
    contracts live in prose now, and a dangling cross-link is doc rot;
  * ``benchmarks/run.py --check`` must exit zero, so the reproduction
    commands the README documents cannot silently lose an import.
"""
from __future__ import annotations

import pathlib
import re
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent

# [text](target) markdown links; targets split from any #fragment below
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# `path/to/file.ext` backtick references that look like repo files
_TICK = re.compile(
    r"`([A-Za-z0-9_\-./]+\.(?:py|md|json|sh|txt|yaml|yml|toml))`"
)
_EXTERNAL = ("http://", "https://", "mailto:")


def doc_files() -> list[pathlib.Path]:
    docs = [REPO / "README.md"]
    docs += sorted((REPO / "docs").glob("*.md"))
    assert docs and all(d.exists() for d in docs)
    return docs


def _resolve(doc: pathlib.Path, target: str) -> bool:
    """A doc target may be relative to the doc's directory or repo-rooted."""
    target = target.split("#", 1)[0]
    if not target:
        return True  # pure-fragment link into the same document
    return (doc.parent / target).exists() or (REPO / target).exists()


@pytest.mark.parametrize("doc", doc_files(), ids=lambda d: d.name)
def test_markdown_links_resolve(doc: pathlib.Path):
    text = doc.read_text()
    broken = []
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(_EXTERNAL):
            continue
        if not _resolve(doc, target):
            broken.append(target)
    assert not broken, f"{doc.relative_to(REPO)}: broken links {broken}"


@pytest.mark.parametrize("doc", doc_files(), ids=lambda d: d.name)
def test_backtick_file_references_resolve(doc: pathlib.Path):
    """`path.py`-style references must point at real files; module paths
    with no directory part (e.g. `conftest.py` in prose) only need to exist
    somewhere under the repo."""
    text = doc.read_text()
    broken = []
    for m in _TICK.finditer(text):
        target = m.group(1)
        if "/" in target:
            if not _resolve(doc, target):
                broken.append(target)
        elif not (
            (doc.parent / target).exists()
            or (REPO / target).exists()
            or list(REPO.glob(f"**/{target}"))
        ):
            broken.append(target)
    assert not broken, f"{doc.relative_to(REPO)}: dangling file refs {broken}"


def test_benchmarks_import_check_passes():
    """README's reproduction commands depend on every registered benchmark
    importing; --check exits nonzero on import rot."""
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--check"],
        cwd=REPO,
        env={
            "PYTHONPATH": str(REPO / "src"),
            "PATH": "/usr/bin:/bin:/usr/local/bin",
            "HOME": str(pathlib.Path.home()),
        },
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, (
        f"benchmarks.run --check failed:\n{proc.stdout}\n{proc.stderr}"
    )
    assert "import cleanly" in proc.stdout
