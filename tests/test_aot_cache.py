"""AOT-compiled, persistently cached envelope traces.

The cold-compile contract (see ``docs/backends.md``):

  * ``backend.fit_padded`` / ``backend.assign_padded`` — the
    envelope-keyed AOT dispatchers over
    ``fused_column.precompile_fit_scan_padded`` /
    ``precompile_assign_padded`` — are bit-identical to calling the
    jitted entry points directly;
  * equal envelopes share ONE compiled executable however the operand
    *values* differ (the cache keys on shapes + statics, never on
    weights/volleys/thresholds), and the shared executable still
    computes per-design results;
  * ``backend.compile_cache(dir)`` makes compilation a cross-process,
    one-time cost: a second process against a populated cache compiles
    ZERO modules and reproduces the first process's results bit for bit
    (sha256 over the raw result bytes — Python ``hash()`` is
    process-randomized and useless here);
  * an unusable cache directory degrades gracefully (RuntimeWarning,
    uncached execution), and a deleted cache dir is recreated on
    re-enable, so a resumed DSE run with a vanished cache keeps going.
"""
import json
import os
import shutil
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend
from repro.core.types import TIME_DTYPE
from repro.kernels import fused_column

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _batch(seed=0, d=2, p=19, q=4, t_window=21, n=6):
    """A small heterogeneous padded batch with test-unique geometry."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.integers(0, 8, (d, p, q)), jnp.float32)
    xs = jnp.asarray(rng.integers(0, t_window, (n, d, p)), TIME_DTYPE)
    th = jnp.asarray(rng.uniform(3.0, 8.0, (d,)), jnp.float32)
    tm = jnp.asarray(rng.integers(t_window // 2, t_window + 1, (d,)),
                     TIME_DTYPE)
    qa = jnp.asarray(rng.integers(1, q + 1, (d,)), TIME_DTYPE)
    return w, xs, th, tm, qa


def _fit_kw(t_window=21, **over):
    kw = dict(
        t_window=t_window, w_max=7, wta_k=1, mu_capture=1.0,
        mu_backoff=1.0, mu_search=1.0, stabilize=False, response="rnl",
        epochs=2, lowering="reference",
    )
    kw.update(over)
    return kw


@pytest.fixture
def restore_cache_config():
    """Snapshot/restore the global persistent-cache state around tests
    that call ``backend.compile_cache`` for real."""
    prev_dir = jax.config.jax_compilation_cache_dir
    prev_path = backend._compile_cache_path
    yield
    jax.config.update("jax_compilation_cache_dir", prev_dir)
    backend._compile_cache_path = prev_path


# --------------------------------------------- AOT vs jit bit-identity
def test_fit_and_assign_dispatchers_bit_identical_to_jit_path():
    w, xs, th, tm, qa = _batch(seed=1)
    kw = _fit_kw()
    # fresh weight buffers: the fit scan donates its first argument
    w_jit = fused_column.fit_scan_padded(jnp.array(w), xs, th, tm, qa, **kw)
    w_aot = backend.fit_padded(jnp.array(w), xs, th, tm, qa, **kw)
    np.testing.assert_array_equal(np.asarray(w_jit), np.asarray(w_aot))
    akw = dict(t_window=21, wta_k=1, response="rnl", lowering="reference")
    ids_jit = fused_column.assign_padded(w_jit, xs, th, tm, qa, **akw)
    ids_aot = backend.assign_padded(w_aot, xs, th, tm, qa, **akw)
    np.testing.assert_array_equal(np.asarray(ids_jit), np.asarray(ids_aot))


def test_precompile_needs_no_operands_and_matches_warm_call():
    """The ISSUE's precompile contract: an executable built from shapes
    alone (``jit(...).lower().compile()``) is the very program the jit
    path runs — a service can compile its envelope set before any data
    exists."""
    w, xs, th, tm, qa = _batch(seed=2, d=3, p=17, q=3, t_window=19, n=5)
    kw = _fit_kw(t_window=19)
    exe = fused_column.precompile_fit_scan_padded(
        3, 17, 3, 5, t_window=19, w_max=7, wta_k=1, stabilize=False,
        response="rnl", epochs=2, lowering="reference",
    )
    got = exe(
        jnp.array(w), xs, th, tm, qa,
        mu_capture=jnp.float32(1.0), mu_backoff=jnp.float32(1.0),
        mu_search=jnp.float32(1.0),
    )
    want = fused_column.fit_scan_padded(jnp.array(w), xs, th, tm, qa, **kw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    aexe = fused_column.precompile_assign_padded(
        3, 17, 3, 5, t_window=19, wta_k=1, response="rnl",
        lowering="reference",
    )
    np.testing.assert_array_equal(
        np.asarray(aexe(want, xs, th, tm, qa)),
        np.asarray(fused_column.assign_padded(
            want, xs, th, tm, qa, t_window=19, wta_k=1, response="rnl",
            lowering="reference",
        )),
    )


# ----------------------------------------------- envelope cache keying
def test_equal_envelopes_share_one_executable_but_not_results(
    compile_counter,
):
    """Cache-key collision test: two batches with equal envelopes but
    different runtime operands hit ONE executable (a single backend
    compile, a single AOT cache entry) and still diverge numerically —
    the cache keys programs, never values."""
    kw = _fit_kw(t_window=23)
    w1, xs1, th1, tm1, qa1 = _batch(seed=3, d=2, p=23, q=3, t_window=23)
    w2, xs2, th2, tm2, qa2 = _batch(seed=4, d=2, p=23, q=3, t_window=23)
    backend.aot_cache_clear()
    r1 = backend.fit_padded(w1, xs1, th1, tm1, qa1, **kw)
    grown = backend.aot_cache_size()
    r2 = backend.fit_padded(w2, xs2, th2, tm2, qa2, **kw)
    assert backend.aot_cache_size() == grown == 1
    assert compile_counter.named("fit_scan_padded") == 1, (
        "the second equal-envelope batch must reuse the first executable"
    )
    assert not np.array_equal(np.asarray(r1), np.asarray(r2)), (
        "shared executable, divergent operands -> divergent results"
    )
    # a different envelope (v_blk via a different N) is a new executable
    w3, xs3, th3, tm3, qa3 = _batch(seed=3, d=2, p=23, q=3, t_window=23,
                                    n=9)
    backend.fit_padded(w3, xs3, th3, tm3, qa3, **kw)
    assert backend.aot_cache_size() == 2


# ------------------------------------------- persistent cache round-trip
_CHILD = textwrap.dedent("""
    import json, hashlib, sys
    import numpy as np
    from jax._src import compiler as _compiler

    counts = {"n": 0, "names": []}
    _orig = _compiler.backend_compile
    def _spy(backend, module, *a, **k):
        counts["n"] += 1
        try:
            counts["names"].append(str(module.operation.attributes["sym_name"]))
        except Exception:
            counts["names"].append("")
        return _orig(backend, module, *a, **k)
    _compiler.backend_compile = _spy

    import jax.numpy as jnp
    from repro.core import backend
    from repro.core.types import TIME_DTYPE

    assert backend.compile_cache(sys.argv[1]) is not None
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.integers(0, 8, (2, 27, 3)), jnp.float32)
    xs = jnp.asarray(rng.integers(0, 18, (5, 2, 27)), TIME_DTYPE)
    th = jnp.asarray([6.0, 4.0], jnp.float32)
    tm = jnp.asarray([18, 14], TIME_DTYPE)
    qa = jnp.asarray([3, 2], TIME_DTYPE)
    w2 = backend.fit_padded(
        w, xs, th, tm, qa, t_window=18, w_max=7, wta_k=1, mu_capture=1.0,
        mu_backoff=1.0, mu_search=1.0, stabilize=False, response="rnl",
        epochs=2, lowering="reference",
    )
    ids = backend.assign_padded(
        w2, xs, th, tm, qa, t_window=18, wta_k=1, response="rnl",
        lowering="reference",
    )
    print(json.dumps({
        "compiles": counts["n"],
        "fit_compiles": sum(1 for n in counts["names"]
                            if "fit_scan_padded" in n),
        "digest": hashlib.sha256(
            np.asarray(w2).tobytes() + np.asarray(ids).tobytes()
        ).hexdigest(),
    }))
""")


def _run_child(cache_dir: str) -> dict:
    env = dict(os.environ, PYTHONPATH="src")
    # the child owns its cache dir; a CI-level cache must not leak in
    env.pop("REPRO_COMPILE_CACHE", None)
    r = subprocess.run(
        [sys.executable, "-c", _CHILD, cache_dir],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_second_process_compiles_zero_envelope_traces(tmp_path):
    """The tentpole acceptance: with a populated persistent cache, a
    fresh process compiles NOTHING — not the envelope traces, not the
    helper modules — and its results are bit-identical to the process
    that paid the compile."""
    cache = str(tmp_path / "compile_cache")
    first = _run_child(cache)
    assert first["fit_compiles"] == 1, first
    assert first["compiles"] >= 1
    second = _run_child(cache)
    assert second["compiles"] == 0, (
        f"second process recompiled {second['compiles']} modules with a "
        "populated persistent cache"
    )
    assert second["digest"] == first["digest"], (
        "cached executables must reproduce the original results bit for "
        "bit"
    )


# ------------------------------------------------------ graceful fallback
def test_unusable_cache_dir_warns_and_runs_uncached(restore_cache_config):
    """A cache path that cannot be a directory (here: nested under a
    regular file) must degrade to uncached execution, not break the run.
    (A chmod-based read-only probe is useless in rootful CI containers —
    root writes anywhere — so the unusable path IS the fallback case.)"""
    probe_file = os.path.join(REPO, "README.md")
    with pytest.warns(RuntimeWarning, match="compilation cache disabled"):
        assert backend.compile_cache(
            os.path.join(probe_file, "sub")
        ) is None
    # compilation still works, just in-process
    w, xs, th, tm, qa = _batch(seed=5, d=2, p=13, q=3, t_window=15, n=4)
    out = backend.fit_padded(w, xs, th, tm, qa, **_fit_kw(t_window=15))
    assert np.isfinite(np.asarray(out)).all()


def test_deleted_cache_dir_is_recreated(tmp_path, restore_cache_config):
    """Re-enabling after the directory vanished (the resumed-DSE case)
    repairs it instead of failing."""
    d = str(tmp_path / "cache")
    assert backend.compile_cache(d) == d
    assert backend.compile_cache_dir() == d
    shutil.rmtree(d)
    assert backend.compile_cache(d) == d
    assert os.path.isdir(d)
