"""Clustering substrate: rand index, k-means, DTCR baseline, UCR data."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.clustering.dtcr import DTCRConfig, fit_predict
from repro.clustering.kmeans import kmeans
from repro.clustering.metrics import normalized_rand, rand_index
from repro.data import ucr


def test_rand_index_identical_labelings():
    y = np.array([0, 0, 1, 1, 2])
    assert rand_index(y, y) == 1.0
    assert rand_index(y, y[::-1] * 0 + np.array([2, 2, 0, 0, 1])) == 1.0  # relabel


def test_rand_index_known_value():
    # classic example: RI between these two partitions of 6 points
    a = np.array([0, 0, 0, 1, 1, 1])
    b = np.array([0, 0, 1, 1, 2, 2])
    # pairs agreeing: compute by brute force
    n = len(a)
    agree = sum(
        (a[i] == a[j]) == (b[i] == b[j])
        for i in range(n) for j in range(i + 1, n)
    )
    assert abs(rand_index(a, b) - agree / (n * (n - 1) / 2)) < 1e-12


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(4, 40),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_rand_index_bounds_and_symmetry(n, k, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, k, n)
    b = rng.integers(0, k, n)
    ri = rand_index(a, b)
    assert 0.0 <= ri <= 1.0
    assert abs(ri - rand_index(b, a)) < 1e-12


def test_kmeans_recovers_separated_clusters():
    rng = np.random.default_rng(0)
    x = np.concatenate([
        rng.normal(0, 0.2, (40, 4)), rng.normal(5, 0.2, (40, 4))
    ])
    y = np.array([0] * 40 + [1] * 40)
    _, labels = kmeans(x, 2, seed=0)
    assert rand_index(y, labels) > 0.95


def test_dtcr_runs_and_beats_chance_on_easy_data():
    rng = np.random.default_rng(1)
    t = np.linspace(0, 1, 32)
    xs = [np.sin(2 * np.pi * 3 * t) + rng.normal(0, 0.2, 32) for _ in range(20)]
    xs += [np.sign(np.sin(2 * np.pi * 1 * t)) + rng.normal(0, 0.2, 32) for _ in range(20)]
    x = np.stack(xs)
    y = np.array([0] * 20 + [1] * 20)
    labels = fit_predict(x, DTCRConfig(n_clusters=2, steps=40, hidden=16))
    assert rand_index(y, labels) > 0.55


def test_ucr_synthetic_doubles_match_table_geometry():
    for name, meta in ucr.BENCHMARKS.items():
        ds = ucr.make_synthetic(name)
        assert ds.x.shape[1] == meta["length"], name
        assert ds.n_classes == meta["classes"], name
        p, q = ucr.PAPER_COLUMNS[name]
        assert p == meta["length"] and q == meta["classes"], name


def test_ucr_synthetic_deterministic():
    a = ucr.make_synthetic("Beef", seed=3)
    b = ucr.make_synthetic("Beef", seed=3)
    np.testing.assert_array_equal(a.x, b.x)


def test_normalized_rand():
    assert normalized_rand(0.6, 0.8) == pytest.approx(0.75)
