"""Pallas flash-attention kernel vs the pure-jnp online-softmax oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.layers import chunked_attention


def _oracle(q, k, v, causal):
    B, S, H, d = q.shape
    return chunked_attention(
        q.reshape(B, S, H, 1, d), k, v, causal=causal, kv_chunk=64
    ).reshape(B, S, H, d)


@pytest.mark.parametrize(
    "B,S,H,d,causal",
    [
        (2, 256, 4, 64, True),
        (1, 128, 2, 32, False),
        (2, 384, 3, 128, True),
        (1, 512, 1, 64, True),
    ],
)
def test_flash_matches_oracle(B, S, H, d, causal):
    rng = np.random.default_rng(B * S + H)
    q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    got = np.asarray(flash_attention_pallas(q, k, v, causal=causal))
    want = np.asarray(_oracle(q, k, v, causal))
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 2e-5, err


@settings(max_examples=6, deadline=None)
@given(
    s_blocks=st.integers(1, 3),
    h=st.integers(1, 3),
    d=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_property(s_blocks, h, d, seed):
    rng = np.random.default_rng(seed)
    S = 128 * s_blocks
    q = jnp.asarray(rng.normal(size=(1, S, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, S, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, S, h, d)), jnp.float32)
    got = np.asarray(flash_attention_pallas(q, k, v, causal=True))
    want = np.asarray(_oracle(q, k, v, True))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_flash_bf16_io():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.bfloat16)
    got = np.asarray(flash_attention_pallas(q, k, v), np.float32)
    want = np.asarray(_oracle(q, k, v, True), np.float32)
    err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 2e-2  # bf16 I/O tolerance
