"""Multi-layer fused training path (ISSUE 2 acceptance).

The contract under test:
  * ``network.fit_greedy`` resolves the backend per layer through
    ``backend.resolve`` — same knob semantics as columns;
  * on integer weights, 'pallas', 'cycle', 'event' and 'auto' produce
    BIT-IDENTICAL network outputs and matching weights for a 2-layer net;
  * the fused layer scan compiles once per distinct layer shape (layers
    sharing a padded-envelope shape share one trace) and refits recompile
    nothing;
  * non-fusable layers (LIF, stochastic STDP) train on the solver scan
    under 'auto', and forcing mode='pallas' on them raises;
  * ``simulator.cluster_time_series_network`` plugs networks into the same
    encode -> fit -> assign -> rand-index loop as columns.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend, network, simulator
from repro.core.types import (
    ColumnConfig, LayerConfig, NetworkConfig, NeuronConfig, STDPConfig,
)
from repro.kernels import fused_column


def int_col(p, q, t_max, threshold):
    """Column whose expected-STDP updates keep weights on the integer grid."""
    return ColumnConfig(
        p=p, q=q, t_max=t_max,
        neuron=NeuronConfig(threshold=threshold, w_max=7),
        stdp=STDPConfig(
            mu_capture=1.0, mu_backoff=1.0, mu_search=1.0, stabilizer="none"
        ),
    )


def two_layer_net(t_max=16):
    return NetworkConfig(layers=(
        LayerConfig(columns=2, column=int_col(8, 4, t_max, 5.0)),
        LayerConfig(columns=1, column=int_col(8, 2, t_max, 4.0)),
    ))


def int_net_data(net, in_width, n=10, seed=0):
    rng = np.random.default_rng(seed)
    params = [
        {
            "w": jnp.asarray(
                rng.integers(
                    0, l.column.neuron.w_max + 1,
                    (l.columns, l.column.p, l.column.q),
                ),
                jnp.float32,
            )
        }
        for l in net.layers
    ]
    x = jnp.asarray(rng.integers(0, 20, (n, in_width)), jnp.int32)
    return params, x


def test_network_backends_bit_identical_on_integer_weights():
    """Acceptance: fit_greedy firing times bit-identical across backends."""
    net = two_layer_net()
    params, x = int_net_data(net, in_width=8)
    outs = {}
    for mode in ("pallas", "cycle", "event", "auto"):
        trained = network.fit_greedy(params, x, net, epochs=3, mode=mode)
        # compare on a fixed forward so only training differs between modes
        y = network.apply(trained, x, net, "cycle")
        outs[mode] = (np.asarray(y), [np.asarray(p["w"]) for p in trained])
    for mode in ("cycle", "event", "auto"):
        np.testing.assert_array_equal(
            outs["pallas"][0], outs[mode][0],
            err_msg=f"network firing times diverge: pallas vs {mode}",
        )
        for li, (a, b) in enumerate(zip(outs["pallas"][1], outs[mode][1])):
            np.testing.assert_allclose(
                a, b, rtol=1e-6, atol=1e-6,
                err_msg=f"layer {li} weights diverge: pallas vs {mode}",
            )


def test_network_fit_compiles_once_per_layer_shape(compile_counter):
    """Layers padded to the same envelope shape share ONE compiled scan;
    refitting the same network recompiles nothing.

    Counted at the ``backend_compile`` seam (``compile_counter``), not via
    ``_cache_size()``: the network routes through ``backend.fit_padded``'s
    AOT executable cache, which never touches the jit trace cache.
    """
    # unique geometry (t_max=18) so this test owns its envelope keys:
    # layers 0 and 1 both vmap 2 columns in the (p=10, q=3, 18) envelope
    # -> one shared executable; layer 2 (1 column) -> a second one.
    net = NetworkConfig(layers=(
        LayerConfig(columns=2, column=int_col(10, 3, 18, 5.0)),
        LayerConfig(columns=2, column=int_col(6, 3, 18, 4.0)),
        LayerConfig(columns=1, column=int_col(6, 2, 18, 4.0)),
    ))
    params, x = int_net_data(net, in_width=10, n=9, seed=1)
    for layer in net.layers:
        assert backend.resolve("auto", layer.column, training=True) == "pallas"
    backend.aot_cache_clear()
    trained = network.fit_greedy(params, x, net, epochs=4, mode="auto")
    after_first = compile_counter.named("fit_scan_padded")
    assert after_first == 2, (
        "3 layers / 2 distinct padded shapes must compile exactly 2 scans"
    )
    network.fit_greedy(params, x, net, epochs=4, mode="auto")
    assert compile_counter.named("fit_scan_padded") == after_first, (
        "refit must not recompile"
    )
    assert trained[0]["w"].shape == (2, 10, 3)
    assert trained[2]["w"].shape == (1, 6, 2)


def test_validate_rejects_growing_t_max():
    """A larger downstream window would read the upstream no-spike sentinel
    as a live spike; validate must refuse loudly."""
    net = NetworkConfig(layers=(
        LayerConfig(columns=2, column=int_col(8, 4, 16, 5.0)),
        LayerConfig(columns=1, column=int_col(8, 2, 32, 4.0)),
    ))
    with pytest.raises(ValueError, match="alias"):
        network.validate(net, in_width=8)
    params, x = int_net_data(two_layer_net(), in_width=8)
    with pytest.raises(ValueError, match="alias"):
        network.fit_greedy(params, x, net, epochs=1)
    with pytest.raises(ValueError, match="alias"):  # inference guards too
        network.cluster_assignments(params, x, net)
    # shrinking windows are legal (late spikes fall outside the window)
    shrink = NetworkConfig(layers=(
        LayerConfig(columns=2, column=int_col(8, 4, 32, 5.0)),
        LayerConfig(columns=1, column=int_col(8, 2, 16, 4.0)),
    ))
    network.validate(shrink, in_width=8)


def test_envelope_waste_cap_splits_mismatched_layers():
    """A tiny layer must not ride a huge layer's padding envelope: sharing
    saves one compile, padded FLOPs recur every volley."""
    big = LayerConfig(columns=1, column=int_col(64, 4, 24, 9.0))
    small = LayerConfig(columns=1, column=int_col(4, 2, 24, 3.0))
    envs = network._fused_envelopes([big, small])
    assert envs[0] == (64, 4, 24)
    assert envs[1] == (4, 2, 24), "mismatched layer must keep its own shape"
    # close sizes DO share (the compile-once test's premise)
    near = LayerConfig(columns=1, column=int_col(48, 4, 24, 8.0))
    envs2 = network._fused_envelopes([big, near])
    assert envs2[0] == envs2[1] == (64, 4, 24)


def test_network_resolves_per_layer_and_rejects_bad_pallas():
    """'auto' routes each layer by its own config; forcing 'pallas' on a
    non-fusable layer raises instead of silently switching semantics."""
    lif_col = ColumnConfig(
        p=8, q=2, t_max=16,
        neuron=NeuronConfig(response="lif", threshold=5.0),
    )
    mixed = NetworkConfig(layers=(
        LayerConfig(columns=2, column=int_col(8, 4, 16, 5.0)),
        LayerConfig(columns=1, column=lif_col),
    ))
    assert backend.resolve("auto", mixed.layers[0].column, training=True) == "pallas"
    assert backend.resolve("auto", mixed.layers[1].column, training=True) == "cycle"
    params, x = int_net_data(mixed, in_width=8, n=6, seed=2)
    trained = network.fit_greedy(params, x, mixed, epochs=2, mode="auto")
    moved = sum(
        float(jnp.abs(t["w"] - p["w"]).sum())
        for t, p in zip(trained, params)
    )
    assert moved > 0, "mixed fused/solver network must still learn"
    with pytest.raises(ValueError):
        network.fit_greedy(params, x, mixed, epochs=2, mode="pallas")


def test_network_solver_layer_handles_stochastic_stdp():
    """The solver layer scan carries the config surface the fused step
    rejects (stochastic STDP needs per-volley PRNG plumbing per column)."""
    col = ColumnConfig(
        p=6, q=3, t_max=16,
        neuron=NeuronConfig(threshold=4.0),
        stdp=STDPConfig(mode="stochastic"),
    )
    net = NetworkConfig(layers=(LayerConfig(columns=2, column=col),))
    assert backend.resolve("auto", col, training=True) == "event"
    params, x = int_net_data(net, in_width=6, n=5, seed=3)
    t1 = network.fit_greedy(params, x, net, epochs=2, rng=jax.random.key(7))
    t2 = network.fit_greedy(params, x, net, epochs=2, rng=jax.random.key(7))
    np.testing.assert_array_equal(
        np.asarray(t1[0]["w"]), np.asarray(t2[0]["w"]),
        err_msg="same PRNG key must reproduce stochastic training exactly",
    )
    # no key may not be silently replaced by a fixed one (column parity)
    with pytest.raises(ValueError, match="PRNG key"):
        network.fit_greedy(params, x, net, epochs=1)


def test_network_cluster_assignments_unclustered_bucket():
    net = two_layer_net()
    params, x = int_net_data(net, in_width=8, n=4, seed=4)
    a = np.asarray(network.cluster_assignments(params, x, net))
    assert a.shape == (4,)
    assert np.all((a >= 0) & (a <= network.out_width(net)))
    # silence the net: zero weights never cross threshold -> all unclustered
    dead = [{"w": jnp.zeros_like(p["w"])} for p in params]
    a0 = np.asarray(network.cluster_assignments(dead, x, net))
    np.testing.assert_array_equal(
        a0, np.full(4, network.out_width(net))
    )


def test_cluster_time_series_network_end_to_end():
    """Networks plug into the same clustering/rand-index loop as columns,
    and the run is seed-reproducible."""
    net = NetworkConfig(layers=(
        LayerConfig(columns=2, column=int_col(14, 3, 20, 5.0)),
        LayerConfig(columns=1, column=int_col(6, 2, 20, 4.0)),
    ))
    rng = np.random.default_rng(5)
    series = rng.normal(size=(12, 14))
    labels = rng.integers(0, 2, 12)
    res = simulator.cluster_time_series_network(
        series, labels, net, epochs=2, seed=3
    )
    assert res.assignments.shape == (12,)
    assert 0.0 <= res.rand_index <= 1.0
    res2 = simulator.cluster_time_series_network(
        series, labels, net, epochs=2, seed=3
    )
    np.testing.assert_array_equal(res.assignments, res2.assignments)
    # wrong encoder geometry is a loud error, as for columns
    with pytest.raises(ValueError, match="encoded width"):
        simulator.cluster_time_series_network(
            series[:, :10], labels, net, epochs=1
        )
