"""TNN core behaviour: solver equivalence, WTA, STDP, encodings, networks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import column, encoding, network, neuron, stdp, wta
from repro.core.types import (
    ColumnConfig, LayerConfig, NetworkConfig, NeuronConfig, STDPConfig,
    WTAConfig,
)


# ---------------------------------------------------------------- neurons
@settings(max_examples=30, deadline=None)
@given(
    p=st.integers(2, 24),
    q=st.integers(1, 5),
    t_max=st.integers(4, 48),
    thr=st.floats(0.5, 40.0),
    seed=st.integers(0, 2**31 - 1),
    resp=st.sampled_from(["rnl", "snl"]),
)
def test_event_equals_cycle(p, q, t_max, thr, seed, resp):
    """The paper's event-driven fast path must be bit-identical to the
    cycle-accurate hardware-semantics path for RNL and SNL."""
    rng = np.random.default_rng(seed)
    t_in = jnp.asarray(rng.integers(0, t_max + 4, (3, p)), jnp.int32)
    w = jnp.asarray(rng.uniform(0, 7, (p, q)), jnp.float32)
    cfg = NeuronConfig(response=resp, threshold=thr)
    ev = neuron.fire_times(t_in, w, cfg, t_max, "event")
    cy = neuron.fire_times(t_in, w, cfg, t_max, "cycle")
    np.testing.assert_array_equal(np.asarray(ev), np.asarray(cy))


@settings(max_examples=20, deadline=None)
@given(
    p=st.integers(2, 16),
    t_max=st.integers(8, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_firing_time_monotone_in_threshold(p, t_max, seed):
    """V is nondecreasing => a higher threshold can never fire earlier."""
    rng = np.random.default_rng(seed)
    t_in = jnp.asarray(rng.integers(0, t_max, (2, p)), jnp.int32)
    w = jnp.asarray(rng.uniform(0, 7, (p, 3)), jnp.float32)
    lo = neuron.fire_times(t_in, w, NeuronConfig(threshold=2.0), t_max, "event")
    hi = neuron.fire_times(t_in, w, NeuronConfig(threshold=9.0), t_max, "event")
    assert np.all(np.asarray(hi) >= np.asarray(lo))


def test_no_input_no_spike():
    t_in = jnp.full((1, 5), 99, jnp.int32)  # all silent (t_max=32)
    w = jnp.ones((5, 2), jnp.float32) * 7
    out = neuron.fire_times(t_in, w, NeuronConfig(threshold=1.0), 32, "event")
    assert np.all(np.asarray(out) == 32)


def test_lif_leak_delays_or_prevents_firing():
    t_in = jnp.asarray([[0, 4, 8]], jnp.int32)
    w = jnp.ones((3, 1), jnp.float32) * 2
    no_leak = neuron.fire_times(t_in, w, NeuronConfig(response="lif", threshold=5.0, leak=0.0), 32, "cycle")
    leak = neuron.fire_times(t_in, w, NeuronConfig(response="lif", threshold=5.0, leak=1.0), 32, "cycle")
    assert np.asarray(leak)[0, 0] >= np.asarray(no_leak)[0, 0]


# ---------------------------------------------------------------- WTA
@settings(max_examples=25, deadline=None)
@given(
    q=st.integers(2, 8),
    k=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_wta_winner_count(q, k, seed):
    k = min(k, q)
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.integers(0, 17, (4, q)), jnp.int32)  # 16 == no spike
    out, win = wta.wta(t, WTAConfig(k=k, tie_break="index"), 16)
    win = np.asarray(win)
    spikes = np.asarray(t) < 16
    assert np.all(win.sum(-1) <= np.minimum(k, spikes.sum(-1)))
    # winners must be the earliest spikes
    out = np.asarray(out)
    for b in range(win.shape[0]):
        if win[b].any():
            assert out[b][win[b]].max() <= np.where(~win[b], np.asarray(t)[b], 0).max() or win[b].all()


def test_wta_tie_break_index_picks_lowest():
    t = jnp.asarray([[5, 5, 9]], jnp.int32)
    out, win = wta.wta(t, WTAConfig(k=1, tie_break="index"), 16)
    assert np.asarray(win).tolist() == [[True, False, False]]


def test_wta_tie_break_all_shares():
    t = jnp.asarray([[5, 5, 9]], jnp.int32)
    out, win = wta.wta(t, WTAConfig(k=1, tie_break="all"), 16)
    assert np.asarray(win).tolist() == [[True, True, False]]


# ---------------------------------------------------------------- STDP
@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(2, 12),
    q=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
    mode=st.sampled_from(["expected", "stochastic"]),
)
def test_stdp_weights_stay_bounded(p, q, seed, mode):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.uniform(0, 7, (p, q)), jnp.float32)
    x = jnp.asarray(rng.integers(0, 20, (p,)), jnp.int32)
    y = jnp.asarray(rng.integers(0, 20, (q,)), jnp.int32)
    cfg = STDPConfig(mode=mode)
    w2 = stdp.stdp_update(w, x, y, cfg, 7, 16, rng=jax.random.key(seed))
    w2 = np.asarray(w2)
    assert np.all(w2 >= 0) and np.all(w2 <= 7)


def test_stdp_capture_increases_weight():
    w = jnp.full((1, 1), 3.0)
    x = jnp.asarray([2], jnp.int32)
    y = jnp.asarray([5], jnp.int32)  # x before y -> capture
    w2 = stdp.stdp_update(w, x, y, STDPConfig(), 7, 16)
    assert float(w2[0, 0]) > 3.0


def test_stdp_backoff_decreases_weight():
    w = jnp.full((1, 1), 3.0)
    x = jnp.asarray([9], jnp.int32)
    y = jnp.asarray([5], jnp.int32)  # y before x -> backoff
    w2 = stdp.stdp_update(w, x, y, STDPConfig(), 7, 16)
    assert float(w2[0, 0]) < 3.0


def test_stdp_neither_spike_no_change():
    w = jnp.full((2, 2), 3.0)
    x = jnp.asarray([16, 16], jnp.int32)
    y = jnp.asarray([16, 16], jnp.int32)
    w2 = stdp.stdp_update(w, x, y, STDPConfig(), 7, 16)
    np.testing.assert_allclose(np.asarray(w2), 3.0)


# ---------------------------------------------------------------- encoding
def test_latency_encode_order():
    x = jnp.asarray([[0.1, 0.9, 0.5]])
    t = np.asarray(encoding.latency_encode(x, 32))
    assert t[0, 1] < t[0, 2] < t[0, 0]  # larger value -> earlier spike


def test_onoff_encode_channels():
    x = jnp.asarray([[1.0, -1.0, 0.0, 2.0]])
    t = np.asarray(encoding.onoff_encode(x, 32))
    assert t.shape == (1, 8)
    on, off = t[0, :4], t[0, 4:]
    assert on[1] == 32 and off[1] < 32  # negative dev -> off channel spikes


# ---------------------------------------------------------------- column/network
def test_column_train_changes_weights_and_clusters():
    cfg = ColumnConfig(p=16, q=3, t_max=32)
    cfg = cfg.with_threshold(8.0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 32, (12, 16)), jnp.int32)
    params = column.init_params(jax.random.key(0), cfg)
    p2, y = column.train_step(params, x, cfg)
    assert float(jnp.abs(p2["w"] - params["w"]).sum()) > 0
    a = column.cluster_assignments(p2, x, cfg)
    assert np.asarray(a).shape == (12,)
    assert np.all((np.asarray(a) >= 0) & (np.asarray(a) <= 3))


def test_multilayer_network_shapes():
    col1 = ColumnConfig(p=8, q=4, t_max=16).with_threshold(4.0)
    col2 = ColumnConfig(p=8, q=2, t_max=16).with_threshold(4.0)
    net = NetworkConfig(layers=(
        LayerConfig(columns=2, column=col1, connectivity="full"),
        LayerConfig(columns=1, column=col2, connectivity="full"),
    ))
    params = network.init_params(jax.random.key(0), net, in_width=8)
    x = jnp.asarray(np.random.default_rng(0).integers(0, 16, (5, 8)), jnp.int32)
    out = network.apply(params, x, net)
    assert out.shape == (5, 2)
    trained = network.fit_greedy(params, x, net, epochs=2)
    out2 = network.apply(trained, x, net)
    assert out2.shape == (5, 2)


def test_network_validate_rejects_bad_widths():
    col = ColumnConfig(p=9, q=2, t_max=16)
    net = NetworkConfig(layers=(LayerConfig(columns=1, column=col),))
    with pytest.raises(ValueError):
        network.validate(net, in_width=8)
