"""Padded-envelope fused scan on the Mosaic kernel (ISSUE 3 acceptance).

The contract under test:
  * the Pallas kernel lowering of ``fit_scan_padded`` (interpreter stands in
    for Mosaic off-TPU) is BIT-IDENTICAL to the reference lowering on
    integer weights for a *heterogeneous* padded batch — mixed thresholds,
    effective windows and live-neuron counts all ride as runtime operands;
  * one pallas_call covers the whole design batch and the scan compiles
    exactly ONCE per envelope shape: changing every per-design scalar
    (threshold, t_max, q_active, STDP mus) retraces nothing;
  * ``backend.padded_lowering`` picks the kernel wherever it supports the
    response function and the reference body elsewhere — 'pallas' means
    Mosaic end-to-end on TPU, with no silent per-host semantic switch;
  * the single-column kernel entry point is the same runtime-operand kernel
    (D=1), still matching the reference lowering.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend
from repro.core.types import TIME_DTYPE
from repro.kernels import fused_column


def padded_batch(seed=0, d=3, p_pad=20, q_pad=5, t_window=24, n=8):
    """Heterogeneous integer-grid designs sharing one padding envelope."""
    rng = np.random.default_rng(seed)
    thresholds = jnp.asarray([7.0, 4.0, 5.0][:d], jnp.float32)
    t_maxes = jnp.asarray([24, 12, 20][:d], TIME_DTYPE)
    q_actives = jnp.asarray([5, 2, 3][:d], TIME_DTYPE)
    w = jnp.asarray(rng.integers(0, 8, (d, p_pad, q_pad)), jnp.float32)
    # live inputs in [0, t_max_d); anything >= t_max_d is silent by contract
    xs = jnp.asarray(rng.integers(0, 28, (n, d, p_pad)), TIME_DTYPE)
    return w, xs, thresholds, t_maxes, q_actives, t_window


def run_padded(lowering, seed=0, **kw):
    w, xs, th, tm, qa, t_window = padded_batch(seed=seed)
    args = dict(
        t_window=t_window, w_max=7, wta_k=1, mu_capture=1.0,
        mu_backoff=1.0, mu_search=1.0, stabilize=False, response="rnl",
        epochs=2, lowering=lowering,
    )
    args.update(kw)
    return fused_column.fit_scan_padded(w, xs, th, tm, qa, **args)


def test_padded_kernel_bit_identical_to_reference_heterogeneous():
    """Acceptance: runtime-operand kernel == reference lowering, exactly,
    for a batch mixing thresholds, effective t_max and live-q."""
    w_ref = run_padded("reference")
    w_int = run_padded("interpret")
    np.testing.assert_array_equal(np.asarray(w_ref), np.asarray(w_int))
    # integer mus on the integer grid: the run must stay on the grid, so
    # equality above is exact arithmetic, not a float coincidence
    assert float(jnp.max(jnp.abs(w_ref - jnp.round(w_ref)))) == 0.0


def test_padded_kernel_wta_k_and_stabilizer_paths_match():
    """k>1 WTA and the half stabilizer exercise the remaining kernel
    branches; the stabilizer leaves the grid, so weights get allclose."""
    w_ref = run_padded("reference", seed=1, wta_k=2, stabilize=True)
    w_int = run_padded("interpret", seed=1, wta_k=2, stabilize=True)
    np.testing.assert_allclose(
        np.asarray(w_ref), np.asarray(w_int), rtol=1e-6, atol=1e-6
    )


def test_padded_scan_compiles_once_per_envelope_across_designs(
    compile_counter,
):
    """Acceptance: one compilation per envelope shape.  Re-running with
    every per-design scalar changed — thresholds, windows, live-q, and the
    (now traced) STDP mus — must reuse the first trace."""
    fn = fused_column.fit_scan_padded
    # unique envelope (p_pad=20, q_pad=5, t_window=23) so the cache keys
    # in this test are not shared with other tests
    w0, xs0, th0, _, qa0, _ = padded_batch(seed=2)
    with compile_counter.expect_traces(fn, 1):  # first sweep: one compile
        fn(
            w0, xs0, th0,
            jnp.asarray([23, 12, 20], TIME_DTYPE), qa0,
            t_window=23, w_max=7, wta_k=1, mu_capture=1.0, mu_backoff=1.0,
            mu_search=1.0, stabilize=False, response="rnl", epochs=2,
            lowering="interpret",
        )
    w, xs, *_ = padded_batch(seed=2)
    # per-design scalars are runtime operands; changing them must not
    # recompile
    with compile_counter.expect_traces(fn, 0):
        fn(
            w, xs,
            jnp.asarray([3.0, 9.0, 6.0], jnp.float32),  # new thresholds
            jnp.asarray([16, 23, 8], TIME_DTYPE),  # new windows
            jnp.asarray([1, 4, 2], TIME_DTYPE),  # new live-q
            t_window=23, w_max=7, wta_k=1,
            mu_capture=2.0, mu_backoff=1.0, mu_search=3.0,  # new mus
            stabilize=False, response="rnl", epochs=2, lowering="interpret",
        )
    # a different envelope shape IS a new trace
    w2, xs2, th, tm, qa, _ = padded_batch(seed=3, p_pad=24)
    with compile_counter.expect_traces(fn, 1):
        fn(
            w2, xs2, th, tm, qa,
            t_window=23, w_max=7, wta_k=1, mu_capture=1.0, mu_backoff=1.0,
            mu_search=1.0, stabilize=False, response="rnl", epochs=2,
            lowering="interpret",
        )


def test_padded_lowering_selects_kernel_where_supported(monkeypatch):
    """'pallas' means Mosaic for padded batches on TPU; SNL (which the
    kernel's plane decomposition does not implement) takes the reference
    body of the same algebra instead of raising or switching semantics."""
    assert backend.padded_lowering("rnl") == backend.pallas_lowering()
    assert backend.padded_lowering("snl") == "reference"
    monkeypatch.setattr(backend, "on_tpu", lambda: True)
    assert backend.padded_lowering("rnl") == "mosaic"
    assert backend.padded_lowering("snl") == "reference"


def test_padded_kernel_rejects_snl_and_bad_lowering():
    with pytest.raises(ValueError, match="reference"):
        run_padded("interpret", response="snl")
    with pytest.raises(ValueError, match="lowering"):
        run_padded("mosaik")


def test_design_operands_layout():
    """docs/kernels.md documents this layout; the kernel indexes by column
    number, so the order is load-bearing."""
    ops = fused_column.design_operands(
        jnp.asarray([7.0, 4.0]), jnp.asarray([24, 12]), jnp.asarray([5, 2]),
        1.0, 2.0, 3.0,
    )
    assert ops.shape == (2, fused_column.N_OPERANDS)
    assert ops.dtype == jnp.float32
    np.testing.assert_array_equal(
        np.asarray(ops), [[7, 24, 5, 1, 2, 3], [4, 12, 2, 1, 2, 3]]
    )
    assert fused_column.OPERAND_COLS == (
        "threshold", "t_max", "q_active",
        "mu_capture", "mu_backoff", "mu_search",
    )


def test_network_pallas_mode_drives_kernel_end_to_end(monkeypatch):
    """mode='pallas' reaches the runtime-operand kernel through
    network.fit_greedy (interpreter standing in for Mosaic off-TPU) and
    trains bit-identically to the reference lowering."""
    from repro.core import network
    from repro.core.types import (
        ColumnConfig, LayerConfig, NetworkConfig, NeuronConfig, STDPConfig,
    )

    def int_col(p, q, t_max, threshold):
        return ColumnConfig(
            p=p, q=q, t_max=t_max,
            neuron=NeuronConfig(threshold=threshold, w_max=7),
            stdp=STDPConfig(
                mu_capture=1.0, mu_backoff=1.0, mu_search=1.0,
                stabilizer="none",
            ),
        )

    net = NetworkConfig(layers=(
        LayerConfig(columns=2, column=int_col(9, 4, 22, 4.0)),
        LayerConfig(columns=1, column=int_col(8, 2, 22, 3.0)),
    ))
    rng = np.random.default_rng(5)
    params = [
        {
            "w": jnp.asarray(
                rng.integers(0, 8, (l.columns, l.column.p, l.column.q)),
                jnp.float32,
            )
        }
        for l in net.layers
    ]
    x = jnp.asarray(rng.integers(0, 26, (6, 9)), TIME_DTYPE)
    ref = network.fit_greedy(params, x, net, epochs=2, mode="pallas")
    monkeypatch.setattr(backend, "padded_lowering", lambda resp: "interpret")
    kern = network.fit_greedy(params, x, net, epochs=2, mode="pallas")
    for li, (a, b) in enumerate(zip(ref, kern)):
        np.testing.assert_array_equal(
            np.asarray(a["w"]), np.asarray(b["w"]),
            err_msg=f"layer {li}: kernel path diverges from reference",
        )


def test_single_column_step_is_same_runtime_operand_kernel():
    """fused_step_pallas is the D=1 slice of the padded kernel; a full
    single-column fit through it still matches the reference lowering."""
    from repro.core.types import ColumnConfig, NeuronConfig

    cfg = ColumnConfig(p=13, q=3, t_max=21, neuron=NeuronConfig(threshold=5.0))
    rng = np.random.default_rng(4)
    params = {
        "w": jnp.asarray(rng.integers(0, 8, (cfg.p, cfg.q)), jnp.float32)
    }
    x = jnp.asarray(rng.integers(0, cfg.t_max + 4, (5, cfg.p)), jnp.int32)
    p_ref, y_ref = fused_column.fit_fused(
        params, x, cfg, epochs=2, lowering="reference", trace=True
    )
    p_int, y_int = fused_column.fit_fused(
        params, x, cfg, epochs=2, lowering="interpret", trace=True
    )
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_int))
    np.testing.assert_allclose(
        np.asarray(p_ref["w"]), np.asarray(p_int["w"]), rtol=1e-6, atol=1e-6
    )
