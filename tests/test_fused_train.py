"""Fused training path: cross-backend equivalence + compile-once regression.

The contract under test (ISSUE 1 acceptance):
  * 'event', 'cycle' and the fused 'pallas' path produce BIT-IDENTICAL
    online firing times on integer weights (integer mus, no stabilizer keep
    the weights on the integer grid for the whole run, so the fused path's
    integer-grid fire is exact);
  * weights agree within float tolerance;
  * the Pallas kernel lowering (interpreter) matches the jnp reference
    lowering of the same fused step;
  * a whole fit — every epoch, every volley — triggers exactly ONE
    compilation;
  * train_step's default is the true-online rule; the legacy batch-stale
    fold survives as update='batch' and is genuinely different.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend, column, simulator
from repro.core.types import ColumnConfig, NeuronConfig, STDPConfig
from repro.kernels import fused_column, ref
from repro.kernels.rnl_response import rnl_fire_pallas


def int_cfg(p=19, q=4, t_max=24, threshold=7.0, w_max=7, k=1):
    """Config whose expected-STDP updates keep weights integer-valued."""
    return ColumnConfig(
        p=p, q=q, t_max=t_max,
        neuron=NeuronConfig(threshold=threshold, w_max=w_max),
        stdp=STDPConfig(
            mu_capture=1.0, mu_backoff=1.0, mu_search=1.0, stabilizer="none"
        ),
    )


def int_data(cfg, n=12, seed=0):
    rng = np.random.default_rng(seed)
    params = {
        "w": jnp.asarray(
            rng.integers(0, cfg.neuron.w_max + 1, (cfg.p, cfg.q)), jnp.float32
        )
    }
    x = jnp.asarray(rng.integers(0, cfg.t_max + 6, (n, cfg.p)), jnp.int32)
    return params, x


def test_backends_bit_identical_firing_times_on_integer_weights():
    cfg = int_cfg()
    params, x = int_data(cfg)
    outs = {}
    for name in ("event", "cycle", "pallas"):
        p2, ys = backend.get(name).fit(params, x, cfg, name, 3, None, True, None)
        outs[name] = (np.asarray(p2["w"]), np.asarray(ys))
    for name in ("cycle", "pallas"):
        np.testing.assert_array_equal(
            outs["event"][1], outs[name][1],
            err_msg=f"firing times diverge: event vs {name}",
        )
        np.testing.assert_allclose(
            outs["event"][0], outs[name][0], rtol=1e-6, atol=1e-6,
            err_msg=f"weights diverge: event vs {name}",
        )


def test_fused_interpret_kernel_matches_reference_lowering():
    """The actual Pallas kernel (interpreter) == jnp lowering, full fit."""
    cfg = ColumnConfig(p=13, q=3, t_max=16, neuron=NeuronConfig(threshold=5.0))
    params, x = int_data(cfg, n=6, seed=1)
    p_ref, y_ref = fused_column.fit_fused(
        params, x, cfg, epochs=2, lowering="reference", trace=True
    )
    p_int, y_int = fused_column.fit_fused(
        params, x, cfg, epochs=2, lowering="interpret", trace=True
    )
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_int))
    np.testing.assert_allclose(
        np.asarray(p_ref["w"]), np.asarray(p_int["w"]), rtol=1e-6, atol=1e-6
    )


def test_fused_matches_cycle_mode_firing_times():
    """Acceptance: fused firing times bit-identical to mode='cycle'."""
    cfg = int_cfg(p=31, q=5, t_max=40, threshold=11.0)
    params, x = int_data(cfg, n=10, seed=2)
    _, ys_fused = fused_column.fit_fused(
        params, x, cfg, epochs=2, lowering="reference", trace=True
    )
    _, ys_cycle = backend.get("cycle").fit(
        params, x, cfg, "cycle", 2, None, True, None
    )
    np.testing.assert_array_equal(np.asarray(ys_fused), np.asarray(ys_cycle))


def test_fit_compiles_exactly_once_across_epochs():
    cfg = int_cfg(p=17, q=3, t_max=20)  # unique geometry -> fresh cache key
    params, x = int_data(cfg, n=8, seed=3)
    assert backend.resolve("auto", cfg, training=True) == "pallas"
    fn = fused_column._fused_fit_scan
    before = fn._cache_size()
    column.fit(params, x, cfg, epochs=6)
    after_first = fn._cache_size()
    assert after_first == before + 1, "fit must compile exactly once"
    column.fit(params, x, cfg, epochs=6)
    assert fn._cache_size() == after_first, "refit must not recompile"


def test_train_step_online_default_differs_from_batch_stale():
    """Batch mode computes every winner from stale pre-batch weights; the
    online default must fold each volley before the next one fires."""
    cfg = ColumnConfig(
        p=4, q=2, t_max=16,
        neuron=NeuronConfig(threshold=6.0, w_max=7),
        stdp=STDPConfig(
            mu_capture=1.0, mu_backoff=1.0, mu_search=2.0, stabilizer="none"
        ),
    )
    # neuron 0 starts dead (w=0) and never fires from stale weights; online,
    # mu_search pumps it up each volley until it ties neuron 1 and steals
    # the win via the index tie-break — impossible under the stale fold.
    params = {
        "w": jnp.asarray([[0.0, 2.0]] * 4, jnp.float32)  # [p=4, q=2]
    }
    x = jnp.zeros((4, 4), jnp.int32)  # the same volley, 4 times
    p_on, y_on = column.train_step(params, x, cfg, update="online")
    p_ba, y_ba = column.train_step(params, x, cfg, update="batch")
    assert np.asarray(y_ba).std(axis=0).max() == 0  # stale: identical rows
    assert np.asarray(y_on).std(axis=0).max() > 0  # online: winner flips
    diff = np.abs(np.asarray(p_on["w"]) - np.asarray(p_ba["w"])).max()
    assert diff > 0, "online and batch folds should diverge on repeated input"


def test_train_step_online_equals_sequential_single_steps():
    cfg = int_cfg(p=11, q=3, t_max=16, threshold=5.0)
    params, x = int_data(cfg, n=5, seed=5)
    p_scan, ys = column.train_step(params, x, cfg)
    p_seq = params
    for i in range(x.shape[0]):
        p_seq, yi = column.train_step(p_seq, x[i : i + 1], cfg)
        np.testing.assert_array_equal(np.asarray(ys[i]), np.asarray(yi[0]))
    np.testing.assert_allclose(
        np.asarray(p_scan["w"]), np.asarray(p_seq["w"]), rtol=1e-6, atol=1e-6
    )


def test_kernel_interpret_default_is_central():
    """rnl_fire_pallas with interpret unset must follow the central policy
    (interpreter off-TPU) and still match the oracle."""
    rng = np.random.default_rng(6)
    t_in = jnp.asarray(rng.integers(0, 40, (4, 21)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 8, (21, 3)), jnp.float32)
    got = rnl_fire_pallas(t_in, w, 9.0, 32, 7)  # no interpret kwarg
    want = ref.rnl_fire_ref(t_in, w, 9.0, 32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert backend.pallas_interpret() == (jax.default_backend() != "tpu")


def test_design_sweep_matches_single_design_fit():
    """The padded multi-design vmap must reproduce the single-design fused
    fit exactly for each member design (incl. the non-envelope one)."""
    # designs share the stream (p fixed by the encoder) but differ in q,
    # t_max and threshold — the non-envelope design exercises the masking
    small = ColumnConfig(p=14, q=2, t_max=12).with_threshold(4.0)
    big = ColumnConfig(p=14, q=3, t_max=20).with_threshold(6.0)
    cfgs = [small, big]
    rng = np.random.default_rng(7)
    series = rng.normal(size=(10, 14))
    labels = rng.integers(0, 2, 10)

    sweep = simulator.cluster_time_series_many(series, labels, cfgs, epochs=2, seed=3)

    # replicate the sweep's per-design init-key derivation
    rng_key = jax.random.key(3)
    _, init_key = jax.random.split(rng_key)
    keys = jax.random.split(init_key, len(cfgs))
    from repro.core import encoding

    for i, cfg in enumerate(cfgs):
        params0 = column.init_params(keys[i], cfg)
        volleys = encoding.latency_encode(jnp.asarray(series), cfg.t_max)
        p_fit, _ = fused_column.fit_fused(
            params0, volleys, cfg, epochs=2, lowering="reference"
        )
        np.testing.assert_allclose(
            np.asarray(sweep[i].params["w"]), np.asarray(p_fit["w"]),
            rtol=1e-5, atol=1e-5,
            err_msg=f"sweep weights diverge for design {i}",
        )
        asg = column.cluster_assignments(p_fit, volleys, cfg, "auto")
        np.testing.assert_array_equal(sweep[i].assignments, np.asarray(asg))


def test_fused_rejects_unsupported_configs():
    lif = ColumnConfig(p=8, q=2, t_max=16, neuron=NeuronConfig(response="lif"))
    with pytest.raises(ValueError):
        fused_column.check_fusable(lif, "reference")
    assert backend.resolve("auto", lif, training=True) == "cycle"
    stoch = ColumnConfig(p=8, q=2, t_max=16, stdp=STDPConfig(mode="stochastic"))
    assert backend.resolve("auto", stoch, training=True) == "event"
    # forcing the pallas forward on LIF must raise, not silently run RNL/SNL
    params = {"w": jnp.ones((8, 2), jnp.float32)}
    x = jnp.zeros((3, 8), jnp.int32)
    with pytest.raises(ValueError, match="pallas forward"):
        column.apply(params, x, lif, "pallas")
    # a single-design sweep must validate its (only) config too
    rng = np.random.default_rng(8)
    series = rng.normal(size=(6, 8))
    with pytest.raises(ValueError):
        simulator.cluster_time_series_many(series, None, [stoch], epochs=1)
