"""Envelope-bucketed, sharded design-space exploration (ISSUE 5 acceptance).

The contract under test:
  * the bucketed design sweep is BIT-IDENTICAL per design to the old
    single-global-envelope path on a heterogeneous (varying q, t_max,
    threshold) sweep — bucketing (and sharding) are throughput knobs,
    never semantic ones;
  * buckets with equal envelope shapes share ONE compiled trace (the jit
    cache keys on the envelope, not the bucket);
  * the central bucket policy (``backend.envelope_buckets``) respects the
    waste cap and ``max_bucket``, and covers every design exactly once;
  * the shard policy falls back cleanly on a single device, and on a
    forced multi-device host shards the design axis with bit-identical
    results (subprocess — device count must be set before jax init);
  * degenerate streams: N=0 raises a clear up-front ValueError everywhere,
    ``epochs=0`` trivially returns the init weights;
  * ``backend.assign_lowering`` survives abstract (traced) weights on
    current JAX without touching deprecated tracer internals;
  * ``ClusteringResult.params`` has one dict shape across all front-ends;
  * ``dse.explore`` pairs each design's Rand index with a
    ``hwgen.forecast`` area/leakage estimate and emits a nondominated
    Pareto set.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import dse
from repro.core import backend, simulator
from repro.core.types import ColumnConfig, TIME_DTYPE
from repro.hwgen.forecast import PaperForecaster
from repro.kernels import fused_column


def _cfg(p, q, t_max, scale=1.0):
    c = ColumnConfig(p=p, q=q, t_max=t_max)
    return c.with_threshold(scale * simulator.suggest_threshold(c))


def _stream(n=18, length=10, classes=3, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, length)), rng.integers(0, classes, n)


# ------------------------------------------------------------ bucket policy
def test_envelope_buckets_respects_waste_cap_and_covers_all():
    shapes = [(16, 2, 16), (16, 3, 16), (16, 8, 64), (16, 10, 64)]
    buckets = backend.envelope_buckets(shapes)
    covered = sorted(i for _, idxs in buckets for i in idxs)
    assert covered == [0, 1, 2, 3], "every design in exactly one bucket"
    assert len(buckets) == 2, "small designs must not ride the big envelope"
    for env, idxs in buckets:
        vol = env[0] * env[1] * env[2]
        for i in idxs:
            p, q, t = shapes[i]
            assert vol <= backend.ENVELOPE_WASTE_CAP * p * q * t
    # an infinite cap reproduces the old single-global-envelope behavior
    buckets_inf = backend.envelope_buckets(shapes, waste_cap=float("inf"))
    assert len(buckets_inf) == 1
    assert buckets_inf[0][0] == (16, 10, 64)


def test_envelope_buckets_max_bucket_splits_equal_envelopes():
    shapes = [(8, 3, 16)] * 5
    buckets = backend.envelope_buckets(shapes, max_bucket=2)
    assert [len(idxs) for _, idxs in buckets] == [2, 2, 1]
    assert all(env == (8, 3, 16) for env, _ in buckets)


# --------------------------------------------- bucketed sweep bit-identity
def test_bucketed_sweep_bit_identical_to_global_envelope():
    """Acceptance: a heterogeneous sweep (varying q, t_max, threshold)
    split into envelope buckets reproduces the single-global-envelope
    sweep bit for bit, per design."""
    x, y = _stream(seed=1)
    cfgs = [
        _cfg(10, 2, 16, 0.8), _cfg(10, 3, 16, 1.0),
        _cfg(10, 8, 64, 1.2), _cfg(10, 10, 64, 1.0),
    ]
    res_b = simulator.cluster_time_series_many(x, y, cfgs, epochs=2, seed=3)
    res_g = simulator.cluster_time_series_many(
        x, y, cfgs, epochs=2, seed=3, waste_cap=float("inf")
    )
    assert res_b[0].buckets == 2 and res_g[0].buckets == 1
    for i, (a, b) in enumerate(zip(res_b, res_g)):
        np.testing.assert_array_equal(
            a.assignments, b.assignments,
            err_msg=f"design {i}: bucketing changed assignments",
        )
        np.testing.assert_array_equal(
            np.asarray(a.params["w"]), np.asarray(b.params["w"]),
            err_msg=f"design {i}: bucketing changed trained weights",
        )
        assert a.params["w"].shape == (cfgs[i].p, cfgs[i].q)
        assert a.rand_index == b.rand_index


def test_equal_envelope_buckets_share_one_trace(compile_counter):
    """Acceptance: at most one compiled executable per distinct bucket
    envelope — a max_bucket split into equal envelopes reuses the first
    bucket's AOT executable for fit AND assignment.

    The single-device sweep dispatches through the envelope-keyed AOT
    cache (``backend.fit_padded`` / ``backend.assign_padded``), so the
    invariant is pinned at the true compile seam: the whole sweep
    compiles the fit program once and the assignment program once."""
    x, _ = _stream(n=11, length=9, seed=2)
    # unique geometry (prime-ish sizes) so the cache keys in this test
    # are not shared with other tests
    cfgs = [_cfg(9, 3, 17) for _ in range(4)]
    backend.aot_cache_clear()
    aot_before = backend.aot_cache_size()
    res = simulator.cluster_time_series_many(
        x, None, cfgs, epochs=1, max_bucket=2
    )
    assert res[0].buckets == 2
    assert compile_counter.named("fit_scan_padded") == 1, (
        "equal-envelope buckets must share one compiled fit executable"
    )
    assert compile_counter.named("assign_padded") == 1, (
        "equal-envelope buckets must share one compiled assignment "
        "executable"
    )
    assert backend.aot_cache_size() == aot_before + 2  # one fit + one assign


# ------------------------------------------------------------ shard policy
def test_design_shard_single_device_fallback():
    """On a single-device host the policy is a clean no-op: no mesh,
    shard count 1, arrays left untouched, sweep results tagged shards=1."""
    if jax.local_device_count() != 1:
        pytest.skip("host has multiple devices")
    assert backend.design_shards(4) == 1
    assert backend.design_mesh(4) is None
    x = jnp.arange(6.0)
    assert backend.shard_design_axis(None, x) is x
    series, y = _stream(n=8, length=8, seed=4)
    res = simulator.cluster_time_series_many(
        series, y, [_cfg(8, 2, 16)], epochs=1
    )
    assert res[0].shards == 1


def test_design_shards_divisor_policy():
    """Shard count is the largest divisor of D fitting the device count —
    exercised against a fake device count (the mesh itself needs real
    devices and is covered by the subprocess test)."""
    n_dev = jax.local_device_count()
    assert backend.design_shards(1) == 1
    assert backend.design_shards(n_dev) == n_dev
    assert 1 <= backend.design_shards(7) <= 7


def test_sharded_sweep_bit_identical_multi_device_subprocess():
    """4 forced host devices: the design axis shards 4 ways and the sweep
    stays bit-identical to the unsharded path (subprocess — the device
    count must be set before jax initializes)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax
        from repro.core import simulator, backend
        from repro.core.types import ColumnConfig

        assert jax.local_device_count() == 4
        assert backend.design_shards(4) == 4
        assert backend.design_shards(6) == 3
        assert backend.design_shards(5) == 1  # no divisor -> fallback

        def cfg(q, t):
            c = ColumnConfig(p=12, q=q, t_max=t)
            return c.with_threshold(simulator.suggest_threshold(c))

        rng = np.random.default_rng(0)
        x = rng.normal(size=(14, 12)); y = rng.integers(0, 3, 14)
        cfgs = [cfg(3, 16), cfg(4, 16), cfg(3, 24), cfg(4, 24)]
        res_s = simulator.cluster_time_series_many(x, y, cfgs, epochs=2)
        assert [r.shards for r in res_s] == [4, 4, 4, 4], res_s[0].shards
        backend.design_mesh = lambda d: None  # force the unsharded path
        res_u = simulator.cluster_time_series_many(x, y, cfgs, epochs=2)
        for a, b in zip(res_s, res_u):
            np.testing.assert_array_equal(a.assignments, b.assignments)
            np.testing.assert_array_equal(
                np.asarray(a.params["w"]), np.asarray(b.params["w"]))
        print("SHARD_OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=dict(os.environ, PYTHONPATH="src"),
        timeout=600,
    )
    assert "SHARD_OK" in r.stdout, r.stderr[-3000:]


# ------------------------------------------------------ degenerate streams
def test_empty_stream_raises_up_front():
    cfg = _cfg(8, 2, 16)
    with pytest.raises(ValueError, match="N=0"):
        simulator.cluster_time_series_many(
            np.zeros((0, 8)), None, [cfg], epochs=1
        )
    w = jnp.ones((1, 8, 2))
    xs0 = jnp.zeros((0, 1, 8), TIME_DTYPE)
    th = jnp.asarray([5.0], jnp.float32)
    tm = jnp.asarray([16], TIME_DTYPE)
    qa = jnp.asarray([2], TIME_DTYPE)
    with pytest.raises(ValueError, match="empty stream"):
        fused_column.fit_scan_padded(
            w, xs0, th, tm, qa, t_window=16, w_max=7, wta_k=1,
            mu_capture=1.0, mu_backoff=1.0, mu_search=1.0, stabilize=False,
            response="rnl", epochs=1, lowering="reference",
        )
    with pytest.raises(ValueError, match="empty stream"):
        fused_column.assign_padded(
            w, xs0, th, tm, qa, t_window=16, wta_k=1, response="rnl",
            lowering="reference",
        )


def test_zero_epochs_returns_init_weights_trivially():
    """epochs=0 is well-defined: no training pass, weights unchanged —
    for the raw padded scan and through the sweep front-end (whose
    assignments then come from the init weights)."""
    rng = np.random.default_rng(7)
    w0 = jnp.asarray(rng.integers(0, 8, (2, 8, 3)), jnp.float32)
    xs = jnp.asarray(rng.integers(0, 16, (5, 2, 8)), TIME_DTYPE)
    th = jnp.asarray([5.0, 4.0], jnp.float32)
    tm = jnp.asarray([16, 12], TIME_DTYPE)
    qa = jnp.asarray([3, 2], TIME_DTYPE)
    w = fused_column.fit_scan_padded(
        jnp.array(w0, copy=True), xs, th, tm, qa, t_window=16, w_max=7,
        wta_k=1, mu_capture=1.0, mu_backoff=1.0, mu_search=1.0,
        stabilize=False, response="rnl", epochs=0, lowering="reference",
    )
    np.testing.assert_array_equal(np.asarray(w0), np.asarray(w))

    series, y = _stream(n=6, length=8, seed=8)
    cfg = _cfg(8, 2, 16)
    res = simulator.cluster_time_series_many(series, y, [cfg], epochs=0)
    assert res[0].assignments.shape == (6,)
    # the returned params are exactly the seeded init weights
    import jax as _jax
    from repro.core import column as column_lib
    rng_ = _jax.random.key(0)
    _, init_key = _jax.random.split(rng_)
    (key,) = _jax.random.split(init_key, 1)
    w_init = column_lib.init_params(key, cfg)["w"]
    np.testing.assert_array_equal(
        np.asarray(w_init), np.asarray(res[0].params["w"])
    )


# --------------------------------------------------- assign_lowering (jax)
def test_assign_lowering_abstract_weights_fall_back(monkeypatch):
    """Tracers (abstract values) must fall back to 'reference' without
    touching deprecated jax.core internals — probed via eval_shape, which
    hands the probe abstract arrays exactly like a jit trace would."""
    monkeypatch.setattr(backend, "on_tpu", lambda: True)
    seen = []

    def probe(w):
        seen.append(backend.assign_lowering("rnl", w))
        return w

    jax.eval_shape(probe, jax.ShapeDtypeStruct((2, 2), jnp.float32))
    assert seen == ["reference"]
    # concrete weights still pick the kernel on the integer grid
    assert backend.assign_lowering("rnl", jnp.asarray([[2.0]])) == "mosaic"
    assert (
        backend.assign_lowering("rnl", jnp.asarray([[2.5]])) == "reference"
    )


# ------------------------------------------------------- params unification
def test_clustering_result_params_shape_unified():
    """One dict contract across front-ends: {'w'} for single columns and
    sweep members (cropped to design size), {'layers': [{'w'}, ...]} for
    networks."""
    from repro.core.types import LayerConfig, NetworkConfig

    series, y = _stream(n=8, length=8, seed=9)
    cfg = _cfg(8, 2, 16)
    single = simulator.cluster_time_series(series, y, cfg, epochs=1)
    assert set(single.params) == {"w"}
    (swept,) = simulator.cluster_time_series_many(
        series, y, [cfg], epochs=1
    )
    assert set(swept.params) == {"w"}
    assert swept.params["w"].shape == single.params["w"].shape

    l2 = _cfg(4, 2, 16)
    net = NetworkConfig(layers=(
        LayerConfig(columns=2, column=_cfg(8, 2, 16)),
        LayerConfig(columns=1, column=l2),
    ))
    net_res = simulator.cluster_time_series_network(
        series, y, net, epochs=1
    )
    assert set(net_res.params) == {"layers"}
    assert [set(lp) for lp in net_res.params["layers"]] == [{"w"}, {"w"}]
    assert net_res.params["layers"][0]["w"].shape == (2, 8, 2)


# ----------------------------------------------------------- dse.explore
def test_explore_pairs_rand_index_with_forecast_and_emits_pareto():
    """Acceptance: dse.explore sweeps the space, pairs every design's
    Rand index with the hwgen.forecast area/leakage for its synapse
    count, and returns a nondominated Pareto set."""
    x, y = _stream(n=16, length=8, seed=5)
    space = dse.DesignSpace(
        q=(2, 4), t_max=(16,), threshold_scale=(0.8, 1.2),
    )
    res = dse.explore(x, y, space, epochs=1, seed=1)
    assert len(res.points) == space.size() == 4
    fc = PaperForecaster()
    for p in res.points:
        assert p.synapses == p.cfg.p * p.cfg.q
        assert p.area_um2 == pytest.approx(fc.area_um2(p.synapses))
        assert p.leakage_uw == pytest.approx(fc.leakage_uw(p.synapses))
        assert not np.isnan(p.rand_index)
        assert set(p.params) == {"w"}
    assert res.pareto, "a labeled sweep must yield a frontier"
    for p in res.pareto:
        assert not any(
            dse.dominates(o, p) for o in res.points if o is not p
        ), "pareto point is dominated"
    best = res.best()
    assert best in res.pareto
    assert res.meta["buckets"] == {"latency": 1}
    assert "explored" in dse.summarize(res)


def test_explore_random_search_and_guards():
    x, y = _stream(n=10, length=8, seed=6)
    space = dse.DesignSpace(q=(2, 3), t_max=(16, 24))
    res = dse.explore(
        x, y, space, epochs=1, search="random", budget=2, seed=2
    )
    assert len(res.points) == 2
    with pytest.raises(ValueError, match="labels"):
        dse.explore(x, None, space, epochs=1)
    with pytest.raises(ValueError, match="budget"):
        dse.explore(x, y, space, epochs=1, search="random")
    with pytest.raises(ValueError, match="search"):
        dse.explore(x, y, space, epochs=1, search="anneal")


def test_pareto_front_excludes_dominated_and_nan():
    def pt(i, ri, area, leak=1.0):
        return dse.DesignPoint(
            index=i, cfg=_cfg(8, 2, 16), encoder="latency", rand_index=ri,
            synapses=16, area_um2=area, leakage_uw=leak, params={},
        )

    a = pt(0, 0.9, 100.0)
    b = pt(1, 0.8, 200.0)      # worse RI, bigger area: dominated by a
    c = pt(2, 0.95, 300.0)     # better RI at more area: frontier
    d = pt(3, float("nan"), 1.0)
    front = dse.pareto_front([a, b, c, d])
    assert front == [a, c]
    assert dse.dominates(a, b) and not dse.dominates(b, a)
    assert not dse.dominates(a, c)
