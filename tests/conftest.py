"""Test-suite bootstrap: the ``compile_counter`` fixture and a tiny
vendored ``hypothesis`` shim.

Several test modules hard-import ``hypothesis``; the container does not ship
it and nothing may be pip-installed.  Instead of skipping those modules (and
losing their coverage), we register a minimal drop-in shim into
``sys.modules`` *before collection* that supports exactly the API surface the
suite uses:

  @settings(max_examples=N, deadline=None)
  @given(x=st.integers(a, b), y=st.floats(a, b), z=st.sampled_from(seq))
  def test_...(x, y, z): ...

The shim draws ``max_examples`` pseudo-random examples per test from a
deterministic per-test seed (derived from the test name), so runs are
reproducible.  There is no shrinking and no example database — it is a test
*runner*, not a property-based testing engine.  If the real ``hypothesis``
is installed it is used untouched.
"""
from __future__ import annotations

import contextlib
import functools
import random
import sys
import types
import zlib

import pytest


class CompileCounter:
    """Counts real XLA compilations and jit trace-cache growth.

    ``compiles`` / ``names`` record every module that went through
    ``jax._src.compiler.backend_compile`` — the one funnel below
    ``jit``/``lower().compile()`` that persistent-cache HITS skip, so it
    counts true compilation work, not tracing.  ``named(substr)`` filters
    by HLO module name (e.g. ``'fit_scan_padded'``), which keeps
    assertions robust against incidental helper modules (conversions,
    broadcasts) the runtime compiles on the side.  ``expect_traces``
    pins the *tracing* side via a jitted callable's ``_cache_size()``.
    """

    def __init__(self):
        self.compiles = 0
        self.names: list[str] = []

    def named(self, substr: str) -> int:
        return sum(1 for n in self.names if substr in n)

    @staticmethod
    def traces(fn) -> int:
        return fn._cache_size()

    @contextlib.contextmanager
    def expect_traces(self, fn, n: int):
        before = fn._cache_size()
        yield
        got = fn._cache_size() - before
        assert got == n, (
            f"expected exactly {n} new trace(s) of "
            f"{getattr(fn, '__name__', fn)}, got {got}"
        )


@pytest.fixture
def compile_counter(monkeypatch, tmp_path):
    """Intercept compilation at the jax.jit / AOT lower seam.

    Every trace-count / compile-count assertion in the suite goes through
    this fixture — one seam, one contract.  Both persistence layers — the
    JAX compilation cache AND the serialized-AOT-executable store keyed
    off ``backend.compile_cache_dir()`` — are pointed at a throwaway
    per-test directory for the fixture's lifetime, so counts are
    deterministic regardless of whether the environment (e.g. CI) runs
    the suite with a warm ``REPRO_COMPILE_CACHE``.
    """
    import jax
    from jax._src import compiler as _compiler
    from repro.core import backend as _backend

    counter = CompileCounter()
    orig = _compiler.backend_compile

    def spy(backend, module, *args, **kwargs):
        try:
            name = str(module.operation.attributes["sym_name"])
        except Exception:
            name = str(getattr(module, "name", ""))
        counter.compiles += 1
        counter.names.append(name)
        return orig(backend, module, *args, **kwargs)

    monkeypatch.setattr(_compiler, "backend_compile", spy)
    monkeypatch.setattr(
        _backend, "_compile_cache_path", str(tmp_path / "jaxcache")
    )
    prev = jax.config.jax_compilation_cache_dir
    jax.config.update("jax_compilation_cache_dir", str(tmp_path / "jaxcache"))
    try:
        yield counter
    finally:
        jax.config.update("jax_compilation_cache_dir", prev)


try:  # real hypothesis wins if present
    import hypothesis  # noqa: F401
except ImportError:
    _MODULE = "hypothesis"

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def given(**strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def runner():  # zero-arg: examples are drawn, not fixtures
                cfg = getattr(runner, "_shim_settings", {})
                n = cfg.get("max_examples", 10)
                seed = zlib.adler32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for _ in range(n):
                    kwargs = {
                        name: s.example_from(rng)
                        for name, s in strategies.items()
                    }
                    try:
                        fn(**kwargs)
                    except Exception as e:  # surface the failing example
                        raise AssertionError(
                            f"falsifying example ({fn.__name__}): {kwargs}"
                        ) from e

            # pytest resolves fixtures from inspect.signature, which follows
            # __wrapped__ back to fn's (example-)parameters — drop it.
            del runner.__wrapped__
            return runner

        return decorate

    def settings(**kwargs):
        def decorate(fn):
            fn._shim_settings = kwargs
            return fn

        return decorate

    shim = types.ModuleType(_MODULE)
    shim.given = given
    shim.settings = settings
    shim.__version__ = "0.0-shim"
    strategies_mod = types.ModuleType(f"{_MODULE}.strategies")
    strategies_mod.integers = integers
    strategies_mod.floats = floats
    strategies_mod.sampled_from = sampled_from
    shim.strategies = strategies_mod
    sys.modules[_MODULE] = shim
    sys.modules[f"{_MODULE}.strategies"] = strategies_mod
