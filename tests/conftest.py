"""Test-suite bootstrap: a tiny vendored ``hypothesis`` shim.

Several test modules hard-import ``hypothesis``; the container does not ship
it and nothing may be pip-installed.  Instead of skipping those modules (and
losing their coverage), we register a minimal drop-in shim into
``sys.modules`` *before collection* that supports exactly the API surface the
suite uses:

  @settings(max_examples=N, deadline=None)
  @given(x=st.integers(a, b), y=st.floats(a, b), z=st.sampled_from(seq))
  def test_...(x, y, z): ...

The shim draws ``max_examples`` pseudo-random examples per test from a
deterministic per-test seed (derived from the test name), so runs are
reproducible.  There is no shrinking and no example database — it is a test
*runner*, not a property-based testing engine.  If the real ``hypothesis``
is installed it is used untouched.
"""
from __future__ import annotations

import functools
import random
import sys
import types
import zlib

try:  # real hypothesis wins if present
    import hypothesis  # noqa: F401
except ImportError:
    _MODULE = "hypothesis"

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value, max_value):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def given(**strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def runner():  # zero-arg: examples are drawn, not fixtures
                cfg = getattr(runner, "_shim_settings", {})
                n = cfg.get("max_examples", 10)
                seed = zlib.adler32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for _ in range(n):
                    kwargs = {
                        name: s.example_from(rng)
                        for name, s in strategies.items()
                    }
                    try:
                        fn(**kwargs)
                    except Exception as e:  # surface the failing example
                        raise AssertionError(
                            f"falsifying example ({fn.__name__}): {kwargs}"
                        ) from e

            # pytest resolves fixtures from inspect.signature, which follows
            # __wrapped__ back to fn's (example-)parameters — drop it.
            del runner.__wrapped__
            return runner

        return decorate

    def settings(**kwargs):
        def decorate(fn):
            fn._shim_settings = kwargs
            return fn

        return decorate

    shim = types.ModuleType(_MODULE)
    shim.given = given
    shim.settings = settings
    shim.__version__ = "0.0-shim"
    strategies_mod = types.ModuleType(f"{_MODULE}.strategies")
    strategies_mod.integers = integers
    strategies_mod.floats = floats
    strategies_mod.sampled_from = sampled_from
    shim.strategies = strategies_mod
    sys.modules[_MODULE] = shim
    sys.modules[f"{_MODULE}.strategies"] = strategies_mod
