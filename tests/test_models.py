"""LM model zoo: per-arch smoke tests + cross-path consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import transformer as T


def _batch(cfg, B=2, S=16):
    rng = jax.random.key(7)
    b = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        b["frames"] = jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch_id", C.ARCH_IDS)
def test_arch_smoke_train_step(arch_id):
    """Reduced config: one forward/train step on CPU; shapes + no NaNs."""
    cfg = C.get_arch(arch_id, smoke=True)
    params = T.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg)
    loss, metrics = T.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss)), arch_id
    logits = T.forward(params, batch["tokens"], cfg,
                       frames=batch.get("frames"))
    assert logits.shape == (2, 16, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    grads = jax.grad(lambda p: T.loss_fn(p, batch, cfg)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch_id


@pytest.mark.parametrize("arch_id", C.ARCH_IDS)
def test_arch_smoke_serve(arch_id):
    """Prefill + 2 decode steps must produce finite logits."""
    cfg = C.get_arch(arch_id, smoke=True)
    params = T.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg)
    cache, lg = T.prefill(params, batch["tokens"], cfg, max_len=24,
                          frames=batch.get("frames"))
    for _ in range(2):
        cache, lg = T.decode_step(params, cache, batch["tokens"][:, :1], cfg)
    assert lg.shape == (2, 1, cfg.vocab_padded)
    assert np.isfinite(np.asarray(lg, np.float32)).all()


@pytest.mark.parametrize("arch_id", ["qwen3-14b", "zamba2-7b", "mamba2-370m",
                                     "olmoe-1b-7b", "whisper-medium"])
def test_serve_matches_forward(arch_id):
    """prefill+decode logits must equal the training forward (per token)."""
    cfg = C.get_arch(arch_id, smoke=True)
    params = T.init_params(jax.random.key(1), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    frames = (jnp.ones((B, cfg.enc_seq, cfg.d_model), jnp.float32)
              if cfg.family == "audio" else None)
    full = np.asarray(T.forward(params, toks, cfg, frames=frames), np.float32)
    cache, lg = T.prefill(params, toks[:, : S // 2], cfg, max_len=S + 2,
                          frames=frames)
    outs = [np.asarray(lg, np.float32)]
    for t in range(S // 2, S):
        cache, lg = T.decode_step(params, cache, toks[:, t : t + 1], cfg)
        outs.append(np.asarray(lg, np.float32))
    served = np.concatenate(outs, axis=1)
    err = np.abs(served - full).max() / (np.abs(full).max() + 1e-9)
    assert err < 3e-3, (arch_id, err)


def test_full_configs_match_assignment_table():
    """The exact published hyper-parameters from the assignment block."""
    expect = {
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    }
    for arch_id, (L, D, H, KV, F, V) in expect.items():
        cfg = C.get_arch(arch_id)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, D, H, KV, F, V), arch_id
    assert C.get_arch("kimi-k2-1t-a32b").n_experts == 384
    assert C.get_arch("kimi-k2-1t-a32b").top_k == 8
    assert C.get_arch("olmoe-1b-7b").n_experts == 64
    assert C.get_arch("zamba2-7b").ssm_state == 64
    assert C.get_arch("mamba2-370m").ssm_state == 128
    assert C.get_arch("qwen3-14b").qk_norm
    assert C.get_arch("qwen2-vl-7b").mrope


def test_kimi_is_about_a_trillion_params():
    cfg = C.get_arch("kimi-k2-1t-a32b")
    n = cfg.param_count()
    assert 0.9e12 < n < 1.2e12, n
    a = cfg.active_param_count()
    assert 25e9 < a < 40e9, a  # "a32b"


def test_moe_paths_agree_with_reference():
    cfg = dataclasses.replace(
        C.get_arch("olmoe-1b-7b", smoke=True), moe_capacity_factor=16.0
    )
    p = moe_lib.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 24, cfg.d_model), jnp.float32)
    y_ref = np.asarray(moe_lib.moe_reference(x, p, cfg))
    for impl in ("gathered", "ragged"):
        c = dataclasses.replace(cfg, moe_impl=impl)
        y = np.asarray(moe_lib.moe_apply(x, p, c, mesh=None))
        err = np.abs(y_ref - y).max() / (np.abs(y_ref).max() + 1e-9)
        assert err < 1e-4, impl


def test_moe_drop_rate_negligible_at_cf2():
    """With cf=2 and near-uniform routing, dropped assignments are rare."""
    cfg = C.get_arch("olmoe-1b-7b", smoke=True)  # cf 4.0 in smoke; force 2
    cfg = dataclasses.replace(cfg, moe_capacity_factor=2.0)
    p = moe_lib.init_moe(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (4, 64, cfg.d_model), jnp.float32)
    y_drop = np.asarray(moe_lib.moe_apply(x, p, cfg, mesh=None))
    y_ref = np.asarray(moe_lib.moe_reference(x, p, cfg))
    # dropped tokens show up as rows where outputs differ; require < 15%
    row_err = np.abs(y_drop - y_ref).max(axis=-1) > 1e-5
    assert row_err.mean() < 0.15


def test_ssd_chunked_equals_sequential():
    rng = np.random.default_rng(0)
    B, L, H, dh, N = 2, 29, 3, 8, 16
    x = jnp.asarray(rng.normal(size=(B, L, H, dh)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    y_seq, S_seq = ssm_lib.ssd_sequential(x, dt, A, Bm, Cm)
    for chunk in (1, 8, 29, 64):
        y_ch, S_ch = ssm_lib.ssd_chunked(x, dt, A, Bm, Cm, chunk)
        np.testing.assert_allclose(np.asarray(y_ch), np.asarray(y_seq),
                                   rtol=3e-4, atol=3e-4)
        np.testing.assert_allclose(np.asarray(S_ch), np.asarray(S_seq),
                                   rtol=3e-4, atol=3e-4)


def test_unroll_scans_same_numerics():
    cfg = C.get_arch("qwen3-14b", smoke=True)
    params = T.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    a = np.asarray(T.forward(params, toks, cfg), np.float32)
    cfg_u = dataclasses.replace(cfg, unroll_scans=True, kv_chunk=64)
    b = np.asarray(T.forward(params, toks, cfg_u), np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_vocab_padding_masked_in_loss():
    cfg = dataclasses.replace(
        C.get_arch("granite-3-8b", smoke=True), vocab_size=250, vocab_pad_to=256
    )
    assert cfg.vocab_padded == 256
    params = T.init_params(jax.random.key(0), cfg)
    batch = _batch(cfg)
    batch = {k: jnp.clip(v, 0, 249) if v.dtype == jnp.int32 else v
             for k, v in batch.items()}
    loss, _ = T.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
