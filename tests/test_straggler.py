"""Direct unit tests for ``distributed.straggler.StepMonitor`` (ISSUE 8).

The monitor guards three consumers now — the pod trainer, the design
sweep, and the streaming service's stage timings — so its thresholding
semantics get pinned directly with synthetic durations (no sleeping):
stalls trigger only after warmup, recovery does not keep flagging, and
uniformly fast steps never false-positive.
"""
from __future__ import annotations

from repro.distributed.straggler import RebalancePolicy, StepMonitor


def test_no_events_during_warmup_even_for_huge_stalls():
    m = StepMonitor(window=10, threshold=2.0, warmup=5)
    for step in range(4):
        assert m.observe(step, 100.0 if step else 1.0) is None
    assert m.events == []


def test_stall_past_threshold_triggers_once_warm():
    m = StepMonitor(window=20, threshold=2.0, warmup=5)
    for step in range(5):
        m.observe(step, 1.0)
    ev = m.observe(5, 3.0)  # 3x the median of fast steps
    assert ev is not None and m.events == [ev]
    assert ev.step == 5 and ev.duration_s == 3.0
    assert ev.median_s == 1.0 and ev.ratio == 3.0


def test_no_false_positive_under_fast_uniform_steps():
    m = StepMonitor(window=10, threshold=2.0, warmup=3)
    for step in range(50):
        # jitter well inside the threshold
        assert m.observe(step, 1.0 + 0.01 * (step % 7)) is None
    assert m.events == [] and not m.should_rebalance()


def test_boundary_is_strict():
    """Exactly threshold x median is NOT a stall (strict >)."""
    m = StepMonitor(window=10, threshold=2.0, warmup=3)
    for step in range(3):
        m.observe(step, 1.0)
    assert m.observe(3, 2.0) is None
    assert m.observe(4, 2.0 + 1e-9) is not None


def test_recovery_resets_flagging():
    """After a stall, steps back at the baseline do not keep flagging —
    the median absorbs the outlier instead of chasing it."""
    m = StepMonitor(window=20, threshold=2.0, warmup=3)
    for step in range(5):
        m.observe(step, 1.0)
    assert m.observe(5, 4.0) is not None
    for step in range(6, 16):
        assert m.observe(step, 1.0) is None
    assert len(m.events) == 1
    assert m.median_s == 1.0
    # ... and a NEW stall after recovery still triggers
    assert m.observe(16, 4.0) is not None


def test_should_rebalance_needs_persistent_stalls_in_one_window():
    m = StepMonitor(window=8, threshold=2.0, warmup=3)
    for step in range(5):
        m.observe(step, 1.0)
    # two stalls: below the default patience of 3
    m.observe(5, 3.0)
    m.observe(6, 3.0)
    assert not m.should_rebalance()
    m.observe(7, 3.0)
    assert m.should_rebalance()  # 3 events inside one window
    assert m.should_rebalance(patience=2)
    assert not m.should_rebalance(patience=4)


def test_should_rebalance_ignores_stalls_spread_across_windows():
    """Three one-off hiccups far apart are noise, not a slow host."""
    m = StepMonitor(window=5, threshold=2.0, warmup=3)
    step = 0
    for _ in range(3):
        for _ in range(9):  # long fast stretch between hiccups
            m.observe(step, 1.0)
            step += 1
        m.observe(step, 10.0)
        step += 1
    assert len(m.events) == 3
    assert not m.should_rebalance()  # events span >> one window


def test_stop_without_start_is_a_no_op():
    m = StepMonitor()
    assert m.stop() is None
    assert len(m.times) == 0 and not m.events


def test_stage_labels_attributed_to_events():
    """start(label=...) tags the flagged event with its pipeline stage so
    a multi-stage consumer (the streaming service's 'assign'/'refit') can
    attribute a stall; unlabeled steps keep the empty default."""
    m = StepMonitor(threshold=0.0, warmup=1)
    m.observe(0, 1.0)  # warm the window
    ev = m.observe(1, 1.0, label="refit")
    assert ev is not None and ev.label == "refit"
    m.start("assign")
    ev2 = m.stop()
    assert ev2 is not None and ev2.label == "assign"
    assert m.observe(3, 1.0).label == ""  # default stays positional-safe


def test_start_stop_wall_clock_path():
    m = StepMonitor(warmup=1)
    m.start()
    ev = m.stop()  # warmup: never an event, but the duration is recorded
    assert ev is None and len(m.times) == 1 and m.times[0] >= 0.0
    assert m.median_s == m.times[0]


def test_empty_monitor_median_is_zero():
    assert StepMonitor().median_s == 0.0


def test_rebalance_policy_shaves_and_conserves_weight():
    pol = RebalancePolicy(num_shards=4, shave=0.25)
    w = pol.apply(slow_shard=2)
    assert w[2] == 0.75
    assert abs(sum(w) - 4.0) < 1e-12  # total batch share is conserved
    assert all(abs(wi - (1.0 + 0.25 / 3)) < 1e-12
               for i, wi in enumerate(w) if i != 2)
