"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties.

Spike times are integers, so the RNL kernel is checked with exact equality
(not allclose); the STDP kernel is float and uses allclose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import neuron
from repro.core.types import ColumnConfig, NeuronConfig
from repro.kernels import ops, ref
from repro.kernels.rnl_response import make_weight_planes, rnl_fire_pallas
from repro.kernels.stdp_update import stdp_update_pallas

SHAPE_SWEEP = [
    # (B, p, q, t_max, w_max) — includes the paper's column geometries
    (4, 13, 3, 32, 7),
    (8, 65, 2, 64, 7),
    (2, 96, 2, 100, 7),
    (3, 270, 25, 256, 7),
    (16, 31, 7, 48, 3),
    (1, 129, 9, 128, 15),
]


@pytest.mark.parametrize("B,p,q,t_max,w_max", SHAPE_SWEEP)
def test_rnl_kernel_exact_vs_oracle(B, p, q, t_max, w_max):
    rng = np.random.default_rng(B * p + q)
    t_in = jnp.asarray(rng.integers(0, t_max + 8, (B, p)), jnp.int32)
    w = jnp.asarray(rng.integers(0, w_max + 1, (p, q)), jnp.float32)
    thr = float(rng.uniform(1, p * w_max / 6))
    got = rnl_fire_pallas(t_in, w, thr, t_max, w_max)
    want = ref.rnl_fire_ref(t_in, w, thr, t_max)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rnl_kernel_dtype_int16_inputs():
    """Times arriving as other int dtypes are accepted via f32 staging."""
    rng = np.random.default_rng(0)
    t_in = jnp.asarray(rng.integers(0, 40, (4, 17)), jnp.int16).astype(jnp.int32)
    w = jnp.asarray(rng.integers(0, 8, (17, 3)), jnp.float32)
    got = rnl_fire_pallas(t_in, w, 9.0, 40, 7)
    want = ref.rnl_fire_ref(t_in, w, 9.0, 40)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 6),
    p=st.integers(2, 40),
    q=st.integers(1, 6),
    t_max=st.sampled_from([16, 32, 80]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rnl_kernel_property(b, p, q, t_max, seed):
    rng = np.random.default_rng(seed)
    t_in = jnp.asarray(rng.integers(0, t_max + 4, (b, p)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 8, (p, q)), jnp.float32)
    thr = float(rng.uniform(0.5, p * 2))
    got = rnl_fire_pallas(t_in, w, thr, t_max, 7)
    want = ref.rnl_fire_ref(t_in, w, thr, t_max)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_one_hot_plane_algebra():
    """min(relu(d), w) == relu(d) - sum_v 1[w==v] relu(d - v)."""
    rng = np.random.default_rng(1)
    t_in = jnp.asarray(rng.integers(0, 40, (3, 21)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 8, (21, 4)), jnp.float32)
    a = ref.rnl_fire_ref(t_in, w, 11.0, 32)
    b = ref.rnl_fire_ref_planes(t_in, w, 11.0, 32, 7)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_weight_planes_partition():
    w = jnp.asarray([[0, 3], [7, 1]], jnp.float32)
    planes = make_weight_planes(w, 7)
    assert planes.shape == (8, 2, 2)
    np.testing.assert_allclose(np.asarray(planes.sum(0)), 1.0)  # partition


@pytest.mark.parametrize("p,q", [(13, 3), (270, 25), (650, 130), (7, 1)])
def test_stdp_kernel_vs_oracle(p, q):
    rng = np.random.default_rng(p)
    w = jnp.asarray(rng.uniform(0, 7, (p, q)), jnp.float32)
    x = jnp.asarray(rng.integers(0, 20, (p,)), jnp.int32)
    y = jnp.asarray(rng.integers(0, 20, (q,)), jnp.int32)
    got = stdp_update_pallas(w, x, y, 0.5, 0.5, 1 / 1024, 7, 16)
    want = ref.stdp_ref(w, x, y, 0.5, 0.5, 1 / 1024, 7, 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_kernel_column_forward_matches_core():
    """ops.column_forward (kernel path) == core solver on integer weights."""
    cfg = ColumnConfig(p=65, q=2, t_max=64, neuron=NeuronConfig(threshold=20.0))
    rng = np.random.default_rng(3)
    params = {"w": jnp.asarray(rng.integers(0, 8, (65, 2)), jnp.float32)}
    x = jnp.asarray(rng.integers(0, 64, (9, 65)), jnp.int32)
    y_kernel = ops.column_forward(params, x, cfg)
    t_core = neuron.fire_times(x, params["w"], cfg.neuron, cfg.t_max, "event")
    y_core = ref.wta_ref(t_core, 1, cfg.t_max)
    np.testing.assert_array_equal(np.asarray(y_kernel), np.asarray(y_core))


def test_kernel_online_training_runs():
    cfg = ColumnConfig(p=16, q=2, t_max=32, neuron=NeuronConfig(threshold=8.0))
    rng = np.random.default_rng(4)
    params = {"w": jnp.asarray(rng.integers(0, 8, (16, 2)), jnp.float32)}
    x = jnp.asarray(rng.integers(0, 32, (6, 16)), jnp.int32)
    out = ops.train_volleys(params, x, cfg)
    w = np.asarray(out["w"])
    assert w.shape == (16, 2) and np.all(w >= 0) and np.all(w <= 7)
