"""Volley-blocked fused scan (ISSUE 4 acceptance).

The contract under test:
  * blocking is a throughput knob, NEVER a semantic one: the blocked scan
    is BIT-IDENTICAL to the per-volley scan (``v_blk=1``) for every block
    size, including blocks that do not divide the volley count (the tail
    is silent-padded and a silent volley is an exact weight no-op);
  * the volley-blocked kernel (interpreter standing in for Mosaic
    off-TPU) — one kernel invocation per block, in-kernel sequential
    ``fori_loop``, VMEM-resident weights — matches the reference blocked
    body exactly on heterogeneous padded design batches;
  * a padded D=1 blocked fit stays bit-identical to ``mode='cycle'`` on
    integer weights (the fused contract, end to end through blocking);
  * the batched assignment pass (``assign_padded``) equals per-design,
    per-volley assignment — blocked reference body on float weights,
    grid-batched kernel on integer-grid weights;
  * the central block-size policy (``backend.volley_block``) and the
    weight-grid-aware assignment lowering (``backend.assign_lowering``)
    pick sane, clamped values.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend, column
from repro.core.types import ColumnConfig, NeuronConfig, STDPConfig, TIME_DTYPE
from repro.kernels import fused_column


def padded_batch(seed=0, d=3, p_pad=20, q_pad=5, t_window=24, n=7):
    """Heterogeneous integer-grid designs sharing one padding envelope.

    ``n=7`` volleys on purpose: no default block size divides it, so every
    blocked run exercises the silent-padded tail.
    """
    rng = np.random.default_rng(seed)
    thresholds = jnp.asarray([7.0, 4.0, 5.0][:d], jnp.float32)
    t_maxes = jnp.asarray([24, 12, 20][:d], TIME_DTYPE)
    q_actives = jnp.asarray([5, 2, 3][:d], TIME_DTYPE)
    w = jnp.asarray(rng.integers(0, 8, (d, p_pad, q_pad)), jnp.float32)
    xs = jnp.asarray(rng.integers(0, 28, (n, d, p_pad)), TIME_DTYPE)
    return w, xs, thresholds, t_maxes, q_actives, t_window


def run_padded(lowering, v_blk, seed=0, n=7, **kw):
    w, xs, th, tm, qa, t_window = padded_batch(seed=seed, n=n)
    args = dict(
        t_window=t_window, w_max=7, wta_k=1, mu_capture=1.0,
        mu_backoff=1.0, mu_search=1.0, stabilize=False, response="rnl",
        epochs=2, lowering=lowering, v_blk=v_blk,
    )
    args.update(kw)
    return fused_column.fit_scan_padded(w, xs, th, tm, qa, **args)


def test_blocked_reference_bit_identical_across_block_sizes():
    """Acceptance: every v_blk — dividing or not, larger than N or not —
    reproduces the per-volley (v_blk=1) scan bit for bit."""
    w_1 = np.asarray(run_padded("reference", v_blk=1))
    for v_blk in (2, 3, 5, 7, 8, 16):
        w_b = np.asarray(run_padded("reference", v_blk=v_blk))
        np.testing.assert_array_equal(
            w_1, w_b, err_msg=f"v_blk={v_blk} diverges from per-volley scan"
        )
    # stabilizer path (off-grid weights): still identical across blocking,
    # because blocking never changes the arithmetic, only the batching
    w_1s = np.asarray(run_padded("reference", v_blk=1, stabilize=True))
    w_3s = np.asarray(run_padded("reference", v_blk=3, stabilize=True))
    np.testing.assert_array_equal(w_1s, w_3s)


def test_blocked_tail_is_masked_even_for_degenerate_thresholds():
    """threshold <= 0 makes a fully-silent volley fire every neuron at
    t=0, so the sentinel alone would NOT make tail volleys no-ops — the
    per-block valid count must mask them.  Regression: v_blk must not
    change results even for such degenerate designs."""
    rng = np.random.default_rng(11)
    w = jnp.asarray(rng.integers(1, 8, (1, 8, 3)), jnp.float32)
    xs = jnp.asarray(rng.integers(0, 10, (3, 1, 8)), TIME_DTYPE)
    th = jnp.asarray([0.0], jnp.float32)  # degenerate: silence still fires
    tm = jnp.asarray([10], TIME_DTYPE)
    qa = jnp.asarray([3], TIME_DTYPE)
    args = dict(
        t_window=10, w_max=7, wta_k=1, mu_capture=1.0, mu_backoff=1.0,
        mu_search=1.0, stabilize=False, response="rnl", epochs=1,
    )
    outs = {
        (low, vb): np.asarray(fused_column.fit_scan_padded(
            jnp.array(w, copy=True), xs, th, tm, qa,
            lowering=low, v_blk=vb, **args,
        ))
        for low, vb in (
            ("reference", 1), ("reference", 2), ("interpret", 2),
        )
    }
    np.testing.assert_array_equal(
        outs[("reference", 1)], outs[("reference", 2)],
        err_msg="tail volleys leaked into the weight fold (reference)",
    )
    np.testing.assert_array_equal(
        outs[("reference", 1)], outs[("interpret", 2)],
        err_msg="tail volleys leaked into the weight fold (kernel)",
    )


def test_blocked_kernel_bit_identical_to_reference():
    """The volley-blocked kernel (one invocation per block, in-kernel
    sequential loop) == blocked reference body, heterogeneous designs,
    non-dividing block, both k-WTA branches."""
    for kw in (dict(), dict(wta_k=2, seed=1)):
        w_ref = np.asarray(run_padded("reference", v_blk=3, **kw))
        w_int = np.asarray(run_padded("interpret", v_blk=3, **kw))
        np.testing.assert_array_equal(w_ref, w_int)
    # default (policy-chosen) block sizes differ per lowering; results
    # must not
    w_ref = np.asarray(run_padded("reference", v_blk=None))
    w_int = np.asarray(run_padded("interpret", v_blk=None))
    np.testing.assert_array_equal(w_ref, w_int)


def test_blocked_scan_matches_cycle_on_integer_weights():
    """D=1 blocked padded fit == mode='cycle' column fit on the integer
    grid — the fused contract survives blocking end to end."""
    cfg = ColumnConfig(
        p=11, q=3, t_max=18,
        neuron=NeuronConfig(threshold=6.0, w_max=7),
        stdp=STDPConfig(
            mu_capture=1.0, mu_backoff=1.0, mu_search=1.0, stabilizer="none"
        ),
    )
    rng = np.random.default_rng(3)
    w0 = jnp.asarray(rng.integers(0, 8, (cfg.p, cfg.q)), jnp.float32)
    x = jnp.asarray(rng.integers(0, cfg.t_max + 4, (10, cfg.p)), jnp.int32)

    p_cyc, _ = backend.get("cycle").fit(
        {"w": w0}, x, cfg, "cycle", 2, None, False, None
    )
    for v_blk in (1, 4):
        w_blk = fused_column.fit_scan_padded(
            w0[None], x[:, None, :].astype(TIME_DTYPE),
            jnp.asarray([cfg.neuron.threshold], jnp.float32),
            jnp.asarray([cfg.t_max], TIME_DTYPE),
            jnp.asarray([cfg.q], TIME_DTYPE),
            t_window=cfg.t_max, w_max=cfg.neuron.w_max, wta_k=cfg.wta.k,
            mu_capture=1.0, mu_backoff=1.0, mu_search=1.0, stabilize=False,
            response="rnl", epochs=2, lowering="reference", v_blk=v_blk,
        )
        np.testing.assert_array_equal(
            np.asarray(p_cyc["w"]), np.asarray(w_blk[0]),
            err_msg=f"v_blk={v_blk} diverges from mode='cycle'",
        )


def _assign_single_volley(w, xs, th, tm, qa, t_window, n):
    """Per-design, per-volley assignment spec (the pre-blocking loop)."""
    d = w.shape[0]
    out = np.zeros((d, n), np.int64)
    for di in range(d):
        for vi in range(n):
            t = fused_column.fire_dense_ref(
                w[di], xs[vi, di], th[di], t_window, t_max=tm[di],
                response="rnl",
            )
            t = np.asarray(
                jnp.where(
                    jnp.arange(w.shape[2]) < qa[di], t, tm[di]
                )
            )
            out[di, vi] = (
                int(t.argmin()) if (t < int(tm[di])).any() else int(qa[di])
            )
    return out


def test_assign_padded_identity_vs_single_volley_assignment():
    """Acceptance: the batched assignment pass == per-design single-volley
    assignment, for float weights (reference, blocked) and integer-grid
    weights (kernel, volleys batched into the grid)."""
    rng = np.random.default_rng(5)
    w_int, xs, th, tm, qa, t_window = padded_batch(seed=5, n=9)
    spec = _assign_single_volley(
        np.asarray(w_int), np.asarray(xs), np.asarray(th), np.asarray(tm),
        np.asarray(qa), t_window, 9,
    )
    for v_blk in (1, 4, None):
        got = fused_column.assign_padded(
            w_int, xs, th, tm, qa, t_window=t_window, wta_k=1,
            response="rnl", lowering="reference", v_blk=v_blk,
        )
        np.testing.assert_array_equal(spec, np.asarray(got))
    # the kernel lowering (grid-batched, integer-grid fire) agrees on
    # integer weights
    got_k = fused_column.assign_padded(
        w_int, xs, th, tm, qa, t_window=t_window, wta_k=1,
        response="rnl", lowering="interpret", w_max=7,
    )
    np.testing.assert_array_equal(spec, np.asarray(got_k))
    # float weights: the reference body keeps the established float fire
    w_f = w_int + jnp.asarray(
        rng.uniform(-0.45, 0.45, w_int.shape), jnp.float32
    )
    spec_f = _assign_single_volley(
        np.asarray(w_f), np.asarray(xs), np.asarray(th), np.asarray(tm),
        np.asarray(qa), t_window, 9,
    )
    got_f = fused_column.assign_padded(
        w_f, xs, th, tm, qa, t_window=t_window, wta_k=1,
        response="rnl", lowering="reference",
    )
    np.testing.assert_array_equal(spec_f, np.asarray(got_f))
    # the kernel lowering refuses to run without the grid parameter
    with pytest.raises(ValueError, match="w_max"):
        fused_column.assign_padded(
            w_int, xs, th, tm, qa, t_window=t_window, wta_k=1,
            response="rnl", lowering="interpret",
        )


def test_volley_block_policy_and_assign_lowering(monkeypatch):
    """The central heuristics: small unrolled blocks for the reference
    lowering, larger in-kernel blocks for the kernels, clamped to the
    stream; the assignment kernel only ever picked for on-grid weights."""
    assert backend.volley_block("reference", 100) == 8
    assert backend.volley_block("mosaic", 100) == 32
    assert backend.volley_block("interpret", 100) == 32
    assert backend.volley_block("reference", 3) == 3
    assert backend.volley_block("mosaic", 1) == 1
    # envelope-aware unroll cap: a known small design axis slims the
    # unrolled reference block (cheap traces), never below 2, never above
    # the D-free default, and never affects the in-kernel lowerings
    assert backend.volley_block("reference", 100, d=1) == 2
    assert backend.volley_block("reference", 100, d=2) == 4
    assert backend.volley_block("reference", 100, d=3) == 6
    assert backend.volley_block("reference", 100, d=4) == 8
    assert backend.volley_block("reference", 100, d=64) == 8
    assert backend.volley_block("reference", 3, d=4) == 3  # stream clamp
    assert backend.volley_block("mosaic", 100, d=1) == 32
    assert backend.volley_block("interpret", 100, d=2) == 32
    w_grid = jnp.asarray([[2.0, 3.0]])
    w_off = jnp.asarray([[2.0, 3.5]])
    # off-TPU: reference everywhere
    assert backend.assign_lowering("rnl", w_grid) == backend.pallas_lowering()
    monkeypatch.setattr(backend, "on_tpu", lambda: True)
    assert backend.assign_lowering("rnl", w_grid) == "mosaic"
    assert backend.assign_lowering("rnl", w_off) == "reference"
    assert backend.assign_lowering("snl", w_grid) == "reference"


def test_blocked_scan_still_one_trace_per_envelope(compile_counter):
    """Changing every runtime operand on the blocked scan retraces
    nothing; changing v_blk (a static envelope knob) is a new trace."""
    fn = fused_column.fit_scan_padded
    w, xs, th, tm, qa, _ = padded_batch(seed=2, t_window=23, n=7)
    args = dict(
        t_window=23, w_max=7, wta_k=1, mu_capture=1.0, mu_backoff=1.0,
        mu_search=1.0, stabilize=False, response="rnl", epochs=2,
        lowering="reference", v_blk=4,
    )
    with compile_counter.expect_traces(fn, 1):
        fn(w, xs, th, tm, qa, **args)
    w2, xs2, *_ = padded_batch(seed=3, t_window=23, n=7)
    # per-design scalars are runtime operands of the blocked scan;
    # changing them must not recompile
    with compile_counter.expect_traces(fn, 0):
        fn(
            w2, xs2,
            jnp.asarray([3.0, 9.0, 6.0], jnp.float32),
            jnp.asarray([16, 23, 8], TIME_DTYPE),
            jnp.asarray([1, 4, 2], TIME_DTYPE),
            **args,
        )
    w3, xs3, th3, tm3, qa3, _ = padded_batch(seed=2, t_window=23, n=7)
    with compile_counter.expect_traces(fn, 1):  # v_blk is envelope
        fn(w3, xs3, th3, tm3, qa3, **{**args, "v_blk": 7})
