"""Hardware generator: RTL structure, flow model calibration, forecasting."""
import os
import re
import tempfile

import numpy as np
import pytest

from repro.configs.tnn_columns import all_benchmarks, hardware_spec
from repro.hwgen import flow, pdk, rtl, tcl
from repro.hwgen.forecast import Forecaster, PaperForecaster


SPEC = rtl.ColumnSpec(name="t65x2", p=65, q=2, theta=56, t_max=64)


def _count(text, word):
    return len(re.findall(rf"(?<![\w$]){word}(?![\w$])", text))


def test_rtl_files_generated_and_balanced():
    files = rtl.generate_column(SPEC)
    assert set(files) >= {
        "rnl_unit.v", "neuron.v", "wta_inhibit.v", "stdp_unit.v",
        "tnn_column_t65x2.v", "tb_t65x2.v",
    }
    for name, text in files.items():
        assert _count(text, "module") == _count(text, "endmodule") == 1, name
        assert _count(text, "begin") == _count(text, "end"), name
        assert _count(text, "generate") == _count(text, "endgenerate"), name


def test_rtl_top_parameters_match_spec():
    top = rtl.generate_column_top(SPEC)
    assert "parameter P      = 65" in top
    assert "parameter Q      = 2" in top
    assert "parameter W_BITS = 3" in top
    assert f"THETA({SPEC.theta})" in top
    assert "stdp_unit" in top and "rnl_unit" in top and "wta_inhibit" in top


def test_rtl_module_interfaces():
    u = rtl.generate_rnl_unit(SPEC)
    for port in ("clk", "rst", "in_spike", "weight", "ramping"):
        assert re.search(rf"\b{port}\b", u), port
    s = rtl.generate_stdp_unit(SPEC)
    for port in ("gamma_end", "x_spiked", "y_spiked", "lfsr_capture"):
        assert re.search(rf"\b{port}\b", s), port


def test_netlist_stats_linear_in_synapses():
    s1 = rtl.netlist_stats(rtl.ColumnSpec("a", 100, 2, 50))
    s2 = rtl.netlist_stats(rtl.ColumnSpec("b", 200, 2, 50))
    assert s2["synapses"] == 2 * s1["synapses"]
    # per-synapse flop cost dominates
    assert s2["flops"] > 1.8 * s1["flops"]


def test_tcl_scripts_reference_design_and_library():
    scripts = tcl.generate_flow_scripts(SPEC, "tnn7")
    synth = scripts["synth_tnn7.tcl"]
    assert "tnn_column_t65x2" in synth and "syn_map" in synth
    assert "TNN7" in synth or "tnn7" in synth
    pnr = scripts["pnr_tnn7.tcl"]
    assert "routeDesign" in pnr and "report_power -leakage" in pnr
    # paper scope note: no DRC/LVS signoff
    assert "DRC" in pnr


def test_flow_matches_paper_tables_within_jitter():
    """ModelExecutor interpolates through Tables III/IV; every cell must
    land within the 2% P&R-noise jitter envelope."""
    for name in all_benchmarks():
        spec = hardware_spec(name)
        idx = [b for b, _ in pdk.PAPER_DESIGNS].index(name)
        for lib in pdk.LIBRARIES:
            res = flow.run_flow(spec, lib)
            area_ref = pdk.PAPER_AREA[lib][idx]
            leak_ref = pdk.PAPER_LEAKAGE[lib][idx]
            assert abs(res.area_um2 - area_ref) / area_ref < 0.025, (name, lib)
            assert abs(res.leakage_uw - leak_ref) / leak_ref < 0.025, (name, lib)


def test_flow_writes_artifacts():
    with tempfile.TemporaryDirectory() as d:
        res = flow.run_flow(SPEC, "asap7", build_root=d)
        base = os.path.join(d, "t65x2_asap7")
        assert os.path.exists(os.path.join(base, "tnn_column_t65x2.v"))
        assert os.path.exists(os.path.join(base, "synth_asap7.tcl"))
        assert os.path.exists(os.path.join(base, "flow_result.json"))
        rpt = os.path.join(base, "reports",
                           "tnn_column_t65x2_asap7_pnr_summary.rpt")
        assert os.path.exists(rpt)
        assert res.total_runtime_s > 0


def test_cadence_executor_refuses_cleanly():
    with pytest.raises(RuntimeError):
        flow.run_flow(SPEC, "tnn7", executor=flow.CadenceExecutor())


def test_paper_forecaster_reproduces_table5():
    pf = PaperForecaster()
    # Table V: 6750 -> FC area 37435.1 (+0.2% reported error basis), leak 35.77
    assert abs(pf.area_um2(6750) - 37435.1) < 0.5
    assert abs(pf.leakage_uw(6750) - 35.79) < 0.05
    assert abs(pf.area_um2(130) - 627.9) < 0.5  # smallest design row


def test_refit_forecaster_close_to_paper_model():
    runs = [flow.run_flow(hardware_spec(n), "tnn7") for n in all_benchmarks()]
    fc = Forecaster()
    fc.add_runs(runs)
    fc.fit("tnn7")
    a = fc.area_um2(6750)
    assert abs(a - 35303.88) / 35303.88 < 0.05  # near the paper's actual


def test_tnn7_vs_asap7_headline_reductions():
    syn = [s for _, s in pdk.PAPER_DESIGNS]
    area = np.mean([
        1 - pdk.MODELS["tnn7"].area_um2(s) / pdk.MODELS["asap7"].area_um2(s)
        for s in syn
    ])
    leak = np.mean([
        1 - pdk.MODELS["tnn7"].leakage_uw(s) / pdk.MODELS["asap7"].leakage_uw(s)
        for s in syn
    ])
    assert abs(area - 0.321) < 0.05   # paper: 32.1%
    assert abs(leak - 0.386) < 0.06   # paper: 38.6%


def test_runtime_model_headline_claims():
    spec = hardware_spec("WordSynonyms")  # largest
    asap = flow.run_flow(spec, "asap7")
    tnn7 = flow.run_flow(spec, "tnn7")
    synth_x = asap.synth_runtime_s / tnn7.synth_runtime_s
    total_red = 1 - tnn7.total_runtime_s / asap.total_runtime_s
    assert 2.5 < synth_x < 3.6          # ~3x synthesis speedup
    assert 0.40 < total_red < 0.55      # ~47% total-flow reduction
