"""Distributed runtime: checkpoint/restart, determinism, elastic resume,
straggler monitor, gradient compression, sharding rules."""
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.tokens import DataConfig, TokenSource
from repro.distributed.checkpoint import Checkpointer
from repro.distributed.optimizer import (
    Adafactor, AdamW, ErrorFeedbackInt8, Schedule, make_optimizer,
)
from repro.distributed.straggler import RebalancePolicy, StepMonitor
from repro.distributed.train_loop import TrainConfig, Trainer


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_latest():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
        ck.save(3, tree, blocking=True)
        ck.save(7, jax.tree.map(lambda x: x * 2, tree), blocking=True)
        assert ck.latest_step() == 7
        like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
        out, step = ck.restore(like)
        assert step == 7
        np.testing.assert_allclose(np.asarray(out["a"]), np.arange(6.0).reshape(2, 3) * 2)


def test_checkpoint_interrupted_save_invisible():
    """A .tmp directory (simulated mid-write preemption) must not be
    restorable; the previous complete step remains LATEST."""
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        tree = {"a": jnp.ones(3)}
        ck.save(1, tree, blocking=True)
        os.makedirs(os.path.join(d, "step_2.tmp"))  # torn write
        assert ck.latest_step() == 1
        out, step = ck.restore(jax.tree.map(jnp.zeros_like, tree))
        assert step == 1


def test_checkpoint_structure_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, {"a": jnp.ones(3)}, blocking=True)
        with pytest.raises(ValueError):
            ck.restore({"a": jnp.zeros(3), "b": jnp.zeros(2)})


# ------------------------------------------------------------- data
def test_data_restart_determinism_and_elastic_resharding():
    cfg = DataConfig(vocab_size=100, global_batch=8, seq_len=16, seed=1)
    src = TokenSource(cfg)
    a = src.global_batch_at(5)
    b = src.global_batch_at(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    # re-sharding is a pure re-slice of the same global batch
    s0 = src.shard_at(5, 0, 4)
    s1 = src.shard_at(5, 1, 4)
    full = np.asarray(a["tokens"])
    np.testing.assert_array_equal(np.asarray(s0["tokens"]), full[:2])
    np.testing.assert_array_equal(np.asarray(s1["tokens"]), full[2:4])
    wide = src.shard_at(5, 0, 2)
    np.testing.assert_array_equal(np.asarray(wide["tokens"]), full[:4])


# ------------------------------------------------------------- trainer
def test_trainer_checkpoint_restart_bitexact():
    """Run 6 steps straight vs preempt-after-3 + resume (same config, so
    the LR schedule horizon is identical): losses must match."""
    arch = get_arch("mamba2-370m", smoke=True)
    dc = DataConfig(vocab_size=arch.vocab_size, global_batch=4, seq_len=16)
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        tc_a = TrainConfig(steps=6, checkpoint_every=100, checkpoint_dir=d1,
                           warmup_steps=2)
        straight = Trainer(arch, dc, tc_a).run()["losses"]
        tc_b = TrainConfig(steps=6, checkpoint_every=3, checkpoint_dir=d2,
                           warmup_steps=2)
        Trainer(arch, dc, tc_b).run(stop_after=3)   # preempted
        resumed = Trainer(arch, dc, tc_b).run()["losses"]  # restores step 3
        np.testing.assert_allclose(straight[3:], resumed, rtol=1e-5, atol=1e-6)


def test_trainer_microbatch_equivalence():
    """Gradient accumulation over microbatches ~= single large batch."""
    arch = get_arch("granite-3-8b", smoke=True)
    dc = DataConfig(vocab_size=arch.vocab_size, global_batch=8, seq_len=8)
    l1 = Trainer(arch, dc, TrainConfig(steps=2, microbatches=1, warmup_steps=1)).run()["losses"]
    l2 = Trainer(arch, dc, TrainConfig(steps=2, microbatches=4, warmup_steps=1)).run()["losses"]
    np.testing.assert_allclose(l1, l2, rtol=2e-3, atol=2e-3)


def test_trainer_elastic_resume_different_mesh():
    """Checkpoint on 1 'device', resume on a 4-device (2x2) mesh, in a
    subprocess (device count must be set before jax init)."""
    code = textwrap.dedent("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, numpy as np
        from repro.configs import get_arch
        from repro.data.tokens import DataConfig
        from repro.distributed.train_loop import TrainConfig, Trainer
        from repro.distributed.elastic import resume_elastic

        arch = get_arch("granite-3-8b", smoke=True)
        dc = DataConfig(vocab_size=arch.vocab_size, global_batch=4, seq_len=16)
        with tempfile.TemporaryDirectory() as d:
            tc = TrainConfig(steps=2, checkpoint_every=2, checkpoint_dir=d,
                             warmup_steps=1)
            Trainer(arch, dc, tc, mesh=None).run()   # "old topology"
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            tc2 = TrainConfig(steps=4, checkpoint_every=2, checkpoint_dir=d,
                              warmup_steps=1)
            tr = resume_elastic(arch, dc, tc2, mesh)
            out = tr.run()
            assert len(out["losses"]) == 2      # steps 2..3
            assert all(np.isfinite(out["losses"]))
            print("ELASTIC_OK")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=dict(os.environ, PYTHONPATH="src"),
        timeout=600,
    )
    assert "ELASTIC_OK" in r.stdout, r.stderr[-3000:]


# ------------------------------------------------------------- straggler
def test_straggler_monitor_flags_outliers():
    m = StepMonitor(window=20, threshold=2.0, warmup=3)
    for i in range(10):
        m.observe(i, 0.1)
    ev = m.observe(10, 0.5)
    assert ev is not None and ev.ratio > 2
    assert not m.should_rebalance(patience=3)
    m.observe(11, 0.5)
    m.observe(12, 0.55)
    assert m.should_rebalance(patience=3)


def test_rebalance_policy_conserves_batch():
    pol = RebalancePolicy(num_shards=4, shave=0.25)
    w = pol.apply(slow_shard=2)
    assert abs(sum(w) - 4.0) < 1e-9
    assert w[2] < 1.0 and all(x > 1.0 for i, x in enumerate(w) if i != 2)


# ------------------------------------------------------------- optimizer
def test_adamw_and_adafactor_reduce_loss_quadratic():
    """Both optimizers must descend on a quadratic."""
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)), jnp.float32)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    for opt in (AdamW(Schedule(peak_lr=0.05, warmup_steps=1, total_steps=100),
                      weight_decay=0.0),
                Adafactor(Schedule(peak_lr=0.5, warmup_steps=1, total_steps=100))):
        params = {"w": jnp.zeros((8, 8))}
        state = opt.init(params)
        l0 = float(loss(params))
        for _ in range(40):
            g = jax.grad(loss)(params)
            params, state = opt.update(params, g, state)
        assert float(loss(params)) < 0.5 * l0, type(opt).__name__


def test_adafactor_state_is_factored():
    opt = Adafactor(Schedule())
    params = {"w": jnp.zeros((16, 32)), "b": jnp.zeros((32,))}
    st = opt.init(params)
    assert st["vr"]["w"].shape == (16,)
    assert st["vc"]["w"].shape == (32,)
    assert st["m"]["w"].dtype == jnp.bfloat16


def test_error_feedback_compression_converges():
    """EF-int8 compressed descent matches uncompressed within tolerance."""
    target = jnp.asarray(np.random.default_rng(1).normal(size=(16, 16)), jnp.float32)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    def run(compress):
        opt = make_optimizer(
            "adamw", Schedule(peak_lr=0.05, warmup_steps=1, total_steps=200),
            compress=compress,
        )
        opt.weight_decay = 0.0
        params = {"w": jnp.zeros((16, 16))}
        state = opt.init(params)
        for _ in range(60):
            g = jax.grad(loss)(params)
            params, state = opt.update(params, g, state)
        return float(loss(params))

    plain, comp = run(False), run(True)
    assert comp < 2.0 * plain + 1e-3


def test_ef_quantization_residual_identity():
    ef = ErrorFeedbackInt8()
    g = {"a": jnp.asarray(np.random.default_rng(2).normal(size=(32,)), jnp.float32)}
    r = ef.init(g)
    gq, r2 = ef.apply(g, r)
    np.testing.assert_allclose(
        np.asarray(gq["a"] + r2["a"]), np.asarray(g["a"]), rtol=1e-6, atol=1e-6
    )


# ------------------------------------------------------------- sharding rules
def test_sharding_rules_cover_every_param():
    """Every leaf of every full arch gets a spec with ndim == leaf ndim and
    only valid axis names."""
    import repro.configs as C
    from repro.distributed import sharding
    from repro.models import transformer as T

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    for arch_id in C.ARCH_IDS:
        cfg = C.get_arch(arch_id)
        shapes = jax.eval_shape(lambda: T.init_params(jax.random.key(0), cfg))
        specs = sharding.param_specs(shapes, FakeMesh())
        flat_s, _ = jax.tree_util.tree_flatten(specs)
        flat_p, _ = jax.tree_util.tree_flatten(shapes)
        assert len(flat_s) == len(flat_p)
        for sp, leaf in zip(flat_s, flat_p):
            assert len(sp) <= leaf.ndim, (arch_id, sp, leaf.shape)
            # sharded dims must divide
            for dim, names in zip(leaf.shape, tuple(sp) + (None,) * leaf.ndim):
                if names is None:
                    continue
                names = names if isinstance(names, tuple) else (names,)
                prod = 1
                for nm in names:
                    prod *= FakeMesh.shape[nm]
                assert dim % prod == 0, (arch_id, sp, leaf.shape)
