"""Durable serving: snapshot+WAL crash recovery (ISSUE 9 acceptance).

The contract under test (``repro.serve.durability`` +
``ClusteringService.recover``):

* live weights mutate only at committed re-fits, each committed re-fit's
  exact input window is WAL-logged (fsync'd) after the in-memory commit,
  and snapshots publish atomically BEFORE the WAL truncates — so at
  every instant (latest snapshot) + (WAL tail) reproduces the live
  weights **bit-identical**, losing at most the re-fit in flight;
* ``recover(dir)`` rebuilds the fleet from ``meta.json``, restores the
  newest snapshot, replays the WAL tail through the same ladder/commit
  path, and refuses a directory whose fingerprint does not match the
  reconstructed service;
* the WAL reader tolerates a torn trailing line (the DSE journal's
  defensive-read rule); snapshot retention stays bounded via pruning;
* the SIGKILL test drives a REAL process to death mid-serve (mirroring
  ``test_faults.py``'s DSE kill-and-resume test) and proves the
  recovered service matches an uninterrupted reference bit-for-bit —
  weights AND subsequent assignments.
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import simulator
from repro.core.types import ColumnConfig
from repro.serve import ClusteringService, RequestRejected, durability
from repro.serve.durability import DurableStore, VolleyWAL

P, T_MAX = 12, 16


def _cfg(q=4, t_max=T_MAX) -> ColumnConfig:
    c = ColumnConfig(p=P, q=q, t_max=t_max)
    return c.with_threshold(simulator.suggest_threshold(c))


def _fleet(n=2) -> dict:
    return {f"d{i}": _cfg(q=3 + (i % 2)) for i in range(n)}


def _drive(service, rng, n, names=None):
    names = names or list(service.designs())
    for k in range(n):
        service.submit(rng.normal(size=P), names[k % len(names)])
    service.flush()


# ------------------------------------------------------------------ WAL
def test_wal_header_append_and_torn_tail(tmp_path):
    wal = VolleyWAL(str(tmp_path / "wal.jsonl"))
    wal.create("fp16")
    wal.append({"kind": "refit", "seq": 1, "bucket": 0, "xs": [[1, 2]]})
    wal.append({"kind": "refit", "seq": 2, "bucket": 0, "xs": [[3, 4]]})
    assert [r["seq"] for r in wal.validate("fp16")] == [1, 2]
    # torn trailing line (killed mid-append): skipped, never fatal
    with open(wal.path, "a") as f:
        f.write('{"kind": "refit", "seq": 3, "xs": [[5')
    assert [r["seq"] for r in wal.validate("fp16")] == [1, 2]
    with pytest.raises(ValueError, match="fingerprint"):
        wal.validate("other")


def test_wal_truncate_through_keeps_newer_tail(tmp_path):
    wal = VolleyWAL(str(tmp_path / "wal.jsonl"))
    wal.create("fp")
    for seq in (1, 2, 3):
        wal.append({"kind": "refit", "seq": seq, "bucket": 0, "xs": []})
    wal.truncate_through(2, "fp")
    assert [r["seq"] for r in wal.validate("fp")] == [3]
    # header survives the rewrite
    assert wal.load()[0]["kind"] == "meta"
    with pytest.raises(ValueError, match="header"):
        VolleyWAL(str(tmp_path / "missing.jsonl")).validate("fp")


def test_durable_store_refuses_reuse_and_validates(tmp_path):
    service = ClusteringService(
        _fleet(), batch_size=4, refit_every=0,
        durable_dir=str(tmp_path / "svc"),
    )
    assert service.stats().snapshots == 0  # the seq-0 snapshot is create's
    with pytest.raises(ValueError, match="recover"):
        ClusteringService(
            _fleet(), batch_size=4, refit_every=0,
            durable_dir=str(tmp_path / "svc"),
        )
    store = DurableStore(str(tmp_path / "svc"))
    assert store.exists() and store.ckpt.latest_step() == 0
    with pytest.raises(ValueError, match="fingerprint"):
        store.attach("0000000000000000")
    with pytest.raises(FileNotFoundError, match="no durable service"):
        DurableStore(str(tmp_path / "empty")).load_meta()


# ------------------------------------------------------------- recovery
def test_recover_mid_wal_is_bit_identical_and_keeps_serving(tmp_path):
    """Snapshot at seq 8 + a 2-record WAL tail: recovery replays the tail
    and matches the live service bit-for-bit — weights and the next
    batch's assignments."""
    live = ClusteringService(
        _fleet(), batch_size=4, refit_every=4, refit_window=4, seed=7,
        durable_dir=str(tmp_path / "svc"), snapshot_every=4,
    )
    live.warmup()
    rng = np.random.default_rng(1)
    _drive(live, rng, 40)  # 10 re-fits: snapshot at 8, WAL tail {9, 10}
    st = live.stats()
    assert st.refits == 10 and st.snapshots == 2 and st.wal_records == 2

    rec = ClusteringService.recover(str(tmp_path / "svc"))
    assert rec.stats().replayed == 2
    for d in live.designs():
        np.testing.assert_array_equal(live.weights(d), rec.weights(d))

    rec.warmup()
    names = list(live.designs())
    xs = [rng.normal(size=P) for _ in range(8)]
    a = [live.submit(x, names[i % 2]).result().cluster
         for i, x in enumerate(xs)]
    b = [rec.submit(x, names[i % 2]).result().cluster
         for i, x in enumerate(xs)]
    assert a == b


def test_recover_refuses_mismatched_fleet(tmp_path):
    ClusteringService(
        _fleet(), batch_size=4, refit_every=0,
        durable_dir=str(tmp_path / "svc"),
    )
    meta_path = tmp_path / "svc" / durability.META_FILE
    meta = json.loads(meta_path.read_text())
    # tamper: the recorded fleet no longer matches the fingerprint
    meta["spec"]["seed"] = 999
    meta_path.write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="fingerprint"):
        ClusteringService.recover(str(tmp_path / "svc"))


def test_snapshot_retention_stays_bounded(tmp_path):
    service = ClusteringService(
        _fleet(1), batch_size=4, refit_every=4, refit_window=4,
        durable_dir=str(tmp_path / "svc"), snapshot_every=1, seed=0,
    )
    service.warmup()
    rng = np.random.default_rng(2)
    _drive(service, rng, 24)  # 6 re-fits, one snapshot each
    assert service.stats().snapshots == 6
    store = DurableStore(str(tmp_path / "svc"))
    steps = store.ckpt.steps()
    assert len(steps) <= durability.SNAPSHOTS_KEPT
    assert steps[-1] == store.ckpt.latest_step() == 6


def test_drain_publishes_final_snapshot(tmp_path):
    service = ClusteringService(
        _fleet(), batch_size=4, refit_every=4, refit_window=4, seed=3,
        durable_dir=str(tmp_path / "svc"), snapshot_every=4,
    )
    service.warmup()
    rng = np.random.default_rng(3)
    _drive(service, rng, 12)  # 3 re-fits: WAL tail is non-empty
    assert service.stats().wal_records == 3
    final = service.drain()
    assert final.wal_records == 0  # the drain snapshot covered the tail
    with pytest.raises(RequestRejected, match="draining"):
        service.submit(rng.normal(size=P), "d0")
    rec = ClusteringService.recover(str(tmp_path / "svc"))
    assert rec.stats().replayed == 0  # nothing left to replay
    for d in service.designs():
        np.testing.assert_array_equal(service.weights(d), rec.weights(d))


# ------------------------------------------------------ SIGKILL the serve
def test_serve_sigkill_recover_reproduces_weights_and_answers(tmp_path):
    """Acceptance: a durable service SIGKILLed mid-serve (a real process,
    right after a WAL append — mirroring the DSE kill-and-resume test)
    recovers to weights bit-identical to an uninterrupted reference run,
    and answers the next requests identically too."""
    dd = tmp_path / "svc"
    code = textwrap.dedent(f"""
        import os, signal
        import numpy as np
        from repro.core import simulator
        from repro.core.types import ColumnConfig
        from repro.serve import ClusteringService, durability

        def cfg(q):
            c = ColumnConfig(p={P}, q=q, t_max={T_MAX})
            return c.with_threshold(simulator.suggest_threshold(c))

        fleet = {{"d0": cfg(3), "d1": cfg(4)}}
        orig_append = durability.VolleyWAL.append
        count = [0]

        def killing_append(self, record):
            orig_append(self, record)  # the record IS durable
            count[0] += 1
            if count[0] == 3:
                os.kill(os.getpid(), signal.SIGKILL)  # die mid-serve

        durability.VolleyWAL.append = killing_append
        service = ClusteringService(
            fleet, batch_size=4, refit_every=4, refit_window=4, seed=7,
            durable_dir={str(dd)!r}, snapshot_every=2,
        )
        service.warmup()
        rng = np.random.default_rng(21)
        names = list(fleet)
        for k in range(64):
            service.submit(rng.normal(size={P}), names[k % 2])
        service.flush()
        raise SystemExit("unreachable: the third WAL append must kill us")
    """)
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=dict(os.environ, PYTHONPATH="src"),
        timeout=600,
    )
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr[-2000:])

    # the kill landed after commit #3's append: snapshot at seq 2, WAL
    # tail {3} — recovery must replay exactly one record
    rec = ClusteringService.recover(str(dd))
    assert rec.stats().replayed == 1

    # uninterrupted reference: same fleet/seed/stream through the same 3
    # committed re-fits (12 requests at refit_every=4, batch 4)
    ref = ClusteringService(
        {"d0": _cfg(q=3), "d1": _cfg(q=4)}, batch_size=4, refit_every=4,
        refit_window=4, seed=7,
    )
    ref.warmup()
    rng = np.random.default_rng(21)
    names = list(ref.designs())
    for k in range(12):
        ref.submit(rng.normal(size=P), names[k % 2])
    ref.flush()
    assert ref.stats().refits == 3
    for d in names:
        np.testing.assert_array_equal(
            ref.weights(d), rec.weights(d),
            err_msg=f"{d}: recovered weights differ from uninterrupted run",
        )

    # and the NEXT batch answers identically on both services
    rec.warmup()
    xs = [rng.normal(size=P) for _ in range(8)]
    a = [ref.submit(x, names[i % 2]).result().cluster
         for i, x in enumerate(xs)]
    b = [rec.submit(x, names[i % 2]).result().cluster
         for i, x in enumerate(xs)]
    assert a == b
