"""Edge-case unit tests for ``backend.envelope_buckets`` (ISSUE 8).

The sweep and the streaming service both trust this packer for the
"one compiled executable per bucket" economy; these tests pin the
degenerate corners the broader DSE tests (``test_dse.py``) never hit:
waste_cap 0 and infinity, max_bucket 1, all-identical fleets, and
wildly mismatched fleets that must NOT share an envelope.
"""
from __future__ import annotations

import math

from hypothesis import given, settings, strategies as st

from repro.core import backend


def _check_partition(shapes, buckets):
    """Every index in exactly one bucket; every envelope is the
    elementwise max of its members (contains each, exceeds none)."""
    seen = sorted(i for _, members in buckets for i in members)
    assert seen == list(range(len(shapes)))
    for env, members in buckets:
        for axis in range(3):
            assert env[axis] == max(shapes[i][axis] for i in members)


def test_waste_cap_zero_gives_all_singletons():
    """cap 0: no envelope can satisfy vol <= 0, even for an exact-fit
    member — every design compiles alone."""
    shapes = [(4, 2, 8), (4, 2, 8), (8, 4, 16)]
    buckets = backend.envelope_buckets(shapes, waste_cap=0.0)
    _check_partition(shapes, buckets)
    assert len(buckets) == len(shapes)


def test_waste_cap_inf_gives_one_bucket():
    shapes = [(1, 1, 1), (3, 9, 2), (100, 2, 64), (7, 7, 7)]
    buckets = backend.envelope_buckets(shapes, waste_cap=math.inf)
    _check_partition(shapes, buckets)
    assert len(buckets) == 1
    assert buckets[0][0] == (100, 9, 64)  # elementwise max of the fleet


def test_max_bucket_one_gives_singletons():
    shapes = [(4, 2, 8)] * 5
    buckets = backend.envelope_buckets(shapes, max_bucket=1)
    _check_partition(shapes, buckets)
    assert len(buckets) == 5
    assert all(env == (4, 2, 8) for env, _ in buckets)


def test_identical_shapes_share_one_exact_envelope():
    """All-identical fleet under the TIGHTEST useful cap (1.0): zero
    padding waste, so one bucket holds everything."""
    shapes = [(6, 3, 32)] * 7
    buckets = backend.envelope_buckets(shapes, waste_cap=1.0)
    _check_partition(shapes, buckets)
    assert len(buckets) == 1
    assert buckets[0][0] == (6, 3, 32)


def test_identical_shapes_split_by_max_bucket():
    shapes = [(6, 3, 32)] * 5
    buckets = backend.envelope_buckets(shapes, waste_cap=1.0, max_bucket=2)
    _check_partition(shapes, buckets)
    assert sorted(len(m) for _, m in buckets) == [1, 2, 2]


def test_mismatched_shapes_refuse_to_share():
    """One-shape-per-bucket degenerate: each design's volume is > cap x
    the next smaller one, so sharing any envelope would blow the waste
    budget of the smaller member — the packer must keep them apart."""
    shapes = [(2, 2, 2), (8, 8, 8), (32, 32, 32)]
    buckets = backend.envelope_buckets(shapes, waste_cap=4.0)
    _check_partition(shapes, buckets)
    assert len(buckets) == 3
    assert all(len(m) == 1 for _, m in buckets)


def test_exact_fit_member_always_packs_under_cap_one():
    """cap 1.0 still packs a design whose shape IS the envelope."""
    shapes = [(8, 4, 16), (8, 4, 16)]
    buckets = backend.envelope_buckets(shapes, waste_cap=1.0)
    assert len(buckets) == 1


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10**6),
    n=st.integers(1, 12),
    cap=st.sampled_from([1.0, 2.0, 4.0, 16.0]),
    max_bucket=st.sampled_from([1, 2, 4, None]),
)
def test_random_fleets_respect_partition_and_caps(seed, n, cap, max_bucket):
    """Property: any fleet partitions exactly once, every envelope is the
    member max, per-member waste stays within cap, and bucket sizes
    respect max_bucket."""
    import numpy as np

    rng = np.random.default_rng(seed)
    shapes = [
        (int(rng.integers(1, 64)), int(rng.integers(1, 16)),
         int(rng.integers(2, 128)))
        for _ in range(n)
    ]
    buckets = backend.envelope_buckets(
        shapes, waste_cap=cap, max_bucket=max_bucket
    )
    _check_partition(shapes, buckets)
    for env, members in buckets:
        if max_bucket is not None:
            assert len(members) <= max_bucket
        vol = env[0] * env[1] * env[2]
        for i in members:
            p, q, t = shapes[i]
            assert vol <= cap * (p * q * t)


def test_default_cap_is_used_when_unset():
    # a 2x envelope (within the default cap of 4) merges; make the pair
    # differ only on t_max so the envelope is exactly the larger shape
    shapes = [(8, 4, 16), (8, 4, 32)]
    buckets = backend.envelope_buckets(shapes)
    assert len(buckets) == 1 and buckets[0][0] == (8, 4, 32)
