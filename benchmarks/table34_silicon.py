"""Paper Tables III + IV: post-place-and-route leakage power and die area
for the seven UCR column designs across FreePDK45 / ASAP7 / TNN7.

Runs the full TNNGen flow (RTL + TCL generation + modeled EDA execution,
see hwgen/flow.py) per design x library, and reports model output alongside
the paper's published values with per-cell error — validating the flow
model's calibration end-to-end (sub-±3%: the model jitter envelope).
"""
from __future__ import annotations

import tempfile

from benchmarks.common import emit, time_call
from repro.configs.tnn_columns import all_benchmarks, hardware_spec
from repro.data.ucr import PAPER_COLUMNS
from repro.hwgen import pdk, run_flow


def run(build: bool = True) -> list:
    rows = []
    with tempfile.TemporaryDirectory() as d:
        for name in all_benchmarks():
            spec = hardware_spec(name)
            idx = [b for b, _ in pdk.PAPER_DESIGNS].index(name)
            for lib in pdk.LIBRARIES:
                res = run_flow(spec, lib, build_root=d if build else None)
                area_paper = pdk.PAPER_AREA[lib][idx]
                leak_paper = pdk.PAPER_LEAKAGE[lib][idx]
                rows.append({
                    "benchmark": name, "library": lib,
                    "synapses": res.synapses,
                    "area_um2": res.area_um2, "area_paper": area_paper,
                    "area_err_pct": 100 * (res.area_um2 - area_paper) / area_paper,
                    "leak_uw": res.leakage_uw, "leak_paper": leak_paper,
                    "leak_err_pct": 100 * (res.leakage_uw - leak_paper) / leak_paper,
                })
    return rows


def main(argv=None) -> None:
    rows = run()
    print("\n# Tables III & IV — post-P&R leakage (uW) and area (um^2)")
    print("| benchmark | lib | syn | area | area(paper) | err% | leak | leak(paper) | err% |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['benchmark']} | {r['library']} | {r['synapses']} | "
              f"{r['area_um2']:.1f} | {r['area_paper']:.1f} | "
              f"{r['area_err_pct']:+.1f} | {r['leak_uw']:.3f} | "
              f"{r['leak_paper']:.3f} | {r['leak_err_pct']:+.1f} |")
    # headline claims: TNN7 vs ASAP7 improvements (paper: 32.1% area, 38.6% leakage)
    a = [r for r in rows if r["library"] == "asap7"]
    t = [r for r in rows if r["library"] == "tnn7"]
    area_red = 100 * (1 - sum(x["area_um2"] for x in t) / sum(x["area_um2"] for x in a))
    leak_red = 100 * (1 - sum(x["leak_uw"] for x in t) / sum(x["leak_uw"] for x in a))
    print(f"\nTNN7 vs ASAP7: area -{area_red:.1f}% (paper 32.1%), "
          f"leakage -{leak_red:.1f}% (paper 38.6%)")
    for r in rows:
        emit(f"table34/{r['benchmark']}/{r['library']}", 0.0,
             f"area_err={r['area_err_pct']:+.1f}%")


if __name__ == "__main__":
    main()
