"""Paper Fig. 3 + §III-C: EDA design-flow runtime — ASAP7 vs TNN7 macros.

Reports modeled synthesis and P&R runtimes per design and validates the
paper's three headline relations the model was pinned to:
  * ~3x synthesis speedup with TNN7 macros,
  * ~32% average P&R speedup,
  * ~47% total-flow reduction for the largest (6750-synapse) design.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs.tnn_columns import all_benchmarks, hardware_spec
from repro.hwgen import run_flow


def run() -> list:
    rows = []
    for name in all_benchmarks():
        spec = hardware_spec(name)
        asap = run_flow(spec, "asap7")
        tnn7 = run_flow(spec, "tnn7")
        rows.append({
            "benchmark": name, "synapses": asap.synapses,
            "asap7_synth_s": asap.synth_runtime_s, "tnn7_synth_s": tnn7.synth_runtime_s,
            "asap7_pnr_s": asap.pnr_runtime_s, "tnn7_pnr_s": tnn7.pnr_runtime_s,
            "pnr_speedup_pct": 100 * (1 - tnn7.pnr_runtime_s / asap.pnr_runtime_s),
            "total_speedup_pct": 100 * (1 - tnn7.total_runtime_s / asap.total_runtime_s),
        })
    return rows


def main(argv=None) -> None:
    rows = run()
    print("\n# Fig. 3 — place-and-route runtime (s), ASAP7 vs TNN7")
    print("| benchmark | syn | P&R ASAP7 | P&R TNN7 | P&R speedup | total speedup |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['benchmark']} | {r['synapses']} | {r['asap7_pnr_s']:.0f} | "
              f"{r['tnn7_pnr_s']:.0f} | {r['pnr_speedup_pct']:.0f}% | "
              f"{r['total_speedup_pct']:.0f}% |")
    avg_pnr = sum(r["pnr_speedup_pct"] for r in rows) / len(rows)
    largest = max(rows, key=lambda r: r["synapses"])
    synth_x = rows[0]["asap7_synth_s"] / rows[0]["tnn7_synth_s"]
    print(f"\nsynth speedup {synth_x:.1f}x (paper ~3x); "
          f"avg P&R speedup {avg_pnr:.0f}% (paper ~32%); "
          f"largest-design total speedup {largest['total_speedup_pct']:.0f}% (paper ~47%)")
    for r in rows:
        emit(f"fig3/{r['benchmark']}", r["asap7_pnr_s"] * 1e6,
             f"pnr_speedup={r['pnr_speedup_pct']:.0f}%")


if __name__ == "__main__":
    main()
