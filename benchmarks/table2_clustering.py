"""Paper Table II: time-series clustering rand index — TNN vs DTCR vs
k-means across the seven UCR benchmarks.

Reports, per benchmark:
  * rand index for the TNN column (our JAX simulator, unsupervised STDP),
  * rand index for k-means (the paper's normalization baseline),
  * rand index for the DTCR-like deep baseline,
  * the paper's published normalized values for reference.

Data: real UCR if available (UCR_ROOT), else the synthetic doubles — the
paper-vs-ours comparison is qualitative on doubles (noted in output).
Reduced epochs/steps keep this tractable on CPU; flags can raise them.
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit, time_call
from repro.clustering.dtcr import DTCRConfig, fit_predict
from repro.clustering.kmeans import kmeans
from repro.clustering.metrics import normalized_rand, rand_index
from repro.configs.tnn_columns import column_config
from repro.core import simulator
from repro.data import ucr


def run(benchmarks=None, epochs: int = 4, dtcr_steps: int = 60,
        max_n: int = 240) -> list:
    rows = []
    for name in benchmarks or list(ucr.PAPER_COLUMNS):
        ds = ucr.load(name)
        x, y = ds.x[:max_n], ds.y[:max_n]
        k = ds.n_classes

        _, km_labels = kmeans(x, k, seed=0)
        ri_km = rand_index(y, km_labels)

        cfg = column_config(name)
        cfg = cfg.with_threshold(simulator.suggest_threshold(cfg))
        res = simulator.cluster_time_series(x, y, cfg, epochs=epochs)

        dt_labels = fit_predict(x, DTCRConfig(n_clusters=k, steps=dtcr_steps))
        ri_dtcr = rand_index(y, dt_labels)

        paper = ucr.PAPER_RAND_INDEX[name]
        rows.append({
            "benchmark": name, "synthetic": ds.synthetic,
            "ri_kmeans": ri_km, "ri_tnn": res.rand_index, "ri_dtcr": ri_dtcr,
            "tnn_norm": normalized_rand(res.rand_index, ri_km),
            "dtcr_norm": normalized_rand(ri_dtcr, ri_km),
            "paper_tnn_norm": paper["tnn"], "paper_dtcr_norm": paper["dtcr"],
            "train_seconds": res.train_seconds,
        })
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--dtcr-steps", type=int, default=60)
    ap.add_argument("--benchmarks", nargs="*", default=None)
    args = ap.parse_args(argv)
    rows = run(args.benchmarks, args.epochs, args.dtcr_steps)
    print("\n# Table II — clustering rand index (normalized to k-means)")
    print("| benchmark | data | TNN | DTCR | TNN(paper) | DTCR(paper) |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        src = "synthetic-double" if r["synthetic"] else "UCR"
        print(f"| {r['benchmark']} | {src} | {r['tnn_norm']:.3f} | "
              f"{r['dtcr_norm']:.3f} | {r['paper_tnn_norm']:.3f} | "
              f"{r['paper_dtcr_norm']:.3f} |")
    for r in rows:
        emit(f"table2/{r['benchmark']}", r["train_seconds"] * 1e6,
             f"tnn_norm={r['tnn_norm']:.3f}")


if __name__ == "__main__":
    main()
