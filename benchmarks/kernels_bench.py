"""Kernel-level benchmark: Pallas RNL/STDP kernels vs jnp oracles.

Beyond-paper measurement — the interpreter timings are NOT TPU numbers;
the derived column reports the kernel's algebraic compute shape (one-hot
plane matmul MXU FLOPs) that the roofline reasoning in DESIGN.md uses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import backend
from repro.core.types import ColumnConfig, NeuronConfig
from repro.kernels import fused_column, ref
from repro.kernels.rnl_response import rnl_fire_pallas

CASES = [(64, 65, 2, 64), (64, 270, 25, 64), (16, 637, 2, 256)]
FUSED_CASES = [(65, 2, 64), (470, 5, 64)]  # one fused train-step per volley
# padded heterogeneous batch: D designs, one kernel launch, runtime operands
PADDED_CASES = [(4, 128, 8, 64), (7, 256, 16, 64)]  # (D, p_pad, q_pad, t_win)


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    for B, p, q, t_max in CASES:
        t_in = jnp.asarray(rng.integers(0, t_max, (B, p)), jnp.int32)
        w = jnp.asarray(rng.integers(0, 8, (p, q)), jnp.float32)
        thr = p * 7 / 8

        def k_pallas():
            jax.block_until_ready(rnl_fire_pallas(t_in, w, thr, t_max, 7))

        def k_ref():
            jax.block_until_ready(ref.rnl_fire_ref(t_in, w, thr, t_max))

        us_p = time_call(k_pallas)
        us_r = time_call(k_ref)
        mxu_flops = 2 * B * 8 * p * q * t_max  # 8 one-hot plane matmuls
        rows.append({
            "case": f"B{B}_p{p}_q{q}_t{t_max}",
            "pallas_us": us_p, "ref_us": us_r, "mxu_flops": mxu_flops,
        })

    # fused column step (fire + WTA + STDP in one invocation), 8 volleys:
    # pallas column = the actual kernel (interpreter off-TPU), oracle
    # column = the jnp reference lowering of the same fused step.
    for p, q, t_max in FUSED_CASES:
        cfg = ColumnConfig(
            p=p, q=q, t_max=t_max, neuron=NeuronConfig(threshold=p * 7 / 8.0)
        )
        params = {"w": jnp.asarray(rng.integers(0, 8, (p, q)), jnp.float32)}
        x = jnp.asarray(rng.integers(0, t_max, (8, p)), jnp.int32)

        def k_fused(lowering):
            out, _ = fused_column.fit_fused(
                params, x, cfg, epochs=1, lowering=lowering
            )
            jax.block_until_ready(out["w"])

        kernel_lowering = "mosaic" if backend.on_tpu() else "interpret"
        us_k = time_call(k_fused, kernel_lowering)
        us_r = time_call(k_fused, "reference")
        mxu_flops = 2 * 8 * 8 * p * q * t_max  # planes x volleys
        rows.append({
            "case": f"fused_step_p{p}_q{q}_t{t_max}",
            "pallas_us": us_k, "ref_us": us_r, "mxu_flops": mxu_flops,
        })

    # padded heterogeneous batch: D designs with mixed runtime operands
    # (threshold / t_max / live-q in SMEM) through ONE kernel launch vs the
    # vmapped jnp reference body of the same step.
    for d, p_pad, q_pad, t_win in PADDED_CASES:
        w = jnp.asarray(rng.integers(0, 8, (d, p_pad, q_pad)), jnp.float32)
        t_in = jnp.asarray(
            rng.integers(0, t_win, (d, p_pad)), jnp.float32
        )
        thr = jnp.asarray(rng.uniform(4.0, p_pad, d), jnp.float32)
        t_maxes = jnp.asarray(rng.integers(t_win // 2, t_win + 1, d), jnp.float32)
        q_act = jnp.asarray(rng.integers(2, q_pad + 1, d), jnp.float32)
        operands = fused_column.design_operands(
            thr, t_maxes, q_act, 1.0, 1.0, 1.0
        )

        def k_padded():
            out, _ = fused_column.fused_step_pallas_padded(
                w, t_in, operands, t_window=t_win, w_max=7, wta_k=1,
                stabilize=False,
                interpret=backend.pallas_interpret(),
            )
            jax.block_until_ready(out)

        def k_padded_ref():
            out, _ = jax.vmap(
                lambda wd, xd, th, tm, qa: fused_column.fused_step_ref(
                    wd, xd, th, t_win, 7, 1, 1.0, 1.0, 1.0, False,
                    t_max=tm, response="rnl", integer_fire=True, q_active=qa,
                )
            )(w, t_in.astype(jnp.int32), thr, t_maxes.astype(jnp.int32),
              q_act.astype(jnp.int32))
            jax.block_until_ready(out)

        us_k = time_call(k_padded)
        us_r = time_call(k_padded_ref)
        mxu_flops = 2 * 8 * d * p_pad * q_pad * t_win  # planes x designs
        rows.append({
            "case": f"padded_step_d{d}_p{p_pad}_q{q_pad}_t{t_win}",
            "pallas_us": us_k, "ref_us": us_r, "mxu_flops": mxu_flops,
        })
    return rows


def main(argv=None) -> None:
    rows = run()
    print("\n# Pallas kernels (interpret mode) vs jnp oracle")
    print("| case | pallas us | oracle us | kernel MXU flops |")
    print("|---|---|---|---|")
    for r in rows:
        print(f"| {r['case']} | {r['pallas_us']:.0f} | {r['ref_us']:.0f} | "
              f"{r['mxu_flops']:.2e} |")
    for r in rows:
        emit(f"kernels/{r['case']}", r["pallas_us"], f"flops={r['mxu_flops']:.2e}")


if __name__ == "__main__":
    main()
