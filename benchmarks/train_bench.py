"""Fused online-STDP training benchmark — the ISSUE 1/2/4 perf trajectory.

Times the fused single-scan training path (one jitted, donated lax.scan over
epochs x volleys, fused fire+WTA+STDP body) against the legacy per-epoch
loop, on paper column geometries, a padded heterogeneous design sweep (the
ISSUE 3 tentpole: ONE ``fit_scan_padded`` program with runtime design
operands vs one fused fit per design) AND a multi-layer network (the ISSUE 2
tentpole: ``network.fit_greedy`` as one jitted padded scan per layer vs the
untraced per-epoch Python loop it replaced).  Since ISSUE 4 the padded
cases run the volley-blocked scan (``v_blk`` volleys per step, one kernel
invocation / one unrolled reference body per block) and report BOTH warm
and cold numbers — the blocked path must win warm throughput, not just the
compile cliff, and ``main`` prints a REGRESSION flag whenever a fused case
reports warm speedup below the ``WARM_REGRESSION_MIN`` floor and a
COLD-REGRESSION flag whenever cold speedup
falls below the tracked ``COLD_REGRESSION_MIN`` floor.  Since ISSUE 5 a
bucketed heterogeneous sweep case (``sweepbkt*``) times the envelope-
bucketed front-end against the same sweep forced into one global envelope,
and every padded case records its bucket/shard metadata.

Since ISSUE 7 cold numbers are honest about the persistent compilation
cache (``backend.compile_cache``): ``--cache fresh`` (the default) points
the run at a brand-new empty directory so every cold row is a TRUE
compile — a populated ``REPRO_COMPILE_CACHE`` inherited from the
environment can no longer masquerade as a cold compile — and each padded
row records the cache state it was measured under (``compile_cache``
column, via ``common.cache_state``).  After the in-process run, ``main``
re-measures the padded cold cases in fresh subprocesses against the
now-POPULATED cache directory (``--cold-json`` child mode) and merges the
results as ``warmproc_*`` columns: the warm-process cold start — compile
once, pay disk reads forever after — must stay within measurement parity
of the legacy path (>= ``WARMPROC_REGRESSION_MIN``, flagged
WARMPROC-REGRESSION otherwise).  ``--check`` validates the committed
floors for CI without re-running the bench.

Since ISSUE 10 ``main`` activates the device calibration
(``costmodel.load_or_calibrate``, persisted next to the compile cache so
cold-json children resolve identical plans) and every padded case records
its resolved ``ExecutionPlan`` plus a plan-vs-constants warm head-to-head
(``plan_vs_const_speedup``, PLAN-REGRESSION below ``PLAN_REGRESSION_MIN``)
and the cost model's predicted-vs-measured step-time ratio.  Emits
``BENCH_train.json`` (us/volley + MXU
FLOPs of the fused kernel algebra) so the perf trajectory — including the
reference-vs-kernel gap on the padded path (the 'lowering' column) — is
tracked PR over PR; later PRs append comparable numbers.

MXU FLOPs count the one-hot plane matmuls of the fused Pallas kernel
(2 * (w_max+1) * p * q * t_max per volley) — the work the TPU lowering puts
on the systolic array; off-TPU the reference lowering does the same algebra
on the VPU-equivalent.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cache_state, emit, time_call, time_cold, time_pair
from repro.core import backend, column, network, simulator
from repro.roofline import costmodel
from repro.core.types import (
    ColumnConfig, LayerConfig, NetworkConfig, NeuronConfig, TIME_DTYPE,
)
from repro.kernels import fused_column

# (name, B volleys, p, q, t_max) — Beef-shaped default plus small/large cols
CASES = [
    ("col65x2", 64, 65, 2, 64),
    ("col470x5", 120, 470, 5, 64),
    ("col152x2", 64, 152, 2, 100),
]
EPOCHS = 4

# Tracked cold-regression threshold (ISSUE 5 CI satellite): the padded
# fused paths knowingly trade some cold (first-call, compile-inclusive)
# time for warm throughput — the blocked trace is bigger than the legacy
# per-design/per-epoch ones, and the one-trace-vs-D-traces cliff only wins
# back with design count.  A cold_speedup below this floor is a LOUD
# COLD-REGRESSION flag in ``main``, not a silent JSON column: net96-4x8-1x5
# shipped at 0.33x unflagged before the flag existed.  Raise the floor as
# cold compiles improve; lowering it needs a recorded justification here.
COLD_REGRESSION_MIN = 0.5

# Warm floor for the tracked padded cases.  Not 1.0: sweep4x96p's fused
# and legacy sides are within measurement parity on fast hosts — a clean
# worktree of the PRE-AOT seed commit (3257c6a) measures 0.974x on the
# same host/day that the AOT build measures 0.97-0.98x, and the AOT
# dispatcher itself benches at parity with a direct jit call — so a 1.0
# floor flags host drift, not code regressions.  0.95 still catches any
# real dispatch-overhead regression (a 50us/call slip on this geometry
# is ~0.92x).  Raising it back requires a control measurement like the
# one above.
WARM_REGRESSION_MIN = 0.95

# Plan-vs-constants floor (ISSUE 10): every tracked padded case runs a
# warm head-to-head between the cost-model-chosen blocking (the active
# device calibration) and the hand-tuned constants it replaced
# (``costmodel.override(None)`` forces the fallback).  The plan side must
# hold >= this fraction of the constants' warm throughput — the cost
# model is allowed to trade within measurement parity (this host is warm-
# flat across v_blk 2..8) for its cold-compile wins, never to lose real
# warm throughput.  Without a calibration both sides resolve identically
# and the ratio is ~1.0 by construction.
PLAN_REGRESSION_MIN = 0.95

# Warm-process cold floor: a fresh process against a POPULATED cache
# deserializes instead of compiling, so the bucketed side must stay near
# the global-envelope side.  Not 1.0: with equally-populated caches the
# two sides measure within ~2% of each other in either direction on this
# host (controls: plan- and constants-chosen executables both cold-start
# at ~480ms from the same populated dir; the pre-costmodel floor passed
# at 1.014 — inside the same noise band), so an exact-parity floor flags
# deserialize jitter, not regressions.  What this floor exists to catch —
# a cache miss forcing a real recompile — measures 0.3-0.6x, far below
# it.  Raising it back requires a control like the ones above.
WARMPROC_REGRESSION_MIN = 0.95


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    for name, B, p, q, t_max in CASES:
        cfg = ColumnConfig(
            p=p, q=q, t_max=t_max,
            neuron=NeuronConfig(threshold=p * 7 / 8.0),
        )
        params = {
            "w": jnp.asarray(rng.integers(0, 8, (p, q)), jnp.float32)
        }
        x = jnp.asarray(rng.integers(0, t_max, (B, p)), jnp.int32)

        def fused():
            jax.block_until_ready(
                column.fit(params, x, cfg, epochs=EPOCHS)["w"]
            )

        def legacy():
            pr = params
            for _ in range(EPOCHS):
                pr, _ = column.train_step(pr, x, cfg, update="batch")
            jax.block_until_ready(pr["w"])

        us_fused = time_call(fused)
        us_legacy = time_call(legacy)
        volleys = EPOCHS * B
        mxu_flops = 2 * (cfg.neuron.w_max + 1) * p * q * t_max
        rows.append({
            "case": name,
            "backend": backend.resolve("auto", cfg, training=True),
            "lowering": backend.pallas_lowering(),
            "fused_us_per_volley": us_fused / volleys,
            "legacy_us_per_volley": us_legacy / volleys,
            "speedup": us_legacy / max(us_fused, 1e-9),
            "mxu_flops_per_volley": mxu_flops,
        })
    return rows


def _cold_row(case, fused_fn, legacy_fn, volleys, cache, side) -> dict:
    """One cold-measurement row for a ``--cold-json`` child.

    ``side='fused'`` / ``'legacy'`` times ONLY that closure: the first
    call in a process also pays shared one-time machinery (encode and
    metric traces, dtype-cast helpers), so timing both sides in one
    process hands that cost to whichever runs first and skews the ratio —
    the parent spawns one child per side instead.  ``side='both'`` keeps
    the single-process (order-skewed) measurement for ad-hoc debugging.
    """
    row = {"case": case, "compile_cache": cache}
    if side in ("fused", "both"):
        row["cold_us_per_volley"] = time_cold(fused_fn) / volleys
    if side in ("legacy", "both"):
        row["cold_legacy_us_per_volley"] = time_cold(legacy_fn) / volleys
    if side == "both":
        row["cold_speedup"] = row["cold_legacy_us_per_volley"] / max(
            row["cold_us_per_volley"], 1e-9
        )
    return row


# ------------------------------------------------------- padded design sweep
SWEEP_B = 64  # volleys per epoch
# heterogeneous candidates sharing one envelope: (q, t_max) per design,
# p pinned by the stream as in simulator.cluster_time_series_many
SWEEP_P = 96
SWEEP_DESIGNS = [(5, 32), (5, 64), (10, 32), (10, 64)]


def run_sweep(
    cold_only: bool = False, cache: str | None = None, side: str = "both"
) -> dict:
    """Padded heterogeneous design sweep: ONE fit_scan_padded program
    (runtime design operands, one trace for the whole batch) vs the legacy
    per-design loop (one fused fit per design, D separate compilations).
    The reference-vs-kernel gap on this path is tracked by the 'lowering'
    column: 'reference' off-TPU, 'mosaic' on TPU."""
    rng = np.random.default_rng(2)
    d = len(SWEEP_DESIGNS)
    cfgs = [
        ColumnConfig(
            p=SWEEP_P, q=q, t_max=t_max,
            neuron=NeuronConfig(threshold=SWEEP_P * 7 / 8.0),
        )
        for q, t_max in SWEEP_DESIGNS
    ]
    c0 = cfgs[0]
    q_pad = max(c.q for c in cfgs)
    t_window = max(c.t_max for c in cfgs)
    lowering = backend.padded_lowering(c0.neuron.response)
    # the ExecutionPlan this case's fit will resolve to: the cost model's
    # choice under the active calibration, the volley_block/128 constants
    # otherwise (same resolution fit_padded performs internally)
    plan = backend.execution_plan(
        "fit", lowering, d, SWEEP_P, q_pad, t_window, SWEEP_B, EPOCHS,
        w_max=c0.neuron.w_max, response=c0.neuron.response,
    )

    w0 = np.zeros((d, SWEEP_P, q_pad), np.float32)
    for i, c in enumerate(cfgs):
        w0[i, :, : c.q] = rng.integers(0, 8, (SWEEP_P, c.q))
    x = rng.integers(0, min(c.t_max for c in cfgs), (SWEEP_B, SWEEP_P))
    xs = jnp.asarray(
        np.broadcast_to(x[:, None, :], (SWEEP_B, d, SWEEP_P)), TIME_DTYPE
    )
    thresholds = jnp.asarray([c.neuron.threshold for c in cfgs], jnp.float32)
    t_maxes = jnp.asarray([c.t_max for c in cfgs], TIME_DTYPE)
    q_actives = jnp.asarray([c.q for c in cfgs], TIME_DTYPE)

    def padded():
        # the AOT front door (backend.fit_padded) is the production entry
        # point — simulator and network route through it — so the bench
        # measures it too: same jitted program warm, and cold it reaps the
        # serialized-executable layer a populated cache dir provides
        w = backend.fit_padded(
            jnp.asarray(w0), xs, thresholds, t_maxes, q_actives,
            t_window=t_window, w_max=c0.neuron.w_max, wta_k=c0.wta.k,
            mu_capture=c0.stdp.mu_capture, mu_backoff=c0.stdp.mu_backoff,
            mu_search=c0.stdp.mu_search,
            stabilize=c0.stdp.stabilizer == "half",
            response=c0.neuron.response, epochs=EPOCHS, lowering=lowering,
        )
        jax.block_until_ready(w)

    def legacy():
        # per-design fused fits on the SAME engine the padded path uses
        # (kernel on TPU, reference off-TPU): D traces, no shared envelope,
        # so the row isolates one-trace-vs-D-traces + padding waste.
        xj = jnp.asarray(x, TIME_DTYPE)
        for i, c in enumerate(cfgs):
            p2, _ = fused_column.fit_fused(
                {"w": jnp.asarray(w0[i, :, : c.q])}, xj, c, epochs=EPOCHS,
                lowering=lowering,
            )
            jax.block_until_ready(p2["w"])

    # cold first calls: the padded program compiles ONE trace for the whole
    # heterogeneous batch (runtime design operands), the legacy loop one
    # trace per design — the compilation cliff this path removes.  The
    # cache label is sampled by ``main`` BEFORE anything runs (operand
    # setup already writes tiny dtype-cast modules into a fresh dir, so a
    # per-row sample would always read 'populated'): these numbers only
    # mean "compile" when it says the run STARTED fresh or uncached.
    if cache is None:
        cache = cache_state(backend.compile_cache_dir())
    volleys = EPOCHS * SWEEP_B * d
    if cold_only:
        return _cold_row(
            f"sweep{d}x{SWEEP_P}p", padded, legacy, volleys, cache, side
        )
    cold_padded_us = time_cold(padded)
    cold_legacy_us = time_cold(legacy)

    # alternating rounds: the warm fused-vs-legacy ratio is the ISSUE 4
    # acceptance bar, so neither side may soak up host drift alone
    us_padded, us_legacy = time_pair(padded, legacy)

    # plan-vs-constants head-to-head (ISSUE 10 acceptance bar): the SAME
    # entry point, once under the active calibration and once with the
    # cost model suppressed so the constants fallback resolves
    def padded_const():
        with costmodel.override(None):
            padded()

    us_plan, us_const = time_pair(padded, padded_const)
    mxu_flops = sum(
        2 * (c.neuron.w_max + 1) * c.p * c.q * c.t_max for c in cfgs
    ) // d
    return {
        "case": f"sweep{d}x{SWEEP_P}p",
        "backend": "pallas",
        "lowering": lowering,
        "v_blk": plan.v_blk,
        "plan": plan.meta(),
        "plan_us_per_volley": us_plan / volleys,
        "const_us_per_volley": us_const / volleys,
        "plan_vs_const_speedup": us_const / max(us_plan, 1e-9),
        # predicted vs measured per SCAN volley (one volley spans all d
        # designs — the unit predicted_step_s is defined in)
        "predicted_measured_ratio": (
            plan.predicted_step_s * 1e6
            / max(us_plan / (EPOCHS * SWEEP_B), 1e-9)
            if plan.predicted_step_s else None
        ),
        "compile_cache": cache,
        "buckets": 1,  # one shared envelope: these designs fit the cap
        # this case drives fit_scan_padded directly — sharding happens in
        # the simulator front-end only (see sweepbkt), so this row is 1
        "shards": 1,
        "fused_us_per_volley": us_padded / volleys,
        "legacy_us_per_volley": us_legacy / volleys,
        "speedup": us_legacy / max(us_padded, 1e-9),
        "cold_us_per_volley": cold_padded_us / volleys,
        "cold_legacy_us_per_volley": cold_legacy_us / volleys,
        "cold_speedup": cold_legacy_us / max(cold_padded_us, 1e-9),
        "traces": 1,
        "legacy_traces": d,
        "mxu_flops_per_volley": mxu_flops,
    }


# ------------------------------------------------------ bucketed sweep (DSE)
BKT_B = 64  # volleys per epoch
BKT_P = 96
# heterogeneous candidates a DSE pass actually produces: two tiny read-out
# sized designs next to two big ones — a single global envelope makes the
# small designs pay (10*64)/(2*32) = 10x padding compute on every volley,
# so the central waste cap splits them into two buckets
BKT_DESIGNS = [(2, 32), (2, 32), (10, 64), (10, 64)]


def run_bucketed_sweep(
    cold_only: bool = False, cache: str | None = None, side: str = "both"
) -> dict:
    """Envelope-bucketed heterogeneous sweep (the ISSUE 5 tentpole) vs the
    same sweep forced into one global envelope (waste_cap=inf — the
    pre-bucketing behavior).  Both sides run the full simulator front-end
    (encode + blocked fit + batched assign), so the row measures what a
    DSE pass actually pays; 'buckets'/'shards' record how the bucketed
    side executed."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(BKT_B, BKT_P))
    cfgs = []
    for q, t_max in BKT_DESIGNS:
        c = ColumnConfig(p=BKT_P, q=q, t_max=t_max)
        cfgs.append(c.with_threshold(simulator.suggest_threshold(c)))
    d = len(cfgs)

    def bucketed():
        simulator.cluster_time_series_many(x, None, cfgs, epochs=EPOCHS)

    def global_env():
        simulator.cluster_time_series_many(
            x, None, cfgs, epochs=EPOCHS, waste_cap=float("inf")
        )

    # cold first calls: bucketing compiles one trace per distinct bucket
    # envelope (2 here) vs the global envelope's single bigger trace
    if cache is None:
        cache = cache_state(backend.compile_cache_dir())
    volleys = EPOCHS * BKT_B * d
    if cold_only:
        return _cold_row(
            f"sweepbkt{d}x{BKT_P}p", bucketed, global_env, volleys, cache,
            side,
        )
    cold_bkt_us = time_cold(bucketed)
    cold_glb_us = time_cold(global_env)

    us_bkt, us_glb = time_pair(bucketed, global_env)

    # plan-vs-constants head-to-head through the full front-end: the
    # simulator resolves its buckets' plans internally, so the constants
    # side just suppresses the cost model for the duration
    def bucketed_const():
        with costmodel.override(None):
            bucketed()

    us_plan, us_const = time_pair(bucketed, bucketed_const)
    res = simulator.cluster_time_series_many(x, None, cfgs, epochs=EPOCHS)
    lowering = res[0].lowering
    plan_meta = res[0].plan
    mxu_flops = sum(
        2 * (c.neuron.w_max + 1) * c.p * c.q * c.t_max for c in cfgs
    ) // d
    return {
        "case": f"sweepbkt{d}x{BKT_P}p",
        "backend": "pallas",
        "lowering": lowering,
        # the first bucket's resolved block size — under the constants
        # fallback both 2-design buckets get the d-aware reference cap
        # (v_blk=4, not the homogeneous-sweep 8); a calibration may choose
        # differently, and the full choice is in 'plan'
        "v_blk": (
            plan_meta["v_blk"] if plan_meta
            else backend.volley_block(lowering, BKT_B, d=2)
        ),
        "plan": plan_meta,
        "plan_us_per_volley": us_plan / volleys,
        "const_us_per_volley": us_const / volleys,
        "plan_vs_const_speedup": us_const / max(us_plan, 1e-9),
        # fit-only prediction vs END-TO-END measurement (encode + fit +
        # assign): an upper-bound sanity ratio, not a fit-time error
        "predicted_measured_ratio": (
            plan_meta["predicted_step_us"]
            / max(us_plan / (EPOCHS * BKT_B), 1e-9)
            if plan_meta and plan_meta.get("predicted_step_us") else None
        ),
        "compile_cache": cache,
        "buckets": res[0].buckets,
        "shards": max(r.shards for r in res),
        # fused = bucketed, legacy = single global envelope
        "fused_us_per_volley": us_bkt / volleys,
        "legacy_us_per_volley": us_glb / volleys,
        "speedup": us_glb / max(us_bkt, 1e-9),
        "cold_us_per_volley": cold_bkt_us / volleys,
        "cold_legacy_us_per_volley": cold_glb_us / volleys,
        "cold_speedup": cold_glb_us / max(cold_bkt_us, 1e-9),
        "traces": res[0].buckets,
        "legacy_traces": 1,
        "mxu_flops_per_volley": mxu_flops,
    }


# ---------------------------------------------------- multi-layer network
NET_B = 64  # volleys per epoch


def _net_cfg() -> NetworkConfig:
    """2-layer NSPU: 4 fully-connected 96x8 columns feeding one 32x5."""

    def col(p, q, t_max=64):
        return ColumnConfig(
            p=p, q=q, t_max=t_max, neuron=NeuronConfig(threshold=p * 7 / 8.0)
        )

    return NetworkConfig(layers=(
        LayerConfig(columns=4, column=col(96, 8)),
        LayerConfig(columns=1, column=col(32, 5)),
    ), name="bench2layer")


def run_network(
    cold_only: bool = False, cache: str | None = None, side: str = "both"
) -> dict:
    """Fused per-layer scans (network.fit_greedy) vs the legacy untraced
    per-epoch Python loop they replaced (one vmapped train_step per epoch)."""
    net = _net_cfg()
    rng = np.random.default_rng(1)
    in_width = network.in_width(net)
    params = [
        {
            "w": jnp.asarray(
                rng.integers(
                    0, 8, (l.columns, l.column.p, l.column.q)
                ),
                jnp.float32,
            )
        }
        for l in net.layers
    ]
    x = jnp.asarray(
        rng.integers(0, net.layers[0].column.t_max, (NET_B, in_width)),
        jnp.int32,
    )

    def fused():
        trained = network.fit_greedy(params, x, net, epochs=EPOCHS)
        jax.block_until_ready(trained[-1]["w"])

    def legacy():
        # the pre-fusion fit_greedy: Python epochs loop, per-epoch dispatch
        h = x
        key = jax.random.key(0)
        for lp, layer in zip(params, net.layers):
            c = layer.columns
            hc = jnp.broadcast_to(
                h[..., None, :], h.shape[:-1] + (c, h.shape[-1])
            )
            w = lp["w"]
            for _ in range(EPOCHS):
                key, sub = jax.random.split(key)
                keys = jax.random.split(sub, c)

                def one(wi, xi, ki):
                    p2, _ = column.train_step(
                        {"w": wi}, xi, layer.column, rng=ki
                    )
                    return p2["w"]

                w = jax.vmap(one, in_axes=(0, -2, 0))(w, hc, keys)
            h = network._apply_layer({"w": w}, h, layer, "auto")
        jax.block_until_ready(h)

    # cold first calls: the compile cliff of the blocked per-layer scans vs
    # the legacy per-epoch dispatch loop
    if cache is None:
        cache = cache_state(backend.compile_cache_dir())
    volleys = EPOCHS * NET_B
    if cold_only:
        return _cold_row(
            "net96-4x8-1x5", fused, legacy, volleys, cache, side
        )
    cold_fused_us = time_cold(fused)
    cold_legacy_us = time_cold(legacy)

    # alternating rounds, same rationale as run_sweep
    us_fused, us_legacy = time_pair(fused, legacy)

    # plan-vs-constants head-to-head on the fused side only (the
    # constants fallback resolves when the cost model is suppressed)
    def fused_const():
        with costmodel.override(None):
            fused()

    us_plan, us_const = time_pair(fused, fused_const)
    # one more (warm) training pass to capture the per-layer plans the
    # timed runs resolved to
    layer_plans: list = []
    network.fit_greedy(params, x, net, epochs=EPOCHS, plan_sink=layer_plans)
    mxu_flops = sum(
        l.columns * 2 * (l.column.neuron.w_max + 1)
        * l.column.p * l.column.q * l.column.t_max
        for l in net.layers
    )
    lowering = backend.padded_lowering(net.layers[0].column.neuron.response)
    return {
        "case": "net96-4x8-1x5",
        "backend": backend.resolve(
            "auto", net.layers[0].column, training=True
        ),
        # the padded per-layer scan lowers through backend.padded_lowering:
        # Mosaic kernel on TPU (runtime design operands), reference off-TPU
        "lowering": lowering,
        # per-layer resolved block sizes — under the constants fallback
        # the d-aware reference cap unrolls 8 volleys for the 4-column
        # layer but only 2 for the single-column read-out layer
        "v_blk": (
            [p["v_blk"] for p in layer_plans] if layer_plans
            else [
                backend.volley_block(lowering, NET_B, d=l.columns)
                for l in net.layers
            ]
        ),
        "plan": {"layers": layer_plans},
        "plan_us_per_volley": us_plan / volleys,
        "const_us_per_volley": us_const / volleys,
        "plan_vs_const_speedup": us_const / max(us_plan, 1e-9),
        # per-layer fit predictions sum to a per-volley bound for the
        # whole greedy pass; measured includes the layer handoffs
        "predicted_measured_ratio": (
            sum(p["predicted_step_us"] for p in layer_plans)
            / max(us_plan / (EPOCHS * NET_B), 1e-9)
            if layer_plans
            and all(p.get("predicted_step_us") for p in layer_plans)
            else None
        ),
        "compile_cache": cache,
        # per-layer envelopes: both layers get their own bucket (the 96x8
        # and 32x5 columns are outside the waste cap of each other);
        # network layer training does not shard its columns axis, so 1
        "buckets": len(set(network._fused_envelopes(list(net.layers)))),
        "shards": 1,
        "fused_us_per_volley": us_fused / volleys,
        "legacy_us_per_volley": us_legacy / volleys,
        "speedup": us_legacy / max(us_fused, 1e-9),
        "cold_us_per_volley": cold_fused_us / volleys,
        "cold_legacy_us_per_volley": cold_legacy_us / volleys,
        "cold_speedup": cold_legacy_us / max(cold_fused_us, 1e-9),
        "mxu_flops_per_volley": mxu_flops,
    }


# the padded cases whose cold floors CI tracks (``--check``): each must
# hold cold_speedup >= COLD_REGRESSION_MIN against a FRESH cache dir and
# warmproc_cold_speedup >= 1.0 against the populated one
TRACKED_COLD_CASES = ("sweep4x96p", "sweepbkt4x96p", "net96-4x8-1x5")


def _enable_cache(mode: str):
    """Resolve the ``--cache`` flag into a persistent-cache directory.

    'fresh' (the default) creates a brand-new empty temp dir, so cold
    rows measure true compiles even when the process inherited a warm
    ``REPRO_COMPILE_CACHE``; 'off' leaves whatever the environment set up
    untouched (honest only if that cache is absent or fresh — the rows'
    ``compile_cache`` column records what it actually was); anything else
    is used as the directory itself (the ``--cold-json`` children pass
    the parent's now-populated dir this way).
    """
    if mode == "off":
        return backend.compile_cache_dir()
    if mode == "fresh":
        mode = tempfile.mkdtemp(prefix="repro-train-bench-cache-")
    return backend.compile_cache(mode)


def _cold_child(case: str, side: str, cache_dir: str):
    """One ``--cold-json`` child: cold-start a fresh process, time ONE
    side of ONE case.  Returns (us_per_volley, cache_label) or None."""
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.train_bench", "--cold-json",
         "--case", case, "--side", side, "--cache", cache_dir],
        capture_output=True, text=True, timeout=1800,
    )
    if proc.returncode != 0:
        print(f"cold child ({case}/{side}) failed:\n{proc.stderr[-2000:]}")
        return None
    row = json.loads(proc.stdout.strip().splitlines()[-1])[0]
    us = row.get("cold_us_per_volley", row.get("cold_legacy_us_per_volley"))
    return us, row["compile_cache"]


def _isolated_cold(
    cases, cache_mode: str, attempts: int, floor: float
) -> dict[str, dict]:
    """Measure each case's cold ratio with ONE child process PER SIDE.

    Isolation is the whole point, twice over: measured in one process,
    the cases contaminate each other (the homogeneous sweep compiles the
    very global-envelope executable the bucketed case's legacy side then
    gets for free), and within a case the first side to run pays the
    shared one-time machinery (encode/metric traces) for both.  So every
    (case, side) gets a fresh process; ``cache_mode='fresh'`` also gives
    each child its own empty cache dir (true compile cliff), while a
    directory path reuses it as-is (the warm-process measurement against
    a populated cache).  Ambient interference only ever ADDS time, so
    each side keeps its MINIMUM over up to ``attempts`` children (the
    ``time_pair`` estimator, across processes) with an early stop once
    the ratio clears ``floor``.
    """
    out: dict[str, dict] = {}
    for case in cases:
        if cache_mode not in ("fresh", "off"):
            # warm phase: one UNTIMED child first to finish populating
            # the cache — a handful of tiny-op executables only the
            # child-side code path compiles (the parent ran in-process),
            # which would otherwise be paid inside whichever side's
            # timed region happens to run first
            _cold_child(case, "fused", cache_mode)
        fused = legacy = None
        label = None
        for _ in range(attempts):
            cdir = (
                tempfile.mkdtemp(prefix="repro-train-bench-cold-")
                if cache_mode == "fresh" else cache_mode
            )
            got_f = _cold_child(case, "fused", cdir)
            cdir = (
                tempfile.mkdtemp(prefix="repro-train-bench-cold-")
                if cache_mode == "fresh" else cache_mode
            )
            got_l = _cold_child(case, "legacy", cdir)
            if got_f is None or got_l is None:
                continue
            fused = got_f[0] if fused is None else min(fused, got_f[0])
            legacy = got_l[0] if legacy is None else min(legacy, got_l[0])
            label = got_f[1]
            if legacy / max(fused, 1e-9) >= floor:
                break
        if fused is not None and legacy is not None:
            out[case] = {
                "compile_cache": label,
                "cold_us_per_volley": fused,
                "cold_legacy_us_per_volley": legacy,
                "cold_speedup": legacy / max(fused, 1e-9),
            }
    return out


def _merge_cold(rows: list, cache_dir: str) -> None:
    """Replace the in-process cold columns with the isolated per-side
    child measurements and add the ``warmproc_*`` columns measured
    against the parent's now-populated cache dir — the cost a user
    actually pays on every run after the first."""
    tracked = {r["case"]: r for r in rows if "cold_speedup" in r}
    fresh = _isolated_cold(
        tracked, "fresh", attempts=2, floor=COLD_REGRESSION_MIN
    )
    for case, row in fresh.items():
        tracked[case].update(
            compile_cache=row["compile_cache"],
            cold_us_per_volley=row["cold_us_per_volley"],
            cold_legacy_us_per_volley=row["cold_legacy_us_per_volley"],
            cold_speedup=row["cold_speedup"],
        )
    # the warmproc ratio sits near 1.0 by construction (both sides just
    # deserialize), so on a noisy host the min-estimator needs more
    # attempts than the fresh-cold one; early-stop keeps the extra
    # attempts free whenever the floor clears
    warm = _isolated_cold(
        tracked, cache_dir, attempts=6, floor=WARMPROC_REGRESSION_MIN
    )
    for case, row in warm.items():
        tracked[case].update(
            warmproc_compile_cache=row["compile_cache"],
            warmproc_cold_us_per_volley=row["cold_us_per_volley"],
            warmproc_cold_legacy_us_per_volley=(
                row["cold_legacy_us_per_volley"]
            ),
            warmproc_cold_speedup=row["cold_speedup"],
        )


def check() -> int:
    """Validate the committed ``BENCH_train.json`` floors (CI smoke):
    every tracked padded case must hold warm speedup >=
    WARM_REGRESSION_MIN, fresh-cache cold speedup >=
    COLD_REGRESSION_MIN, and populated-cache warm-process
    cold speedup >= WARMPROC_REGRESSION_MIN.  Returns a nonzero exit
    status on any miss so the workflow step fails loudly."""
    path = pathlib.Path("BENCH_train.json")
    rows = {r["case"]: r for r in json.loads(path.read_text())}
    failed = 0
    for case in TRACKED_COLD_CASES:
        r = rows.get(case)
        if r is None:
            print(f"CHECK-FAIL: tracked case {case} missing from {path}")
            failed = 1
            continue
        floors = [
            ("warm speedup", r.get("speedup"), WARM_REGRESSION_MIN),
            ("cold speedup (fresh cache)", r.get("cold_speedup"),
             COLD_REGRESSION_MIN),
            ("warm-process cold speedup (populated cache)",
             r.get("warmproc_cold_speedup"), WARMPROC_REGRESSION_MIN),
            ("plan-vs-constants warm speedup",
             r.get("plan_vs_const_speedup"), PLAN_REGRESSION_MIN),
        ]
        for label, val, floor in floors:
            if val is None or val < floor:
                print(
                    f"CHECK-FAIL: {case} {label} "
                    f"{'missing' if val is None else f'{val:.2f}x'} "
                    f"< {floor}x floor"
                )
                failed = 1
    if not failed:
        print(f"train bench floors OK for {', '.join(TRACKED_COLD_CASES)}")
    return failed


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--cache", default="fresh", metavar="off|fresh|DIR",
        help="persistent compile cache: 'fresh' (default) = new empty "
             "temp dir so cold rows are true compiles; 'off' = leave the "
             "environment's cache config alone; DIR = use that directory",
    )
    ap.add_argument(
        "--cold-json", action="store_true",
        help="child mode: run ONLY the padded cold first-calls and print "
             "one JSON line (used for the isolated cold / warm-process "
             "re-measurements)",
    )
    ap.add_argument(
        "--case", default=None, choices=TRACKED_COLD_CASES,
        help="with --cold-json: restrict to one padded case, so cases "
             "cannot warm each other's executables",
    )
    ap.add_argument(
        "--side", default="both", choices=("fused", "legacy", "both"),
        help="with --cold-json: time only one side of the case, so the "
             "first side run cannot absorb the shared one-time machinery "
             "for the other",
    )
    ap.add_argument(
        "--check", action="store_true",
        help="validate the committed BENCH_train.json floors and exit",
    )
    args = ap.parse_args(argv)
    if args.check:
        raise SystemExit(check())
    cache_dir = _enable_cache(args.cache)
    # sample the label ONCE, before anything compiles: it describes the
    # state the run started from, which is what makes cold rows honest
    cache = cache_state(cache_dir)
    # activate the device calibration AFTER the cache dir is resolved: the
    # calibration persists NEXT TO the compile cache (calibration.json),
    # so the --cold-json children handed the parent's populated dir load
    # the SAME profile -> resolve the SAME plans -> hit the SAME AOT keys.
    # A fresh dir calibrates once (~seconds, before any timed region).
    try:
        prof = costmodel.load_or_calibrate()
        print(
            f"device calibration: {prof.name} "
            f"(peak={prof.peak_flops:.3g} FLOP/s, bw={prof.hbm_bw:.3g} B/s, "
            f"fused_eff={prof.fused_eff:.2f})"
        )
    except Exception as e:  # constants fallback is always available
        print(f"device calibration unavailable ({e!r}); constants fallback")
    if args.cold_json:
        runners = {
            "sweep4x96p": run_sweep,
            "sweepbkt4x96p": run_bucketed_sweep,
            "net96-4x8-1x5": run_network,
        }
        names = [args.case] if args.case else list(runners)
        cold = [
            runners[n](cold_only=True, cache=cache, side=args.side)
            for n in names
        ]
        print(json.dumps(cold))
        return
    if cache_dir:
        print(f"persistent compile cache: {cache_dir} ({cache})")
    rows = run()
    rows.append(run_sweep(cache=cache))
    rows.append(run_bucketed_sweep(cache=cache))
    rows.append(run_network(cache=cache))
    # the in-process cold columns above are contaminated (earlier cases
    # warm later cases' shared executables and the jit caches), so when a
    # cache dir is in play they are REPLACED by per-case isolated child
    # measurements, and the warm-process columns are added the same way
    if cache_dir:
        _merge_cold(rows, cache_dir)
    print("\n# Fused online-STDP training vs legacy per-epoch loop")
    print("| case | backend | fused us/volley | legacy us/volley | speedup | MXU flops/volley |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['case']} | {r['backend']}/{r['lowering']} | "
              f"{r['fused_us_per_volley']:.1f} | {r['legacy_us_per_volley']:.1f} | "
              f"{r['speedup']:.2f}x | {r['mxu_flops_per_volley']:.2e} |")
    out = pathlib.Path("BENCH_train.json")
    out.write_text(json.dumps(rows, indent=2) + "\n")
    print(f"wrote {out.resolve()}")
    for r in rows:
        emit(f"train/{r['case']}", r["fused_us_per_volley"],
             f"speedup={r['speedup']:.2f}x flops={r['mxu_flops_per_volley']:.2e}")
    # warm throughput is the ISSUE 4 acceptance bar: a fused case that only
    # wins the compile cliff is a regression, and says so loudly
    for r in rows:
        if r["speedup"] < WARM_REGRESSION_MIN:
            print(
                f"REGRESSION: {r['case']} warm fused speedup "
                f"{r['speedup']:.2f}x < {WARM_REGRESSION_MIN}x floor vs legacy "
                f"({r['fused_us_per_volley']:.1f} vs "
                f"{r['legacy_us_per_volley']:.1f} us/volley, "
                f"lowering={r['lowering']})"
            )
    # cold (first-call, compile-inclusive) time is tracked too: the fused
    # paths may trade SOME cold time for warm throughput, but below the
    # tracked floor the compile cliff is a real usability regression and
    # must be loud, not a silent JSON column
    for r in rows:
        cold = r.get("cold_speedup")
        if cold is not None and cold < COLD_REGRESSION_MIN:
            print(
                f"COLD-REGRESSION: {r['case']} cold fused speedup "
                f"{cold:.2f}x < {COLD_REGRESSION_MIN}x floor vs legacy "
                f"({r['cold_us_per_volley']:.1f} vs "
                f"{r['cold_legacy_us_per_volley']:.1f} us/volley cold, "
                f"lowering={r['lowering']}, "
                f"compile_cache={r.get('compile_cache', 'off')})"
            )
    # the cost model may only ever trade within warm parity: a plan that
    # loses real warm throughput against the constants it replaced is a
    # regression in the one metric the chooser optimizes
    for r in rows:
        pvc = r.get("plan_vs_const_speedup")
        if pvc is not None and pvc < PLAN_REGRESSION_MIN:
            print(
                f"PLAN-REGRESSION: {r['case']} plan-vs-constants warm "
                f"speedup {pvc:.2f}x < {PLAN_REGRESSION_MIN}x floor "
                f"({r['plan_us_per_volley']:.1f} vs "
                f"{r['const_us_per_volley']:.1f} us/volley, "
                f"plan={r.get('plan')})"
            )
    # against a POPULATED persistent cache a fresh process reads its
    # executables from disk instead of compiling — that cold start must
    # beat the legacy path outright, or the cache isn't paying its way
    for r in rows:
        wp = r.get("warmproc_cold_speedup")
        if wp is not None and wp < WARMPROC_REGRESSION_MIN:
            print(
                f"WARMPROC-REGRESSION: {r['case']} warm-process cold "
                f"speedup {wp:.2f}x < {WARMPROC_REGRESSION_MIN}x vs "
                f"legacy with a populated "
                f"persistent cache ({r['warmproc_cold_us_per_volley']:.1f}"
                f" vs {r['warmproc_cold_legacy_us_per_volley']:.1f} "
                f"us/volley)"
            )


if __name__ == "__main__":
    main()
