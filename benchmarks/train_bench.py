"""Fused online-STDP training benchmark — the ISSUE 1/2 perf trajectory.

Times the fused single-scan training path (one jitted, donated lax.scan over
epochs x volleys, fused fire+WTA+STDP body) against the legacy per-epoch
loop, on paper column geometries AND a multi-layer network (the ISSUE 2
tentpole: ``network.fit_greedy`` as one jitted padded scan per layer vs the
untraced per-epoch Python loop it replaced).  Emits ``BENCH_train.json``
(us/volley + MXU FLOPs of the fused kernel algebra) so the perf trajectory
is tracked PR over PR; later PRs append comparable numbers.

MXU FLOPs count the one-hot plane matmuls of the fused Pallas kernel
(2 * (w_max+1) * p * q * t_max per volley) — the work the TPU lowering puts
on the systolic array; off-TPU the reference lowering does the same algebra
on the VPU-equivalent.
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import backend, column, network
from repro.core.types import (
    ColumnConfig, LayerConfig, NetworkConfig, NeuronConfig,
)

# (name, B volleys, p, q, t_max) — Beef-shaped default plus small/large cols
CASES = [
    ("col65x2", 64, 65, 2, 64),
    ("col470x5", 120, 470, 5, 64),
    ("col152x2", 64, 152, 2, 100),
]
EPOCHS = 4


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    for name, B, p, q, t_max in CASES:
        cfg = ColumnConfig(
            p=p, q=q, t_max=t_max,
            neuron=NeuronConfig(threshold=p * 7 / 8.0),
        )
        params = {
            "w": jnp.asarray(rng.integers(0, 8, (p, q)), jnp.float32)
        }
        x = jnp.asarray(rng.integers(0, t_max, (B, p)), jnp.int32)

        def fused():
            jax.block_until_ready(
                column.fit(params, x, cfg, epochs=EPOCHS)["w"]
            )

        def legacy():
            pr = params
            for _ in range(EPOCHS):
                pr, _ = column.train_step(pr, x, cfg, update="batch")
            jax.block_until_ready(pr["w"])

        us_fused = time_call(fused)
        us_legacy = time_call(legacy)
        volleys = EPOCHS * B
        mxu_flops = 2 * (cfg.neuron.w_max + 1) * p * q * t_max
        rows.append({
            "case": name,
            "backend": backend.resolve("auto", cfg, training=True),
            "lowering": backend.pallas_lowering(),
            "fused_us_per_volley": us_fused / volleys,
            "legacy_us_per_volley": us_legacy / volleys,
            "speedup": us_legacy / max(us_fused, 1e-9),
            "mxu_flops_per_volley": mxu_flops,
        })
    return rows


# ---------------------------------------------------- multi-layer network
NET_B = 64  # volleys per epoch


def _net_cfg() -> NetworkConfig:
    """2-layer NSPU: 4 fully-connected 96x8 columns feeding one 32x5."""

    def col(p, q, t_max=64):
        return ColumnConfig(
            p=p, q=q, t_max=t_max, neuron=NeuronConfig(threshold=p * 7 / 8.0)
        )

    return NetworkConfig(layers=(
        LayerConfig(columns=4, column=col(96, 8)),
        LayerConfig(columns=1, column=col(32, 5)),
    ), name="bench2layer")


def run_network() -> dict:
    """Fused per-layer scans (network.fit_greedy) vs the legacy untraced
    per-epoch Python loop they replaced (one vmapped train_step per epoch)."""
    net = _net_cfg()
    rng = np.random.default_rng(1)
    in_width = network.in_width(net)
    params = [
        {
            "w": jnp.asarray(
                rng.integers(
                    0, 8, (l.columns, l.column.p, l.column.q)
                ),
                jnp.float32,
            )
        }
        for l in net.layers
    ]
    x = jnp.asarray(
        rng.integers(0, net.layers[0].column.t_max, (NET_B, in_width)),
        jnp.int32,
    )

    def fused():
        trained = network.fit_greedy(params, x, net, epochs=EPOCHS)
        jax.block_until_ready(trained[-1]["w"])

    def legacy():
        # the pre-fusion fit_greedy: Python epochs loop, per-epoch dispatch
        h = x
        key = jax.random.key(0)
        for lp, layer in zip(params, net.layers):
            c = layer.columns
            hc = jnp.broadcast_to(
                h[..., None, :], h.shape[:-1] + (c, h.shape[-1])
            )
            w = lp["w"]
            for _ in range(EPOCHS):
                key, sub = jax.random.split(key)
                keys = jax.random.split(sub, c)

                def one(wi, xi, ki):
                    p2, _ = column.train_step(
                        {"w": wi}, xi, layer.column, rng=ki
                    )
                    return p2["w"]

                w = jax.vmap(one, in_axes=(0, -2, 0))(w, hc, keys)
            h = network._apply_layer({"w": w}, h, layer, "auto")
        jax.block_until_ready(h)

    us_fused = time_call(fused)
    us_legacy = time_call(legacy)
    volleys = EPOCHS * NET_B
    mxu_flops = sum(
        l.columns * 2 * (l.column.neuron.w_max + 1)
        * l.column.p * l.column.q * l.column.t_max
        for l in net.layers
    )
    return {
        "case": "net96-4x8-1x5",
        "backend": backend.resolve(
            "auto", net.layers[0].column, training=True
        ),
        # the padded per-layer scan runs the reference lowering of the
        # fused algebra on every host (traced per-layer scalars)
        "lowering": "reference",
        "fused_us_per_volley": us_fused / volleys,
        "legacy_us_per_volley": us_legacy / volleys,
        "speedup": us_legacy / max(us_fused, 1e-9),
        "mxu_flops_per_volley": mxu_flops,
    }


def main(argv=None) -> None:
    rows = run()
    rows.append(run_network())
    print("\n# Fused online-STDP training vs legacy per-epoch loop")
    print("| case | backend | fused us/volley | legacy us/volley | speedup | MXU flops/volley |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['case']} | {r['backend']}/{r['lowering']} | "
              f"{r['fused_us_per_volley']:.1f} | {r['legacy_us_per_volley']:.1f} | "
              f"{r['speedup']:.2f}x | {r['mxu_flops_per_volley']:.2e} |")
    out = pathlib.Path("BENCH_train.json")
    out.write_text(json.dumps(rows, indent=2) + "\n")
    print(f"wrote {out.resolve()}")
    for r in rows:
        emit(f"train/{r['case']}", r["fused_us_per_volley"],
             f"speedup={r['speedup']:.2f}x flops={r['mxu_flops_per_volley']:.2e}")


if __name__ == "__main__":
    main()
