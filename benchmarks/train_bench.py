"""Fused online-STDP training benchmark — the ISSUE 1/2/4 perf trajectory.

Times the fused single-scan training path (one jitted, donated lax.scan over
epochs x volleys, fused fire+WTA+STDP body) against the legacy per-epoch
loop, on paper column geometries, a padded heterogeneous design sweep (the
ISSUE 3 tentpole: ONE ``fit_scan_padded`` program with runtime design
operands vs one fused fit per design) AND a multi-layer network (the ISSUE 2
tentpole: ``network.fit_greedy`` as one jitted padded scan per layer vs the
untraced per-epoch Python loop it replaced).  Since ISSUE 4 the padded
cases run the volley-blocked scan (``v_blk`` volleys per step, one kernel
invocation / one unrolled reference body per block) and report BOTH warm
and cold numbers — the blocked path must win warm throughput, not just the
compile cliff, and ``main`` prints a REGRESSION flag whenever a fused case
reports warm speedup < 1 and a COLD-REGRESSION flag whenever cold speedup
falls below the tracked ``COLD_REGRESSION_MIN`` floor.  Since ISSUE 5 a
bucketed heterogeneous sweep case (``sweepbkt*``) times the envelope-
bucketed front-end against the same sweep forced into one global envelope,
and every padded case records its bucket/shard metadata.  Emits
``BENCH_train.json`` (us/volley + MXU
FLOPs of the fused kernel algebra) so the perf trajectory — including the
reference-vs-kernel gap on the padded path (the 'lowering' column) — is
tracked PR over PR; later PRs append comparable numbers.

MXU FLOPs count the one-hot plane matmuls of the fused Pallas kernel
(2 * (w_max+1) * p * q * t_max per volley) — the work the TPU lowering puts
on the systolic array; off-TPU the reference lowering does the same algebra
on the VPU-equivalent.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call, time_pair
from repro.core import backend, column, network, simulator
from repro.core.types import (
    ColumnConfig, LayerConfig, NetworkConfig, NeuronConfig, TIME_DTYPE,
)
from repro.kernels import fused_column

# (name, B volleys, p, q, t_max) — Beef-shaped default plus small/large cols
CASES = [
    ("col65x2", 64, 65, 2, 64),
    ("col470x5", 120, 470, 5, 64),
    ("col152x2", 64, 152, 2, 100),
]
EPOCHS = 4

# Tracked cold-regression threshold (ISSUE 5 CI satellite): the padded
# fused paths knowingly trade some cold (first-call, compile-inclusive)
# time for warm throughput — the blocked trace is bigger than the legacy
# per-design/per-epoch ones, and the one-trace-vs-D-traces cliff only wins
# back with design count.  A cold_speedup below this floor is a LOUD
# COLD-REGRESSION flag in ``main``, not a silent JSON column: net96-4x8-1x5
# shipped at 0.33x unflagged before the flag existed.  Raise the floor as
# cold compiles improve; lowering it needs a recorded justification here.
COLD_REGRESSION_MIN = 0.5


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    for name, B, p, q, t_max in CASES:
        cfg = ColumnConfig(
            p=p, q=q, t_max=t_max,
            neuron=NeuronConfig(threshold=p * 7 / 8.0),
        )
        params = {
            "w": jnp.asarray(rng.integers(0, 8, (p, q)), jnp.float32)
        }
        x = jnp.asarray(rng.integers(0, t_max, (B, p)), jnp.int32)

        def fused():
            jax.block_until_ready(
                column.fit(params, x, cfg, epochs=EPOCHS)["w"]
            )

        def legacy():
            pr = params
            for _ in range(EPOCHS):
                pr, _ = column.train_step(pr, x, cfg, update="batch")
            jax.block_until_ready(pr["w"])

        us_fused = time_call(fused)
        us_legacy = time_call(legacy)
        volleys = EPOCHS * B
        mxu_flops = 2 * (cfg.neuron.w_max + 1) * p * q * t_max
        rows.append({
            "case": name,
            "backend": backend.resolve("auto", cfg, training=True),
            "lowering": backend.pallas_lowering(),
            "fused_us_per_volley": us_fused / volleys,
            "legacy_us_per_volley": us_legacy / volleys,
            "speedup": us_legacy / max(us_fused, 1e-9),
            "mxu_flops_per_volley": mxu_flops,
        })
    return rows


# ------------------------------------------------------- padded design sweep
SWEEP_B = 64  # volleys per epoch
# heterogeneous candidates sharing one envelope: (q, t_max) per design,
# p pinned by the stream as in simulator.cluster_time_series_many
SWEEP_P = 96
SWEEP_DESIGNS = [(5, 32), (5, 64), (10, 32), (10, 64)]


def run_sweep() -> dict:
    """Padded heterogeneous design sweep: ONE fit_scan_padded program
    (runtime design operands, one trace for the whole batch) vs the legacy
    per-design loop (one fused fit per design, D separate compilations).
    The reference-vs-kernel gap on this path is tracked by the 'lowering'
    column: 'reference' off-TPU, 'mosaic' on TPU."""
    rng = np.random.default_rng(2)
    d = len(SWEEP_DESIGNS)
    cfgs = [
        ColumnConfig(
            p=SWEEP_P, q=q, t_max=t_max,
            neuron=NeuronConfig(threshold=SWEEP_P * 7 / 8.0),
        )
        for q, t_max in SWEEP_DESIGNS
    ]
    c0 = cfgs[0]
    q_pad = max(c.q for c in cfgs)
    t_window = max(c.t_max for c in cfgs)
    lowering = backend.padded_lowering(c0.neuron.response)
    v_blk = backend.volley_block(lowering, SWEEP_B)

    w0 = np.zeros((d, SWEEP_P, q_pad), np.float32)
    for i, c in enumerate(cfgs):
        w0[i, :, : c.q] = rng.integers(0, 8, (SWEEP_P, c.q))
    x = rng.integers(0, min(c.t_max for c in cfgs), (SWEEP_B, SWEEP_P))
    xs = jnp.asarray(
        np.broadcast_to(x[:, None, :], (SWEEP_B, d, SWEEP_P)), TIME_DTYPE
    )
    thresholds = jnp.asarray([c.neuron.threshold for c in cfgs], jnp.float32)
    t_maxes = jnp.asarray([c.t_max for c in cfgs], TIME_DTYPE)
    q_actives = jnp.asarray([c.q for c in cfgs], TIME_DTYPE)

    def padded():
        w = fused_column.fit_scan_padded(
            jnp.asarray(w0), xs, thresholds, t_maxes, q_actives,
            t_window=t_window, w_max=c0.neuron.w_max, wta_k=c0.wta.k,
            mu_capture=c0.stdp.mu_capture, mu_backoff=c0.stdp.mu_backoff,
            mu_search=c0.stdp.mu_search,
            stabilize=c0.stdp.stabilizer == "half",
            response=c0.neuron.response, epochs=EPOCHS, lowering=lowering,
            v_blk=v_blk,
        )
        jax.block_until_ready(w)

    def legacy():
        # per-design fused fits on the SAME engine the padded path uses
        # (kernel on TPU, reference off-TPU): D traces, no shared envelope,
        # so the row isolates one-trace-vs-D-traces + padding waste.
        xj = jnp.asarray(x, TIME_DTYPE)
        for i, c in enumerate(cfgs):
            p2, _ = fused_column.fit_fused(
                {"w": jnp.asarray(w0[i, :, : c.q])}, xj, c, epochs=EPOCHS,
                lowering=lowering,
            )
            jax.block_until_ready(p2["w"])

    # cold first calls: the padded program compiles ONE trace for the whole
    # heterogeneous batch (runtime design operands), the legacy loop one
    # trace per design — the compilation cliff this path removes.
    t0 = time.perf_counter()
    padded()
    cold_padded_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    legacy()
    cold_legacy_us = (time.perf_counter() - t0) * 1e6

    # alternating rounds: the warm fused-vs-legacy ratio is the ISSUE 4
    # acceptance bar, so neither side may soak up host drift alone
    us_padded, us_legacy = time_pair(padded, legacy)
    volleys = EPOCHS * SWEEP_B * d
    mxu_flops = sum(
        2 * (c.neuron.w_max + 1) * c.p * c.q * c.t_max for c in cfgs
    ) // d
    return {
        "case": f"sweep{d}x{SWEEP_P}p",
        "backend": "pallas",
        "lowering": lowering,
        "v_blk": v_blk,
        "buckets": 1,  # one shared envelope: these designs fit the cap
        # this case drives fit_scan_padded directly — sharding happens in
        # the simulator front-end only (see sweepbkt), so this row is 1
        "shards": 1,
        "fused_us_per_volley": us_padded / volleys,
        "legacy_us_per_volley": us_legacy / volleys,
        "speedup": us_legacy / max(us_padded, 1e-9),
        "cold_us_per_volley": cold_padded_us / volleys,
        "cold_legacy_us_per_volley": cold_legacy_us / volleys,
        "cold_speedup": cold_legacy_us / max(cold_padded_us, 1e-9),
        "traces": 1,
        "legacy_traces": d,
        "mxu_flops_per_volley": mxu_flops,
    }


# ------------------------------------------------------ bucketed sweep (DSE)
BKT_B = 64  # volleys per epoch
BKT_P = 96
# heterogeneous candidates a DSE pass actually produces: two tiny read-out
# sized designs next to two big ones — a single global envelope makes the
# small designs pay (10*64)/(2*32) = 10x padding compute on every volley,
# so the central waste cap splits them into two buckets
BKT_DESIGNS = [(2, 32), (2, 32), (10, 64), (10, 64)]


def run_bucketed_sweep() -> dict:
    """Envelope-bucketed heterogeneous sweep (the ISSUE 5 tentpole) vs the
    same sweep forced into one global envelope (waste_cap=inf — the
    pre-bucketing behavior).  Both sides run the full simulator front-end
    (encode + blocked fit + batched assign), so the row measures what a
    DSE pass actually pays; 'buckets'/'shards' record how the bucketed
    side executed."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(BKT_B, BKT_P))
    cfgs = []
    for q, t_max in BKT_DESIGNS:
        c = ColumnConfig(p=BKT_P, q=q, t_max=t_max)
        cfgs.append(c.with_threshold(simulator.suggest_threshold(c)))
    d = len(cfgs)

    def bucketed():
        simulator.cluster_time_series_many(x, None, cfgs, epochs=EPOCHS)

    def global_env():
        simulator.cluster_time_series_many(
            x, None, cfgs, epochs=EPOCHS, waste_cap=float("inf")
        )

    # cold first calls: bucketing compiles one trace per distinct bucket
    # envelope (2 here) vs the global envelope's single bigger trace
    t0 = time.perf_counter()
    bucketed()
    cold_bkt_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    global_env()
    cold_glb_us = (time.perf_counter() - t0) * 1e6

    us_bkt, us_glb = time_pair(bucketed, global_env)
    res = simulator.cluster_time_series_many(x, None, cfgs, epochs=EPOCHS)
    lowering = res[0].lowering
    volleys = EPOCHS * BKT_B * d
    mxu_flops = sum(
        2 * (c.neuron.w_max + 1) * c.p * c.q * c.t_max for c in cfgs
    ) // d
    return {
        "case": f"sweepbkt{d}x{BKT_P}p",
        "backend": "pallas",
        "lowering": lowering,
        "v_blk": backend.volley_block(lowering, BKT_B),
        "buckets": res[0].buckets,
        "shards": max(r.shards for r in res),
        # fused = bucketed, legacy = single global envelope
        "fused_us_per_volley": us_bkt / volleys,
        "legacy_us_per_volley": us_glb / volleys,
        "speedup": us_glb / max(us_bkt, 1e-9),
        "cold_us_per_volley": cold_bkt_us / volleys,
        "cold_legacy_us_per_volley": cold_glb_us / volleys,
        "cold_speedup": cold_glb_us / max(cold_bkt_us, 1e-9),
        "traces": res[0].buckets,
        "legacy_traces": 1,
        "mxu_flops_per_volley": mxu_flops,
    }


# ---------------------------------------------------- multi-layer network
NET_B = 64  # volleys per epoch


def _net_cfg() -> NetworkConfig:
    """2-layer NSPU: 4 fully-connected 96x8 columns feeding one 32x5."""

    def col(p, q, t_max=64):
        return ColumnConfig(
            p=p, q=q, t_max=t_max, neuron=NeuronConfig(threshold=p * 7 / 8.0)
        )

    return NetworkConfig(layers=(
        LayerConfig(columns=4, column=col(96, 8)),
        LayerConfig(columns=1, column=col(32, 5)),
    ), name="bench2layer")


def run_network() -> dict:
    """Fused per-layer scans (network.fit_greedy) vs the legacy untraced
    per-epoch Python loop they replaced (one vmapped train_step per epoch)."""
    net = _net_cfg()
    rng = np.random.default_rng(1)
    in_width = network.in_width(net)
    params = [
        {
            "w": jnp.asarray(
                rng.integers(
                    0, 8, (l.columns, l.column.p, l.column.q)
                ),
                jnp.float32,
            )
        }
        for l in net.layers
    ]
    x = jnp.asarray(
        rng.integers(0, net.layers[0].column.t_max, (NET_B, in_width)),
        jnp.int32,
    )

    def fused():
        trained = network.fit_greedy(params, x, net, epochs=EPOCHS)
        jax.block_until_ready(trained[-1]["w"])

    def legacy():
        # the pre-fusion fit_greedy: Python epochs loop, per-epoch dispatch
        h = x
        key = jax.random.key(0)
        for lp, layer in zip(params, net.layers):
            c = layer.columns
            hc = jnp.broadcast_to(
                h[..., None, :], h.shape[:-1] + (c, h.shape[-1])
            )
            w = lp["w"]
            for _ in range(EPOCHS):
                key, sub = jax.random.split(key)
                keys = jax.random.split(sub, c)

                def one(wi, xi, ki):
                    p2, _ = column.train_step(
                        {"w": wi}, xi, layer.column, rng=ki
                    )
                    return p2["w"]

                w = jax.vmap(one, in_axes=(0, -2, 0))(w, hc, keys)
            h = network._apply_layer({"w": w}, h, layer, "auto")
        jax.block_until_ready(h)

    # cold first calls: the compile cliff of the blocked per-layer scans vs
    # the legacy per-epoch dispatch loop
    t0 = time.perf_counter()
    fused()
    cold_fused_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    legacy()
    cold_legacy_us = (time.perf_counter() - t0) * 1e6

    # alternating rounds, same rationale as run_sweep
    us_fused, us_legacy = time_pair(fused, legacy)
    volleys = EPOCHS * NET_B
    mxu_flops = sum(
        l.columns * 2 * (l.column.neuron.w_max + 1)
        * l.column.p * l.column.q * l.column.t_max
        for l in net.layers
    )
    lowering = backend.padded_lowering(net.layers[0].column.neuron.response)
    return {
        "case": "net96-4x8-1x5",
        "backend": backend.resolve(
            "auto", net.layers[0].column, training=True
        ),
        # the padded per-layer scan lowers through backend.padded_lowering:
        # Mosaic kernel on TPU (runtime design operands), reference off-TPU
        "lowering": lowering,
        "v_blk": backend.volley_block(lowering, NET_B),
        # per-layer envelopes: both layers get their own bucket (the 96x8
        # and 32x5 columns are outside the waste cap of each other);
        # network layer training does not shard its columns axis, so 1
        "buckets": len(set(network._fused_envelopes(list(net.layers)))),
        "shards": 1,
        "fused_us_per_volley": us_fused / volleys,
        "legacy_us_per_volley": us_legacy / volleys,
        "speedup": us_legacy / max(us_fused, 1e-9),
        "cold_us_per_volley": cold_fused_us / volleys,
        "cold_legacy_us_per_volley": cold_legacy_us / volleys,
        "cold_speedup": cold_legacy_us / max(cold_fused_us, 1e-9),
        "mxu_flops_per_volley": mxu_flops,
    }


def main(argv=None) -> None:
    rows = run()
    rows.append(run_sweep())
    rows.append(run_bucketed_sweep())
    rows.append(run_network())
    print("\n# Fused online-STDP training vs legacy per-epoch loop")
    print("| case | backend | fused us/volley | legacy us/volley | speedup | MXU flops/volley |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['case']} | {r['backend']}/{r['lowering']} | "
              f"{r['fused_us_per_volley']:.1f} | {r['legacy_us_per_volley']:.1f} | "
              f"{r['speedup']:.2f}x | {r['mxu_flops_per_volley']:.2e} |")
    out = pathlib.Path("BENCH_train.json")
    out.write_text(json.dumps(rows, indent=2) + "\n")
    print(f"wrote {out.resolve()}")
    for r in rows:
        emit(f"train/{r['case']}", r["fused_us_per_volley"],
             f"speedup={r['speedup']:.2f}x flops={r['mxu_flops_per_volley']:.2e}")
    # warm throughput is the ISSUE 4 acceptance bar: a fused case that only
    # wins the compile cliff is a regression, and says so loudly
    for r in rows:
        if r["speedup"] < 1.0:
            print(
                f"REGRESSION: {r['case']} warm fused speedup "
                f"{r['speedup']:.2f}x < 1.0 vs legacy "
                f"({r['fused_us_per_volley']:.1f} vs "
                f"{r['legacy_us_per_volley']:.1f} us/volley, "
                f"lowering={r['lowering']})"
            )
    # cold (first-call, compile-inclusive) time is tracked too: the fused
    # paths may trade SOME cold time for warm throughput, but below the
    # tracked floor the compile cliff is a real usability regression and
    # must be loud, not a silent JSON column
    for r in rows:
        cold = r.get("cold_speedup")
        if cold is not None and cold < COLD_REGRESSION_MIN:
            print(
                f"COLD-REGRESSION: {r['case']} cold fused speedup "
                f"{cold:.2f}x < {COLD_REGRESSION_MIN}x floor vs legacy "
                f"({r['cold_us_per_volley']:.1f} vs "
                f"{r['cold_legacy_us_per_volley']:.1f} us/volley cold, "
                f"lowering={r['lowering']})"
            )


if __name__ == "__main__":
    main()
