"""Fused online-STDP training benchmark — the ISSUE 1 perf trajectory.

Times the fused single-scan training path (one jitted, donated lax.scan over
epochs x volleys, fused fire+WTA+STDP body) against the legacy per-epoch
batch-stale loop, on paper column geometries.  Emits ``BENCH_train.json``
(us/volley + MXU FLOPs of the fused kernel algebra) so the perf trajectory
is tracked from this PR onward; later PRs append comparable numbers.

MXU FLOPs count the one-hot plane matmuls of the fused Pallas kernel
(2 * (w_max+1) * p * q * t_max per volley) — the work the TPU lowering puts
on the systolic array; off-TPU the reference lowering does the same algebra
on the VPU-equivalent.
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import backend, column
from repro.core.types import ColumnConfig, NeuronConfig

# (name, B volleys, p, q, t_max) — Beef-shaped default plus small/large cols
CASES = [
    ("col65x2", 64, 65, 2, 64),
    ("col470x5", 120, 470, 5, 64),
    ("col152x2", 64, 152, 2, 100),
]
EPOCHS = 4


def run() -> list:
    rows = []
    rng = np.random.default_rng(0)
    for name, B, p, q, t_max in CASES:
        cfg = ColumnConfig(
            p=p, q=q, t_max=t_max,
            neuron=NeuronConfig(threshold=p * 7 / 8.0),
        )
        params = {
            "w": jnp.asarray(rng.integers(0, 8, (p, q)), jnp.float32)
        }
        x = jnp.asarray(rng.integers(0, t_max, (B, p)), jnp.int32)

        def fused():
            jax.block_until_ready(
                column.fit(params, x, cfg, epochs=EPOCHS)["w"]
            )

        def legacy():
            pr = params
            for _ in range(EPOCHS):
                pr, _ = column.train_step(pr, x, cfg, update="batch")
            jax.block_until_ready(pr["w"])

        us_fused = time_call(fused)
        us_legacy = time_call(legacy)
        volleys = EPOCHS * B
        mxu_flops = 2 * (cfg.neuron.w_max + 1) * p * q * t_max
        rows.append({
            "case": name,
            "backend": backend.resolve("auto", cfg, training=True),
            "lowering": backend.pallas_lowering(),
            "fused_us_per_volley": us_fused / volleys,
            "legacy_us_per_volley": us_legacy / volleys,
            "speedup": us_legacy / max(us_fused, 1e-9),
            "mxu_flops_per_volley": mxu_flops,
        })
    return rows


def main(argv=None) -> None:
    rows = run()
    print("\n# Fused online-STDP training vs legacy per-epoch loop")
    print("| case | backend | fused us/volley | legacy us/volley | speedup | MXU flops/volley |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['case']} | {r['backend']}/{r['lowering']} | "
              f"{r['fused_us_per_volley']:.1f} | {r['legacy_us_per_volley']:.1f} | "
              f"{r['speedup']:.2f}x | {r['mxu_flops_per_volley']:.2e} |")
    out = pathlib.Path("BENCH_train.json")
    out.write_text(json.dumps(rows, indent=2) + "\n")
    print(f"wrote {out.resolve()}")
    for r in rows:
        emit(f"train/{r['case']}", r["fused_us_per_volley"],
             f"speedup={r['speedup']:.2f}x flops={r['mxu_flops_per_volley']:.2e}")


if __name__ == "__main__":
    main()
