"""§Roofline report: three-term roofline per (arch x shape) from the
dry-run artifacts, dominant-bottleneck identification, and the hillclimb
cell selection.  Writes results/roofline.md and fits the beyond-paper
RooflineForecaster (the paper's silicon forecasting idea applied to
compiled cost, DESIGN.md §5).

Run AFTER ``python -m repro.launch.dryrun --all --mesh both``.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit
from repro.configs import REGISTRY, get_arch
from repro.hwgen.forecast import RooflineForecaster
from repro.roofline import analysis


def main(argv=None) -> None:
    rows = analysis.analyze_all(mesh="single")
    if not rows:
        print("no dry-run results found — run repro.launch.dryrun first")
        return
    md = analysis.render_markdown(rows)
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write("# Roofline (single-pod 16x16, per-device terms)\n\n" + md)
    print(md)

    ok = [r for r in rows if r.status == "ok"]
    if ok:
        picks = analysis.pick_hillclimb_cells(rows)
        print("## hillclimb cells")
        for why, r in picks.items():
            print(f"  {why}: {r.arch} x {r.shape} "
                  f"(dominant={r.dominant}, frac={r.roofline_fraction:.3f})")

        # beyond-paper: fit the roofline forecaster on the dry-run table
        feats, targets = [], {t: [] for t in RooflineForecaster.TERMS}
        for r in ok:
            cfg = get_arch(r.arch)
            feats.append([
                cfg.param_count() / 1e9,
                r.model_flops / 1e15,
                r.n_chips / 256.0,
            ])
            targets["compute_s"].append(r.compute_s)
            targets["memory_s"].append(r.memory_s)
            targets["collective_s"].append(r.collective_s)
        if len(feats) >= 4:
            # fit in log space (terms span 5 orders of magnitude across
            # train vs decode cells) — same recipe as the paper's silicon
            # regression, which is also fit on a size-spanning sweep
            fc = RooflineForecaster()
            lf = np.log10(np.maximum(np.asarray(feats), 1e-12))
            lt = {k: np.log10(np.maximum(np.asarray(v), 1e-9))
                  for k, v in targets.items()}
            fc.fit(lf, lt)
            fc.save("results/roofline_forecaster.json")
            pred = fc.predict(lf)
            ratio = 10.0 ** np.abs(pred["compute_s"] - lt["compute_s"])
            print(f"## roofline forecaster (log-space) compute-term fit: "
                  f"median x{np.median(ratio):.2f} / p90 x{np.percentile(ratio, 90):.2f} "
                  f"over {len(feats)} cells")

    for r in ok:
        emit(f"roofline/{r.arch}/{r.shape}", r.bound_s * 1e6,
             f"dominant={r.dominant};frac={r.roofline_fraction:.3f}")


if __name__ == "__main__":
    main()
