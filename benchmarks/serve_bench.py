"""Streaming-service benchmark — requests/sec and latency vs batch/streams.

Stands up a ``repro.serve.ClusteringService`` over a heterogeneous design
fleet (two envelope buckets), warms every executable, then multiplexes
concurrent synthetic streams round-robin through the full serving
pipeline (admission -> encode -> bucket-dispatch -> assign -> online
re-fit) and measures sustained requests/sec plus p50/p99 per-request
latency for several (batch size, stream count) points — the ISSUE 8
millions-of-users story in miniature: >= 64 concurrent streams must
sustain steady-state throughput with ZERO per-request XLA compiles.

Compiles are counted at the same seam the test suite's
``compile_counter`` fixture uses (``jax._src.compiler.backend_compile``
— the one funnel below jit / AOT lowering), installed AFTER
``service.warmup()``: any nonzero count means a request re-traced or
re-compiled something, which is exactly the cliff the envelope-keyed AOT
executables exist to remove.  Results land in ``BENCH_serve.json``;
``--check`` validates the committed floors (requests/sec >= REQS_MIN on
every tracked case, zero steady-state compiles, and at least one case
with >= 64 streams) for CI without re-running the bench, mirroring
``train_bench --check``.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from benchmarks.common import emit

# (case name, batch size, concurrent streams, requests per stream)
CASES = [
    ("serve-b8-s64", 8, 64, 6),
    ("serve-b32-s64", 32, 64, 6),
    ("serve-b32-s256", 32, 256, 3),
]
DESIGNS = 4
LENGTH = 24
T_MAX = 32
REFIT_EVERY = 64

# Floors for --check: the dev host measures ~2000 req/s at every tracked
# point, so 200 req/s trips only on a real regression (a per-request
# compile, a lost executable reuse), not on CI host jitter.  The compile
# floor is exact: the steady state performs ZERO XLA compiles, and any
# other number is a broken warmup or a shape leak.
REQS_MIN = 200.0
MIN_TRACKED_STREAMS = 64


def _fleet():
    from repro.core import simulator
    from repro.core.types import ColumnConfig

    cfgs = {}
    for i in range(DESIGNS):
        # q 3/5, t_max 32/64: under the tightened waste cap the service is
        # built with (2.0), the smallest design falls outside the largest
        # designs' envelope, so the fleet serves from TWO envelope buckets
        # and the bench exercises bucket dispatch
        c = ColumnConfig(
            p=LENGTH, q=3 + 2 * (i % 2), t_max=T_MAX * (1 + (i // 2) % 2)
        )
        cfgs[f"nspu{i}"] = c.with_threshold(simulator.suggest_threshold(c))
    return cfgs


def run_case(name: str, batch: int, streams: int, requests: int) -> dict:
    from jax._src import compiler as _compiler

    from repro.serve import ClusteringService

    service = ClusteringService(
        _fleet(), batch_size=batch, refit_every=REFIT_EVERY,
        refit_window=max(batch, REFIT_EVERY), seed=0, waste_cap=2.0,
    )
    warm = service.warmup()

    # steady-state compile counting starts AFTER warmup, at the suite's
    # compile_counter seam: backend_compile is the one funnel every jit
    # and lower().compile() goes through
    compiles = 0
    orig = _compiler.backend_compile

    def spy(*args, **kwargs):
        nonlocal compiles
        compiles += 1
        return orig(*args, **kwargs)

    rngs = [np.random.default_rng(s) for s in range(streams)]
    names = service.designs()
    handles = []
    _compiler.backend_compile = spy
    try:
        t0 = time.perf_counter()
        for _ in range(requests):
            for s, rng in enumerate(rngs):
                handles.append(service.submit(
                    rng.normal(size=LENGTH), names[s % len(names)]
                ))
        service.flush()
        elapsed = time.perf_counter() - t0
    finally:
        _compiler.backend_compile = orig

    lat = sorted(h.result().latency_s for h in handles)
    stats = service.stats()
    assert stats.served == len(handles) and not stats.failed, stats
    n = len(lat)
    return {
        "case": name,
        "batch": batch,
        "streams": streams,
        "requests": n,
        "buckets": warm["buckets"],
        "reqs_per_sec": n / max(elapsed, 1e-9),
        "us_per_request": elapsed * 1e6 / n,
        "p50_ms": lat[n // 2] * 1e3,
        "p99_ms": lat[min(n - 1, int(n * 0.99))] * 1e3,
        "refits": stats.refits,
        "compiles_after_warmup": compiles,
    }


def check() -> int:
    """Validate the committed ``BENCH_serve.json`` floors (CI smoke)."""
    path = pathlib.Path("BENCH_serve.json")
    rows = {r["case"]: r for r in json.loads(path.read_text())}
    failed = 0
    if not any(
        r["streams"] >= MIN_TRACKED_STREAMS for r in rows.values()
    ):
        print(
            f"CHECK-FAIL: no tracked case sustains >= "
            f"{MIN_TRACKED_STREAMS} concurrent streams"
        )
        failed = 1
    for name, _, _, _ in CASES:
        r = rows.get(name)
        if r is None:
            print(f"CHECK-FAIL: tracked case {name} missing from {path}")
            failed = 1
            continue
        if r["reqs_per_sec"] < REQS_MIN:
            print(
                f"CHECK-FAIL: {name} {r['reqs_per_sec']:.0f} req/s < "
                f"{REQS_MIN:.0f} floor"
            )
            failed = 1
        if r["compiles_after_warmup"] != 0:
            print(
                f"CHECK-FAIL: {name} performed "
                f"{r['compiles_after_warmup']} steady-state XLA compiles "
                f"(must be 0 after warmup)"
            )
            failed = 1
    if not failed:
        print(f"serve bench floors OK for {', '.join(n for n, *_ in CASES)}")
    return failed


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--check", action="store_true",
        help="validate the committed BENCH_serve.json floors and exit",
    )
    args = ap.parse_args(argv)
    if args.check:
        raise SystemExit(check())
    rows = [run_case(*case) for case in CASES]
    print("\n# Streaming clustering service — throughput vs batch/streams")
    print("| case | batch | streams | req/s | p50 ms | p99 ms | refits | "
          "compiles |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(
            f"| {r['case']} | {r['batch']} | {r['streams']} | "
            f"{r['reqs_per_sec']:.0f} | {r['p50_ms']:.2f} | "
            f"{r['p99_ms']:.2f} | {r['refits']} | "
            f"{r['compiles_after_warmup']} |"
        )
    out = pathlib.Path("BENCH_serve.json")
    out.write_text(json.dumps(rows, indent=2) + "\n")
    print(f"wrote {out.resolve()}")
    for r in rows:
        emit(
            f"serve/{r['case']}", r["us_per_request"],
            f"rps={r['reqs_per_sec']:.0f} p50={r['p50_ms']:.2f}ms "
            f"p99={r['p99_ms']:.2f}ms compiles={r['compiles_after_warmup']}",
        )
    for r in rows:
        if r["reqs_per_sec"] < REQS_MIN:
            print(
                f"REGRESSION: {r['case']} {r['reqs_per_sec']:.0f} req/s "
                f"< {REQS_MIN:.0f} floor"
            )
        if r["compiles_after_warmup"]:
            print(
                f"COMPILE-REGRESSION: {r['case']} performed "
                f"{r['compiles_after_warmup']} XLA compiles after warmup "
                "(steady state must be compile-free)"
            )


if __name__ == "__main__":
    main()
