"""Streaming-service benchmark — requests/sec and latency vs batch/streams.

Stands up a ``repro.serve.ClusteringService`` over a heterogeneous design
fleet (two envelope buckets), warms every executable, then multiplexes
concurrent synthetic streams round-robin through the full serving
pipeline (admission -> encode -> bucket-dispatch -> assign -> online
re-fit) and measures sustained requests/sec plus p50/p99 per-request
latency for several (batch size, stream count) points — the ISSUE 8
millions-of-users story in miniature: >= 64 concurrent streams must
sustain steady-state throughput with ZERO per-request XLA compiles.

Compiles are counted at the same seam the test suite's
``compile_counter`` fixture uses (``jax._src.compiler.backend_compile``
— the one funnel below jit / AOT lowering), installed AFTER
``service.warmup()``: any nonzero count means a request re-traced or
re-compiled something, which is exactly the cliff the envelope-keyed AOT
executables exist to remove.  Results land in ``BENCH_serve.json``;
``--check`` validates the committed floors (requests/sec >= REQS_MIN on
every tracked case, zero steady-state compiles, and at least one case
with >= 64 streams) for CI without re-running the bench, mirroring
``train_bench --check``.

Two robustness rows ride along with the throughput cases:

* ``serve-overload-b32`` offers more traffic than ``max_pending`` admits
  each round and measures what overload control delivers: a real shed
  rate (structured ``reason='overloaded'`` rejections, not timeouts) and
  a bounded p99 for the requests that WERE admitted.
* ``serve-chaos-refit`` injects a hard online re-fit failure through the
  shared fault harness (``repro.testing.faults``) at the fused-kernel
  seam, drives traffic through the degraded window, lifts the fault and
  drives to recovery — the service must keep answering from last-good
  weights throughout (zero request failures, zero steady-state
  compiles), then re-fit again.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from benchmarks.common import emit

# (case name, batch size, concurrent streams, requests per stream)
CASES = [
    ("serve-b8-s64", 8, 64, 6),
    ("serve-b32-s64", 32, 64, 6),
    ("serve-b32-s256", 32, 256, 3),
]
DESIGNS = 4
LENGTH = 24
T_MAX = 32
REFIT_EVERY = 64

# Floors for --check: the dev host measures ~2000 req/s at every tracked
# point, so 200 req/s trips only on a real regression (a per-request
# compile, a lost executable reuse), not on CI host jitter.  The compile
# floor is exact: the steady state performs ZERO XLA compiles, and any
# other number is a broken warmup or a shape leak.
REQS_MIN = 200.0
MIN_TRACKED_STREAMS = 64

# Overload row: 64 offers/round against max_pending=24 must shed most of
# the excess (the dev host sheds ~60%; 5% trips only if shedding broke)
# while the admitted requests keep a sane tail — 1s is ~3 orders above
# the measured p99, so it trips on a stall, not on jitter.
OVERLOAD_CASE = ("serve-overload-b32", 32, 24, 64, 6)  # batch, max_pending, offered/round, rounds
SHED_MIN = 0.05
P99_OVERLOAD_MAX_MS = 1000.0

# Chaos row: the injected re-fit outage must register (>= 1 failed
# window), never fail a request, and fully recover once lifted.
CHAOS_CASE = ("serve-chaos-refit", 8, 16)  # batch, streams


def _fleet():
    from repro.core import simulator
    from repro.core.types import ColumnConfig

    cfgs = {}
    for i in range(DESIGNS):
        # q 3/5, t_max 32/64: under the tightened waste cap the service is
        # built with (2.0), the smallest design falls outside the largest
        # designs' envelope, so the fleet serves from TWO envelope buckets
        # and the bench exercises bucket dispatch
        c = ColumnConfig(
            p=LENGTH, q=3 + 2 * (i % 2), t_max=T_MAX * (1 + (i // 2) % 2)
        )
        cfgs[f"nspu{i}"] = c.with_threshold(simulator.suggest_threshold(c))
    return cfgs


class _CompileSpy:
    """Steady-state compile counter at the suite's ``compile_counter``
    seam (``jax._src.compiler.backend_compile`` — the one funnel below
    jit / AOT lowering).  Install AFTER ``service.warmup()``."""

    def __init__(self):
        self.count = 0

    def __enter__(self):
        from jax._src import compiler as _compiler

        self._compiler = _compiler
        self._orig = _compiler.backend_compile

        def spy(*args, **kwargs):
            self.count += 1
            return self._orig(*args, **kwargs)

        _compiler.backend_compile = spy
        return self

    def __exit__(self, *exc):
        self._compiler.backend_compile = self._orig
        return False


def run_case(name: str, batch: int, streams: int, requests: int) -> dict:
    from repro.serve import ClusteringService

    service = ClusteringService(
        _fleet(), batch_size=batch, refit_every=REFIT_EVERY,
        refit_window=max(batch, REFIT_EVERY), seed=0, waste_cap=2.0,
    )
    warm = service.warmup()

    rngs = [np.random.default_rng(s) for s in range(streams)]
    names = service.designs()
    handles = []
    with _CompileSpy() as spy:
        t0 = time.perf_counter()
        for _ in range(requests):
            for s, rng in enumerate(rngs):
                handles.append(service.submit(
                    rng.normal(size=LENGTH), names[s % len(names)]
                ))
        service.flush()
        elapsed = time.perf_counter() - t0
    compiles = spy.count

    lat = sorted(h.result().latency_s for h in handles)
    stats = service.stats()
    assert stats.served == len(handles) and not stats.failed, stats
    n = len(lat)
    return {
        "case": name,
        "batch": batch,
        "streams": streams,
        "requests": n,
        "buckets": warm["buckets"],
        "reqs_per_sec": n / max(elapsed, 1e-9),
        "us_per_request": elapsed * 1e6 / n,
        "p50_ms": lat[n // 2] * 1e3,
        "p99_ms": lat[min(n - 1, int(n * 0.99))] * 1e3,
        "refits": stats.refits,
        "compiles_after_warmup": compiles,
    }


def run_overload_case() -> dict:
    """Offer more traffic per round than the bounded queue admits; measure
    the shed rate and the served requests' tail latency under overload."""
    from repro.serve import ClusteringService, RequestRejected

    name, batch, max_pending, offered_per_round, rounds = OVERLOAD_CASE
    service = ClusteringService(
        _fleet(), batch_size=batch, refit_every=REFIT_EVERY,
        refit_window=max(batch, REFIT_EVERY), seed=0, waste_cap=2.0,
        max_pending=max_pending,
    )
    warm = service.warmup()

    rngs = [np.random.default_rng(s) for s in range(offered_per_round)]
    names = service.designs()
    handles = []
    shed_overloaded = 0
    with _CompileSpy() as spy:
        t0 = time.perf_counter()
        for _ in range(rounds):
            # a burst far above capacity: max_pending < batch, so nothing
            # auto-executes mid-burst and the tail of every burst sheds
            for s, rng in enumerate(rngs):
                try:
                    handles.append(service.submit(
                        rng.normal(size=LENGTH), names[s % len(names)]
                    ))
                except RequestRejected as e:
                    assert e.reason == "overloaded", e
                    shed_overloaded += 1
            service.flush()
        elapsed = time.perf_counter() - t0
    compiles = spy.count

    lat = sorted(h.result().latency_s for h in handles)
    stats = service.stats()
    assert stats.served == len(handles) and not stats.failed, stats
    assert stats.rejections.get("overloaded", 0) == shed_overloaded
    offered = rounds * offered_per_round
    n = len(lat)
    return {
        "case": name,
        "batch": batch,
        "max_pending": max_pending,
        "streams": offered_per_round,
        "offered": offered,
        "requests": n,
        "buckets": warm["buckets"],
        "shed_rate": shed_overloaded / offered,
        "reqs_per_sec": n / max(elapsed, 1e-9),
        "us_per_request": elapsed * 1e6 / max(n, 1),
        "p50_ms": lat[n // 2] * 1e3,
        "p99_ms": lat[min(n - 1, int(n * 0.99))] * 1e3,
        "compiles_after_warmup": compiles,
    }


def run_chaos_case() -> dict:
    """Inject a hard online re-fit failure at the fused-kernel seam, drive
    traffic through the degraded window (the service must keep answering
    from last-good weights), lift the fault and drive to recovery."""
    from repro.serve import ClusteringService
    from repro.testing import faults

    name, batch, streams = CHAOS_CASE
    refit_every = batch  # one re-fit decision per bucket per round
    service = ClusteringService(
        _fleet(), batch_size=batch, refit_every=refit_every,
        refit_window=batch, seed=0, waste_cap=2.0,
    )
    warm = service.warmup()
    buckets = warm["buckets"]

    rngs = [np.random.default_rng(s) for s in range(streams)]
    names = service.designs()
    handles = []

    def drive_round():
        for s, rng in enumerate(rngs):
            handles.append(service.submit(
                rng.normal(size=LENGTH), names[s % len(names)]
            ))
        service.flush()

    with _CompileSpy() as spy:
        t0 = time.perf_counter()
        # phase 1: the re-fit path is down hard; serving must not be
        with faults.injected("fit_scan_padded", faults.fail_always,
                             detail="chaos: refit executable down"):
            for _ in range(8):
                drive_round()
        mid = service.stats()
        # phase 2: fault lifted; cooldown expires, re-fits commit again
        lift_rounds = 0
        while service.stats().degraded and lift_rounds < 40:
            drive_round()
            lift_rounds += 1
        elapsed = time.perf_counter() - t0
    compiles = spy.count

    stats = service.stats()
    assert stats.served == len(handles) and not stats.failed, stats
    assert mid.degraded == buckets, mid  # every bucket degraded under injection
    n = len(handles)
    return {
        "case": name,
        "batch": batch,
        "streams": streams,
        "requests": n,
        "buckets": buckets,
        "reqs_per_sec": n / max(elapsed, 1e-9),
        "us_per_request": elapsed * 1e6 / max(n, 1),
        "refit_failures": stats.refit_failures,
        "recoveries": stats.recoveries,
        "degraded_at_end": stats.degraded,
        "failed": stats.failed,
        "lift_rounds": lift_rounds,
        "compiles_after_warmup": compiles,
    }


def check() -> int:
    """Validate the committed ``BENCH_serve.json`` floors (CI smoke)."""
    path = pathlib.Path("BENCH_serve.json")
    rows = {r["case"]: r for r in json.loads(path.read_text())}
    failed = 0
    if not any(
        r["streams"] >= MIN_TRACKED_STREAMS for r in rows.values()
    ):
        print(
            f"CHECK-FAIL: no tracked case sustains >= "
            f"{MIN_TRACKED_STREAMS} concurrent streams"
        )
        failed = 1
    for name, _, _, _ in CASES:
        r = rows.get(name)
        if r is None:
            print(f"CHECK-FAIL: tracked case {name} missing from {path}")
            failed = 1
            continue
        if r["reqs_per_sec"] < REQS_MIN:
            print(
                f"CHECK-FAIL: {name} {r['reqs_per_sec']:.0f} req/s < "
                f"{REQS_MIN:.0f} floor"
            )
            failed = 1
        if r["compiles_after_warmup"] != 0:
            print(
                f"CHECK-FAIL: {name} performed "
                f"{r['compiles_after_warmup']} steady-state XLA compiles "
                f"(must be 0 after warmup)"
            )
            failed = 1

    ov = rows.get(OVERLOAD_CASE[0])
    if ov is None:
        print(f"CHECK-FAIL: overload case {OVERLOAD_CASE[0]} missing")
        failed = 1
    else:
        if ov["shed_rate"] < SHED_MIN:
            print(
                f"CHECK-FAIL: {ov['case']} shed rate {ov['shed_rate']:.3f} "
                f"< {SHED_MIN} — overload control is not shedding"
            )
            failed = 1
        if ov["p99_ms"] > P99_OVERLOAD_MAX_MS:
            print(
                f"CHECK-FAIL: {ov['case']} p99 {ov['p99_ms']:.1f} ms > "
                f"{P99_OVERLOAD_MAX_MS:.0f} ms under overload"
            )
            failed = 1
        if ov["reqs_per_sec"] < REQS_MIN:
            print(
                f"CHECK-FAIL: {ov['case']} served "
                f"{ov['reqs_per_sec']:.0f} req/s < {REQS_MIN:.0f} floor"
            )
            failed = 1
        if ov["compiles_after_warmup"] != 0:
            print(
                f"CHECK-FAIL: {ov['case']} compiled under overload "
                f"({ov['compiles_after_warmup']})"
            )
            failed = 1

    ch = rows.get(CHAOS_CASE[0])
    if ch is None:
        print(f"CHECK-FAIL: chaos case {CHAOS_CASE[0]} missing")
        failed = 1
    else:
        if ch["refit_failures"] < 1:
            print(
                f"CHECK-FAIL: {ch['case']} registered no re-fit failures — "
                "the injected outage did not land"
            )
            failed = 1
        if ch["recoveries"] < 1 or ch["degraded_at_end"]:
            print(
                f"CHECK-FAIL: {ch['case']} did not recover "
                f"(recoveries={ch['recoveries']}, "
                f"degraded_at_end={ch['degraded_at_end']})"
            )
            failed = 1
        if ch["failed"]:
            print(
                f"CHECK-FAIL: {ch['case']} failed {ch['failed']} requests "
                "during the re-fit outage (must serve from last-good "
                "weights)"
            )
            failed = 1
        if ch["compiles_after_warmup"] != 0:
            print(
                f"CHECK-FAIL: {ch['case']} compiled during the outage "
                f"({ch['compiles_after_warmup']})"
            )
            failed = 1

    if not failed:
        tracked = [n for n, *_ in CASES] + [OVERLOAD_CASE[0], CHAOS_CASE[0]]
        print(f"serve bench floors OK for {', '.join(tracked)}")
    return failed


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--check", action="store_true",
        help="validate the committed BENCH_serve.json floors and exit",
    )
    args = ap.parse_args(argv)
    if args.check:
        raise SystemExit(check())
    rows = [run_case(*case) for case in CASES]
    print("\n# Streaming clustering service — throughput vs batch/streams")
    print("| case | batch | streams | req/s | p50 ms | p99 ms | refits | "
          "compiles |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(
            f"| {r['case']} | {r['batch']} | {r['streams']} | "
            f"{r['reqs_per_sec']:.0f} | {r['p50_ms']:.2f} | "
            f"{r['p99_ms']:.2f} | {r['refits']} | "
            f"{r['compiles_after_warmup']} |"
        )
    ov = run_overload_case()
    print(
        f"\n{ov['case']}: offered {ov['offered']}, served {ov['requests']} "
        f"({ov['shed_rate']:.0%} shed), p99 {ov['p99_ms']:.2f} ms, "
        f"{ov['reqs_per_sec']:.0f} req/s, "
        f"compiles {ov['compiles_after_warmup']}"
    )
    ch = run_chaos_case()
    print(
        f"{ch['case']}: {ch['requests']} served through "
        f"{ch['refit_failures']} failed re-fit window(s), "
        f"{ch['recoveries']} recovery(ies) after {ch['lift_rounds']} "
        f"round(s), failed {ch['failed']}, "
        f"compiles {ch['compiles_after_warmup']}"
    )
    rows += [ov, ch]
    out = pathlib.Path("BENCH_serve.json")
    out.write_text(json.dumps(rows, indent=2) + "\n")
    print(f"wrote {out.resolve()}")
    for r in rows:
        extra = (
            f"rps={r['reqs_per_sec']:.0f} "
            f"compiles={r['compiles_after_warmup']}"
        )
        if "shed_rate" in r:
            extra += f" shed={r['shed_rate']:.2f} p99={r['p99_ms']:.2f}ms"
        elif "recoveries" in r:
            extra += (
                f" refit_failures={r['refit_failures']} "
                f"recoveries={r['recoveries']}"
            )
        else:
            extra += f" p50={r['p50_ms']:.2f}ms p99={r['p99_ms']:.2f}ms"
        emit(f"serve/{r['case']}", r["us_per_request"], extra)
    for r in rows[: len(CASES)]:
        if r["reqs_per_sec"] < REQS_MIN:
            print(
                f"REGRESSION: {r['case']} {r['reqs_per_sec']:.0f} req/s "
                f"< {REQS_MIN:.0f} floor"
            )
        if r["compiles_after_warmup"]:
            print(
                f"COMPILE-REGRESSION: {r['case']} performed "
                f"{r['compiles_after_warmup']} XLA compiles after warmup "
                "(steady state must be compile-free)"
            )


if __name__ == "__main__":
    main()
