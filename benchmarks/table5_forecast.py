"""Paper Table V + Fig. 4: forecasting post-layout area/leakage from the
synapse count without running the hardware flow.

Two forecasters:
  * the paper's fixed regression (area = 5.56*syn - 94.9;
    leakage = 0.00541*syn - 0.725) against the paper's own TNN7 actuals —
    reproduces Table V's errors exactly,
  * our refit forecaster, trained on leave-one-out flow runs (the paper's
    "continually refined with more design points" workflow).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.configs.tnn_columns import all_benchmarks, hardware_spec
from repro.data.ucr import PAPER_COLUMNS
from repro.hwgen import pdk, run_flow
from repro.hwgen.forecast import Forecaster, PaperForecaster


def run() -> list:
    pf = PaperForecaster()
    rows = []
    all_runs = {n: run_flow(hardware_spec(n), "tnn7") for n in all_benchmarks()}
    for name in all_benchmarks():
        idx = [b for b, _ in pdk.PAPER_DESIGNS].index(name)
        syn = pdk.PAPER_DESIGNS[idx][1]
        area_actual = pdk.PAPER_AREA["tnn7"][idx]
        leak_actual = pdk.PAPER_LEAKAGE["tnn7"][idx]
        # leave-one-out refit on the modeled flow database
        fc = Forecaster()
        fc.add_runs([r for n, r in all_runs.items() if n != name])
        fc.fit("tnn7")
        rows.append({
            "benchmark": name, "synapses": syn,
            "fc_area": pf.area_um2(syn),
            "fc_area_err_pct": 100 * (pf.area_um2(syn) - area_actual) / area_actual,
            "fc_leak": pf.leakage_uw(syn),
            "fc_leak_err_pct": 100 * (pf.leakage_uw(syn) - leak_actual) / leak_actual,
            "refit_area_err_pct": 100 * (fc.area_um2(syn) - area_actual) / area_actual,
            "refit_leak_err_pct": 100 * (fc.leakage_uw(syn) - leak_actual) / leak_actual,
        })
    return rows


def main(argv=None) -> None:
    rows = run()
    print("\n# Table V — forecasted TNN7 7nm PPA (paper eqns + refit model)")
    print("| benchmark | syn | FC area | FC err% | FC leak | FC err% | refit area err% | refit leak err% |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['benchmark']} | {r['synapses']} | {r['fc_area']:.1f} | "
              f"{r['fc_area_err_pct']:+.2f} | {r['fc_leak']:.2f} | "
              f"{r['fc_leak_err_pct']:+.2f} | {r['refit_area_err_pct']:+.2f} | "
              f"{r['refit_leak_err_pct']:+.2f} |")
    for r in rows:
        emit(f"table5/{r['benchmark']}", 0.0,
             f"fc_area_err={r['fc_area_err_pct']:+.2f}%")


if __name__ == "__main__":
    main()
