"""Cost-model smoke bench — predict-vs-measure error report (ISSUE 10).

Calibrates (or loads) the host's ``costmodel.DeviceProfile``, then for a
small grid of padded-fit envelopes asks the cost model for its
``ExecutionPlan`` and predicted warm step time, measures the real warm
step time through the production entry point (``backend.fit_padded``),
and prints the prediction error per case.  The point is NOT tight error —
the prediction only has to rank candidate blockings correctly — but the
ratio drifting far from its recorded band is the earliest sign the model
or the probes rotted.  Registered in ``benchmarks.run`` so ``--check``
fails on import rot like every other table.

Emits ``costmodel/<case>,measured_us_per_volley,pred=...`` CSV rows.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import backend
from repro.core.types import TIME_DTYPE
from repro.roofline import costmodel

# (name, d, p, q, t_window, volleys, epochs) — the tracked sweep geometry
# plus a skinny and a wide neighbor, so the report covers the envelope
# range the simulator front-end actually produces
CASES = [
    ("fit4x96x10t64", 4, 96, 10, 64, 64, 4),
    ("fit2x64x8t64", 2, 64, 8, 64, 64, 2),
    ("fit8x128x5t32", 8, 128, 5, 32, 64, 2),
]


def _measure_case(name, d, p, q, t_window, n_volleys, epochs) -> dict:
    rng = np.random.default_rng(0)
    w0 = np.asarray(rng.integers(0, 8, (d, p, q)), np.float32)
    xs = jnp.asarray(
        rng.integers(0, t_window, (n_volleys, d, p)), TIME_DTYPE
    )
    thresholds = jnp.full((d,), p * 7 / 8.0, jnp.float32)
    t_maxes = jnp.full((d,), t_window, TIME_DTYPE)
    q_actives = jnp.full((d,), q, TIME_DTYPE)
    lowering = backend.padded_lowering("rnl")
    plan = backend.execution_plan(
        "fit", lowering, d, p, q, t_window, n_volleys, epochs,
    )

    def fit():
        # fresh device copy each call: fit_padded donates its weight operand
        jax.block_until_ready(backend.fit_padded(
            jnp.asarray(w0), xs, thresholds, t_maxes, q_actives,
            t_window=t_window, w_max=7, wta_k=1,
            mu_capture=0.5, mu_backoff=-0.5, mu_search=0.1,
            stabilize=True, response="rnl",
            epochs=epochs, lowering=lowering,
        ))

    us = time_call(fit)
    meas_step_us = us / (epochs * n_volleys)
    pred_step_us = plan.predicted_step_s * 1e6
    return {
        "case": name,
        "lowering": lowering,
        "plan": plan.meta(),
        "measured_us_per_volley": meas_step_us,
        "predicted_us_per_volley": pred_step_us,
        "predicted_measured_ratio": (
            pred_step_us / meas_step_us if meas_step_us else float("nan")
        ),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--force", action="store_true",
        help="re-probe the device even if a calibration is already saved",
    )
    args = ap.parse_args(argv)
    try:
        prof = (
            costmodel.calibrate(force=True) if args.force
            else costmodel.load_or_calibrate()
        )
        print(
            f"profile: {prof.name} (calibrated={prof.calibrated}, "
            f"peak={prof.peak_flops:.3g} FLOP/s, bw={prof.hbm_bw:.3g} B/s, "
            f"dispatch={prof.dispatch_s * 1e6:.1f} us, "
            f"fused_eff={prof.fused_eff:.2f})"
        )
    except Exception as e:
        print(f"calibration unavailable ({e!r}); constants fallback")

    rows = [_measure_case(*case) for case in CASES]
    print("\n# Cost model: predicted vs measured warm step time")
    print("| case | lowering | plan (v,t,shards) | predicted us/volley | "
          "measured us/volley | pred/meas |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        pl = r["plan"]
        print(
            f"| {r['case']} | {r['lowering']} | "
            f"({pl['v_blk']},{pl['t_blk']},{pl['shards']}) | "
            f"{r['predicted_us_per_volley']:.1f} | "
            f"{r['measured_us_per_volley']:.1f} | "
            f"{r['predicted_measured_ratio']:.2f} |"
        )
    for r in rows:
        emit(
            f"costmodel/{r['case']}", r["measured_us_per_volley"],
            f"pred/meas={r['predicted_measured_ratio']:.2f} "
            f"source={r['plan']['source']}",
        )


if __name__ == "__main__":
    main()
