"""Paper Fig. 2 + §III-B: per-sample computation latency of generated
columns, and functional-simulator throughput (cycle vs event mode).

Latency comes from the calibrated silicon latency model; the simulator
half times our JAX implementation's two timing modes on the same column —
quantifying the event-driven speedup the paper's hybrid scheduler exploits.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, time_call
from repro.configs.tnn_columns import column_config
from repro.core import column as column_lib
from repro.core import encoding
from repro.core.simulator import suggest_threshold
from repro.data.ucr import PAPER_COLUMNS
from repro.hwgen import pdk

FITTED = [(65, 2), (96, 2), (152, 2), (270, 25)]  # Fig. 2 + largest column


def run() -> list:
    rows = []
    for p, q in FITTED:
        lat = pdk.latency_model_ns(p, q)
        paper = pdk.PAPER_LATENCY_NS.get((p, q))
        name = next(n for n, pq in PAPER_COLUMNS.items() if pq == (p, q))
        cfg = column_config(name)
        cfg = cfg.with_threshold(suggest_threshold(cfg))
        ds_x = np.random.default_rng(0).normal(size=(64, cfg.p))
        volleys = encoding.latency_encode(jax.numpy.asarray(ds_x), cfg.t_max)
        params = column_lib.init_params(jax.random.key(0), cfg)

        def fwd(mode):
            y, _ = column_lib.apply(params, volleys, cfg, mode)
            jax.block_until_ready(y)

        us_event = time_call(fwd, "event")
        us_cycle = time_call(fwd, "cycle")
        rows.append({
            "column": f"{p}x{q}", "latency_ns": lat, "paper_ns": paper,
            "sim_event_us": us_event, "sim_cycle_us": us_cycle,
            "event_speedup": us_cycle / max(us_event, 1e-9),
        })
    return rows


def main(argv=None) -> None:
    rows = run()
    print("\n# Fig. 2 — computation latency + simulator mode comparison")
    print("| column | latency(model) ns | latency(paper) ns | sim event us/64 | sim cycle us/64 | event speedup |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['column']} | {r['latency_ns']:.1f} | {r['paper_ns']:.1f} | "
              f"{r['sim_event_us']:.0f} | {r['sim_cycle_us']:.0f} | "
              f"{r['event_speedup']:.1f}x |")
    for r in rows:
        emit(f"fig2/{r['column']}", r["sim_event_us"],
             f"latency_ns={r['latency_ns']:.1f}")


if __name__ == "__main__":
    main()
