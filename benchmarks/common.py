"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
contract) plus a human-readable table reproducing its paper table.
"""
from __future__ import annotations

import time


def time_call(fn, *args, repeats: int = 3, warmup: int = 1, **kw) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn(*args, **kw)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def time_pair(
    fn_a, fn_b, repeats: int = 7, warmup: int = 1
) -> tuple[float, float]:
    """Min wall-time per call (us) for two workloads, measured in
    ALTERNATING rounds.

    For head-to-head rows (fused vs legacy) on a shared, drifting host —
    frequency scaling, co-tenant load — sequential timing systematically
    biases whichever side runs first, so the rounds alternate; and ambient
    interference only ever ADDS time, so the minimum over rounds is the
    robust estimator of each side's true cost (the same reasoning behind
    ``timeit``'s min-not-mean recommendation).
    """
    for _ in range(warmup):
        fn_a()
        fn_b()
    ta, tb = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn_a()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        tb.append(time.perf_counter() - t0)
    return min(ta) * 1e6, min(tb) * 1e6


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")
