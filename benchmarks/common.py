"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
contract) plus a human-readable table reproducing its paper table.
"""
from __future__ import annotations

import os
import time


def time_call(fn, *args, repeats: int = 3, warmup: int = 1, **kw) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn(*args, **kw)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def time_pair(
    fn_a, fn_b, repeats: int = 7, warmup: int = 1
) -> tuple[float, float]:
    """Min wall-time per call (us) for two workloads, measured in
    ALTERNATING rounds.

    For head-to-head rows (fused vs legacy) on a shared, drifting host —
    frequency scaling, co-tenant load — sequential timing systematically
    biases whichever side runs first, so the rounds alternate; and ambient
    interference only ever ADDS time, so the minimum over rounds is the
    robust estimator of each side's true cost (the same reasoning behind
    ``timeit``'s min-not-mean recommendation).
    """
    for _ in range(warmup):
        fn_a()
        fn_b()
    ta, tb = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn_a()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        tb.append(time.perf_counter() - t0)
    return min(ta) * 1e6, min(tb) * 1e6


def time_cold(fn) -> float:
    """Wall time of ONE first call in microseconds — the compile-inclusive
    cold cost.

    Only meaningful when two preconditions hold, and the caller owns both:
    ``fn`` has never executed in this process (no jit/AOT cache hit), and
    the persistent compilation cache state is known and RECORDED next to
    the number (see ``cache_state``).  Against a populated persistent
    cache the very same first call is a disk read, not a compile — fast,
    real, and worth reporting, but as a warm-process cold start, never as
    the compile cliff it silently masquerades as.
    """
    t0 = time.perf_counter()
    fn()
    return (time.perf_counter() - t0) * 1e6


def cache_state(path) -> str:
    """Label the persistent-compilation-cache state for a cold row:
    ``'off'`` (no cache dir), ``'fresh'`` (enabled but empty — first calls
    pay true compiles), ``'populated'`` (has entries — first calls may be
    cache reads).  Call BEFORE the cold measurement: the measurement
    itself populates the cache.
    """
    if not path:
        return "off"
    try:
        entries = [e for e in os.listdir(path) if not e.startswith(".")]
    except OSError:
        return "off"
    return "populated" if entries else "fresh"


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")
