"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
contract) plus a human-readable table reproducing its paper table.
"""
from __future__ import annotations

import time


def time_call(fn, *args, repeats: int = 3, warmup: int = 1, **kw) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        fn(*args, **kw)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")
