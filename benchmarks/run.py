"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table2 fig3 ...]

Emits ``name,us_per_call,derived`` CSV rows (plus human tables) for:
  table2   — Table II  clustering rand index (TNN / DTCR / k-means)
  table34  — Tables III+IV  post-P&R leakage + area, 3 libraries
  fig2     — Fig. 2  computation latency + simulator mode comparison
  fig3     — Fig. 3  P&R runtime ASAP7 vs TNN7
  table5   — Table V  area/leakage forecasting + errors
  kernels  — Pallas kernel sweeps (beyond paper)
  train    — fused online-STDP training vs legacy loop (BENCH_train.json)
  roofline — §Roofline report from dry-run artifacts (if present)
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    fig2_latency,
    fig3_runtime,
    kernels_bench,
    roofline,
    table2_clustering,
    table34_silicon,
    table5_forecast,
    train_bench,
)

MODULES = {
    "table2": table2_clustering,
    "table34": table34_silicon,
    "fig2": fig2_latency,
    "fig3": fig3_runtime,
    "table5": table5_forecast,
    "kernels": kernels_bench,
    "train": train_bench,
    "roofline": roofline,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", choices=tuple(MODULES), default=None)
    args = ap.parse_args()
    failed = []
    for name, mod in MODULES.items():
        if args.only and name not in args.only:
            continue
        print(f"\n===== {name} =====")
        try:
            mod.main([])
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"\nFAILED benchmarks: {failed}")
        return 1
    print("\nall benchmarks complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
