"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only table2 fig3 ...]
    PYTHONPATH=src python -m benchmarks.run --check

Emits ``name,us_per_call,derived`` CSV rows (plus human tables) for:
  table2   — Table II  clustering rand index (TNN / DTCR / k-means)
  table34  — Tables III+IV  post-P&R leakage + area, 3 libraries
  fig2     — Fig. 2  computation latency + simulator mode comparison
  fig3     — Fig. 3  P&R runtime ASAP7 vs TNN7
  table5   — Table V  area/leakage forecasting + errors
  kernels  — Pallas kernel sweeps (beyond paper)
  train    — fused online-STDP training (columns + multi-layer network)
             vs legacy loops (BENCH_train.json)
  dse      — fault-isolation + journal overhead of the design sweep
  serve    — streaming clustering service req/s + latency (BENCH_serve.json)
  roofline — §Roofline report from dry-run artifacts (if present)
  costmodel — device-calibrated cost model: predicted vs measured step time

``--check`` imports every registered benchmark and exits nonzero if any
fails to import, so the reproduction commands documented in README.md
cannot silently rot.  Modules are imported lazily either way: one broken
benchmark never takes down the others.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

MODULES = {
    "table2": "benchmarks.table2_clustering",
    "table34": "benchmarks.table34_silicon",
    "fig2": "benchmarks.fig2_latency",
    "fig3": "benchmarks.fig3_runtime",
    "table5": "benchmarks.table5_forecast",
    "kernels": "benchmarks.kernels_bench",
    "train": "benchmarks.train_bench",
    "dse": "benchmarks.dse_bench",
    "serve": "benchmarks.serve_bench",
    "roofline": "benchmarks.roofline",
    "costmodel": "benchmarks.costmodel_bench",
}


def check(only=None) -> int:
    """Import the registered benchmarks; nonzero exit on any failure."""
    failed = []
    checked = 0
    for name, path in MODULES.items():
        if only and name not in only:
            continue
        checked += 1
        try:
            mod = importlib.import_module(path)
        except Exception:
            traceback.print_exc()
            failed.append(name)
            continue
        if not callable(getattr(mod, "main", None)):
            print(f"{name}: {path} has no callable main()")
            failed.append(name)
    if failed:
        print(f"FAILED import check: {failed}")
        return 1
    print(f"all {checked} checked benchmarks import cleanly")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", choices=tuple(MODULES), default=None)
    ap.add_argument(
        "--check", action="store_true",
        help="only verify every benchmark imports; exit nonzero on failure",
    )
    args = ap.parse_args()
    if args.check:
        return check(args.only)
    failed = []
    for name, path in MODULES.items():
        if args.only and name not in args.only:
            continue
        print(f"\n===== {name} =====")
        try:
            importlib.import_module(path).main([])
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"\nFAILED benchmarks: {failed}")
        return 1
    print("\nall benchmarks complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
