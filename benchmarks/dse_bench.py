"""Fault-tolerant DSE overhead benchmark — the ISSUE 6 robustness tax.

The fault-isolation machinery (guarded bucket evaluation, degradation
ladder, post-fit degeneracy guards) and the journal must be near-free on
the happy path: a clean sweep under ``on_error='isolate'`` should cost
what the same sweep costs under ``'raise'``, and journaling should add
only the per-bucket atomic publish.  This benchmark times:

  sweep-raise / sweep-isolate  — the same warm design sweep with the
        guards off vs on (derived column: isolate/raise overhead ratio;
        anything well above 1.0x means the guard layer leaked onto the
        hot path)
  explore-plain / explore-journal — one full explore run without vs
        with a journal (derived: journal overhead ratio)
  explore-resume — re-running the journaled explore with resume=True
        (derived: speedup vs explore-plain; resume evaluates nothing,
        so this is the journal's read-and-restore floor)

Emits ``name,us_per_call,derived`` CSV rows (harness contract).
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit, time_pair
from repro import dse
from repro.core import simulator
from repro.core.types import ColumnConfig

N, LEN, CLASSES = 24, 8, 3
EPOCHS = 2


def _stream():
    rng = np.random.default_rng(0)
    return rng.normal(size=(N, LEN)), rng.integers(0, CLASSES, N)


def _cfgs():
    out = []
    for q in (2, 3):
        for scale in (0.9, 1.0, 1.1):
            c = ColumnConfig(p=LEN, q=q, t_max=16)
            out.append(
                c.with_threshold(scale * simulator.suggest_threshold(c))
            )
    return out


def run() -> list:
    rows = []
    x, y = _stream()
    cfgs = _cfgs()

    def sweep(on_error):
        simulator.cluster_time_series_many(
            x, y, cfgs, epochs=EPOCHS, seed=0, on_error=on_error
        )

    t_raise, t_isolate = time_pair(
        lambda: sweep("raise"), lambda: sweep("isolate"), repeats=5
    )
    rows.append(("sweep-raise", t_raise, ""))
    rows.append(
        ("sweep-isolate", t_isolate, f"{t_isolate / t_raise:.2f}x vs raise")
    )

    space = dse.DesignSpace(q=(2, 3), t_max=(16,), threshold_scale=(0.9, 1.1))

    def explore_plain():
        dse.explore(x, y, space, epochs=EPOCHS, seed=0)

    tmp = tempfile.mkdtemp(prefix="dse_bench_")

    def explore_journal():
        path = os.path.join(tmp, f"j{time.monotonic_ns()}.jsonl")
        dse.explore(x, y, space, epochs=EPOCHS, seed=0, journal=path)
        return path

    t_plain, t_journal = time_pair(explore_plain, explore_journal, repeats=5)
    rows.append(("explore-plain", t_plain, ""))
    rows.append(
        ("explore-journal", t_journal, f"{t_journal / t_plain:.2f}x vs plain")
    )

    path = explore_journal()
    t0 = time.perf_counter()
    dse.explore(x, y, space, epochs=EPOCHS, seed=0, journal=path, resume=True)
    t_resume = (time.perf_counter() - t0) * 1e6
    rows.append(
        ("explore-resume", t_resume, f"{t_plain / t_resume:.1f}x speedup")
    )
    return rows


def main(argv=None) -> int:
    rows = run()
    for name, us, derived in rows:
        emit(name, us, derived)
    print()
    print("fault-tolerant DSE overhead (warm, CPU reference lowering)")
    for name, us, derived in rows:
        print(f"  {name:<16} {us / 1e3:9.1f} ms  {derived}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main([]))
